"""Dependency-aware cluster scheduling: differential + property blitz
(ISSUE 3, DESIGN.md §13).

- differential: ``run()`` vs ``run_ref()`` bit-exact for montage / galactic
  / sipht / chain DAGs × all 6 policies × {scalar, mesh2d+contiguous,
  dragonfly+topo}, including a deps+preemption case (a victim's dependents
  must not release early);
- property-based (hypothesis shim): random layered DAGs — no start before
  deps finish or submit, node conservation at every event, makespan >= the
  critical path, engine == refsim, and the no-deps JobSet reproduces the
  seed schedule bit-for-bit;
- windows: dependency releases spanning ``simulate_window`` round
  boundaries, and multicluster conservative rounds with per-cluster DAGs;
- sweep: a policy × alloc grid over one workflow DAG compiles once, and a
  repeated sweep is a pure executable-cache hit.
"""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.api import (
    ArrayTrace, Multicluster, Scenario, SyntheticTrace, Topology,
    WorkflowTrace, run, run_ref, sweep,
)
from repro.core import metrics
from repro.core.engine import make_alloc_ctx, simulate, simulate_window
from repro.core.jobs import (
    DONE, INF_TIME, POLICY_IDS, SimState, make_jobset,
)
from repro.core.workflow import critical_path_length
from repro.refsim import simulate_reference
from repro.traces.workflows import random_layered, workflow_to_trace

ALL_POLICIES = ("fcfs", "sjf", "ljf", "bestfit", "backfill", "preempt")

# This module is the longest tier-1 differential grid (~10 min of the 20+
# min suite); it rides the slow lane — CI's required fast lane runs
# ``-m "not slow"``, the full suite runs as a separate job (ISSUE 5).
pytestmark = pytest.mark.slow

# one shared row capacity pads every DAG to the same table shape, so the
# whole differential matrix reuses a handful of compiled executables
CAP = 64

DAGS = {
    "chain": WorkflowTrace(kind="chain", params=(("n", 10), ("exec_time", 40),
                                                 ("cpu", 3))),
    "montage": WorkflowTrace(kind="montage", params=(("width", 8),)),
    "galactic": WorkflowTrace(kind="galactic", params=(("tiles", 2),
                                                       ("width", 5))),
    "sipht": WorkflowTrace(kind="sipht", params=(("width", 12),)),
}

CONFIGS = {
    "scalar": dict(total_nodes=8),
    "mesh2d_contiguous": dict(topology=Topology.mesh2d(8, 8),
                              alloc="contiguous"),
    "dragonfly_topo": dict(topology=Topology.dragonfly(8, 8), alloc="topo"),
}


# ---------------------------------------------------------------------------
# differential: run() vs run_ref() over the full matrix
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("config", sorted(CONFIGS))
@pytest.mark.parametrize("policy", ALL_POLICIES)
@pytest.mark.parametrize("dag", sorted(DAGS))
def test_run_matches_ref_workflow_matrix(dag, policy, config):
    scn = Scenario(trace=DAGS[dag], policy=policy, capacity=CAP,
                   **CONFIGS[config])
    ours, ref = run(scn), run_ref(scn)
    with_maps = scn.topology is not None
    assert ours.matches(ref, node_maps=with_maps), (dag, policy, config)
    n = int(ref.to_np()["valid"].sum())
    np.testing.assert_array_equal(ours["ready"][:n], ref["ready"])
    np.testing.assert_array_equal(ours["wait"][:n], ref["wait"])
    assert ours.to_np()["done"][:n].all()


def test_workflow_wait_is_start_minus_ready_not_submit():
    """All tasks submit at t=0 but deep tasks release late: the Fig. 7 wait
    metric must charge queueing only from the release point."""
    scn = Scenario(trace=DAGS["montage"], total_nodes=8, policy="fcfs",
                   capacity=CAP)
    out = run(scn).to_np()
    v = out["valid"]
    assert (out["submit"][v] == 0).all()
    assert (out["ready"][v] > 0).any()          # non-root tasks release late
    np.testing.assert_array_equal(
        out["wait"][v], out["start"][v] - out["ready"][v])
    assert (out["wait"][v] >= 0).all()
    # summary() consumes the ready-based wait
    s = run(scn).summary()
    w = out["wait"][v & out["done"]].astype(float)
    assert s["avg_wait"] == pytest.approx(w.mean())


def test_cpath_priority_flows_through_preempt_policy():
    spec = WorkflowTrace(kind="galactic", params=(("tiles", 2), ("width", 5)),
                         priority="cpath")
    trace = spec.materialize()
    assert "priority" in trace
    scn = Scenario(trace=spec, total_nodes=8, policy="preempt", capacity=CAP)
    assert run(scn).matches(run_ref(scn))


# ---------------------------------------------------------------------------
# deps + preemption: a victim's dependents must not release early
# ---------------------------------------------------------------------------


def test_preempted_dependency_does_not_release_dependents():
    # A (low priority, 4 nodes) starts at 0; B (high priority) preempts it at
    # t=10; C depends on A.  A is WAITING (not DONE) while suspended, so C
    # must release only at A's true finish (120), never at its preemption.
    trace = {
        "submit": np.array([0, 10, 0]),
        "runtime": np.array([100, 20, 10]),
        "nodes": np.array([4, 4, 2]),
        "estimate": np.array([100, 20, 10]),
        "priority": np.array([5, 0, 5]),
        "deps": [(2, 0)],                      # C depends on A
    }
    scn = Scenario(trace=dict(trace), total_nodes=4, policy="preempt")
    out = run(scn).to_np()
    # rows sort to (submit, id): A=0, C=1, B=2
    a, c, b = 0, 1, 2
    assert out["start"][b] == 10               # preemptor waits zero seconds
    assert out["finish"][a] == 120             # 10 run + 20 suspended + 90 left
    assert out["ready"][c] == 120
    assert out["start"][c] >= out["finish"][a]
    ref = run_ref(scn)
    assert run(scn).matches(ref)
    np.testing.assert_array_equal(out["ready"][:3], ref["ready"])


# ---------------------------------------------------------------------------
# property-based: random layered DAGs
# ---------------------------------------------------------------------------


def dag_strategy():
    @st.composite
    def build(draw):
        seed = draw(st.integers(0, 2**31 - 1))
        layers = draw(st.integers(2, 6))
        wf = random_layered(30, layers, p_edge=0.2, seed=seed)
        return workflow_to_trace(wf)
    return build()


@settings(max_examples=25, deadline=None)
@given(trace=dag_strategy(), policy=st.sampled_from(ALL_POLICIES),
       total_nodes=st.sampled_from([8, 16]))
def test_workflow_invariants(trace, policy, total_nodes):
    jobs = make_jobset(trace["submit"], trace["runtime"], trace["nodes"],
                       trace["estimate"], deps=trace["deps"],
                       total_nodes=total_nodes)
    res = simulate(jobs, POLICY_IDS[policy], total_nodes)
    out = {k: np.asarray(getattr(res, k))
           for k in ("start", "finish", "ready", "wait", "done")}
    out.update(submit=np.asarray(jobs.submit), nodes=np.asarray(jobs.nodes),
               runtime=np.asarray(jobs.runtime),
               valid=np.asarray(jobs.valid), makespan=int(res.makespan))
    v = out["valid"]
    assert out["done"][v].all(), "every task completes"
    # no start before submission, nor before the release point
    assert (out["start"][v] >= out["submit"][v]).all()
    assert (out["start"][v] >= out["ready"][v]).all()
    # no job starts before ALL its dependencies finish
    deps = np.asarray(jobs.deps)
    for i, j in zip(*np.nonzero(deps)):
        assert out["start"][i] >= out["finish"][j], (i, j)
    # ready is exactly max(submit, last dep finish)
    dep_fin = np.max(np.where(deps, out["finish"][None, :], 0), axis=1)
    np.testing.assert_array_equal(
        out["ready"][v], np.maximum(out["submit"], dep_fin)[v])
    # node conservation at every event
    t, occ = metrics.occupancy_series(out)
    assert (occ <= total_nodes).all() and (occ >= 0).all()
    # makespan is bounded below by the DAG's critical path
    cp = -critical_path_length(out["runtime"][v], list(zip(*np.nonzero(deps))))
    assert out["makespan"] >= int(cp.max())


@settings(max_examples=15, deadline=None)
@given(trace=dag_strategy(), policy=st.sampled_from(ALL_POLICIES))
def test_workflow_engine_matches_refsim(trace, policy):
    jobs = make_jobset(trace["submit"], trace["runtime"], trace["nodes"],
                       trace["estimate"], deps=trace["deps"], total_nodes=16)
    res = simulate(jobs, POLICY_IDS[policy], 16)
    ref = simulate_reference(trace, policy, total_nodes=16)
    n = len(ref["start"])
    np.testing.assert_array_equal(np.asarray(res.start)[:n], ref["start"])
    np.testing.assert_array_equal(np.asarray(res.finish)[:n], ref["finish"])
    np.testing.assert_array_equal(np.asarray(res.ready)[:n], ref["ready"])


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), policy=st.sampled_from(ALL_POLICIES))
def test_no_deps_jobset_bit_identical_to_seed(seed, policy):
    """deps=[] / all-False is statically elided: the JobSet pytree and the
    schedule are bit-identical to a dependency-free (seed) construction."""
    rng = np.random.default_rng(seed)
    n = 30
    trace = dict(submit=rng.integers(0, 200, n), runtime=rng.integers(1, 80, n),
                 nodes=rng.integers(1, 9, n))
    seed_jobs = make_jobset(**trace, total_nodes=16)
    elided = make_jobset(**trace, deps=[], total_nodes=16)
    dense0 = make_jobset(**trace, deps=np.zeros((n, n), bool), total_nodes=16)
    assert elided.deps is None and dense0.deps is None
    a = simulate(seed_jobs, POLICY_IDS[policy], 16)
    b = simulate(elided, POLICY_IDS[policy], 16)
    for field in ("start", "finish", "ready", "wait"):
        np.testing.assert_array_equal(np.asarray(getattr(a, field)),
                                      np.asarray(getattr(b, field)), field)


def test_make_jobset_rejects_cycles_and_bad_pairs():
    trace = dict(submit=[0, 0, 0], runtime=[1, 1, 1], nodes=[1, 1, 1])
    with pytest.raises(ValueError, match="cycle"):
        make_jobset(**trace, deps=[(0, 1), (1, 2), (2, 0)], total_nodes=4)
    with pytest.raises(ValueError, match="self-dependency"):
        make_jobset(**trace, deps=[(1, 1)], total_nodes=4)
    with pytest.raises(ValueError, match="out of range"):
        make_jobset(**trace, deps=[(0, 7)], total_nodes=4)


def test_deps_follow_the_submit_sort_permutation():
    """Dep pairs are given in input order; rows are sorted by (submit, id).
    The matrix must be permuted with them."""
    trace = dict(submit=[50, 0], runtime=[10, 10], nodes=[1, 1])
    jobs = make_jobset(**trace, deps=[(0, 1)], total_nodes=2)  # input 0 needs 1
    deps = np.asarray(jobs.deps)
    # input job 1 (submit 0) sorts to row 0; input job 0 (submit 50) to row 1
    assert deps[1, 0] and deps.sum() == 1
    res = simulate(jobs, 0, 2)
    assert np.asarray(res.start)[1] >= np.asarray(res.finish)[0]


# ---------------------------------------------------------------------------
# windows: releases spanning round boundaries
# ---------------------------------------------------------------------------


def test_simulate_window_release_lands_in_a_later_round():
    """chain tasks run 100 s each but the conservative window is 30 s: every
    dependency release event falls 3+ rounds after its dependent was loaded,
    and the round-by-round composition must equal the single-shot run."""
    spec = WorkflowTrace(kind="chain", params=(("n", 4), ("exec_time", 100)))
    trace = spec.materialize()
    jobs = make_jobset(trace["submit"], trace["runtime"], trace["nodes"],
                       trace["estimate"], deps=trace["deps"], total_nodes=4)
    one_shot = simulate(jobs, POLICY_IDS["fcfs"], 4)

    W, ev_cap = 30, 8 * jobs.capacity + 8
    state = SimState.init(jobs, 4)
    rounds_with_release = 0
    prev_done = 0
    for r in range(20):
        state, sat = simulate_window(np.int32(POLICY_IDS["fcfs"]), jobs, state,
                                     np.int32((r + 1) * W), ev_cap)
        assert not bool(sat)
        n_done = int((np.asarray(state.jstate) == DONE).sum())
        rounds_with_release += n_done > prev_done
        prev_done = n_done
    state, sat = simulate_window(np.int32(POLICY_IDS["fcfs"]), jobs, state,
                                 np.int32(INF_TIME), ev_cap)
    assert not bool(sat)
    assert rounds_with_release >= 3          # releases really did span rounds
    np.testing.assert_array_equal(np.asarray(state.start),
                                  np.asarray(one_shot.start))
    np.testing.assert_array_equal(np.asarray(state.finish),
                                  np.asarray(one_shot.finish))


def test_simulate_window_with_alloc_ctx_and_deps():
    spec = WorkflowTrace(kind="montage", params=(("width", 6),))
    trace = spec.materialize()
    machine = Topology.mesh2d(4, 4).build()
    jobs = make_jobset(trace["submit"], trace["runtime"], trace["nodes"],
                       trace["estimate"], deps=trace["deps"], total_nodes=16)
    ctx = make_alloc_ctx(machine, "contiguous", None)
    one_shot = simulate(jobs, POLICY_IDS["backfill"], 16, machine=machine,
                        alloc="contiguous")
    ev_cap = 8 * jobs.capacity + 8
    state = SimState.init(jobs, 16, machine=machine, event_log=ev_cap)
    for r in range(40):
        state, sat = simulate_window(np.int32(POLICY_IDS["backfill"]), jobs,
                                     state, np.int32((r + 1) * 25), ev_cap, ctx)
        assert not bool(sat)
    state, sat = simulate_window(np.int32(POLICY_IDS["backfill"]), jobs, state,
                                 np.int32(INF_TIME), ev_cap, ctx)
    assert not bool(sat)
    np.testing.assert_array_equal(np.asarray(state.start),
                                  np.asarray(one_shot.start))
    np.testing.assert_array_equal(np.asarray(state.alloc_sum),
                                  np.asarray(one_shot.alloc_sum))


def test_multicluster_workflow_clusters_stay_independent():
    """Jobs with dependency edges are pinned to their cluster, so a 2-DAG
    multicluster run must equal each DAG's standalone schedule even with
    migration enabled."""
    specs = tuple(WorkflowTrace(kind="montage", seed=s, params=(("width", 6),))
                  for s in (0, 1))
    base = dict(trace=specs, total_nodes=8,
                policy="fcfs", capacity=CAP)
    mig = run(Scenario(**base, multicluster=Multicluster(window=50)))
    no_mig = run(Scenario(**base,
                          multicluster=Multicluster(window=50, migrate=False)))
    np.testing.assert_array_equal(mig["start"], no_mig["start"])
    assert mig.to_np()["migrated"] == 0
    # per-cluster slice == standalone single-cluster run
    for c, spec in enumerate(specs):
        single = run(Scenario(trace=spec, total_nodes=8, policy="fcfs",
                              capacity=CAP)).to_np()
        sl = slice(c * CAP, (c + 1) * CAP)
        np.testing.assert_array_equal(mig["start"][sl], single["start"])
        np.testing.assert_array_equal(mig["ready"][sl], single["ready"])


def test_multicluster_mixed_workflow_and_plain_clusters():
    """One DAG cluster + one dependency-free cluster: the dep-free table is
    padded with an all-False matrix so the stacked pytree is uniform, and
    only dep-free jobs may migrate."""
    scn = Scenario(
        trace=(WorkflowTrace(kind="sipht", params=(("width", 8),)),
               SyntheticTrace(n_jobs=40, seed=3, kind="das2", congest=20)),
        total_nodes=16, policy="fcfs", capacity=CAP,
        multicluster=Multicluster(window=100))
    out = run(scn).to_np()
    assert out["valid"].sum() == 18 + 40     # sipht(8) has 18 tasks
    assert out["done"][out["valid"]].all()
    assert out["dropped"] == 0


# ---------------------------------------------------------------------------
# sweep: workflow DAG grids compile once and cache across calls
# ---------------------------------------------------------------------------


def test_sweep_policy_alloc_grid_over_workflow_single_executable():
    from repro.api.sweep import _bucket_fn

    scn = Scenario(trace=WorkflowTrace(kind="galactic",
                                       params=(("tiles", 2), ("width", 5))),
                   topology=Topology.mesh2d(8, 8), policy="fcfs", capacity=CAP)
    axes = {"policy": ("fcfs", "sjf", "backfill"),
            "alloc": ("simple", "contiguous")}
    grid = sweep(scn, axes=axes)
    assert len(grid) == 6
    assert grid.n_compiles == 1              # one static bucket -> one executable
    for point, res in grid:
        assert res.matches(run_ref(res.scenario), node_maps=True), point

    # re-running the same grid is a pure cache hit: the batched runner is
    # resolved from the same lru slot (no new executable is built)
    info_before = _bucket_fn.cache_info()
    grid2 = sweep(scn, axes=axes)
    info_after = _bucket_fn.cache_info()
    assert info_after.misses == info_before.misses
    assert info_after.hits > info_before.hits
    for r1, r2 in zip(grid.results, grid2.results):
        np.testing.assert_array_equal(r1.to_np()["start"], r2.to_np()["start"])


def test_sweep_workflow_seed_is_traced_data():
    """Same DAG shape, different seeds: the dep matrix is vmap data, so a
    2-seed × 2-policy grid stays in one compile bucket."""
    scn = Scenario(trace=WorkflowTrace(kind="random",
                                       params=(("n_tasks", 24),
                                               ("n_layers", 4))),
                   total_nodes=8, policy="fcfs")
    grid = sweep(scn, axes={"trace.seed": (0, 1),
                            "policy": ("fcfs", "bestfit")})
    assert grid.n_compiles == 1
    for point, res in grid:
        assert res.matches(run_ref(res.scenario)), point
    a = grid.get(**{"trace.seed": 0}, policy="fcfs")
    b = grid.get(**{"trace.seed": 1}, policy="fcfs")
    assert not np.array_equal(a["runtime"], b["runtime"])


def test_workflow_trace_spec_hygiene():
    spec = WorkflowTrace(kind="montage", params=(("width", 8),))
    assert spec.static_key() == WorkflowTrace(
        kind="montage", seed=99, params=(("width", 8),)).static_key()
    assert spec.n_rows == 29                 # 5*width - 1 + 6 montage stages
    with pytest.raises(ValueError, match="unknown workflow kind"):
        WorkflowTrace(kind="pegasus").materialize()
    with pytest.raises(ValueError, match="unknown workflow priority"):
        WorkflowTrace(priority="hef").materialize()
    scn = Scenario(trace=spec, topology=Topology.mesh2d(4, 4), policy="fcfs")
    assert isinstance(scn.with_(**{"trace.seed": 5}).trace, WorkflowTrace)
    import repro
    assert repro.WorkflowTrace is WorkflowTrace
