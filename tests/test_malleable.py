"""Malleable jobs (ISSUE 7, DESIGN.md §17): moldable width selection and
elastic grow/shrink under queue pressure.

- model: closed-form speedup curves (Amdahl / power-law / tabulated
  efficiency), deterministic materialization into a padded per-job
  width/dilation table row-aligned with the sorted job table, int32
  clock- and node-second-overflow guards at the saturation boundary,
  validation of every curve/mode/threshold constraint;
- elision: ``malleable=None`` carries no ``mal`` subtree at all (the
  byte-identical-HLO guarantee is pinned by ``test_engine_fastpath``'s
  committed fingerprints);
- differential: engine vs refsim bit-exact (starts, finishes, chosen
  widths, dilated durations, resize counts, node-second ledgers, event
  counts, every summary scalar) over {amdahl-moldable, power-elastic} x
  {fcfs, sjf, backfill} x {scalar, mesh2d+contiguous} — the full grid
  rides the ``slow`` lane, a 4-config corner stays in the fast lane —
  plus an elastic + node-failure composition (shrink-instead-of-requeue);
- properties (hypothesis): random curves/width ranges/thresholds keep the
  engines bit-identical and chosen widths inside ``[min_width,
  max_width]``;
- sweeps: a curve-family x param x threshold grid compiles to ONE
  executable; width range and mode are static (recompile) axes;
- metrics: the ``mal_*`` summary scalars match their closed forms.
"""

import dataclasses

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.api import (
    FailureModel, MalleableModel, Multicluster, Scenario, SyntheticTrace,
    Topology, run, run_ref, sweep,
)
from repro.core.jobs import INF_TIME
from repro.malleable import MalleablePlan, make_mal_ctx, materialize_plan

POLICIES = ("fcfs", "sjf", "backfill")

AMDAHL_MOLD = MalleableModel(curve="amdahl", param=0.2, min_width=1,
                             max_width=8, mode="moldable")
POWER_ELAST = MalleableModel(curve="power", param=0.7, min_width=1,
                             max_width=8, mode="elastic", interval=30,
                             max_ticks=64, shrink_threshold=8,
                             grow_threshold=2, step=2)
CURVES = (AMDAHL_MOLD, POWER_ELAST)


def _scenario(mode, policy, mal, n_jobs=100, seed=0, **kw):
    base = dict(trace=SyntheticTrace(n_jobs=n_jobs, seed=seed, congest=4),
                policy=policy, malleable=mal)
    if mode == "mesh2d":
        base.update(topology=Topology.mesh2d(4, 8), alloc="contiguous")
    else:
        base.update(total_nodes=32)
    base.update(kw)
    return Scenario(**base)


MAL_COLS = ("mal_width", "mal_nref", "mal_nresize", "mal_node_s", "mal_dur")


def _assert_bit_exact(scn):
    res, ref = run(scn), run_ref(scn)
    assert res.matches(ref)
    a, b = res.to_np(), ref.to_np()
    n = int(b["valid"].sum())
    for key in MAL_COLS:
        np.testing.assert_array_equal(a[key][:n], b[key], err_msg=key)
    assert a["n_events"] == b["n_events"]
    sa, sb = res.summary(), ref.summary()
    assert set(sa) == set(sb)
    for key in sa:
        np.testing.assert_allclose(sa[key], sb[key], rtol=0, atol=0,
                                   err_msg=key)
    return res, ref


# ---------------------------------------------------------------------------
# model / materialization
# ---------------------------------------------------------------------------


def test_speedup_curves_closed_form():
    w = np.arange(1, 9, dtype=np.float64)
    amdahl = MalleableModel(curve="amdahl", param=0.25, max_width=8)
    np.testing.assert_allclose(amdahl.speedup(w), 1.0 / (0.25 + 0.75 / w))
    power = MalleableModel(curve="power", param=0.5, max_width=8)
    np.testing.assert_allclose(power.speedup(w), np.sqrt(w))
    eff = tuple(1.0 / (1 + 0.05 * k) for k in range(8))
    table = MalleableModel(curve="table", table=eff, max_width=8)
    np.testing.assert_allclose(table.speedup(w), w * np.asarray(eff))


def test_materialize_rows_align_with_jobset():
    # a messy trace: unsorted submits with an offset, degenerate runtimes,
    # node requests above the machine — materialize_plan must replicate
    # make_jobset's normalization so rows line up with the padded job table
    trace = {"submit": np.array([107, 103, 103, 120]),
             "runtime": np.array([50, 0, 9, 31]),
             "nodes": np.array([4, 99, 2, 1]),
             "estimate": np.array([60, 1, 9, 40])}
    mal = dataclasses.replace(POWER_ELAST, min_width=1, max_width=6)
    plan = materialize_plan(mal, trace, total_nodes=6, capacity=8)
    assert isinstance(plan, MalleablePlan)
    assert plan.capacity == 8 and plan.n_jobs == 4 and plan.n_widths == 6
    # sorted order: (103, job1), (103, job2), (107, job0), (120, job3);
    # nref = clip(min(nodes, machine), 1, 6); runtime clamped >= 1
    np.testing.assert_array_equal(plan.nref[:4], [6, 2, 4, 1])
    runtimes = [1, 9, 50, 31]
    for j, (r, nref) in enumerate(zip(runtimes, plan.nref[:4])):
        # exact at the reference width (float64 ratio is exactly 1.0)
        assert plan.dur[j, nref - 1] == r
        # dilation is monotone: wider never slower, narrower never faster
        assert (np.diff(plan.dur[j]) <= 0).all()
    # padding rows are inert
    assert (plan.dur[4:] == 1).all() and (plan.nref[4:] == 1).all()
    np.testing.assert_array_equal(
        plan.tick_time, np.arange(1, mal.max_ticks + 1) * mal.interval)
    # moldable mode has no tick stream at all
    plan2 = materialize_plan(AMDAHL_MOLD, trace, total_nodes=6)
    assert plan2.tick_time.shape == (0,) and plan2.capacity == 4

    again = materialize_plan(mal, trace, total_nodes=6, capacity=8)
    for key in ("dur", "nref", "tick_time"):
        np.testing.assert_array_equal(getattr(plan, key), getattr(again, key))


def test_validation_errors():
    with pytest.raises(ValueError, match="unknown curve"):
        MalleableModel(curve="gustafson")
    with pytest.raises(ValueError, match="serial fraction"):
        MalleableModel(curve="amdahl", param=1.5)
    with pytest.raises(ValueError, match="alpha"):
        MalleableModel(curve="power", param=0.0)
    with pytest.raises(ValueError, match="one efficiency per width"):
        MalleableModel(curve="table", table=(1.0, 0.9), max_width=8)
    with pytest.raises(ValueError, match="efficiencies"):
        MalleableModel(curve="table", table=(1.0, 1.2), min_width=1,
                       max_width=2)
    with pytest.raises(ValueError, match="only meaningful"):
        MalleableModel(curve="amdahl", table=(1.0,))
    with pytest.raises(ValueError, match="min_width <= max_width"):
        MalleableModel(min_width=8, max_width=4)
    with pytest.raises(ValueError, match="unknown mode"):
        MalleableModel(mode="evolving")
    with pytest.raises(ValueError, match="hysteresis"):
        MalleableModel(mode="elastic", shrink_threshold=2, grow_threshold=2)
    with pytest.raises(ValueError, match="max_ticks"):
        MalleableModel(mode="elastic", max_ticks=0)
    with pytest.raises(TypeError, match="mal ctx"):
        make_mal_ctx((1, 2, 3))
    with pytest.raises(ValueError, match="exceeds the machine"):
        materialize_plan(
            MalleableModel(min_width=4, max_width=8),
            {"submit": [0], "runtime": [10], "nodes": [4]}, total_nodes=2)


def test_scenario_validation():
    t = SyntheticTrace(n_jobs=8, seed=0)
    with pytest.raises(TypeError, match="MalleableModel"):
        Scenario(trace=t, total_nodes=8, malleable="amdahl")
    with pytest.raises(ValueError, match="multicluster"):
        Scenario(trace=(t, t), total_nodes=(8, 8),
                 multicluster=Multicluster(window=50), malleable=AMDAHL_MOLD)
    with pytest.raises(ValueError, match="contention"):
        Scenario(trace=t, topology=Topology.mesh2d(2, 4), alloc="contiguous",
                 contention=(1, 5), malleable=AMDAHL_MOLD)
    with pytest.raises(ValueError, match="preempt"):
        Scenario(trace=t, total_nodes=8, policy="preempt",
                 malleable=AMDAHL_MOLD)


# ---------------------------------------------------------------------------
# overflow guards at the saturation boundary (ISSUE 7 satellite)
# ---------------------------------------------------------------------------

# amdahl param=1.0 is a flat curve (S(w) == 1): dur == runtime at every
# width, so the guarded horizon is exactly submit + 2 * runtime and the
# boundaries below are closed-form.
_FLAT = MalleableModel(curve="amdahl", param=1.0, min_width=1, max_width=1)


def test_clock_overflow_guard_saturation_boundary():
    limit = int(INF_TIME)            # top = 2 * runtime >= INF_TIME raises
    ok = {"submit": [0], "runtime": [(limit - 1) // 2], "nodes": [1]}
    plan = materialize_plan(_FLAT, ok, total_nodes=1)
    assert plan.dur[0, 0] == (limit - 1) // 2
    bad = {"submit": [0], "runtime": [(limit + 1) // 2], "nodes": [1]}
    with pytest.raises(ValueError, match="int32 clock"):
        materialize_plan(_FLAT, bad, total_nodes=1)


def test_node_second_overflow_guard_saturation_boundary():
    wide = dataclasses.replace(_FLAT, max_width=8)
    # top = 2 * runtime; 8 * top >= 2**31 exactly at runtime = 2**27
    ok = {"submit": [0], "runtime": [2**27 - 1], "nodes": [8]}
    assert materialize_plan(wide, ok, total_nodes=8).dur[0, 7] == 2**27 - 1
    bad = {"submit": [0], "runtime": [2**27], "nodes": [8]}
    with pytest.raises(ValueError, match="node-second"):
        materialize_plan(wide, bad, total_nodes=8)


def test_run_just_below_saturation_is_exact():
    # a near-horizon-limit job survives both engines without wrapping
    scn = Scenario(trace={"submit": [0, 0], "runtime": [2**27 - 5, 100],
                          "nodes": [8, 8]},
                   total_nodes=8, malleable=dataclasses.replace(
                       _FLAT, max_width=8))
    res, _ = _assert_bit_exact(scn)
    out = res.to_np()
    assert int(out["finish"][:2].max()) >= 2**27 - 5
    assert (out["finish"][:2] < int(INF_TIME)).all()


# ---------------------------------------------------------------------------
# static elision
# ---------------------------------------------------------------------------


def test_malleable_none_is_statically_elided():
    # the SimResult of a rigid run carries no mal subtree at all (the
    # byte-identical-HLO guarantee is pinned by test_engine_fastpath's
    # committed fingerprints; this is the cheap pytree-level check)
    scn = Scenario(trace={"submit": [0, 1], "runtime": [5, 5],
                          "nodes": [1, 1]}, total_nodes=2)
    res = run(scn)
    assert res.raw.mal is None
    out = res.to_np()
    assert not any(k.startswith("mal_") for k in out)
    assert "total_resizes" not in res.summary()


# ---------------------------------------------------------------------------
# differential grid
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode,policy,mal", [
    ("scalar", "fcfs", AMDAHL_MOLD),
    ("scalar", "backfill", POWER_ELAST),
    ("mesh2d", "backfill", AMDAHL_MOLD),
    ("mesh2d", "sjf", POWER_ELAST),
], ids=("scalar_fcfs_mold", "scalar_backfill_elastic",
        "mesh_backfill_mold", "mesh_sjf_elastic"))
def test_differential_corner_fast(mode, policy, mal):
    _assert_bit_exact(_scenario(mode, policy, mal))


@pytest.mark.slow
@pytest.mark.parametrize("mal", CURVES, ids=("amdahl_mold", "power_elastic"))
@pytest.mark.parametrize("policy", POLICIES)
@pytest.mark.parametrize("mode", ("scalar", "mesh2d"))
def test_differential_grid(mal, policy, mode):
    _assert_bit_exact(_scenario(mode, policy, mal))


def test_elastic_resizes_actually_fire():
    res, _ = _assert_bit_exact(_scenario("scalar", "backfill", POWER_ELAST))
    s = res.summary()
    assert s["total_resizes"] > 0
    w = res.to_np()["mal_width"]
    assert w.min() >= 1 and w.max() <= 8


def test_failure_shrink_composes_with_elastic():
    # elastic + node failures: a hit on a job with width to give sheds just
    # the failed node instead of requeueing — both engines must agree on
    # every width, ledger and restart column
    scn = _scenario(
        "scalar", "backfill", POWER_ELAST,
        failures=FailureModel(mtbf=400.0, seed=3, mean_repair=50,
                              horizon=4000, max_failures=16))
    res, ref = _assert_bit_exact(scn)
    a, b = res.to_np(), ref.to_np()
    n = int(b["valid"].sum())
    np.testing.assert_array_equal(a["n_restarts"][:n], b["n_restarts"])
    assert res.summary()["total_resizes"] > 0


# ---------------------------------------------------------------------------
# properties
# ---------------------------------------------------------------------------


@pytest.mark.slow
@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**16),
       curve=st.sampled_from(("amdahl", "power")),
       param=st.floats(0.05, 0.95),
       whi=st.integers(2, 8),
       grow=st.integers(0, 2), shrink_delta=st.integers(1, 8),
       step=st.integers(1, 3),
       mal_mode=st.sampled_from(("moldable", "elastic")),
       policy=st.sampled_from(POLICIES),
       mode=st.sampled_from(("scalar", "mesh2d")))
def test_random_curves_engines_bit_exact(seed, curve, param, whi, grow,
                                         shrink_delta, step, mal_mode,
                                         policy, mode):
    mal = MalleableModel(curve=curve, param=param, min_width=1,
                         max_width=whi, mode=mal_mode, interval=25,
                         max_ticks=64, shrink_threshold=grow + shrink_delta,
                         grow_threshold=grow, step=step)
    res, _ = _assert_bit_exact(
        _scenario(mode, policy, mal, n_jobs=60, seed=seed))
    out = res.to_np()
    done = out["valid"] & out["done"]
    w = out["mal_width"][done]
    if len(w):
        assert w.min() >= 1 and w.max() <= whi


# ---------------------------------------------------------------------------
# sweeps compile once
# ---------------------------------------------------------------------------


def test_curve_sweep_single_executable():
    scn = _scenario("scalar", "backfill", POWER_ELAST, n_jobs=60)
    grid = sweep(scn, axes={
        "malleable.curve": ("amdahl", "power"),
        "malleable.param": (0.2, 0.5),
        "malleable.shrink_threshold": (6, 10),
    })
    assert grid.n_compiles == 1
    assert len(grid) == 8
    widths = set()
    for point, res in grid:
        ref = run_ref(res.scenario)
        assert res.matches(ref), point
        np.testing.assert_array_equal(
            res["mal_width"][:len(ref["mal_width"])], ref["mal_width"],
            err_msg=str(point))
        widths.add(tuple(res["mal_width"].tolist()))
    # distinct curves really steer distinct width choices
    assert len(widths) > 1


def test_width_range_and_mode_are_static_axes():
    scn = _scenario("scalar", "backfill", AMDAHL_MOLD, n_jobs=40)
    grid = sweep(scn, axes={"malleable": (
        AMDAHL_MOLD,
        dataclasses.replace(AMDAHL_MOLD, max_width=16),   # new dur-table W
        POWER_ELAST,                                      # new tick stream
    )})
    assert grid.n_compiles == 3
    for point, res in grid:
        assert res.matches(run_ref(res.scenario)), point


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------


def test_malleable_summary_closed_forms():
    res, _ = _assert_bit_exact(_scenario("scalar", "sjf", AMDAHL_MOLD))
    out, s = res.to_np(), res.summary()
    done = out["valid"] & out["done"]
    assert done.any()
    # moldable + no failures: nobody ever resizes, and the node-second
    # ledger is exactly width * dilated duration
    assert s["total_resizes"] == 0.0
    np.testing.assert_array_equal(
        out["mal_node_s"][done],
        (out["mal_width"] * out["mal_dur"])[done])
    assert s["mean_width"] == pytest.approx(out["mal_width"][done].mean())
    assert s["max_width"] == out["mal_width"][done].max()
    dil = out["mal_dur"][done] / out["runtime"][done]
    assert s["mean_dilation"] == pytest.approx(dil.mean())
    ideal = float((out["runtime"] * out["mal_nref"])[done].sum())
    assert s["parallel_efficiency"] == pytest.approx(
        ideal / out["mal_node_s"][done].sum())
    assert s["parallel_efficiency"] > 0.0
