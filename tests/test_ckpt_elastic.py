"""Elastic restore across device-count changes (subprocess: 4 -> 2 devices).

The checkpoint stores unsharded global arrays; restore re-device_puts onto
whatever mesh the restarted job has — the core of elastic scaling.

Each subprocess pays a full JAX cold start; on slow single-core containers
that can exceed any fixed limit, so the per-subprocess timeout is tunable
via ``REPRO_ELASTIC_TIMEOUT`` (seconds, default 240) and a timeout SKIPS
with a reason instead of hanging or failing tier-1.
"""

import os
import subprocess
import sys
import textwrap

import pytest

# wall-clock budget per subprocess; the pytest.mark.timeout below (enforced
# by pytest-timeout when installed, registered in pytest.ini either way)
# adds headroom for both subprocesses plus parent overhead
SUBPROC_TIMEOUT = int(os.environ.get("REPRO_ELASTIC_TIMEOUT", "240"))

_SAVE = textwrap.dedent("""
    import os, sys
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    sys.path.insert(0, "src")
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    from repro.ckpt.store import save_checkpoint
    mesh = Mesh(np.array(jax.devices()).reshape(4), ("data",))
    tree = {
        "w": jax.device_put(jnp.arange(64, dtype=jnp.float32).reshape(8, 8),
                            NamedSharding(mesh, P("data", None))),
        "b": jnp.float32(7.0),
    }
    save_checkpoint(sys.argv[1], 5, tree, extra={"devices": 4})
    print("SAVED", len(jax.devices()))
""")

_LOAD = textwrap.dedent("""
    import os, sys
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    sys.path.insert(0, "src")
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    from repro.ckpt.store import load_checkpoint
    mesh = Mesh(np.array(jax.devices()).reshape(2), ("data",))
    template = {"w": jnp.zeros((8, 8), jnp.float32), "b": jnp.float32(0)}
    shardings = {"w": NamedSharding(mesh, P("data", None)),
                 "b": NamedSharding(mesh, P())}
    tree, step, extra = load_checkpoint(sys.argv[1], template,
                                        shardings=shardings)
    assert step == 5 and extra["devices"] == 4
    assert np.array_equal(np.asarray(tree["w"]),
                          np.arange(64, dtype=np.float32).reshape(8, 8))
    assert len(tree["w"].sharding.device_set) == 2
    print("RESTORED", len(jax.devices()))
""")


def _run_step(argv, env, step: str) -> subprocess.CompletedProcess:
    try:
        return subprocess.run(argv, env=env, capture_output=True, text=True,
                              timeout=SUBPROC_TIMEOUT)
    except subprocess.TimeoutExpired:
        pytest.skip(
            f"elastic-restore {step} subprocess exceeded {SUBPROC_TIMEOUT}s "
            "(slow container; raise REPRO_ELASTIC_TIMEOUT to run it)")


@pytest.mark.timeout(2 * SUBPROC_TIMEOUT + 60)
def test_elastic_restore_across_device_counts(tmp_path):
    env = {**os.environ, "PYTHONPATH": "src"}
    env.pop("JAX_PLATFORMS", None)
    ck = str(tmp_path / "ck")
    p1 = _run_step([sys.executable, "-c", _SAVE, ck], env, "save")
    assert "SAVED 4" in p1.stdout, p1.stderr[-800:]
    p2 = _run_step([sys.executable, "-c", _LOAD, ck], env, "load")
    assert "RESTORED 2" in p2.stdout, p2.stderr[-800:]
