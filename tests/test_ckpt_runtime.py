"""Checkpoint store + fault-tolerant trainer tests."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt.store import (
    CheckpointManager, latest_step, load_checkpoint, save_checkpoint,
)
from repro.configs import get_config
from repro.data.pipeline import SyntheticTokens
from repro.optim.adamw import AdamWConfig
from repro.runtime.straggler import StragglerMonitor
from repro.runtime.trainer import Trainer, TrainerConfig


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"a": jax.random.normal(k, (8, 16)),
            "nested": {"b": jnp.arange(10, dtype=jnp.int32),
                       "c": jnp.float32(3.5)}}


def test_checkpoint_roundtrip(tmp_path):
    tree = _tree()
    save_checkpoint(str(tmp_path), 7, tree, extra={"data_step": 7})
    out, step, extra = load_checkpoint(str(tmp_path), tree)
    assert step == 7 and extra["data_step"] == 7
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_crc_detects_corruption(tmp_path):
    tree = _tree()
    path = save_checkpoint(str(tmp_path), 1, tree)
    victim = os.path.join(path, "leaf_00000.npy")
    arr = np.load(victim)
    arr.flat[0] += 1
    np.save(victim, arr)
    with pytest.raises(IOError, match="crc"):
        load_checkpoint(str(tmp_path), tree)


def test_checkpoint_keep_k(tmp_path):
    tree = _tree()
    for s in range(6):
        save_checkpoint(str(tmp_path), s, tree, keep=2)
    kept = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert kept == ["step_4", "step_5"]
    assert latest_step(str(tmp_path)) == 5


def test_checkpoint_async(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=True)
    mgr.save(3, _tree())
    mgr.wait()
    assert latest_step(str(tmp_path)) == 3


def _trainer(tmp_path, **kw):
    cfg = get_config("llama3.2-3b").reduced()
    ds = SyntheticTokens(cfg.vocab, batch=4, seq=32, seed=1)
    tcfg = TrainerConfig(steps=24, ckpt_dir=str(tmp_path), ckpt_every=8,
                         log_every=1000, **kw)
    return Trainer(cfg, AdamWConfig(lr=1e-3, total_steps=24), tcfg, ds,
                   log=lambda *_: None)


def test_failure_recovery_is_bit_deterministic(tmp_path):
    """Fault at step 13 -> restart from step 8 -> identical final history."""
    clean = _trainer(tmp_path / "clean").run()
    faulty = _trainer(tmp_path / "faulty", inject_failure_at=13).run()
    assert faulty["restarts"] == 1
    a = {h["step"]: h["loss"] for h in clean["history"]}
    b = {h["step"]: h["loss"] for h in faulty["history"]}
    for s in range(20, 24):  # steps after recovery must match exactly
        assert a[s] == b[s], f"divergence at step {s}: {a[s]} vs {b[s]}"


def test_training_reduces_loss(tmp_path):
    out = _trainer(tmp_path).run()
    losses = [h["loss"] for h in out["history"]]
    assert losses[-1] < losses[0]


def test_grad_accum_matches_full_batch():
    """accum=4 microbatching must equal the full-batch gradient step."""
    cfg = get_config("llama3.2-3b").reduced()
    ds = SyntheticTokens(cfg.vocab, batch=8, seq=16, seed=2)
    base = dict(steps=2, log_every=1000)
    t1 = Trainer(cfg, AdamWConfig(lr=1e-3), TrainerConfig(**base), ds,
                 log=lambda *_: None)
    r1 = t1.run()
    ds2 = SyntheticTokens(cfg.vocab, batch=8, seq=16, seed=2)
    t2 = Trainer(cfg, AdamWConfig(lr=1e-3), TrainerConfig(accum=4, **base),
                 ds2, log=lambda *_: None)
    r2 = t2.run()
    np.testing.assert_allclose(r1["final_loss"], r2["final_loss"],
                               rtol=2e-4)


def test_straggler_monitor_flags_slow_rank():
    mon = StragglerMonitor(4, warn_ratio=1.3, evict_ratio=2.0, patience=3)
    decisions = []
    for step in range(20):
        times = [0.1, 0.1, 0.1, 0.1]
        if step >= 8:
            times[2] = 0.5  # rank 2 becomes 5x slower
        decisions += mon.update(times)
    assert any(d.rank == 2 and d.action == "evict" for d in decisions)
    assert all(d.rank == 2 for d in decisions)


def test_data_replay_determinism():
    ds = SyntheticTokens(1000, batch=2, seq=16, seed=9)
    first = [next(ds)["tokens"].copy() for _ in range(5)]
    ds.state.step = 0  # simulate checkpoint restore
    replay = [next(ds)["tokens"].copy() for _ in range(5)]
    for a, b in zip(first, replay):
        np.testing.assert_array_equal(a, b)


def test_memmap_dataset(tmp_path):
    from repro.data.pipeline import MemmapTokens
    path = str(tmp_path / "tokens.bin")
    np.arange(10_000, dtype=np.int32).tofile(path)
    ds = MemmapTokens(path, batch=3, seq=32, seed=4)
    b1 = next(ds)
    assert b1["tokens"].shape == (3, 32)
    np.testing.assert_array_equal(b1["labels"][:, :-1], b1["tokens"][:, 1:])
