"""Engine fast paths (ISSUE 4, DESIGN.md §14): sparse dependency counters +
batched scheduling pass.

- representation: the padded ``dep_dst``/``dep_src`` edge list reconstructs
  exactly the dense matrix the engine used to carry, and the unmet counters
  initialize to the dense in-degrees;
- bit-exactness: the statically-specialized fast executable (batched prefix
  pass, direct selector dispatch) equals the fully-dynamic seed-loop
  executable — same schedule, same ``ready``/``wait`` columns — across
  policies, DAGs, and count-capped allocation strategies;
- elision: ``deps=None`` / zero-edge job tables still produce bit-identical
  results to the seed engine across all six policies;
- stacking: ``stack_jobsets`` pads members mixing edge lists of different
  lengths and edge-free tables, without changing any member's schedule.
"""

import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.api import Scenario, Topology, WorkflowTrace, run, run_ref
from repro.core import engine
from repro.core.engine import _simulate_jit, make_alloc_ctx, simulate
from repro.core.jobs import (
    POLICY_IDS, _dense_deps, make_jobset,
)
from repro.core.parallel import simulate_ensemble, stack_jobsets
from repro.traces.workflows import (
    galactic_like, montage_like, random_layered, workflow_to_trace,
)

ALL_POLICIES = ("fcfs", "sjf", "ljf", "bestfit", "backfill", "preempt")
BLOCKING = ("fcfs", "sjf", "ljf")


def _loop_simulate(jobs, policy, total_nodes, ctx=None):
    """The fully-dynamic executable: no static policy/strategy hints, so the
    scheduling pass is the seed per-start selector loop."""
    return _simulate_jit(
        jobs, jnp.asarray(POLICY_IDS[policy], jnp.int32),
        jnp.asarray(total_nodes, jnp.int32), ctx, max_events=None,
        static_policy=None, static_strategy=None)


def _assert_same(a, b, fields=("start", "finish", "ready", "wait"), msg=""):
    for f in fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(a, f)), np.asarray(getattr(b, f)),
            err_msg=f"{msg}:{f}")


# ---------------------------------------------------------------------------
# representation: edge list == dense matrix
# ---------------------------------------------------------------------------


def test_edge_list_round_trips_the_dense_matrix():
    wf = montage_like(8, seed=3)
    trace = workflow_to_trace(wf)
    n = len(trace["submit"])
    jobs = make_jobset(trace["submit"], trace["runtime"], trace["nodes"],
                       trace["estimate"], deps=trace["deps"], total_nodes=8)
    # reference: the dense normalizer permuted by the (submit, id) sort —
    # exactly what the removed dense field used to hold
    order = np.lexsort((np.arange(n), np.asarray(trace["submit"])))
    want = _dense_deps(trace["deps"], n)[order][:, order]
    got = np.asarray(jobs.deps)  # property reconstructs from the edge list
    np.testing.assert_array_equal(got[:n, :n], want)
    assert not got[n:].any() and not got[:, n:].any()
    # padding: edge list is 64-aligned, pad slots hold the OOB row index
    E = jobs.edge_capacity
    assert E % 64 == 0 and E >= want.sum()
    dst = np.asarray(jobs.dep_dst)
    assert (dst[int(want.sum()):] == jobs.capacity).all()


def test_n_unmet_initializes_to_dense_indegree():
    from repro.core.jobs import SimState

    trace = workflow_to_trace(galactic_like(tiles=2, width=5, seed=1))
    n = len(trace["submit"])
    jobs = make_jobset(trace["submit"], trace["runtime"], trace["nodes"],
                       deps=trace["deps"], total_nodes=8)
    state = SimState.init(jobs, 8)
    indeg = np.asarray(jobs.deps).sum(axis=1)
    np.testing.assert_array_equal(np.asarray(state.n_unmet), indeg)
    # no-deps tables carry the zero-size placeholder (static elision)
    plain = make_jobset(trace["submit"], trace["runtime"], trace["nodes"],
                        total_nodes=8)
    assert SimState.init(plain, 8).n_unmet.shape == (0,)


def test_make_jobset_edge_capacity_validates():
    trace = dict(submit=[0, 0, 0], runtime=[5, 5, 5], nodes=[1, 1, 1])
    jobs = make_jobset(**trace, deps=[(1, 0), (2, 1)], total_nodes=4,
                       edge_capacity=8)
    assert jobs.edge_capacity == 8
    with pytest.raises(ValueError, match="edge_capacity"):
        make_jobset(**trace, deps=[(1, 0), (2, 1)], total_nodes=4,
                    edge_capacity=1)


# ---------------------------------------------------------------------------
# bit-exactness: fast executable == seed-loop executable
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("policy", ALL_POLICIES)
def test_fast_equals_loop_on_workflow(policy):
    trace = workflow_to_trace(galactic_like(tiles=2, width=5, seed=0))
    jobs = make_jobset(trace["submit"], trace["runtime"], trace["nodes"],
                       trace["estimate"], deps=trace["deps"], total_nodes=8)
    fast = simulate(jobs, POLICY_IDS[policy], 8)       # static specialization
    slow = _loop_simulate(jobs, policy, 8)             # seed loop
    _assert_same(fast, slow, msg=policy)
    assert int(fast.n_events) == int(slow.n_events)


@pytest.mark.parametrize("policy", BLOCKING)
def test_fast_equals_loop_on_plain_trace(policy):
    rng = np.random.default_rng(7)
    n = 120
    jobs = make_jobset(rng.integers(0, 400, n), rng.integers(1, 90, n),
                       rng.integers(1, 9, n), rng.integers(1, 120, n),
                       total_nodes=16)
    _assert_same(simulate(jobs, POLICY_IDS[policy], 16),
                 _loop_simulate(jobs, policy, 16), msg=policy)


@pytest.mark.parametrize("alloc", ("simple", "spread"))
@pytest.mark.parametrize("policy", BLOCKING)
def test_fast_equals_loop_count_capped_machine(policy, alloc):
    """With a machine and a count-capped strategy the batched pass picks the
    same start set and places it in the same order — node maps included."""
    machine = Topology.mesh2d(4, 4).build()
    trace = workflow_to_trace(montage_like(6, seed=2))
    jobs = make_jobset(trace["submit"], trace["runtime"], trace["nodes"],
                       trace["estimate"], deps=trace["deps"], total_nodes=16)
    fast = simulate(jobs, POLICY_IDS[policy], 16, machine=machine, alloc=alloc)
    ctx = make_alloc_ctx(machine, alloc, None)
    slow = _simulate_jit(
        jobs, jnp.asarray(POLICY_IDS[policy], jnp.int32), jnp.asarray(16, jnp.int32),
        ctx, max_events=None, static_policy=None, static_strategy=None)
    _assert_same(fast, slow,
                 fields=("start", "finish", "alloc_first", "alloc_span",
                         "alloc_sum"), msg=f"{policy}/{alloc}")


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), policy=st.sampled_from(BLOCKING),
       total_nodes=st.sampled_from([8, 16]))
def test_fast_equals_loop_random_dags(seed, policy, total_nodes):
    trace = workflow_to_trace(random_layered(30, 4, p_edge=0.2, seed=seed))
    jobs = make_jobset(trace["submit"], trace["runtime"], trace["nodes"],
                       deps=trace["deps"], total_nodes=total_nodes)
    _assert_same(simulate(jobs, POLICY_IDS[policy], total_nodes),
                 _loop_simulate(jobs, policy, total_nodes),
                 msg=f"{policy}@{seed}")


@pytest.mark.parametrize("policy", ALL_POLICIES)
def test_no_deps_still_bit_identical_to_seed_engine(policy):
    """deps=None and zero-edge inputs compile to the seed event graph: the
    schedule matches the reference simulator row for row."""
    rng = np.random.default_rng(11)
    n = 80
    trace = dict(submit=rng.integers(0, 300, n), runtime=rng.integers(1, 70, n),
                 nodes=rng.integers(1, 9, n), estimate=rng.integers(1, 90, n),
                 priority=rng.integers(0, 3, n))
    plain = make_jobset(**trace, total_nodes=16)
    elided = make_jobset(**trace, deps=[], total_nodes=16)
    assert elided.dep_dst is None and elided.dep_src is None
    a = simulate(plain, POLICY_IDS[policy], 16)
    b = simulate(elided, POLICY_IDS[policy], 16)
    _assert_same(a, b, msg=policy)
    from repro.refsim import simulate_reference
    ref = simulate_reference(trace, policy, total_nodes=16)
    np.testing.assert_array_equal(np.asarray(a.start)[:n], ref["start"])
    np.testing.assert_array_equal(np.asarray(a.finish)[:n], ref["finish"])


# ---------------------------------------------------------------------------
# stacking: ragged edge lists + edge-free members
# ---------------------------------------------------------------------------


def test_stack_jobsets_pads_ragged_edge_lists():
    cap = 64
    dag_a = workflow_to_trace(montage_like(8, seed=0))       # pads to 64
    dag_b = workflow_to_trace(galactic_like(tiles=2, width=8, seed=0))  # 128
    rng = np.random.default_rng(0)
    plain = dict(submit=rng.integers(0, 100, 20), runtime=rng.integers(1, 50, 20),
                 nodes=rng.integers(1, 5, 20))
    js = [
        make_jobset(dag_a["submit"], dag_a["runtime"], dag_a["nodes"],
                    deps=dag_a["deps"], capacity=cap, total_nodes=8),
        make_jobset(dag_b["submit"], dag_b["runtime"], dag_b["nodes"],
                    deps=dag_b["deps"], capacity=cap, total_nodes=8),
        make_jobset(**plain, capacity=cap, total_nodes=8),   # edge-free
    ]
    assert js[0].edge_capacity != js[1].edge_capacity        # genuinely ragged
    stacked = stack_jobsets(js)
    E = max(j.edge_capacity for j in js)
    assert stacked.dep_dst.shape == (3, E) and stacked.dep_src.shape == (3, E)
    # edge-free member got only inert OOB padding
    assert (np.asarray(stacked.dep_dst[2]) == cap).all()
    # stacked members reproduce their standalone schedules bit-for-bit
    pol = np.full((3,), POLICY_IDS["fcfs"], np.int32)
    batched = simulate_ensemble(stacked, pol, np.full((3,), 8, np.int32))
    for i, j in enumerate(js):
        single = simulate(j, POLICY_IDS["fcfs"], 8)
        np.testing.assert_array_equal(np.asarray(batched.start)[i],
                                      np.asarray(single.start), f"member {i}")
        np.testing.assert_array_equal(np.asarray(batched.ready)[i],
                                      np.asarray(single.ready), f"member {i}")


def test_sweep_mixed_edge_counts_single_bucket():
    """Random-DAG seeds generate different edge counts; the sweep stacks them
    into one executable and every point still matches the reference."""
    from repro.api import sweep

    scn = Scenario(trace=WorkflowTrace(kind="random",
                                       params=(("n_tasks", 24), ("n_layers", 4))),
                   total_nodes=8, policy="fcfs")
    grid = sweep(scn, axes={"trace.seed": (0, 1, 2), "policy": ("fcfs", "sjf")})
    assert grid.n_compiles == 1
    for point, res in grid:
        assert res.matches(run_ref(res.scenario)), point


# ---------------------------------------------------------------------------
# scheduling-pass equivalence at the event level
# ---------------------------------------------------------------------------


def test_batched_pass_starts_exact_feasible_prefix():
    """Six 2-node jobs plus one dependent, 7 free nodes: FCFS starts exactly
    three (the longest prefix whose cumulative demand fits) in one event.

    The dependency edge matters twice: it makes the table eligible for the
    batched prefix pass (dep-free tables keep the selector loop), and it
    pins the prefix boundary — an off-by-one in ``take``/``n_take`` would
    start a fourth job at t=0."""
    n = 7
    trace = dict(submit=np.zeros(n), runtime=np.full(n, 50),
                 nodes=np.full(n, 2), deps=[(6, 0)])   # last job needs job 0
    jobs = make_jobset(**trace, total_nodes=7)
    assert engine._fast_order(jobs, None, POLICY_IDS["fcfs"], None) is not None
    res = simulate(jobs, POLICY_IDS["fcfs"], 7)
    start = np.asarray(res.start)
    assert (start[:3] == 0).all()            # rows 0-2 start at t=0
    assert (start[3:6] == 50).all()          # the rest wait for completions
    assert start[6] >= 50                    # dependent releases at t=50
    ref = run_ref(Scenario(trace=trace, total_nodes=7, policy="fcfs"))
    np.testing.assert_array_equal(start, ref["start"])
    np.testing.assert_array_equal(np.asarray(res.finish), ref["finish"])


# ---------------------------------------------------------------------------
# backfill batched pass (ISSUE 8, DESIGN.md §18)
# ---------------------------------------------------------------------------


BF = "backfill"
BF_FAIL = dict(mtbf=600.0, requeue="requeue", seed=7, mean_repair=50,
               horizon=4000, max_failures=32, checkpoint_interval=20,
               restart_overhead=5)


def _bf_trace(dag: bool) -> dict:
    if dag:
        t = workflow_to_trace(galactic_like(tiles=2, width=5, seed=4))
        return dict(submit=t["submit"], runtime=t["runtime"],
                    nodes=t["nodes"], estimate=t["estimate"],
                    deps=t["deps"])
    rng = np.random.default_rng(9)
    n = 60
    return dict(submit=rng.integers(0, 400, n),
                runtime=rng.integers(5, 80, n),
                nodes=rng.integers(1, 6, n),
                estimate=rng.integers(5, 100, n))


def _bf_run_three_ways(trace, *, machine=None, alloc=None, ftrace=None,
                       plan=None, total_nodes=16, msg=""):
    """simulate (batched where eligible) == seed loop == refsim, bit-exact."""
    from repro.malleable import make_mal_ctx
    from repro.refsim import simulate_reference
    from repro.reliability import make_fail_ctx

    jobs = make_jobset(trace["submit"], trace["runtime"], trace["nodes"],
                       trace["estimate"], deps=trace.get("deps"),
                       total_nodes=total_nodes)
    if plan is not None:
        from repro.malleable import materialize_plan
        plan = materialize_plan(plan, trace, total_nodes=total_nodes,
                                capacity=jobs.capacity)
    fast = simulate(jobs, POLICY_IDS[BF], total_nodes, machine=machine,
                    alloc=alloc, failures=ftrace, malleable=plan)
    ctx = make_alloc_ctx(machine, alloc, None) if machine is not None else None
    slow = _simulate_jit(
        jobs, jnp.asarray(POLICY_IDS[BF], jnp.int32),
        jnp.asarray(total_nodes, jnp.int32), ctx,
        fctx=make_fail_ctx(ftrace, n_nodes=total_nodes),
        mctx=make_mal_ctx(plan), max_events=None,
        static_policy=None, static_strategy=None)
    _assert_same(fast, slow, msg=msg)
    assert int(fast.n_events) == int(slow.n_events), msg
    ref = simulate_reference(trace, BF, total_nodes=total_nodes,
                             machine=machine,
                             alloc=alloc if alloc is not None else "simple",
                             failures=ftrace, malleable=plan)
    n = len(trace["submit"])
    for f in ("start", "finish"):
        np.testing.assert_array_equal(np.asarray(getattr(fast, f))[:n],
                                      ref[f], err_msg=f"{msg}:ref:{f}")


@pytest.mark.slow
@pytest.mark.parametrize("mold", (False, True), ids=("rigid", "moldable"))
@pytest.mark.parametrize("fail", (False, True), ids=("nofail", "failures"))
@pytest.mark.parametrize("mode", ("scalar", "mesh"))
@pytest.mark.parametrize("dag", (False, True), ids=("nodeps", "galactic"))
def test_backfill_differential_grid(dag, mode, fail, mold):
    """The full ISSUE-8 grid: batched pass (where eligible — scalar/spread
    rigid) vs seed selector loop vs refsim, bit-exact.  The mesh+contiguous
    and moldable corners run the per-start loop by eligibility (DESIGN.md
    §18's table) and must *still* match refsim — the gate itself is part of
    the contract."""
    from repro.api import FailureModel
    from repro.malleable import MalleableModel

    trace = _bf_trace(dag)
    kw = {"msg": f"{dag}/{mode}/{fail}/{mold}"}
    if mode == "mesh":
        kw.update(machine=Topology.mesh2d(4, 4).build(), alloc="contiguous")
    if fail:
        kw.update(ftrace=FailureModel(**BF_FAIL).materialize(16))
    if mold:
        kw.update(plan=MalleableModel(curve="amdahl", param=0.2, min_width=1,
                                      max_width=8, mode="moldable"))
    _bf_run_three_ways(trace, **kw)


@pytest.mark.parametrize("dag", (False, True), ids=("nodeps", "galactic"))
def test_backfill_batched_pass_fast_lane(dag):
    """Fast-lane corner of the grid above: the two cases that actually take
    the batched pass (scalar cap, rigid jobs), both trace shapes."""
    _bf_run_three_ways(_bf_trace(dag), msg=f"fastlane/{dag}")


def test_backfill_fast_order_eligibility():
    """DESIGN.md §18 eligibility: backfill batches on count-capped caps for
    BOTH dep-free and DAG tables (unlike FCFS/SJF/LJF, which batch only
    with deps); contiguous caps and malleable jobs keep the seed loop."""
    import repro.alloc as _alloc

    trace = _bf_trace(False)
    jobs = make_jobset(trace["submit"], trace["runtime"], trace["nodes"],
                       trace["estimate"], total_nodes=16)
    bf = POLICY_IDS[BF]
    assert engine._fast_order(jobs, None, bf, None) is not None
    # dep-free FCFS stays on the selector loop (prefix pass needs deps to
    # pay for itself) — backfill is the documented exception
    assert engine._fast_order(jobs, None, POLICY_IDS["fcfs"], None) is None
    machine = Topology.mesh2d(4, 4).build()
    for strat, want in (("simple", True), ("spread", True),
                        ("contiguous", False), ("topo", False)):
        ctx = make_alloc_ctx(machine, strat, None)
        got = engine._fast_order(jobs, ctx, bf, _alloc.canonical_id(strat))
        assert (got is not None) == want, strat
    # a traced strategy id (static_strategy=None) must also fall back
    ctx = make_alloc_ctx(machine, "simple", None)
    assert engine._fast_order(jobs, ctx, bf, None) is None


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), dag=st.booleans())
def test_backfill_random_traces_engine_equals_refsim(seed, dag):
    """Property: random traces (and random DAGs) keep the batched backfill
    pass bit-identical to both the ``static_policy=None`` seed loop and the
    refsim oracle."""
    from repro.refsim import simulate_reference

    if dag:
        trace = workflow_to_trace(random_layered(24, 4, p_edge=0.2, seed=seed))
    else:
        rng = np.random.default_rng(seed)
        n = 40
        trace = dict(submit=rng.integers(0, 300, n),
                     runtime=rng.integers(1, 70, n),
                     nodes=rng.integers(1, 8, n),
                     estimate=rng.integers(1, 90, n))
    jobs = make_jobset(trace["submit"], trace["runtime"], trace["nodes"],
                       trace["estimate"], deps=trace.get("deps"),
                       total_nodes=16)
    # the property is about the batched path: assert it is actually taken
    assert engine._fast_order(jobs, None, POLICY_IDS[BF], None) is not None
    fast = simulate(jobs, POLICY_IDS[BF], 16)
    slow = _loop_simulate(jobs, BF, 16)
    _assert_same(fast, slow, msg=f"bf@{seed}")
    ref = simulate_reference(trace, BF, total_nodes=16)
    n = len(trace["submit"])
    np.testing.assert_array_equal(np.asarray(fast.start)[:n], ref["start"])
    np.testing.assert_array_equal(np.asarray(fast.finish)[:n], ref["finish"])


# ---------------------------------------------------------------------------
# reliability elision (ISSUE 5): failures=None is the pre-reliability engine
# ---------------------------------------------------------------------------


def test_failures_none_hlo_identical_to_pre_reliability_head():
    """The strongest seed-identity property: lowering the engine with
    ``failures=None`` across the policy x alloc x DAG differential grid
    produces byte-identical StableHLO modules to the commit BEFORE the
    reliability subsystem existed (hashes recorded in
    ``tests/data/hlo_nofail.json`` at that commit).  Identical programs
    imply bit-identical results, so this subsumes output comparison.

    Regenerate the fixture ONLY for intentional engine-graph changes:
    ``PYTHONPATH=src:tests python tests/_hlo_fixture.py --write``.
    """
    import jax

    from _hlo_fixture import fingerprints, load_fixture

    fixture = load_fixture()
    if fixture["jax_version"] != jax.__version__:
        pytest.skip(f"fixture lowered with jax {fixture['jax_version']}, "
                    f"running {jax.__version__}")
    got = fingerprints()
    want = fixture["hashes"]
    assert set(got) == set(want)
    bad = sorted(k for k in want if want[k] != got[k])
    assert not bad, (
        f"failures=None no longer lowers to the pre-reliability HLO for "
        f"{bad}; the reliability subsystem must stay statically elided")


def test_failures_none_result_carries_no_reliability_state():
    jobs = make_jobset([0, 0], [5, 5], [1, 1], total_nodes=4)
    res = simulate(jobs, 0, 4)
    assert res.rel is None
    from repro.core.jobs import SimState
    assert SimState.init(jobs, 4).n_unmet.shape == (0,)
    assert SimState.init(jobs, 4).rel is None


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       policy=st.sampled_from(ALL_POLICIES))
def test_empty_failure_stream_is_semantically_elided(seed, policy):
    """Property over random traces: an attached-but-eventless failure model
    never perturbs the schedule (the executables differ, the event graphs
    agree — HLO identity for failures=None itself is the test above)."""
    from repro.reliability import FailureModel

    rng = np.random.default_rng(seed)
    n = 50
    trace = dict(submit=rng.integers(0, 300, n), runtime=rng.integers(1, 60, n),
                 nodes=rng.integers(1, 8, n), estimate=rng.integers(1, 80, n),
                 priority=rng.integers(0, 3, n))
    jobs = make_jobset(**trace, total_nodes=16)
    quiet = FailureModel(mtbf=1e12, max_failures=8).materialize(16)
    assert quiet.n_failures == 0
    a = simulate(jobs, POLICY_IDS[policy], 16)
    b = simulate(jobs, POLICY_IDS[policy], 16, failures=quiet)
    for f in ("start", "finish", "ready", "wait"):
        np.testing.assert_array_equal(np.asarray(getattr(a, f)),
                                      np.asarray(getattr(b, f)), f)
    assert int(a.n_events) == int(b.n_events)


def test_traced_policy_keeps_seed_semantics_under_vmap():
    """A vmapped policy axis cannot specialize statically; the ensemble path
    must still match per-policy single runs (i.e. the dynamic loop is intact
    and bit-exact)."""
    trace = workflow_to_trace(montage_like(6, seed=5))
    jobs = make_jobset(trace["submit"], trace["runtime"], trace["nodes"],
                       deps=trace["deps"], total_nodes=8)
    pols = np.asarray([POLICY_IDS[p] for p in ("fcfs", "sjf", "ljf")], np.int32)
    batched = simulate_ensemble(stack_jobsets([jobs] * 3), pols,
                                np.full((3,), 8, np.int32))
    for i, p in enumerate(("fcfs", "sjf", "ljf")):
        single = simulate(jobs, POLICY_IDS[p], 8)
        np.testing.assert_array_equal(np.asarray(batched.start)[i],
                                      np.asarray(single.start), p)
