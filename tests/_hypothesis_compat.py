"""Optional-hypothesis shim (see ISSUE 1 satellite: seed collection fix).

``from _hypothesis_compat import given, settings, st`` works whether or not
hypothesis is installed.  When it is missing, ``@given(...)`` replaces the
property test with a ``pytest.importorskip``-style skip at run time, so
deterministic tests in the same module still collect and run.
"""

from __future__ import annotations

import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised when hypothesis is absent
    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """Stands in for ``hypothesis.strategies``: every attribute lookup and
        call returns another stub, so module-level strategy construction
        (``st.integers(...)``, ``@st.composite`` builders) parses fine."""

        def __getattr__(self, _name):
            return self

        def __call__(self, *_args, **_kwargs):
            return self

    st = _AnyStrategy()

    def given(*_args, **_kwargs):
        def decorate(_fn):
            @pytest.mark.skip(reason="hypothesis not installed")
            def skipped():
                pytest.importorskip("hypothesis")
            skipped.__name__ = _fn.__name__
            skipped.__doc__ = _fn.__doc__
            return skipped
        return decorate

    def settings(*_args, **_kwargs):
        def decorate(fn):
            return fn
        return decorate
