"""End-to-end behaviour tests for the whole system."""

import numpy as np
import pytest

from repro.configs import get_config
from repro.core import metrics
from repro.core.engine import simulate_np
from repro.refsim import simulate_reference
from repro.traces import das2_like


def test_paper_fig3_occupancy_pipeline():
    """Fig 3(a) path: simulate -> occupancy series, ours vs reference."""
    trace = das2_like(400, seed=21)
    ours = simulate_np(trace, "fcfs", total_nodes=400)
    ref = simulate_reference(trace, "fcfs", total_nodes=400)
    t1, occ1 = metrics.occupancy_series(ours)
    t2, occ2 = metrics.occupancy_series(ref)
    grid = np.linspace(0, max(t1.max(), t2.max()), 200)
    s1 = metrics.sample_series(t1, occ1, grid)
    s2 = metrics.sample_series(t2, occ2, grid)
    np.testing.assert_allclose(s1, s2)


def test_paper_fig4b_policy_ordering():
    """Fig 4(b): backfill utilization >= plain FCFS on a congested trace."""
    trace = das2_like(800, seed=5)
    trace["submit"] = trace["submit"] // 3  # congest
    res = {p: metrics.summary(simulate_np(trace, p, total_nodes=400), 400)
           for p in ("fcfs", "backfill", "sjf", "ljf", "bestfit")}
    assert res["backfill"]["avg_wait"] <= res["fcfs"]["avg_wait"]
    assert res["backfill"]["utilization"] >= res["fcfs"]["utilization"] - 1e-9
    assert res["sjf"]["avg_bounded_slowdown"] <= res["ljf"]["avg_bounded_slowdown"]


def test_end_to_end_train_example(tmp_path):
    """examples/train path: reduced model, loss decreases."""
    from repro.launch.train import main
    out = main([
        "--arch", "llama3.2-3b", "--reduced", "--steps", "15",
        "--batch", "4", "--seq", "32", "--ckpt-dir", str(tmp_path),
    ])
    hist = out["history"]
    assert hist[-1]["loss"] < hist[0]["loss"]


def test_end_to_end_serve_example():
    from repro.launch.serve import serve_batch
    cfg = get_config("h2o-danube-1.8b").reduced()
    seqs, stats = serve_batch(cfg, batch=2, prompt_len=8, gen=4)
    assert seqs.shape == (2, 12)
    assert stats["tok_per_s"] > 0


def test_fleet_cost_model_roundtrip():
    """Roofline-derived job costs feed the DES (schedule_fleet path)."""
    from repro.launch.roofline import PEAK_FLOPS, model_flops
    step_s = model_flops(int(3e9), 256 * 4096, "train") / (256 * PEAK_FLOPS)
    assert 0.001 < step_s < 10.0
    trace = {
        "submit": np.zeros(4, np.int64),
        "runtime": np.full(4, max(int(step_s * 1000), 1), np.int64),
        "nodes": np.full(4, 256, np.int64),
    }
    out = simulate_np(trace, "fcfs", total_nodes=512)
    assert out["done"][:4].all()
