"""Multi-cluster DES under a real device mesh (subprocess, 4 host devices):
the shard_map + all_gather migration path must match the single-device
vmapped path bit-for-bit (conservative-sync correctness on actual SPMD)."""

import os
import subprocess
import sys
import textwrap

import pytest

_CHILD = textwrap.dedent("""
    import os, sys
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    sys.path.insert(0, "src")
    import jax, numpy as np
    from jax.sharding import Mesh
    from repro.core.jobs import POLICY_IDS, make_jobset
    from repro.core.parallel import (multicluster_result_np,
                                     simulate_multicluster, stack_jobsets)
    from repro.traces import das2_like

    C, J = 4, 120
    trs = [das2_like(J, seed=50 + s) for s in range(C)]
    jsets = [make_jobset(t["submit"], t["runtime"], t["nodes"], t["estimate"],
                         capacity=J + 32, total_nodes=96) for t in trs]
    jc = stack_jobsets(jsets)
    horizon = int(max(t["submit"].max() for t in trs) + 50_000)
    kw = dict(window=4000, horizon=horizon, migrate=True, max_export=4)

    mesh = Mesh(np.array(jax.devices()), ("sim",))
    a = simulate_multicluster(jc, POLICY_IDS["backfill"], [96] * C,
                              mesh=mesh, **kw)
    b = simulate_multicluster(jc, POLICY_IDS["backfill"], [96] * C,
                              mesh=None, **kw)
    for x, y in zip(jax.tree.leaves(a.state), jax.tree.leaves(b.state)):
        assert np.array_equal(np.asarray(x), np.asarray(y)), "sharded != vmapped"
    out = multicluster_result_np(a)
    assert out["dropped"] == 0 and out["done"].sum() == C * J
    assert not out["saturated"]
    print("SHARDED_OK migrated=", out["migrated"])
""")


@pytest.mark.timeout(600)
def test_multicluster_sharded_matches_single_device(tmp_path):
    env = {**os.environ, "PYTHONPATH": "src"}
    env.pop("JAX_PLATFORMS", None)
    p = subprocess.run([sys.executable, "-c", _CHILD], env=env,
                       capture_output=True, text=True, timeout=540)
    assert "SHARDED_OK" in p.stdout, (p.stdout[-400:], p.stderr[-800:])
