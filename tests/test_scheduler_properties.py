"""Hypothesis property tests: system invariants of the DES engine."""

import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core import metrics
from repro.core.engine import simulate_np
from repro.core.jobs import POLICY_IDS

POLICIES = list(POLICY_IDS)


def trace_strategy(max_jobs=40):
    n = st.integers(3, max_jobs)

    @st.composite
    def build(draw):
        k = draw(n)
        seed = draw(st.integers(0, 2**31 - 1))
        rng = np.random.default_rng(seed)
        return {
            "submit": rng.integers(0, 200, k),
            "runtime": rng.integers(1, 100, k),
            "nodes": rng.integers(1, 17, k),
            "estimate": rng.integers(1, 200, k),
        }
    return build()


@settings(max_examples=25, deadline=None)
@given(trace=trace_strategy(), policy=st.sampled_from(POLICIES),
       total_nodes=st.sampled_from([4, 16, 64]))
def test_invariants(trace, policy, total_nodes):
    out = simulate_np(trace, policy, total_nodes=total_nodes)
    v = out["valid"]
    assert out["done"][v].all(), "every job completes"
    # jobs never start before submission
    assert (out["start"][v] >= out["submit"][v]).all()
    # finish = start + runtime
    np.testing.assert_array_equal(
        out["finish"][v], out["start"][v] + out["runtime"][v])
    # node capacity never exceeded at any instant
    t, occ = metrics.occupancy_series(out)
    assert (occ <= total_nodes).all()
    assert (occ >= 0).all()
    # makespan bound
    assert out["makespan"] >= int((out["submit"][v] + out["runtime"][v]).max())


@settings(max_examples=10, deadline=None)
@given(trace=trace_strategy(20), policy=st.sampled_from(POLICIES))
def test_determinism(trace, policy):
    a = simulate_np(trace, policy, total_nodes=16)
    b = simulate_np(trace, policy, total_nodes=16)
    np.testing.assert_array_equal(a["start"], b["start"])


@settings(max_examples=15, deadline=None)
@given(trace=trace_strategy(30))
def test_work_conservation_across_policies(trace):
    """Total node-seconds executed is policy-invariant."""
    totals = []
    for policy in POLICIES:
        out = simulate_np(trace, policy, total_nodes=32)
        v = out["valid"]
        totals.append(int((out["nodes"][v] * out["runtime"][v]).sum()))
    assert len(set(totals)) == 1


@settings(max_examples=10, deadline=None)
@given(trace=trace_strategy(25))
def test_single_node_jobs_fcfs_equals_bestfit_waits(trace):
    """With uniform 1-node jobs every policy that never blocks idles equally:
    BestFit degenerates to FCFS."""
    trace = dict(trace)
    trace["nodes"] = np.ones_like(trace["nodes"])
    a = simulate_np(trace, "fcfs", total_nodes=4)
    b = simulate_np(trace, "bestfit", total_nodes=4)
    np.testing.assert_array_equal(a["start"], b["start"])
