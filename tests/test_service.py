"""What-if service tests (DESIGN.md §20; ISSUE 10).

The load-bearing guarantee is *differential*: every service answer must be
bit-exact against running the lowered scenario directly — ``run()`` (JAX
engine) AND ``run_ref()`` (host reference simulator) of
``apply_delta(base, delta)``.  The service is then pure plumbing over the
proven engines and can never invent numbers.

Also covered: the sweep executable-cache contract (repeated same-bucket
queries compile exactly once; bucket-splitting deltas split as predicted),
strict JSON round trips against a versioned golden fixture, and an
end-to-end HTTP smoke test running ``python -m repro.service`` in a
subprocess (skips, not fails, on slow containers — tune
``REPRO_SERVICE_TIMEOUT``).
"""

import json
import os
import subprocess
import sys
import urllib.error
import urllib.request

import numpy as np
import pytest

from _hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st

from repro import api, service
from repro.api import (
    FailureModel, Scenario, SyntheticTrace, Topology, cache_stats,
    reset_cache_stats, run, run_ref,
)
from repro.service import (
    CapacityPlanner, JobRequest, Objective, ScenarioDelta, SchemaError,
    WhatIfQuery, apply_delta, canonical_dumps,
)

SUBPROC_TIMEOUT = int(os.environ.get("REPRO_SERVICE_TIMEOUT", "240"))

DATA = os.path.join(os.path.dirname(__file__), "data",
                    "whatif_queries_v1.json")


def base_scenario(policy="fcfs", topo=False, failures=False,
                  n_jobs=60, seed=0):
    kw = {}
    if topo:
        kw.update(topology=Topology.mesh2d(4, 8), alloc="contiguous")
    else:
        kw.update(total_nodes=32)
    if failures:
        kw.update(failures=FailureModel(mtbf=300_000.0, seed=3,
                                        max_failures=64))
    return Scenario(trace=SyntheticTrace(n_jobs=n_jobs, seed=seed,
                                         kind="sdsc_sp2"),
                    policy=policy, **kw)


def assert_differential(planner, query, fleet):
    """Every evaluated point must be bit-exact vs direct run()/run_ref()
    of the independently lowered scenario."""
    points = planner.evaluate(query)
    assert points
    for p in points:
        if p.get("infeasible"):
            continue
        scn = p["scenario"]
        direct = run(scn)
        assert p["result"].matches(direct), p["label"]
        assert direct.matches(run_ref(scn)), p["label"]
        # and the lowering itself is reproducible from the query alone
        if p.get("delta") is not None:
            assert apply_delta(fleet[p["queue"]], p["delta"]) == scn
    return points


# ---------------------------------------------------------------------------
# differential harness
# ---------------------------------------------------------------------------


@pytest.mark.slow
@pytest.mark.parametrize("policy", ["fcfs", "sjf", "backfill"])
@pytest.mark.parametrize("topo", [False, True])
@pytest.mark.parametrize("failures", [False, True])
def test_differential_grid(policy, topo, failures):
    """Every query family, bit-exact vs run()+run_ref(), across
    {fcfs, sjf, backfill} x {scalar, mesh2d+contiguous} x {failures}."""
    base = base_scenario(policy, topo=topo, failures=failures)
    fleet = {"q": base}
    planner = CapacityPlanner(fleet)

    job = JobRequest(submit=50, runtime=400, nodes=8)
    assert_differential(
        planner, WhatIfQuery(kind="placement", job=job), fleet)

    deltas = [ScenarioDelta(), ScenarioDelta(policy="fcfs"),
              ScenarioDelta(inject=(job, JobRequest(submit=0, runtime=100,
                                                    nodes=4)))]
    if topo:
        deltas.append(ScenarioDelta(alloc="simple"))
    else:
        deltas.append(ScenarioDelta(add_nodes=32))
    if failures:
        deltas.append(ScenarioDelta(mtbf=150_000.0,
                                    checkpoint_interval=500))
    pts = assert_differential(
        planner, WhatIfQuery(kind="capacity", queue="q",
                             deltas=tuple(deltas)), fleet)
    assert len(pts) == len(deltas)

    if failures:
        assert_differential(
            planner, WhatIfQuery(kind="reliability", queue="q",
                                 mtbf_grid=(100_000.0, 300_000.0),
                                 checkpoint_grid=(0, 800)), fleet)


def test_differential_fast_corner():
    """One un-marked corner so the default suite always exercises the
    differential contract: batched add_nodes grid + candidate injection
    on a scalar backfill queue with failures."""
    fleet = {"q": base_scenario("backfill", failures=True, n_jobs=40)}
    planner = CapacityPlanner(fleet)
    q = WhatIfQuery(
        kind="capacity", queue="q",
        deltas=(ScenarioDelta(), ScenarioDelta(add_nodes=16),
                ScenarioDelta(add_nodes=-8),
                ScenarioDelta(inject=(JobRequest(submit=10, runtime=200,
                                                 nodes=6),))))
    pts = assert_differential(planner, q, fleet)
    ans = planner.answer(q)
    assert [p["label"] for p in ans["points"]] == [p["label"] for p in pts]
    assert ans["recommendations"][0]["rank"] == 1
    assert ans["recommended"] == ans["recommendations"][0]["label"]
    # deltas vs the baseline summary are present and consistent
    for rec in ans["recommendations"]:
        assert rec["delta"] == pytest.approx(
            rec["value"] - rec["baseline"], nan_ok=True)


def test_placement_candidate_semantics():
    """The candidate lands at the lexsort position (behind equal-submit
    incumbents), and its reported wait is its own row's wait in the
    direct run."""
    fleet = {"small": base_scenario("fcfs", n_jobs=30),
             "big": base_scenario("fcfs", n_jobs=30, seed=1)}
    # make "big" actually bigger
    fleet["big"] = fleet["big"].with_(total_nodes=64)
    planner = CapacityPlanner(fleet)
    job = JobRequest(submit=0, runtime=300, nodes=8)
    ans = planner.answer(WhatIfQuery(kind="placement", job=job))
    assert set(p["queue"] for p in ans["points"]) == {"small", "big"}
    for p in ans["points"]:
        scn = apply_delta(fleet[p["queue"]], ScenarioDelta(inject=(job,)))
        direct = run(scn).to_np()
        row = p["candidate"]["row"]
        assert p["candidate"]["wait"] == int(direct["wait"][row])
        # appended last => sorts behind every equal-submit incumbent
        sub = scn.trace.materialize()["submit"]
        assert row == int(np.sum(np.asarray(sub) <= job.submit) - 1)
    assert ans["recommended"] in ("small", "big")


def test_placement_infeasible_queue_excluded():
    fleet = {"small": base_scenario(n_jobs=20),
             "big": base_scenario(n_jobs=20).with_(total_nodes=256)}
    planner = CapacityPlanner(fleet)
    ans = planner.answer(WhatIfQuery(
        kind="placement", job=JobRequest(submit=0, runtime=50, nodes=100)))
    by_queue = {p["queue"]: p for p in ans["points"]}
    assert "infeasible" in by_queue["small"]
    assert ans["recommended"] == "big"
    # every queue too small => structured error, not a clamped answer
    with pytest.raises(SchemaError):
        planner.answer(WhatIfQuery(
            kind="placement", job=JobRequest(submit=0, runtime=50,
                                             nodes=9999)))


@pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis not installed")
@given(st.data())
@settings(max_examples=15, deadline=None)
def test_random_delta_differential(data):
    """Property: ANY valid delta stays bit-exact vs the direct engines —
    on the cold path (fresh planner) and the warm path (second answer)."""
    base = base_scenario(
        policy=data.draw(st.sampled_from(["fcfs", "sjf", "backfill"])),
        failures=data.draw(st.booleans()), n_jobs=30,
        seed=data.draw(st.integers(0, 3)))
    inject = tuple(
        JobRequest(submit=data.draw(st.integers(0, 1000)),
                   runtime=data.draw(st.integers(1, 500)),
                   nodes=data.draw(st.integers(1, 32)))
        for _ in range(data.draw(st.integers(0, 2))))
    delta = ScenarioDelta(
        add_nodes=data.draw(st.integers(-16, 64)),
        policy=data.draw(st.sampled_from(
            [None, "fcfs", "sjf", "backfill"])),
        mtbf=(data.draw(st.floats(50_000, 500_000))
              if base.failures is not None and data.draw(st.booleans())
              else None),
        inject=inject)
    fleet = {"q": base}
    planner = CapacityPlanner(fleet)
    query = WhatIfQuery(kind="capacity", queue="q", deltas=(delta,))
    for attempt in ("cold", "warm"):
        pts = planner.evaluate(query)
        scn = pts[0]["scenario"]
        assert scn == apply_delta(base, delta)
        direct = run(scn)
        assert pts[0]["result"].matches(direct), attempt
        assert direct.matches(run_ref(scn)), attempt


# ---------------------------------------------------------------------------
# compile-count regression (the persistent-executable contract)
# ---------------------------------------------------------------------------


def test_repeated_queries_compile_once():
    """Same-bucket queries pay the XLA compile exactly once: the first
    answer is the only cold execution, every repeat (different candidate
    values, same shapes) is a cache hit."""
    fleet = {"q": base_scenario("backfill", n_jobs=40)}
    planner = CapacityPlanner(fleet)
    q1 = WhatIfQuery(kind="placement",
                     job=JobRequest(submit=0, runtime=100, nodes=4))
    planner.fleet_status()  # warm the baseline bucket first
    reset_cache_stats()
    ans = planner.answer(q1)
    assert ans["cache"]["compiles"] == 1
    assert ans["cache"]["hits"] == 0
    # different job VALUES -> same InjectedTrace static key -> warm
    for submit, runtime, nodes in ((50, 700, 16), (999, 1, 1)):
        ans = planner.answer(WhatIfQuery(
            kind="placement",
            job=JobRequest(submit=submit, runtime=runtime, nodes=nodes)))
        assert ans["cache"]["compiles"] == 0, (submit, runtime, nodes)
        assert ans["cache"]["hits"] == 1


def test_bucket_splitting_deltas():
    """Deltas that change compiled shapes split buckets exactly as the
    static keys predict; traced deltas do not."""
    fleet = {"q": base_scenario("fcfs", n_jobs=40)}
    planner = CapacityPlanner(fleet)
    job = JobRequest(submit=0, runtime=100, nodes=4)

    planner.fleet_status()  # warm the baseline bucket first
    reset_cache_stats()
    # policy swap: static_policy is part of the executable key -> 2 compiles
    ans = planner.answer(WhatIfQuery(
        kind="capacity", queue="q",
        deltas=(ScenarioDelta(inject=(job,)),
                ScenarioDelta(policy="sjf", inject=(job,)))))
    assert ans["cache"]["compiles"] == 2

    # injected COUNT splits the trace shape: 1 job vs 2 jobs -> new compile;
    # repeating either count is warm
    reset_cache_stats()
    one = WhatIfQuery(kind="capacity", queue="q",
                      deltas=(ScenarioDelta(inject=(job,)),))
    two = WhatIfQuery(kind="capacity", queue="q",
                      deltas=(ScenarioDelta(inject=(job, job)),))
    assert planner.answer(one)["cache"] == {"compiles": 0, "hits": 1,
                                            "entries": cache_stats().entries}
    c = planner.answer(two)["cache"]
    assert (c["compiles"], c["hits"]) == (1, 0)
    c = planner.answer(two)["cache"]
    assert (c["compiles"], c["hits"]) == (0, 1)

    # a batched add_nodes grid on a scalar queue is ONE executable
    reset_cache_stats()
    grid = WhatIfQuery(kind="capacity", queue="q",
                       deltas=tuple(ScenarioDelta(add_nodes=d)
                                    for d in (0, 16, 32, 64)))
    c = planner.answer(grid)["cache"]
    assert (c["compiles"], c["hits"]) == (1, 0)
    c = planner.answer(grid)["cache"]
    assert (c["compiles"], c["hits"]) == (0, 1)


def test_reset_cache_stats_clear_goes_cold():
    fleet = {"q": base_scenario(n_jobs=30)}
    planner = CapacityPlanner(fleet)
    q = WhatIfQuery(kind="placement",
                    job=JobRequest(submit=0, runtime=10, nodes=1))
    planner.answer(q)
    reset_cache_stats(clear=True)
    assert cache_stats() == api.SweepCacheStats(0, 0, 0)
    ans = planner.answer(q)
    assert ans["cache"]["compiles"] == 1  # genuinely cold again


# ---------------------------------------------------------------------------
# JSON round trips + golden fixture
# ---------------------------------------------------------------------------


def test_golden_fixture_round_trips_byte_identical():
    with open(DATA, "r", encoding="utf-8") as f:
        doc = json.load(f)
    assert doc["version"] == service.SCHEMA_VERSION
    assert len(doc["queries"]) >= 3
    kinds = set()
    for entry in doc["queries"]:
        text = canonical_dumps(entry)
        q = WhatIfQuery.from_json(text)
        kinds.add(q.kind)
        # serialize -> deserialize -> re-serialize is byte-identical
        assert q.to_json() == text
        assert WhatIfQuery.from_json(q.to_json()).to_json() == text
    assert kinds == {"placement", "capacity", "reliability"}


def test_query_codec_rejects_unknown_and_missing_fields():
    good = WhatIfQuery(kind="capacity", queue="q",
                       deltas=(ScenarioDelta(add_nodes=8),)).to_json_dict()

    bad = dict(good, frobnicate=1)
    with pytest.raises(SchemaError) as e:
        WhatIfQuery.from_json_dict(bad)
    assert e.value.code == "unknown_field"

    bad = {k: v for k, v in good.items() if k != "version"}
    with pytest.raises(SchemaError) as e:
        WhatIfQuery.from_json_dict(bad)
    assert e.value.code == "missing_field"

    with pytest.raises(SchemaError) as e:
        WhatIfQuery.from_json_dict(dict(good, version=99))
    assert e.value.code == "bad_version"

    deltas = [dict(good["deltas"][0], nonsense=True)]
    with pytest.raises(SchemaError) as e:
        WhatIfQuery.from_json_dict(dict(good, deltas=deltas))
    assert e.value.code == "unknown_field"

    with pytest.raises(SchemaError):
        WhatIfQuery.from_json("not json at all {")
    with pytest.raises(SchemaError):  # kind-level validation
        WhatIfQuery.from_json_dict(dict(good, deltas=[]))


def test_fleet_codec_round_trips():
    fleet = service.demo_fleet()
    doc = service.fleet_to_json(fleet)
    text = canonical_dumps(doc)
    again = service.fleet_from_json(json.loads(text))
    assert again == fleet
    assert canonical_dumps(service.fleet_to_json(again)) == text
    # unsupported scenarios fail loudly instead of serializing partially
    with pytest.raises(SchemaError):
        service.scenario_to_json(Scenario(
            trace=(SyntheticTrace(n_jobs=5), SyntheticTrace(n_jobs=5)),
            total_nodes=8, multicluster=api.Multicluster(window=16)))


def test_apply_delta_structured_errors():
    scalar = base_scenario()
    with pytest.raises(SchemaError) as e:  # no failures to override
        apply_delta(scalar, ScenarioDelta(mtbf=1000.0))
    assert e.value.code == "unsupported"
    with pytest.raises(SchemaError):  # alloc without topology
        apply_delta(scalar, ScenarioDelta(alloc="contiguous"))
    with pytest.raises(SchemaError):  # shrink below 1 node
        apply_delta(scalar, ScenarioDelta(add_nodes=-scalar.total_nodes))
    mesh = base_scenario(topo=True)
    with pytest.raises(SchemaError) as e:  # ambiguous mesh growth
        apply_delta(mesh, ScenarioDelta(add_nodes=16))
    assert e.value.code == "unsupported"


# ---------------------------------------------------------------------------
# HTTP smoke (subprocess end-to-end)
# ---------------------------------------------------------------------------


def _post(url, payload):
    req = urllib.request.Request(
        url, data=canonical_dumps(payload).encode(),
        headers={"Content-Type": "application/json"}, method="POST")
    try:
        with urllib.request.urlopen(req, timeout=SUBPROC_TIMEOUT) as r:
            return r.status, json.loads(r.read())
    except urllib.error.HTTPError as e:
        return e.code, json.loads(e.read())


@pytest.mark.slow
@pytest.mark.timeout(2 * SUBPROC_TIMEOUT + 60)
def test_http_smoke():
    """End-to-end: `python -m repro.service --demo` in a subprocess, all
    three query families over HTTP, responses equal to direct in-process
    answers, malformed requests get structured 4xx."""
    env = dict(os.environ, JAX_PLATFORMS="cpu",
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    proc = subprocess.Popen(
        [sys.executable, "-m", "repro.service", "--demo"],
        stdout=subprocess.PIPE, stderr=subprocess.STDOUT, text=True,
        env=env)
    try:
        line = proc.stdout.readline()
        if not line.startswith("serving on "):
            rest = ""
            try:
                rest = proc.communicate(timeout=10)[0] or ""
            except subprocess.TimeoutExpired:
                pass
            pytest.fail(f"server failed to start: {line!r}\n{rest}")
        url = line.split("serving on ", 1)[1].strip()

        try:
            with urllib.request.urlopen(f"{url}/health",
                                        timeout=SUBPROC_TIMEOUT) as r:
                health = json.loads(r.read())
        except TimeoutError:
            pytest.skip(
                f"service subprocess exceeded {SUBPROC_TIMEOUT}s (slow "
                "container; raise REPRO_SERVICE_TIMEOUT to run it)")
        assert health["status"] == "ok"
        assert health["queues"] == ["batch", "flaky", "mesh"]

        queries = [
            WhatIfQuery(kind="placement",
                        job=JobRequest(submit=0, runtime=400, nodes=16)),
            WhatIfQuery(kind="capacity", queue="batch",
                        deltas=(ScenarioDelta(),
                                ScenarioDelta(add_nodes=64))),
            WhatIfQuery(kind="reliability", queue="flaky",
                        mtbf_grid=(500_000.0, 2_000_000.0),
                        objective=Objective(metric="goodput", goal="max")),
        ]
        planner = CapacityPlanner(service.demo_fleet())
        for q in queries:
            status, body = _post(f"{url}/query", q.to_json_dict())
            assert status == 200, body
            direct = planner.answer(q)
            # identical answers modulo the per-process cache counters
            for k in ("points", "recommendations", "recommended",
                      "baseline", "objective", "kind"):
                assert body[k] == json.loads(
                    canonical_dumps(direct[k])), (q.kind, k)

        # fleet aggregation over HTTP
        with urllib.request.urlopen(f"{url}/fleet",
                                    timeout=SUBPROC_TIMEOUT) as r:
            fleet = json.loads(r.read())
        assert set(fleet["queues"]) == {"batch", "flaky", "mesh"}
        for qst in fleet["queues"].values():
            assert qst["summary"]["n_jobs"] > 0

        # malformed / invalid / unknown -> structured errors
        status, body = _post(f"{url}/query", {"version": 1, "kind": "??"})
        assert status == 400 and body["error"]["type"] == "bad_value"
        status, body = _post(
            f"{url}/query",
            WhatIfQuery(kind="capacity", queue="nope",
                        deltas=(ScenarioDelta(),)).to_json_dict())
        assert status == 404 and body["error"]["type"] == "unknown_queue"
        status, body = _post(
            f"{url}/query",
            WhatIfQuery(kind="reliability", queue="batch",
                        mtbf_grid=(1e6,)).to_json_dict())
        assert status == 422 and body["error"]["type"] == "unsupported"
        req = urllib.request.Request(
            f"{url}/query", data=b"{not json", method="POST")
        try:
            urllib.request.urlopen(req, timeout=SUBPROC_TIMEOUT)
            pytest.fail("malformed JSON must 4xx")
        except urllib.error.HTTPError as e:
            assert e.code == 400
            assert json.loads(e.read())["error"]["type"] == "bad_value"
    except (TimeoutError, subprocess.TimeoutExpired):
        pytest.skip(
            f"service subprocess exceeded {SUBPROC_TIMEOUT}s (slow "
            "container; raise REPRO_SERVICE_TIMEOUT to run it)")
    finally:
        proc.terminate()
        try:
            proc.wait(timeout=10)
        except subprocess.TimeoutExpired:
            proc.kill()
