"""Paper §4.2 methodology: validate the JAX engine against the reference
simulator (CQsim analogue) — per-job exact start/finish equality, driven
end-to-end through the Scenario API (both engines consume the SAME spec)."""

import numpy as np
import pytest

from repro.api import ArrayTrace, Scenario, SyntheticTrace, run, run_ref

POLICIES = ["fcfs", "sjf", "ljf", "bestfit", "backfill"]


@pytest.mark.parametrize("policy", POLICIES)
@pytest.mark.parametrize("kind,nodes", [("das2", 400), ("sdsc_sp2", 128)])
def test_exact_match_vs_reference(policy, kind, nodes):
    scn = Scenario(trace=SyntheticTrace(n_jobs=300, seed=7, kind=kind),
                   total_nodes=nodes, policy=policy)
    ours, ref = run(scn), run_ref(scn)
    out, refnp = ours.to_np(), ref.to_np()
    n = len(refnp["start"])
    assert out["done"][:n].all()
    np.testing.assert_array_equal(out["start"][:n], refnp["start"])
    np.testing.assert_array_equal(out["finish"][:n], refnp["finish"])
    assert out["makespan"] == refnp["makespan"]


@pytest.mark.parametrize("seed", range(4))
def test_exact_match_random_small(seed):
    """Dense tiny traces maximize same-timestamp collisions (edge cases)."""
    rng = np.random.default_rng(seed)
    n = 60
    trace = ArrayTrace(
        submit=rng.integers(0, 50, n),
        runtime=rng.integers(1, 30, n),
        nodes=rng.integers(1, 9, n),
        estimate=rng.integers(1, 60, n),
    )
    for policy in POLICIES:
        scn = Scenario(trace=trace, total_nodes=8, policy=policy)
        ours, ref = run(scn), run_ref(scn)
        np.testing.assert_array_equal(
            ours["start"][:n], ref["start"],
            err_msg=f"policy={policy} seed={seed}")


def test_backfill_beats_fcfs_on_wait():
    """Qualitative paper claim (Fig 4b): EASY reduces average wait."""
    scn = Scenario(trace=SyntheticTrace(n_jobs=600, seed=11, kind="sdsc_sp2"),
                   total_nodes=128)
    f = run(scn.with_(policy="fcfs")).to_np()
    b = run(scn.with_(policy="backfill")).to_np()
    v = f["valid"]
    assert b["wait"][v].mean() <= f["wait"][v].mean()


def test_estimates_drive_sjf_not_runtime():
    n = 50
    rng = np.random.default_rng(3)
    estimate = rng.permutation(n).astype(np.int64) + 1
    scn = Scenario(
        trace=ArrayTrace(submit=np.zeros(n, np.int64),
                         runtime=np.full(n, 10, np.int64),
                         nodes=np.full(n, 8, np.int64),
                         estimate=estimate),
        total_nodes=8, policy="sjf")
    out = run(scn).to_np()
    # one job runs at a time; k-th start must be the k-th smallest estimate
    # (rows keep submission order: all submits equal)
    order_by_start = np.argsort(out["start"][:n])
    assert (np.diff(estimate[order_by_start]) > 0).all()
