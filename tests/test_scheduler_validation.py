"""Paper §4.2 methodology: validate the JAX engine against the reference
simulator (CQsim analogue) — per-job exact start/finish equality."""

import numpy as np
import pytest

from repro.core.engine import simulate_np
from repro.core.jobs import POLICY_IDS
from repro.refsim import simulate_reference
from repro.traces import das2_like, sdsc_sp2_like, synthetic_trace

POLICIES = ["fcfs", "sjf", "ljf", "bestfit", "backfill"]


@pytest.mark.parametrize("policy", POLICIES)
@pytest.mark.parametrize("trace_fn,nodes", [
    (das2_like, 400), (sdsc_sp2_like, 128),
])
def test_exact_match_vs_reference(policy, trace_fn, nodes):
    trace = trace_fn(300, seed=7)
    ref = simulate_reference(trace, policy, total_nodes=nodes)
    ours = simulate_np(trace, policy, total_nodes=nodes)
    n = len(ref["start"])
    assert ours["done"][:n].all()
    np.testing.assert_array_equal(ours["start"][:n], ref["start"])
    np.testing.assert_array_equal(ours["finish"][:n], ref["finish"])
    assert ours["makespan"] == ref["makespan"]


@pytest.mark.parametrize("seed", range(4))
def test_exact_match_random_small(seed):
    """Dense tiny traces maximize same-timestamp collisions (edge cases)."""
    rng = np.random.default_rng(seed)
    n = 60
    trace = {
        "submit": rng.integers(0, 50, n),
        "runtime": rng.integers(1, 30, n),
        "nodes": rng.integers(1, 9, n),
        "estimate": rng.integers(1, 60, n),
    }
    for policy in POLICIES:
        ref = simulate_reference(trace, policy, total_nodes=8)
        ours = simulate_np(trace, policy, total_nodes=8)
        np.testing.assert_array_equal(
            ours["start"][:n], ref["start"],
            err_msg=f"policy={policy} seed={seed}")


def test_backfill_beats_fcfs_on_wait():
    """Qualitative paper claim (Fig 4b): EASY reduces average wait."""
    trace = sdsc_sp2_like(600, seed=11)
    f = simulate_np(trace, "fcfs", total_nodes=128)
    b = simulate_np(trace, "backfill", total_nodes=128)
    v = f["valid"]
    assert b["wait"][v].mean() <= f["wait"][v].mean()


def test_estimates_drive_sjf_not_runtime():
    n = 50
    rng = np.random.default_rng(3)
    trace = {
        "submit": np.zeros(n, np.int64),
        "runtime": np.full(n, 10, np.int64),
        "nodes": np.full(n, 8, np.int64),
        "estimate": rng.permutation(n).astype(np.int64) + 1,
    }
    out = simulate_np(trace, "sjf", total_nodes=8)
    # one job runs at a time; k-th start must be the k-th smallest estimate
    # (rows keep submission order: all submits equal)
    order_by_start = np.argsort(out["start"][:n])
    assert (np.diff(trace["estimate"][order_by_start]) > 0).all()
