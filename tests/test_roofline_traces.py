"""HLO analyzer correctness (trip counts, dot flops, collectives), trace
loaders, and sharding-rule repair."""

import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.launch.hlo_analysis import analyze_hlo_text
from repro.launch.roofline import analytic_hbm_bytes, roofline_terms
from repro.sharding.rules import repair_pspec
from repro.traces.swf import load_swf


def test_analyzer_counts_loop_trips_for_flops():
    """L-layer scanned matmul: flops must be ~ 2*M*K*N*L, not /L."""
    M = K = N = 64
    L = 7

    def f(ws, x):
        def body(h, w):
            return h @ w, 0
        h, _ = jax.lax.scan(body, x, ws)
        return h

    ws = jnp.zeros((L, K, N))
    x = jnp.zeros((M, K))
    compiled = jax.jit(f).lower(ws, x).compile()
    stats = analyze_hlo_text(compiled.as_text())
    expect = 2 * M * K * N * L
    assert stats.flops == pytest.approx(expect, rel=0.05), (
        stats.flops, expect, stats.while_loops)
    # XLA's own cost_analysis undercounts by ~L (the bug we correct);
    # jax 0.4.x returns a one-dict-per-device list, newer jax a plain dict
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    xla = float(ca.get("flops", 0))
    assert xla < stats.flops


def test_analyzer_parses_collectives_with_trip_counts():
    hlo = textwrap.dedent("""\
    HloModule m

    %body (p: (s32[], f32[16,8])) -> (s32[], f32[16,8]) {
      %p = (s32[], f32[16,8]) parameter(0)
      %i = s32[] get-tuple-element(%p), index=0
      %x = f32[16,8]{1,0} get-tuple-element(%p), index=1
      %ag = f32[32,8]{1,0} all-gather(%x), dimensions={0}
      %rs = f32[16,8]{1,0} reduce-scatter(%ag), dimensions={0}, to_apply=%add
      ROOT %t = (s32[], f32[16,8]) tuple(%i, %rs)
    }

    %cond (p: (s32[], f32[16,8])) -> pred[] {
      %p = (s32[], f32[16,8]) parameter(0)
      ROOT %c = pred[] constant(true)
    }

    ENTRY %main (a: f32[16,8]) -> f32[16,8] {
      %a = f32[16,8]{1,0} parameter(0)
      %ar = f32[16,8]{1,0} all-reduce(%a), to_apply=%add
      %t0 = (s32[], f32[16,8]) tuple(%ar, %ar)
      %w = (s32[], f32[16,8]) while(%t0), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"5"}}
      ROOT %o = f32[16,8]{1,0} get-tuple-element(%w), index=1
    }
    """)
    stats = analyze_hlo_text(hlo)
    assert stats.collective_bytes["all-reduce"] == 16 * 8 * 4
    assert stats.collective_bytes["all-gather"] == 5 * 32 * 8 * 4
    assert stats.collective_bytes["reduce-scatter"] == 5 * 32 * 8 * 4  # max(in,out)
    assert stats.while_loops == {"body": 5}


def test_roofline_terms_pick_dominant():
    t = roofline_terms(flops_per_device=197e12, bytes_per_device=1.0,
                       coll_bytes_per_device=1.0)
    assert t["bottleneck"] == "compute" and t["t_compute_s"] == pytest.approx(1.0)
    t = roofline_terms(flops_per_device=1.0, bytes_per_device=819e9 * 2,
                       coll_bytes_per_device=1.0)
    assert t["bottleneck"] == "memory" and t["t_memory_s"] == pytest.approx(2.0)


def test_analytic_bytes_monotone_in_params():
    from repro.configs.base import SHAPES, get_config
    mesh = {"data": 16, "model": 16}
    small = analytic_hbm_bytes(get_config("llama3.2-3b"), SHAPES["train_4k"],
                               mesh, int(3.2e9), "train_fsdp_tp")
    big = analytic_hbm_bytes(get_config("qwen2-vl-72b"), SHAPES["train_4k"],
                             mesh, int(72e9), "train_fsdp_tp")
    assert big > small > 0


def test_repair_pspec_moves_uneven_axis():
    # jax.sharding.AxisType is absent on jax 0.4.x, where every axis is
    # implicitly Auto — construct the mesh the version-appropriate way
    # (mirrors repro.launch.mesh._mesh_kwargs)
    axis_type = getattr(jax.sharding, "AxisType", None)
    kwargs = {} if axis_type is None else {"axis_types": (axis_type.Auto,) * 2}
    mesh = jax.make_mesh((1, 1), ("data", "model"), **kwargs)

    class FakeMesh:
        shape = {"data": 16, "model": 16}
    fm = FakeMesh()
    # kv=8 not divisible by 16 -> "model" moves to head_dim (128)
    spec = repair_pspec((32, 4096, 8, 128), P(None, "data", "model", None), fm)
    assert spec == P(None, "data", None, "model")
    # nothing fits -> axis dropped entirely
    spec = repair_pspec((3, 5), P("data", "model"), fm)
    assert spec == P(None, None)
    # already fine -> untouched
    spec = repair_pspec((64, 32), P("data", "model"), fm)
    assert spec == P("data", "model")


def test_swf_parser(tmp_path):
    swf = textwrap.dedent("""\
    ; SWF header comment
    ; MaxNodes: 128
    1 0 -1 120 16 -1 -1 16 300 -1 1 1 1 1 1 -1 -1 -1
    2 30 -1 60 8 -1 -1 8 100 -1 1 1 1 1 1 -1 -1 -1
    3 60 -1 0 4 -1 -1 4 50 -1 0 1 1 1 1 -1 -1 -1
    """)
    p = tmp_path / "log.swf"
    p.write_text(swf)
    tr, rep = load_swf(str(p))
    assert len(tr["submit"]) == 2  # zero-runtime row dropped
    assert rep.n_jobs == 2 and rep.n_skipped == 1
    np.testing.assert_array_equal(tr["nodes"], [16, 8])
    np.testing.assert_array_equal(tr["estimate"], [300, 100])


def test_synthetic_traces_shape_and_determinism():
    from repro.traces import das2_like, sdsc_sp2_like
    a = das2_like(500, seed=3)
    b = das2_like(500, seed=3)
    np.testing.assert_array_equal(a["submit"], b["submit"])
    assert (a["nodes"] >= 1).all() and (a["nodes"] <= 400).all()
    assert (a["estimate"] >= a["runtime"]).all()
    c = sdsc_sp2_like(200, seed=1)
    assert (c["nodes"] <= 128).all()
    assert (np.diff(c["submit"]) >= 0).all()
