import os
import sys

# Library code must see the real (1-device) CPU host; only launch/dryrun.py
# sets the 512-device flag, in its own process.
os.environ.setdefault("JAX_PLATFORMS", "cpu")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(__file__))  # _hypothesis_compat shim

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
