"""Reliability-aware simulation (ISSUE 5, DESIGN.md §15): node failures,
requeue, and checkpoint-restart, locked down by a differential
failure-trace harness.

- model: deterministic seeded renewal streams (a node never fails while
  down, failures and repairs are kept/dropped in pairs, padding is inert),
  and the merged stream both engines walk is pinned by one shared sort;
- semantics: hand-built failure traces exercise the kill rule, the
  checkpoint rework charge, requeue-at-submit-rank, and abort's after-any
  dependent release, against closed-form expected schedules;
- differential: engine vs refsim bit-exact (starts, finishes, restarts,
  lost work, aborts, node maps) over {3 MTBF levels} x {requeue, abort} x
  {3 policies} x {scalar, mesh2d+contiguous} — the big grid rides the
  ``slow`` lane, a 4-config corner stays in the fast lane;
- properties (hypothesis): random failure streams on random traces keep
  the engines bit-identical, ``n_restarts`` matches the refsim kill log,
  completed work never exceeds submitted work plus charged rework, and no
  job is ever placed on a down node (asserted inside the refsim oracle);
- sweeps: an MTBF x requeue-policy grid compiles to ONE executable.
"""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.api import (
    ArrayTrace, FailureModel, Scenario, SyntheticTrace, Topology, run,
    run_ref, sweep,
)
from repro.core.engine import simulate
from repro.core.jobs import INF_TIME, POLICY_IDS, make_jobset
from repro.refsim import simulate_reference
from repro.reliability import (
    FAIL, REPAIR, FailureTrace, make_fail_ctx, merge_stream,
)

MTBFS = (300.0, 800.0, 2500.0)
POLICIES = ("fcfs", "sjf", "backfill")
REQUEUE_MODES = ("requeue", "abort")


def _model(mtbf, requeue="requeue", **kw):
    kw.setdefault("seed", 7)
    kw.setdefault("mean_repair", 50)
    kw.setdefault("horizon", 4000)
    kw.setdefault("max_failures", 32)
    kw.setdefault("checkpoint_interval", 20)
    kw.setdefault("restart_overhead", 5)
    return FailureModel(mtbf=mtbf, requeue=requeue, **kw)


def _trace(n=60, seed=1, total_nodes=16):
    rng = np.random.default_rng(seed)
    return dict(submit=rng.integers(0, 400, n), runtime=rng.integers(5, 80, n),
                nodes=rng.integers(1, 6, n), estimate=rng.integers(5, 100, n))


def _scenario(mode, mtbf, requeue, policy, trace=None):
    trace = trace if trace is not None else _trace()
    kw = dict(trace=ArrayTrace.from_dict(trace), policy=policy,
              failures=_model(mtbf, requeue))
    if mode == "scalar":
        return Scenario(total_nodes=16, **kw)
    return Scenario(topology=Topology.mesh2d(4, 4), alloc="contiguous", **kw)


def _assert_bit_exact(scn):
    res, ref = run(scn), run_ref(scn)
    assert res.matches(ref, node_maps=scn.topology is not None), scn
    a, b = res.to_np(), ref.to_np()
    for key in ("n_restarts", "lost_work", "aborted", "done", "ready",
                "wait"):
        n = int(b["valid"].sum())
        np.testing.assert_array_equal(a[key][:n], b[key][:n], err_msg=key)
    assert int(a["n_events"]) == int(b["n_events"])


# ---------------------------------------------------------------------------
# model: deterministic materialization, renewal invariants, stream pinning
# ---------------------------------------------------------------------------


def test_materialize_is_deterministic_and_padded():
    fm = _model(500.0)
    a, b = fm.materialize(16), fm.materialize(16)
    np.testing.assert_array_equal(a.fail_time, b.fail_time)
    np.testing.assert_array_equal(a.fail_node, b.fail_node)
    np.testing.assert_array_equal(a.repair_time, b.repair_time)
    assert a.capacity == fm.max_failures
    assert (a.fail_time[a.n_failures:] == INF_TIME).all()
    assert (a.repair_time[a.n_failures:] == INF_TIME).all()
    # sorted by (fail_time, node), repairs strictly after failures
    live_t = a.fail_time[:a.n_failures]
    assert (np.diff(live_t) >= 0).all()
    assert (a.repair_time[:a.n_failures] > live_t).all()


def test_renewal_never_fails_a_down_node():
    fm = _model(120.0, mean_repair=200, max_failures=64)
    tr = fm.materialize(8)
    for node in range(8):
        sel = tr.fail_node[:tr.n_failures] == node
        fails = tr.fail_time[:tr.n_failures][sel]
        repairs = tr.repair_time[:tr.n_failures][sel]
        # per-node intervals [fail, repair) are disjoint and ordered
        assert (fails[1:] > repairs[:-1]).all()


def test_merge_stream_orders_fail_before_repair_on_ties():
    tr = FailureTrace(
        fail_time=np.array([10, 20], np.int32),
        fail_node=np.array([0, 1], np.int32),
        repair_time=np.array([20, 30], np.int32),   # node 0 repair ties node 1 fail
        requeue=1, checkpoint_interval=0, restart_overhead=0, n_failures=2)
    t, node, kind = merge_stream(tr)
    assert t.tolist() == [10, 20, 20, 30]
    # stable sort over [fails..., repairs...]: the t=20 fail precedes the repair
    assert kind.tolist() == [FAIL, FAIL, REPAIR, REPAIR]
    assert node.tolist() == [0, 1, 0, 1]


def test_failure_model_validation():
    with pytest.raises(ValueError, match="mtbf"):
        FailureModel(mtbf=0.0)
    with pytest.raises(ValueError, match="distribution"):
        FailureModel(mtbf=10.0, distribution="pareto")
    with pytest.raises(ValueError, match="requeue"):
        FailureModel(mtbf=10.0, requeue="retry")
    with pytest.raises(ValueError, match="horizon"):
        FailureModel(mtbf=10.0, horizon=int(INF_TIME))
    with pytest.raises(TypeError, match="FailureModel"):
        Scenario(trace=_trace(), total_nodes=16,
                 failures=_model(100.0).materialize(16))
    with pytest.raises(ValueError, match="multicluster"):
        from repro.api import Multicluster
        Scenario(trace=(SyntheticTrace(n_jobs=10), SyntheticTrace(n_jobs=10)),
                 total_nodes=8, multicluster=Multicluster(window=64),
                 failures=_model(100.0))


def test_truncation_is_flagged_and_warned():
    """A stream that saturates max_failures keeps only the earliest window
    — legitimate for bounded differential tests, but a silent saturation
    would turn an MTBF sweep into a truncation study, so it is loud."""
    import repro.reliability.model as _m

    harsh = FailureModel(mtbf=50.0, mean_repair=10, horizon=4000,
                         max_failures=8)
    _m._materialize.cache_clear()       # the warning fires once per cache miss
    with pytest.warns(UserWarning, match="keeping only the earliest"):
        tr = harsh.materialize(16)
    assert tr.truncated and tr.n_failures == 8
    quiet = FailureModel(mtbf=1e9, max_failures=8)
    assert not quiet.materialize(16).truncated


def test_weibull_stream_differs_from_exponential():
    exp = FailureModel(mtbf=300.0, seed=0).materialize(8)
    wei = FailureModel(mtbf=300.0, seed=0, distribution="weibull",
                       k=0.7).materialize(8)
    assert not np.array_equal(exp.fail_time, wei.fail_time)


# ---------------------------------------------------------------------------
# semantics: hand-built traces against closed-form schedules
# ---------------------------------------------------------------------------


def _one_failure(t_fail, node, t_repair, requeue=1, ckpt=0, overhead=0):
    return FailureTrace(
        fail_time=np.array([t_fail], np.int32),
        fail_node=np.array([node], np.int32),
        repair_time=np.array([t_repair], np.int32),
        requeue=requeue, checkpoint_interval=ckpt, restart_overhead=overhead,
        n_failures=1)


def test_checkpoint_rework_closed_form():
    """One 4-node job on 4 nodes, killed at t=50 with 20s checkpoints:
    work since the last checkpoint (10s) is lost, the job waits out the
    repair (t=80) because it needs the whole machine, and finishes at
    80 + remaining(50) + lost(10) + overhead(5) = 145."""
    jobs = make_jobset([0], [100], [4], total_nodes=4)
    ft = _one_failure(50, 2, 80, ckpt=20, overhead=5)
    res = simulate(jobs, POLICY_IDS["fcfs"], 4, failures=ft)
    assert int(res.start[0]) == 0
    assert int(res.finish[0]) == 145
    assert int(res.rel.n_restarts[0]) == 1
    assert int(res.rel.lost_work[0]) == 15       # 10 rework + 5 overhead
    assert not bool(res.rel.aborted[0])
    ref = simulate_reference(dict(submit=[0], runtime=[100], nodes=[4]),
                             "fcfs", total_nodes=4, failures=ft)
    assert ref["finish"][0] == 145 and ref["n_restarts"][0] == 1
    assert len(ref["kill_log"]) == 1 and ref["kill_log"][0]["lost"] == 10


def test_no_checkpoint_means_full_rework():
    """checkpoint_interval=0: the whole 50s of progress is lost."""
    jobs = make_jobset([0], [100], [4], total_nodes=4)
    ft = _one_failure(50, 0, 60, ckpt=0)
    res = simulate(jobs, POLICY_IDS["fcfs"], 4, failures=ft)
    # restart at repair (t=60): remaining 50 + lost 50 => finish 160
    assert int(res.finish[0]) == 160
    assert int(res.rel.lost_work[0]) == 50


def test_requeue_rejoins_at_submit_rank():
    """The killed job outranks later submits when it requeues: FCFS keys on
    submit, so the victim (submit=0) restarts before the t=5 job."""
    trace = dict(submit=[0, 5], runtime=[100, 30], nodes=[4, 4])
    jobs = make_jobset(**trace, total_nodes=4)
    ft = _one_failure(50, 1, 55, ckpt=0)
    res = simulate(jobs, POLICY_IDS["fcfs"], 4, failures=ft)
    ref = simulate_reference(trace, "fcfs", total_nodes=4, failures=ft)
    np.testing.assert_array_equal(np.asarray(res.start)[:2], ref["start"])
    np.testing.assert_array_equal(np.asarray(res.finish)[:2], ref["finish"])
    # victim restarts at t=55 (repair), job 1 waits for it to finish
    assert int(res.start[1]) > int(res.finish[0]) - 30 - 1  # sanity
    assert ref["start"][1] == ref["finish"][0]


def test_abort_terminates_and_releases_dependents():
    """Under "abort" the killed job is DONE-but-failed at the kill time and
    its dependents release immediately (after-any), not at its would-be
    completion."""
    trace = dict(submit=[0, 0], runtime=[100, 10], nodes=[4, 1],
                 deps=[(1, 0)])
    jobs = make_jobset(**trace, total_nodes=4)
    ft = _one_failure(40, 3, 90, requeue=0)
    res = simulate(jobs, POLICY_IDS["fcfs"], 4, failures=ft)
    assert bool(res.rel.aborted[0]) and not bool(res.rel.aborted[1])
    assert int(res.finish[0]) == 40              # abort time, not 100
    assert not bool(res.done[0]) and bool(res.done[1])
    assert int(res.ready[1]) == 40               # released by the abort
    ref = simulate_reference(trace, "fcfs", total_nodes=4, failures=ft)
    assert ref["aborted"][0] and ref["ready"][1] == 40
    np.testing.assert_array_equal(np.asarray(res.start)[:2], ref["start"])
    # makespan excludes the aborted job's would-be finish
    assert int(res.makespan) == int(res.finish[1]) == ref["makespan"]


def test_requeue_does_not_release_dependents_early():
    """A requeued dependency is WAITING, not DONE: its dependent releases
    only at the real (post-restart) completion."""
    trace = dict(submit=[0, 0], runtime=[100, 10], nodes=[4, 1],
                 deps=[(1, 0)])
    jobs = make_jobset(**trace, total_nodes=4)
    ft = _one_failure(40, 3, 45, requeue=1, ckpt=0)
    res = simulate(jobs, POLICY_IDS["fcfs"], 4, failures=ft)
    # restart at 45 with full 100s rework => finish 145; dependent after
    assert int(res.finish[0]) == 145
    assert int(res.ready[1]) == 145
    ref = simulate_reference(trace, "fcfs", total_nodes=4, failures=ft)
    np.testing.assert_array_equal(np.asarray(res.finish)[:2], ref["finish"])


def test_idle_node_failure_shrinks_capacity_only():
    """A failure landing on an idle slot kills nobody but removes one node
    from service until the repair."""
    trace = dict(submit=[0, 10], runtime=[20, 20], nodes=[2, 4])
    jobs = make_jobset(**trace, total_nodes=4)
    # scalar slot rule: at t=5 busy=2 (job 0), n_up=4, node id 2 -> slot 2
    # >= busy -> idle hit; job 1 (4 nodes) must wait for the repair at 30
    ft = _one_failure(5, 2, 30)
    res = simulate(jobs, POLICY_IDS["fcfs"], 4, failures=ft)
    assert int(res.rel.n_restarts.sum()) == 0
    assert int(res.start[1]) == 30
    ref = simulate_reference(trace, "fcfs", total_nodes=4, failures=ft)
    assert ref["kill_log"] == [] and ref["start"][1] == 30


def test_down_node_is_never_placed_on_mesh():
    """Machine mode: the failed node is excluded from placement until its
    repair — the job that fits only with that node waits."""
    topo = Topology.mesh2d(2, 2)
    trace = dict(submit=[0, 2], runtime=[50, 20], nodes=[2, 2])
    scn = Scenario(trace=ArrayTrace.from_dict(trace), topology=topo,
                   policy="fcfs", alloc="simple",
                   failures=_model(1e9, max_failures=1))
    # node 3 fails at t=1 (idle — job 0 holds nodes 0,1; job 1 submits later),
    # and is back only at t=100
    ft = _one_failure(1, 3, 100)
    jobs = make_jobset(**trace, total_nodes=4)
    res = simulate(jobs, POLICY_IDS["fcfs"], 4, machine=topo.build(),
                   alloc="simple", failures=ft)
    # job 1 needs 2 nodes; only node 2 is up+free until t=50... then job 0's
    # nodes free at 50 -> job 1 starts at 50 on nodes 0,1 (first-fit)
    assert int(res.start[1]) == 50
    assert int(res.alloc_first[1]) == 0
    ref = simulate_reference(trace, "fcfs", total_nodes=4,
                             machine=topo.build(), alloc="simple", failures=ft)
    np.testing.assert_array_equal(np.asarray(res.start)[:2], ref["start"])
    np.testing.assert_array_equal(np.asarray(res.alloc_sum)[:2],
                                  ref["alloc_sum"])
    assert scn.failures.max_failures == 1  # scenario spec sanity


# ---------------------------------------------------------------------------
# differential grid: engine vs refsim bit-exact
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode", ("scalar", "mesh"))
@pytest.mark.parametrize("requeue", REQUEUE_MODES)
def test_differential_corner_fast(mode, requeue):
    """Fast-lane corner of the big grid: one MTBF, FCFS, both kill rules,
    both machine modes."""
    _assert_bit_exact(_scenario(mode, 800.0, requeue, "fcfs"))


@pytest.mark.slow
@pytest.mark.parametrize("mode", ("scalar", "mesh"))
@pytest.mark.parametrize("policy", POLICIES)
@pytest.mark.parametrize("requeue", REQUEUE_MODES)
@pytest.mark.parametrize("mtbf", MTBFS)
def test_differential_grid(mtbf, requeue, policy, mode):
    """The full {3 MTBF} x {requeue, abort} x {3 policies} x {scalar,
    mesh2d+contiguous} differential grid (ISSUE 5 acceptance)."""
    _assert_bit_exact(_scenario(mode, mtbf, requeue, policy))


@pytest.mark.slow
@pytest.mark.parametrize("policy", ("preempt", "bestfit", "ljf"))
def test_differential_remaining_policies_scalar(policy):
    """The policies outside the headline grid stay bit-exact too (preempt
    composes kills with preemption suspends)."""
    trace = _trace(seed=3)
    trace["priority"] = np.random.default_rng(3).integers(0, 3, 60)
    _assert_bit_exact(_scenario("scalar", 500.0, "requeue", policy, trace))


def test_zero_failure_stream_matches_failures_none():
    """A failure model whose horizon produces no events is semantically the
    no-failure engine: bit-identical schedules (the executables differ —
    HLO identity for failures=None itself is pinned in
    test_engine_fastpath)."""
    trace = _trace(seed=5)
    jobs = make_jobset(**trace, total_nodes=16)
    quiet = FailureModel(mtbf=1e12, max_failures=8, horizon=1 << 19)
    ft = quiet.materialize(16)
    assert ft.n_failures == 0
    for policy in ("fcfs", "backfill"):
        a = simulate(jobs, POLICY_IDS[policy], 16)
        b = simulate(jobs, POLICY_IDS[policy], 16, failures=ft)
        np.testing.assert_array_equal(np.asarray(a.start), np.asarray(b.start))
        np.testing.assert_array_equal(np.asarray(a.finish),
                                      np.asarray(b.finish))
        assert int(b.rel.n_restarts.sum()) == 0
        assert int(a.n_events) == int(b.n_events)
    assert a.rel is None and b.rel is not None


# ---------------------------------------------------------------------------
# sweeps: failure arrays are vmap leaves
# ---------------------------------------------------------------------------


def test_mtbf_sweep_single_executable():
    scn = Scenario(trace=SyntheticTrace(n_jobs=50, seed=0, kind="sdsc_sp2",
                                        congest=4),
                   total_nodes=32, policy="fcfs", failures=_model(500.0))
    grid = sweep(scn, axes={
        "failures.mtbf": (200.0, 400.0, 600.0, 900.0, 1500.0, 3000.0),
        "failures.requeue": ("requeue", "abort"),
    })
    assert grid.n_compiles == 1
    for point, res in grid:
        ref = run_ref(res.scenario)
        assert res.matches(ref), point
        np.testing.assert_array_equal(res["n_restarts"], ref["n_restarts"])
    # the reliability axis is live: kills happen, and low MTBF materializes
    # at least as many failures as high MTBF (restart *counts* need not be
    # monotone — max_failures truncates the low-MTBF stream to its earliest
    # window, so late-arriving jobs there run failure-free)
    n_restarts = {p["failures.mtbf"]: s["total_restarts"]
                  for p, s in zip(grid.points, grid.summaries())
                  if p["failures.requeue"] == "requeue"}
    assert any(v > 0 for v in n_restarts.values())
    fails_at = {m: _model(m, "requeue").materialize(32).n_failures
                for m in (200.0, 3000.0)}
    assert fails_at[200.0] >= fails_at[3000.0]


def test_total_nodes_stays_a_vmap_axis_with_failures():
    """Scalar-counter mode: machine size is traced data even with a failure
    model attached (streams materialize host-side per point; no compiled
    shape depends on total_nodes without a topology)."""
    scn = Scenario(trace=SyntheticTrace(n_jobs=30, seed=0), total_nodes=16,
                   failures=_model(800.0))
    grid = sweep(scn, axes={"total_nodes": (12, 16, 24),
                            "failures.mtbf": (400.0, 2500.0)})
    assert grid.n_compiles == 1
    for point, res in grid:
        assert res.matches(run_ref(res.scenario)), point


def test_max_failures_is_a_static_axis():
    scn = Scenario(trace=SyntheticTrace(n_jobs=30, seed=0), total_nodes=16,
                   failures=_model(500.0))
    grid = sweep(scn, axes={"failures.max_failures": (16, 32)})
    assert grid.n_compiles == 2          # padded capacity recompiles
    for point, res in grid:
        assert res.matches(run_ref(res.scenario)), point


def test_ensemble_failures_axis():
    """repro.core.parallel.simulate_ensemble batches stacked fail ctxs."""
    import jax
    import jax.numpy as jnp
    from repro.core.parallel import simulate_ensemble, stack_jobsets

    trace = _trace(n=30, seed=2)
    jobs = make_jobset(**trace, total_nodes=16)
    models = [_model(m) for m in (300.0, 900.0, 2500.0)]
    fctxs = [make_fail_ctx(m, n_nodes=16) for m in models]
    fail_b = jax.tree.map(lambda *xs: jnp.stack(xs), *fctxs)
    batched = simulate_ensemble(
        stack_jobsets([jobs] * 3),
        np.full(3, POLICY_IDS["fcfs"], np.int32),
        np.full(3, 16, np.int32), failures_b=fail_b)
    for i, m in enumerate(models):
        single = simulate(jobs, POLICY_IDS["fcfs"], 16, failures=fctxs[i])
        np.testing.assert_array_equal(np.asarray(batched.start)[i],
                                      np.asarray(single.start), f"member {i}")
        np.testing.assert_array_equal(np.asarray(batched.rel.n_restarts)[i],
                                      np.asarray(single.rel.n_restarts))


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------


def test_reliability_summary_scalars():
    scn = _scenario("scalar", 300.0, "requeue", "fcfs")
    s = run(scn).summary()
    for key in ("total_restarts", "n_aborted", "lost_node_s", "goodput"):
        assert key in s
    assert 0.0 < s["goodput"] <= 1.0
    assert s["n_aborted"] == 0.0
    s_abort = run(_scenario("scalar", 300.0, "abort", "fcfs")).summary()
    assert s_abort["n_aborted"] > 0
    # failure-free summaries stay clean
    s0 = run(Scenario(trace=SyntheticTrace(n_jobs=20), total_nodes=8)).summary()
    assert "goodput" not in s0


# ---------------------------------------------------------------------------
# hypothesis: random failure streams
# ---------------------------------------------------------------------------


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       mtbf=st.sampled_from([150.0, 500.0, 2000.0]),
       requeue=st.sampled_from(REQUEUE_MODES),
       ckpt=st.sampled_from([0, 15, 40]),
       policy=st.sampled_from(POLICIES))
def test_random_streams_engines_bit_exact(seed, mtbf, requeue, ckpt, policy):
    """Engine vs refsim over random traces x random failure streams, plus
    the kill-log audit: n_restarts == per-job requeue kills, aborted ==
    per-job abort kills, and (refsim-internal assert) no job ever lands on
    a down node."""
    rng = np.random.default_rng(seed)
    n = 40
    trace = dict(submit=rng.integers(0, 300, n).tolist(),
                 runtime=rng.integers(5, 60, n).tolist(),
                 nodes=rng.integers(1, 5, n).tolist())
    fm = FailureModel(mtbf=mtbf, seed=seed % 1000, mean_repair=40,
                      horizon=3000, max_failures=32, requeue=requeue,
                      checkpoint_interval=ckpt)
    ft = fm.materialize(16)
    jobs = make_jobset(**trace, total_nodes=16)
    res = simulate(jobs, POLICY_IDS[policy], 16, failures=ft)
    ref = simulate_reference(trace, policy, total_nodes=16, failures=ft)
    np.testing.assert_array_equal(np.asarray(res.start)[:n], ref["start"])
    np.testing.assert_array_equal(np.asarray(res.finish)[:n], ref["finish"])
    np.testing.assert_array_equal(np.asarray(res.rel.n_restarts)[:n],
                                  ref["n_restarts"])
    np.testing.assert_array_equal(np.asarray(res.rel.aborted)[:n],
                                  ref["aborted"])
    # kill-log audit
    log = ref["kill_log"]
    from collections import Counter
    requeues = Counter(k["job"] for k in log if k["requeued"])
    aborts = Counter(k["job"] for k in log if not k["requeued"])
    for i in range(n):
        assert ref["n_restarts"][i] == requeues.get(i, 0)
        assert ref["aborted"][i] == (aborts.get(i, 0) > 0)
    # completed work never exceeds submitted work + charged rework:
    # elapsed wall time >= runtime + lost rework for every completed job
    done = ref["done"]
    elapsed = (ref["finish"] - ref["start"])[done]
    assert (elapsed >= (ref["runtime"] + ref["lost_work"])[done]).all()
    assert (ref["lost_work"] >= 0).all()


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_random_streams_on_mesh_with_node_maps(seed):
    """Machine-mode random streams: node maps agree and the refsim
    placement assert guarantees down nodes are never allocated."""
    rng = np.random.default_rng(seed)
    n = 30
    trace = dict(submit=rng.integers(0, 200, n).tolist(),
                 runtime=rng.integers(5, 50, n).tolist(),
                 nodes=rng.integers(1, 5, n).tolist())
    fm = FailureModel(mtbf=300.0, seed=seed % 1000, mean_repair=30,
                      horizon=2000, max_failures=24)
    scn = Scenario(trace=ArrayTrace.from_dict(trace),
                   topology=Topology.mesh2d(4, 4), policy="fcfs",
                   alloc="contiguous", failures=fm)
    assert run(scn).matches(run_ref(scn), node_maps=True)
