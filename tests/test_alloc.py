"""Topology-aware node allocation (DESIGN.md §11).

Covers the three acceptance claims of ISSUE 1:

1. ``alloc="simple"`` with contention off reproduces the seed scalar-counter
   schedule bit-for-bit on the validation traces,
2. ``contiguous`` vs ``spread`` on a dragonfly machine produce measurably
   different locality/fragmentation metrics,
3. the JAX engine matches the reference simulator exactly — starts, finishes
   *and* node-map fingerprints — under every strategy (property-style sweep
   over random traces x strategies x policies x contention).

Plus unit tests pinning each strategy's placement on hand-built machines.
"""

import numpy as np
import jax.numpy as jnp
import pytest

from repro import alloc
from repro.alloc import host
from repro.core import metrics
from repro.core.engine import simulate_np
from repro.core.jobs import POLICY_IDS, make_jobset
from repro.core.parallel import simulate_alloc_sweep
from repro.refsim import simulate_reference
from repro.traces import das2_like, sdsc_sp2_like

STRATEGIES = ["simple", "contiguous", "spread", "topo"]
POLICIES = ["fcfs", "sjf", "ljf", "bestfit", "backfill", "preempt"]


def place_ids(strategy, machine, owner, need):
    mask = np.asarray(alloc.place(
        jnp.int32(alloc.alloc_id(strategy)), machine,
        jnp.asarray(owner, dtype=jnp.int32), jnp.int32(need)))
    return np.nonzero(mask)[0]


# ---------------------------------------------------------------------------
# unit placement on hand-built machines
# ---------------------------------------------------------------------------


def test_simple_takes_lowest_free_ids():
    m = alloc.linear(8, group_size=4)
    owner = np.array([0, -1, -1, 3, -1, -1, -1, 5])
    np.testing.assert_array_equal(place_ids("simple", m, owner, 3), [1, 2, 4])


def test_contiguous_best_fit_block():
    m = alloc.linear(10, group_size=5)
    # runs: [1,2] (len 2), [4,5,6] (len 3), [8,9] (len 2)
    owner = np.array([0, -1, -1, 1, -1, -1, -1, 2, -1, -1])
    # need 2: best fit = first run of exactly len 2 -> nodes 1,2
    np.testing.assert_array_equal(place_ids("contiguous", m, owner, 2), [1, 2])
    # need 3: only the middle run fits
    np.testing.assert_array_equal(place_ids("contiguous", m, owner, 3), [4, 5, 6])


def test_contiguous_tie_breaks_by_start():
    m = alloc.linear(8, group_size=8)
    owner = np.array([-1, -1, 9, -1, -1, 9, -1, -1])  # three len-2 runs
    np.testing.assert_array_equal(place_ids("contiguous", m, owner, 2), [0, 1])


def test_spread_round_robins_groups():
    m = alloc.dragonfly(3, 3)  # groups {0,1,2},{3,4,5},{6,7,8}
    owner = np.full(9, -1)
    # one node per group first, in group order, lowest id within group
    np.testing.assert_array_equal(place_ids("spread", m, owner, 3), [0, 3, 6])
    np.testing.assert_array_equal(place_ids("spread", m, owner, 5), [0, 1, 3, 4, 6])


def test_topo_packs_fullest_groups_first():
    m = alloc.dragonfly(3, 3)
    owner = np.full(9, -1)
    owner[0] = 7          # group 0 has 2 free, groups 1,2 have 3 free
    # need 4: fill group 1 (3 free, lowest id among fullest), spill into group 2
    np.testing.assert_array_equal(place_ids("topo", m, owner, 4), [3, 4, 5, 6])


def test_placeable_cap_contiguous_blocks_on_fragmentation():
    owner = jnp.asarray(np.array([-1, 0, -1, 1, -1, 2, -1, 3]), dtype=jnp.int32)
    assert int(alloc.placeable_cap(jnp.int32(alloc.SIMPLE), owner)) == 4
    assert int(alloc.placeable_cap(jnp.int32(alloc.CONTIGUOUS), owner)) == 1


def test_group_span_counts_distinct_groups():
    m = alloc.dragonfly(4, 2)
    mask = jnp.asarray(np.array([True, False, False, True, False, False, True, True]))
    assert int(alloc.group_span(m, mask)) == 3


def test_jax_placement_matches_host_mirror_random_maps():
    m = alloc.mesh2d(4, 4)
    mh = m.to_host()
    rng = np.random.default_rng(11)
    for _ in range(60):
        owner = np.where(rng.random(16) < 0.45,
                         rng.integers(0, 6, 16), -1).astype(np.int32)
        free = host.free_count_host(owner)
        if free == 0:
            continue
        need = int(rng.integers(1, free + 1))
        for s in STRATEGIES:
            np.testing.assert_array_equal(
                place_ids(s, m, owner, need), host.place_host(s, mh, owner, need),
                err_msg=f"strategy={s} owner={owner} need={need}")


# ---------------------------------------------------------------------------
# acceptance: simple == seed scalar counter, bit-for-bit
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("policy", ["fcfs", "sjf", "ljf", "bestfit", "backfill"])
@pytest.mark.parametrize("trace_fn,nodes,machine_fn", [
    (das2_like, 400, lambda: alloc.linear(400, group_size=16)),
    (sdsc_sp2_like, 128, lambda: alloc.dragonfly(16, 8)),
])
def test_simple_reproduces_scalar_counter_bit_for_bit(policy, trace_fn, nodes,
                                                      machine_fn):
    trace = trace_fn(300, seed=7)
    scalar = simulate_np(trace, policy, total_nodes=nodes)
    mapped = simulate_np(trace, policy, total_nodes=nodes,
                         machine=machine_fn(), alloc="simple")
    np.testing.assert_array_equal(mapped["start"], scalar["start"])
    np.testing.assert_array_equal(mapped["finish"], scalar["finish"])
    assert mapped["makespan"] == scalar["makespan"]
    assert mapped["n_events"] == scalar["n_events"]


# ---------------------------------------------------------------------------
# acceptance: JAX engine == refsim under every strategy (node maps included)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("strategy", STRATEGIES)
def test_exact_match_vs_reference_all_policies(strategy):
    m = alloc.dragonfly(4, 4)
    for seed in range(3):
        rng = np.random.default_rng(seed)
        n = 50
        trace = {
            "submit": rng.integers(0, 120, n),
            "runtime": rng.integers(1, 50, n),
            "nodes": rng.integers(1, 10, n),
            "estimate": rng.integers(1, 100, n),
            "priority": rng.integers(0, 3, n),
        }
        for policy in POLICIES:
            ours = simulate_np(trace, policy, total_nodes=16, machine=m,
                               alloc=strategy)
            ref = simulate_reference(trace, policy, total_nodes=16, machine=m,
                                     alloc=strategy)
            assert ours["done"][:n].all(), (strategy, policy, seed)
            for k in ("start", "finish", "alloc_first", "alloc_span",
                      "alloc_sum"):
                np.testing.assert_array_equal(
                    ours[k][:n], ref[k],
                    err_msg=f"{k} strategy={strategy} policy={policy} seed={seed}")
            # per-event fragmentation log is pinned too
            assert ours["n_events"] == ref["n_events"]
            for k in ("ev_time", "ev_free", "ev_lfb"):
                np.testing.assert_array_equal(ours[k], ref[k])


@pytest.mark.parametrize("strategy", STRATEGIES)
def test_exact_match_vs_reference_with_contention(strategy):
    m = alloc.dragonfly(4, 4)
    con = alloc.Contention.make(1, 5)   # +20% per extra group spanned
    rng = np.random.default_rng(99)
    n = 40
    trace = {
        "submit": rng.integers(0, 100, n),
        "runtime": rng.integers(1, 40, n),
        "nodes": rng.integers(1, 9, n),
        "estimate": rng.integers(1, 80, n),
        "priority": rng.integers(0, 3, n),
    }
    for policy in ("fcfs", "backfill", "preempt"):
        ours = simulate_np(trace, policy, total_nodes=16, machine=m,
                           alloc=strategy, contention=con)
        ref = simulate_reference(trace, policy, total_nodes=16, machine=m,
                                 alloc=strategy, contention=con)
        for k in ("start", "finish", "alloc_span", "alloc_sum"):
            np.testing.assert_array_equal(
                ours[k][:n], ref[k],
                err_msg=f"{k} strategy={strategy} policy={policy}")


# ---------------------------------------------------------------------------
# contention semantics
# ---------------------------------------------------------------------------


def test_contention_dilates_by_span_exactly():
    # 6-node job on a dragonfly of 2-node groups must span 3 groups
    m = alloc.dragonfly(4, 2)
    trace = {"submit": np.array([0]), "runtime": np.array([100]),
             "nodes": np.array([6]), "estimate": np.array([100])}
    con = alloc.Contention.make(1, 10)  # +10% per extra group
    out = simulate_np(trace, "fcfs", total_nodes=8, machine=m, alloc="topo",
                      contention=con)
    assert out["alloc_span"][0] == 3
    # dilated = 100 + (100 * 1 * 2) // 10 = 120
    assert out["finish"][0] - out["start"][0] == 120


def test_contention_dilation_saturates_without_overflow():
    """Extreme alpha x span x remaining stays positive, saturates at the
    trace-horizon bound, and matches the host mirror bit-for-bit
    (DESIGN.md §11.3)."""
    for num, rem, span in ((50, 2_000_000, 30), (1000, 2 ** 29, 2 ** 14),
                           (1, 100, 3)):
        con = alloc.Contention.make(num, 1)
        j = int(alloc.dilate(con, jnp.int32(rem), jnp.int32(span)))
        h = alloc.dilate_host(num, 1, rem, span)
        assert j == h, (num, rem, span)
        assert 0 < j <= 2 ** 30 - 1


def test_alloc_args_require_machine():
    trace = {"submit": np.array([0]), "runtime": np.array([5]),
             "nodes": np.array([1])}
    with pytest.raises(ValueError):
        simulate_np(trace, "fcfs", total_nodes=8, alloc="contiguous")
    with pytest.raises(ValueError):
        simulate_np(trace, "fcfs", total_nodes=8,
                    contention=alloc.Contention.make(1, 5))


def test_contention_off_is_identity():
    m = alloc.dragonfly(4, 4)
    trace = sdsc_sp2_like(150, seed=5)
    trace = {k: v for k, v in trace.items()}
    trace["nodes"] = np.minimum(trace["nodes"], 16)
    a = simulate_np(trace, "backfill", total_nodes=16, machine=m, alloc="spread")
    b = simulate_np(trace, "backfill", total_nodes=16, machine=m, alloc="spread",
                    contention=alloc.Contention.off())
    np.testing.assert_array_equal(a["finish"], b["finish"])


def test_contention_penalizes_spread_vs_topo():
    """Same trace + machine: the span-heavy allocator pays a larger makespan
    tax — the allocator choice is now a first-class scenario axis."""
    m = alloc.dragonfly(16, 8)
    trace = sdsc_sp2_like(250, seed=2)
    con = alloc.Contention.make(1, 4)
    sp = simulate_np(trace, "backfill", total_nodes=128, machine=m,
                     alloc="spread", contention=con)
    tp = simulate_np(trace, "backfill", total_nodes=128, machine=m,
                     alloc="topo", contention=con)
    v = sp["valid"]
    assert sp["alloc_span"][v].mean() > tp["alloc_span"][v].mean()
    assert sp["makespan"] > tp["makespan"]


# ---------------------------------------------------------------------------
# acceptance: strategies measurably differ on a dragonfly machine
# ---------------------------------------------------------------------------


def test_contiguous_vs_spread_locality_and_fragmentation_differ():
    m = alloc.dragonfly(16, 8)
    trace = sdsc_sp2_like(300, seed=7)
    res = {}
    for s in ("contiguous", "spread"):
        out = simulate_np(trace, "backfill", total_nodes=128, machine=m, alloc=s)
        res[s] = metrics.alloc_summary(out)
    # spread scatters across groups; contiguous packs a block
    assert res["spread"]["mean_job_span"] > 1.5 * res["contiguous"]["mean_job_span"]
    assert res["spread"]["mean_frag"] != res["contiguous"]["mean_frag"]


def test_fragmentation_series_bounds():
    m = alloc.dragonfly(8, 8)
    trace = sdsc_sp2_like(200, seed=1)
    trace = {k: np.minimum(v, 64) if k == "nodes" else v for k, v in trace.items()}
    out = simulate_np(trace, "fcfs", total_nodes=64, machine=m, alloc="spread")
    t, frag = metrics.fragmentation_series(out)
    assert len(t) > 0 and (frag >= 0).all() and (frag <= 1).all()
    t2, lfb = metrics.largest_free_block_series(out)
    assert (lfb <= 64).all() and (lfb >= 0).all()
    # largest free block never exceeds the free count
    assert (lfb <= np.maximum(out["ev_free"][np.r_[
        out["ev_time"][1:] != out["ev_time"][:-1], True]], 0)).all()
    tj, span = metrics.job_span_series(out)
    assert np.nanmax(span) <= 8  # cannot span more groups than exist


# ---------------------------------------------------------------------------
# ensemble sweep axis
# ---------------------------------------------------------------------------


def test_alloc_sweep_matches_individual_runs():
    trace = sdsc_sp2_like(120, seed=9)
    jobs = make_jobset(trace["submit"], trace["runtime"], trace["nodes"],
                       trace["estimate"], total_nodes=64)
    m = alloc.dragonfly(8, 8)
    res = simulate_alloc_sweep(jobs, POLICY_IDS["backfill"], 64, m, STRATEGIES)
    assert res.start.shape == (4, jobs.capacity)
    for i, s in enumerate(STRATEGIES):
        single = simulate_np(trace, "backfill", total_nodes=64, machine=m,
                             alloc=s)
        np.testing.assert_array_equal(np.asarray(res.start[i]), single["start"])
        np.testing.assert_array_equal(np.asarray(res.alloc_sum[i]),
                                      single["alloc_sum"])


# ---------------------------------------------------------------------------
# machine builders
# ---------------------------------------------------------------------------


def test_machine_builders_invariants():
    for m in (alloc.linear(12, group_size=5), alloc.mesh2d(3, 4),
              alloc.dragonfly(3, 4)):
        g = np.asarray(m.group)
        assert (np.diff(g) >= 0).all()
        gs = np.asarray(m.group_start)
        sz = np.asarray(m.group_size)
        for i in range(m.n_nodes):
            members = np.nonzero(g == g[i])[0]
            assert gs[i] == members[0] and sz[i] == len(members)


def test_total_nodes_mismatch_raises():
    trace = {"submit": np.array([0]), "runtime": np.array([5]),
             "nodes": np.array([1])}
    with pytest.raises(ValueError):
        simulate_np(trace, "fcfs", total_nodes=8,
                    machine=alloc.dragonfly(2, 2))
