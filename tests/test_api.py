"""Unified Scenario API acceptance tests (ISSUE 2 / DESIGN.md §12).

- ``run``/``run_ref`` bit-identical start/finish tables for a fixed
  synthetic scenario across all 5 policies × {no machine, mesh2d +
  contiguous};
- one ``sweep()`` call reproduces ``simulate_alloc_sweep`` exactly;
- a mixed policy × alloc × contention grid (inexpressible by any legacy
  entry point) runs in ONE compile bucket and each point matches its
  individual ``run``;
- static-vs-traced axis partitioning, mesh sharding, multicluster specs,
  the shared strategy canonicalizer, and the public package exports.
"""

import numpy as np
import pytest

from repro import api
from repro.api import (
    ArrayTrace, Multicluster, Scenario, SwfTrace, SyntheticTrace, Topology,
    run, run_ref, sweep,
)

POLICIES = ("fcfs", "sjf", "ljf", "bestfit", "backfill")

BASE = Scenario(trace=SyntheticTrace(n_jobs=150, seed=7, kind="sdsc_sp2"),
                total_nodes=128, policy="fcfs")
MESH_BASE = Scenario(trace=SyntheticTrace(n_jobs=150, seed=7, kind="sdsc_sp2"),
                     topology=Topology.mesh2d(16, 8), policy="fcfs",
                     alloc="contiguous")


# ---------------------------------------------------------------------------
# run() vs run_ref(): the acceptance matrix
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("policy", POLICIES)
def test_run_matches_ref_scalar_counter(policy):
    scn = BASE.with_(policy=policy)
    ours, ref = run(scn), run_ref(scn)
    n = int(ref.to_np()["valid"].sum())
    np.testing.assert_array_equal(ours["start"][:n], ref["start"])
    np.testing.assert_array_equal(ours["finish"][:n], ref["finish"])
    assert ours.to_np()["makespan"] == ref.to_np()["makespan"]


@pytest.mark.parametrize("policy", POLICIES)
def test_run_matches_ref_mesh2d_contiguous(policy):
    scn = MESH_BASE.with_(policy=policy)
    ours, ref = run(scn), run_ref(scn)
    assert ours.matches(ref, node_maps=True)


# ---------------------------------------------------------------------------
# sweep(): legacy regression + beyond-legacy grids
# ---------------------------------------------------------------------------


def test_sweep_reproduces_simulate_alloc_sweep():
    from repro import alloc
    from repro.api.run import build_jobset
    from repro.core.jobs import POLICY_IDS
    from repro.core.parallel import simulate_alloc_sweep

    strategies = ("simple", "contiguous", "spread", "topo")
    scn = Scenario(trace=SyntheticTrace(n_jobs=120, seed=3, kind="sdsc_sp2"),
                   topology=Topology.dragonfly(8, 8), policy="backfill",
                   contention=(1, 5))
    grid = sweep(scn, axes={"alloc": strategies})
    assert grid.n_compiles == 1

    legacy = simulate_alloc_sweep(
        build_jobset(scn), POLICY_IDS["backfill"], 64,
        Topology.dragonfly(8, 8).build(), strategies,
        contention=alloc.Contention.make(1, 5))
    for i, strat in enumerate(strategies):
        out = grid.get(alloc=strat).to_np()
        for field in ("start", "finish", "wait", "alloc_first", "alloc_span",
                      "alloc_sum"):
            np.testing.assert_array_equal(
                np.asarray(getattr(legacy, field)[i]), out[field],
                err_msg=f"{strat}.{field}")
        assert int(legacy.makespan[i]) == out["makespan"]


def test_sweep_mixed_grid_beyond_legacy_entry_points():
    """policy × alloc × contention in one call — and every batched point is
    bit-identical to its own standalone run()."""
    scn = Scenario(trace=SyntheticTrace(n_jobs=100, seed=5, kind="sdsc_sp2"),
                   topology=Topology.mesh2d(8, 8), policy="fcfs")
    axes = {"policy": ("fcfs", "backfill"),
            "alloc": ("simple", "topo"),
            "contention": (None, (1, 5))}
    grid = sweep(scn, axes=axes)
    assert len(grid) == 8
    assert grid.n_compiles == 1  # all three axes are traced vmap data
    for point, batched in grid:
        single = run(scn.with_(**point))
        np.testing.assert_array_equal(
            batched.to_np()["start"], single.to_np()["start"], err_msg=str(point))
        np.testing.assert_array_equal(
            batched.to_np()["alloc_sum"], single.to_np()["alloc_sum"],
            err_msg=str(point))
    # contention must actually bite: spanning allocs get dilated makespans
    con = grid.get(policy="backfill", alloc="topo", contention=(1, 5))
    off = grid.get(policy="backfill", alloc="topo", contention=None)
    assert con.to_np()["makespan"] >= off.to_np()["makespan"]


def test_sweep_partitions_traced_vs_static_axes():
    """topology is a recompile axis, trace.seed/policy are vmap axes: a
    2-topology × 2-seed × 2-policy grid compiles exactly twice."""
    scn = Scenario(trace=SyntheticTrace(n_jobs=60, seed=0, kind="das2"),
                   total_nodes=64, policy="fcfs")
    grid = sweep(scn, axes={
        "topology": (None, Topology.linear(64, group_size=8)),
        "trace.seed": (0, 1),
        "policy": ("fcfs", "sjf"),
    })
    assert len(grid) == 8
    assert grid.n_compiles == 2
    # seeds really differ, and each point matches its standalone run
    a = grid.get(topology=None, **{"trace.seed": 0}, policy="fcfs")
    b = grid.get(topology=None, **{"trace.seed": 1}, policy="fcfs")
    assert not np.array_equal(a.to_np()["submit"], b.to_np()["submit"])
    for point, batched in grid:
        single = run(scn.with_(**point))
        np.testing.assert_array_equal(
            batched.to_np()["start"], single.to_np()["start"], err_msg=str(point))


def test_sweep_total_nodes_traced_without_topology():
    scn = Scenario(trace=SyntheticTrace(n_jobs=80, seed=2, kind="das2"),
                   total_nodes=64, policy="backfill")
    grid = sweep(scn, axes={"total_nodes": (32, 64, 128)})
    assert grid.n_compiles == 1  # machine size is ensemble data w/o topology
    makespans = [r.to_np()["makespan"] for _, r in grid]
    assert makespans[0] >= makespans[1] >= makespans[2]
    for point, batched in grid:
        single = run(scn.with_(**point))
        np.testing.assert_array_equal(
            batched.to_np()["start"], single.to_np()["start"], err_msg=str(point))


def test_sweep_multicluster_static_axis():
    scn = Scenario(
        trace=tuple(SyntheticTrace(n_jobs=40, seed=s, kind="das2")
                    for s in range(2)),
        total_nodes=64, policy="backfill",
        multicluster=Multicluster(window=4000, migrate=False))
    grid = sweep(scn, axes={"multicluster.window": (2000, 8000)})
    assert grid.n_compiles == 2
    a, b = (r.to_np() for _, r in grid)
    # without migration the conservative window cannot change outcomes
    np.testing.assert_array_equal(a["start"], b["start"])
    assert a["valid"].sum() == 80


def test_sweep_empty_axes_degenerates_to_run():
    grid = sweep(BASE, axes={})
    assert len(grid) == 1
    np.testing.assert_array_equal(
        grid[0].to_np()["start"], run(BASE).to_np()["start"])


# ---------------------------------------------------------------------------
# Result: one wrapper over all three legacy output shapes
# ---------------------------------------------------------------------------


CANONICAL_KEYS = {"submit", "runtime", "nodes", "start", "finish", "wait",
                  "valid", "done", "makespan"}


def test_result_schema_unifies_all_backends():
    single = run(BASE).to_np()
    ref = run_ref(BASE).to_np()
    mc = run(Scenario(
        trace=(SyntheticTrace(n_jobs=30, seed=0), SyntheticTrace(n_jobs=30, seed=1)),
        total_nodes=128, policy="fcfs",
        multicluster=Multicluster(window=5000))).to_np()
    for out in (single, ref, mc):
        assert CANONICAL_KEYS <= set(out)
    alloc_out = run(MESH_BASE).to_np()
    assert {"alloc_first", "alloc_span", "alloc_sum", "ev_time", "ev_free",
            "ev_lfb"} <= set(alloc_out)


def test_result_summary_metrics():
    s = run(MESH_BASE).summary()
    for key in ("avg_wait", "p95_wait", "makespan", "utilization",
                "mean_frag", "mean_job_span"):
        assert key in s, key
    s2 = run(BASE).summary()
    assert "mean_frag" not in s2  # no topology -> no fragmentation series


def test_array_trace_and_dict_coercion():
    rng = np.random.default_rng(0)
    trace = {"submit": rng.integers(0, 50, 40), "runtime": rng.integers(1, 30, 40),
             "nodes": rng.integers(1, 9, 40)}
    scn = Scenario(trace=trace, total_nodes=8, policy="sjf")
    assert isinstance(scn.trace, ArrayTrace)
    assert run(scn).matches(run_ref(scn))


# ---------------------------------------------------------------------------
# shared strategy canonicalizer (repro.alloc.canonical_id)
# ---------------------------------------------------------------------------


def test_canonical_id_scalars_and_sequences():
    from repro import alloc

    assert alloc.canonical_id("topo") == alloc.TOPO
    assert alloc.canonical_id(2) == alloc.SPREAD
    assert alloc.canonical_id(np.int64(1)) == alloc.CONTIGUOUS
    assert alloc.canonical_id(None) == alloc.SIMPLE
    mixed = alloc.canonical_id(["simple", 1, np.int32(2), "TOPO"])
    np.testing.assert_array_equal(np.asarray(mixed), [0, 1, 2, 3])
    arr = alloc.canonical_id(np.array([3, 0], dtype=np.int64))
    np.testing.assert_array_equal(np.asarray(arr), [3, 0])
    with pytest.raises(ValueError, match="unknown allocation strategy"):
        alloc.canonical_id("best_fit")
    with pytest.raises(ValueError, match="out of range"):
        alloc.canonical_id(7)


def test_simulate_ensemble_accepts_numpy_and_mixed_alloc_b():
    """The alloc_b branch used to only canonicalize list/tuple of str."""
    from repro.api.run import build_jobset
    from repro.core.jobs import POLICY_IDS
    from repro.core.parallel import simulate_ensemble, stack_jobsets

    scn = Scenario(trace=SyntheticTrace(n_jobs=60, seed=9, kind="sdsc_sp2"),
                   topology=Topology.dragonfly(4, 4), policy="fcfs")
    jobs = build_jobset(scn)
    machine = scn.topology.build()
    jb = stack_jobsets([jobs] * 3)
    pols = np.full((3,), POLICY_IDS["fcfs"], np.int32)
    nodes = np.full((3,), 16, np.int32)
    mixed = simulate_ensemble(jb, pols, nodes, machine=machine,
                              alloc_b=["simple", 1, np.int64(3)])
    as_np = simulate_ensemble(jb, pols, nodes, machine=machine,
                              alloc_b=np.array([0, 1, 3]))
    np.testing.assert_array_equal(np.asarray(mixed.start), np.asarray(as_np.start))
    np.testing.assert_array_equal(np.asarray(mixed.alloc_sum),
                                  np.asarray(as_np.alloc_sum))


# ---------------------------------------------------------------------------
# spec hygiene + public exports
# ---------------------------------------------------------------------------


def test_scenario_validation_errors():
    with pytest.raises(ValueError, match="alloc/contention require topology"):
        Scenario(trace=SyntheticTrace(n_jobs=10), total_nodes=8, alloc="topo")
    with pytest.raises(ValueError, match="total_nodes is required"):
        Scenario(trace=SyntheticTrace(n_jobs=10))
    with pytest.raises(ValueError, match="topology has 64 nodes"):
        Scenario(trace=SyntheticTrace(n_jobs=10), total_nodes=32,
                 topology=Topology.mesh2d(8, 8))
    with pytest.raises(ValueError, match="one trace spec per cluster"):
        Scenario(trace=SyntheticTrace(n_jobs=10), total_nodes=8,
                 multicluster=Multicluster(window=100))
    # topology defaults total_nodes
    scn = Scenario(trace=SyntheticTrace(n_jobs=10),
                   topology=Topology.dragonfly(4, 4))
    assert scn.total_nodes == 16


def test_public_package_exports():
    import repro

    assert repro.Scenario is Scenario
    assert repro.run is run
    assert repro.sweep is sweep
    assert repro.api.SwfTrace is SwfTrace
    from repro.core import simulate_np  # stable low-level surface
    assert callable(simulate_np)
