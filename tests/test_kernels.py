"""Per-kernel shape/dtype sweeps, interpret=True, allclose vs ref oracles."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_reference
from repro.kernels.linattn_scan.ops import linattn
from repro.kernels.linattn_scan.ref import linattn_reference
from repro.kernels.queue_select.ops import queue_select
from repro.kernels.queue_select.ref import queue_select_reference

KEY = jax.random.PRNGKey(42)


@pytest.mark.parametrize("B,Sq,Sk,H,KV,hd", [
    (2, 256, 256, 4, 2, 64),
    (1, 128, 384, 8, 8, 128),
    (2, 200, 200, 4, 1, 64),     # unaligned seq -> padding path
    (1, 1, 256, 8, 2, 64),       # decode-style single query
    (2, 64, 512, 4, 4, 32),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal,window", [(True, None), (True, 96), (False, None)])
def test_flash_attention_sweep(B, Sq, Sk, H, KV, hd, dtype, causal, window):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, Sq, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, Sk, KV, hd), dtype)
    v = jax.random.normal(ks[2], (B, Sk, KV, hd), dtype)
    qoff = Sk - Sq if Sq <= Sk else 0
    out = flash_attention(q, k, v, causal=causal, window=window,
                          q_offset=qoff, block_q=128, block_k=128,
                          interpret=True)
    ref = attention_reference(q, k, v, causal=causal, window=window,
                              q_offset=qoff)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)


def test_flash_attention_matches_model_blockwise():
    from repro.models.attention import blockwise_attention
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (2, 160, 8, 64))
    k = jax.random.normal(ks[1], (2, 160, 4, 64))
    v = jax.random.normal(ks[2], (2, 160, 4, 64))
    a = flash_attention(q, k, v, causal=True, interpret=True)
    b = blockwise_attention(q, k, v, causal=True, block_q=64, block_k=64)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=3e-5)


@pytest.mark.parametrize("B,H,S,K,chunk", [
    (2, 3, 64, 16, 16),
    (1, 2, 128, 64, 32),
    (2, 1, 100, 32, 32),     # unaligned -> padding path
    (1, 4, 256, 64, 128),    # long chunk: stability regression test
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_linattn_sweep(B, H, S, K, chunk, dtype):
    ks = jax.random.split(KEY, 5)
    r = (jax.random.normal(ks[0], (B, H, S, K)) * 0.5).astype(dtype)
    k = (jax.random.normal(ks[1], (B, H, S, K)) * 0.5).astype(dtype)
    v = (jax.random.normal(ks[2], (B, H, S, K)) * 0.5).astype(dtype)
    logw = -jnp.exp(jax.random.normal(ks[3], (B, H, S, K)) * 0.5)
    u = jax.random.normal(ks[4], (H, K)) * 0.5
    y = linattn(r, k, v, logw.astype(dtype), u, chunk=chunk, interpret=True)
    ref = linattn_reference(r, k, v, logw.astype(dtype), u)
    scale = float(jnp.max(jnp.abs(ref.astype(jnp.float32)))) + 1e-6
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    err = float(jnp.max(jnp.abs(y.astype(jnp.float32) - ref.astype(jnp.float32))))
    assert err / scale < tol, (err, scale)


def test_linattn_steep_decay_stability():
    """Steep data-dependent decays used to overflow the factored chunk form."""
    B, H, S, K = 1, 2, 256, 32
    ks = jax.random.split(KEY, 3)
    r = jax.random.normal(ks[0], (B, H, S, K))
    k = jax.random.normal(ks[1], (B, H, S, K))
    v = jax.random.normal(ks[2], (B, H, S, K))
    logw = jnp.full((B, H, S, K), -6.0)   # near-instant forgetting
    u = jnp.zeros((H, K))
    y = linattn(r, k, v, logw, u, chunk=128, interpret=True)
    assert bool(jnp.isfinite(y).all())
    ref = linattn_reference(r, k, v, logw, u)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), atol=1e-4)


@pytest.mark.parametrize("N,tile", [(7, 8), (100, 32), (1024, 256),
                                    (5000, 1024), (65536, 2048)])
@pytest.mark.parametrize("feas_rate", [0.0, 0.05, 0.5, 1.0])
def test_queue_select_sweep(N, tile, feas_rate, rng):
    scores = rng.integers(0, 10_000, N).astype(np.int32)
    feas = (rng.random(N) < feas_rate).astype(np.int32)
    out = np.asarray(queue_select(jnp.asarray(scores), jnp.asarray(feas),
                                  tile=tile, interpret=True))
    ref = np.asarray(queue_select_reference(jnp.asarray(scores),
                                            jnp.asarray(feas)))
    np.testing.assert_array_equal(out, ref)


def test_queue_select_ties_pick_lowest_index(rng):
    scores = np.zeros(256, np.int32)
    feas = np.zeros(256, np.int32)
    feas[[40, 7, 200]] = 1
    out = np.asarray(queue_select(jnp.asarray(scores), jnp.asarray(feas),
                                  tile=64, interpret=True))
    assert out[0] == 7


@pytest.mark.parametrize("N,tile", [(7, 8), (100, 32), (1024, 256),
                                    (5000, 1024), (65536, 2048)])
@pytest.mark.parametrize("feas_rate", [0.0, 0.05, 0.5, 1.0])
def test_queue_select_compiled_default_sweep(N, tile, feas_rate, rng):
    """The default (interpret unset) must be a compiled lowering on every
    backend and bit-identical to the oracle — this is the path the
    benchmarks time (ISSUE 8: the old default silently ran the Pallas
    interpreter)."""
    scores = rng.integers(0, 10_000, N).astype(np.int32)
    feas = (rng.random(N) < feas_rate).astype(np.int32)
    out = np.asarray(queue_select(jnp.asarray(scores), jnp.asarray(feas),
                                  tile=tile))
    ref = np.asarray(queue_select_reference(jnp.asarray(scores),
                                            jnp.asarray(feas)))
    np.testing.assert_array_equal(out, ref)


def test_queue_select_compiled_default_ties_and_empty():
    scores = np.zeros(256, np.int32)
    feas = np.zeros(256, np.int32)
    feas[[40, 7, 200]] = 1
    out = np.asarray(queue_select(jnp.asarray(scores), jnp.asarray(feas),
                                  tile=64))
    assert out[0] == 7
    none = np.asarray(queue_select(jnp.asarray(scores),
                                   jnp.zeros(256, np.int32), tile=64))
    assert none[0] == -1
