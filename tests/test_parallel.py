"""Parallel DES: ensemble/vmap equivalence, multicluster conservative sync."""

import numpy as np
import pytest

from repro.core.engine import simulate
from repro.core.jobs import POLICY_IDS, make_jobset
from repro.core.parallel import (
    multicluster_result_np, simulate_ensemble, simulate_multicluster,
    stack_jobsets,
)
from repro.traces import das2_like


def _jobsets(C, J, cap_slack=64, total_nodes=128, seed0=30):
    traces = [das2_like(J, seed=seed0 + s) for s in range(C)]
    jsets = [make_jobset(t["submit"], t["runtime"], t["nodes"], t["estimate"],
                         capacity=J + cap_slack, total_nodes=total_nodes)
             for t in traces]
    horizon = int(max(t["submit"].max() for t in traces) + 50_000)
    return jsets, horizon


def test_ensemble_matches_single():
    jsets, _ = _jobsets(3, 150)
    jb = stack_jobsets(jsets)
    pols = [POLICY_IDS["fcfs"], POLICY_IDS["backfill"], POLICY_IDS["sjf"]]
    res = simulate_ensemble(jb, pols, [128] * 3)
    for i, (js, p) in enumerate(zip(jsets, pols)):
        single = simulate(js, p, 128)
        np.testing.assert_array_equal(np.asarray(res.start[i]),
                                      np.asarray(single.start))


def test_multicluster_no_migration_equals_independent():
    jsets, horizon = _jobsets(4, 120)
    jc = stack_jobsets(jsets)
    mc = simulate_multicluster(
        jc, POLICY_IDS["backfill"], [128] * 4, window=4000, horizon=horizon,
        migrate=False)
    assert not np.asarray(mc.saturated).any(), "window rounds hit the event cap"
    for s, js in enumerate(jsets):
        ind = simulate(js, POLICY_IDS["backfill"], 128)
        np.testing.assert_array_equal(
            np.asarray(mc.state.start[s]), np.asarray(ind.start))


def test_multicluster_window_invariance_without_migration():
    """Conservative windows must not change results (lookahead correctness)."""
    jsets, horizon = _jobsets(2, 100)
    jc = stack_jobsets(jsets)
    outs = []
    for window in (1000, 7000, 50_000):
        mc = simulate_multicluster(
            jc, POLICY_IDS["fcfs"], [128] * 2, window=window, horizon=horizon,
            migrate=False)
        outs.append(np.asarray(mc.state.start))
    np.testing.assert_array_equal(outs[0], outs[1])
    np.testing.assert_array_equal(outs[0], outs[2])


def test_multicluster_migration_conserves_jobs():
    jsets, horizon = _jobsets(4, 150, total_nodes=64)
    jc = stack_jobsets(jsets)
    mc = simulate_multicluster(
        jc, POLICY_IDS["backfill"], [64] * 4, window=5000, horizon=horizon,
        migrate=True, max_export=4)
    out = multicluster_result_np(mc)
    assert out["dropped"] == 0
    assert not out["saturated"], "a window round silently hit the event cap"
    assert out["valid"].sum() == 4 * 150, "jobs conserved across migration"
    assert out["done"].sum() == 4 * 150, "every job completes"
    # conservative latency: a migrated job never starts before its re-arrival
    assert (out["start"][out["valid"]] >= out["submit"][out["valid"]]).all()


def test_migration_helps_imbalanced_load():
    """A hot cluster + idle clusters: migration should cut total makespan."""
    hot = das2_like(200, seed=77)
    hot["submit"] = (hot["submit"] // 4)  # compress arrivals: overload
    cold = {k: v[:20] for k, v in das2_like(20, seed=78).items()}
    jsets = [
        make_jobset(hot["submit"], hot["runtime"], hot["nodes"],
                    hot["estimate"], capacity=280, total_nodes=64),
        make_jobset(cold["submit"], cold["runtime"], cold["nodes"],
                    cold["estimate"], capacity=280, total_nodes=64),
    ]
    jc = stack_jobsets(jsets)
    horizon = int(hot["submit"].max() + 100_000)
    kw = dict(window=2000, horizon=horizon, max_export=8,
              load_imbalance_threshold=1.2)
    a = multicluster_result_np(simulate_multicluster(
        jc, POLICY_IDS["fcfs"], [64, 64], migrate=False, **kw))
    b = multicluster_result_np(simulate_multicluster(
        jc, POLICY_IDS["fcfs"], [64, 64], migrate=True, **kw))
    assert b["migrated"] > 0
    assert b["makespan"] <= a["makespan"]
