"""HLO fingerprints of the no-failure engine executables (ISSUE 5).

The reliability subsystem must *statically elide* to nothing: a
``failures=None`` simulation has to lower to the exact HLO module the
pre-reliability engine produced — not just the same results, the same
compiled program.  This module pins that: ``fingerprints()`` lowers the
engine across the existing policy × alloc × DAG differential grid and
hashes the StableHLO text; ``tests/data/hlo_nofail.json`` holds the hashes
recorded at the commit *before* the reliability changes, and
``tests/test_engine_fastpath.py`` asserts today's lowering still matches.

Regenerate (only when an *intentional* engine-graph change lands)::

    PYTHONPATH=src:tests python tests/_hlo_fixture.py --write

Hashes are stable across processes for a fixed jax version; the fixture
records the jax version it was built with so a toolchain bump skips (not
fails) the comparison.
"""

from __future__ import annotations

import hashlib
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import engine
from repro.core.jobs import POLICY_IDS, make_jobset
from repro.traces import sdsc_sp2_like
from repro.traces.workflows import galactic_like, montage_like, workflow_to_trace

FIXTURE = os.path.join(os.path.dirname(__file__), "data", "hlo_nofail.json")

ALL_POLICIES = ("fcfs", "sjf", "ljf", "bestfit", "backfill", "preempt")


def _dag_jobs(total_nodes: int):
    trace = workflow_to_trace(galactic_like(tiles=2, width=5, seed=0))
    return make_jobset(trace["submit"], trace["runtime"], trace["nodes"],
                       trace["estimate"], deps=trace["deps"],
                       total_nodes=total_nodes)


def _montage_jobs(total_nodes: int):
    trace = workflow_to_trace(montage_like(6, seed=2))
    return make_jobset(trace["submit"], trace["runtime"], trace["nodes"],
                       trace["estimate"], deps=trace["deps"],
                       total_nodes=total_nodes)


def _plain_jobs(total_nodes: int):
    trace = sdsc_sp2_like(80, seed=11)
    return make_jobset(trace["submit"], trace["runtime"], trace["nodes"],
                       trace["estimate"], total_nodes=total_nodes)


def configs():
    """(name, jobs, policy_name, total_nodes, topology_or_None, alloc) grid.

    Mirrors the differential grid the fast-path identity tests run: every
    policy on a DAG and on a plain trace in scalar-counter mode, plus the
    machine modes (count-capped and geometry-capped strategies).
    """
    from repro.api import Topology

    out = []
    for pol in ALL_POLICIES:
        out.append((f"dag_scalar_{pol}", _dag_jobs(8), pol, 8, None, None))
        out.append((f"plain_scalar_{pol}", _plain_jobs(16), pol, 16, None, None))
    mesh = Topology.mesh2d(4, 4)
    for pol in ("fcfs", "backfill"):
        for alloc in ("simple", "contiguous"):
            out.append((f"dag_mesh_{pol}_{alloc}", _montage_jobs(16), pol, 16,
                        mesh, alloc))
    # the fully-dynamic executable (traced policy — the vmap-sweep path)
    out.append(("plain_dynamic", _plain_jobs(16), None, 16, None, None))
    return out


def _lower(jobs, policy_name, total_nodes, topology, alloc):
    if topology is not None:
        machine = topology.build()
        ctx = engine.make_alloc_ctx(machine, alloc, None)
    else:
        ctx = None
    if policy_name is None:
        pol_id, static_policy, static_strategy = 0, None, None
    else:
        pol_id = POLICY_IDS[policy_name]
        static_policy = engine._static_policy_hint(pol_id)
        static_strategy = (engine._concrete_int(ctx[1])
                           if ctx is not None else None)
    kwargs = dict(max_events=None, static_policy=static_policy,
                  static_strategy=static_strategy)
    args = (jobs, jnp.asarray(pol_id, jnp.int32),
            jnp.asarray(total_nodes, jnp.int32), ctx)
    try:
        # post-reliability signature: the elided failure context is explicit
        return engine._simulate_jit.lower(*args, fctx=None, **kwargs)
    except TypeError:
        # pre-reliability signature (fixture generation at the seed commit)
        return engine._simulate_jit.lower(*args, **kwargs)


def fingerprints() -> dict:
    out = {}
    for name, jobs, pol, tn, topo, alloc in configs():
        txt = _lower(jobs, pol, tn, topo, alloc).as_text()
        out[name] = hashlib.sha256(txt.encode()).hexdigest()
    return out


def load_fixture() -> dict:
    with open(FIXTURE) as f:
        return json.load(f)


def write_fixture() -> dict:
    fp = {"jax_version": jax.__version__, "hashes": fingerprints()}
    os.makedirs(os.path.dirname(FIXTURE), exist_ok=True)
    with open(FIXTURE, "w") as f:
        json.dump(fp, f, indent=1, sort_keys=True)
        f.write("\n")
    return fp


if __name__ == "__main__":
    import sys

    if "--write" in sys.argv:
        fp = write_fixture()
        print(f"wrote {FIXTURE} ({len(fp['hashes'])} configs, "
              f"jax {fp['jax_version']})")
    else:
        want = load_fixture()["hashes"]
        got = fingerprints()
        bad = {k for k in want if want[k] != got.get(k)}
        print("MATCH" if not bad else f"MISMATCH: {sorted(bad)}")
        sys.exit(1 if bad else 0)
