"""Workflow engine: reference validation, dependency/resource invariants,
JSON I/O (paper §3)."""

import numpy as np
import pytest  # noqa: F401  (used by the hypothesis fallback shim)
from _hypothesis_compat import given, settings, st

from repro.core.workflow import (
    WF_POLICY_IDS, critical_path_length, make_taskset, simulate_workflow,
    workflow_result_np,
)
from repro.refsim.workflow import simulate_workflow_reference
from repro.traces import workflows as W

POOLS = np.array([16, 16384])
GENS = {
    "chain": lambda s: W.chain(15),
    "forkjoin": lambda s: W.fork_join(6, 3, seed=s),
    "montage": lambda s: W.montage_like(12, seed=s),
    "sipht": lambda s: W.sipht_like(20, seed=s),
    "galactic": lambda s: W.galactic_like(3, 8, seed=s),
    "random": lambda s: W.random_layered(80, 8, seed=s),
}


def run_both(wf, policy, pools=POOLS, priority=None):
    ts = make_taskset(wf["exec_time"], wf["resources"], wf["dep_pairs"],
                      priority=priority)
    st_ = simulate_workflow(ts, pools, WF_POLICY_IDS[policy])
    ours = workflow_result_np(ts, st_)
    ref = simulate_workflow_reference(
        wf["exec_time"], wf["resources"], wf["dep_pairs"], pools, policy,
        priority=priority)
    return ours, ref, ts


@pytest.mark.parametrize("gen", list(GENS))
@pytest.mark.parametrize("policy", ["fcfs", "fcfs_fit", "cpath"])
def test_exact_match_vs_reference(gen, policy):
    wf = GENS[gen](5)
    prio = (critical_path_length(wf["exec_time"], wf["dep_pairs"])
            if policy == "cpath" else None)
    ours, ref, _ = run_both(wf, policy, priority=prio)
    n = len(ref["start"])
    assert ours["done"][:n].all()
    np.testing.assert_array_equal(ours["start"][:n], ref["start"])
    np.testing.assert_array_equal(ours["finish"][:n], ref["finish"])


@pytest.mark.parametrize("gen", list(GENS))
def test_dependencies_respected(gen):
    wf = GENS[gen](9)
    ours, _, _ = run_both(wf, "fcfs_fit")
    start, finish = ours["start"], ours["finish"]
    for t, d in wf["dep_pairs"]:
        assert start[t] >= finish[d], f"task {t} started before dep {d} done"


def test_resource_bounds_never_exceeded():
    wf = W.random_layered(60, 6, seed=3)
    ours, _, ts = run_both(wf, "fcfs_fit")
    n = len(wf["exec_time"])
    res = np.asarray(ts.resources)[:n]
    events = sorted(set(ours["start"][:n]) | set(ours["finish"][:n]))
    for t in events:
        running = (ours["start"][:n] <= t) & (t < ours["finish"][:n])
        used = res[running].sum(axis=0)
        assert (used <= POOLS).all(), f"pool exceeded at t={t}: {used}"


def test_cycle_detection():
    with pytest.raises(ValueError, match="cycle"):
        make_taskset([10, 10, 10], [[1, 1]] * 3, [(0, 1), (1, 2), (2, 0)])
    with pytest.raises(ValueError, match="self"):
        make_taskset([10], [[1, 1]], [(0, 0)])


def test_json_roundtrip_paper_format():
    wf = W.montage_like(8, seed=1)
    js = W.to_json(wf, POOLS)
    wf2, pools2, policy = W.from_json(js)
    np.testing.assert_array_equal(wf["exec_time"], wf2["exec_time"])
    np.testing.assert_array_equal(wf["resources"], wf2["resources"])
    assert sorted(wf["dep_pairs"]) == sorted(wf2["dep_pairs"])
    np.testing.assert_array_equal(pools2, POOLS)
    assert policy == "Static"


def test_paper_listing2_example_parses():
    """The exact workflow from the paper's Listing 2."""
    doc = """
    {"tasks": [
      {"id": 1, "execution_time": 100, "resources": {"cpu": 2, "memory": 1024}, "dependencies": []},
      {"id": 2, "execution_time": 150, "resources": {"cpu": 1, "memory": 512}, "dependencies": [1]},
      {"id": 3, "execution_time": 200, "resources": {"cpu": 1, "memory": 512}, "dependencies": [1]},
      {"id": 4, "execution_time": 300, "resources": {"cpu": 2, "memory": 1024}, "dependencies": [2, 3]}],
     "resources_available": {"cpu": 10, "memory": 8192},
     "scheduling_policy": "Static", "preemption": false}
    """
    wf, pools, _ = W.from_json(doc)
    ours, ref, _ = run_both(wf, "fcfs", pools=pools)
    # diamond DAG: 1 -> (2 || 3) -> 4
    assert ours["makespan"] == 100 + 200 + 300
    np.testing.assert_array_equal(ours["start"][:4], ref["start"])


def test_cpath_no_worse_than_fcfs_on_makespan_montage():
    wf = W.montage_like(20, seed=4)
    prio = critical_path_length(wf["exec_time"], wf["dep_pairs"])
    a, _, _ = run_both(wf, "fcfs_fit")
    b, _, _ = run_both(wf, "cpath", priority=prio)
    assert b["makespan"] <= a["makespan"] * 1.05


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000), n=st.integers(10, 60))
def test_random_dags_complete_and_match(seed, n):
    wf = W.random_layered(n, max(n // 8, 2), seed=seed)
    ours, ref, _ = run_both(wf, "fcfs_fit")
    m = len(ref["start"])
    assert ours["done"][:m].all()
    np.testing.assert_array_equal(ours["start"][:m], ref["start"])
