"""Priority preemption — the paper's §5 planned future work, implemented.

Semantics (mirrored in both engines): queue order (priority, submit, row);
a head that does not fit may reclaim nodes from strictly-lower-priority
running jobs; victims are suspended (remaining runtime preserved), requeued
with their original submit rank, and `start` records first dispatch only.
"""

import numpy as np
import pytest  # noqa: F401  (used by the hypothesis fallback shim)
from _hypothesis_compat import given, settings, st

from repro.core.engine import simulate_np
from repro.refsim import simulate_reference


def test_high_priority_job_preempts_immediately():
    trace = {
        "submit": np.array([0, 10]),
        "runtime": np.array([100, 20]),
        "nodes": np.array([8, 8]),
        "estimate": np.array([100, 20]),
        "priority": np.array([5, 0]),      # lower value = more important
    }
    out = simulate_np(trace, "preempt", total_nodes=8)
    assert out["start"][1] == 10           # preemptor waits zero seconds
    assert out["finish"][1] == 30
    # victim ran [0,10), suspended [10,30), resumed with 90 s left
    assert out["finish"][0] == 120
    ref = simulate_reference(trace, "preempt", total_nodes=8)
    np.testing.assert_array_equal(out["start"][:2], ref["start"])
    np.testing.assert_array_equal(out["finish"][:2], ref["finish"])


def test_equal_priority_never_preempts():
    rng = np.random.default_rng(1)
    n = 40
    trace = {
        "submit": rng.integers(0, 100, n),
        "runtime": rng.integers(1, 50, n),
        "nodes": rng.integers(1, 9, n),
        "estimate": rng.integers(1, 100, n),
    }
    a = simulate_np(trace, "preempt", total_nodes=16)
    b = simulate_np(trace, "fcfs", total_nodes=16)
    np.testing.assert_array_equal(a["start"], b["start"])
    np.testing.assert_array_equal(a["finish"], b["finish"])


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000), n=st.integers(10, 60),
       levels=st.integers(2, 4))
def test_exact_match_vs_reference_random(seed, n, levels):
    rng = np.random.default_rng(seed)
    trace = {
        "submit": rng.integers(0, 150, n),
        "runtime": rng.integers(1, 60, n),
        "nodes": rng.integers(1, 9, n),
        "estimate": rng.integers(1, 120, n),
        "priority": rng.integers(0, levels, n),
    }
    ours = simulate_np(trace, "preempt", total_nodes=16)
    ref = simulate_reference(trace, "preempt", total_nodes=16)
    assert ours["done"][:n].all()
    np.testing.assert_array_equal(ours["start"][:n], ref["start"])
    np.testing.assert_array_equal(ours["finish"][:n], ref["finish"])


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_priority_zero_jobs_never_wait_behind_lower(seed):
    """A top-priority job's wait is bounded by top-priority contention only."""
    rng = np.random.default_rng(seed)
    n = 30
    trace = {
        "submit": rng.integers(0, 100, n),
        "runtime": rng.integers(1, 40, n),
        "nodes": rng.integers(1, 5, n),
        "estimate": rng.integers(1, 80, n),
        "priority": np.r_[np.zeros(5, np.int64), np.ones(n - 5, np.int64)],
    }
    out = simulate_np(trace, "preempt", total_nodes=16)
    # with <= 5 top-priority jobs of <= 5 nodes each on 16 nodes, at most
    # ceil(25/16)-1 rounds of top-tier contention: wait bounded by their
    # own runtimes, never by priority-1 jobs
    top = out["wait"][:n][np.asarray(trace["priority"])[
        np.lexsort((np.arange(n), trace["submit"]))] == 0]
    assert (top <= 40 * 2).all()


def test_work_conserved_under_preemption():
    rng = np.random.default_rng(3)
    n = 50
    trace = {
        "submit": rng.integers(0, 100, n),
        "runtime": rng.integers(1, 50, n),
        "nodes": rng.integers(1, 9, n),
        "estimate": rng.integers(1, 100, n),
        "priority": rng.integers(0, 3, n),
    }
    out = simulate_np(trace, "preempt", total_nodes=16)
    v = out["valid"]
    # suspension delays completion but never loses work: finish - start >= runtime
    assert (out["finish"][v] - out["start"][v] >= out["runtime"][v]).all()


def test_victim_order_survives_priorities_near_inf_time():
    """Regression (ISSUE 4): the seed engine ranked victims with the packed
    key ``-(priority * J + row)``, which overflows int32 once priority is
    within a factor of J of INF_TIME and silently preempts the wrong jobs.
    The two-stage lexicographic sort must agree with the reference simulator
    even at sentinel-scale priorities."""
    huge = int(2**29)
    trace = {
        "submit": np.array([0, 0, 0, 10]),
        "runtime": np.array([100, 100, 100, 20]),
        "nodes": np.array([2, 2, 2, 4]),
        "estimate": np.array([100, 100, 100, 20]),
        # three running jobs whose priorities straddle the int32 wrap point
        # of the packed key (huge*J crosses 2**31): row 0 keeps a positive
        # packed key while rows 1-2 wrap negative, so the seed ordering
        # inverts and preempts the most-important victim first
        "priority": np.array([huge - 1, huge + 2, huge + 1, 0]),
    }
    out = simulate_np(trace, "preempt", total_nodes=6)
    ref = simulate_reference(trace, "preempt", total_nodes=6)
    np.testing.assert_array_equal(out["start"][:4], ref["start"])
    np.testing.assert_array_equal(out["finish"][:4], ref["finish"])
    # victims are most-preemptible-first (priority desc, row desc): rows 1+2
    # suspend for the 4-node preemptor, row 0 runs to completion untouched
    assert out["finish"][0] == 100
    assert out["start"][3] == 10 and out["finish"][3] == 30
    assert out["finish"][1] > 100 and out["finish"][2] > 100


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_exact_match_vs_reference_huge_priorities(seed):
    """Random near-INF priority levels: victim ordering must stay bit-exact
    against the reference at any priority magnitude."""
    rng = np.random.default_rng(seed)
    n = 24
    trace = {
        "submit": rng.integers(0, 120, n),
        "runtime": rng.integers(1, 60, n),
        "nodes": rng.integers(1, 7, n),
        "estimate": rng.integers(1, 120, n),
        "priority": rng.integers(2**28, 2**30 - 1, n),
    }
    out = simulate_np(trace, "preempt", total_nodes=12)
    ref = simulate_reference(trace, "preempt", total_nodes=12)
    np.testing.assert_array_equal(out["start"][:n], ref["start"])
    np.testing.assert_array_equal(out["finish"][:n], ref["finish"])
