"""Optimizer + gradient compression tests."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim.adamw import (
    AdamWConfig, adamw_init, adamw_update, cosine_schedule, global_norm,
)
from repro.optim.compression import compress_gradients, compression_init


def _quadratic_problem(seed=0, d=16):
    rng = np.random.default_rng(seed)
    A = jnp.asarray(rng.normal(size=(d, d)) / np.sqrt(d))
    b = jnp.asarray(rng.normal(size=(d,)))

    def loss(w):
        return jnp.mean((A @ w["w"] - b) ** 2)

    return loss, {"w": jnp.zeros((d,))}


def test_adamw_converges_on_least_squares():
    # random square A is ill-conditioned; hold lr near peak (long schedule)
    loss, params = _quadratic_problem()
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=10,
                      total_steps=10_000, min_lr_frac=0.5)
    state = adamw_init(params)
    step = jax.jit(lambda p, s: adamw_update(jax.grad(loss)(p), s, p, cfg))
    l0 = float(loss(params))
    for _ in range(1000):
        params, state, _ = step(params, state)
    assert float(loss(params)) < 0.05 * l0


def test_grad_clip_bounds_update():
    loss, params = _quadratic_problem()
    cfg = AdamWConfig(lr=1.0, grad_clip=1e-6, weight_decay=0.0)
    state = adamw_init(params)
    new, _, m = adamw_update(jax.grad(loss)(params), state, params, cfg)
    assert float(m["grad_norm"]) > 1e-6  # unclipped norm reported
    delta = global_norm(jax.tree.map(lambda a, b: a - b, new, params))
    assert float(delta) < 1.0  # clipped + unit-scale Adam step


def test_schedule_shape():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                      min_lr_frac=0.1)
    lrs = [float(cosine_schedule(cfg, jnp.int32(s))) for s in range(101)]
    assert lrs[0] < lrs[9] <= 1.0          # warmup increasing
    assert abs(lrs[10] - 1.0) < 0.02        # peak
    assert abs(lrs[100] - 0.1) < 0.02       # floor
    assert all(a >= b - 1e-9 for a, b in zip(lrs[10:], lrs[11:]))  # decay


def test_compression_error_feedback_telescopes():
    """sum(dequantized) + final residual == sum(raw grads) exactly."""
    params = {"w": jnp.zeros((64,))}
    state = compression_init(params)
    rng = np.random.default_rng(1)
    total_raw = jnp.zeros((64,))
    total_deq = jnp.zeros((64,))
    for _ in range(20):
        g = {"w": jnp.asarray(rng.normal(size=(64,)), jnp.float32)}
        total_raw = total_raw + g["w"]
        deq, state, _ = compress_gradients(g, state)
        total_deq = total_deq + deq["w"]
    np.testing.assert_allclose(
        np.asarray(total_deq + state.residual["w"]),
        np.asarray(total_raw), atol=1e-4)


def test_compression_convergence_parity():
    loss, params = _quadratic_problem(seed=3)
    cfg = AdamWConfig(lr=0.05, weight_decay=0.0, total_steps=400)

    def run(compressed):
        p = jax.tree.map(jnp.copy, params)
        st = adamw_init(p)
        cst = compression_init(p)
        for _ in range(400):
            g = jax.grad(loss)(p)
            if compressed:
                g, cst, _ = compress_gradients(g, cst)
            p, st, _ = adamw_update(g, st, p, cfg)
        return float(loss(p))

    plain, comp = run(False), run(True)
    assert comp < 0.05 or comp < 5 * max(plain, 1e-4)
