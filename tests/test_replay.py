"""Streaming trace replay (DESIGN.md §19): windowed chunking is bit-exact
against one-shot ``simulate`` and the host refsim, checkpoints make a
killed run resume to a byte-identical result, and the degradation ladder
(event-cap saturation, window overflow, clock-rebase overflow) fails
loud-then-soft with typed flags.

- fast lane: chunked == one-shot corners (tiny windows force the doubling
  ladder), saturation/overflow flags, kill+resume identity, config-mismatch
  refusal, a beyond-int32-horizon archive vs the int64 refsim oracle;
- slow lane: the full differential grid {fcfs, sjf, backfill, preempt} x
  {scalar, mesh2d+contiguous} x {failures on/off} vs BOTH oracles, plus
  hypothesis properties on a ~2k-job trace with random window sizes and a
  kill-at-random-round resume test.
"""

import dataclasses
import functools
import tempfile

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.api import FailureModel, Topology
from repro.core.engine import simulate
from repro.core.jobs import POLICY_IDS, make_jobset
from repro.refsim import replay_reference
from repro.replay import (
    ReplayError, ReplayInterrupted, StreamingReplay, replay_trace, resume,
)
from repro.traces import das2_like

TOTAL = 32


def _trace(n=300, seed=2):
    t = dict(das2_like(n, seed=seed))
    t["priority"] = np.random.default_rng(seed).integers(0, 4, n)
    return t


def _mesh():
    return Topology.mesh2d(4, 8).build()


def _failures():
    return FailureModel(mtbf=30_000.0, mean_repair=2_000, horizon=1 << 19,
                        seed=7, max_failures=64, checkpoint_interval=500,
                        restart_overhead=20).materialize(TOTAL)


def _oneshot(trace, policy, machine=None, alloc=None, failures=None):
    js = make_jobset(trace["submit"], trace["runtime"], trace["nodes"],
                     trace["estimate"], priority=trace.get("priority"),
                     total_nodes=TOTAL)
    return simulate(js, POLICY_IDS[policy], TOTAL, machine=machine,
                    alloc=alloc, failures=failures)


def _assert_vs_oneshot(res, one, *, machine=False, failures=False):
    np.testing.assert_array_equal(res.start,
                                  np.asarray(one.start).astype(np.int64))
    np.testing.assert_array_equal(res.finish,
                                  np.asarray(one.finish).astype(np.int64))
    np.testing.assert_array_equal(res.done, np.asarray(one.done))
    assert res.n_events == int(np.asarray(one.n_events))
    if machine:
        for key in ("alloc_first", "alloc_span", "alloc_sum"):
            np.testing.assert_array_equal(
                getattr(res, key),
                np.asarray(getattr(one, key)).astype(np.int64), err_msg=key)
    if failures:
        for key in ("n_restarts", "lost_work"):
            np.testing.assert_array_equal(
                getattr(res, key),
                np.asarray(getattr(one.rel, key)).astype(np.int64),
                err_msg=key)
        np.testing.assert_array_equal(res.aborted, np.asarray(one.rel.aborted))


def _assert_vs_refsim(res, trace, policy, machine=None, alloc="simple",
                      failures=None):
    ref = replay_reference(trace, policy, total_nodes=TOTAL, machine=machine,
                           alloc=alloc, failures=failures)
    np.testing.assert_array_equal(res.start, ref["start"])
    np.testing.assert_array_equal(res.finish[res.done],
                                  ref["finish"][ref["done"]])
    np.testing.assert_array_equal(res.wait[res.done],
                                  ref["wait"][ref["done"]])
    np.testing.assert_array_equal(res.done, ref["done"])
    assert res.n_events == int(ref["n_events"])
    if machine is not None:
        for key in ("alloc_first", "alloc_span", "alloc_sum"):
            np.testing.assert_array_equal(getattr(res, key), ref[key],
                                          err_msg=key)
    if failures is not None:
        np.testing.assert_array_equal(res.n_restarts, ref["n_restarts"])
        np.testing.assert_array_equal(res.lost_work, ref["lost_work"])
        np.testing.assert_array_equal(res.aborted, ref["aborted"])


# ---------------------------------------------------------------------------
# fast lane: chunking corners + the degradation ladder
# ---------------------------------------------------------------------------


def test_tiny_window_bitexact_and_bounded():
    """A window far below the live-job peak forces the doubling ladder and
    still reproduces the one-shot schedule decision-for-decision; the device
    table never exceeds the final window (bounded memory)."""
    t = _trace(200)
    one = _oneshot(t, "backfill")
    res = replay_trace(dict(t), "backfill", total_nodes=TOTAL, window=16)
    _assert_vs_oneshot(res, one)
    _assert_vs_refsim(res, t, "backfill")
    assert res.flags.window_doublings >= 1
    assert res.peak_live <= res.window
    assert res.n_rounds > 1


def test_window_larger_than_trace_single_round():
    t = _trace(80)
    one = _oneshot(t, "fcfs")
    res = replay_trace(dict(t), "fcfs", total_nodes=TOTAL, window=256)
    _assert_vs_oneshot(res, one)
    assert res.flags.window_doublings == 0


def test_event_cap_saturation_flagged_and_recovered():
    """A tiny auto-doubling cap saturates, sets the typed flag, doubles, and
    the truncated-prefix rounds still compose to the exact schedule."""
    t = _trace(150)
    one = _oneshot(t, "fcfs")
    runner = StreamingReplay(dict(t), "fcfs", total_nodes=TOTAL, window=64)
    runner.cap = 8   # force saturation on the first busy round
    res = runner.run()
    _assert_vs_oneshot(res, one)
    assert res.flags.saturated_rounds >= 1
    assert res.flags.cap_doublings >= 1


def test_fixed_event_cap_saturates_without_doubling():
    """max_events= pins the cap: saturation is flagged but never doubled,
    and progress continues one capful of events at a time."""
    t = _trace(100)
    one = _oneshot(t, "sjf")
    res = replay_trace(dict(t), "sjf", total_nodes=TOTAL, window=128,
                       max_events=16)
    _assert_vs_oneshot(res, one)
    assert res.flags.saturated_rounds >= 1
    assert res.flags.cap_doublings == 0


def test_failures_cross_window_rounds():
    """Failure/repair events deferred across a round boundary fire at the
    identical clock: kills, restarts, and repairs are bit-exact under
    aggressive chunking."""
    t = _trace(150)
    ft = _failures()
    one = _oneshot(t, "fcfs", failures=ft)
    res = replay_trace(dict(t), "fcfs", total_nodes=TOTAL, window=32,
                       failures=ft)
    _assert_vs_oneshot(res, one, failures=True)
    _assert_vs_refsim(res, t, "fcfs", failures=ft)
    assert int(res.n_restarts.sum()) > 0, "grid corner must exercise kills"


def test_kill_then_resume_byte_identical(tmp_path):
    """Crash after a durable round, resume(): every result column, counter,
    and flag matches the uninterrupted run."""
    t = _trace(150)
    kw = dict(total_nodes=TOTAL, window=48)
    full = replay_trace(dict(t), "backfill", **kw)
    ck = str(tmp_path / "ck")
    with pytest.raises(ReplayInterrupted):
        StreamingReplay(dict(t), "backfill", ckpt_dir=ck, ckpt_every=1,
                        _crash_after_round=3, **kw).run()
    res = resume(ck, dict(t), "backfill", **kw)
    for f in dataclasses.fields(full):
        a, b = getattr(full, f.name), getattr(res, f.name)
        if isinstance(a, np.ndarray):
            np.testing.assert_array_equal(a, b, err_msg=f.name)
    assert (full.n_events, full.n_rounds, full.peak_live, full.window) == \
        (res.n_events, res.n_rounds, res.peak_live, res.window)
    assert full.flags == res.flags


def test_resume_refuses_config_mismatch(tmp_path):
    t = _trace(100)
    ck = str(tmp_path / "ck")
    with pytest.raises(ReplayInterrupted):
        StreamingReplay(dict(t), "fcfs", total_nodes=TOTAL, window=48,
                        ckpt_dir=ck, ckpt_every=1, _crash_after_round=2).run()
    with pytest.raises(ReplayError, match="different replay configuration"):
        resume(ck, dict(t), "sjf", total_nodes=TOTAL, window=48)


def test_beyond_int32_horizon_replays_against_refsim():
    """A month-scale archive whose absolute horizon overflows int32: the
    one-shot engine refuses it outright, windowed rebasing replays it, and
    the int64 refsim agrees column-for-column."""
    base = _trace(60, seed=4)
    far = {k: v.copy() for k, v in base.items()}
    far["submit"] = far["submit"] + (np.int64(3) << 31)
    t = {k: np.concatenate([base[k], far[k]]) for k in base}
    with pytest.raises(ValueError, match="overflows int32"):
        make_jobset(t["submit"], t["runtime"], t["nodes"], t["estimate"],
                    total_nodes=TOTAL)
    res = replay_trace(dict(t), "backfill", total_nodes=TOTAL, window=64)
    _assert_vs_refsim(res, t, "backfill")
    assert res.makespan > 2 ** 31
    assert res.done.all()
    assert res.flags.rebase_overflows == 0


def test_deps_rejected():
    t = _trace(20)
    t["deps"] = [(1, 0)]
    with pytest.raises(ValueError, match="dependency-free"):
        replay_trace(t, "fcfs", total_nodes=TOTAL)


def test_summary_shape():
    t = _trace(80)
    res = replay_trace(dict(t), "fcfs", total_nodes=TOTAL, window=96)
    s = res.summary()
    assert s["n_done"] == 80 and s["n_jobs"] == 80
    assert s["makespan"] == res.makespan > 0
    assert s["p95_wait"] >= s["p50_wait"] >= 0
    assert set(s["flags"]) == {"saturated_rounds", "cap_doublings",
                               "window_doublings", "rebase_overflows"}


# ---------------------------------------------------------------------------
# slow lane: the differential grid and hypothesis properties
# ---------------------------------------------------------------------------

GRID_POLICIES = ("fcfs", "sjf", "backfill", "preempt")


@pytest.mark.slow
@pytest.mark.timeout(900)
@pytest.mark.parametrize("failures", (False, True), ids=("nofail", "fail"))
@pytest.mark.parametrize("mode", ("scalar", "mesh"))
@pytest.mark.parametrize("policy", GRID_POLICIES)
def test_differential_grid(policy, mode, failures):
    """Acceptance grid: chunked replay vs one-shot AND refsim, policies x
    {scalar, mesh2d+contiguous} x {failures on/off}."""
    if policy == "preempt" and mode == "mesh":
        pytest.skip("preemption is scalar-counter mode only")
    t = _trace(300)
    machine = _mesh() if mode == "mesh" else None
    alloc = "contiguous" if mode == "mesh" else None
    ft = _failures() if failures else None
    one = _oneshot(t, policy, machine=machine, alloc=alloc, failures=ft)
    res = replay_trace(dict(t), policy, total_nodes=TOTAL, window=64,
                       machine=machine, alloc=alloc, failures=ft)
    _assert_vs_oneshot(res, one, machine=machine is not None,
                       failures=failures)
    _assert_vs_refsim(res, t, policy, machine=machine,
                      alloc=alloc or "simple", failures=ft)


_PROP_TRACE = _trace(2000, seed=6)


@functools.lru_cache(maxsize=4)
def _prop_oneshot(policy):
    return _oneshot(_PROP_TRACE, policy)


@functools.lru_cache(maxsize=4)
def _prop_refsim(policy):
    return replay_reference(_PROP_TRACE, policy, total_nodes=TOTAL)


@pytest.mark.slow
@pytest.mark.timeout(900)
@given(window=st.integers(8, 160),
       policy=st.sampled_from(["fcfs", "backfill"]))
@settings(max_examples=10, deadline=None)
def test_property_chunked_replay_window_invariant(window, policy):
    """Hypothesis: for ANY window size (hence any chunk boundaries), replay
    of a ~2k-job trace is bit-exact vs one-shot simulate and vs refsim."""
    res = replay_trace(dict(_PROP_TRACE), policy, total_nodes=TOTAL,
                       window=window)
    one = _prop_oneshot(policy)
    np.testing.assert_array_equal(res.start,
                                  np.asarray(one.start).astype(np.int64))
    np.testing.assert_array_equal(res.finish,
                                  np.asarray(one.finish).astype(np.int64))
    assert res.n_events == int(np.asarray(one.n_events))
    ref = _prop_refsim(policy)
    np.testing.assert_array_equal(res.start, ref["start"])
    assert res.n_events == int(ref["n_events"])
    assert res.peak_live <= res.window


@pytest.mark.slow
@pytest.mark.timeout(900)
@given(window=st.integers(12, 96), crash_round=st.integers(1, 12))
@settings(max_examples=10, deadline=None)
def test_property_kill_at_random_round_resumes_identical(window, crash_round):
    """Hypothesis: killing the run after ANY durable round and resuming
    yields the byte-identical result."""
    t = _trace(250, seed=8)
    kw = dict(total_nodes=TOTAL, window=window)
    full = replay_trace(dict(t), "fcfs", **kw)
    with tempfile.TemporaryDirectory() as ck:
        try:
            StreamingReplay(dict(t), "fcfs", ckpt_dir=ck, ckpt_every=1,
                            _crash_after_round=crash_round, **kw).run()
            crashed = False   # the run finished before the crash round
        except ReplayInterrupted:
            crashed = True
        if not crashed:
            return
        res = resume(ck, dict(t), "fcfs", **kw)
    for f in dataclasses.fields(full):
        a, b = getattr(full, f.name), getattr(res, f.name)
        if isinstance(a, np.ndarray):
            np.testing.assert_array_equal(a, b, err_msg=f.name)
    assert full.flags == res.flags
    assert (full.n_events, full.n_rounds) == (res.n_events, res.n_rounds)
