"""Online serving subsystem (ISSUE 6, DESIGN.md §16): open arrivals,
per-class SLOs, and queue-pressure autoscaling.

- model: deterministic seeded materialization into padded job arrays
  (deadline/class columns row-aligned with the job table), loud truncation,
  int32 clock-overflow guards (ServiceTrace AND SwfTrace), validation;
- semantics: a hand-built tie collision (completion == tick == arrival at
  one timestamp) pins the completions -> capacity -> arrivals order via a
  closed-form capacity log; drain semantics (scale-down never strands a
  running job) are asserted inside the refsim oracle on every run;
- differential: engine vs refsim bit-exact (starts, finishes, SLO verdicts,
  capacity log, event counts, p50/p99 wait and deadline-miss summary
  columns) over {3 rates} x {2 class mixes} x {autoscale on/off} x
  {fcfs, sjf} x {scalar, mesh2d} — the big grid rides the ``slow`` lane,
  a 4-config corner stays in the fast lane;
- properties (hypothesis): random rates/thresholds/seeds keep the engines
  bit-identical and the capacity log inside [min_nodes, max_nodes];
- sweeps: a rate x autoscale x seed grid compiles to ONE executable;
- metrics: ``percentiles()`` matches ``numpy.percentile`` exactly.
"""

import dataclasses

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.api import (
    AutoscalePolicy, FailureModel, Multicluster, Scenario, ServiceClass,
    ServiceTrace, SwfTrace, Topology, run, run_ref, sweep,
)
from repro.core import metrics
from repro.core.jobs import INF_TIME
from repro.serving import ServicePlan, make_svc_ctx

RATES = (0.02, 0.06, 0.11)
POLICIES = ("fcfs", "sjf")

ONE_CLASS = (ServiceClass("default", nodes=1, mean_runtime=45, slo_wait=60),)
TWO_CLASS = (
    ServiceClass("small", nodes=1, mean_runtime=30, slo_wait=40),
    ServiceClass("big", nodes=4, mean_runtime=120, dist="exponential",
                 slo_wait=200, weight=0.3),
)
SCALER = AutoscalePolicy(up_threshold=6, down_threshold=1, min_nodes=4,
                         max_nodes=16, step=2, interval=50, max_ticks=64)


def _spec(rate=0.06, classes=TWO_CLASS, autoscale=SCALER, **kw):
    kw.setdefault("horizon", 1500)
    kw.setdefault("seed", 7)
    kw.setdefault("max_jobs", 256)
    return ServiceTrace(rate=rate, classes=classes, autoscale=autoscale, **kw)


def _scenario(mode, rate, classes, autoscale, policy):
    kw = dict(policy=policy)
    if mode == "mesh2d":
        kw.update(topology=Topology.mesh2d(4, 4), alloc="simple")
    else:
        kw.update(total_nodes=16)
    return Scenario(trace=_spec(rate, classes, autoscale), **kw)


def _assert_bit_exact(scn):
    res, ref = run(scn), run_ref(scn)
    assert res.matches(ref)
    a, b = res.to_np(), ref.to_np()
    n = int(b["valid"].sum())
    for key in ("slo_met", "deadline", "class_id"):
        np.testing.assert_array_equal(a[key][:n], b[key])
    np.testing.assert_array_equal(a["cap_online"], b["cap_online"])
    np.testing.assert_array_equal(a["cap_time"], b["cap_time"])
    assert a["n_events"] == b["n_events"]
    sa, sb = res.summary(), ref.summary()
    for key in sa:
        np.testing.assert_allclose(sa[key], sb[key], rtol=0, atol=0,
                                   err_msg=key)
    return res, ref


# ---------------------------------------------------------------------------
# model / materialization
# ---------------------------------------------------------------------------


def test_materialize_is_deterministic_and_padded():
    plan = _spec().plan()
    again = _spec().plan()
    assert isinstance(plan, ServicePlan)
    for key in ("submit", "runtime", "nodes", "deadline", "class_id",
                "tick_time"):
        np.testing.assert_array_equal(getattr(plan, key), getattr(again, key))
    n, J = plan.n_requests, plan.capacity
    assert 0 < n <= J == 256
    assert (np.diff(plan.submit) >= 0).all() and plan.submit.min() == 0
    # deadline = submit + class slo, row-aligned; padding is inert
    slo = np.asarray([c.slo_wait for c in TWO_CLASS])
    np.testing.assert_array_equal(
        plan.deadline[:n], plan.submit + slo[plan.class_id[:n]])
    assert (plan.deadline[n:] == INF_TIME).all()
    assert (plan.class_id[n:] == -1).all()
    assert plan.tick_time.shape == (SCALER.max_ticks,)
    np.testing.assert_array_equal(
        plan.tick_time,
        np.arange(1, SCALER.max_ticks + 1) * SCALER.interval)


def test_fixed_and_exponential_runtimes():
    plan = _spec().plan()
    cid = plan.class_id[:plan.n_requests]
    assert (plan.runtime[cid == 0] == 30).all()        # fixed class
    assert len(set(plan.runtime[cid == 1].tolist())) > 1   # exponential
    assert (plan.estimate >= plan.runtime).all()
    assert (plan.nodes == np.asarray([1, 4])[cid]).all()


def test_disabled_autoscaler_keeps_tick_shape():
    on = _spec().plan()
    off = _spec(autoscale=dataclasses.replace(SCALER, enabled=False)).plan()
    assert on.tick_time.shape == off.tick_time.shape
    assert (off.tick_time == INF_TIME).all()
    none = _spec(autoscale=None).plan()
    assert none.tick_time.shape == (0,)


def test_trace_driven_arrivals():
    spec = _spec(arrivals=((3, 0), (3, 1), (10, 0)), autoscale=None,
                 classes=TWO_CLASS)
    plan = spec.plan()
    assert plan.n_requests == 3
    np.testing.assert_array_equal(plan.submit, [0, 0, 7])  # shifted to 0
    np.testing.assert_array_equal(plan.class_id[:3], [0, 1, 0])


def test_truncation_is_flagged_and_warned():
    with pytest.warns(UserWarning, match="max_jobs=8"):
        plan = ServiceTrace(horizon=2000, rate=0.1, seed=0,
                            max_jobs=8).plan()
    assert plan.truncated and plan.n_requests == 8


def test_validation_errors():
    with pytest.raises(ValueError, match="dist"):
        ServiceClass("x", dist="pareto")
    with pytest.raises(ValueError, match="down_threshold < up_threshold"):
        AutoscalePolicy(up_threshold=2, down_threshold=2)
    with pytest.raises(ValueError, match="deadlock"):
        ServiceTrace(horizon=100, classes=TWO_CLASS,
                     autoscale=AutoscalePolicy(up_threshold=5,
                                               down_threshold=1, min_nodes=2))
    with pytest.raises(ValueError, match="sorted"):
        ServiceTrace(horizon=100, arrivals=((5, 0), (3, 0)))
    with pytest.raises(ValueError, match="horizon"):
        ServiceTrace(horizon=0)
    with pytest.raises(TypeError, match="svc ctx"):
        make_svc_ctx((1, 2, 3))


def test_scenario_validation():
    spec = _spec()
    with pytest.raises(ValueError, match="max_jobs"):
        Scenario(trace=spec, total_nodes=16, capacity=512)
    with pytest.raises(ValueError, match="multicluster"):
        Scenario(trace=(spec, spec), total_nodes=16,
                 multicluster=Multicluster(window=50))
    with pytest.raises(ValueError, match="autoscal"):
        Scenario(trace=spec, topology=Topology.mesh2d(4, 4),
                 failures=FailureModel(mtbf=500.0))


# ---------------------------------------------------------------------------
# overflow guards (ServiceTrace + SwfTrace)
# ---------------------------------------------------------------------------


def test_service_trace_clock_overflow_guard():
    big = int(INF_TIME) // 2 - 1
    spec = ServiceTrace(
        horizon=big, arrivals=((0, 0), (big - 1, 0)),
        classes=(ServiceClass("x", mean_runtime=300_000_000),))
    with pytest.raises(ValueError, match="int32 clock"):
        spec.plan()


def test_swf_trace_clock_overflow_guard(tmp_path):
    path = tmp_path / "huge.swf"
    pad = "-1 " * 9
    path.write_text(
        f"1 0 0 100 4 -1 -1 4 120 -1 1 {pad}\n"
        f"2 {2**30} 0 100 4 -1 -1 4 120 -1 1 {pad}\n")
    with pytest.raises(ValueError, match="int32 clock"):
        SwfTrace(str(path)).materialize()
    # a sane log still loads
    ok = tmp_path / "ok.swf"
    ok.write_text(f"1 0 0 100 4 -1 -1 4 120 -1 1 {pad}\n")
    assert len(SwfTrace(str(ok)).materialize()["submit"]) == 1


# ---------------------------------------------------------------------------
# event-order semantics
# ---------------------------------------------------------------------------


def test_tie_order_completions_then_capacity_then_arrivals():
    # one timestamp (t=50) carries a completion, a tick, and an arrival:
    # the tick must read queued demand AFTER the completion but BEFORE the
    # arrival — demand 0 scales down, so the capacity log reads 1, and the
    # arriving request still starts on the remaining node at t=50
    spec = ServiceTrace(
        horizon=250, arrivals=((0, 0), (50, 0), (180, 0)),
        classes=(ServiceClass("c", nodes=1, mean_runtime=50, slo_wait=100),),
        max_jobs=8,
        autoscale=AutoscalePolicy(up_threshold=5, down_threshold=0,
                                  min_nodes=1, max_nodes=2, step=1,
                                  interval=50, max_ticks=4))
    scn = Scenario(trace=spec, total_nodes=2)
    res, ref = _assert_bit_exact(scn)
    out = res.to_np()
    np.testing.assert_array_equal(out["start"][:3], [0, 50, 180])
    # demand read before the t=50 arrival -> scale-down happened (2 -> 1);
    # later ticks hold at min_nodes=1 (each completion already freed its
    # node before the colliding tick walked)
    np.testing.assert_array_equal(out["cap_time"], [50, 100, 150, 200])
    np.testing.assert_array_equal(out["cap_online"], [1, 1, 1, 1])
    assert bool(out["slo_met"][1])


def test_scale_up_reacts_to_queue_pressure():
    # all nodes drained to min, then a burst: the scaler must climb back
    # up before the queue clears
    spec = ServiceTrace(
        horizon=1200, rate=0.12, seed=3, max_jobs=256, classes=ONE_CLASS,
        autoscale=AutoscalePolicy(up_threshold=3, down_threshold=0,
                                  min_nodes=1, max_nodes=8, step=2,
                                  interval=25, max_ticks=64))
    scn = Scenario(trace=spec, total_nodes=8)
    res, _ = _assert_bit_exact(scn)
    cap = res.to_np()["cap_online"]
    assert cap.min() >= 1 and cap.max() <= 8
    assert (np.diff(cap) > 0).any() and (np.diff(cap) < 0).any()


def test_service_none_is_statically_elided():
    # the SimResult of a service-free run carries no svc subtree at all
    # (the byte-identical-HLO guarantee is pinned by test_engine_fastpath's
    # committed fingerprints; this is the cheap pytree-level check)
    scn = Scenario(trace={"submit": [0, 1], "runtime": [5, 5],
                          "nodes": [1, 1]}, total_nodes=2)
    res = run(scn)
    assert res.raw.svc is None
    assert "slo_met" not in res.to_np()


# ---------------------------------------------------------------------------
# differential grid
# ---------------------------------------------------------------------------

AUTOSCALES = (SCALER, dataclasses.replace(SCALER, enabled=False))


@pytest.mark.parametrize("mode,policy", [
    ("scalar", "fcfs"), ("scalar", "sjf"),
    ("mesh2d", "fcfs"), ("mesh2d", "sjf"),
])
def test_differential_corner_fast(mode, policy):
    _assert_bit_exact(_scenario(mode, 0.06, TWO_CLASS, SCALER, policy))


@pytest.mark.slow
@pytest.mark.parametrize("rate", RATES)
@pytest.mark.parametrize("classes", (ONE_CLASS, TWO_CLASS),
                         ids=("one_class", "two_class"))
@pytest.mark.parametrize("autoscale", AUTOSCALES, ids=("on", "off"))
@pytest.mark.parametrize("policy", POLICIES)
@pytest.mark.parametrize("mode", ("scalar", "mesh2d"))
def test_differential_grid(rate, classes, autoscale, policy, mode):
    _assert_bit_exact(_scenario(mode, rate, classes, autoscale, policy))


def test_scalar_failures_compose_with_service():
    from repro.api import FailureModel
    scn = Scenario(
        trace=_spec(autoscale=AutoscalePolicy(
            up_threshold=5, down_threshold=1, min_nodes=4, step=1,
            interval=40, max_ticks=64)),
        total_nodes=16, policy="fcfs",
        failures=FailureModel(mtbf=900.0, seed=2, mean_repair=60,
                              horizon=1500, max_failures=16))
    _assert_bit_exact(scn)


# ---------------------------------------------------------------------------
# properties
# ---------------------------------------------------------------------------


@pytest.mark.slow
@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**16), rate=st.floats(0.01, 0.15),
       up=st.integers(2, 10), down=st.integers(0, 1),
       interval=st.integers(10, 80), policy=st.sampled_from(POLICIES),
       mode=st.sampled_from(("scalar", "mesh2d")))
def test_random_serving_engines_bit_exact(seed, rate, up, down, interval,
                                          policy, mode):
    # drain semantics are asserted inside the refsim oracle (scale-down
    # candidates are free nodes only; placements never land on a drained
    # node), so engine==refsim here transfers the property to the engine
    auto = AutoscalePolicy(up_threshold=up, down_threshold=down,
                           min_nodes=4, max_nodes=16, step=2,
                           interval=interval, max_ticks=64)
    kw = dict(policy=policy)
    if mode == "mesh2d":
        kw.update(topology=Topology.mesh2d(4, 4), alloc="simple")
    else:
        kw.update(total_nodes=16)
    scn = Scenario(trace=_spec(rate=rate, seed=seed, autoscale=auto), **kw)
    res, _ = _assert_bit_exact(scn)
    cap = res.to_np()["cap_online"]
    if len(cap):
        assert cap.min() >= auto.min_nodes and cap.max() <= auto.max_nodes


# ---------------------------------------------------------------------------
# sweeps compile once
# ---------------------------------------------------------------------------


def test_rate_autoscale_sweep_single_executable():
    scn = Scenario(trace=_spec(), total_nodes=16, policy="fcfs")
    grid = sweep(scn, axes={
        "trace.rate": (0.03, 0.07, 0.11),
        "trace.autoscale": AUTOSCALES,
        "trace.seed": (0, 1),
    })
    assert grid.n_compiles == 1
    assert len(grid) == 12
    # rate points are distinct traffic (the job-table cache keys the full
    # spec, not just its static shape)
    reqs = {p["trace.rate"]: s["n_requests"]
            for p, s in zip(grid.points, grid.summaries())
            if p["trace.seed"] == 0 and p["trace.autoscale"] is AUTOSCALES[0]}
    assert len(set(reqs.values())) > 1
    for point, res in grid:
        ref = run_ref(res.scenario)
        assert res.matches(ref), point
        np.testing.assert_array_equal(
            res["cap_online"], ref["cap_online"], err_msg=str(point))


def test_max_ticks_is_a_static_axis():
    scn = Scenario(trace=_spec(), total_nodes=16, policy="fcfs")
    grid = sweep(scn, axes={"trace.autoscale": (
        SCALER, dataclasses.replace(SCALER, max_ticks=32))})
    assert grid.n_compiles == 2   # padded tick capacity recompiles


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------


def test_percentiles_match_numpy():
    rng = np.random.default_rng(0)
    for size in (1, 2, 7, 100, 1001):
        x = rng.normal(size=size) * 100
        qs = (0.0, 25.0, 50.0, 90.0, 95.0, 99.0, 100.0)
        np.testing.assert_allclose(
            metrics.percentiles(x, qs), np.percentile(x, qs),
            rtol=0, atol=1e-9)
        # scalar q returns a bare float
        p50 = metrics.percentiles(x, 50)
        assert isinstance(p50, float) and p50 == np.percentile(x, 50)
    # masked selection == pre-masked numpy
    x = rng.integers(0, 1000, 200).astype(float)
    m = rng.random(200) < 0.5
    np.testing.assert_allclose(metrics.percentiles(x, 99, mask=m),
                               np.percentile(x[m], 99))
    assert np.isnan(metrics.percentiles(x, 50, mask=np.zeros(200, bool)))
    with pytest.raises(ValueError):
        metrics.percentiles(x, 101)


def test_summary_wait_stats_ride_percentiles():
    scn = _scenario("scalar", 0.06, TWO_CLASS, SCALER, "fcfs")
    out = run(scn).to_np()
    s = run(scn).summary()
    v = out["valid"] & out["done"]
    wait = out["wait"][v].astype(float)
    assert s["p50_wait"] == np.percentile(wait, 50)
    assert s["p95_wait"] == np.percentile(wait, 95)


def test_slo_summary_scalars():
    scn = _scenario("scalar", 0.06, TWO_CLASS, SCALER, "fcfs")
    s = run(scn).summary()
    assert 0.0 <= s["slo_attainment"] <= 1.0
    assert s["deadline_miss_rate"] == pytest.approx(1 - s["slo_attainment"])
    assert s["p99_wait"] >= s["p50_wait"] >= 0.0
    for name in ("small", "big"):
        assert f"{name}_p99_wait" in s and f"{name}_miss_rate" in s
    assert 0.0 < s["slo_goodput"] <= 1.0
    # per-class miss rates aggregate to the global rate
    out = run(scn).to_np()
    done = out["valid"] & out["done"]
    n_small = int((done & (out["class_id"] == 0)).sum())
    n_big = int((done & (out["class_id"] == 1)).sum())
    agg = (s["small_miss_rate"] * n_small + s["big_miss_rate"] * n_big) \
        / max(n_small + n_big, 1)
    assert agg == pytest.approx(s["deadline_miss_rate"])
