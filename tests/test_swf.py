"""SWF loader end-to-end: hardened parsing (quarantine/skip/cancel taxonomy,
strict mode, int32-downcast warning) on the checked-in fixture, then dtype
flow through ``run(Scenario(trace=SwfTrace(...)))`` including the
int64 -> int32 downcast in ``make_jobset``."""

import os
import tempfile

import numpy as np
import pytest

from repro import api
from repro.api import Scenario, SwfTrace, run, run_ref
from repro.traces import dump_swf, load_swf

FIXTURE = os.path.join(os.path.dirname(__file__), "data", "tiny.swf")

# fixture rows surviving the loader's filters, keyed by SWF job id:
# job 3 (runtime 0) and 5 (no procs) are skipped, job 12 (status 5) is
# cancelled, the trailing short row is quarantined, so 13 of 17 load
KEPT_JOBS = 13


def test_load_swf_filters_dtypes_and_report():
    t, rep = load_swf(FIXTURE)
    assert set(t) == {"submit", "runtime", "nodes", "estimate"}
    for key in t:
        assert t[key].dtype == np.int64, key
        assert len(t[key]) == KEPT_JOBS
    # ingest taxonomy is fully accounted: every line is loaded, skipped,
    # cancelled, or quarantined
    assert rep.n_lines == 17
    assert rep.n_jobs == KEPT_JOBS
    assert rep.n_skipped == 2        # runtime 0 / zero procs
    assert rep.n_cancelled == 1      # SWF status 5
    assert rep.n_quarantined == 1    # trailing short row
    assert rep.n_jobs + rep.n_skipped + rep.n_cancelled + rep.n_quarantined \
        == rep.n_lines
    assert any("fields" in reason for _, reason in rep.examples)
    # submit times are rebased to the earliest kept submit (t0 recorded)
    assert rep.t0 == 1000
    assert t["submit"][0] == 0
    assert rep.int32_safe
    # cancelled / zero-runtime rows are gone: no zero/negative values
    assert (t["runtime"] > 0).all() and (t["nodes"] > 0).all()
    assert "13 jobs loaded" in rep.summary()


def test_load_swf_no_rebase_keeps_raw_submits():
    t, rep = load_swf(FIXTURE, rebase=False)
    assert t["submit"][0] == 1000
    assert rep.t0 == 1000


def test_load_swf_field_fallbacks():
    t, _ = load_swf(FIXTURE)
    # job 2: requested procs <= 0 -> allocated procs (field 5) used
    assert t["nodes"][1] == 2
    # job 9: requested procs (4) preferred over allocated (2)
    assert t["nodes"][6] == 4
    # jobs 4 and 13: requested time <= 0 -> estimate falls back to runtime
    assert t["estimate"][2] == t["runtime"][2] == 200
    assert t["estimate"][9] == t["runtime"][9] == 60


def test_load_swf_gz_identical_and_max_jobs():
    plain, _ = load_swf(FIXTURE)
    gz, _ = load_swf(FIXTURE + ".gz")
    for key in plain:
        np.testing.assert_array_equal(plain[key], gz[key])
    head, rep = load_swf(FIXTURE, max_jobs=5)
    assert len(head["submit"]) == 5 and rep.n_jobs == 5
    np.testing.assert_array_equal(head["nodes"], plain["nodes"][:5])


def test_load_swf_quarantines_bad_lines_lenient_raises_strict(tmp_path):
    """Negative submits and non-numeric fields are quarantined (with the
    offending line number) in lenient mode and raise in strict mode."""
    p = tmp_path / "bad.swf"
    p.write_text(
        "; header\n"
        "1 -50 0 10 1 -1 -1 1 10 -1 1 -1 -1 -1 -1 -1 -1 -1\n"
        "2 0 0 10 1 -1 -1 1 10 -1 1 -1 -1 -1 -1 -1 -1 -1\n"
        "3 5 0 oops 1 -1 -1 1 10 -1 1 -1 -1 -1 -1 -1 -1 -1\n")
    t, rep = load_swf(str(p))
    assert rep.n_jobs == 1 and rep.n_quarantined == 2
    assert len(t["submit"]) == 1
    assert any("negative submit" in reason for _, reason in rep.examples)
    assert any("non-numeric" in reason for _, reason in rep.examples)
    with pytest.raises(ValueError, match=r"bad\.swf:2: negative submit"):
        load_swf(str(p), strict=True)


def test_load_swf_int32_downcast_warning(tmp_path):
    """Values past int32 load fine (int64 arrays) but warn that the engine's
    downcast would truncate; the report records int32_safe=False."""
    p = tmp_path / "big.swf"
    p.write_text(
        f"1 {2 ** 31} 0 10 1 -1 -1 1 10 -1 1 -1 -1 -1 -1 -1 -1 -1\n"
        "2 0 0 10 1 -1 -1 1 10 -1 1 -1 -1 -1 -1 -1 -1 -1\n")
    with pytest.warns(UserWarning, match="int32"):
        t, rep = load_swf(str(p), rebase=False)
    assert not rep.int32_safe
    assert t["submit"].max() == 2 ** 31


def test_dump_swf_round_trip(tmp_path):
    """dump_swf -> load_swf is the identity on the kept columns (the CI
    smoke uses this to materialize synthetic archives)."""
    from repro.traces import synthetic_trace
    t = synthetic_trace(n_jobs=200, seed=11)
    path = str(tmp_path / "rt.swf.gz")
    n = dump_swf(path, t, comment="round-trip fixture")
    assert n == 200
    back, rep = load_swf(path, rebase=False)
    assert rep.n_jobs == 200 and rep.n_quarantined == 0
    for key in ("submit", "runtime", "nodes", "estimate"):
        np.testing.assert_array_equal(
            np.asarray(t[key], dtype=np.int64), back[key])


def test_swf_scenario_end_to_end():
    """run(Scenario(trace=SwfTrace(...))): int64 loader arrays flow through
    make_jobset's int32 downcast, submit normalization, and node clamping,
    and the result validates bit-exact against the reference simulator."""
    scn = Scenario(trace=SwfTrace(FIXTURE), total_nodes=32, policy="backfill")
    res = run(scn)

    jobs = res.jobs
    for arr in (jobs.submit, jobs.runtime, jobs.estimate, jobs.nodes,
                jobs.priority):
        assert arr.dtype == np.int32
    out = res.to_np()
    assert out["valid"].sum() == KEPT_JOBS
    assert out["done"].sum() == KEPT_JOBS
    # make_jobset normalized raw submits (min was 1000) to start at 0
    assert out["submit"][out["valid"]].min() == 0
    # the 64-node request was clamped to the 32-node machine
    assert out["nodes"][out["valid"]].max() == 32
    assert res.matches(run_ref(scn))


def test_swf_scenario_gz_and_topology():
    """The .gz copy drives the allocation engine identically, and the swf
    spec composes with topology like any other trace source."""
    scn = Scenario(trace=SwfTrace(FIXTURE + ".gz", max_jobs=10),
                   topology=api.Topology.mesh2d(4, 8), policy="fcfs",
                   alloc="contiguous")
    res = run(scn)
    assert res.matches(run_ref(scn), node_maps=True)
    assert "mean_frag" in res.summary()


def test_swf_downcast_overflow_guard():
    """Traces whose horizon would overflow the int32 sentinel are rejected
    by make_jobset rather than silently wrapped (streaming replay is the
    supported path for such archives)."""
    with tempfile.NamedTemporaryFile("w", suffix=".swf", delete=False) as fh:
        f = ["1", str(2 ** 31), "0", "10", "1", "-1", "-1", "1", "10", "-1",
             "1"] + ["-1"] * 7
        g = ["2", "0", "0", "10", "1", "-1", "-1", "1", "10", "-1",
             "1"] + ["-1"] * 7
        fh.write(" ".join(f) + "\n" + " ".join(g) + "\n")
        path = fh.name
    try:
        scn = Scenario(trace=SwfTrace(path), total_nodes=4)
        with pytest.raises(ValueError, match="overflows int32"):
            run(scn)
    finally:
        os.unlink(path)
