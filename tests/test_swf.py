"""SWF loader end-to-end: parsing/filtering/fallbacks on the checked-in
fixture, then dtype flow through ``run(Scenario(trace=SwfTrace(...)))``
including the int64 -> int32 downcast in ``make_jobset``."""

import os

import numpy as np
import pytest

from repro import api
from repro.api import Scenario, SwfTrace, run, run_ref
from repro.traces import load_swf

FIXTURE = os.path.join(os.path.dirname(__file__), "data", "tiny.swf")

# fixture rows surviving the loader's filters, keyed by SWF job id:
# job 3 (runtime 0), 5 (no procs), 12 (negative runtime) are dropped, the
# trailing short row is skipped, so 13 of 16 data rows load
KEPT_JOBS = 13


def test_load_swf_filters_and_dtypes():
    t = load_swf(FIXTURE)
    assert set(t) == {"submit", "runtime", "nodes", "estimate"}
    for key in t:
        assert t[key].dtype == np.int64, key
        assert len(t[key]) == KEPT_JOBS
    # submit times are raw (unnormalized) seconds from the log
    assert t["submit"][0] == 1000
    # cancelled rows (ids 3, 5, 12) are gone: no zero/negative runtimes
    assert (t["runtime"] > 0).all() and (t["nodes"] > 0).all()


def test_load_swf_field_fallbacks():
    t = load_swf(FIXTURE)
    # job 2: requested procs <= 0 -> allocated procs (field 5) used
    assert t["nodes"][1] == 2
    # job 9: requested procs (4) preferred over allocated (2)
    assert t["nodes"][6] == 4
    # jobs 4 and 13: requested time <= 0 -> estimate falls back to runtime
    assert t["estimate"][2] == t["runtime"][2] == 200
    assert t["estimate"][9] == t["runtime"][9] == 60


def test_load_swf_gz_identical_and_max_jobs():
    plain = load_swf(FIXTURE)
    gz = load_swf(FIXTURE + ".gz")
    for key in plain:
        np.testing.assert_array_equal(plain[key], gz[key])
    head = load_swf(FIXTURE, max_jobs=5)
    assert len(head["submit"]) == 5
    np.testing.assert_array_equal(head["nodes"], plain["nodes"][:5])


def test_swf_scenario_end_to_end():
    """run(Scenario(trace=SwfTrace(...))): int64 loader arrays flow through
    make_jobset's int32 downcast, submit normalization, and node clamping,
    and the result validates bit-exact against the reference simulator."""
    scn = Scenario(trace=SwfTrace(FIXTURE), total_nodes=32, policy="backfill")
    res = run(scn)

    jobs = res.jobs
    for arr in (jobs.submit, jobs.runtime, jobs.estimate, jobs.nodes,
                jobs.priority):
        assert arr.dtype == np.int32
    out = res.to_np()
    assert out["valid"].sum() == KEPT_JOBS
    assert out["done"].sum() == KEPT_JOBS
    # make_jobset normalized raw submits (min was 1000) to start at 0
    assert out["submit"][out["valid"]].min() == 0
    # the 64-node request was clamped to the 32-node machine
    assert out["nodes"][out["valid"]].max() == 32
    assert res.matches(run_ref(scn))


def test_swf_scenario_gz_and_topology():
    """The .gz copy drives the allocation engine identically, and the swf
    spec composes with topology like any other trace source."""
    scn = Scenario(trace=SwfTrace(FIXTURE + ".gz", max_jobs=10),
                   topology=api.Topology.mesh2d(4, 8), policy="fcfs",
                   alloc="contiguous")
    res = run(scn)
    assert res.matches(run_ref(scn), node_maps=True)
    assert "mean_frag" in res.summary()


def test_swf_downcast_overflow_guard():
    """Traces whose horizon would overflow the int32 sentinel are rejected
    by make_jobset rather than silently wrapped."""
    import tempfile

    with tempfile.NamedTemporaryFile("w", suffix=".swf", delete=False) as fh:
        f = ["1", str(2 ** 31), "0", "10", "1", "-1", "-1", "1", "10", "-1",
             "1"] + ["-1"] * 7
        g = ["2", "0", "0", "10", "1", "-1", "-1", "1", "10", "-1",
             "1"] + ["-1"] * 7
        fh.write(" ".join(f) + "\n" + " ".join(g) + "\n")
        path = fh.name
    try:
        scn = Scenario(trace=SwfTrace(path), total_nodes=4)
        with pytest.raises(ValueError, match="overflows int32"):
            run(scn)
    finally:
        os.unlink(path)
