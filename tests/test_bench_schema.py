"""Schema regression tests for the engine perf artifact (ISSUE 5, ISSUE 8).

``benchmarks/des_throughput.py`` emits ``results/BENCH_engine.json`` — the
machine-readable perf trajectory future PRs regress against.  A benchmark
refactor that silently changes keys or units would corrupt that trajectory
without failing anything; these tests pin the schema:

- every case carries a positive ``run_s``; engine cases carry ``n_events``
  / ``events_per_s`` / ``compile_s`` that are mutually consistent;
- wall-clock stamps are present and monotonic (schema >= 2);
- kernel cases are timed *compiled* and carry bytes/tile so GB/s figures
  are comparable across cases (schema >= 3 — ISSUE 8: the old artifact
  timed the Pallas interpreter and hardcoded the element size);
- the checked-in artifact (if present) parses under the same validator and
  holds the ISSUE-8 throughput floors: backfill within 3x of FCFS on the
  2k no-deps case, no >10x GB/s cliff between queue_select sizes;
- the smoke variant produces the identical shape (slow lane: it runs the
  real benchmark at tiny sizes).
"""

import json
import os

import pytest

RESULTS_JSON = os.path.join(os.path.dirname(__file__), "..", "results",
                            "BENCH_engine.json")


def validate_bench_report(report: dict) -> None:
    assert isinstance(report.get("schema"), int) and report["schema"] >= 1
    assert isinstance(report.get("smoke"), bool)
    cases = report.get("cases")
    assert isinstance(cases, dict) and cases, "report carries no cases"
    for name, case in cases.items():
        assert isinstance(case, dict), name
        assert case.get("run_s", 0) > 0, f"{name}: run_s must be positive"
        if "n_events" in case:  # engine throughput case
            assert case["n_events"] > 0, name
            assert case.get("events_per_s", 0) > 0, name
            assert case.get("compile_s", -1) >= 0, name
            # events/s == n_events / run_s (same units: events, seconds)
            want = case["n_events"] / case["run_s"]
            assert abs(case["events_per_s"] - want) <= 1e-6 * max(want, 1), \
                f"{name}: events_per_s inconsistent with n_events/run_s"
        if "GBps" in case:      # kernel bandwidth case
            assert case["GBps"] > 0, name
            if report["schema"] >= 3:
                # compiled timing with auditable units: GB/s must derive
                # from the actual argument bytes, not a hardcoded width
                assert case.get("mode") == "compiled", \
                    f"{name}: kernel case must be timed compiled"
                assert case.get("tile", 0) > 0, name
                assert case.get("bytes", 0) > 0, name
                want = (case["bytes"] / case["run_s"]) / 1e9
                assert abs(case["GBps"] - want) <= 1e-6 * max(want, 1e-9), \
                    f"{name}: GBps inconsistent with bytes/run_s"
    if report["schema"] >= 2:
        t0, t1 = report["generated_unix"], report["finished_unix"]
        assert t0 > 1e9, "generated_unix is not an epoch timestamp"
        assert t1 >= t0, "timestamps must be monotonic"


def _load_artifact() -> dict:
    if not os.path.exists(RESULTS_JSON):
        pytest.skip("no committed BENCH_engine.json")
    with open(RESULTS_JSON) as f:
        return json.load(f)


def test_checked_in_artifact_parses():
    """The committed perf artifact stays machine-readable."""
    report = _load_artifact()
    validate_bench_report(report)
    # the perf trajectory needs the headline cases to exist under stable
    # names; renaming them silently orphans every historical comparison
    full_run_cases = {"nodeps_fcfs", "nodeps_backfill", "moldable_backfill",
                      "galactic8k_backfill", "trace_replay",
                      "queue_select_N65536", "queue_select_N1048576"}
    smoke_cases = {"nodeps_fcfs", "nodeps_backfill", "galactic_smoke_fcfs",
                   "moldable_backfill", "trace_replay", "queue_select_N65536"}
    have = set(report["cases"])
    assert (full_run_cases <= have) or (smoke_cases <= have), sorted(have)
    # the malleable width-choice case (DESIGN.md §17) carries its static
    # dur-table width so trajectory tooling can match like against like
    assert report["cases"]["moldable_backfill"].get("n_widths", 0) >= 2


def test_checked_in_artifact_is_schema3_compiled():
    """ISSUE 8 regression gate: the committed artifact must be schema >= 3,
    i.e. queue_select timed on the compiled lowering with auditable units —
    an ``interpret_mode`` artifact can never be checked in again."""
    report = _load_artifact()
    assert report["schema"] >= 3
    ks = [c for n, c in report["cases"].items() if n.startswith("queue_select")]
    assert ks, "artifact lost its queue_select cases"
    for case in ks:
        assert case.get("mode") == "compiled"


@pytest.mark.slow
def test_checked_in_artifact_throughput_floors():
    """ISSUE 8/9 acceptance floors on the committed full-run artifact:

    - batched backfill (DESIGN.md §18) holds >= 1/3 of FCFS events/s on
      the 2k no-deps case;
    - compiled queue_select has no >10x GB/s cliff going 64k -> 1M;
    - streaming replay sustains >= 1000 jobs/s on a >= 200k-job archive
      with bounded window occupancy.
    """
    report = _load_artifact()
    if report.get("smoke"):
        pytest.skip("floors are pinned on the full-run artifact")
    cases = report["cases"]
    bf = cases["nodeps_backfill"]["events_per_s"]
    fcfs = cases["nodeps_fcfs"]["events_per_s"]
    assert bf >= fcfs / 3, (
        f"backfill {bf:.0f} ev/s fell below 1/3 of FCFS {fcfs:.0f} ev/s — "
        "the batched backfill pass regressed")
    small = cases["queue_select_N65536"]["GBps"]
    big = cases["queue_select_N1048576"]["GBps"]
    assert big >= small / 10, (
        f"queue_select GB/s cliff: {small:.2f} at 64k vs {big:.2f} at 1M")
    # ISSUE 9 floors: the streaming replay runner (DESIGN.md §19) holds
    # archive scale — >= 200k jobs at >= 1000 jobs/s with the active window
    # bounded by the configured W (no silent whole-trace materialization)
    tr = cases["trace_replay"]
    assert tr["n_jobs"] >= 200_000, tr["n_jobs"]
    assert tr["jobs_per_s"] >= 1000, (
        f"trace_replay fell to {tr['jobs_per_s']:.0f} jobs/s — the windowed "
        "runner regressed")
    assert tr["peak_live"] <= tr["window"], (
        f"peak_live {tr['peak_live']} exceeds window {tr['window']} — replay "
        "memory is no longer bounded")


@pytest.mark.slow
def test_smoke_run_emits_valid_schema(tmp_path):
    """`--smoke` produces the same artifact shape the full run does (CI
    uploads it), validated end-to-end."""
    import sys
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    from benchmarks.des_throughput import run_bench

    report = run_bench(str(tmp_path), smoke=True)
    validate_bench_report(report)
    assert report["smoke"] is True
    assert report["schema"] >= 3
    with open(tmp_path / "BENCH_engine.json") as f:
        on_disk = json.load(f)
    validate_bench_report(on_disk)
    assert on_disk["cases"].keys() == report["cases"].keys()


# -- what-if service benchmark (ISSUE 10) -----------------------------------

WHATIF_JSON = os.path.join(os.path.dirname(__file__), "..", "results",
                           "fig_whatif.json")

WHATIF_FAMILIES = ("placement", "capacity", "reliability")


def validate_whatif_report(report: dict) -> None:
    """The cold/warm amortization contract, pinned on the artifact:
    every family carries both paths, the cold path compiled at least
    once, the warm path compiled exactly ZERO times and was no slower
    than cold — a static-key regression that re-compiles per query can
    never check in a passing artifact."""
    validate_bench_report(report)
    assert report["generated_unix"] > 1e9
    assert report["finished_unix"] >= report["generated_unix"]
    for family in WHATIF_FAMILIES:
        cold = report["cases"][f"{family}_cold"]
        warm = report["cases"][f"{family}_warm"]
        assert cold["compiles"] >= 1, family
        assert warm["compiles"] == 0, (
            f"{family}: warm queries recompiled — the persistent "
            "executable cache regressed")
        assert warm["hits"] >= 1, family
        assert warm["run_s"] <= cold["run_s"], (
            f"{family}: warm {warm['run_s']:.3f}s slower than cold "
            f"{cold['run_s']:.3f}s")
        assert warm["n_queries"] == cold["n_queries"] > 0, family


def test_checked_in_whatif_artifact():
    if not os.path.exists(WHATIF_JSON):
        pytest.skip("no committed fig_whatif.json")
    with open(WHATIF_JSON) as f:
        report = json.load(f)
    validate_whatif_report(report)


@pytest.mark.slow
def test_whatif_smoke_run_emits_valid_schema(tmp_path):
    import sys
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    from benchmarks.fig_whatif import _run

    _run(smoke=True, outdir=str(tmp_path))
    with open(tmp_path / "fig_whatif.json") as f:
        on_disk = json.load(f)
    validate_whatif_report(on_disk)
    assert on_disk["smoke"] is True
