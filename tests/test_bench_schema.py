"""Schema regression tests for the engine perf artifact (ISSUE 5).

``benchmarks/des_throughput.py`` emits ``results/BENCH_engine.json`` — the
machine-readable perf trajectory future PRs regress against.  A benchmark
refactor that silently changes keys or units would corrupt that trajectory
without failing anything; these tests pin the schema:

- every case carries a positive ``run_s``; engine cases carry ``n_events``
  / ``events_per_s`` / ``compile_s`` that are mutually consistent;
- wall-clock stamps are present and monotonic (schema >= 2);
- the checked-in artifact (if present) parses under the same validator;
- the smoke variant produces the identical shape (slow lane: it runs the
  real benchmark at tiny sizes).
"""

import json
import os

import pytest

RESULTS_JSON = os.path.join(os.path.dirname(__file__), "..", "results",
                            "BENCH_engine.json")


def validate_bench_report(report: dict) -> None:
    assert isinstance(report.get("schema"), int) and report["schema"] >= 1
    assert isinstance(report.get("smoke"), bool)
    cases = report.get("cases")
    assert isinstance(cases, dict) and cases, "report carries no cases"
    for name, case in cases.items():
        assert isinstance(case, dict), name
        assert case.get("run_s", 0) > 0, f"{name}: run_s must be positive"
        if "n_events" in case:  # engine throughput case
            assert case["n_events"] > 0, name
            assert case.get("events_per_s", 0) > 0, name
            assert case.get("compile_s", -1) >= 0, name
            # events/s == n_events / run_s (same units: events, seconds)
            want = case["n_events"] / case["run_s"]
            assert abs(case["events_per_s"] - want) <= 1e-6 * max(want, 1), \
                f"{name}: events_per_s inconsistent with n_events/run_s"
        if "GBps" in case:      # kernel bandwidth case
            assert case["GBps"] > 0, name
    if report["schema"] >= 2:
        t0, t1 = report["generated_unix"], report["finished_unix"]
        assert t0 > 1e9, "generated_unix is not an epoch timestamp"
        assert t1 >= t0, "timestamps must be monotonic"


def test_checked_in_artifact_parses():
    """The committed perf artifact stays machine-readable."""
    if not os.path.exists(RESULTS_JSON):
        pytest.skip("no committed BENCH_engine.json")
    with open(RESULTS_JSON) as f:
        report = json.load(f)
    validate_bench_report(report)
    # the perf trajectory needs the headline cases to exist under stable
    # names; renaming them silently orphans every historical comparison
    full_run_cases = {"nodeps_fcfs", "nodeps_backfill", "moldable_backfill"}
    smoke_cases = {"nodeps_fcfs", "galactic_smoke_fcfs", "moldable_backfill"}
    have = set(report["cases"])
    assert (full_run_cases <= have) or (smoke_cases <= have), sorted(have)
    # the malleable width-choice case (DESIGN.md §17) carries its static
    # dur-table width so trajectory tooling can match like against like
    assert report["cases"]["moldable_backfill"].get("n_widths", 0) >= 2


@pytest.mark.slow
def test_smoke_run_emits_valid_schema(tmp_path):
    """`--smoke` produces the same artifact shape the full run does (CI
    uploads it), validated end-to-end."""
    import sys
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    from benchmarks.des_throughput import run_bench

    report = run_bench(str(tmp_path), smoke=True)
    validate_bench_report(report)
    assert report["smoke"] is True
    assert report["schema"] >= 2
    with open(tmp_path / "BENCH_engine.json") as f:
        on_disk = json.load(f)
    validate_bench_report(on_disk)
    assert on_disk["cases"].keys() == report["cases"].keys()
