"""Per-arch reduced-config smoke + numerics: loss finite, decode==forward
consistency, MoE capacity oracle, chunked recurrences vs step recurrences."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs
from repro.models.api import get_model
from repro.sharding.rules import ParamDef

KEY = jax.random.PRNGKey(0)
ARCHS = list_archs()


def zeros_cache(model, B, S):
    return jax.tree.map(
        lambda d: jnp.zeros(d.shape, d.dtype), model.cache_defs_fn(B, S),
        is_leaf=lambda x: isinstance(x, ParamDef))


def make_batch(cfg, B, S, key):
    if cfg.family == "encdec":
        return {
            "src_embeds": jax.random.normal(key, (B, S // 2, cfg.d_model)),
            "tokens": jax.random.randint(key, (B, S // 2), 1, cfg.vocab),
            "labels": jax.random.randint(key, (B, S // 2), 1, cfg.vocab),
        }
    if cfg.family == "vlm":
        sv = S // 4
        return {
            "patches": jax.random.normal(key, (B, sv, cfg.d_model)),
            "tokens": jax.random.randint(key, (B, S - sv), 1, cfg.vocab),
            "labels": jax.random.randint(key, (B, S - sv), 1, cfg.vocab),
        }
    return {
        "tokens": jax.random.randint(key, (B, S), 1, cfg.vocab),
        "labels": jax.random.randint(key, (B, S), 1, cfg.vocab),
    }


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_loss_shapes(arch):
    """Assignment-required smoke: reduced config, one loss eval, no NaNs."""
    cfg = get_config(arch).reduced()
    model = get_model(cfg)
    params = model.init(KEY)
    batch = make_batch(cfg, 2, 32, KEY)
    loss, metrics = jax.jit(model.loss_fn)(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch} loss not finite"
    assert bool(jnp.isfinite(metrics["ce"]))


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step_no_nans(arch):
    from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
    cfg = get_config(arch).reduced()
    model = get_model(cfg)
    params = model.init(KEY)
    batch = make_batch(cfg, 2, 16, KEY)

    @jax.jit
    def step(params, opt):
        (loss, _), g = jax.value_and_grad(model.loss_fn, has_aux=True)(
            params, batch)
        params, opt, _ = adamw_update(g, opt, params, AdamWConfig(lr=1e-3))
        return params, opt, loss

    params, opt, loss0 = step(params, adamw_init(params))
    for leaf in jax.tree.leaves(params):
        assert bool(jnp.isfinite(leaf).all()), f"{arch}: non-finite param"


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_step_shapes(arch):
    cfg = get_config(arch).reduced()
    model = get_model(cfg)
    params = model.init(KEY)
    B, S = 2, 24
    cache = zeros_cache(model, B, S)
    logits, cache2 = jax.jit(model.decode_step)(
        params, jnp.ones((B,), jnp.int32), jnp.int32(0), cache)
    assert logits.shape == (B, cfg.vocab_c)
    assert bool(jnp.isfinite(logits).all())
    assert jax.tree.structure(cache) == jax.tree.structure(cache2)


@pytest.mark.parametrize("arch", [a for a in ARCHS
                                  if get_config(a).family in
                                  ("dense", "moe", "rwkv", "hybrid")])
def test_decode_matches_forward(arch):
    """Sequential decode with cache must reproduce teacher-forced forward.

    MoE capacity is sequence-level (tokens compete for expert slots) while
    decode is token-level; equivalence is only defined drop-free, so raise
    the capacity factor to E (no drops possible).
    """
    from repro.models import lm
    cfg = get_config(arch).reduced()
    if cfg.n_experts:
        cfg = dataclasses.replace(cfg, capacity_factor=float(cfg.n_experts))
    model = get_model(cfg)
    params = model.init(KEY)
    B, S = 2, 12
    tokens = jax.random.randint(jax.random.PRNGKey(5), (B, S), 1, cfg.vocab)
    full_logits, _, _ = lm.forward(params, {"tokens": tokens}, cfg)

    cache = zeros_cache(model, B, S)
    dec = jax.jit(model.decode_step)
    outs = []
    for t in range(S):
        lg, cache = dec(params, tokens[:, t], jnp.int32(t), cache)
        outs.append(lg)
    dec_logits = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(dec_logits[:, :, :cfg.vocab_c], np.float32),
        np.asarray(full_logits, np.float32), atol=2e-3, rtol=2e-3)


def test_moe_matches_dense_oracle_with_large_capacity():
    """With capacity >= S*k no token drops: MoE == explicit top-k mixture."""
    from repro.models.moe import apply_moe
    cfg = get_config("mixtral-8x7b").reduced()
    cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    model = get_model(cfg)
    params = model.init(KEY)["blocks"]["moe"]
    p0 = jax.tree.map(lambda x: x[0], params)  # layer 0
    x = jax.random.normal(KEY, (2, 16, cfg.d_model))
    y, aux = apply_moe(p0, x, cfg)

    # oracle: route every token through its top-k experts densely
    logits = jnp.einsum("bsd,de->bse", x, p0["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gv, gi = jax.lax.top_k(probs, cfg.top_k)
    gv = gv / gv.sum(-1, keepdims=True)
    y_ref = jnp.zeros_like(x)
    for e in range(cfg.n_experts):
        h = jnp.einsum("bsd,df->bsf", x, p0["wi"][e])
        g = jnp.einsum("bsd,df->bsf", x, p0["wg"][e])
        o = jnp.einsum("bsf,fd->bsd", jax.nn.silu(g) * h, p0["wo"][e])
        w = jnp.sum(jnp.where(gi == e, gv, 0.0), axis=-1)
        y_ref = y_ref + w[..., None] * o
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               atol=1e-4, rtol=1e-4)
    assert float(aux) > 0


def test_moe_capacity_drops_tokens():
    from repro.models.moe import _capacity
    cfg = get_config("mixtral-8x7b").reduced()
    tight = dataclasses.replace(cfg, capacity_factor=0.25)
    assert _capacity(64, tight) < _capacity(64, cfg)


def test_ssd_chunked_matches_step_recurrence():
    from repro.models.ssm import _ssd_chunked
    B, S, H, P, N = 2, 40, 3, 8, 4
    ks = jax.random.split(KEY, 4)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, S, N)) * 0.5
    Cm = jax.random.normal(ks[0], (B, S, N)) * 0.5
    y, fin = _ssd_chunked(x, dt, A, Bm, Cm, chunk=8)

    # step oracle
    st = jnp.zeros((B, H, P, N))
    ys = []
    for t in range(S):
        a = jnp.exp(dt[:, t] * A[None])
        dBx = jnp.einsum("bh,bhp,bn->bhpn", dt[:, t], x[:, t], Bm[:, t])
        st = a[:, :, None, None] * st + dBx
        ys.append(jnp.einsum("bn,bhpn->bhp", Cm[:, t], st))
    y_ref = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               atol=2e-4, rtol=2e-3)
    np.testing.assert_allclose(np.asarray(fin), np.asarray(st),
                               atol=2e-4, rtol=2e-3)


def test_wkv_chunked_matches_step_recurrence():
    from repro.models.rwkv import wkv_chunked, wkv_step
    B, S, H, K = 2, 24, 2, 8
    ks = jax.random.split(KEY, 4)
    r = jax.random.normal(ks[0], (B, S, H, K)) * 0.5
    k = jax.random.normal(ks[1], (B, S, H, K)) * 0.5
    v = jax.random.normal(ks[2], (B, S, H, K)) * 0.5
    logw = -jnp.exp(jax.random.normal(ks[3], (B, S, H, K)))
    u = jax.random.normal(ks[0], (H, K)) * 0.5
    y, fin = wkv_chunked(r, k, v, logw, u, chunk=8)

    st = jnp.zeros((B, H, K, K))
    ys = []
    for t in range(S):
        yt, st = wkv_step(r[:, t], k[:, t], v[:, t], logw[:, t], u, st)
        ys.append(yt)
    y_ref = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               atol=2e-4, rtol=2e-3)
    np.testing.assert_allclose(np.asarray(fin), np.asarray(st),
                               atol=2e-4, rtol=2e-3)


def test_chunked_lm_loss_matches_full_ce():
    from repro.models.layers import chunked_lm_loss, cross_entropy, \
        logits_from_hidden
    cfg = get_config("llama3.2-3b").reduced()
    model = get_model(cfg)
    params = model.init(KEY)
    h = jax.random.normal(KEY, (2, 40, cfg.d_model))
    labels = jax.random.randint(KEY, (2, 40), 0, cfg.vocab)
    full = cross_entropy(logits_from_hidden(params["embed"], h, cfg), labels)
    chunked = chunked_lm_loss(params["embed"], h, labels, cfg, chunk=16)
    np.testing.assert_allclose(float(chunked), float(full), rtol=1e-5)


def test_sliding_window_limits_attention():
    """With window=w, token t must ignore tokens < t-w+1."""
    from repro.models.attention import blockwise_attention
    B, S, H, hd = 1, 64, 2, 16
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, H, hd))
    v = jax.random.normal(ks[2], (B, S, H, hd))
    out1 = blockwise_attention(q, k, v, causal=True, window=8,
                               block_q=16, block_k=16)
    # perturb keys/values far outside every query's window
    k2 = k.at[:, :40].set(jax.random.normal(ks[0], (B, 40, H, hd)))
    v2 = v.at[:, :40].set(jax.random.normal(ks[1], (B, 40, H, hd)))
    out2 = blockwise_attention(q, k2, v2, causal=True, window=8,
                               block_q=16, block_k=16)
    np.testing.assert_allclose(np.asarray(out1[:, 48:]),
                               np.asarray(out2[:, 48:]), atol=1e-5)
