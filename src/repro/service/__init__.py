"""What-if capacity-planning query service (DESIGN.md §20).

A long-running process loads a *fleet* of named queue
:class:`~repro.api.Scenario`\\ s once and answers versioned, JSON-round-
trippable :class:`WhatIfQuery` documents — "where should this job run",
"what happens to p99 wait if we add 64 nodes", "which MTBF budget meets a
goodput target" — by lowering each query onto the existing ``sweep()``
API, so scenario buckets reuse the persistent compiled executables across
queries (assertable via :func:`repro.api.cache_stats`).

    from repro import service

    planner = service.CapacityPlanner(service.demo_fleet())
    q = service.WhatIfQuery(kind="capacity", queue="batch",
                            deltas=(service.ScenarioDelta(add_nodes=64),))
    print(planner.answer(q)["recommendations"][0])

``python -m repro.service --demo`` (or ``--fleet fleet.json``) serves the
same planner over stdlib HTTP — see :mod:`repro.service.http`.
"""

from repro.service.http import WhatIfServer, demo_fleet, main, make_server
from repro.service.planner import (
    CapacityPlanner, UnknownQueueError, candidate_outcome, enriched_summary,
)
from repro.service.query import (
    JobRequest, Objective, SCHEMA_VERSION, ScenarioDelta, SchemaError,
    WhatIfQuery, apply_delta, canonical_dumps, fleet_from_json,
    fleet_to_json, scenario_from_json, scenario_to_json,
)

__all__ = [
    "CapacityPlanner", "JobRequest", "Objective", "SCHEMA_VERSION",
    "ScenarioDelta", "SchemaError", "UnknownQueueError", "WhatIfQuery",
    "WhatIfServer", "apply_delta", "candidate_outcome", "canonical_dumps",
    "demo_fleet", "enriched_summary", "fleet_from_json", "fleet_to_json",
    "main", "make_server", "scenario_from_json", "scenario_to_json",
]
