"""Stdlib HTTP front end for the capacity planner (DESIGN.md §20).

No framework, no new dependencies: a ``ThreadingHTTPServer`` wrapping one
:class:`~repro.service.planner.CapacityPlanner` (which serializes query
evaluation internally — HTTP concurrency buys request pipelining, not
parallel sweeps).  Routes:

- ``GET  /health``  — liveness + queue names;
- ``GET  /fleet``   — per-queue baseline metrics (fleet-status aggregation);
- ``GET  /cache``   — sweep executable-cache counters;
- ``POST /query``   — one :class:`WhatIfQuery` JSON document in, one
  recommendation response out.

Errors are structured: ``{"error": {"type": ..., "message": ...}}`` with
400 for malformed/invalid documents, 404 for unknown queues, 422 for
schema-valid but unanswerable queries (e.g. reliability against a queue
with no failure model), 405/404 for bad routes.

``python -m repro.service --fleet fleet.json`` serves a fleet config;
``--demo`` serves a small built-in three-queue fleet (what the CI smoke
test and ``examples/whatif_queries.py`` use).
"""

from __future__ import annotations

import argparse
import json
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Any, Dict, Optional, Tuple

from repro.api import FailureModel, Scenario, SyntheticTrace, Topology

from repro.service.planner import CapacityPlanner, UnknownQueueError
from repro.service.query import (
    SchemaError, WhatIfQuery, canonical_dumps, fleet_from_json,
)

# SchemaError.code -> HTTP status: a query that *cannot be expressed* is the
# client's fault (400); one that is well-formed but unanswerable here is 422
_STATUS_BY_CODE = {"unknown_field": 400, "missing_field": 400,
                   "bad_value": 400, "bad_version": 400, "unsupported": 422}


def demo_fleet() -> Dict[str, Scenario]:
    """Small three-queue fleet: a scalar-counter batch queue, a mesh2d
    queue with contiguous allocation, and a failure-prone backfill queue —
    one of each mode so every query kind has a natural target."""
    return {
        "batch": Scenario(
            trace=SyntheticTrace(n_jobs=200, seed=0, kind="sdsc_sp2"),
            total_nodes=128, policy="fcfs"),
        "mesh": Scenario(
            trace=SyntheticTrace(n_jobs=200, seed=1, kind="sdsc_sp2"),
            topology=Topology.mesh2d(8, 16), policy="sjf",
            alloc="contiguous"),
        "flaky": Scenario(
            trace=SyntheticTrace(n_jobs=200, seed=2, kind="sdsc_sp2"),
            total_nodes=128, policy="backfill",
            failures=FailureModel(mtbf=1_000_000.0, seed=7,
                                  max_failures=512)),
    }


class _Handler(BaseHTTPRequestHandler):
    server: "WhatIfServer"
    protocol_version = "HTTP/1.1"

    # -- plumbing ------------------------------------------------------------

    def _send(self, status: int, payload: Dict[str, Any]) -> None:
        body = canonical_dumps(payload).encode("utf-8")
        self.send_response(status)
        self.send_header("Content-Type", "application/json")
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def _error(self, status: int, err_type: str, message: str) -> None:
        self._send(status, {"error": {"type": err_type, "message": message}})

    def log_message(self, fmt, *args):  # quiet by default
        if self.server.verbose:
            super().log_message(fmt, *args)

    # -- routes --------------------------------------------------------------

    def do_GET(self):
        planner = self.server.planner
        try:
            if self.path == "/health":
                self._send(200, {"status": "ok", "version": 1,
                                 "queues": sorted(planner.fleet)})
            elif self.path == "/fleet":
                self._send(200, planner.fleet_status())
            elif self.path == "/cache":
                self._send(200, planner.fleet_status()["cache"])
            else:
                self._error(404, "not_found",
                            f"no route {self.path!r}; routes: /health "
                            "/fleet /cache, POST /query")
        except Exception as e:  # noqa: BLE001 — a request must not kill the server
            self._error(500, "internal", f"{type(e).__name__}: {e}")

    def do_POST(self):
        if self.path != "/query":
            self._error(404, "not_found",
                        f"no POST route {self.path!r}; POST /query")
            return
        try:
            length = int(self.headers.get("Content-Length", 0))
            body = self.rfile.read(length).decode("utf-8")
            query = WhatIfQuery.from_json(body)
            self._send(200, self.server.planner.answer(query))
        except SchemaError as e:
            self._error(_STATUS_BY_CODE.get(e.code, 400), e.code, str(e))
        except UnknownQueueError as e:
            self._error(404, "unknown_queue", str(e))
        except Exception as e:  # noqa: BLE001
            self._error(500, "internal", f"{type(e).__name__}: {e}")


class WhatIfServer(ThreadingHTTPServer):
    """ThreadingHTTPServer + the planner it fronts."""

    daemon_threads = True

    def __init__(self, fleet: Dict[str, Scenario],
                 address: Tuple[str, int] = ("127.0.0.1", 0), *,
                 verbose: bool = False):
        super().__init__(address, _Handler)
        self.planner = CapacityPlanner(fleet)
        self.verbose = verbose

    @property
    def url(self) -> str:
        host, port = self.server_address[:2]
        return f"http://{host}:{port}"


def make_server(fleet: Dict[str, Scenario], host: str = "127.0.0.1",
                port: int = 0, *, verbose: bool = False) -> WhatIfServer:
    """Build (but don't start) a service; ``port=0`` picks a free port."""
    return WhatIfServer(fleet, (host, port), verbose=verbose)


def main(argv: Optional[list] = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.service",
        description="What-if capacity-planning query service")
    src = parser.add_mutually_exclusive_group(required=True)
    src.add_argument("--fleet", help="fleet config JSON "
                     '({"version": 1, "queues": {name: scenario}})')
    src.add_argument("--demo", action="store_true",
                     help="serve the built-in three-queue demo fleet")
    parser.add_argument("--host", default="127.0.0.1")
    parser.add_argument("--port", type=int, default=0,
                        help="0 picks a free port (printed on startup)")
    parser.add_argument("--verbose", action="store_true",
                        help="log every request")
    args = parser.parse_args(argv)

    if args.demo:
        fleet = demo_fleet()
    else:
        with open(args.fleet, "r", encoding="utf-8") as f:
            try:
                doc = json.load(f)
            except json.JSONDecodeError as e:
                parser.error(f"{args.fleet}: not valid JSON: {e}")
        fleet = fleet_from_json(doc)

    server = make_server(fleet, args.host, args.port, verbose=args.verbose)
    # the subprocess smoke test scrapes this exact line for the bound port
    print(f"serving on {server.url}", flush=True)
    try:
        server.serve_forever()
    except KeyboardInterrupt:
        pass
    finally:
        server.server_close()
    return 0
