"""The capacity planner: what-if queries against a fleet of named queues.

:class:`CapacityPlanner` holds a fleet (``{queue_name: Scenario}``) for the
lifetime of a service process and answers :class:`~repro.service.query.
WhatIfQuery` objects by *lowering* them onto the existing ``sweep()`` API
(DESIGN.md §12.2, §20):

- every evaluation routes through a non-degenerate ``sweep`` call (a
  single-value axis when nothing varies), so each point runs the shared
  vmapped bucket executables and the module-level jit cache makes repeated
  queries against the same scenario bucket pay the XLA compile exactly
  once — asserted via :func:`repro.api.cache_stats`;
- grids that are traced sweep data batch into ONE executable per query:
  ``add_nodes`` grids on scalar-counter queues sweep ``total_nodes``,
  reliability queries sweep ``failures.mtbf`` × ``failures.
  checkpoint_interval`` (DESIGN.md §15);
- candidate-job injection goes through :class:`repro.api.InjectedTrace`,
  whose static key is (base key, count) — placement queries against one
  queue always share one executable regardless of the candidate's values.

``evaluate()`` returns the lowered scenarios next to their Results so the
differential harness can replay every point through ``run()``/``run_ref()``
and assert bit-exactness; ``answer()`` wraps the same evaluation into the
JSON-able response the HTTP layer ships.
"""

from __future__ import annotations

import threading
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.api import Scenario, cache_stats, sweep
from repro.api.result import Result
from repro.core import metrics

from repro.service.query import (
    Objective, SCHEMA_VERSION, ScenarioDelta, SchemaError, WhatIfQuery,
    apply_delta,
)


class UnknownQueueError(KeyError):
    """Query names a queue the fleet does not have (HTTP 404)."""

    def __init__(self, name: str, known):
        super().__init__(name)
        self.name = name
        self.known = sorted(known)

    def __str__(self):
        return (f"unknown queue {self.name!r}; fleet has "
                f"{self.known}")


def jsonable(obj):
    """Deep-copy with non-finite floats replaced by None: responses go
    through the strict (``allow_nan=False``) canonical encoder, and an
    empty percentile must degrade to ``null``, not a 500."""
    if isinstance(obj, dict):
        return {k: jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [jsonable(v) for v in obj]
    if isinstance(obj, float) and not np.isfinite(obj):
        return None
    return obj


def enriched_summary(result: Result) -> Dict[str, float]:
    """``Result.summary()`` plus ``p99_wait`` — the planning objective the
    standard summary (p50/p95) lacks.  Serving results already carry an
    exact p99 from ``slo_summary``; batch results get the same
    ``metrics.percentiles`` computation over the canonical wait column."""
    s = result.summary()
    if "p99_wait" not in s:
        out = result.to_np()
        done = (np.asarray(out["valid"], dtype=bool)
                & np.asarray(out["done"], dtype=bool))
        s["p99_wait"] = metrics.percentiles(out["wait"], 99, mask=done)
    return s


def _candidate_row(scn: Scenario) -> int:
    """Sorted-row index of the LAST injected job.

    ``make_jobset`` sorts by (submit, input index), so the appended
    candidate (input row n-1) lands at a deterministic sorted position —
    behind every incumbent sharing its submit time."""
    sub = np.asarray(scn.trace.materialize()["submit"])
    n = len(sub)
    order = np.lexsort((np.arange(n), sub))
    return int(np.nonzero(order == n - 1)[0][0])


def candidate_outcome(scn: Scenario, result: Result) -> Dict[str, Any]:
    """The injected candidate's row metrics from a placement point."""
    row = _candidate_row(scn)
    out = result.to_np()
    started = bool(out["done"][row]) and bool(out["valid"][row])
    return {
        "row": row,
        "start": int(out["start"][row]),
        "finish": int(out["finish"][row]),
        "wait": int(out["wait"][row]),
        "started": started,
    }


def _single_point(scn: Scenario) -> Result:
    """Run one scenario through the batched bucket path (B=1).

    ``sweep(s, axes={})`` degenerates to ``run()`` and would bypass the
    shared executable cache; a single-value ``policy`` axis is the
    universal no-op axis (every scenario has a policy) that keeps the
    service on the cached vmapped runners — and on the cache statistics.
    """
    return sweep(scn, axes={"policy": (scn.policy,)}).results[0]


class CapacityPlanner:
    """Long-running what-if answerer over a fleet of named queues."""

    def __init__(self, fleet: Dict[str, Scenario]):
        if not fleet:
            raise SchemaError("bad_value", "fleet has no queues")
        self.fleet: Dict[str, Scenario] = dict(fleet)
        self._status: Dict[str, Dict[str, float]] = {}
        # one query at a time: evaluation mutates the process-wide jit /
        # stats caches, and interleaved queries would misattribute deltas
        self._lock = threading.Lock()

    # -- fleet ---------------------------------------------------------------

    def queue(self, name: Optional[str]) -> Tuple[str, Scenario]:
        if name is None:
            if len(self.fleet) == 1:
                return next(iter(self.fleet.items()))
            raise SchemaError(
                "missing_field", f"query names no queue and the fleet has "
                f"{len(self.fleet)}; set 'queue'")
        if name not in self.fleet:
            raise UnknownQueueError(name, self.fleet)
        return name, self.fleet[name]

    def baseline_summary(self, name: str) -> Dict[str, float]:
        """The queue's as-is summary (cached for the planner's lifetime —
        the fleet is immutable once loaded)."""
        if name not in self._status:
            _, scn = self.queue(name)
            self._status[name] = enriched_summary(_single_point(scn))
        return dict(self._status[name])

    def fleet_status(self) -> Dict[str, Any]:
        """Per-queue baseline metrics — the service's aggregate dashboard."""
        with self._lock:
            queues = {}
            for name, scn in self.fleet.items():
                queues[name] = {
                    "total_nodes": int(np.sum(scn.nodes_per_cluster())),
                    "policy": str(scn.policy),
                    "topology": (None if scn.topology is None
                                 else scn.topology.kind),
                    "failures": scn.failures is not None,
                    "summary": self.baseline_summary(name),
                }
            c = cache_stats()
            return jsonable(
                {"version": SCHEMA_VERSION, "queues": queues,
                 "cache": {"compiles": c.compiles, "hits": c.hits,
                           "entries": c.entries}})

    # -- evaluation ----------------------------------------------------------

    def evaluate(self, query: WhatIfQuery) -> List[Dict[str, Any]]:
        """Lower a query to scenarios, run them, return grid-ordered points.

        Each point dict carries ``label``, ``queue``, the lowered
        ``scenario`` (what the differential harness replays through
        ``run()``/``run_ref()``), its ``result``, and per-kind metadata
        (``delta`` / ``mtbf`` / ``checkpoint_interval`` / ``candidate``).
        """
        with self._lock:
            return self._evaluate_locked(query)

    def _evaluate_locked(self, query: WhatIfQuery) -> List[Dict[str, Any]]:
        if query.kind == "placement":
            return self._eval_placement(query)
        if query.kind == "capacity":
            return self._eval_capacity(query)
        return self._eval_reliability(query)

    def _eval_placement(self, query: WhatIfQuery) -> List[Dict[str, Any]]:
        names = query.queues
        if names is None:
            names = tuple(self.fleet)
        points = []
        for name in names:
            name, base = self.queue(name)
            job = query.job
            if job.nodes > int(np.sum(base.nodes_per_cluster())):
                # make_jobset would silently clamp the request; an answer
                # computed from a clamped job is not the job the user asked
                # about, so the queue is reported infeasible instead
                points.append({
                    "label": name, "queue": name, "scenario": None,
                    "result": None, "candidate": None,
                    "infeasible": f"job needs {job.nodes} nodes; queue "
                                  f"has {base.total_nodes}",
                })
                continue
            delta = ScenarioDelta(inject=(job,))
            scn = apply_delta(base, delta)
            res = _single_point(scn)
            points.append({
                "label": name, "queue": name, "scenario": scn,
                "result": res, "candidate": candidate_outcome(scn, res),
                "infeasible": None,
            })
        return points

    def _eval_capacity(self, query: WhatIfQuery) -> List[Dict[str, Any]]:
        name, base = self.queue(query.queue)
        scenarios = [apply_delta(base, d) for d in query.deltas]
        # a pure add_nodes grid on a scalar-counter queue is traced sweep
        # data: ONE executable runs every delta (DESIGN.md §12.2)
        nodes_only = base.topology is None and all(
            d == ScenarioDelta(add_nodes=d.add_nodes) for d in query.deltas)
        if nodes_only and len(query.deltas) > 1:
            grid = sweep(base, axes={
                "total_nodes": tuple(int(s.total_nodes) for s in scenarios)})
            results = list(grid.results)
        else:
            results = [_single_point(s) for s in scenarios]
        return [{
            "label": d.describe(), "queue": name, "scenario": s,
            "result": r, "delta": d,
            "candidate": (candidate_outcome(s, r) if d.inject else None),
        } for d, s, r in zip(query.deltas, scenarios, results)]

    def _eval_reliability(self, query: WhatIfQuery) -> List[Dict[str, Any]]:
        name, base = self.queue(query.queue)
        if base.failures is None:
            raise SchemaError(
                "unsupported", f"queue {name!r} carries no FailureModel; "
                "reliability queries need a failures= spec on the base "
                "scenario")
        axes: Dict[str, tuple] = {"failures.mtbf": query.mtbf_grid}
        if query.checkpoint_grid:
            axes["failures.checkpoint_interval"] = query.checkpoint_grid
        grid = sweep(base, axes=axes)
        points = []
        for point, res in grid:
            mtbf = float(point["failures.mtbf"])
            ckpt = point.get("failures.checkpoint_interval")
            label = f"mtbf={mtbf:g}"
            if ckpt is not None:
                label += f", ckpt={int(ckpt)}"
            points.append({
                "label": label, "queue": name,
                "scenario": base.with_(**point), "result": res,
                "mtbf": mtbf,
                "checkpoint_interval": None if ckpt is None else int(ckpt),
            })
        return points

    # -- answers -------------------------------------------------------------

    def answer(self, query: WhatIfQuery) -> Dict[str, Any]:
        """The JSON-able response for one query (module docstring)."""
        before = cache_stats()
        points = self.evaluate(query)
        objective = query.default_objective()
        rows = []
        out_points = []
        for p in points:
            entry: Dict[str, Any] = {"label": p["label"],
                                     "queue": p["queue"]}
            if p.get("delta") is not None:
                entry["delta"] = p["delta"].to_json_dict()
            for k in ("mtbf", "checkpoint_interval"):
                if k in p:
                    entry[k] = p[k]
            if p.get("infeasible"):
                entry["infeasible"] = p["infeasible"]
                out_points.append(entry)
                continue
            summ = enriched_summary(p["result"])
            if p.get("candidate") is not None:
                entry["candidate"] = p["candidate"]
                summ["candidate_wait"] = (
                    float(p["candidate"]["wait"])
                    if p["candidate"]["started"] else float("nan"))
            entry["summary"] = summ
            rows.append((p["label"], summ))
            out_points.append(entry)
        if not rows:
            raise SchemaError(
                "unsupported", "no feasible evaluation point (every "
                "candidate queue was too small for the job)")

        baseline = None
        if query.kind in ("capacity", "reliability"):
            baseline = self.baseline_summary(points[0]["queue"])
        try:
            recs = metrics.rank_candidates(
                rows, objective.metric, goal=objective.goal,
                baseline=baseline, target=objective.target)
        except KeyError as e:
            raise SchemaError("bad_value", str(e))

        # with a target, "recommended" is the first candidate IN INPUT
        # ORDER meeting it (input order encodes the asker's cost
        # preference: cheapest deltas first); without one, the best-ranked
        recommended = None
        if objective.target is not None:
            by_label = {r["label"]: r for r in recs}
            for label, _ in rows:
                if by_label[label].get("meets_target"):
                    recommended = label
                    break
        elif recs:
            recommended = recs[0]["label"]

        after = cache_stats()
        return jsonable({
            "version": SCHEMA_VERSION,
            "kind": query.kind,
            "queue": query.queue,
            "objective": objective.to_json_dict(),
            "baseline": baseline,
            "points": out_points,
            "recommendations": recs,
            "recommended": recommended,
            "cache": {"compiles": after.compiles - before.compiles,
                      "hits": after.hits - before.hits,
                      "entries": after.entries},
        })
