"""The frozen what-if query schema and its JSON codec (DESIGN.md §20).

A :class:`WhatIfQuery` is a versioned, JSON-round-trippable description of
ONE capacity-planning question against a fleet of named queues:

- ``kind="placement"``  — "where should this job run": inject a candidate
  :class:`JobRequest` into every candidate queue and rank queues by the
  candidate's wait;
- ``kind="capacity"``   — "what happens to p99 wait if we add 64 nodes":
  evaluate a list of :class:`ScenarioDelta`\\ s against one queue;
- ``kind="reliability"``— "which MTBF budget meets a goodput target":
  sweep ``failures.mtbf`` (× optionally ``checkpoint_interval``) grids.

Every query *lowers* onto the existing :class:`repro.api.Scenario` API via
:func:`apply_delta` — the same function the differential test harness uses
to materialize the equivalent direct-run scenario, so "service answer ==
``run()``/``run_ref()`` of the lowered scenario" is checkable bit-for-bit.

The codec is strict and canonical: unknown or missing fields raise
:class:`SchemaError`, every field is always emitted (no omit-if-default),
and :func:`canonical_dumps` fixes key order and separators, so
serialize → deserialize → re-serialize is byte-identical.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, Optional, Tuple

from repro.api.scenario import (
    InjectedTrace, Scenario, SwfTrace, SyntheticTrace, Topology,
    WorkflowTrace,
)
from repro.reliability import FailureModel

SCHEMA_VERSION = 1

QUERY_KINDS = ("placement", "capacity", "reliability")


class SchemaError(ValueError):
    """A query/scenario JSON document violates the v1 schema.

    ``code`` is a stable machine-readable tag the HTTP layer maps onto
    4xx responses: ``unknown_field`` / ``missing_field`` / ``bad_value`` /
    ``bad_version`` / ``unsupported``.
    """

    def __init__(self, code: str, message: str):
        super().__init__(message)
        self.code = code


def _require(obj: Dict[str, Any], allowed: Dict[str, bool],
             what: str) -> None:
    """Strict key check: every required key present, no unknown keys."""
    if not isinstance(obj, dict):
        raise SchemaError("bad_value", f"{what} must be a JSON object, "
                                       f"got {type(obj).__name__}")
    unknown = sorted(set(obj) - set(allowed))
    if unknown:
        raise SchemaError(
            "unknown_field", f"{what} has unknown field(s) {unknown}; "
            f"allowed: {sorted(allowed)}")
    missing = sorted(k for k, req in allowed.items() if req and k not in obj)
    if missing:
        raise SchemaError(
            "missing_field", f"{what} is missing required field(s) "
            f"{missing}")


def _opt_num(obj, key, what, *, integer=False):
    v = obj.get(key)
    if v is None:
        return None
    if isinstance(v, bool) or not isinstance(v, (int, float)):
        raise SchemaError("bad_value", f"{what}.{key} must be a number")
    return int(v) if integer else float(v)


def canonical_dumps(obj: Any) -> str:
    """The one canonical JSON encoding (sorted keys, tight separators) —
    what makes round trips byte-comparable."""
    return json.dumps(obj, sort_keys=True, separators=(",", ":"),
                      allow_nan=False)


# ---------------------------------------------------------------------------
# query dataclasses
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class JobRequest:
    """The candidate job of a placement query."""

    submit: int
    runtime: int
    nodes: int
    estimate: Optional[int] = None
    priority: Optional[int] = None

    def __post_init__(self):
        object.__setattr__(self, "submit", int(self.submit))
        object.__setattr__(self, "runtime", int(self.runtime))
        object.__setattr__(self, "nodes", int(self.nodes))
        if self.estimate is not None:
            object.__setattr__(self, "estimate", int(self.estimate))
        if self.priority is not None:
            object.__setattr__(self, "priority", int(self.priority))
        if self.runtime < 1 or self.nodes < 1 or self.submit < 0:
            raise SchemaError(
                "bad_value", "job needs submit >= 0, runtime >= 1 and "
                f"nodes >= 1; got {self}")

    def as_tuple(self) -> Tuple[Optional[int], ...]:
        return (self.submit, self.runtime, self.nodes, self.estimate,
                self.priority)

    def to_json_dict(self) -> Dict[str, Any]:
        return {"submit": self.submit, "runtime": self.runtime,
                "nodes": self.nodes, "estimate": self.estimate,
                "priority": self.priority}

    _FIELDS = {"submit": True, "runtime": True, "nodes": True,
               "estimate": False, "priority": False}

    @classmethod
    def from_json_dict(cls, obj: Dict[str, Any]) -> "JobRequest":
        _require(obj, cls._FIELDS, "job")
        try:
            return cls(submit=_opt_num(obj, "submit", "job", integer=True),
                       runtime=_opt_num(obj, "runtime", "job", integer=True),
                       estimate=_opt_num(obj, "estimate", "job",
                                         integer=True),
                       priority=_opt_num(obj, "priority", "job",
                                         integer=True),
                       nodes=_opt_num(obj, "nodes", "job", integer=True))
        except TypeError:
            raise SchemaError(
                "bad_value", "job.submit/runtime/nodes must be numbers")


@dataclasses.dataclass(frozen=True)
class ScenarioDelta:
    """One hypothetical change to a queue's base scenario.

    Any combination of: grow/shrink the machine (``add_nodes``, scalar
    counter or linear topology only), swap the scheduling ``policy`` or the
    ``alloc`` strategy, override the failure model's ``mtbf`` /
    ``checkpoint_interval`` / ``restart_overhead`` (requires the base to
    carry a :class:`FailureModel`), and ``inject`` candidate jobs.  The
    identity delta (all defaults) is valid and means "the queue as-is".
    """

    add_nodes: int = 0
    policy: Optional[str] = None
    alloc: Optional[str] = None
    mtbf: Optional[float] = None
    checkpoint_interval: Optional[int] = None
    restart_overhead: Optional[int] = None
    inject: Tuple[JobRequest, ...] = ()

    def __post_init__(self):
        object.__setattr__(self, "add_nodes", int(self.add_nodes))
        object.__setattr__(self, "inject", tuple(self.inject))
        for j in self.inject:
            if not isinstance(j, JobRequest):
                raise SchemaError(
                    "bad_value",
                    f"delta.inject entries must be JobRequests, got "
                    f"{type(j).__name__}")
        if self.mtbf is not None:
            object.__setattr__(self, "mtbf", float(self.mtbf))
            if not self.mtbf > 0:
                raise SchemaError("bad_value",
                                  f"delta.mtbf must be > 0, got {self.mtbf}")
        for k in ("checkpoint_interval", "restart_overhead"):
            v = getattr(self, k)
            if v is not None:
                object.__setattr__(self, k, int(v))
                if getattr(self, k) < 0:
                    raise SchemaError("bad_value", f"delta.{k} must be >= 0")

    def describe(self) -> str:
        """Compact human-readable label for recommendation rows."""
        parts = []
        if self.add_nodes:
            parts.append(f"{self.add_nodes:+d} nodes")
        if self.policy is not None:
            parts.append(f"policy={self.policy}")
        if self.alloc is not None:
            parts.append(f"alloc={self.alloc}")
        if self.mtbf is not None:
            parts.append(f"mtbf={self.mtbf:g}")
        if self.checkpoint_interval is not None:
            parts.append(f"ckpt={self.checkpoint_interval}")
        if self.restart_overhead is not None:
            parts.append(f"restart={self.restart_overhead}")
        if self.inject:
            parts.append(f"+{len(self.inject)} job(s)")
        return ", ".join(parts) if parts else "as-is"

    def to_json_dict(self) -> Dict[str, Any]:
        return {
            "add_nodes": self.add_nodes,
            "policy": self.policy,
            "alloc": self.alloc,
            "mtbf": self.mtbf,
            "checkpoint_interval": self.checkpoint_interval,
            "restart_overhead": self.restart_overhead,
            "inject": [j.to_json_dict() for j in self.inject],
        }

    _FIELDS = {"add_nodes": False, "policy": False, "alloc": False,
               "mtbf": False, "checkpoint_interval": False,
               "restart_overhead": False, "inject": False}

    @classmethod
    def from_json_dict(cls, obj: Dict[str, Any]) -> "ScenarioDelta":
        _require(obj, cls._FIELDS, "delta")
        inject = obj.get("inject") or []
        if not isinstance(inject, list):
            raise SchemaError("bad_value", "delta.inject must be a list")
        for k in ("policy", "alloc"):
            if obj.get(k) is not None and not isinstance(obj[k], str):
                raise SchemaError("bad_value", f"delta.{k} must be a string")
        return cls(
            add_nodes=_opt_num(obj, "add_nodes", "delta", integer=True) or 0,
            policy=obj.get("policy"),
            alloc=obj.get("alloc"),
            mtbf=_opt_num(obj, "mtbf", "delta"),
            checkpoint_interval=_opt_num(obj, "checkpoint_interval", "delta",
                                         integer=True),
            restart_overhead=_opt_num(obj, "restart_overhead", "delta",
                                      integer=True),
            inject=tuple(JobRequest.from_json_dict(j) for j in inject),
        )


@dataclasses.dataclass(frozen=True)
class Objective:
    """What the recommendation optimizes: a summary metric, a direction,
    and an optional target level ("meets the goal")."""

    metric: str = "p99_wait"
    goal: str = "min"
    target: Optional[float] = None

    def __post_init__(self):
        if self.goal not in ("min", "max"):
            raise SchemaError(
                "bad_value", f"objective.goal must be 'min' or 'max', "
                f"got {self.goal!r}")
        if self.target is not None:
            object.__setattr__(self, "target", float(self.target))

    def to_json_dict(self) -> Dict[str, Any]:
        return {"metric": self.metric, "goal": self.goal,
                "target": self.target}

    _FIELDS = {"metric": False, "goal": False, "target": False}

    @classmethod
    def from_json_dict(cls, obj: Dict[str, Any]) -> "Objective":
        _require(obj, cls._FIELDS, "objective")
        metric = obj.get("metric", "p99_wait")
        goal = obj.get("goal", "min")
        if not isinstance(metric, str) or not isinstance(goal, str):
            raise SchemaError("bad_value",
                              "objective.metric/goal must be strings")
        return cls(metric=metric, goal=goal,
                   target=_opt_num(obj, "target", "objective"))


@dataclasses.dataclass(frozen=True)
class WhatIfQuery:
    """One versioned what-if question (module docstring).

    ``queue`` names the target queue for capacity/reliability queries;
    ``queues`` restricts placement candidates (None = every fleet queue).
    Either may be None when the fleet has an unambiguous default.
    """

    kind: str
    queue: Optional[str] = None
    queues: Optional[Tuple[str, ...]] = None
    job: Optional[JobRequest] = None
    deltas: Tuple[ScenarioDelta, ...] = ()
    mtbf_grid: Tuple[float, ...] = ()
    checkpoint_grid: Tuple[int, ...] = ()
    objective: Optional[Objective] = None

    def __post_init__(self):
        if self.kind not in QUERY_KINDS:
            raise SchemaError(
                "bad_value", f"kind must be one of {QUERY_KINDS}, "
                f"got {self.kind!r}")
        object.__setattr__(self, "deltas", tuple(self.deltas))
        object.__setattr__(self, "mtbf_grid",
                           tuple(float(m) for m in self.mtbf_grid))
        object.__setattr__(self, "checkpoint_grid",
                           tuple(int(c) for c in self.checkpoint_grid))
        if self.queues is not None:
            object.__setattr__(self, "queues", tuple(self.queues))
        if self.kind == "placement":
            if self.job is None:
                raise SchemaError("missing_field",
                                  "placement queries need a job")
            if self.deltas or self.mtbf_grid or self.checkpoint_grid:
                raise SchemaError(
                    "bad_value", "placement queries take only a job (the "
                    "deltas/mtbf_grid fields belong to capacity/"
                    "reliability queries)")
        elif self.kind == "capacity":
            if not self.deltas:
                raise SchemaError("missing_field",
                                  "capacity queries need >= 1 delta")
            if self.job is not None or self.mtbf_grid:
                raise SchemaError(
                    "bad_value", "capacity queries take deltas only "
                    "(inject jobs through a delta's `inject` field)")
        else:  # reliability
            if not self.mtbf_grid:
                raise SchemaError("missing_field",
                                  "reliability queries need an mtbf_grid")
            if self.job is not None or self.deltas:
                raise SchemaError(
                    "bad_value", "reliability queries take mtbf_grid "
                    "(+ optional checkpoint_grid) only")

    def to_json_dict(self) -> Dict[str, Any]:
        return {
            "version": SCHEMA_VERSION,
            "kind": self.kind,
            "queue": self.queue,
            "queues": None if self.queues is None else list(self.queues),
            "job": None if self.job is None else self.job.to_json_dict(),
            "deltas": [d.to_json_dict() for d in self.deltas],
            "mtbf_grid": list(self.mtbf_grid),
            "checkpoint_grid": list(self.checkpoint_grid),
            "objective": (None if self.objective is None
                          else self.objective.to_json_dict()),
        }

    def to_json(self) -> str:
        return canonical_dumps(self.to_json_dict())

    _FIELDS = {"version": True, "kind": True, "queue": False,
               "queues": False, "job": False, "deltas": False,
               "mtbf_grid": False, "checkpoint_grid": False,
               "objective": False}

    @classmethod
    def from_json_dict(cls, obj: Dict[str, Any]) -> "WhatIfQuery":
        _require(obj, cls._FIELDS, "query")
        if obj["version"] != SCHEMA_VERSION:
            raise SchemaError(
                "bad_version", f"unsupported query version "
                f"{obj['version']!r}; this service speaks "
                f"version {SCHEMA_VERSION}")
        if not isinstance(obj["kind"], str):
            raise SchemaError("bad_value", "kind must be a string")
        queues = obj.get("queues")
        if queues is not None:
            if (not isinstance(queues, list)
                    or not all(isinstance(q, str) for q in queues)):
                raise SchemaError("bad_value",
                                  "queues must be a list of strings")
            queues = tuple(queues)
        queue = obj.get("queue")
        if queue is not None and not isinstance(queue, str):
            raise SchemaError("bad_value", "queue must be a string")
        deltas = obj.get("deltas") or []
        mtbf_grid = obj.get("mtbf_grid") or []
        ckpt_grid = obj.get("checkpoint_grid") or []
        for name, grid in (("deltas", deltas), ("mtbf_grid", mtbf_grid),
                           ("checkpoint_grid", ckpt_grid)):
            if not isinstance(grid, list):
                raise SchemaError("bad_value", f"{name} must be a list")
        if any(isinstance(m, bool) or not isinstance(m, (int, float))
               for m in mtbf_grid):
            raise SchemaError("bad_value", "mtbf_grid must hold numbers")
        if any(isinstance(c, bool) or not isinstance(c, int)
               for c in ckpt_grid):
            raise SchemaError("bad_value",
                              "checkpoint_grid must hold integers")
        job = obj.get("job")
        objective = obj.get("objective")
        return cls(
            kind=obj["kind"],
            queue=queue,
            queues=queues,
            job=None if job is None else JobRequest.from_json_dict(job),
            deltas=tuple(ScenarioDelta.from_json_dict(d) for d in deltas),
            mtbf_grid=tuple(float(m) for m in mtbf_grid),
            checkpoint_grid=tuple(ckpt_grid),
            objective=(None if objective is None
                       else Objective.from_json_dict(objective)),
        )

    @classmethod
    def from_json(cls, text: str) -> "WhatIfQuery":
        try:
            obj = json.loads(text)
        except json.JSONDecodeError as e:
            raise SchemaError("bad_value", f"query is not valid JSON: {e}")
        return cls.from_json_dict(obj)

    def default_objective(self) -> Objective:
        """The per-kind objective when the query leaves it None."""
        if self.objective is not None:
            return self.objective
        if self.kind == "placement":
            return Objective(metric="candidate_wait", goal="min")
        if self.kind == "reliability":
            return Objective(metric="goodput", goal="max")
        return Objective(metric="p99_wait", goal="min")


# ---------------------------------------------------------------------------
# delta -> Scenario lowering (shared with the differential test harness)
# ---------------------------------------------------------------------------


def apply_delta(base: Scenario, delta: ScenarioDelta) -> Scenario:
    """Lower one :class:`ScenarioDelta` onto a base :class:`Scenario`.

    This is THE semantics of a what-if point: the service's answer for a
    delta must be bit-exact against ``run(apply_delta(base, delta))`` and
    ``run_ref(...)`` of the very same scenario — the differential harness
    in ``tests/test_service.py`` asserts exactly that.
    """
    overrides: Dict[str, Any] = {}
    if delta.policy is not None:
        overrides["policy"] = delta.policy
    if delta.alloc is not None:
        if base.topology is None:
            raise SchemaError(
                "unsupported", "delta swaps alloc but the queue has no "
                "topology (scalar-counter queues ignore placement)")
        overrides["alloc"] = delta.alloc
    if delta.add_nodes:
        if base.topology is None:
            n = int(base.total_nodes) + delta.add_nodes
            if n < 1:
                raise SchemaError(
                    "bad_value", f"delta removes {-delta.add_nodes} nodes "
                    f"from a {base.total_nodes}-node queue")
            overrides["total_nodes"] = n
        elif base.topology.kind == "linear":
            n = base.topology.shape[0] + delta.add_nodes
            if n < 1:
                raise SchemaError(
                    "bad_value", f"delta removes {-delta.add_nodes} nodes "
                    f"from a {base.topology.shape[0]}-node linear machine")
            overrides["topology"] = Topology("linear",
                                             (n, base.topology.shape[1]))
            overrides["total_nodes"] = n
        else:
            raise SchemaError(
                "unsupported", f"add_nodes on a {base.topology.kind} "
                "topology is ambiguous (which rows/groups grow?); "
                "model it as a scalar-counter or linear queue")
    for field, key in (("mtbf", "failures.mtbf"),
                       ("checkpoint_interval",
                        "failures.checkpoint_interval"),
                       ("restart_overhead", "failures.restart_overhead")):
        v = getattr(delta, field)
        if v is not None:
            if base.failures is None:
                raise SchemaError(
                    "unsupported", f"delta sets {field} but the queue "
                    "carries no FailureModel; give the base scenario a "
                    "failures= spec first")
            overrides[key] = v
    scn = base.with_(**overrides) if overrides else base
    if delta.inject:
        jobs = tuple(j.as_tuple() for j in delta.inject)
        trace = scn.trace
        if isinstance(trace, InjectedTrace):
            trace = InjectedTrace(base=trace.base,
                                  jobs=trace.jobs + jobs)
        else:
            trace = InjectedTrace(base=trace, jobs=jobs)
        scn = dataclasses.replace(scn, trace=trace)
    return scn


# ---------------------------------------------------------------------------
# Scenario <-> JSON (the fleet-config codec)
# ---------------------------------------------------------------------------

_TRACE_FIELDS = {
    "synthetic": {"type": True, "n_jobs": False, "seed": False,
                  "kind": False, "params": False, "congest": False},
    "workflow": {"type": True, "kind": False, "seed": False,
                 "params": False, "submit": False, "priority": False},
    "swf": {"type": True, "path": True, "max_jobs": False, "strict": False},
    "inject": {"type": True, "base": True, "jobs": True},
}


def trace_to_json(spec) -> Dict[str, Any]:
    if isinstance(spec, SyntheticTrace):
        return {"type": "synthetic", "n_jobs": spec.n_jobs,
                "seed": spec.seed, "kind": spec.kind,
                "params": dict(spec.params), "congest": spec.congest}
    if isinstance(spec, WorkflowTrace):
        return {"type": "workflow", "kind": spec.kind, "seed": spec.seed,
                "params": dict(spec.params), "submit": spec.submit,
                "priority": spec.priority}
    if isinstance(spec, SwfTrace):
        return {"type": "swf", "path": spec.path,
                "max_jobs": spec.max_jobs, "strict": spec.strict}
    if isinstance(spec, InjectedTrace):
        return {"type": "inject", "base": trace_to_json(spec.base),
                "jobs": [list(j) for j in spec.jobs]}
    raise SchemaError(
        "unsupported", f"trace spec {type(spec).__name__} has no JSON form "
        "(ArrayTrace/ServiceTrace queues cannot be described in a fleet "
        "config)")


def trace_from_json(obj: Dict[str, Any]):
    if not isinstance(obj, dict) or "type" not in obj:
        raise SchemaError("missing_field",
                          "trace needs a 'type' field")
    kind = obj["type"]
    if kind not in _TRACE_FIELDS:
        raise SchemaError(
            "bad_value", f"unknown trace type {kind!r}; known: "
            f"{sorted(_TRACE_FIELDS)}")
    _require(obj, _TRACE_FIELDS[kind], f"trace[{kind}]")
    if kind == "synthetic":
        params = obj.get("params") or {}
        return SyntheticTrace(
            n_jobs=int(obj.get("n_jobs", 1000)), seed=int(obj.get("seed", 0)),
            kind=obj.get("kind", "generic"),
            params=tuple(sorted(params.items())),
            congest=int(obj.get("congest", 1)))
    if kind == "workflow":
        params = obj.get("params") or {}
        return WorkflowTrace(
            kind=obj.get("kind", "montage"), seed=int(obj.get("seed", 0)),
            params=tuple(sorted(params.items())),
            submit=int(obj.get("submit", 0)), priority=obj.get("priority"))
    if kind == "swf":
        return SwfTrace(path=obj["path"], max_jobs=obj.get("max_jobs"),
                        strict=bool(obj.get("strict", False)))
    jobs = obj["jobs"]
    if not isinstance(jobs, list):
        raise SchemaError("bad_value", "trace[inject].jobs must be a list")
    return InjectedTrace(base=trace_from_json(obj["base"]),
                         jobs=tuple(tuple(j) for j in jobs))


_SCENARIO_FIELDS = {"version": True, "trace": True, "total_nodes": False,
                    "policy": False, "topology": False, "alloc": False,
                    "contention": False, "capacity": False,
                    "max_events": False, "failures": False}

_FAILURE_FIELDS = {"mtbf": True, "seed": False, "distribution": False,
                   "k": False, "mean_repair": False, "horizon": False,
                   "max_failures": False, "requeue": False,
                   "checkpoint_interval": False, "restart_overhead": False}


def scenario_to_json(scn: Scenario) -> Dict[str, Any]:
    """Serialize a queue scenario (the serviceable subset) to JSON."""
    for field, why in (("multicluster", "multicluster queues"),
                       ("malleable", "malleable queues")):
        if getattr(scn, field) is not None:
            raise SchemaError("unsupported",
                              f"{why} have no JSON form yet")
    if scn.contention is not None and not isinstance(
            scn.contention, (tuple, list)):
        raise SchemaError(
            "unsupported", "only (num, den) contention tuples serialize")
    out = {
        "version": SCHEMA_VERSION,
        "trace": trace_to_json(scn.trace),
        "total_nodes": int(scn.total_nodes),
        "policy": str(scn.policy),
        "topology": (None if scn.topology is None
                     else {"kind": scn.topology.kind,
                           "shape": list(scn.topology.shape)}),
        "alloc": scn.alloc,
        "contention": (None if scn.contention is None
                       else [int(x) for x in scn.contention]),
        "capacity": scn.capacity,
        "max_events": scn.max_events,
        "failures": None,
    }
    if scn.failures is not None:
        f = scn.failures
        out["failures"] = {
            "mtbf": float(f.mtbf), "seed": f.seed,
            "distribution": f.distribution, "k": float(f.k),
            "mean_repair": f.mean_repair, "horizon": f.horizon,
            "max_failures": f.max_failures, "requeue": f.requeue,
            "checkpoint_interval": f.checkpoint_interval,
            "restart_overhead": f.restart_overhead,
        }
    return out


def scenario_from_json(obj: Dict[str, Any]) -> Scenario:
    _require(obj, _SCENARIO_FIELDS, "scenario")
    if obj["version"] != SCHEMA_VERSION:
        raise SchemaError(
            "bad_version", f"unsupported scenario version "
            f"{obj['version']!r}; this service speaks version "
            f"{SCHEMA_VERSION}")
    topology = None
    topo = obj.get("topology")
    if topo is not None:
        _require(topo, {"kind": True, "shape": True}, "topology")
        shape = topo["shape"]
        if not isinstance(shape, list) or len(shape) != 2:
            raise SchemaError("bad_value",
                              "topology.shape must be a 2-element list")
        topology = Topology(topo["kind"], (int(shape[0]), int(shape[1])))
    failures = None
    fobj = obj.get("failures")
    if fobj is not None:
        _require(fobj, _FAILURE_FIELDS, "failures")
        defaults = FailureModel(mtbf=1.0)
        try:
            failures = FailureModel(
                mtbf=float(fobj["mtbf"]),
                seed=int(fobj.get("seed", defaults.seed)),
                distribution=fobj.get("distribution",
                                      defaults.distribution),
                k=float(fobj.get("k", defaults.k)),
                mean_repair=int(fobj.get("mean_repair",
                                         defaults.mean_repair)),
                horizon=int(fobj.get("horizon", defaults.horizon)),
                max_failures=int(fobj.get("max_failures",
                                          defaults.max_failures)),
                requeue=fobj.get("requeue", defaults.requeue),
                checkpoint_interval=int(
                    fobj.get("checkpoint_interval",
                             defaults.checkpoint_interval)),
                restart_overhead=int(fobj.get("restart_overhead",
                                              defaults.restart_overhead)),
            )
        except ValueError as e:
            raise SchemaError("bad_value", f"bad failures spec: {e}")
    contention = obj.get("contention")
    if contention is not None:
        if not isinstance(contention, list) or len(contention) != 2:
            raise SchemaError("bad_value",
                              "contention must be a [num, den] pair")
        contention = (int(contention[0]), int(contention[1]))
    try:
        return Scenario(
            trace=trace_from_json(obj["trace"]),
            total_nodes=obj.get("total_nodes"),
            policy=obj.get("policy", "fcfs"),
            topology=topology,
            alloc=obj.get("alloc"),
            contention=contention,
            capacity=obj.get("capacity"),
            max_events=obj.get("max_events"),
            failures=failures,
        )
    except (ValueError, TypeError) as e:
        if isinstance(e, SchemaError):
            raise
        raise SchemaError("bad_value", f"bad scenario: {e}")


def fleet_to_json(fleet: Dict[str, Scenario]) -> Dict[str, Any]:
    """Serialize a named-queue fleet to its config-file form."""
    return {"version": SCHEMA_VERSION,
            "queues": {name: scenario_to_json(s)
                       for name, s in fleet.items()}}


def fleet_from_json(obj: Dict[str, Any]) -> Dict[str, Scenario]:
    _require(obj, {"version": True, "queues": True}, "fleet")
    if obj["version"] != SCHEMA_VERSION:
        raise SchemaError("bad_version",
                          f"unsupported fleet version {obj['version']!r}")
    queues = obj["queues"]
    if not isinstance(queues, dict) or not queues:
        raise SchemaError("bad_value",
                          "fleet.queues must be a non-empty object")
    return {name: scenario_from_json(s) for name, s in queues.items()}
