from repro.service.http import main

raise SystemExit(main())
