"""Synthetic workload generators with the statistical shape of the paper's
traces (GWA-DAS2, SDSC-SP2).

Published characteristics we match (Iosup et al. 2008; PWA SDSC-SP2 page):

- DAS-2: ~1.1M jobs over ~1.5 years on 400 processors across 5 clusters;
  bursty arrivals, short median runtimes (tens of seconds to minutes),
  power-of-two node requests dominate, heavy-tailed runtime distribution.
- SDSC-SP2: 73,496 jobs, 128-node SP2, longer runtimes (median ~8 min,
  heavy tail to 18h), requested walltimes overestimate actuals ~2-5x.

Everything is deterministic given ``seed``.
"""

from __future__ import annotations

from typing import Dict

import numpy as np


def synthetic_trace(
    n_jobs: int,
    *,
    seed: int = 0,
    mean_interarrival: float = 30.0,
    runtime_lognorm=(5.0, 1.6),
    max_runtime: int = 36_000,
    node_pow2_max: int = 6,
    large_frac: float = 0.08,
    total_nodes: int = 128,
    estimate_factor=(1.0, 5.0),
    burstiness: float = 0.5,
) -> Dict[str, np.ndarray]:
    """Generic bursty heavy-tailed trace generator.

    - arrivals: Markov-modulated Poisson-ish (bursts switch the rate x8),
    - runtimes: lognormal clipped to ``max_runtime``,
    - nodes: power-of-two biased, with a ``large_frac`` tail of big jobs,
    - estimates: runtime x Uniform(estimate_factor), as in SP2-style logs.
    """
    rng = np.random.default_rng(seed)
    burst = rng.random(n_jobs) < burstiness
    gaps = rng.exponential(mean_interarrival, n_jobs)
    gaps = np.where(burst, gaps / 8.0, gaps)
    submit = np.cumsum(gaps).astype(np.int64)

    mu, sigma = runtime_lognorm
    runtime = np.clip(rng.lognormal(mu, sigma, n_jobs), 1, max_runtime).astype(np.int64)

    pows = rng.integers(0, node_pow2_max + 1, n_jobs)
    nodes = (2 ** pows).astype(np.int64)
    big = rng.random(n_jobs) < large_frac
    nodes = np.where(big, rng.integers(total_nodes // 4, total_nodes + 1, n_jobs), nodes)
    nodes = np.clip(nodes, 1, total_nodes)

    lo, hi = estimate_factor
    estimate = np.clip((runtime * rng.uniform(lo, hi, n_jobs)).astype(np.int64),
                       runtime, None)
    return {
        "submit": submit, "runtime": runtime, "nodes": nodes, "estimate": estimate,
    }


def das2_like(n_jobs: int = 10_000, *, seed: int = 0) -> Dict[str, np.ndarray]:
    """DAS-2-shaped trace (400-processor grid, short bursty jobs)."""
    return synthetic_trace(
        n_jobs, seed=seed, mean_interarrival=45.0, runtime_lognorm=(4.2, 1.8),
        max_runtime=15 * 3600, node_pow2_max=5, large_frac=0.04,
        total_nodes=400, estimate_factor=(1.5, 8.0), burstiness=0.6,
    )


def sdsc_sp2_like(n_jobs: int = 10_000, *, seed: int = 1) -> Dict[str, np.ndarray]:
    """SDSC-SP2-shaped trace (128-node SP2, longer heavy-tailed jobs)."""
    return synthetic_trace(
        n_jobs, seed=seed, mean_interarrival=430.0, runtime_lognorm=(6.2, 1.9),
        max_runtime=18 * 3600, node_pow2_max=7, large_frac=0.06,
        total_nodes=128, estimate_factor=(1.2, 5.0), burstiness=0.4,
    )


DAS2_TOTAL_NODES = 400
SDSC_SP2_TOTAL_NODES = 128
