from repro.traces.swf import SwfReport, dump_swf, load_swf  # noqa: F401
from repro.traces.synthetic import (  # noqa: F401
    das2_like, sdsc_sp2_like, synthetic_trace,
)
from repro.traces.workflows import workflow_to_trace  # noqa: F401
