"""Standard Workload Format (SWF) parser, hardened for full-archive logs.

The paper uses GWA-DAS2 (Grid Workloads Archive) and SDSC-SP2 (Parallel
Workloads Archive).  Both distribute SWF: one job per line, 18 whitespace-
separated fields, ';' comment header.  This container is offline, so tests
and benchmarks use the statistical generators in ``synthetic.py``; drop a
real ``.swf``/``.swf.gz`` file in and this loader feeds it straight to the
engines (``repro.replay`` for full archives, one-shot ``simulate`` for
trimmed ones).

SWF fields used (1-indexed per the spec):
  1 job id, 2 submit time, 4 run time, 5 allocated processors,
  8 requested processors, 9 requested time (estimate), 11 status.

Archive-grade input is messy, so the loader is an auditor, not a crasher
(DESIGN.md §19): every line lands in exactly one of

- **loaded** — a well-formed row that survives the filters,
- **skipped** — well-formed but filtered by data semantics (non-positive
  runtime or processor count, the classic cancelled/failed encodings),
- **cancelled** — dropped because the SWF status field says 5 (cancelled
  before start; such jobs never consumed resources),
- **quarantined** — malformed (too few fields, non-numeric values,
  negative submit time); lenient mode counts these and keeps going,
  ``strict=True`` raises on the first one with the line number.

``load_swf`` returns ``(trace, report)``: the int64 column dict the
engines consume plus a :class:`SwfReport` of those counters.  Submit
times are rebased to the earliest kept submit (``rebase=False`` keeps raw
log seconds; the raw epoch is preserved in ``report.t0`` either way), and
the report warns — loudly, via ``warnings.warn`` — when any column would
truncate under the engines' int32 downcast.
"""

from __future__ import annotations

import dataclasses
import gzip
import warnings
from typing import Dict, Tuple

import numpy as np

# SWF status-field values (field 11).  Per the spec: 1 = completed, 0 =
# failed, 5 = cancelled.  Failed jobs ran (they consumed resources) and are
# kept when their runtime is positive, matching AccaSim/CQsim replay
# practice; cancelled jobs never started and are dropped.
STATUS_CANCELLED = 5

_I32_MAX = int(np.iinfo(np.int32).max)

# keep at most this many (line_no, reason) samples in the report
_MAX_EXAMPLES = 3


@dataclasses.dataclass(frozen=True)
class SwfReport:
    """Ingestion audit for one ``load_swf`` call (DESIGN.md §19)."""

    path: str
    n_lines: int = 0          # data lines seen (comments/blank excluded)
    n_jobs: int = 0           # rows loaded into the trace
    n_skipped: int = 0        # well-formed rows filtered (runtime/procs <= 0)
    n_quarantined: int = 0    # malformed rows (short/non-numeric/neg submit)
    n_cancelled: int = 0      # rows dropped by SWF status == 5
    t0: int = 0               # earliest kept raw submit (the rebase epoch)
    int32_safe: bool = True   # False => the int32 downcast would truncate
    examples: tuple = ()      # up to 3 (line_no, reason) bad-line samples

    def summary(self) -> str:
        return (f"{self.path}: {self.n_jobs} jobs loaded / {self.n_lines} "
                f"rows ({self.n_skipped} filtered, {self.n_cancelled} "
                f"cancelled, {self.n_quarantined} quarantined)")


def _opener(path: str):
    return gzip.open if str(path).endswith(".gz") else open


def load_swf(
    path: str,
    *,
    max_jobs: int | None = None,
    strict: bool = False,
    rebase: bool = True,
) -> Tuple[Dict[str, np.ndarray], SwfReport]:
    """Parse an SWF log into int64 columns plus an ingestion report.

    Returns ``(trace, report)`` where ``trace`` has ``submit``/``runtime``/
    ``nodes``/``estimate`` int64 arrays and ``report`` is a
    :class:`SwfReport`.  ``strict=True`` raises :class:`ValueError` on the
    first malformed line instead of quarantining it; data-semantics filters
    (non-positive runtime/procs, cancelled status) never raise.  With
    ``rebase=True`` (default) submit times start at 0 and the raw epoch is
    recorded in ``report.t0``.
    """
    submit, runtime, nodes, estimate = [], [], [], []
    n_lines = n_skipped = n_quarantined = n_cancelled = 0
    examples: list[tuple[int, str]] = []

    def bad(lineno: int, reason: str, line: str):
        nonlocal n_quarantined
        if strict:
            raise ValueError(f"{path}:{lineno}: {reason}: {line!r}")
        n_quarantined += 1
        if len(examples) < _MAX_EXAMPLES:
            examples.append((lineno, reason))

    with _opener(path)(path, "rt") as fh:
        for lineno, line in enumerate(fh, start=1):
            line = line.strip()
            if not line or line.startswith(";"):
                continue
            n_lines += 1
            f = line.split()
            if len(f) < 9:
                bad(lineno, f"expected >= 9 fields, got {len(f)}", line)
                continue
            try:
                sub = int(float(f[1]))
                rt = int(float(f[3]))
                alloc_procs = int(float(f[4]))
                req_procs = int(float(f[7]))
                est = int(float(f[8]))
                status = int(float(f[10])) if len(f) >= 11 else None
            except ValueError:
                bad(lineno, "non-numeric field", line)
                continue
            if sub < 0:
                bad(lineno, f"negative submit time {sub}", line)
                continue
            if status == STATUS_CANCELLED:
                n_cancelled += 1
                continue
            procs = req_procs if req_procs > 0 else alloc_procs
            if rt <= 0 or procs <= 0:
                n_skipped += 1   # failed/zero-width rows, per common practice
                continue
            submit.append(sub)
            runtime.append(rt)
            nodes.append(procs)
            estimate.append(est if est > 0 else rt)
            if max_jobs is not None and len(submit) >= max_jobs:
                break

    trace = {
        "submit": np.asarray(submit, dtype=np.int64),
        "runtime": np.asarray(runtime, dtype=np.int64),
        "nodes": np.asarray(nodes, dtype=np.int64),
        "estimate": np.asarray(estimate, dtype=np.int64),
    }
    t0 = int(trace["submit"].min()) if len(submit) else 0
    if rebase:
        trace["submit"] = trace["submit"] - t0
    top = max((int(v.max()) for v in trace.values() if v.size), default=0)
    int32_safe = top <= _I32_MAX
    if not int32_safe:
        warnings.warn(
            f"{path}: column values up to {top} exceed int32; the one-shot "
            "engine's downcast would truncate — replay this trace through "
            "repro.replay (int64 host clocks) or rescale its time unit",
            stacklevel=2)
    report = SwfReport(
        path=str(path), n_lines=n_lines, n_jobs=len(submit),
        n_skipped=n_skipped, n_quarantined=n_quarantined,
        n_cancelled=n_cancelled, t0=t0, int32_safe=int32_safe,
        examples=tuple(examples),
    )
    return trace, report


def dump_swf(path: str, trace: Dict[str, np.ndarray], *,
             comment: str | None = None) -> int:
    """Write a trace dict as a standard 18-field SWF file (gz by suffix).

    The inverse of :func:`load_swf` for the fields this project consumes
    (submit/runtime/nodes/estimate; unused fields hold -1, status 1), so
    synthetic traces can exercise the real archive ingestion path — CI
    generates its ~200k-job replay input this way.  Returns the number of
    rows written.
    """
    submit = np.asarray(trace["submit"], dtype=np.int64)
    runtime = np.asarray(trace["runtime"], dtype=np.int64)
    nodes = np.asarray(trace["nodes"], dtype=np.int64)
    estimate = np.asarray(trace.get("estimate", runtime), dtype=np.int64)
    n = len(submit)
    with _opener(path)(path, "wt") as fh:
        if comment:
            for ln in comment.splitlines():
                fh.write(f"; {ln}\n")
        fh.write("; job submit wait run alloc_procs avgcpu mem req_procs "
                 "req_time req_mem status uid gid exe queue part prev think\n")
        for i in range(n):
            fh.write(
                f"{i + 1} {submit[i]} -1 {runtime[i]} {nodes[i]} -1 -1 "
                f"{nodes[i]} {estimate[i]} -1 1 -1 -1 -1 -1 -1 -1 -1\n")
    return n
