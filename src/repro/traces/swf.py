"""Standard Workload Format (SWF) parser.

The paper uses GWA-DAS2 (Grid Workloads Archive) and SDSC-SP2 (Parallel
Workloads Archive).  Both distribute SWF: one job per line, 18 whitespace-
separated fields, ';' comment header.  This container is offline, so tests
and benchmarks use the statistical generators in ``synthetic.py``; drop a
real ``.swf`` file in and this loader feeds it straight to the engines.

SWF fields used (1-indexed per the spec):
  1 job id, 2 submit time, 4 run time, 5 allocated processors,
  8 requested processors, 9 requested time (estimate), 11 status.
"""

from __future__ import annotations

import gzip
from typing import Dict

import numpy as np


def load_swf(path: str, *, max_jobs: int | None = None) -> Dict[str, np.ndarray]:
    opener = gzip.open if str(path).endswith(".gz") else open
    submit, runtime, nodes, estimate = [], [], [], []
    with opener(path, "rt") as fh:
        for line in fh:
            line = line.strip()
            if not line or line.startswith(";"):
                continue
            f = line.split()
            if len(f) < 9:
                continue
            rt = int(float(f[3]))
            procs = int(float(f[7])) if int(float(f[7])) > 0 else int(float(f[4]))
            est = int(float(f[8]))
            if rt <= 0 or procs <= 0:
                continue  # cancelled/failed rows, per common practice
            submit.append(int(float(f[1])))
            runtime.append(rt)
            nodes.append(procs)
            estimate.append(est if est > 0 else rt)
            if max_jobs is not None and len(submit) >= max_jobs:
                break
    return {
        "submit": np.asarray(submit, dtype=np.int64),
        "runtime": np.asarray(runtime, dtype=np.int64),
        "nodes": np.asarray(nodes, dtype=np.int64),
        "estimate": np.asarray(estimate, dtype=np.int64),
    }
