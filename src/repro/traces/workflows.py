"""Workflow DAG generators + the paper's JSON input format (Listing 2).

Topologies follow the published structure of the workflows the paper uses
for validation (Juve et al. 2013 "Characterizing and Profiling Scientific
Workflows"; Pegasus workflow gallery):

- Montage: mProjectPP (W) -> mDiffFit (~3W edges between neighbours)
  -> mConcatFit (1) -> mBgModel (1) -> mBackground (W) -> mImgtbl (1)
  -> mAdd (1) -> mShrink (1) -> mJPEG (1).  Many short tasks.
- Galactic Plane: union of K independent Montage tile workflows feeding a
  final mosaic merge (paper Fig. 6 runs this at scale).
- SIPHT: parallel sRNA prediction chains (Patser x W -> concat), several
  independent annotation tasks, final sRNA annotate (paper Fig. 7).

All generators return plain dicts compatible with ``make_taskset`` /
``simulate_workflow_reference`` and the JSON round-trip below.
"""

from __future__ import annotations

import json
from typing import Dict, List, Tuple

import numpy as np

WorkflowDict = Dict[str, object]


def _mk(exec_time, cpu, mem, dep_pairs) -> WorkflowDict:
    return {
        "exec_time": np.asarray(exec_time, dtype=np.int64),
        "resources": np.stack(
            [np.asarray(cpu, dtype=np.int64), np.asarray(mem, dtype=np.int64)], axis=1
        ),
        "dep_pairs": list(dep_pairs),
    }


def chain(n: int, exec_time: int = 100, cpu: int = 1, mem: int = 512) -> WorkflowDict:
    return _mk([exec_time] * n, [cpu] * n, [mem] * n, [(i, i - 1) for i in range(1, n)])


def fork_join(width: int, depth: int, *, seed: int = 0) -> WorkflowDict:
    """depth stages of `width` parallel tasks with barrier joins."""
    rng = np.random.default_rng(seed)
    n = depth * width + depth + 1
    et, cpu, mem, deps = [], [], [], []
    src = 0
    et.append(10); cpu.append(1); mem.append(256)
    prev_join = 0
    idx = 1
    for _ in range(depth):
        stage = list(range(idx, idx + width))
        for t in stage:
            et.append(int(rng.integers(50, 500)))
            cpu.append(int(rng.integers(1, 4)))
            mem.append(int(rng.choice([256, 512, 1024])))
            deps.append((t, prev_join))
        idx += width
        join = idx
        et.append(20); cpu.append(1); mem.append(256)
        for t in stage:
            deps.append((join, t))
        prev_join = join
        idx += 1
    return _mk(et, cpu, mem, deps)


def random_layered(
    n_tasks: int, n_layers: int, p_edge: float = 0.15, *, seed: int = 0
) -> WorkflowDict:
    """Random layered DAG (Gupta et al. 2017-style generator, paper §3.2)."""
    rng = np.random.default_rng(seed)
    layer = np.sort(rng.integers(0, n_layers, n_tasks))
    et = rng.integers(10, 1000, n_tasks)
    cpu = rng.integers(1, 8, n_tasks)
    mem = rng.choice([256, 512, 1024, 2048], n_tasks)
    deps: List[Tuple[int, int]] = []
    for i in range(n_tasks):
        cands = np.nonzero(layer < layer[i])[0]
        if len(cands) == 0:
            continue
        picks = cands[rng.random(len(cands)) < p_edge]
        if len(picks) == 0 and layer[i] > 0:
            picks = [int(rng.choice(cands))]
        deps.extend((i, int(j)) for j in picks)
    return _mk(et, cpu, mem, deps)


def montage_like(width: int = 20, *, seed: int = 0) -> WorkflowDict:
    rng = np.random.default_rng(seed)
    et, cpu, mem, deps = [], [], [], []

    def add(t, c, m):
        et.append(int(t)); cpu.append(int(c)); mem.append(int(m))
        return len(et) - 1

    project = [add(rng.integers(8, 25), 1, 512) for _ in range(width)]
    diff = []
    for i in range(width - 1):
        d = add(rng.integers(3, 12), 1, 256)
        deps += [(d, project[i]), (d, project[i + 1])]
        diff.append(d)
    concat = add(rng.integers(30, 80), 1, 1024)
    deps += [(concat, d) for d in diff]
    bgmodel = add(rng.integers(50, 150), 2, 2048)
    deps.append((bgmodel, concat))
    background = []
    for i in range(width):
        b = add(rng.integers(5, 15), 1, 512)
        deps += [(b, project[i]), (b, bgmodel)]
        background.append(b)
    imgtbl = add(rng.integers(10, 30), 1, 512)
    deps += [(imgtbl, b) for b in background]
    madd = add(rng.integers(100, 300), 4, 4096)
    deps.append((madd, imgtbl))
    shrink = add(rng.integers(20, 60), 1, 1024)
    deps.append((shrink, madd))
    jpeg = add(rng.integers(5, 15), 1, 256)
    deps.append((jpeg, shrink))
    return _mk(et, cpu, mem, deps)


def galactic_like(tiles: int = 8, width: int = 12, *, seed: int = 0) -> WorkflowDict:
    """Union of `tiles` Montage tile workflows + final mosaic merge."""
    et, cpu, mem, deps = [], [], [], []
    finals = []
    for k in range(tiles):
        sub = montage_like(width, seed=seed * 1000 + k)
        off = len(et)
        et.extend(sub["exec_time"].tolist())
        cpu.extend(sub["resources"][:, 0].tolist())
        mem.extend(sub["resources"][:, 1].tolist())
        deps.extend((t + off, d + off) for t, d in sub["dep_pairs"])
        finals.append(off + len(sub["exec_time"]) - 1)
    merge = len(et)
    et.append(200); cpu.append(4); mem.append(8192)
    deps.extend((merge, f) for f in finals)
    return _mk(et, cpu, mem, deps)


def sipht_like(width: int = 30, *, seed: int = 0) -> WorkflowDict:
    rng = np.random.default_rng(seed)
    et, cpu, mem, deps = [], [], [], []

    def add(t, c, m):
        et.append(int(t)); cpu.append(int(c)); mem.append(int(m))
        return len(et) - 1

    patser = [add(rng.integers(2, 10), 1, 256) for _ in range(width)]
    pconcat = add(rng.integers(10, 30), 1, 512)
    deps += [(pconcat, p) for p in patser]
    # independent analysis tasks (blast, RNAMotif, transterm, findterm, ...)
    analyses = [add(rng.integers(60, 3600), int(rng.integers(1, 4)), 1024)
                for _ in range(6)]
    srna = add(rng.integers(300, 1200), 2, 2048)
    deps += [(srna, a) for a in analyses]
    ffn = add(rng.integers(30, 120), 1, 512)
    deps.append((ffn, srna))
    annotate = add(rng.integers(100, 400), 2, 2048)
    deps += [(annotate, ffn), (annotate, pconcat)]
    return _mk(et, cpu, mem, deps)


# ---------------------------------------------------------------------------
# lowering: workflow DAG -> cluster job trace (DESIGN.md §13)
# ---------------------------------------------------------------------------

def workflow_to_trace(wf: WorkflowDict, *, submit: int = 0,
                      priority: str | None = None) -> Dict[str, object]:
    """Lower a workflow dict to a cluster job-trace dict with ``deps``.

    Tasks become cluster jobs: ``exec_time`` -> runtime/estimate, the cpu
    requirement (``resources[:, 0]``) -> node count (memory is a pool-model
    resource with no cluster analogue and is dropped), and the DAG edges
    ride along as ``deps`` pairs for ``make_jobset``.  Every task shares one
    ``submit`` time — release order is driven purely by the dependency
    structure, so wait = start - ready isolates queueing delay (paper
    Fig. 7).  ``priority="cpath"`` attaches critical-path-length priorities
    (longest path first) for the ``preempt`` policy.
    """
    et = np.asarray(wf["exec_time"], dtype=np.int64)
    nodes = np.asarray(wf["resources"], dtype=np.int64)
    if nodes.ndim == 2:
        nodes = nodes[:, 0]
    n = len(et)
    trace: Dict[str, object] = {
        "submit": np.full(n, int(submit), dtype=np.int64),
        "runtime": et.copy(),
        "estimate": et.copy(),
        "nodes": np.maximum(nodes, 1),
        "deps": [(int(t), int(d)) for t, d in wf["dep_pairs"]],
    }
    if priority == "cpath":
        from repro.core.workflow import critical_path_length
        trace["priority"] = critical_path_length(et, wf["dep_pairs"])
    elif priority is not None:
        raise ValueError(f"unknown workflow priority scheme {priority!r}")
    return trace


# ---------------------------------------------------------------------------
# Paper Listing 2 JSON format
# ---------------------------------------------------------------------------

def to_json(wf: WorkflowDict, pools, *, policy: str = "Static",
            preemption: bool = False) -> str:
    """Serialize to the paper's JSON workflow input format (Listing 2)."""
    tasks = []
    dep_map: Dict[int, List[int]] = {}
    for t, d in wf["dep_pairs"]:
        dep_map.setdefault(int(t), []).append(int(d) + 1)  # paper ids are 1-based
    for i, et in enumerate(np.asarray(wf["exec_time"]).tolist()):
        tasks.append({
            "id": i + 1,
            "execution_time": int(et),
            "resources": {
                "cpu": int(wf["resources"][i][0]),
                "memory": int(wf["resources"][i][1]),
            },
            "dependencies": sorted(dep_map.get(i, [])),
        })
    pools = np.asarray(pools).tolist()
    doc = {
        "tasks": tasks,
        "resources_available": {"cpu": int(pools[0]), "memory": int(pools[1])},
        "scheduling_policy": policy,
        "preemption": preemption,
    }
    return json.dumps(doc, indent=1)


def from_json(text: str) -> Tuple[WorkflowDict, np.ndarray, str]:
    """Parse the paper's JSON workflow format -> (workflow, pools, policy)."""
    doc = json.loads(text)
    tasks = doc["tasks"]
    ids = [int(t["id"]) for t in tasks]
    remap = {tid: i for i, tid in enumerate(ids)}
    et = [int(t["execution_time"]) for t in tasks]
    cpu = [int(t["resources"].get("cpu", 1)) for t in tasks]
    mem = [int(t["resources"].get("memory", 0)) for t in tasks]
    deps = []
    for t in tasks:
        for d in t.get("dependencies", []):
            deps.append((remap[int(t["id"])], remap[int(d)]))
    ra = doc.get("resources_available", {"cpu": 1, "memory": 0})
    pools = np.asarray([int(ra.get("cpu", 1)), int(ra.get("memory", 0))], dtype=np.int64)
    return _mk(et, cpu, mem, deps), pools, doc.get("scheduling_policy", "Static")
