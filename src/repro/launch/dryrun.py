import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape) on the production
mesh, prove it fits (memory_analysis) and extract roofline terms
(cost_analysis + HLO collective parse).  One cell per process:

    PYTHONPATH=src python -m repro.launch.dryrun --arch mixtral-8x7b \
        --shape train_4k --mesh single --out results/mixtral_train.json

The XLA_FLAGS line above MUST run before any other jax import — jax locks
the device count at first init (assignment requirement; do not move it).
"""

import argparse
import json
import sys
import time


def run_cell(arch: str, shape_name: str, mesh_kind: str, rules_name: str | None,
             out_path: str | None, print_hlo: bool = False,
             accum: int | None = None, remat_policy: str | None = None) -> dict:
    import jax
    from repro.configs.base import SHAPES, cell_supported
    from repro.launch.hlo_analysis import analyze_hlo_text
    from repro.launch.mesh import make_production_mesh, mesh_num_devices
    from repro.launch.roofline import summarize_cell
    from repro.launch.specs import build_cell
    from repro.sharding.rules import RULE_SETS

    ok, reason = cell_supported(arch, shape_name)
    if not ok:
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
               "status": "skipped", "reason": reason}
        if out_path:
            with open(out_path, "w") as f:
                json.dump(rec, f, indent=1)
        print(json.dumps(rec))
        return rec

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_dev = mesh_num_devices(mesh)
    rules = RULE_SETS[rules_name] if rules_name else None
    cell = build_cell(arch, shape_name, mesh, rules=rules, accum=accum,
                      remat_policy=remat_policy)

    t0 = time.time()
    with jax.set_mesh(mesh):
        jitted = jax.jit(
            cell["fn"],
            in_shardings=cell["in_shardings"],
            donate_argnums=cell["donate_argnums"],
        )
        lowered = jitted.lower(*cell["args"])
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    mem_d = {
        "argument_bytes": int(mem.argument_size_in_bytes),
        "output_bytes": int(mem.output_size_in_bytes),
        "temp_bytes": int(mem.temp_size_in_bytes),
        "alias_bytes": int(mem.alias_size_in_bytes),
        "peak_bytes_per_device": int(
            mem.argument_size_in_bytes + mem.output_size_in_bytes
            + mem.temp_size_in_bytes - mem.alias_size_in_bytes
        ),
    }
    ca = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    # Trip-count-aware analysis (cost_analysis counts while bodies once).
    hs = analyze_hlo_text(hlo)
    ca_fixed = {"flops": hs.flops, "bytes accessed": hs.hbm_bytes}
    colls = {k: int(v) for k, v in hs.collective_bytes.items()}

    from repro.configs.base import get_config
    from repro.launch.roofline import analytic_hbm_bytes
    shape_cfg = SHAPES[shape_name]
    mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape))
    ab = analytic_hbm_bytes(
        get_config(arch), shape_cfg, mesh_shape,
        cell["meta"]["n_active" if shape_cfg.kind != "train" else "n_params"],
        cell["meta"]["rules"],
    )
    rec = summarize_cell(cell["meta"], shape_cfg, n_dev, ca_fixed,
                         mem_d, colls, analytic_bytes=ab)
    rec["xla_cost_analysis_flops_uncorrected"] = float(ca.get("flops", 0.0))
    rec["while_loops"] = hs.while_loops
    rec["dot_count"] = hs.dot_count
    rec.update(
        status="ok", mesh=mesh_kind, mesh_shape=list(mesh.devices.shape),
        t_lower_s=round(t_lower, 2), t_compile_s=round(t_compile, 2),
    )
    print(f"== {arch} x {shape_name} [{mesh_kind}] "
          f"rules={rec['rules']} devices={n_dev}")
    print(f"memory_analysis: {mem}")
    print(f"cost_analysis: flops/dev={rec['hlo_flops_per_device']:.3e} "
          f"bytes/dev={rec['hlo_bytes_per_device']:.3e}")
    print(f"collectives/dev: {colls}")
    print(f"roofline: compute={rec['t_compute_s']:.4f}s "
          f"memory={rec['t_memory_s']:.4f}s coll={rec['t_collective_s']:.4f}s "
          f"-> {rec['bottleneck']}-bound; useful-flops={rec['useful_flops_ratio']:.3f}")
    if print_hlo:
        print(hlo[:20000])
    if out_path:
        os.makedirs(os.path.dirname(out_path) or ".", exist_ok=True)
        with open(out_path, "w") as f:
            json.dump(rec, f, indent=1, default=str)
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True, choices=[
        "train_4k", "prefill_32k", "decode_32k", "long_500k"])
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--rules", default=None)
    ap.add_argument("--out", default=None)
    ap.add_argument("--print-hlo", action="store_true")
    ap.add_argument("--accum", type=int, default=None)
    ap.add_argument("--remat-policy", default=None)
    args = ap.parse_args(argv)
    try:
        rec = run_cell(args.arch, args.shape, args.mesh, args.rules, args.out,
                       args.print_hlo, args.accum, args.remat_policy)
        return 0 if rec.get("status") in ("ok", "skipped") else 1
    except Exception as e:  # record the failure for the sweep collector
        rec = {"arch": args.arch, "shape": args.shape, "mesh": args.mesh,
               "status": "error", "error": f"{type(e).__name__}: {e}"}
        if args.out:
            os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
            with open(args.out, "w") as f:
                json.dump(rec, f, indent=1)
        print(json.dumps(rec)[:2000], file=sys.stderr)
        raise


if __name__ == "__main__":
    sys.exit(main())
