"""Training launcher.

CPU-scale run (reduced config, the end-to-end example driver):

    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-3b --reduced \
        --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

Fault-tolerance demo (injected fault -> checkpoint restart, identical
stream replay):  add --inject-failure-at 30

Full-scale configs lower through the same code path via launch/dryrun.py.
"""

from __future__ import annotations

import argparse
import dataclasses
import json

from repro.configs.base import get_config
from repro.data.pipeline import make_dataset
from repro.optim.adamw import AdamWConfig
from repro.runtime.trainer import Trainer, TrainerConfig


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="tiny same-family config for CPU")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--dataset", default="synthetic",
                    choices=["synthetic", "memmap"])
    ap.add_argument("--data-path", default=None)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--inject-failure-at", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if cfg.family in ("vlm", "encdec"):
        # modality batches come from input_specs; the CLI trains LM families
        cfg = dataclasses.replace(cfg, family="dense", frontend=None,
                                  enc_layers=0)

    ds = make_dataset(args.dataset, vocab=cfg.vocab, batch=args.batch,
                      seq=args.seq, path=args.data_path, seed=args.seed)
    opt = AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 5),
                      total_steps=args.steps)
    tcfg = TrainerConfig(
        steps=args.steps, ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
        accum=args.accum, compress_grads=args.compress_grads,
        inject_failure_at=args.inject_failure_at, seed=args.seed,
    )
    trainer = Trainer(cfg, opt, tcfg, ds)
    result = trainer.run()
    print(f"final loss: {result['final_loss']:.4f} "
          f"restarts: {result['restarts']}")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(result, f, indent=1)
    return result


if __name__ == "__main__":
    main()
