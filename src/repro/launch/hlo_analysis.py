"""Trip-count-aware analysis of optimized (post-SPMD) HLO text.

XLA's ``compiled.cost_analysis()`` counts a ``while`` body **once**, so any
scan-over-layers program under-reports FLOPs/bytes by ~L and hides
loop-carried collectives.  This analyzer walks the computation graph with
multipliers taken from each while's ``known_trip_count`` backend config:

- **flops**: 2 x |output| x |contraction| for every ``dot`` (descending into
  fusion bodies), x enclosing trip counts.
- **hbm_bytes**: sum of operand+output bytes at *fusion granularity* (fusion
  boundary == materialization boundary on TPU), x trip counts.  Control ops
  (tuple/gte/parameter/constant/bitcast) are skipped.
- **collective_bytes**: per-device payload (max of in/out sums) of
  all-reduce / all-gather / reduce-scatter / all-to-all / collective-permute,
  x trip counts, by kind.

All quantities are per-device (the HLO module is the per-partition SPMD
program).  Validated against 6·N·D analytics in tests/test_roofline.py.
"""

from __future__ import annotations

import json
import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "token": 0, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.+?)\s+([a-z][\w\-]*)\((.*)$"
)
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s+\(.*\)\s+->\s+.*\{")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLS_RE = re.compile(r"calls=%?([\w\.\-]+)")
_COND_BODY_RE = re.compile(r"condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")

_SKIP_BYTES_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "iota",
}
_COLLECTIVE_OPS = {
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "all-reduce-start", "all-gather-start",
    "collective-permute-start",
}


def _shapes_of(type_str: str) -> List[Tuple[str, Tuple[int, ...]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        shape = tuple(int(d) for d in dims.split(",")) if dims.strip() else ()
        out.append((dt, shape))
    return out


def _nbytes(shapes) -> int:
    tot = 0
    for dt, shape in shapes:
        n = 1
        for d in shape:
            n *= d
        tot += n * _DTYPE_BYTES[dt]
    return tot


@dataclass
class Instr:
    name: str
    op: str
    out_shapes: list
    operands: List[str]
    rest: str


@dataclass
class Computation:
    name: str
    instrs: List[Instr] = field(default_factory=list)
    by_name: Dict[str, Instr] = field(default_factory=dict)


def _parse(text: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    entry = None
    for raw in text.splitlines():
        line = raw.rstrip()
        hdr = _COMP_HDR_RE.match(line)
        if hdr and line.endswith("{"):
            cur = Computation(hdr.group(1))
            comps[cur.name] = cur
            if raw.startswith("ENTRY"):
                entry = cur.name
            continue
        if line.startswith("}"):
            cur = None
            continue
        if cur is None:
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, type_str, op, rest = m.groups()
        # operand names: up to the metadata section (operands come first)
        arg_end = rest.find("), ")
        arg_str = rest if arg_end < 0 else rest[:arg_end]
        operands = _OPERAND_RE.findall(arg_str)
        ins = Instr(name=name, op=op, out_shapes=_shapes_of(type_str),
                    operands=operands, rest=rest)
        cur.instrs.append(ins)
        cur.by_name[name] = ins
    return comps, entry


@dataclass
class HloStats:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_bytes: Dict[str, float] = field(default_factory=dict)
    dot_count: int = 0
    while_loops: Dict[str, int] = field(default_factory=dict)

    def as_dict(self):
        return {
            "flops": self.flops, "hbm_bytes": self.hbm_bytes,
            "collective_bytes": dict(self.collective_bytes),
            "dot_count": self.dot_count, "while_loops": dict(self.while_loops),
        }


def analyze_hlo_text(text: str) -> HloStats:
    comps, entry = _parse(text)
    if entry is None:
        # fall back: the largest computation is usually main
        entry = max(comps, key=lambda c: len(comps[c].instrs))
    stats = HloStats(collective_bytes=defaultdict(float))

    def operand_bytes(comp: Computation, ins: Instr) -> int:
        tot = 0
        for opn in ins.operands:
            src = comp.by_name.get(opn)
            if src is not None:
                tot += _nbytes(src.out_shapes)
        return tot

    def dot_flops(comp: Computation, ins: Instr) -> float:
        out_elems = 1
        for _, shape in ins.out_shapes[:1]:
            for d in shape:
                out_elems *= d
        m = _CONTRACT_RE.search(ins.rest)
        contract = 1
        if m and ins.operands:
            lhs = comp.by_name.get(ins.operands[0])
            if lhs is not None and lhs.out_shapes:
                lshape = lhs.out_shapes[0][1]
                for idx in (int(i) for i in m.group(1).split(",") if i.strip()):
                    if idx < len(lshape):
                        contract *= lshape[idx]
        return 2.0 * out_elems * contract

    visited_depth = [0]

    def walk(comp_name: str, mult: float, count_bytes: bool):
        if visited_depth[0] > 64 or comp_name not in comps:
            return
        visited_depth[0] += 1
        comp = comps[comp_name]
        for ins in comp.instrs:
            op = ins.op
            if op == "dot":
                stats.flops += mult * dot_flops(comp, ins)
                stats.dot_count += 1
            if op in _COLLECTIVE_OPS:
                kind = op.replace("-start", "")
                payload = max(_nbytes(ins.out_shapes), operand_bytes(comp, ins))
                stats.collective_bytes[kind] += mult * payload
            if op == "while":
                m = _TRIP_RE.search(ins.rest)
                trips = int(m.group(1)) if m else 1
                cb = _COND_BODY_RE.search(ins.rest)
                if cb:
                    stats.while_loops[cb.group(2)] = trips
                    walk(cb.group(2), mult * trips, count_bytes)
                continue
            if op in ("fusion",):
                m = _CALLS_RE.search(ins.rest)
                if m:
                    walk(m.group(1), mult, False)  # flops inside; bytes at boundary
                if count_bytes:
                    stats.hbm_bytes += mult * (
                        _nbytes(ins.out_shapes) + operand_bytes(comp, ins)
                    )
                continue
            if op in ("call", "async-start", "custom-call"):
                m = _CALLS_RE.search(ins.rest)
                if m:
                    walk(m.group(1), mult, count_bytes)
                continue
            if op == "conditional":
                for m in re.finditer(r"(?:true_computation|false_computation|branch_computations=\{)([\w\.\-,%\s]+)", ins.rest):
                    for c in _OPERAND_RE.findall(m.group(1)):
                        walk(c, mult, count_bytes)
                continue
            if count_bytes and op not in _SKIP_BYTES_OPS:
                stats.hbm_bytes += mult * (
                    _nbytes(ins.out_shapes) + operand_bytes(comp, ins)
                )
        visited_depth[0] -= 1

    walk(entry, 1.0, True)
    stats.collective_bytes = dict(stats.collective_bytes)
    return stats
