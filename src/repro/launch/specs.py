"""ShapeDtypeStruct input stand-ins + shardings for every dry-run cell.

``input_specs(arch, shape)`` returns (abstract inputs, input shardings,
step-callable) — weak-type-correct, shardable, zero device allocation.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig, SHAPES, get_config
from repro.models.api import ModelAPI, get_model
from repro.optim.adamw import AdamWConfig, OptState, adamw_init, adamw_update
from repro.sharding.rules import (
    LONG_DECODE_RULES, PREFILL_RULES, SERVE_RULES, TRAIN_RULES, ShardingRules,
    shapes_from_defs, specs_from_defs,
)


def rules_for(shape: ShapeConfig, override: ShardingRules | None = None) -> ShardingRules:
    if override is not None:
        return override
    if shape.kind == "train":
        return TRAIN_RULES
    if shape.kind == "prefill":
        return PREFILL_RULES
    if shape.kind == "long_decode":
        return LONG_DECODE_RULES
    return SERVE_RULES


def _batch_specs(cfg: ModelConfig, shape: ShapeConfig, with_labels: bool):
    B, S = shape.global_batch, shape.seq_len
    tok = lambda s: jax.ShapeDtypeStruct(s, jnp.int32)
    emb = lambda s: jax.ShapeDtypeStruct(s, jnp.dtype(cfg.dtype))
    if cfg.family == "vlm":
        sv = int(S * cfg.frontend_frac)
        st = S - sv
        d = {"patches": emb((B, sv, cfg.d_model)), "tokens": tok((B, st))}
        if with_labels:
            d["labels"] = tok((B, st))
    elif cfg.family == "encdec":
        ss = S // 2
        d = {"src_embeds": emb((B, ss, cfg.d_model)), "tokens": tok((B, S - ss))}
        if with_labels:
            d["labels"] = tok((B, S - ss))
    else:
        d = {"tokens": tok((B, S))}
        if with_labels:
            d["labels"] = tok((B, S))
    return d


def _batch_shardings(batch, rules: ShardingRules, mesh: Mesh):
    def spec(name, v):
        if v.ndim == 3:
            return NamedSharding(mesh, rules.pspec(("batch", None, None), mesh))
        if v.ndim == 2:
            return NamedSharding(mesh, rules.pspec(("batch", None), mesh))
        return NamedSharding(mesh, rules.pspec(("batch",), mesh))
    return {k: spec(k, v) for k, v in batch.items()}


# Default microbatch counts for the full-scale train_4k cells: chosen so the
# per-microbatch activation footprint fits v5e HBM (16 GiB/chip).  Visible
# cost: weights are re-gathered per microbatch under FSDP (collective term).
TRAIN_ACCUM = {
    # tuned per cell in EXPERIMENTS.md SSPerf: minimum accum that fits 16GiB
    # (fewer microbatches => fewer FSDP weight re-gathers), except llama4
    # where the MoE gather pattern inverts the trend (measured).
    "mixtral-8x7b": 8, "llama4-scout-17b-a16e": 16, "qwen2-vl-72b": 4,
    "zamba2-2.7b": 2, "rwkv6-7b": 4, "mistral-nemo-12b": 2,
    "llama3.2-3b": 1, "stablelm-3b": 1, "h2o-danube-1.8b": 1,
    "seamless-m4t-medium": 1,
}


def make_train_step(model: ModelAPI, opt_cfg: AdamWConfig, rules, mesh,
                    accum: int = 1):
    cfg = model.cfg

    def train_step(params, opt_state, batch):
        def lf(p, b):
            # Cast the f32 master params to bf16 *inside* the grad scope so
            # every FSDP all-gather moves bf16 (XLA otherwise hoists the
            # gather above the cast and ships f32: 2x collective bytes).
            # Grad of the cast converts cotangents back to f32 at the
            # parameter boundary (bf16 gradient reduction).
            pc = jax.tree.map(
                lambda x: x.astype(jnp.bfloat16)
                if x.dtype == jnp.float32 else x, p)
            return model.loss_fn(pc, b, rules=rules, mesh=mesh)

        if accum > 1:
            def micro(carry, mb):
                gsum, ce = carry
                (_, m), g = jax.value_and_grad(lf, has_aux=True)(params, mb)
                return (jax.tree.map(jnp.add, gsum, g), ce + m["ce"]), None

            mbs = jax.tree.map(
                lambda x: x.reshape((accum, x.shape[0] // accum) + x.shape[1:]),
                batch)
            zeros = jax.tree.map(jnp.zeros_like, params)
            (gsum, ce), _ = jax.lax.scan(micro, (zeros, jnp.float32(0)), mbs)
            grads = jax.tree.map(lambda g: g / accum, gsum)
            loss = ce / accum
            metrics = {"ce": loss, "aux": jnp.float32(0)}
        else:
            (loss, metrics), grads = jax.value_and_grad(
                lf, has_aux=True)(params, batch)
        params, opt_state, om = adamw_update(grads, opt_state, params, opt_cfg)
        return params, opt_state, {"loss": loss, **metrics, **om}

    return train_step


def make_prefill_step(model: ModelAPI, rules, mesh):
    def prefill_step(params, batch):
        return model.prefill(params, batch, rules=rules, mesh=mesh)
    return prefill_step


def make_decode_step(model: ModelAPI, rules, mesh):
    def serve_step(params, tokens, pos, cache):
        return model.decode_step(params, tokens, pos, cache, rules=rules, mesh=mesh)
    return serve_step


def build_cell(
    arch: str, shape_name: str, mesh: Mesh,
    *, rules: ShardingRules | None = None,
    opt_cfg: AdamWConfig | None = None,
    accum: int | None = None,
    remat_policy: str | None = None,
):
    """Everything needed to lower one (arch x shape) cell on ``mesh``.

    Returns dict with: fn, args (ShapeDtypeStructs), in_shardings,
    out_shardings(None => infer), donate, meta.
    """
    import dataclasses as _dc

    cfg_true = get_config(arch)
    # Pad head/vocab computation dims to the model-axis size so GSPMD never
    # resolves uneven shardings with global gathers (DESIGN.md §6).
    cfg = _dc.replace(cfg_true, shard_pad=int(mesh.shape.get("model", 1)),
                      **({"remat_policy": remat_policy} if remat_policy else {}))
    shape = SHAPES[shape_name]
    rules = rules_for(shape, rules)
    model = get_model(cfg)
    model_true = get_model(cfg_true)
    pspecs = model.param_specs(rules, mesh)
    pshapes = model.param_shapes()
    meta = {
        "arch": arch, "shape": shape_name, "rules": rules.name,
        "n_params": model_true.n_params(), "n_active": model_true.n_active_params(),
        "n_params_padded": model.n_params(),
        "family": cfg.family,
    }

    if shape.kind == "train":
        opt_cfg = opt_cfg or AdamWConfig()
        accum = accum if accum is not None else TRAIN_ACCUM.get(arch, 1)
        meta["accum"] = accum
        meta["remat_policy"] = cfg.remat_policy
        fn = make_train_step(model, opt_cfg, rules, mesh, accum=accum)
        batch = _batch_specs(cfg, shape, with_labels=True)
        opt_shapes = OptState(
            m=pshapes, v=pshapes, step=jax.ShapeDtypeStruct((), jnp.int32)
        )
        opt_specs = OptState(
            m=pspecs, v=pspecs,
            step=NamedSharding(mesh, P()),
        )
        return dict(
            fn=fn,
            args=(pshapes, opt_shapes, batch),
            in_shardings=(pspecs, opt_specs, _batch_shardings(batch, rules, mesh)),
            donate_argnums=(0, 1),
            meta=meta,
        )

    if shape.kind == "prefill":
        fn = make_prefill_step(model, rules, mesh)
        batch = _batch_specs(cfg, shape, with_labels=False)
        return dict(
            fn=fn,
            args=(pshapes, batch),
            in_shardings=(pspecs, _batch_shardings(batch, rules, mesh)),
            donate_argnums=(),
            meta=meta,
        )

    # decode / long_decode: serve_step with a full KV/state cache.
    # Serving weights are bf16 (stationary shards; halves weight memory+reads).
    pshapes = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(
            s.shape, jnp.bfloat16 if s.dtype == jnp.float32 else s.dtype),
        pshapes)
    B, S = shape.global_batch, shape.seq_len
    cdefs = model.cache_defs_fn(B, S)
    cache_shapes = shapes_from_defs(cdefs)
    cache_specs = specs_from_defs(cdefs, rules, mesh)
    fn = make_decode_step(model, rules, mesh)
    tok = jax.ShapeDtypeStruct((B,), jnp.int32)
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    return dict(
        fn=fn,
        args=(pshapes, tok, pos, cache_shapes),
        in_shardings=(
            pspecs,
            NamedSharding(mesh, rules.pspec(("batch",), mesh)),
            NamedSharding(mesh, P()),
            cache_specs,
        ),
        donate_argnums=(3,),
        meta=meta,
    )
