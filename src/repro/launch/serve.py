"""Serving launcher: batched prefill + decode loop on CPU-scale configs.

    PYTHONPATH=src python -m repro.launch.serve --arch mixtral-8x7b --reduced \
        --batch 4 --prompt-len 32 --gen 16

Demonstrates the full request path: batch prompts -> prefill (cache build)
-> greedy decode loop with ring-buffer SWA caches where configured.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.models.api import get_model
from repro.sharding.rules import shapes_from_defs


def serve_batch(cfg, batch: int, prompt_len: int, gen: int, seed: int = 0):
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    rng = np.random.default_rng(seed)
    prompts = jnp.asarray(
        rng.integers(1, cfg.vocab - 1, (batch, prompt_len)), jnp.int32)

    total_len = prompt_len + gen
    cdefs = model.cache_defs_fn(batch, total_len)
    cache = jax.tree.map(
        lambda d: jnp.zeros(d.shape, d.dtype), cdefs,
        is_leaf=lambda x: hasattr(x, "axes") and hasattr(x, "init"),
    )
    decode = jax.jit(model.decode_step, donate_argnums=(3,))

    # prefill via decode steps (works for every family incl. recurrent)
    tok = prompts[:, 0]
    t0 = time.time()
    out_tokens = [tok]
    for pos in range(total_len - 1):
        logits, cache = decode(params, tok, jnp.int32(pos), cache)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        tok = jnp.where(pos + 1 < prompt_len, prompts[:, pos + 1], nxt)
        out_tokens.append(tok)
    seqs = jnp.stack(out_tokens, axis=1)
    dt = time.time() - t0
    toks = batch * (total_len - 1)
    return seqs, {"tokens": toks, "seconds": dt, "tok_per_s": toks / dt}


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args(argv)
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    seqs, stats = serve_batch(cfg, args.batch, args.prompt_len, args.gen)
    print(f"generated {seqs.shape} tokens: {stats['tok_per_s']:.1f} tok/s "
          f"({stats['seconds']:.2f}s)")


if __name__ == "__main__":
    main()
