"""Production mesh construction (assignment-mandated shapes).

Defined as functions — importing this module never touches jax device
state, so library users on 1-device hosts are unaffected.
"""

from __future__ import annotations

import jax

# jax.sharding.AxisType landed after 0.4.x; on older jax every mesh axis is
# implicitly Auto, so omitting axis_types is the exact equivalent.
_AXIS_TYPE = getattr(jax.sharding, "AxisType", None)


def _mesh_kwargs(n_axes: int) -> dict:
    if _AXIS_TYPE is None:
        return {}
    return {"axis_types": (_AXIS_TYPE.Auto,) * n_axes}


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_mesh_kwargs(len(axes)))


def make_local_mesh(axes=("data", "model")):
    """All local devices on the first axis (CPU tests / examples)."""
    n = len(jax.devices())
    shape = (n,) + (1,) * (len(axes) - 1)
    return jax.make_mesh(shape, axes, **_mesh_kwargs(len(axes)))


def mesh_num_devices(mesh) -> int:
    n = 1
    for s in mesh.devices.shape:
        n *= s
    return n
