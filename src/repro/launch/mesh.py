"""Production mesh construction (assignment-mandated shapes).

Defined as functions — importing this module never touches jax device
state, so library users on 1-device hosts are unaffected.
"""

from __future__ import annotations

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_local_mesh(axes=("data", "model")):
    """All local devices on the first axis (CPU tests / examples)."""
    n = len(jax.devices())
    shape = (n,) + (1,) * (len(axes) - 1)
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def mesh_num_devices(mesh) -> int:
    n = 1
    for s in mesh.devices.shape:
        n *= s
    return n
