"""Roofline-term extraction from compiled dry-run artifacts (DESIGN.md §10).

TPU v5e hardware model (per chip):
    peak bf16:  197 TFLOP/s
    HBM bw:     819 GB/s
    ICI link:   ~50 GB/s per link

``cost_analysis()`` reports the per-device (post-SPMD) module's FLOPs and
bytes.  Collective bytes are parsed from the optimized HLO text: we sum the
output shard sizes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute instruction (per-device payload).
"""

from __future__ import annotations

import math
import re
from collections import defaultdict
from typing import Dict

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.  %foo = f32[16,128]{1,0} all-gather(...)
_INSTR_RE = re.compile(
    r"=\s*(?:\()?([a-z0-9]+)\[([0-9,]*)\][^a-z]*\s*"
    r"(all-reduce-start|all-reduce|all-gather-start|all-gather|"
    r"reduce-scatter|all-to-all|collective-permute-start|collective-permute)\b"
)
# tuple-result collectives:  = (f32[..], f32[..]) all-reduce(
_TUPLE_RE = re.compile(
    r"=\s*\(([^)]*)\)\s*"
    r"(all-reduce-start|all-reduce|all-gather-start|all-gather|"
    r"reduce-scatter|all-to-all|collective-permute-start|collective-permute)\b"
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims.strip():
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-device collective payload bytes by op kind."""
    out: Dict[str, int] = defaultdict(int)
    for line in hlo_text.splitlines():
        if not any(c in line for c in _COLLECTIVES):
            continue
        m = _TUPLE_RE.search(line)
        if m:
            kind = m.group(2).replace("-start", "")
            for dt, dims in _SHAPE_RE.findall(m.group(1)):
                out[kind] += _shape_bytes(dt, dims)
            continue
        m = _INSTR_RE.search(line)
        if m:
            kind = m.group(3).replace("-start", "")
            out[kind] += _shape_bytes(m.group(1), m.group(2))
    return dict(out)


def roofline_terms(
    *, flops_per_device: float, bytes_per_device: float,
    coll_bytes_per_device: float,
) -> Dict[str, float]:
    """Three roofline times in seconds (per step, per device)."""
    t_compute = flops_per_device / PEAK_FLOPS
    t_memory = bytes_per_device / HBM_BW
    t_coll = coll_bytes_per_device / ICI_BW
    dom = max(
        ("compute", t_compute), ("memory", t_memory), ("collective", t_coll),
        key=lambda kv: kv[1],
    )[0]
    total = max(t_compute, t_memory, t_coll)
    return {
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "bottleneck": dom,
        "bound_step_s": total,
    }


def model_flops(n_params_active: int, tokens: int, kind: str) -> float:
    """6·N·D convention (training); 2·N·D for inference-only cells."""
    mult = 6.0 if kind == "train" else 2.0
    return mult * n_params_active * tokens


def analytic_hbm_bytes(cfg, shape, mesh_shape: dict, n_params: int,
                       rules_name: str) -> float:
    """Structural per-device HBM traffic model (bytes/step).

    The CPU-lowered HLO materializes attention scores and masks that stay in
    VMEM on a real TPU (flash kernel), so HLO byte counts are a gross upper
    bound.  This model counts the traffic that *must* cross HBM on TPU:

      train:   gathered bf16 weights (w+r x 3 passes: fwd, remat, bwd),
               f32 master params + Adam moments (r+w), f32 grads (r+w),
               layer-boundary activations (~8 tensors/layer/pass),
               logits (fwd+bwd).
      prefill: 1 pass of the above, last-position logits only.
      decode:  weight shards (gathered over data under FSDP serving rules;
               stationary under serve-2d rules), full KV/state cache read,
               single-token writes.

    Every term is per device; mesh_shape = {"model": m, "data": d, "pod": p}.
    """
    m = mesh_shape.get("model", 1)
    dp = mesh_shape.get("data", 1) * mesh_shape.get("pod", 1)
    B, S = shape.global_batch, shape.seq_len
    B_loc = max(B / dp, 1.0)
    L, D, V = cfg.n_layers, cfg.d_model, cfg.vocab
    P = float(n_params)
    bf2, f4 = 2.0, 4.0

    def weights_pass(n_passes, gathered_over_data: bool):
        shard = P * bf2 / m if gathered_over_data else P * bf2 / (m * dp)
        return 2.0 * shard * n_passes  # write after gather + read by matmul

    if shape.kind == "train":
        w = weights_pass(3, gathered_over_data=True)
        opt = (6 + 2) * P * f4 / (m * dp)          # p,m,v r+w  + grads r+w
        act = 8 * L * 3 * B_loc * S * D * bf2 / m
        logits = 2 * 2 * B * S * V * bf2 / (dp * m)
        return w + opt + act + logits
    if shape.kind == "prefill":
        w = weights_pass(1, gathered_over_data=True)
        act = 8 * L * B_loc * S * D * bf2 / m
        logits = 2 * B * V * bf2 / (dp * m)
        return w + act + logits
    # decode / long_decode
    gathered = rules_name != "serve_2d_stationary"
    w = weights_pass(1, gathered_over_data=gathered)
    KV, hd = getattr(cfg, "kv_heads_c", cfg.n_kv_heads), cfg.head_dim
    cache_len = min(S, cfg.window) if cfg.window else S
    if cfg.family == "rwkv":
        Hh = D // 64
        cache_total = L * B * (Hh * 64 * 64 * f4 + 2 * D * bf2)
    elif cfg.family == "hybrid":
        G = L // max(cfg.attn_every, 1)
        cache_total = (G * B * cache_len * KV * hd * 2 * bf2
                       + L * B * cfg.ssm_heads * cfg.ssm_head_dim
                       * cfg.ssm_state * bf2)
    elif cfg.family == "encdec":
        cache_total = L * B * (cache_len + cache_len // 2) * KV * hd * 2 * bf2
    else:
        cache_total = L * B * cache_len * KV * hd * 2 * bf2
    cache = cache_total / (dp * m)
    logits = 2 * B * V * bf2 / (dp * m)
    return w + cache + logits


def summarize_cell(meta, shape, n_devices: int, ca: dict, mem: dict,
                   colls: Dict[str, int], analytic_bytes: float | None = None) -> dict:
    flops_dev = float(ca.get("flops", 0.0))
    hlo_bytes_dev = float(ca.get("bytes accessed", 0.0))
    bytes_dev = analytic_bytes if analytic_bytes is not None else hlo_bytes_dev
    coll_dev = float(sum(colls.values()))
    terms = roofline_terms(
        flops_per_device=flops_dev, bytes_per_device=bytes_dev,
        coll_bytes_per_device=coll_dev,
    )
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
    else:
        tokens = shape.global_batch  # one new token per sequence
    mf = model_flops(meta["n_active"], tokens, shape.kind)
    useful = mf / max(flops_dev * n_devices, 1.0)
    mfu_bound = mf / (n_devices * PEAK_FLOPS) / max(terms["bound_step_s"], 1e-30)
    return {
        **meta,
        "n_devices": n_devices,
        "hlo_flops_per_device": flops_dev,
        "hlo_bytes_per_device": hlo_bytes_dev,
        "analytic_hbm_bytes_per_device": bytes_dev,
        "collective_bytes_per_device": coll_dev,
        "collectives": colls,
        "model_flops_total": mf,
        "useful_flops_ratio": useful,
        "roofline_mfu_bound": mfu_bound,
        **terms,
        "memory": mem,
    }
