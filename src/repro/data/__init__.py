from repro.data.pipeline import (  # noqa: F401
    DataState, MemmapTokens, SyntheticTokens, make_dataset,
)
