"""Deterministic, checkpointable data pipeline.

Fault-tolerance contract: a batch is a pure function of (seed, step, shard),
so restoring ``DataState.step`` after a failure replays the exact stream —
no data loss or duplication across restarts (tested in test_runtime.py).

Two sources:
- ``SyntheticTokens``: Philox-keyed synthetic LM tokens (offline container).
- ``MemmapTokens``: packed binary token file (np.memmap), sharded striding.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import numpy as np


@dataclasses.dataclass
class DataState:
    seed: int
    step: int

    def to_dict(self):
        return {"seed": self.seed, "step": self.step}

    @classmethod
    def from_dict(cls, d):
        return cls(seed=int(d["seed"]), step=int(d["step"]))


class SyntheticTokens:
    """Zipf-ish synthetic token stream; batch = f(seed, step, shard)."""

    def __init__(self, vocab: int, batch: int, seq: int, *, seed: int = 0,
                 shard: int = 0, num_shards: int = 1):
        self.vocab, self.batch, self.seq = vocab, batch, seq
        self.state = DataState(seed=seed, step=0)
        self.shard, self.num_shards = shard, num_shards

    def batch_at(self, step: int):
        rng = np.random.default_rng(
            np.random.Philox(key=[(self.state.seed << 16) ^ self.shard, step])
        )
        # heavy-tailed unigram stream with short-range repetition structure
        base = rng.zipf(1.3, size=(self.batch, self.seq + 1))
        tokens = (base % (self.vocab - 2)) + 1
        rep = rng.random((self.batch, self.seq + 1)) < 0.2
        tokens = np.where(rep, np.roll(tokens, 1, axis=1), tokens)
        return {
            "tokens": tokens[:, :-1].astype(np.int32),
            "labels": tokens[:, 1:].astype(np.int32),
        }

    def __next__(self):
        b = self.batch_at(self.state.step)
        self.state.step += 1
        return b


class MemmapTokens:
    """Packed int32 token file; deterministic strided sampling per step."""

    def __init__(self, path: str, batch: int, seq: int, *, seed: int = 0,
                 shard: int = 0, num_shards: int = 1):
        self.tokens = np.memmap(path, dtype=np.int32, mode="r")
        self.batch, self.seq = batch, seq
        self.state = DataState(seed=seed, step=0)
        self.shard, self.num_shards = shard, num_shards
        self.n_windows = max((len(self.tokens) - 1) // seq, 1)

    def batch_at(self, step: int):
        rng = np.random.default_rng(
            np.random.Philox(key=[(self.state.seed << 16) ^ self.shard ^ (1 << 30), step])
        )
        idx = rng.integers(0, self.n_windows, self.batch)
        starts = idx * self.seq
        tok = np.stack([self.tokens[s:s + self.seq + 1] for s in starts])
        return {"tokens": tok[:, :-1].astype(np.int32),
                "labels": tok[:, 1:].astype(np.int32)}

    def __next__(self):
        b = self.batch_at(self.state.step)
        self.state.step += 1
        return b


def make_dataset(kind: str, *, vocab: int, batch: int, seq: int,
                 path: Optional[str] = None, seed: int = 0):
    if kind == "synthetic":
        return SyntheticTokens(vocab, batch, seq, seed=seed)
    if kind == "memmap":
        if not path:
            raise ValueError("memmap dataset needs --data-path")
        return MemmapTokens(path, batch, seq, seed=seed)
    raise ValueError(f"unknown dataset kind {kind!r}")
