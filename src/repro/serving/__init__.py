"""Online serving subsystem (DESIGN.md §16).

Open-arrival service traffic as a first-class scenario family: a frozen
:class:`ServiceTrace` materializes deterministic per-class request
streams with per-request SLO deadlines, and a queue-pressure
:class:`AutoscalePolicy` drives a deterministic capacity event stream
both engines consume bit-identically.  ``service=None`` statically
elides the whole subsystem — the serving-free engine compiles to the
exact pre-serving event graph (property-tested via HLO fingerprints).
"""

from repro.serving.model import (
    AutoscalePolicy, ServiceClass, ServicePlan, ServiceTrace, make_svc_ctx,
)

__all__ = [
    "AutoscalePolicy", "ServiceClass", "ServicePlan", "ServiceTrace",
    "make_svc_ctx",
]
