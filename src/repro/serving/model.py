"""Online serving traffic as a first-class scenario family (DESIGN.md §16).

A :class:`ServiceTrace` is a frozen host-side spec of an *open* arrival
process over a bounded horizon: requests arrive Poisson (or via an explicit
trace-driven arrival list), each drawn from a per-class mix — a
:class:`ServiceClass` fixes the node footprint, the runtime distribution
and the class's SLO wait target — and ``materialize()`` lowers the spec to
deterministic, padded job arrays exactly like ``FailureModel`` does for
failure streams.  Arrival rate, class mix, runtimes, deadlines and every
autoscaler threshold are trace *data*: a rate sweep (or an SLO sweep, or
autoscale on/off) batches through ``vmap`` into ONE executable; the only
static axes are the padded job capacity ``max_jobs`` and the autoscaler's
padded tick capacity ``max_ticks``.

The queue-pressure autoscaler (:class:`AutoscalePolicy`) is a deterministic
capacity event stream: ticks at ``k * interval`` re-evaluate queued node
demand against hysteresis thresholds and move nodes in or out of service,
riding the same node-masking machinery reliability outages use (an offline
node is painted with an out-of-range owner id; scale-down only ever takes
*free* nodes, so a running job is never stranded).  Both engines consume
the identical materialized plan through :func:`make_svc_ctx`, and
``service=None`` statically elides the whole subsystem.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import numpy as np

# The int32 "infinite time" sentinel, == repro.core.jobs.INF_TIME (imported
# late to keep this module import-light; asserted equal at materialization).
INF_TIME = np.int32(2**30 - 1)

_DISTRIBUTIONS = ("fixed", "exponential")


@dataclasses.dataclass(frozen=True)
class ServiceClass:
    """One request class of an open-arrival mix.

    ``nodes`` is the per-request node footprint, ``mean_runtime`` the mean
    service duration under ``dist`` (``"fixed"`` — every request runs
    exactly ``mean_runtime`` — or ``"exponential"``), ``slo_wait`` the
    class's SLO: a request *meets* its SLO iff it starts within
    ``slo_wait`` seconds of arriving (the verdict is fixed at start time).
    ``weight`` is the class's share of the arrival mix.
    """

    name: str
    nodes: int = 1
    mean_runtime: int = 60
    dist: str = "fixed"
    slo_wait: int = 60
    weight: float = 1.0

    def __post_init__(self):
        if self.nodes < 1:
            raise ValueError(f"class {self.name!r}: nodes must be >= 1")
        if self.mean_runtime < 1:
            raise ValueError(f"class {self.name!r}: mean_runtime must be >= 1")
        if self.dist not in _DISTRIBUTIONS:
            raise ValueError(
                f"class {self.name!r}: unknown dist {self.dist!r}; "
                f"known: {_DISTRIBUTIONS}")
        if self.slo_wait < 0:
            raise ValueError(f"class {self.name!r}: slo_wait must be >= 0")
        if not self.weight > 0:
            raise ValueError(f"class {self.name!r}: weight must be positive")


@dataclasses.dataclass(frozen=True)
class AutoscalePolicy:
    """Queue-pressure hysteresis autoscaler (DESIGN.md §16).

    Every ``interval`` seconds (up to ``max_ticks`` ticks — the padded
    static capacity) the scaler reads the queued node demand (sum of node
    requests over WAITING jobs) and:

    - demand >= ``up_threshold``: bring up to ``step`` nodes back online
      (never beyond ``max_nodes``, which is capped at the machine size);
    - demand <= ``down_threshold``: take up to ``step`` *free* nodes
      offline (never below ``min_nodes``, and never a busy node — a
      running job is never stranded; drain happens by simply not
      re-adding capacity);
    - otherwise hold (hysteresis band).

    ``enabled=False`` keeps the padded tick shape but materializes every
    tick at ``INF_TIME`` — autoscale on/off points share one compiled
    executable.  ``max_nodes=None`` means the machine size.
    """

    up_threshold: int
    down_threshold: int
    min_nodes: int = 1
    max_nodes: Optional[int] = None
    step: int = 1
    interval: int = 60
    max_ticks: int = 256
    enabled: bool = True

    def __post_init__(self):
        if self.down_threshold < 0 or self.up_threshold <= self.down_threshold:
            raise ValueError(
                "hysteresis requires 0 <= down_threshold < up_threshold, "
                f"got down={self.down_threshold} up={self.up_threshold}")
        if self.min_nodes < 1:
            raise ValueError("min_nodes must be >= 1")
        if self.max_nodes is not None and self.max_nodes < self.min_nodes:
            raise ValueError("max_nodes must be >= min_nodes")
        if self.step < 1:
            raise ValueError("step must be >= 1")
        if self.interval < 1:
            raise ValueError("interval must be >= 1")
        if self.max_ticks < 0:
            raise ValueError("max_ticks must be >= 0")

    def static_key(self) -> tuple:
        """Only the padded tick capacity changes compiled shapes."""
        return ("autoscale", self.max_ticks)


@dataclasses.dataclass(frozen=True, eq=False)
class ServicePlan:
    """Materialized serving plan (host arrays; both engines consume this).

    ``submit``/``runtime``/``nodes``/``estimate`` are the *unpadded*
    request arrays in arrival order (submit already 0-based and
    non-decreasing, so ``make_jobset``'s (submit, id) sort is the identity
    permutation and the padded ``deadline``/``class_id`` columns stay
    row-aligned with the job table).  ``deadline[j] = submit[j] +
    slo_wait[class]``, ``INF_TIME`` in the padding slots.  ``tick_time``
    is the padded autoscaler tick stream (all ``INF_TIME`` when the
    scaler is disabled; shape ``[0]`` when the spec carries none).
    """

    submit: np.ndarray       # i32[n] arrival times, sorted, 0-based
    runtime: np.ndarray      # i32[n]
    nodes: np.ndarray        # i32[n]
    estimate: np.ndarray     # i32[n]
    deadline: np.ndarray     # i32[max_jobs], INF_TIME = padding
    class_id: np.ndarray     # i32[max_jobs], -1 = padding
    class_names: Tuple[str, ...]
    tick_time: np.ndarray    # i32[T], INF_TIME = padding/disabled
    up_threshold: int
    down_threshold: int
    step: int
    min_nodes: int
    max_nodes: Optional[int]  # None = machine size
    interval: int
    n_requests: int          # real (unpadded) request count
    truncated: bool = False  # arrival process generated > max_jobs requests

    @property
    def capacity(self) -> int:
        return int(self.deadline.shape[-1])

    def trace(self) -> Dict[str, np.ndarray]:
        return {"submit": self.submit, "runtime": self.runtime,
                "nodes": self.nodes, "estimate": self.estimate}


@dataclasses.dataclass(frozen=True)
class ServiceTrace:
    """Frozen open-arrival serving spec for a :class:`repro.api.Scenario`.

    Poisson arrivals at ``rate`` requests/second over ``[0, horizon)``
    (or the explicit ``arrivals`` tuple of ``(time, class_index)`` pairs),
    classes drawn from the ``classes`` mix by weight.  ``max_jobs`` is the
    padded job capacity — requests past the horizon simply don't exist,
    and a draw that produces more than ``max_jobs`` requests truncates
    (loudly) to the earliest ones, so every rate point of a sweep shares
    one compiled shape.  ``autoscale`` attaches the queue-pressure
    capacity stream (``None`` elides it to a zero-length tick array).

    Everything except ``max_jobs`` and ``autoscale.max_ticks`` is vmap
    *data*: rate / mix / SLO / seed / threshold sweeps compile once.
    """

    horizon: int
    rate: float = 0.1
    seed: int = 0
    classes: Tuple[ServiceClass, ...] = (ServiceClass("default"),)
    max_jobs: int = 1024
    arrivals: Optional[Tuple[Tuple[int, int], ...]] = None
    autoscale: Optional[AutoscalePolicy] = None

    def __post_init__(self):
        if not 0 < self.horizon < int(INF_TIME) // 2:
            raise ValueError(
                f"horizon must be in (0, {int(INF_TIME) // 2}) so arrival "
                "and deadline timestamps stay clear of the int32 sentinel")
        if self.arrivals is None and not self.rate > 0:
            raise ValueError(f"rate must be positive, got {self.rate}")
        if not self.classes:
            raise ValueError("at least one ServiceClass is required")
        if self.max_jobs < 1:
            raise ValueError("max_jobs must be >= 1")
        if self.arrivals is not None:
            times = [t for t, _ in self.arrivals]
            if any(t2 < t1 for t1, t2 in zip(times, times[1:])):
                raise ValueError("trace-driven arrivals must be sorted by time")
            if times and (times[0] < 0 or times[-1] >= self.horizon):
                raise ValueError("trace-driven arrival times must lie in "
                                 f"[0, {self.horizon})")
            for _, c in self.arrivals:
                if not 0 <= c < len(self.classes):
                    raise ValueError(f"arrival class index {c} out of range")
        if self.autoscale is not None and self.autoscale.enabled:
            biggest = max(c.nodes for c in self.classes)
            if biggest > self.autoscale.min_nodes:
                raise ValueError(
                    f"autoscale.min_nodes={self.autoscale.min_nodes} is "
                    f"smaller than the largest class footprint ({biggest} "
                    "nodes); a scaled-down cluster could never start such "
                    "a request (deadlock)")

    def static_key(self) -> tuple:
        """Compile-bucket contribution: the padded job capacity and the
        padded tick capacity are the only static shapes — rate / mix /
        SLO / seed / thresholds are vmap data (``repro.api.sweep``)."""
        return ("service", self.max_jobs,
                None if self.autoscale is None
                else self.autoscale.static_key())

    @property
    def pad_capacity(self) -> int:
        """Padded job-table capacity (``repro.api.build_jobset`` pads every
        rate point to this one shape)."""
        return self.max_jobs

    @property
    def n_rows(self) -> int:
        return self.plan().n_requests

    def plan(self) -> ServicePlan:
        """The deterministic materialized plan (lru-cached per spec)."""
        return _materialize(self)

    def materialize(self) -> Dict[str, np.ndarray]:
        """Trace-spec interface: the job arrays for ``make_jobset``."""
        return self.plan().trace()


@functools.lru_cache(maxsize=256)
def _materialize(spec: ServiceTrace) -> ServicePlan:
    from repro.core.jobs import INF_TIME as _engine_inf

    assert INF_TIME == _engine_inf, "sentinel drifted from repro.core.jobs"
    rng = np.random.default_rng(spec.seed)
    n_classes = len(spec.classes)

    if spec.arrivals is not None:
        times = np.asarray([t for t, _ in spec.arrivals], dtype=np.int64)
        cls = np.asarray([c for _, c in spec.arrivals], dtype=np.int64)
    else:
        # Poisson process: exponential gaps accumulated in float, floored to
        # integer seconds (simultaneous arrivals are legal ties); generation
        # stops at the horizon or at a loud truncation cap
        times_l = []
        t = 0.0
        limit = 4 * spec.max_jobs + 16
        while len(times_l) < limit:
            t += rng.exponential(1.0 / spec.rate)
            if t >= spec.horizon:
                break
            times_l.append(int(t))
        times = np.asarray(times_l, dtype=np.int64)
        w = np.asarray([c.weight for c in spec.classes], dtype=np.float64)
        cls = rng.choice(n_classes, size=len(times), p=w / w.sum())

    truncated = len(times) > spec.max_jobs
    if truncated:
        import warnings

        warnings.warn(
            f"ServiceTrace(rate={spec.rate}, horizon={spec.horizon}) "
            f"generated {len(times)} requests but max_jobs={spec.max_jobs}; "
            f"keeping only the earliest {spec.max_jobs} — raise max_jobs "
            "(or lower rate/horizon) unless early-window truncation is "
            "intended", stacklevel=3)
        times, cls = times[:spec.max_jobs], cls[:spec.max_jobs]

    n = len(times)
    times = times - (times.min() if n else 0)   # make_jobset's shift a no-op
    c_nodes = np.asarray([c.nodes for c in spec.classes], dtype=np.int64)
    c_mean = np.asarray([c.mean_runtime for c in spec.classes], dtype=np.int64)
    c_slo = np.asarray([c.slo_wait for c in spec.classes], dtype=np.int64)
    fixed = np.asarray([c.dist == "fixed" for c in spec.classes], dtype=bool)
    # one rng draw per request regardless of dist, so the class mix never
    # perturbs the arrival stream of other requests
    u = rng.random(n)
    drawn = np.ceil(-c_mean[cls] * np.log1p(-u)).astype(np.int64)
    runtime = np.where(fixed[cls], c_mean[cls], np.maximum(drawn, 1))
    nodes = c_nodes[cls]
    estimate = np.maximum(c_mean[cls], runtime)   # walltime request >= actual

    top = int(times.max(initial=0)) + 2 * int(estimate.max(initial=1)) \
        + int(c_slo.max(initial=0))
    if top >= int(INF_TIME):
        raise ValueError(
            f"ServiceTrace horizon overflows the int32 clock: max arrival "
            f"{int(times.max(initial=0))} + runtimes/SLOs reaches {top} >= "
            f"{int(INF_TIME)}; rescale horizon or mean_runtime")

    J = spec.max_jobs
    deadline = np.full((J,), INF_TIME, dtype=np.int32)
    class_id = np.full((J,), -1, dtype=np.int32)
    deadline[:n] = (times + c_slo[cls]).astype(np.int32)
    class_id[:n] = cls.astype(np.int32)

    auto = spec.autoscale
    if auto is None:
        tick_time = np.zeros((0,), dtype=np.int32)
        up_t, down_t, step, min_n, max_n, interval = 0, 0, 1, 1, None, 1
    else:
        T = auto.max_ticks
        tick_time = np.full((T,), INF_TIME, dtype=np.int32)
        if auto.enabled:
            ticks = (np.arange(1, T + 1, dtype=np.int64) * auto.interval)
            ticks = np.minimum(ticks, int(INF_TIME))
            tick_time[:] = ticks.astype(np.int32)
        up_t, down_t = auto.up_threshold, auto.down_threshold
        step, min_n = auto.step, auto.min_nodes
        max_n, interval = auto.max_nodes, auto.interval

    return ServicePlan(
        submit=times.astype(np.int32), runtime=runtime.astype(np.int32),
        nodes=nodes.astype(np.int32), estimate=estimate.astype(np.int32),
        deadline=deadline, class_id=class_id,
        class_names=tuple(c.name for c in spec.classes),
        tick_time=tick_time, up_threshold=int(up_t),
        down_threshold=int(down_t), step=int(step), min_nodes=int(min_n),
        max_nodes=None if max_n is None else int(max_n),
        interval=int(interval), n_requests=n, truncated=truncated,
    )


def make_svc_ctx(service, *, n_nodes: Optional[int] = None):
    """Canonicalize a ``service`` argument into the engine's SvcCtx.

    Accepts ``None`` (statically elided — the engine compiles the exact
    pre-serving graph), a :class:`ServicePlan`, or an already-built ctx
    tuple (the ``vmap`` sweep path — leaves may be tracers).  The ctx is
    the 7-tuple ``(deadline, tick_time, up_threshold, down_threshold,
    step, min_nodes, max_nodes)`` of i32 device arrays; ``max_nodes`` is
    the raw spec value (``INF_TIME`` for "machine size") — the engine
    clamps it to ``total_nodes`` at trace time.
    """
    import jax.numpy as jnp

    if service is None:
        return None
    if isinstance(service, ServiceTrace):
        service = service.plan()
    if isinstance(service, ServicePlan):
        max_n = service.max_nodes
        if max_n is None:
            max_n = int(n_nodes) if n_nodes is not None else int(INF_TIME)
        service = (service.deadline, service.tick_time,
                   service.up_threshold, service.down_threshold,
                   service.step, service.min_nodes, max_n)
    if not (isinstance(service, tuple) and len(service) == 7):
        raise TypeError(
            "service must be None, a ServiceTrace, a ServicePlan, or a "
            f"7-tuple svc ctx; got {type(service).__name__}")
    return tuple(jnp.asarray(x, dtype=jnp.int32) for x in service)
