from repro.sharding.rules import (  # noqa: F401
    ParamDef, ShardingRules, TRAIN_RULES, SERVE_RULES, LONG_DECODE_RULES,
    init_from_defs, shapes_from_defs, specs_from_defs, logical_to_pspec,
    constrain,
)
