"""Logical-axis sharding rules (MaxText-style) + parameter definition trees.

Every parameter/activation dimension carries a *logical* axis name; a
``ShardingRules`` table maps logical names to physical mesh axes.  Swapping
rule tables re-lays-out the whole model without touching model code — this
is how the dry-run explores baseline vs. hillclimbed shardings and how the
same model serves under train (FSDP+TP), serve (2D-TP) and long-context
(sequence-sharded KV cache) regimes.

Defaults (DESIGN.md §6):
  - ``fsdp``   -> "data":   ZeRO-3-style parameter sharding axis
  - ``tensor`` -> "model":  Megatron-style tensor parallel axis
  - batch      -> ("pod","data") when the pod axis exists

Uneven dims (e.g. 40 heads over a 16-way axis) are allowed: GSPMD pads
internally (verified on this container; waste shows up in the roofline
utilization ratio and is hillclimb material).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Mapping, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Axes = Tuple[Optional[str], ...]


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """Mapping logical axis name -> mesh axis (or tuple of mesh axes)."""

    rules: Mapping[str, Any]
    name: str = "custom"

    def physical(self, logical: Optional[str], mesh: Mesh):
        if logical is None:
            return None
        phys = self.rules.get(logical, None)
        if phys is None:
            return None
        if isinstance(phys, str):
            return phys if phys in mesh.axis_names else None
        present = tuple(a for a in phys if a in mesh.axis_names)
        return present if present else None

    def pspec(self, axes: Sequence[Optional[str]], mesh: Mesh) -> P:
        return P(*[self.physical(a, mesh) for a in axes])


# Training: FSDP over "data" (+ pure DP over "pod"), TP over "model".
TRAIN_RULES = ShardingRules(
    name="train_fsdp_tp",
    rules={
        "batch": ("pod", "data"),
        "cache_batch": ("pod", "data"),
        "act_batch": ("pod", "data"),
        "act_seq": "model",       # scan-carry activations: sequence-sharded (SP)
        "act_embed": None,
        "heads": "model",
        "kv": "model",
        "mlp": "model",
        "vocab": "model",
        "embed_fsdp": "data",     # parameter dim sharded ZeRO-3 style
        "experts": None,          # baseline: TP-within-expert
        "moe_wD": None,           # expert weights gathered over data at use
                                  # (stationary-expert variant measured WORSE:
                                  #  GSPMD re-gathers rows per shard; see §Perf)
        "cache_seq": None,
        "state": None,
    },
)

# Prefill: no backward pass => no per-layer activation checkpoints, so the
# carry can keep the sequence unsharded — removing the act_seq<->heads
# reshard (and its per-tile all-to-alls) from every layer.
PREFILL_RULES = ShardingRules(
    name="prefill_seq_unsharded",
    rules={**TRAIN_RULES.rules, "act_seq": None},
)

# Training without FSDP: weights replicated over "data" (fit-permitting),
# killing the per-layer/per-microbatch weight all-gathers (hillclimb rules).
TRAIN_TP_REPLICATED = ShardingRules(
    name="train_tp_replicated",
    rules={**TRAIN_RULES.rules, "embed_fsdp": None},
)

# Serving (decode): weights STATIONARY, fully 2-D sharded (model x data); the
# residual stream is D-sharded over "data" so every matmul contracts against
# a local weight shard + small partial-sum all-reduce — no weight gathers.
# The KV cache (the big state) stays (batch x kv-heads)-sharded.
SERVE_RULES = ShardingRules(
    name="serve_2d_stationary",
    rules={
        "batch": ("pod", "data"),      # attention activations / cache side
        "cache_batch": ("pod", "data"),
        "act_batch": None,             # residual batch replicated (tiny at S=1)
        "act_seq": None,
        "act_embed": "data",           # residual stream D-dim sharded
        "heads": "model",
        "kv": "model",
        "mlp": "model",
        "vocab": "model",
        "embed_fsdp": "data",          # stationary: never gathered
        "experts": None,
        "moe_wD": "data",              # expert weights stay D-sharded (stationary)
        "cache_seq": None,
        "state": None,
    },
)

# Long-context decode (batch=1): KV cache sequence-sharded over "data".
LONG_DECODE_RULES = ShardingRules(
    name="long_decode_seqshard",
    rules={
        "batch": None,
        "cache_batch": None,
        "act_batch": None,
        "act_seq": None,
        "act_embed": "data",
        "heads": "model",
        "kv": "model",
        "mlp": "model",
        "vocab": "model",
        "embed_fsdp": "data",
        "experts": None,
        "moe_wD": "data",
        "cache_seq": "data",
        "state": "data",          # rwkv/ssm recurrent state heads spread on data
    },
)

RULE_SETS = {r.name: r for r in (TRAIN_RULES, TRAIN_TP_REPLICATED,
                                 PREFILL_RULES, SERVE_RULES,
                                 LONG_DECODE_RULES)}


def logical_to_pspec(axes: Sequence[Optional[str]], rules: ShardingRules,
                     mesh: Mesh) -> P:
    return rules.pspec(axes, mesh)


def constrain(x: jax.Array, axes: Sequence[Optional[str]], rules: ShardingRules,
              mesh: Optional[Mesh]) -> jax.Array:
    """with_sharding_constraint by logical axes (no-op without a mesh)."""
    if mesh is None or mesh.empty:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, rules.pspec(axes, mesh))
    )


# ---------------------------------------------------------------------------
# parameter definition trees
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ParamDef:
    """Declarative parameter: shape + logical axes + initializer.

    The same def tree yields (a) concrete initialized arrays, (b) pure
    ShapeDtypeStructs for the allocation-free dry-run, (c) PartitionSpecs —
    guaranteed structurally consistent because they share one source.
    """

    shape: Tuple[int, ...]
    axes: Axes
    init: str = "normal"      # normal | zeros | ones | embed
    scale: Optional[float] = None
    dtype: Any = jnp.float32

    def __post_init__(self):
        if len(self.shape) != len(self.axes):
            raise ValueError(f"shape {self.shape} vs axes {self.axes} rank mismatch")


def _leaf_init(d: ParamDef, key: jax.Array) -> jax.Array:
    if d.init == "zeros":
        return jnp.zeros(d.shape, d.dtype)
    if d.init == "ones":
        return jnp.ones(d.shape, d.dtype)
    if d.init in ("normal", "embed"):
        # fan-in scaling on the contracting dim; embeds scale by 1.0
        if d.scale is not None:
            s = d.scale
        elif d.init == "embed":
            s = 1.0
        else:
            fan_in = d.shape[-2] if len(d.shape) >= 2 else d.shape[-1]
            s = fan_in ** -0.5
        return (jax.random.normal(key, d.shape, jnp.float32) * s).astype(d.dtype)
    raise ValueError(f"unknown init {d.init!r}")


def init_from_defs(defs, key: jax.Array):
    """Initialize a pytree of ParamDefs into arrays (deterministic per path)."""
    leaves, treedef = jax.tree.flatten(
        defs, is_leaf=lambda x: isinstance(x, ParamDef)
    )
    keys = jax.random.split(key, len(leaves))
    return jax.tree.unflatten(
        treedef, [_leaf_init(d, k) for d, k in zip(leaves, keys)]
    )


def shapes_from_defs(defs):
    """ShapeDtypeStruct tree — dry-run stand-in, zero allocation."""
    return jax.tree.map(
        lambda d: jax.ShapeDtypeStruct(d.shape, d.dtype),
        defs, is_leaf=lambda x: isinstance(x, ParamDef),
    )


def _axis_size(mesh: Mesh, phys) -> int:
    names = (phys,) if isinstance(phys, str) else tuple(phys)
    n = 1
    for a in names:
        n *= mesh.shape[a]
    return n


def repair_pspec(shape: Tuple[int, ...], spec: P, mesh: Mesh) -> P:
    """Divisibility-aware spec repair for jit *input* shardings.

    ``with_sharding_constraint`` tolerates uneven dims (GSPMD pads), but
    ``in_shardings`` require exact divisibility.  When a dim is not
    divisible by its assigned mesh axis (e.g. 8 KV heads over a 16-way
    model axis) we drop the assignment and re-place the axis on the
    right-most free dim that IS divisible (typically head_dim) — the
    tensor stays fully distributed, just along a different dim.
    """
    phys = list(spec) + [None] * (len(shape) - len(spec))
    out, dropped = [], []
    for dim, p in zip(shape, phys):
        if p is None:
            out.append(None)
        elif dim % _axis_size(mesh, p) == 0:
            out.append(p)
        else:
            out.append(None)
            dropped.append(p)
    for p in dropped:
        for i in range(len(out) - 1, -1, -1):
            if out[i] is None and shape[i] % _axis_size(mesh, p) == 0:
                out[i] = p
                break
    return P(*out)


def specs_from_defs(defs, rules: ShardingRules, mesh: Mesh):
    """NamedSharding tree matching the def tree (divisibility-repaired)."""
    return jax.tree.map(
        lambda d: NamedSharding(
            mesh, repair_pspec(d.shape, rules.pspec(d.axes, mesh), mesh)
        ),
        defs, is_leaf=lambda x: isinstance(x, ParamDef),
    )


def count_params(defs) -> int:
    return sum(
        int(np.prod(d.shape))
        for d in jax.tree.leaves(defs, is_leaf=lambda x: isinstance(x, ParamDef))
    )
