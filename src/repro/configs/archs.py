"""Import-for-effect registry of all assigned architectures."""
from repro.configs import (  # noqa: F401
    llama4_scout_17b_a16e, mixtral_8x7b, mistral_nemo_12b, llama3_2_3b,
    stablelm_3b, h2o_danube_1_8b, zamba2_2_7b, rwkv6_7b, qwen2_vl_72b,
    seamless_m4t_medium,
)
