"""rwkv6-7b (Finch) — 32L d=4096 attention-free, data-dependent decay,
d_ff=14336 vocab=65536. [arXiv:2404.05892; hf]"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="rwkv6-7b", family="rwkv",
    n_layers=32, d_model=4096, n_heads=64, n_kv_heads=64, d_ff=14336,
    vocab=65536, head_dim=64, rotary_pct=0.0,
))
