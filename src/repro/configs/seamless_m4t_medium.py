"""seamless-m4t-medium — enc-dec, 12+12L d=1024 16H (kv=16) d_ff=4096
vocab=256206, audio frontend stubbed to precomputed frame embeddings.
[arXiv:2308.11596; hf]"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="seamless-m4t-medium", family="encdec",
    n_layers=12, enc_layers=12, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=4096, vocab=256206, head_dim=64, norm="layernorm", act="gelu",
    rope_theta=10_000.0,
))
