"""qwen2-vl-72b — 80L d=8192 64H (GQA kv=8) d_ff=29568 vocab=152064,
M-RoPE (stubbed to 1-D RoPE; DESIGN.md §7), dynamic-resolution vision
frontend stubbed to precomputed patch embeddings. [arXiv:2409.12191; hf]"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen2-vl-72b", family="vlm",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=29568,
    vocab=152064, head_dim=128, rope_theta=1_000_000.0,
    frontend="vision", frontend_frac=0.25,
))
