from repro.configs.base import (  # noqa: F401
    SHAPES, LONG_CONTEXT_OK, ModelConfig, ShapeConfig, cell_supported,
    get_config, list_archs, register,
)
