"""zamba2-2.7b — 54L d=2560 Mamba2 backbone (ssm_state=64) with one shared
attention+MLP block applied every 6 layers (32H kv=32, d_ff=10240).
[arXiv:2411.15242; hf]"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="zamba2-2.7b", family="hybrid",
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32, d_ff=10240,
    vocab=32000, head_dim=80, rope_theta=10_000.0,
    ssm_state=64, ssm_expand=2, ssm_head_dim=64, attn_every=6,
))
