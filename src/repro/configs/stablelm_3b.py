"""stablelm-3b — 32L d=2560 32H (MHA kv=32) d_ff=6912 vocab=50304,
LayerNorm + 25% partial rotary. [hf:stabilityai/stablelm-2-1_6b; unverified]"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="stablelm-3b", family="dense",
    n_layers=32, d_model=2560, n_heads=32, n_kv_heads=32, d_ff=6912,
    vocab=50304, head_dim=80, norm="layernorm", rotary_pct=0.25,
    rope_theta=10_000.0,
))
