"""Model/shape configuration dataclasses + the architecture registry."""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | hybrid | rwkv | vlm | encdec
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 128
    norm: str = "rmsnorm"         # rmsnorm | layernorm
    act: str = "swiglu"           # swiglu | gelu
    rope_theta: float = 500_000.0
    rotary_pct: float = 1.0       # stablelm-style partial rotary
    window: Optional[int] = None  # sliding-window attention width
    qk_norm: bool = False
    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    moe_d_ff: int = 0             # expert hidden size (falls back to d_ff)
    n_shared_experts: int = 0     # llama4-style always-on shared expert
    # hybrid (zamba2): Mamba2 backbone + one shared attn block every k layers
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 128
    attn_every: int = 0
    # rwkv
    rwkv_chunk: int = 128
    # modality frontend stubs
    frontend: Optional[str] = None   # vision | audio
    frontend_frac: float = 0.25      # fraction of seq that is frontend embeds
    # encoder-decoder
    enc_layers: int = 0
    tie_embeddings: bool = True
    # numerics / memory
    dtype: str = "bfloat16"          # compute dtype (params master f32)
    block_q: int = 512               # blockwise-attention tile sizes (jnp path)
    block_k: int = 512
    remat: bool = True
    remat_policy: str = "nothing"   # nothing | dots (saves matmul outputs)
    scan_layers: bool = True
    use_pallas: bool = False         # TPU fast path (interpret-validated on CPU)
    # Mesh-divisibility padding for computation shapes (DESIGN.md §6):
    # head/vocab dims are padded up to a multiple of `shard_pad` so GSPMD
    # never has to resolve uneven shardings (which it does by inserting
    # global gathers).  1 = true arch shapes (CPU tests); the launcher sets
    # 16 for the production mesh.  Waste shows up in useful_flops_ratio.
    shard_pad: int = 1

    def _pad(self, n: int) -> int:
        p = self.shard_pad
        return ((n + p - 1) // p) * p

    @property
    def heads_c(self) -> int:
        return self._pad(self.n_heads)

    @property
    def kv_heads_c(self) -> int:
        kv = self._pad(self.n_kv_heads)
        return min(kv, self.heads_c)

    @property
    def vocab_c(self) -> int:
        return self._pad(self.vocab)

    @property
    def gqa_groups(self) -> int:
        return max(self.heads_c // max(self.kv_heads_c, 1), 1)

    @property
    def d_inner(self) -> int:        # ssm inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def expert_ff(self) -> int:
        return self.moe_d_ff or self.d_ff

    def reduced(self, **overrides) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests."""
        small = dict(
            n_layers=min(self.n_layers, 4 if self.attn_every == 0 else self.attn_every),
            d_model=128,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 4) if self.n_kv_heads < self.n_heads else 4,
            d_ff=256,
            vocab=512,
            head_dim=32,
            window=min(self.window, 64) if self.window else None,
            n_experts=min(self.n_experts, 4),
            moe_d_ff=128 if self.n_experts else 0,
            ssm_state=16 if self.ssm_state else 0,
            ssm_head_dim=32 if self.ssm_state else 64,
            ssm_chunk=16,
            rwkv_chunk=16,
            attn_every=2 if self.attn_every else 0,
            enc_layers=2 if self.enc_layers else 0,
            block_q=64,
            block_k=64,
            dtype="float32",
            remat=False,
        )
        if self.attn_every:
            small["n_layers"] = 4
        small.update(overrides)
        return dataclasses.replace(self, **small)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One (input-shape) cell from the assignment."""

    name: str
    seq_len: int
    global_batch: int
    kind: str                     # train | prefill | decode | long_decode

    @property
    def is_training(self) -> bool:
        return self.kind == "train"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "long_decode"),
}

# Archs for which long_500k is runnable (sub-quadratic attention):
# SSM/hybrid are attention-free/bounded; SWA archs have bounded KV windows.
LONG_CONTEXT_OK = {"rwkv6-7b", "zamba2-2.7b", "mixtral-8x7b", "h2o-danube-1.8b"}

_REGISTRY: dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    import repro.configs.archs  # noqa: F401  (populates the registry)
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_archs() -> list[str]:
    import repro.configs.archs  # noqa: F401
    return sorted(_REGISTRY)


def cell_supported(arch: str, shape: str) -> tuple[bool, str]:
    """Is (arch x shape) a valid dry-run cell? (False, reason) if skipped."""
    cfg = get_config(arch)
    if shape == "long_500k" and arch not in LONG_CONTEXT_OK:
        return False, "full-attention arch: 500k KV cache is quadratic-regime; skipped per assignment"
    return True, ""
