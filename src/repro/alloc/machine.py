"""Static machine topologies for the node-allocation subsystem (DESIGN.md §11).

The paper's SST component models the machine as interconnected node
components; here the whole machine is one static pytree of per-node arrays,
so a jitted simulation specializes on the topology *shape* while group
membership and coordinates stay device-resident data.

Invariants every builder maintains (the vectorized strategies rely on them):

- node ids are ``0..N-1`` in a fixed linear order (the "cable order"),
- ``group`` ids are nondecreasing along node index, i.e. each group is one
  contiguous id range (true of linear racks, mesh rows, dragonfly groups),
- ``group_start[i]`` / ``group_size[i]`` describe node *i*'s group extent,
  allowing O(1) per-node segment lookups via plain gathers,
- ``N * n_groups < 2**30`` so the lexicographic sort keys used by the
  ``spread``/``topo`` strategies stay inside int32.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class Machine:
    """Static per-node topology description (see module docstring)."""

    group: jax.Array        # i32[N] group id, nondecreasing along node index
    group_start: jax.Array  # i32[N] first node id of this node's group
    group_size: jax.Array   # i32[N] number of nodes in this node's group
    coord: jax.Array        # i32[N, 2] (row, col)-style coordinates (hop metrics)
    n_groups: jax.Array     # i32 scalar

    @property
    def n_nodes(self) -> int:
        return self.group.shape[-1]

    def to_host(self) -> dict:
        """Numpy view for the reference simulator / offline metrics."""
        return {
            "group": np.asarray(self.group),
            "group_start": np.asarray(self.group_start),
            "group_size": np.asarray(self.group_size),
            "coord": np.asarray(self.coord),
            "n_groups": int(self.n_groups),
        }


def _from_groups(group: np.ndarray, coord: np.ndarray) -> Machine:
    n = group.shape[0]
    if n == 0:
        raise ValueError("machine must have at least one node")
    if (np.diff(group) < 0).any():
        raise ValueError("group ids must be nondecreasing along node index")
    n_groups = int(group.max()) + 1
    if n >= 2 ** 15 or n * n_groups >= 2 ** 30:
        raise ValueError(
            f"machine too large for int32 sort keys (N={n}, groups={n_groups}); "
            "all placement keys must stay below the 2**30 sentinel"
        )
    # first index of each node's group and the group extent
    first_of = np.zeros(n_groups, dtype=np.int64)
    counts = np.zeros(n_groups, dtype=np.int64)
    for g in range(n_groups):
        idx = np.nonzero(group == g)[0]
        first_of[g] = idx[0] if len(idx) else 0
        counts[g] = len(idx)
    return Machine(
        group=jnp.asarray(group, dtype=jnp.int32),
        group_start=jnp.asarray(first_of[group], dtype=jnp.int32),
        group_size=jnp.asarray(counts[group], dtype=jnp.int32),
        coord=jnp.asarray(coord, dtype=jnp.int32),
        n_groups=jnp.int32(n_groups),
    )


def linear(n_nodes: int, *, group_size: int = 8) -> Machine:
    """1-D chain of nodes partitioned into contiguous racks of ``group_size``."""
    ids = np.arange(n_nodes, dtype=np.int64)
    group = ids // max(int(group_size), 1)
    coord = np.stack([np.zeros_like(ids), ids], axis=1)
    return _from_groups(group, coord)


def mesh2d(rows: int, cols: int) -> Machine:
    """``rows x cols`` mesh in row-major cable order; each row is one group
    (the row is the locality domain: intra-row hops are cheap)."""
    ids = np.arange(rows * cols, dtype=np.int64)
    r, c = ids // cols, ids % cols
    coord = np.stack([r, c], axis=1)
    return _from_groups(r, coord)


def dragonfly(n_groups: int, nodes_per_group: int) -> Machine:
    """Dragonfly-style machine: all-to-all connected groups of
    ``nodes_per_group`` nodes; inter-group traffic pays the global-link tax
    (the contention model charges per distinct group spanned)."""
    ids = np.arange(n_groups * nodes_per_group, dtype=np.int64)
    g, k = ids // nodes_per_group, ids % nodes_per_group
    coord = np.stack([g, k], axis=1)
    return _from_groups(g, coord)
