"""Allocation-span contention model (DESIGN.md §11.3).

A job spanning ``s`` topology groups pays an inter-group communication tax:
its remaining runtime is dilated *at dispatch time* by

    dilated = remaining + (remaining * alpha_num * (s - 1)) // alpha_den

saturating at ``2**30 - 1`` (the trace-horizon bound).  Integer-exact and
overflow-free by construction — ``alpha_num < 2**10``, ``alpha_den < 2**15``
(enforced by :meth:`Contention.make`) and ``span < 2**15`` (machine builder
bound) keep every intermediate inside int32, and the host mirror applies
the *same* clamped formula — so the JAX engine and the reference simulator
agree bit-for-bit even in the saturated regime.  ``alpha =
alpha_num/alpha_den`` is the fractional slowdown per extra group (e.g.
1/10 ⇒ +10% per extra group).

Pinned semantics:

- dilation applies to ``remaining`` each time the job is (re)dispatched; a
  preempted job's leftover (``finish - clock``, already dilated) is dilated
  again on resume under its *new* allocation's span,
- walltime *estimates* (EASY-backfill shadow math, ``rsv_finish``) are never
  dilated — user requests don't know the allocator,
- all fields are traced i32 scalars, so contention parameters are a valid
  ``vmap`` sweep axis.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

_LIM = 2 ** 30 - 1  # dilated runtimes saturate here (trace-horizon bound)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class Contention:
    enabled: jax.Array    # i32 scalar: 0 = off, 1 = on
    alpha_num: jax.Array  # i32 slowdown numerator per extra group spanned
    alpha_den: jax.Array  # i32 slowdown denominator, >= 1

    @classmethod
    def off(cls) -> "Contention":
        return cls(enabled=jnp.int32(0), alpha_num=jnp.int32(0),
                   alpha_den=jnp.int32(1))

    @classmethod
    def make(cls, alpha_num: int, alpha_den: int) -> "Contention":
        if not 0 < alpha_den < 2 ** 15:
            raise ValueError("alpha_den must be in [1, 2**15)")
        if not 0 <= alpha_num < 2 ** 10:
            raise ValueError("alpha_num must be in [0, 2**10)")
        return cls(enabled=jnp.int32(1), alpha_num=jnp.int32(alpha_num),
                   alpha_den=jnp.int32(alpha_den))

    @classmethod
    def canonical(cls, value) -> "Contention":
        """THE contention canonicalizer: ``None`` -> off, ``(num, den)`` ->
        :meth:`make`, a ``Contention`` passes through — shared by
        ``engine.make_alloc_ctx``, the sweep layer, and the refsim driver."""
        if value is None:
            return cls.off()
        if isinstance(value, tuple):
            return cls.make(*value)
        if not isinstance(value, cls):
            raise TypeError(
                f"contention must be None, (num, den), or Contention; "
                f"got {type(value).__name__}")
        return value


def dilate(con: Contention, remaining: jax.Array, span: jax.Array) -> jax.Array:
    """Dilated runtime for an allocation spanning ``span`` groups (int32).

    ``factor = alpha_num * (span-1) < 2**25``; ``remaining`` is clamped so
    the product stays below ``2**30`` (exact whenever the true result is
    representable, deterministically saturated otherwise — mirrored
    verbatim by :func:`dilate_host`).
    """
    factor = con.alpha_num * jnp.maximum(span - 1, 0)
    safe_rem = jnp.minimum(remaining, _LIM // jnp.maximum(factor, 1))
    extra = (safe_rem * factor) // con.alpha_den
    dilated = jnp.minimum(remaining + extra, _LIM)
    return jnp.where(con.enabled > 0, dilated, remaining)


def dilate_host(alpha_num: int, alpha_den: int, remaining: int, span: int) -> int:
    """Host mirror of :func:`dilate` (plain Python ints, same clamping)."""
    factor = alpha_num * max(span - 1, 0)
    safe_rem = min(remaining, _LIM // max(factor, 1))
    return min(remaining + (safe_rem * factor) // alpha_den, _LIM)
