"""Host-side (numpy) mirror of the placement strategies (DESIGN.md §11.4).

``repro.refsim`` validates the JAX engine per-job *and* per-node; these
functions reproduce ``repro.alloc.strategies`` tie-breaking exactly, written
as straightforward scans so the two implementations fail independently.

``owner`` is the same i32[N] occupancy map (-1 = free).  Placement returns a
sorted array of node ids (the mask's set bits).
"""

from __future__ import annotations

from typing import Dict

import numpy as np

from repro.alloc.strategies import CONTIGUOUS, SIMPLE, SPREAD, TOPO, alloc_id


def free_count_host(owner: np.ndarray) -> int:
    return int((owner < 0).sum())


def largest_free_run_host(owner: np.ndarray) -> int:
    best = run = 0
    for busy in owner >= 0:
        run = 0 if busy else run + 1
        best = max(best, run)
    return best


def placeable_cap_host(strategy, owner: np.ndarray) -> int:
    if alloc_id(strategy) == CONTIGUOUS:
        return largest_free_run_host(owner)
    return free_count_host(owner)


def _runs(owner: np.ndarray):
    """Maximal free runs as (length, start) tuples in start order."""
    runs, start = [], None
    for i, busy in enumerate(owner >= 0):
        if busy:
            if start is not None:
                runs.append((i - start, start))
                start = None
        elif start is None:
            start = i
    if start is not None:
        runs.append((len(owner) - start, start))
    return runs


def place_host(strategy, mach: Dict[str, np.ndarray], owner: np.ndarray,
               need: int) -> np.ndarray:
    """Mirror of ``strategies.place``: ids of the chosen ``need`` nodes."""
    sid = alloc_id(strategy)
    free_ids = np.nonzero(owner < 0)[0]
    if sid == SIMPLE:
        return free_ids[:need]
    if sid == CONTIGUOUS:
        fits = [r for r in _runs(owner) if r[0] >= need]
        if not fits:  # preempt-policy fallback, pinned identically in JAX
            return free_ids[:need]
        length, start = min(fits)
        return np.arange(start, start + need)
    group = mach["group"]
    if sid == SPREAD:
        # (rank among free within group, group id, node id)
        rank: Dict[int, int] = {}
        keyed = []
        for i in free_ids:
            g = int(group[i])
            rank[g] = rank.get(g, 0) + 1
            keyed.append((rank[g], g, int(i)))
        keyed.sort()
        return np.array(sorted(k[2] for k in keyed[:need]), dtype=np.int64)
    if sid == TOPO:
        # groups by (free count desc, group id), nodes within a group by id
        per_group: Dict[int, list] = {}
        for i in free_ids:
            per_group.setdefault(int(group[i]), []).append(int(i))
        order = sorted(per_group, key=lambda g: (-len(per_group[g]), g))
        chosen: list = []
        for g in order:
            chosen.extend(per_group[g])
        return np.array(sorted(chosen[:need]), dtype=np.int64)
    raise ValueError(f"unknown allocation strategy {strategy!r}")


def group_span_host(mach: Dict[str, np.ndarray], node_ids: np.ndarray) -> int:
    return len(np.unique(mach["group"][node_ids])) if len(node_ids) else 0


def fingerprint_host(node_ids: np.ndarray) -> tuple[int, int]:
    """(lowest node id, sum of 1-based ids); mirrors ``alloc_fingerprint``."""
    if len(node_ids) == 0:
        return int(2 ** 30 - 1), 0
    return int(node_ids.min()), int((node_ids + 1).sum())
