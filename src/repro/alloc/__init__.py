"""Topology-aware node allocation (DESIGN.md §11).

The seed engine tracked one scalar free-node counter; this subsystem gives
the machine a concrete shape.  A static :class:`Machine` pytree describes
the topology (linear racks, 2-D mesh rows, dragonfly groups), ``SimState``
carries a per-node occupancy map, and four placement strategies decide which
nodes each job gets:

====================  =====================================================
``simple``            first-fit scattered — timing-identical to the seed
                      scalar counter (the bit-for-bit compatibility mode)
``contiguous``        best-fit contiguous block; blocks under fragmentation
``spread``            round-robin across groups (maximizes span)
``topo``              pack fewest groups (minimizes span)
====================  =====================================================

An optional :class:`Contention` model dilates job runtime per extra group
spanned, so the same trace under different allocators yields different
makespans.  Everything is jit-able and the strategy id is a traced int —
``repro.core.parallel.simulate_alloc_sweep`` vmaps over strategies exactly
like policy sweeps.
"""

from repro.alloc.contention import Contention, dilate, dilate_host
from repro.alloc.machine import Machine, dragonfly, linear, mesh2d
from repro.alloc.strategies import (
    ALLOC_IDS, ALLOC_NAMES, CONTIGUOUS, SIMPLE, SPREAD, TOPO,
    alloc_fingerprint, alloc_id, canonical_id, free_count, group_span,
    largest_free_run, place, placeable_cap,
)

__all__ = [
    "ALLOC_IDS", "ALLOC_NAMES", "CONTIGUOUS", "SIMPLE", "SPREAD", "TOPO",
    "Contention", "Machine", "alloc_fingerprint", "alloc_id", "canonical_id",
    "dilate", "dilate_host", "dragonfly", "free_count", "group_span",
    "largest_free_run", "linear", "mesh2d", "place", "placeable_cap",
]
