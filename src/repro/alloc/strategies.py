"""Vectorized node-placement strategies (DESIGN.md §11.2).

Each strategy answers two questions against the per-node occupancy map
``owner`` (i32[N], ``-1`` = free, else owning job row):

1. *feasibility* — can a ``need``-node job be placed at all?  Collapsed to a
   single scalar ``placeable_cap``: a job fits iff ``need <= cap``.  For the
   count-based strategies the cap is the free-node count (identical to the
   seed scalar counter); for ``contiguous`` it is the largest free run.
2. *placement* — which concrete nodes does the job get?  ``place`` returns a
   bool[N] mask with exactly ``need`` set bits whenever ``need`` free nodes
   exist.

Pinned tie-breaking, mirrored bit-for-bit by ``repro.alloc.host`` (and hence
``repro.refsim``):

- ``simple``     first-fit scattered: the ``need`` lowest-id free nodes.
- ``contiguous`` best-fit block: the maximal free run minimizing
                 (run length, start id); take its first ``need`` nodes.
                 Falls back to ``simple`` when no run fits (reachable only
                 via the preempt policy, whose reclaim check is count-based).
- ``spread``     round-robin over groups: order free nodes by
                 (rank-within-group, group id, node id), take ``need``.
- ``topo``       pack fewest groups: order groups by (free count desc,
                 group id), nodes within a group by id, take ``need``.

Strategy ids are dense ints so ``place``/``placeable_cap`` dispatch through
``lax.switch`` on a *traced* id — an ensemble can ``vmap`` over strategies
exactly like it vmaps over scheduling policies.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.alloc.machine import Machine

SIMPLE = 0
CONTIGUOUS = 1
SPREAD = 2
TOPO = 3

ALLOC_NAMES = {SIMPLE: "simple", CONTIGUOUS: "contiguous", SPREAD: "spread",
               TOPO: "topo"}
ALLOC_IDS = {v: k for k, v in ALLOC_NAMES.items()}

_BIG = jnp.int32(2 ** 30 - 1)


def alloc_id(strategy) -> int:
    if isinstance(strategy, str):
        try:
            return ALLOC_IDS[strategy.lower()]
        except KeyError:
            raise ValueError(
                f"unknown allocation strategy {strategy!r}; "
                f"known: {sorted(ALLOC_IDS)}") from None
    return int(strategy)


def canonical_id(strategy):
    """THE strategy canonicalizer (shared by every entry point).

    Accepts a name, a dense id, a numpy/JAX integer scalar, or any sequence
    mixing those (list, tuple, or numpy array — including object/str
    arrays), and returns a plain ``int`` for scalars or ``i32`` values for
    sequences/traced inputs:

    - scalar str/int/np integer -> ``int``
    - traced JAX value          -> passed through as i32 (sweep axes)
    - sequence of any of these  -> ``jnp.int32[B]``

    Every id is validated against the known strategy table, so a typo'd
    name or out-of-range id fails loudly at canonicalization time instead
    of silently clipping inside ``lax.switch``.
    """
    import numpy as np

    if strategy is None:
        return SIMPLE
    if isinstance(strategy, jax.core.Tracer):
        return jnp.asarray(strategy, dtype=jnp.int32)  # sweep-axis data
    if isinstance(strategy, jax.Array):
        strategy = np.asarray(strategy)
    if isinstance(strategy, (list, tuple)):
        return jnp.asarray([canonical_id(s) for s in strategy],
                           dtype=jnp.int32)
    if isinstance(strategy, np.ndarray):
        if strategy.ndim == 0:
            return canonical_id(strategy.item())
        return jnp.asarray([canonical_id(s) for s in strategy.tolist()],
                           dtype=jnp.int32)
    sid = alloc_id(strategy)
    if sid not in ALLOC_NAMES:
        raise ValueError(
            f"allocation strategy id {sid} out of range; "
            f"known: {sorted(ALLOC_NAMES)}")
    return sid


# ---------------------------------------------------------------------------
# occupancy-map scalars
# ---------------------------------------------------------------------------


def free_count(owner: jax.Array) -> jax.Array:
    return jnp.sum((owner < 0).astype(jnp.int32))


def largest_free_run(owner: jax.Array) -> jax.Array:
    """Length of the longest run of consecutive free nodes (fragmentation)."""
    free = owner < 0
    n = owner.shape[0]
    ii = jnp.arange(n, dtype=jnp.int32)
    prev_busy = jax.lax.cummax(jnp.where(free, jnp.int32(-1), ii))
    run_len = jnp.where(free, ii - prev_busy, 0)
    return jnp.max(run_len).astype(jnp.int32)


def placeable_cap(strategy: jax.Array, owner: jax.Array) -> jax.Array:
    """Largest job size placeable right now: ``need <= cap`` ⇔ feasible."""
    return jax.lax.switch(
        jnp.clip(strategy, 0, 3),
        (free_count, largest_free_run, free_count, free_count),
        owner,
    )


# ---------------------------------------------------------------------------
# placement
# ---------------------------------------------------------------------------


def _take_first(order: jax.Array, free: jax.Array, need: jax.Array) -> jax.Array:
    """Mask of the first ``need`` *free* rows of ``order`` (a permutation that
    sorts free nodes first by preference key)."""
    n = order.shape[0]
    take = jnp.arange(n, dtype=jnp.int32) < need
    return jnp.zeros((n,), bool).at[order].set(take) & free


def _place_simple(machine: Machine, owner: jax.Array, need: jax.Array) -> jax.Array:
    free = owner < 0
    rank = jnp.cumsum(free.astype(jnp.int32))
    return free & (rank <= need)


def _place_contiguous(machine: Machine, owner: jax.Array, need: jax.Array) -> jax.Array:
    free = owner < 0
    n = owner.shape[0]
    ii = jnp.arange(n, dtype=jnp.int32)
    prev_busy = jax.lax.cummax(jnp.where(free, jnp.int32(-1), ii))
    run_start = prev_busy + 1
    run_len = jnp.where(free, ii - prev_busy, 0)
    nxt_free = jnp.concatenate([free[1:], jnp.zeros((1,), bool)])
    run_end = free & ~nxt_free
    feasible = run_end & (run_len >= need)
    # best fit: minimize (total run length, start id); key is collision-free
    # because a run is identified by its start
    key = jnp.where(feasible, run_len * jnp.int32(n + 1) + run_start, _BIG)
    best = jnp.argmin(key)
    found = jnp.any(feasible)
    start = run_start[best]
    block = (ii >= start) & (ii < start + need)
    return jnp.where(found, block, _place_simple(machine, owner, need))


def _group_base(machine: Machine, csum: jax.Array) -> jax.Array:
    """Per-node cumulative count just *before* the node's group starts."""
    gs = machine.group_start
    return jnp.where(gs > 0, csum[jnp.maximum(gs - 1, 0)], 0)


def _place_spread(machine: Machine, owner: jax.Array, need: jax.Array) -> jax.Array:
    free = owner < 0
    csum = jnp.cumsum(free.astype(jnp.int32))
    rank_in_group = csum - _group_base(machine, csum)  # 1-based among free
    g = machine.n_groups
    key = jnp.where(free, (rank_in_group - 1) * g + machine.group, _BIG)
    order = jnp.argsort(key, stable=True)  # stable ⇒ ties broken by node id
    return _take_first(order, free, need)


def _place_topo(machine: Machine, owner: jax.Array, need: jax.Array) -> jax.Array:
    free = owner < 0
    n = owner.shape[0]
    csum = jnp.cumsum(free.astype(jnp.int32))
    base = _group_base(machine, csum)
    last = machine.group_start + machine.group_size - 1
    group_free = csum[last] - base  # per-node: free nodes in my whole group
    key = jnp.where(free, (jnp.int32(n) - group_free) * machine.n_groups
                    + machine.group, _BIG)
    order = jnp.argsort(key, stable=True)  # stable ⇒ within-group by node id
    return _take_first(order, free, need)


_PLACERS = (_place_simple, _place_contiguous, _place_spread, _place_topo)


def place(strategy: jax.Array, machine: Machine, owner: jax.Array,
          need: jax.Array) -> jax.Array:
    """Choose ``need`` free nodes; guaranteed to succeed iff they exist."""
    return jax.lax.switch(
        jnp.clip(strategy, 0, 3), _PLACERS, machine, owner, need
    )


# ---------------------------------------------------------------------------
# locality score + fingerprints
# ---------------------------------------------------------------------------


def group_span(machine: Machine, mask: jax.Array) -> jax.Array:
    """Number of distinct topology groups the allocation touches (the
    locality score; the contention model charges per extra group)."""
    csum = jnp.cumsum(mask.astype(jnp.int32))
    within = csum - _group_base(machine, csum)
    first_in_group = mask & (within == 1)
    return jnp.sum(first_in_group.astype(jnp.int32))


def alloc_fingerprint(mask: jax.Array) -> tuple[jax.Array, jax.Array]:
    """(lowest node id, sum of 1-based node ids) — a cheap exact-equality
    witness for cross-engine node-map validation (DESIGN.md §11.4)."""
    n = mask.shape[0]
    ii = jnp.arange(n, dtype=jnp.int32)
    first = jnp.min(jnp.where(mask, ii, _BIG))
    asum = jnp.sum(jnp.where(mask, ii + 1, 0)).astype(jnp.int32)
    return first, asum
