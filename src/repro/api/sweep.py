"""Generic multi-axis scenario sweeps (DESIGN.md §12.2).

``sweep(scenario, axes={...})`` expands a cartesian grid of dotted-path
axes over a base :class:`Scenario` and runs every point with as few
compiled executables as possible:

1. every grid point becomes a scenario via ``Scenario.with_``;
2. points are partitioned into *static buckets* — everything that changes
   compiled shapes (topology, trace shape, capacity, ``max_events``,
   multicluster settings, and ``total_nodes`` when a topology pins the
   machine) keys the bucket;
3. within a bucket the remaining axes (``policy``, ``alloc``,
   ``contention``, ``total_nodes``, ``trace.seed``) are *data*: job tables
   are stacked (workflow dependency edge lists included — a DAG's *shape*
   is static but its edges are ordinary vmap leaves; ``stack_jobsets`` pads
   ragged edge counts to one shape), scalar knobs become i32[B] arrays,
   contention pytrees are leaf-stacked, and ONE ``vmap``-ped executable
   runs the whole bucket — optionally sharded over a 1-D device mesh.
   When every point in a bucket shares one ``policy`` (and, with a
   machine, one ``alloc``) the shared value is passed *statically* so the
   batched executable gets the engine's trace-time specialization —
   including the §14/§18 batched scheduling passes; a mixed policy axis
   keeps the fully-dynamic path, whose backfill cost under vmap is pinned
   by the lazy full-sort guard in ``policies.backfill_shadow``
   (DESIGN.md §18);
4. the batched outputs are re-sliced into per-point :class:`Result`\\ s in
   grid order.

This replaces ``simulate_alloc_sweep`` (an alloc-only special case,
regression-tested bit-exact in ``tests/test_api.py``) and every
hand-rolled benchmark loop, and it expresses grids no legacy entry point
could — e.g. policy × alloc × contention in one call.
"""

from __future__ import annotations

import dataclasses
import functools
import itertools
from typing import Any, Dict, Iterator, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import alloc as _alloc
from repro.core import engine
from repro.core.jobs import JobSet
from repro.core.parallel import stack_jobsets

from repro.api.result import Result
from repro.api.run import build_jobset, run
from repro.api.scenario import Scenario


def _static_key(scenario: Scenario) -> tuple:
    """Hashable compile-bucket key: everything that forces a recompile.

    A failure model contributes only its padded capacity: the failure
    *arrays* are ordinary vmap leaves (materialization is host-side per
    scenario and no compiled shape depends on ``total_nodes`` without a
    topology), so MTBF / checkpoint / requeue — and ``total_nodes`` in
    scalar-counter mode — batch into one executable (DESIGN.md §15).
    """
    tn: Any = None
    if scenario.topology is not None or scenario.multicluster is not None:
        tn = scenario.total_nodes  # pins machine / cluster shapes
    return (
        tuple(t.static_key() for t in scenario.trace_specs()),
        scenario.topology,
        tn,
        scenario.multicluster,
        scenario.capacity,
        scenario.max_events,
        None if scenario.failures is None else scenario.failures.static_key(),
        # the width-range / mode / tick-capacity shapes; curve kind and
        # parameters are plan data (vmap leaves), so a speedup-curve grid
        # stays in one bucket (DESIGN.md §17)
        None if scenario.malleable is None
        else scenario.malleable.static_key(),
    )


@dataclasses.dataclass
class SweepResult:
    """Grid-ordered sweep outcome.

    ``points[i]`` is the axis-value dict of grid point *i* and
    ``results[i]`` its :class:`Result`; iteration yields ``(point,
    result)`` pairs.  ``summaries()`` flattens to a list of plain dicts
    (axis values + scalar metrics) ready for CSV emission, and
    ``stack(field)`` restacks one per-job array across the whole grid.
    ``n_compiles`` reports how many static buckets (≈ executables) the
    sweep needed.
    """

    axes: Dict[str, List[Any]]
    points: List[Dict[str, Any]]
    results: List[Result]
    n_compiles: int

    def __len__(self) -> int:
        return len(self.points)

    def __iter__(self) -> Iterator[Tuple[Dict[str, Any], Result]]:
        return iter(zip(self.points, self.results))

    def __getitem__(self, i: int) -> Result:
        return self.results[i]

    def get(self, **coords) -> Result:
        """The unique result whose point matches every given axis value."""
        hits = [r for p, r in self if all(p[k] == v for k, v in coords.items())]
        if len(hits) != 1:
            raise KeyError(f"{coords} matches {len(hits)} grid points")
        return hits[0]

    def summaries(self) -> List[Dict[str, Any]]:
        return [{**p, **r.summary()} for p, r in self]

    def stack(self, field: str) -> np.ndarray:
        return np.stack([r.to_np()[field] for r in self.results])


def sweep(scenario: Scenario, axes: Dict[str, Sequence[Any]], *,
          mesh: Optional[Mesh] = None) -> SweepResult:
    """Run the cartesian grid of ``axes`` over ``scenario`` (module doc).

    ``axes`` maps dotted scenario paths to value sequences, e.g.::

        sweep(s, axes={"policy": ("fcfs", "backfill"),
                       "alloc": ("simple", "topo"),
                       "contention": (None, (1, 5))})

    With ``mesh`` (1-D device mesh) each batched bucket is padded to the
    device count and sharded, devices advancing their grid shards fully
    independently.
    """
    axes = {k: list(v) for k, v in axes.items()}
    if not axes:
        return SweepResult(axes={}, points=[{}], results=[run(scenario)],
                           n_compiles=1)
    names = list(axes)
    points = [dict(zip(names, combo))
              for combo in itertools.product(*axes.values())]

    buckets: Dict[tuple, List[int]] = {}
    scenarios: List[Scenario] = []
    for i, point in enumerate(points):
        scn = scenario.with_(**point)
        scenarios.append(scn)
        buckets.setdefault(_static_key(scn), []).append(i)

    results: List[Optional[Result]] = [None] * len(points)
    for indices in buckets.values():
        bucket = [scenarios[i] for i in indices]
        if bucket[0].multicluster is not None:
            # every multicluster knob is static: one executable per point
            for i, scn in zip(indices, bucket):
                results[i] = run(scn)
        else:
            for i, res in zip(indices, _run_bucket(bucket, mesh)):
                results[i] = res
    return SweepResult(axes=axes, points=points, results=results,
                       n_compiles=len(buckets))


# ---------------------------------------------------------------------------
# one compiled executable per static bucket
# ---------------------------------------------------------------------------

# The batched runners are cached at module level so jit's executable cache
# (keyed on function identity + argument shapes) survives across sweep()
# calls: re-running the same grid costs milliseconds, not a recompile.  The
# machine is a runtime pytree argument, so one cached function serves every
# topology of a given shape; distinct shapes retrace automatically.


# ---------------------------------------------------------------------------
# public cache statistics (DESIGN.md §20)
# ---------------------------------------------------------------------------

# The what-if query service (repro.service) promises that repeated queries
# against one scenario bucket pay the XLA compile exactly once.  That
# contract needs to be *assertable*, so every `_run_bucket` execution is
# logged against its compile signature — the `_bucket_fn` cache key plus
# the batched argument treedef and leaf shapes/dtypes, i.e. exactly what
# determines whether jit reuses an executable or compiles a new one.  A
# signature seen before counts as a `hit` (warm), a new one as a `compile`
# (cold).  Stats cover the batched bucket runners only: `sweep(s, axes={})`
# degenerates to `run()` and multicluster buckets run point-wise, neither
# of which goes through the shared executable cache.

_CACHE_LOG = {"compiles": 0, "hits": 0}
_SEEN_SIGNATURES: set = set()


@dataclasses.dataclass(frozen=True)
class SweepCacheStats:
    """Warm-vs-cold executable counters for the shared sweep bucket cache.

    ``compiles`` counts bucket executions whose compile signature had not
    been seen since the last ``reset_cache_stats(clear=True)`` (cold path:
    trace + XLA compile); ``hits`` counts executions that reused a known
    signature (warm path: milliseconds).  ``entries`` is the number of
    distinct live signatures.
    """

    compiles: int
    hits: int
    entries: int


def cache_stats() -> SweepCacheStats:
    """Current warm-vs-cold counters for the sweep executable cache."""
    return SweepCacheStats(compiles=_CACHE_LOG["compiles"],
                           hits=_CACHE_LOG["hits"],
                           entries=len(_SEEN_SIGNATURES))


def reset_cache_stats(*, clear: bool = False) -> None:
    """Zero the warm/cold counters.

    With ``clear=False`` (default) the cached bucket runners — and the
    signature set that marks them warm — survive, so subsequent reuse still
    counts as hits; this is how a long-running service zeroes per-query
    deltas.  ``clear=True`` additionally drops the cached runner functions
    (``_bucket_fn.cache_clear()``) and the signature set, so the next query
    genuinely recompiles — the cold-path fixture for benchmarks and tests.
    """
    _CACHE_LOG["compiles"] = 0
    _CACHE_LOG["hits"] = 0
    if clear:
        _SEEN_SIGNATURES.clear()
        _bucket_fn.cache_clear()


def _log_bucket_execution(fn_key: tuple, args: tuple, machine) -> None:
    leaves, treedef = jax.tree.flatten((args, machine))
    sig = (fn_key, str(treedef),
           tuple((tuple(np.shape(leaf)),
                  np.dtype(getattr(leaf, "dtype",
                                   np.asarray(leaf).dtype)).str)
                 for leaf in leaves))
    if sig in _SEEN_SIGNATURES:
        _CACHE_LOG["hits"] += 1
    else:
        _SEEN_SIGNATURES.add(sig)
        _CACHE_LOG["compiles"] += 1


@functools.lru_cache(maxsize=None)
def _bucket_fn(with_alloc: bool, with_fail: bool, with_svc: bool,
               with_mal: bool, max_events: Optional[int],
               mesh: Optional[Mesh], axis: Optional[str],
               static_policy: Optional[int] = None,
               static_alloc: Optional[int] = None):
    # one generic batched runner: the optional subsystem args ride behind
    # (jobs, policy, total_nodes) in a fixed order — alloc pair, fail ctx,
    # svc ctx, mal ctx — and the machine (a non-batched pytree) comes last.
    # A bucket whose points all share one policy (or alloc) passes it here
    # as a Python int instead of a batched leaf: the engine then resolves
    # its static hints at trace time and the whole bucket runs the
    # specialized executable, batched scheduling passes included.
    def fn(*args):
        if with_alloc:
            *batched, machine = args
        else:
            batched, machine = args, None

        def one(*leaves):
            it = iter(leaves)
            j = next(it)
            p = static_policy if static_policy is not None else next(it)
            t = next(it)
            kw = {}
            if with_alloc:
                kw["alloc"] = (static_alloc if static_alloc is not None
                               else next(it))
                kw["contention"] = next(it)
            if with_fail:
                kw["failures"] = next(it)
            if with_svc:
                kw["service"] = next(it)
            if with_mal:
                kw["malleable"] = next(it)
            return engine.simulate(j, p, t, machine=machine,
                                   max_events=max_events, **kw)

        return jax.vmap(one)(*batched)

    if mesh is None:
        return jax.jit(fn)
    # a single prefix sharding applies the batch-axis partition to every
    # output leaf (all leaves carry the leading B dim after vmap)
    return jax.jit(fn, out_shardings=NamedSharding(mesh, P(axis)))


def _run_bucket(bucket: List[Scenario], mesh: Optional[Mesh]) -> List[Result]:
    """vmap-batch all scenarios of one static bucket (single-cluster only)."""
    base = bucket[0]
    machine = base.topology.build() if base.topology is not None else None
    max_events = base.max_events

    jobs_cache: Dict[tuple, JobSet] = {}
    jobsets = []
    for scn in bucket:
        spec = scn.trace_specs()[0]
        # key on the full spec (all specs are hashable; ArrayTrace by
        # identity): two points sharing a static bucket may still differ
        # in trace *data* — seed, arrival rate, class mix — and must not
        # collide onto one job table
        key = (spec, int(scn.total_nodes))
        if key not in jobs_cache:
            jobs_cache[key] = build_jobset(scn)
        jobsets.append(jobs_cache[key])

    B = len(bucket)
    # a policy (or alloc) uniform across the bucket is hoisted out of the
    # batched leaves and baked into the executable as a static hint — this
    # is what routes a backfill sweep axis onto the §18 batched pass
    pol_ids = [engine.policies_id(s.policy) for s in bucket]
    static_pol: Optional[int] = pol_ids[0] if len(set(pol_ids)) == 1 else None
    pol_b = jnp.asarray(pol_ids, dtype=jnp.int32)
    tn_b = jnp.asarray([int(s.total_nodes) for s in bucket], dtype=jnp.int32)

    pad = 0
    if mesh is not None:
        D = int(np.prod([mesh.shape[a] for a in mesh.axis_names]))
        pad = (-B) % D
        jobsets += [jobsets[-1]] * pad
        pol_b = jnp.concatenate([pol_b, jnp.repeat(pol_b[-1:], pad)])
        tn_b = jnp.concatenate([tn_b, jnp.repeat(tn_b[-1:], pad)])
    jobs_b = stack_jobsets(jobsets)

    pol_args = () if static_pol is not None else (pol_b,)
    static_alloc: Optional[int] = None
    if machine is None:
        args = (jobs_b, *pol_args, tn_b)
    else:
        alloc_ids = [
            _alloc.canonical_id(s.alloc if s.alloc is not None else "simple")
            for s in bucket]
        if len(set(alloc_ids)) == 1:
            static_alloc = alloc_ids[0]
        alloc_b = jnp.asarray(alloc_ids + [0] * pad, dtype=jnp.int32)
        alloc_args = () if static_alloc is not None else (alloc_b,)
        con_b = jax.tree.map(
            lambda *xs: jnp.stack(xs),
            *([_alloc.Contention.canonical(s.contention) for s in bucket]
              + [_alloc.Contention.off()] * pad))
        args = (jobs_b, *pol_args, tn_b, *alloc_args, con_b)

    with_fail = base.failures is not None
    if with_fail:
        # per-point materialized streams stack into ordinary vmap leaves
        # (uniform shapes: max_failures is part of the static bucket key)
        from repro.reliability import make_fail_ctx

        fctxs = [make_fail_ctx(s.failures, n_nodes=int(s.total_nodes))
                 for s in bucket]
        fctxs += [fctxs[-1]] * pad
        args = args + (jax.tree.map(lambda *xs: jnp.stack(xs), *fctxs),)

    with_svc = hasattr(base.trace_specs()[0], "plan")
    if with_svc:
        # materialized serving plans stack into ordinary vmap leaves
        # (uniform shapes: max_jobs / max_ticks key the static bucket), so
        # a rate × mix × threshold grid is ONE executable (DESIGN.md §16)
        from repro.serving import make_svc_ctx

        sctxs = [make_svc_ctx(s.trace_specs()[0].plan(),
                              n_nodes=int(s.total_nodes)) for s in bucket]
        sctxs += [sctxs[-1]] * pad
        args = args + (jax.tree.map(lambda *xs: jnp.stack(xs), *sctxs),)

    with_mal = base.malleable is not None
    if with_mal:
        # materialized width/dilation tables stack into ordinary vmap
        # leaves (uniform shapes: the width range and tick capacity key
        # the static bucket), so a speedup-curve / threshold grid is ONE
        # executable (DESIGN.md §17)
        from repro.api.run import _mal_plan
        from repro.malleable import make_mal_ctx

        mctxs = [make_mal_ctx(_mal_plan(s)) for s in bucket]
        mctxs += [mctxs[-1]] * pad
        args = args + (jax.tree.map(lambda *xs: jnp.stack(xs), *mctxs),)

    axis = mesh.axis_names[0] if mesh is not None else None
    fn_key = (machine is not None, with_fail, with_svc, with_mal,
              max_events, mesh, axis, static_pol, static_alloc)
    fn = _bucket_fn(*fn_key)
    _log_bucket_execution(fn_key, args, machine)
    if mesh is not None:
        shard = NamedSharding(mesh, P(axis))
        args = tuple(jax.device_put(a, shard) for a in args)
    batched = fn(*args) if machine is None else fn(*args, machine)

    return [
        Result(scenario=scn, backend="jax",
               raw=jax.tree.map(lambda a, i=i: a[i], batched), jobs=jobsets[i])
        for i, scn in enumerate(bucket)
    ]
