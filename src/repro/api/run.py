"""The single entry point: ``run(scenario) -> Result`` (DESIGN.md §12).

``run`` dispatches on the spec — scalar-counter engine, topology-aware
allocation engine, or the conservative-window multicluster engine — and
always returns the unified :class:`repro.api.Result`.  ``run_ref`` drives
the host reference simulator (CQsim analogue) from the *same* spec, so

    run(s).matches(run_ref(s))

is the project's cross-engine validation predicate in one line.
"""

from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

from repro import alloc as _alloc
from repro.core import engine
from repro.core.jobs import JobSet, POLICY_NAMES, make_jobset
from repro.core.parallel import simulate_multicluster, stack_jobsets

from repro.api.result import Result
from repro.api.scenario import Scenario


def _policy_name(policy) -> str:
    if isinstance(policy, str):
        return policy.lower()
    return POLICY_NAMES[int(policy)]


def build_jobset(scenario: Scenario, *, cluster: int = 0,
                 capacity: Optional[int] = None) -> JobSet:
    """Materialize one cluster's trace spec into a device ``JobSet``."""
    spec = scenario.trace_specs()[cluster]
    total_nodes = scenario.nodes_per_cluster()[cluster]
    trace = spec.materialize()
    if capacity is None:
        capacity = scenario.capacity
    if capacity is None:
        # ServiceTrace pads to max_jobs so the deadline/class columns stay
        # row-aligned with the job table across every rate point
        capacity = getattr(spec, "pad_capacity", None)
    return make_jobset(
        trace["submit"], trace["runtime"], trace["nodes"],
        trace.get("estimate"), trace.get("priority"),
        deps=trace.get("deps"),
        capacity=capacity,
        total_nodes=total_nodes,
    )


def _machine(scenario: Scenario):
    return scenario.topology.build() if scenario.topology is not None else None


def _failure_trace(scenario: Scenario):
    """The ONE materialized failure trace both engines consume (cached by
    the model's lru, so ``run`` and ``run_ref`` see identical arrays)."""
    if scenario.failures is None:
        return None
    return scenario.failures.materialize(int(scenario.total_nodes))


def _service_plan(scenario: Scenario):
    """The ONE materialized serving plan both engines consume (cached by
    the spec's lru, so ``run`` and ``run_ref`` see identical arrays)."""
    spec = scenario.trace_specs()[0]
    return spec.plan() if hasattr(spec, "plan") else None


def _mal_plan(scenario: Scenario):
    """The ONE materialized malleable plan both engines consume.

    ``materialize_plan`` normalizes and (submit, id)-sorts the trace with
    the same rules as ``make_jobset``, so the plan's dur/nref rows align
    with the job table rows in BOTH engines; the model-level lru keeps
    ``run`` and ``run_ref`` on identical arrays."""
    if scenario.malleable is None:
        return None
    from repro.malleable import materialize_plan

    spec = scenario.trace_specs()[0]
    capacity = scenario.capacity
    if capacity is None:
        capacity = getattr(spec, "pad_capacity", None)
    return materialize_plan(scenario.malleable, spec.materialize(),
                            total_nodes=int(scenario.total_nodes),
                            capacity=capacity)


def run(scenario: Scenario) -> Result:
    """Run one scenario on the JAX engine and return a unified ``Result``."""
    if scenario.multicluster is not None:
        return _run_multicluster(scenario)
    jobs = build_jobset(scenario)
    res = engine.simulate(
        jobs,
        engine.policies_id(scenario.policy),
        int(scenario.total_nodes),
        machine=_machine(scenario),
        alloc=scenario.alloc,
        contention=scenario.contention,
        failures=_failure_trace(scenario),
        service=_service_plan(scenario),
        malleable=_mal_plan(scenario),
        max_events=scenario.max_events,
    )
    return Result(scenario=scenario, backend="jax", raw=res, jobs=jobs)


def run_ref(scenario: Scenario) -> Result:
    """Run the SAME spec on the host reference simulator (bit-exact twin)."""
    from repro.refsim import simulate_reference

    if scenario.multicluster is not None:
        raise ValueError(
            "the reference simulator has no multicluster mode; validate the "
            "single-cluster scenario per cluster instead")
    spec = scenario.trace_specs()[0]
    machine = _machine(scenario)
    alloc_name = ("simple" if scenario.alloc is None
                  else _alloc.ALLOC_NAMES[_alloc.canonical_id(scenario.alloc)])
    out = simulate_reference(
        spec.materialize(),
        _policy_name(scenario.policy),
        total_nodes=int(scenario.total_nodes),
        machine=machine,
        alloc=alloc_name,
        contention=scenario.contention,
        failures=_failure_trace(scenario),
        service=_service_plan(scenario),
        malleable=_mal_plan(scenario),
    )
    return Result(scenario=scenario, backend="ref", raw=out)


# ---------------------------------------------------------------------------
# multicluster
# ---------------------------------------------------------------------------


def _multicluster_capacity(scenario: Scenario,
                           traces: Tuple[dict, ...]) -> int:
    """Uniform per-cluster row capacity: the largest cluster plus headroom
    for imported jobs (migration inserts rows; DESIGN.md §2)."""
    if scenario.capacity is not None:
        return scenario.capacity
    biggest = max(len(t["submit"]) for t in traces)
    mc = scenario.multicluster
    slack = 8 * mc.max_export if mc.migrate else 0
    return biggest + slack


def _default_horizon(traces, nodes_c, window: int) -> int:
    """Migration-round horizon when the spec leaves it None.

    Rounds must cover the *busy period*, not just the submission span — a
    congested cluster keeps a backlog (and load imbalance worth migrating)
    long after the last submit.  Per cluster we bound the drain time by
    aggregate demand, ``ceil(sum(nodes*runtime) / total_nodes)``, plus the
    longest single job; the horizon is the worst cluster's span + drain.
    Heuristic (head-of-line blocking can exceed it) — pass an explicit
    ``Multicluster(horizon=...)`` for precise control; events beyond the
    horizon still complete, they just stop triggering migration.
    """
    worst = 0
    for t, n in zip(traces, nodes_c):
        sub = np.asarray(t["submit"])
        rt = np.maximum(np.asarray(t["runtime"]), 1)
        est = np.asarray(t["estimate"]) if "estimate" in t else rt
        span = int(sub.max(initial=0) - sub.min(initial=0))
        nodes = np.clip(np.asarray(t["nodes"]), 1, n)
        drain = -(-int(np.sum(nodes * rt)) // int(n))   # ceil(work / machine)
        tail = max(drain, 2 * int(max(rt.max(initial=1), est.max(initial=1))))
        worst = max(worst, span + tail)
    return worst + 2 * window


def _run_multicluster(scenario: Scenario) -> Result:
    if scenario.topology is not None:
        raise ValueError(
            "multicluster scenarios run scalar-counter clusters; "
            "per-cluster topologies are not supported yet")
    mc = scenario.multicluster
    specs = scenario.trace_specs()
    nodes_c = scenario.nodes_per_cluster()
    traces = tuple(s.materialize() for s in specs)
    cap = _multicluster_capacity(scenario, traces)
    # clusters may mix DAG and plain traces: stack_jobsets pads the dep-free
    # tables (and ragged edge lists) with inert out-of-range edges to keep
    # the stacked pytree uniform
    jobsets = [
        make_jobset(t["submit"], t["runtime"], t["nodes"], t.get("estimate"),
                    t.get("priority"), deps=t.get("deps"), capacity=cap,
                    total_nodes=n)
        for t, n in zip(traces, nodes_c)
    ]
    horizon = mc.horizon
    if horizon is None:
        horizon = _default_horizon(traces, nodes_c, int(mc.window))
    res = simulate_multicluster(
        stack_jobsets(jobsets),
        engine.policies_id(scenario.policy),
        np.asarray(nodes_c, dtype=np.int32),
        window=int(mc.window),
        horizon=horizon,
        migrate=mc.migrate,
        max_export=mc.max_export,
        latency=mc.latency,
        load_imbalance_threshold=mc.load_imbalance_threshold,
        max_events=scenario.max_events,
    )
    return Result(scenario=scenario, backend="multicluster", raw=res)
