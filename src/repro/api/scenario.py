"""Declarative experiment specs (DESIGN.md §12.1).

A :class:`Scenario` is a frozen, host-side description of ONE simulation:
where the jobs come from (`trace` — synthetic generators, SWF logs,
explicit arrays, or a :class:`WorkflowTrace` DAG scheduled onto the
cluster), what machine runs them (`total_nodes` plus an optional
:class:`Topology`), how they are scheduled (`policy`, `alloc`,
`contention`), and whether the run is partitioned into
conservatively-synchronized clusters (`multicluster`).  Specs carry no
device arrays — they are cheap to construct, compare, copy and sweep, and
the same spec drives both the JAX engine (``repro.api.run``) and the
host reference simulator (``repro.api.run_ref``) for bit-exact validation.

Sweepable fields split into two classes (DESIGN.md §12.2):

- *traced* — ``policy``, ``alloc``, ``contention``, ``total_nodes`` (when no
  topology pins the machine size) and ``trace.seed``: batched with ``vmap``,
  one executable serves every value;
- *static* — the topology, trace shape (``n_jobs``/source), ``capacity``,
  ``max_events`` and every multicluster setting: each distinct combination
  compiles its own executable.

``Scenario.with_(...)`` applies dotted-path overrides (``"trace.seed"``),
which is how ``repro.api.sweep`` expands an axis grid into scenario points.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple, Union

import numpy as np

from repro import alloc as _alloc
from repro.core.jobs import INF_TIME
from repro.malleable import MalleableModel
from repro.reliability import FailureModel
from repro.serving import ServiceTrace
from repro.traces import das2_like, load_swf, sdsc_sp2_like, synthetic_trace
from repro.traces import workflows as _workflows
from repro.traces.workflows import workflow_to_trace

# ---------------------------------------------------------------------------
# trace sources
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SyntheticTrace:
    """Deterministic synthetic workload (``repro.traces.synthetic``).

    ``kind`` selects the generator: ``"generic"`` (``synthetic_trace``),
    ``"das2"`` or ``"sdsc_sp2"``.  ``params`` are extra keyword arguments
    for the generator as a tuple of (name, value) pairs — a tuple so the
    spec stays hashable (specs key compile-bucket caches).  ``congest``
    divides submit times by an integer factor to densify arrivals (the
    benchmarks' standard trick to make policies diverge).
    """

    n_jobs: int = 1000
    seed: int = 0
    kind: str = "generic"
    params: Tuple[Tuple[str, Any], ...] = ()
    congest: int = 1

    _GENERATORS = {"generic": synthetic_trace, "das2": das2_like,
                   "sdsc_sp2": sdsc_sp2_like}

    def materialize(self) -> Dict[str, np.ndarray]:
        try:
            gen = self._GENERATORS[self.kind]
        except KeyError:
            raise ValueError(
                f"unknown synthetic trace kind {self.kind!r}; "
                f"known: {sorted(self._GENERATORS)}") from None
        trace = gen(self.n_jobs, seed=self.seed, **dict(self.params))
        if self.congest != 1:
            trace["submit"] = trace["submit"] // int(self.congest)
        return trace

    def static_key(self):
        """Everything except ``seed`` — seed is trace *data*, not shape."""
        return ("synthetic", self.n_jobs, self.kind, self.params, self.congest)

    @property
    def n_rows(self) -> int:
        return self.n_jobs


@dataclasses.dataclass(frozen=True)
class SwfTrace:
    """A Standard Workload Format log on disk (optionally gzipped).

    ``strict=True`` makes ingestion raise on the first malformed line
    instead of quarantining it (the lenient default counts bad lines in
    the loader report and keeps going — see ``repro.traces.SwfReport``).
    This spec feeds the one-shot engine, so it keeps the int32 horizon
    guard; full-archive logs that overflow it go through ``repro.replay``
    instead (int64 host clocks, windowed rounds).
    """

    path: str
    max_jobs: Optional[int] = None
    strict: bool = False

    def materialize(self) -> Dict[str, np.ndarray]:
        trace, _report = load_swf(self.path, max_jobs=self.max_jobs,
                                  strict=self.strict)
        # int32 clock-overflow guard (mirrors ServiceTrace.materialize):
        # the engine runs the clock in int32, so the span of the log plus
        # the largest completion must stay below INF_TIME — a silent
        # wraparound would corrupt every downstream metric
        sub = np.asarray(trace["submit"], dtype=np.int64)
        if len(sub):
            run = np.asarray(trace["runtime"], dtype=np.int64)
            est = np.asarray(trace.get("estimate", run), dtype=np.int64)
            top = int(sub.max() - sub.min()) + 2 * int(
                max(run.max(initial=1), est.max(initial=1)))
            if top >= int(INF_TIME):
                raise ValueError(
                    f"SWF trace {self.path!r} overflows int32 clock range: "
                    f"submit span + 2*max runtime = {top} >= {int(INF_TIME)} "
                    "(INF_TIME); trim the log with max_jobs= or rescale "
                    "its time unit")
        return trace

    def static_key(self):
        return ("swf", self.path, self.max_jobs)

    @property
    def n_rows(self) -> Optional[int]:
        return None  # unknown until loaded


@dataclasses.dataclass(frozen=True)
class WorkflowTrace:
    """A workflow DAG scheduled *onto the cluster* (paper §3, DESIGN.md §13).

    ``kind`` selects the ``repro.traces.workflows`` generator: ``"montage"``,
    ``"galactic"`` (Galactic Plane: K montage tiles + merge), ``"sipht"``,
    ``"chain"``, ``"fork_join"`` or ``"random"`` (random layered DAG).
    ``params`` are generator keyword arguments as (name, value) pairs —
    e.g. ``(("width", 16),)`` or ``(("tiles", 4), ("width", 8))``.  The DAG
    lowers through ``workflow_to_trace``: tasks become jobs (cpu requirement
    -> node count), edges become the ``JobSet.dep_dst``/``dep_src`` edge
    list (O(E) per vmap leaf, DESIGN.md §14), and every task shares one
    ``submit`` time so release order is purely dependency-driven.

    The DAG *shape* (kind/params/submit/priority) is a static recompile
    axis; ``seed`` only perturbs task durations and random edges, so it is
    traced sweep data exactly like ``SyntheticTrace.seed`` (a seed that
    changes the edge *count* is fine — ``stack_jobsets`` pads ragged edge
    lists to one shape inside the sweep bucket).
    ``priority="cpath"`` attaches critical-path priorities for ``preempt``.
    """

    kind: str = "montage"
    seed: int = 0
    params: Tuple[Tuple[str, Any], ...] = ()
    submit: int = 0
    priority: Optional[str] = None

    _GENERATORS = {
        "montage": _workflows.montage_like,
        "galactic": _workflows.galactic_like,
        "sipht": _workflows.sipht_like,
        "chain": _workflows.chain,
        "fork_join": _workflows.fork_join,
        "random": _workflows.random_layered,
    }
    _SEEDLESS = frozenset({"chain"})

    def materialize(self) -> Dict[str, np.ndarray]:
        # shallow copy of the cached dict: the spec is frozen/hashable, so
        # sweep grids and n_rows don't regenerate (and re-cycle-check) the
        # same DAG per grid point
        return dict(_materialize_workflow(self))

    def static_key(self):
        """Everything except ``seed`` — the DAG's task count and edge-matrix
        shape are fixed by (kind, params), so seed is trace *data*."""
        return ("workflow", self.kind, self.params, self.submit,
                self.priority)

    @property
    def n_rows(self) -> int:
        return len(self.materialize()["submit"])


@functools.lru_cache(maxsize=128)
def _materialize_workflow(spec: WorkflowTrace) -> Dict[str, np.ndarray]:
    try:
        gen = spec._GENERATORS[spec.kind]
    except KeyError:
        raise ValueError(
            f"unknown workflow kind {spec.kind!r}; "
            f"known: {sorted(spec._GENERATORS)}") from None
    kwargs = dict(spec.params)
    if spec.kind not in spec._SEEDLESS:
        kwargs["seed"] = spec.seed
    return workflow_to_trace(gen(**kwargs), submit=spec.submit,
                             priority=spec.priority)


@dataclasses.dataclass(frozen=True, eq=False)
class ArrayTrace:
    """Explicit host arrays — the escape hatch for custom workloads.

    ``eq=False`` keeps the dataclass hashable by identity: two ArrayTraces
    are the "same trace" for compile-bucketing iff they are the same object.
    ``deps`` (optional (job, dep) pairs or dense bool matrix, input order)
    makes the jobs a workflow (DESIGN.md §13).
    """

    submit: Any
    runtime: Any
    nodes: Any
    estimate: Any = None
    priority: Any = None
    deps: Any = None

    @classmethod
    def from_dict(cls, trace: Dict[str, Any]) -> "ArrayTrace":
        return cls(submit=trace["submit"], runtime=trace["runtime"],
                   nodes=trace["nodes"], estimate=trace.get("estimate"),
                   priority=trace.get("priority"), deps=trace.get("deps"))

    def materialize(self) -> Dict[str, np.ndarray]:
        out = {"submit": np.asarray(self.submit),
               "runtime": np.asarray(self.runtime),
               "nodes": np.asarray(self.nodes)}
        if self.estimate is not None:
            out["estimate"] = np.asarray(self.estimate)
        if self.priority is not None:
            out["priority"] = np.asarray(self.priority)
        if self.deps is not None:
            out["deps"] = self.deps
        return out

    def static_key(self):
        return ("arrays", id(self))

    @property
    def n_rows(self) -> int:
        return len(np.asarray(self.submit))


@dataclasses.dataclass(frozen=True)
class InjectedTrace:
    """A base trace spec plus appended *candidate* jobs (DESIGN.md §20).

    The what-if service's "where should this job run" queries need to add
    a hypothetical job to an existing workload without tearing the sweep
    compile cache: the injected job *values* are trace data (vmap leaves),
    and only the injected job *count* changes compiled shapes.
    ``static_key`` is therefore ``(base static key, len(jobs))`` — every
    placement query against the same base workload with the same number of
    candidates reuses one cached executable.

    ``jobs`` is a tuple of ``(submit, runtime, nodes, estimate, priority)``
    tuples (``estimate``/``priority`` may be None); rows are appended after
    the base trace in input order, so with equal submit times the candidate
    sorts *behind* every incumbent — a what-if query never jumps the queue.
    Base dependency edges (pair lists or dense matrices) are preserved;
    injected jobs are always dependency-free.
    """

    base: Any                    # a TraceSpec (not ServiceTrace)
    jobs: Tuple[Tuple[Optional[int], ...], ...]

    def __post_init__(self):
        base = as_trace_spec(self.base)
        if isinstance(base, ServiceTrace):
            raise ValueError(
                "InjectedTrace cannot wrap a ServiceTrace: open-arrival "
                "plans carry their own padded job table (inject the "
                "candidate through ServiceTrace.arrivals instead)")
        object.__setattr__(self, "base", base)
        norm = []
        for j in self.jobs:
            j = tuple(j) + (None,) * (5 - len(j))
            if len(j) != 5:
                raise ValueError(
                    "injected jobs are (submit, runtime, nodes[, estimate"
                    f"[, priority]]) tuples; got {j!r}")
            submit, runtime, nodes = (int(j[0]), int(j[1]), int(j[2]))
            if runtime < 1 or nodes < 1:
                raise ValueError(
                    f"injected job needs runtime >= 1 and nodes >= 1; "
                    f"got runtime={runtime}, nodes={nodes}")
            if submit < 0:
                raise ValueError(
                    f"injected job submit must be >= 0, got {submit} "
                    "(make_jobset re-zeroes the trace on its minimum "
                    "submit; an earlier candidate would shift every "
                    "incumbent timestamp)")
            est = None if j[3] is None else int(j[3])
            pri = None if j[4] is None else int(j[4])
            norm.append((submit, runtime, nodes, est, pri))
        if not norm:
            raise ValueError("InjectedTrace needs at least one injected job")
        object.__setattr__(self, "jobs", tuple(norm))

    def materialize(self) -> Dict[str, np.ndarray]:
        t = dict(self.base.materialize())
        k = len(self.jobs)
        sub = np.asarray(t["submit"], dtype=np.int64)
        run = np.asarray(t["runtime"], dtype=np.int64)
        j_sub = np.asarray([j[0] for j in self.jobs], dtype=np.int64)
        j_run = np.asarray([j[1] for j in self.jobs], dtype=np.int64)
        j_nod = np.asarray([j[2] for j in self.jobs], dtype=np.int64)
        out = {
            "submit": np.concatenate([sub, j_sub]),
            "runtime": np.concatenate([run, j_run]),
            "nodes": np.concatenate(
                [np.asarray(t["nodes"], dtype=np.int64), j_nod]),
        }
        # optional columns exist iff the base carries them OR an injected
        # job sets them; the base default mirrors make_jobset (estimate ==
        # runtime, priority == 0)
        j_est = [j[3] for j in self.jobs]
        if "estimate" in t or any(e is not None for e in j_est):
            base_est = np.asarray(t.get("estimate", run), dtype=np.int64)
            inj = np.asarray(
                [e if e is not None else r
                 for e, r in zip(j_est, j_run)], dtype=np.int64)
            out["estimate"] = np.concatenate([base_est, inj])
        j_pri = [j[4] for j in self.jobs]
        if "priority" in t or any(p is not None for p in j_pri):
            base_pri = np.asarray(
                t.get("priority", np.zeros(len(sub))), dtype=np.int64)
            inj = np.asarray([p if p is not None else 0 for p in j_pri],
                             dtype=np.int64)
            out["priority"] = np.concatenate([base_pri, inj])
        deps = t.get("deps")
        if deps is not None:
            dm = np.asarray(deps)
            if dm.ndim == 2 and dm.dtype == bool:
                # dense matrix: pad k all-False rows/cols (injected jobs
                # neither depend on nor release anything)
                n = dm.shape[0]
                padded = np.zeros((n + k, n + k), dtype=bool)
                padded[:n, :n] = dm
                out["deps"] = padded
            else:
                # (job, dependency) pairs index the base's input order,
                # which appending at the tail leaves untouched
                out["deps"] = deps
        return out

    def static_key(self):
        """Base key + injected COUNT: the candidate jobs' values are vmap
        data, only how many rows they add is a compiled shape."""
        return ("inject", self.base.static_key(), len(self.jobs))

    @property
    def n_rows(self) -> Optional[int]:
        base = self.base.n_rows
        return None if base is None else base + len(self.jobs)


TraceSpec = Union[SyntheticTrace, SwfTrace, ArrayTrace, WorkflowTrace,
                  ServiceTrace, InjectedTrace]


def as_trace_spec(trace) -> TraceSpec:
    """Accept a spec, a plain dict-of-arrays, or an .swf path string."""
    if isinstance(trace, (SyntheticTrace, SwfTrace, ArrayTrace,
                          WorkflowTrace, ServiceTrace, InjectedTrace)):
        return trace
    if isinstance(trace, dict):
        return ArrayTrace.from_dict(trace)
    if isinstance(trace, str):
        return SwfTrace(trace)
    raise TypeError(
        f"trace must be a trace spec, dict of arrays, or .swf path; "
        f"got {type(trace).__name__}")


# ---------------------------------------------------------------------------
# machine topology
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Topology:
    """Declarative machine shape; builds a ``repro.alloc.Machine`` on demand.

    ``kind`` ∈ {"linear", "mesh2d", "dragonfly"}; ``shape`` is the builder's
    positional arguments: (n_nodes, group_size), (rows, cols), or
    (n_groups, nodes_per_group) respectively.
    """

    kind: str
    shape: Tuple[int, int]

    @classmethod
    def linear(cls, n_nodes: int, *, group_size: int = 8) -> "Topology":
        return cls("linear", (int(n_nodes), int(group_size)))

    @classmethod
    def mesh2d(cls, rows: int, cols: int) -> "Topology":
        return cls("mesh2d", (int(rows), int(cols)))

    @classmethod
    def dragonfly(cls, n_groups: int, nodes_per_group: int) -> "Topology":
        return cls("dragonfly", (int(n_groups), int(nodes_per_group)))

    @property
    def n_nodes(self) -> int:
        if self.kind == "linear":
            return self.shape[0]
        return self.shape[0] * self.shape[1]

    def build(self) -> _alloc.Machine:
        a, b = self.shape
        if self.kind == "linear":
            return _alloc.linear(a, group_size=b)
        if self.kind == "mesh2d":
            return _alloc.mesh2d(a, b)
        if self.kind == "dragonfly":
            return _alloc.dragonfly(a, b)
        raise ValueError(
            f"unknown topology kind {self.kind!r}; "
            "known: linear, mesh2d, dragonfly")


# ---------------------------------------------------------------------------
# multicluster settings
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Multicluster:
    """Conservative-window multi-cluster settings (DESIGN.md §2).

    When set on a :class:`Scenario`, ``trace`` must be a tuple of trace
    specs (one per cluster) and ``total_nodes`` is per-cluster (one int
    broadcast to all clusters, or a tuple).
    """

    window: int
    horizon: Optional[int] = None   # None: derived from max submit time
    migrate: bool = True
    max_export: int = 8
    latency: Optional[int] = None   # None: == window (minimum conservative)
    load_imbalance_threshold: float = 1.5


# ---------------------------------------------------------------------------
# the scenario itself
# ---------------------------------------------------------------------------

# dotted axis paths vmap-batched by repro.api.sweep; everything else forces
# a recompile bucket ("total_nodes" moves to static when a topology pins the
# machine size — see sweep._static_key).  Every FailureModel field except
# max_failures (the padded capacity, a compiled shape) is trace data: the
# materialized failure arrays are ordinary vmap leaves, so an MTBF /
# checkpoint / requeue grid compiles to ONE executable (DESIGN.md §15).
TRACED_AXES = ("policy", "alloc", "contention", "total_nodes", "trace.seed",
               "failures.mtbf", "failures.seed", "failures.mean_repair",
               "failures.requeue", "failures.checkpoint_interval",
               "failures.restart_overhead",
               # ServiceTrace (DESIGN.md §16): everything except max_jobs
               # and autoscale.max_ticks is trace data, so arrival-rate /
               # horizon / class-mix / autoscale-threshold sweeps compile
               # once per static bucket
               "trace.rate", "trace.horizon", "trace.classes",
               "trace.autoscale",
               # MalleableModel (DESIGN.md §17): the width range and mode
               # fix the dur-table / tick-stream shapes; the curve family
               # and its parameters, the resize cadence and the thresholds
               # are all plan data, so speedup-curve grids compile once
               "malleable.curve", "malleable.param", "malleable.table",
               "malleable.interval", "malleable.step",
               "malleable.shrink_threshold", "malleable.grow_threshold")


@dataclasses.dataclass(frozen=True)
class Scenario:
    """One fully-specified experiment (see module docstring).

    ``total_nodes=None`` with a ``topology`` defaults to the topology's node
    count.  ``alloc``/``contention`` require a ``topology`` (without one the
    engine runs in scalar-counter mode and would silently ignore them —
    ``run`` rejects the combination, mirroring the engine's own check).

    ``failures`` (a frozen ``repro.reliability.FailureModel``) switches on
    reliability-aware simulation (DESIGN.md §15); both engines consume the
    one materialized trace, and ``failures=None`` statically elides the
    whole subsystem.

    ``malleable`` (a frozen ``repro.malleable.MalleableModel``) switches on
    two-level resource management (DESIGN.md §17): moldable width choice at
    dispatch, optionally elastic grow/shrink at capacity ticks, and
    shrink-instead-of-requeue under node failures.  ``malleable=None``
    statically elides the whole subsystem.
    """

    trace: Union[TraceSpec, Dict[str, Any], str, Tuple[TraceSpec, ...]]
    total_nodes: Optional[Union[int, Tuple[int, ...]]] = None
    policy: Union[str, int] = "fcfs"
    topology: Optional[Topology] = None
    alloc: Optional[Union[str, int]] = None
    contention: Optional[Any] = None    # Contention | (num, den) | None
    multicluster: Optional[Multicluster] = None
    capacity: Optional[int] = None
    max_events: Optional[int] = None
    failures: Optional[FailureModel] = None
    malleable: Optional[MalleableModel] = None

    def __post_init__(self):
        if self.malleable is not None:
            if not isinstance(self.malleable, MalleableModel):
                raise TypeError(
                    "Scenario.malleable must be a repro.malleable."
                    f"MalleableModel, got {type(self.malleable).__name__} "
                    "(specs stay frozen/hashable; materialized "
                    "MalleablePlans belong to the engine call, not the "
                    "scenario)")
            if self.multicluster is not None:
                raise ValueError(
                    "malleable jobs are not supported in multicluster "
                    "scenarios yet; simulate the clusters individually")
            if self.contention is not None:
                raise ValueError(
                    "malleable jobs cannot be combined with contention "
                    "dilation: the speedup curve already rescales runtime "
                    "per width, and composing the two dilations is "
                    "undefined (DESIGN.md §17)")
            if self.policy == "preempt":
                raise ValueError(
                    "malleable jobs cannot be combined with the preempt "
                    "policy (width-aware preemption is an open item, "
                    "DESIGN.md §17)")
        if self.failures is not None:
            if not isinstance(self.failures, FailureModel):
                raise TypeError(
                    "Scenario.failures must be a repro.reliability."
                    f"FailureModel, got {type(self.failures).__name__} "
                    "(specs stay frozen/hashable; materialized FailureTraces "
                    "belong to the engine call, not the scenario)")
            if self.multicluster is not None:
                raise ValueError(
                    "failures are not supported in multicluster scenarios "
                    "yet; simulate the clusters individually")
        if self.multicluster is None:
            object.__setattr__(self, "trace", as_trace_spec(self.trace))
        else:
            traces = self.trace
            if not isinstance(traces, (tuple, list)):
                raise ValueError(
                    "multicluster scenarios take one trace spec per cluster "
                    "(a tuple); got a single trace")
            object.__setattr__(
                self, "trace", tuple(as_trace_spec(t) for t in traces))
            if any(isinstance(t, ServiceTrace) for t in self.trace):
                raise ValueError(
                    "ServiceTrace is not supported in multicluster "
                    "scenarios yet; serve each cluster individually")
        if isinstance(self.trace, ServiceTrace):
            if (self.failures is not None and self.topology is not None
                    and self.trace.autoscale is not None):
                raise ValueError(
                    "machine-mode failures cannot be combined with an "
                    "autoscaling ServiceTrace; drop topology=, failures=, "
                    "or autoscale (engine restriction, DESIGN.md §16)")
            if (self.capacity is not None
                    and int(self.capacity) != self.trace.max_jobs):
                raise ValueError(
                    f"capacity={self.capacity} disagrees with "
                    f"ServiceTrace.max_jobs={self.trace.max_jobs}; the "
                    "deadline/class columns are padded to max_jobs, so the "
                    "job table must share that shape")
        if self.topology is None and (self.alloc is not None
                                      or self.contention is not None):
            raise ValueError(
                "alloc/contention require topology=; without a Topology the "
                "simulation runs in scalar-counter mode and would silently "
                "ignore them")
        if self.total_nodes is None:
            if self.topology is None:
                raise ValueError(
                    "total_nodes is required when no topology is given")
            object.__setattr__(self, "total_nodes", self.topology.n_nodes)
        if self.topology is not None and self.multicluster is None \
                and int(self.total_nodes) != self.topology.n_nodes:
            raise ValueError(
                f"topology has {self.topology.n_nodes} nodes but "
                f"total_nodes={self.total_nodes}")

    # -- sweep support ------------------------------------------------------

    def with_(self, **overrides) -> "Scenario":
        """Functional update; keys may be dotted paths into sub-specs,
        e.g. ``with_(policy="sjf", **{"trace.seed": 3})``."""
        flat: Dict[str, Any] = {}
        nested: Dict[str, Dict[str, Any]] = {}
        for key, value in overrides.items():
            if "." in key:
                head, rest = key.split(".", 1)
                nested.setdefault(head, {})[rest] = value
            else:
                flat[key] = value
        for head, sub in nested.items():
            target = flat.get(head, getattr(self, head))
            if target is None:
                raise ValueError(f"cannot set {head}.{next(iter(sub))}: "
                                 f"scenario has no {head}")
            if isinstance(target, tuple):  # per-cluster trace specs
                target = tuple(dataclasses.replace(t, **sub) for t in target)
            else:
                target = dataclasses.replace(target, **sub)
            flat[head] = target
        return dataclasses.replace(self, **flat)

    def trace_specs(self) -> Tuple[TraceSpec, ...]:
        """Per-cluster tuple view of ``trace`` (length 1 without
        multicluster)."""
        return self.trace if isinstance(self.trace, tuple) else (self.trace,)

    def nodes_per_cluster(self) -> Tuple[int, ...]:
        """Per-cluster ``total_nodes`` tuple (length 1 without
        multicluster)."""
        n_clusters = len(self.trace_specs())
        tn = self.total_nodes
        if isinstance(tn, tuple):
            if len(tn) != n_clusters:
                raise ValueError(
                    f"total_nodes tuple has {len(tn)} entries for "
                    f"{n_clusters} clusters")
            return tuple(int(x) for x in tn)
        return (int(tn),) * n_clusters
