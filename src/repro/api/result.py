"""Unified result wrapper (DESIGN.md §12.3).

Before this layer the project had three divergent output shapes: the device
``SimResult`` pytree, ``simulate_np``'s dict-of-numpy, and
``multicluster_result_np``'s flattened per-cluster dict.  :class:`Result`
fronts all three: ``raw`` keeps whatever the backend produced, ``to_np()``
converts (lazily, cached) to the *one* canonical numpy schema —
``submit/runtime/nodes/start/finish/wait/valid/done/makespan/n_events``
plus the ``alloc_*``/``ev_*`` allocation fields when a topology was active —
and ``summary()`` derives the standard scalar metrics
(wait/makespan/utilization/fragmentation) via ``repro.core.metrics``.

Dependency-aware runs (DESIGN.md §13) add a ``ready`` column —
``max(submit, last dependency finish)`` — and ``wait`` is uniformly
``start - ready`` (== ``start - submit`` for dependency-free jobs), the
paper's Fig. 7 workflow wait metric.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import numpy as np

from repro.core import metrics
from repro.core.jobs import JobSet, SimResult

from repro.api.scenario import Scenario


@dataclasses.dataclass
class Result:
    """One simulation outcome, from any backend.

    ``backend`` ∈ {"jax", "ref", "multicluster"}.  ``raw`` is the backend's
    native object (``SimResult``, the reference simulator's numpy dict, or a
    ``MulticlusterResult``); ``jobs`` is the device job table for the JAX
    backends (None for "ref").
    """

    scenario: Scenario
    backend: str
    raw: Any
    jobs: Optional[JobSet] = None
    _np: Optional[Dict[str, np.ndarray]] = dataclasses.field(
        default=None, repr=False)

    # -- canonical numpy view ----------------------------------------------

    def to_np(self) -> Dict[str, np.ndarray]:
        """Canonical host-side result dict (cached)."""
        if self._np is None:
            self._np = self._materialize_np()
        return self._np

    def _materialize_np(self) -> Dict[str, np.ndarray]:
        if self.backend == "ref":
            return dict(self.raw)
        if self.backend == "multicluster":
            from repro.core.parallel import multicluster_result_np
            return multicluster_result_np(self.raw)
        return simresult_to_np(self.raw, self.jobs,
                               with_alloc=self.scenario.topology is not None,
                               service=self._service_plan())

    def _service_plan(self):
        spec = self.scenario.trace_specs()[0]
        return spec.plan() if hasattr(spec, "plan") else None

    def __getitem__(self, key: str) -> np.ndarray:
        return self.to_np()[key]

    # -- derived metrics ----------------------------------------------------

    def summary(self) -> Dict[str, float]:
        """Scalar metrics: n_jobs, wait stats, bounded slowdown, makespan,
        utilization, throughput — plus job-span/fragmentation scalars when
        the scenario carried a topology."""
        out = self.to_np()
        total = int(np.sum(self.scenario.nodes_per_cluster()))
        s = metrics.summary(out, total)
        if "ev_time" in out and "alloc_span" in out:
            s.update(metrics.alloc_summary(out))
        if "n_restarts" in out:
            s.update(metrics.reliability_summary(out))
        if "slo_met" in out:
            plan = self._service_plan()
            names = plan.class_names if plan is not None else None
            s.update(metrics.slo_summary(out, class_names=names,
                                         total_nodes=total))
        if "mal_width" in out:
            s.update(metrics.malleable_summary(out))
        return s

    @property
    def makespan(self) -> int:
        return int(self.to_np()["makespan"])

    def matches(self, other: "Result", *, node_maps: bool = False) -> bool:
        """Bit-exact start/finish (and optionally allocation-fingerprint)
        agreement with another result over the shorter table — the
        cross-engine validation predicate (DESIGN.md §9)."""
        a, b = self.to_np(), other.to_np()
        n = min(int(a["valid"].sum()), int(b["valid"].sum()))
        keys = ["start", "finish"]
        if node_maps:
            keys += ["alloc_first", "alloc_span", "alloc_sum"]
        return all(bool(np.array_equal(a[k][:n], b[k][:n])) for k in keys)


def simresult_to_np(res: SimResult, jobs: JobSet, *, with_alloc: bool,
                    service=None) -> Dict[str, np.ndarray]:
    """``SimResult`` + ``JobSet`` -> the canonical numpy dict (the schema
    ``simulate_np`` established; shared by every backend)."""
    out = {
        "submit": np.asarray(jobs.submit),
        "nodes": np.asarray(jobs.nodes),
        "runtime": np.asarray(jobs.runtime),
        "start": np.asarray(res.start),
        "finish": np.asarray(res.finish),
        "ready": np.asarray(res.ready),
        "wait": np.asarray(res.wait),
        "makespan": int(res.makespan),
        "n_events": int(res.n_events),
        "done": np.asarray(res.done),
        "valid": np.asarray(jobs.valid),
    }
    if with_alloc:
        n_ev = out["n_events"]
        out["alloc_first"] = np.asarray(res.alloc_first)
        out["alloc_span"] = np.asarray(res.alloc_span)
        out["alloc_sum"] = np.asarray(res.alloc_sum)
        out["ev_time"] = np.asarray(res.ev_time)[:n_ev]
        out["ev_free"] = np.asarray(res.ev_free)[:n_ev]
        out["ev_lfb"] = np.asarray(res.ev_lfb)[:n_ev]
    if res.rel is not None:
        out["n_restarts"] = np.asarray(res.rel.n_restarts)
        out["lost_work"] = np.asarray(res.rel.lost_work)
        out["aborted"] = np.asarray(res.rel.aborted)
    if res.mal is not None:
        # chosen/final width, reference width, resize count, node-second
        # ledger and dispatch-time dilated duration (DESIGN.md §17); rows
        # align with the job table like every other column
        out["mal_width"] = np.asarray(res.mal.width, dtype=np.int64)
        out["mal_nref"] = np.asarray(res.mal.nref, dtype=np.int64)
        out["mal_nresize"] = np.asarray(res.mal.n_resizes, dtype=np.int64)
        out["mal_node_s"] = np.asarray(res.mal.node_s, dtype=np.int64)
        out["mal_dur"] = np.asarray(res.mal.disp_dur, dtype=np.int64)
    if res.svc is not None:
        out["slo_met"] = np.asarray(res.svc.slo_met)
        out["deadline"] = np.asarray(res.svc.deadline)
        # capacity series: the engine logs the online level per consumed
        # tick (-1 = never consumed); the times come from the plan's tick
        # stream, which is how the refsim emits the same two columns
        cap = np.asarray(res.svc.cap_online)
        used = cap >= 0
        out["cap_online"] = cap[used].astype(np.int64)
        if service is not None:
            out["class_id"] = np.asarray(service.class_id, dtype=np.int64)
            out["cap_time"] = np.asarray(
                service.tick_time, dtype=np.int64)[used]
    return out
