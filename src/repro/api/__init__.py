"""Unified Scenario API (DESIGN.md §12): declarative experiment specs, one
``run()`` entry point, and generic multi-axis ``sweep()``.

    from repro import api

    scn = api.Scenario(
        trace=api.SyntheticTrace(n_jobs=500, seed=0, kind="sdsc_sp2"),
        total_nodes=128, policy="backfill",
    )
    res = api.run(scn)                      # -> api.Result
    assert res.matches(api.run_ref(scn))    # bit-exact vs reference sim

    grid = api.sweep(scn.with_(topology=api.Topology.dragonfly(16, 8)),
                     axes={"policy": ("fcfs", "backfill"),
                           "alloc": ("simple", "topo"),
                           "contention": (None, (1, 5))})
    for point, r in grid:
        print(point, r.summary()["makespan"])

New scenario axes are one-field additions to :class:`Scenario` — not new
``simulate_*`` entry points.
"""

from repro.api.result import Result, simresult_to_np
from repro.api.run import build_jobset, run, run_ref
from repro.api.scenario import (
    ArrayTrace, InjectedTrace, Multicluster, Scenario, SwfTrace,
    SyntheticTrace, Topology, TRACED_AXES, WorkflowTrace, as_trace_spec,
)
from repro.api.sweep import (
    SweepCacheStats, SweepResult, cache_stats, reset_cache_stats, sweep,
)
from repro.malleable import MalleableModel
from repro.reliability import FailureModel
from repro.serving import AutoscalePolicy, ServiceClass, ServiceTrace

__all__ = [
    "ArrayTrace", "AutoscalePolicy", "FailureModel", "InjectedTrace",
    "MalleableModel", "Multicluster", "Result", "Scenario", "ServiceClass",
    "ServiceTrace", "SweepCacheStats", "SweepResult", "SwfTrace",
    "SyntheticTrace", "Topology", "TRACED_AXES", "WorkflowTrace",
    "as_trace_spec", "build_jobset", "cache_stats", "reset_cache_stats",
    "run", "run_ref", "simresult_to_np", "sweep",
]
