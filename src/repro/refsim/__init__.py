"""CQsim-analogue reference simulator (pure Python, heap-based).

The paper validates its SST component against CQsim; we reproduce that
methodology by validating the JAX engine against this independently-written
event-driven simulator with identical pinned semantics (DESIGN.md §8).
It is also the asymptotically-efficient CPU path for million-job traces.
"""

from repro.refsim.sim import (  # noqa: F401
    ReferenceSimulator, replay_reference, simulate_reference,
)
# workflow reference imported lazily in repro.refsim.workflow
