"""Heap-based reference workflow simulator (Pegasus/Airflow-style engine).

Mirrors ``repro.core.workflow`` semantics exactly for validation: completions
advance the clock; ready = all deps DONE; policies ``fcfs`` (blocking on
priority order), ``fcfs_fit`` / ``cpath`` (work-conserving on priority).
"""

from __future__ import annotations

import heapq
from typing import Dict, List, Sequence, Tuple

import numpy as np


def simulate_workflow_reference(
    exec_time: Sequence[int],
    resources,
    dep_pairs: Sequence[Tuple[int, int]],
    pools,
    policy: str = "fcfs",
    priority=None,
) -> Dict[str, np.ndarray]:
    exec_time = np.maximum(np.asarray(exec_time, dtype=np.int64), 1)
    resources = np.asarray(resources, dtype=np.int64)
    if resources.ndim == 1:
        resources = resources[:, None]
    pools = np.asarray(pools, dtype=np.int64)
    n = len(exec_time)
    prio = (np.asarray(priority, dtype=np.int64)
            if priority is not None else np.arange(n, dtype=np.int64))

    deps: List[set] = [set() for _ in range(n)]
    dependents: List[list] = [[] for _ in range(n)]
    for t, d in dep_pairs:
        deps[t].add(d)
        dependents[d].append(t)

    unmet = np.array([len(d) for d in deps], dtype=np.int64)
    state = np.zeros(n, dtype=np.int64)  # 0 waiting, 1 running, 2 done
    start = np.full(n, -1, dtype=np.int64)
    finish = np.full(n, -1, dtype=np.int64)
    ready_at = np.zeros(n, dtype=np.int64)
    free = pools.copy()
    heap: List[tuple] = []
    clock = 0
    n_events = 0

    def select():
        ready = np.nonzero((state == 0) & (unmet == 0))[0]
        if len(ready) == 0:
            return -1
        order = ready[np.lexsort((ready, prio[ready]))]
        if policy == "fcfs":
            head = order[0]
            return head if np.all(resources[head] <= free) else -1
        for t in order:  # fcfs_fit / cpath: first (by priority) that fits
            if np.all(resources[t] <= free):
                return t
        return -1

    def sched_pass():
        nonlocal free
        while True:
            t = select()
            if t < 0:
                break
            state[t] = 1
            start[t] = clock
            finish[t] = clock + exec_time[t]
            free = free - resources[t]
            heapq.heappush(heap, (int(finish[t]), int(t)))

    sched_pass()
    while heap:
        clock = heap[0][0]
        n_events += 1
        while heap and heap[0][0] <= clock:
            _, t = heapq.heappop(heap)
            state[t] = 2
            free = free + resources[t]
            for u in dependents[t]:
                unmet[u] -= 1
                ready_at[u] = max(ready_at[u], clock)
        sched_pass()

    return {
        "exec_time": exec_time,
        "start": start,
        "finish": finish,
        "ready": ready_at,
        "wait": start - ready_at,
        "done": state == 2,
        "valid": np.ones(n, dtype=bool),
        "makespan": int(finish.max(initial=0)),
        "n_events": n_events,
    }
