"""Heap-based event-driven cluster scheduling simulator (CQsim analogue).

Implements exactly the semantics pinned in DESIGN.md §8 / repro.core:
completions, then arrivals, then a scheduling pass that repeatedly applies
the policy selector until it blocks.  O(E log E) via a completion heap, but
the scheduling pass scans the waiting queue (like CQsim's list scan).

Dependencies (DESIGN.md §13): a job with unmet dependencies is invisible —
it generates no arrival event and never enters the waiting queue.  Its
release happens inside the completion step of its last dependency
(completions run before arrivals, mirroring the JAX engine bit-for-bit),
and ``ready = max(submit, last dep finish)`` is recorded for the paper's
Fig. 7 wait metric.  A preempted job is WAITING, not DONE, so its
dependents stay blocked until it actually finishes.

Node allocation (DESIGN.md §11): given a ``repro.alloc.Machine`` this
simulator maintains the same per-node occupancy map as the JAX engine,
places nodes through the ``repro.alloc.host`` mirrors (identical
tie-breaking), applies the same contention dilation, and reports the same
allocation fingerprints — the host-side oracle for bit-exact validation of
starts, finishes *and* node maps.

Reliability (DESIGN.md §15): given a ``repro.reliability.FailureTrace``
this simulator walks the *same* merged failure/repair stream as the JAX
engine (one shared stable sort, ``repro.reliability.merge_stream``) with
the same kill rule — machine mode kills the failed node's owner, scalar
mode kills the job covering slot ``node % n_up`` of the row-order running
node cumsum — the same requeue/abort transitions, and the same checkpoint
rework accounting, recording every kill in an explicit ``kill_log`` the
differential tests audit ``n_restarts`` against.

Serving (DESIGN.md §16): given a ``repro.serving.ServicePlan`` this
simulator carries the per-job SLO deadline column, fixes the met/missed
verdict at start time, and walks the *same* autoscaler tick stream as the
JAX engine — one hysteresis rule application per consumed tick, after the
reliability stream and before arrivals, with scale-down bounded by the
free count (drain semantics: a running job is never stranded) and
machine-mode deactivation taking the highest-index free nodes /
reactivation the lowest-index offline ones.

Malleable jobs (DESIGN.md §17): given a ``repro.malleable.MalleablePlan``
this simulator mirrors the two-level width decisions bit-exactly — the
moldable width choice at dispatch (min dilated duration among widths that
fit, narrowest on ties), the elastic one-resize-per-tick rule at the
plan's capacity ticks (shed from the widest running job under queue
pressure, grow the narrowest when the queue drains), the same pinned
float32 remaining-work rescale on every resize, and shrink-instead-of-kill
when a node failure hits a job running above its minimum width.  The
node-second ledger closes a segment at every width change exactly like
the engine's ``MalState`` accounting.
"""

from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from repro.alloc import contention as _con
from repro.alloc import host as _host
from repro.core.jobs import (
    BACKFILL, BESTFIT, FCFS, INF_TIME, LJF, PREEMPT, SJF, dep_edge_arrays,
)
from repro.reliability.model import FAIL, REQUEUE, merge_stream

_POL = {"fcfs": FCFS, "sjf": SJF, "ljf": LJF, "bestfit": BESTFIT,
        "backfill": BACKFILL, "preempt": PREEMPT}


def _ratio_ceil_host(r: int, dur_new: int, dur_old: int) -> int:
    """Remaining-work rescale on a width change — the engine's pinned
    float32 operation order ``ceil((f32(r) * f32(new)) / f32(old))``,
    floored at one tick (host mirror of ``engine._ratio_ceil``)."""
    v = (np.float32(r) * np.float32(dur_new)) / np.float32(dur_old)
    return max(int(np.ceil(v)), 1)


@dataclass
class _Job:
    idx: int
    submit: int
    runtime: int
    estimate: int
    nodes: int
    priority: int = 0
    start: int = -1
    finish: int = -1
    remaining: int = -1
    alloc_first: int = -1
    alloc_span: int = 0
    alloc_sum: int = 0
    last_start: int = -1   # latest dispatch (checkpoint base, shadow math)
    n_restarts: int = 0
    lost_work: int = 0
    aborted: bool = False
    # malleable state (``nodes`` holds the CURRENT effective width; the
    # original request is preserved separately for the output columns)
    prev_w: int = 0        # width backing ``remaining`` (0 = fresh job)
    n_resizes: int = 0
    node_s: int = 0        # closed node-second segments
    seg_start: int = 0     # open segment start (valid while RUNNING)
    disp_dur: int = -1     # dur-table entry at the latest dispatch


@dataclass
class ReferenceSimulator:
    total_nodes: int
    policy: str = "fcfs"
    machine: object = None          # repro.alloc.Machine or its to_host() dict
    alloc: str = "simple"
    contention: object = None       # repro.alloc.Contention, (num, den), or None
    failures: object = None         # repro.reliability.FailureTrace or None
    service: object = None          # repro.serving.ServicePlan or None
    malleable: object = None        # repro.malleable.MalleablePlan or None
    jobs: List[_Job] = field(default_factory=list)
    dep_pairs: List[tuple] = field(default_factory=list)  # sorted-row indices
    _order: np.ndarray = None       # input-row -> sorted-row permutation

    def load(self, submit, runtime, nodes, estimate=None, priority=None,
             deps=None):
        submit = np.asarray(submit, dtype=np.int64)
        submit = submit - (submit.min() if len(submit) else 0)
        runtime = np.maximum(np.asarray(runtime, dtype=np.int64), 1)
        estimate = (
            np.maximum(np.asarray(estimate, dtype=np.int64), 1)
            if estimate is not None else runtime.copy()
        )
        nodes = np.minimum(np.maximum(np.asarray(nodes, dtype=np.int64), 1),
                           self.total_nodes)
        priority = (np.asarray(priority, dtype=np.int64) if priority is not None
                    else np.zeros(len(submit), dtype=np.int64))
        order = np.lexsort((np.arange(len(submit)), submit))
        self._order = order
        self.jobs = [
            _Job(i, int(submit[o]), int(runtime[o]), int(estimate[o]),
                 int(nodes[o]), int(priority[o]), remaining=int(runtime[o]))
            for i, o in enumerate(order)
        ]
        self.dep_pairs = []
        if deps is not None:
            # one shared normalizer (validation + cycle check + (submit, id)
            # sort permutation) with make_jobset, so both engines hold
            # bit-identical edge sets
            dst, src = dep_edge_arrays(deps, len(submit), order)
            self.dep_pairs = list(zip(dst.tolist(), src.tolist()))
        return self

    # ---- allocation helpers (mirror repro.alloc) ---------------------------

    def _mach_host(self) -> Optional[Dict[str, np.ndarray]]:
        if self.machine is None:
            return None
        if isinstance(self.machine, dict):
            return self.machine
        return self.machine.to_host()

    def _alpha(self) -> tuple[int, int]:
        con = self.contention
        if con is None:
            return 0, 1
        if isinstance(con, tuple):
            return int(con[0]), int(con[1])
        if int(np.asarray(con.enabled)) == 0:
            return 0, 1
        return int(np.asarray(con.alpha_num)), int(np.asarray(con.alpha_den))

    # ---- policy selectors (mirror repro.core.policies) ---------------------

    def _select(self, waiting: List[_Job], running: List[_Job], free: int,
                cap: int, clock: int,
                bf: Optional[dict] = None) -> Optional[_Job]:
        if not waiting:
            return None
        pol = self.policy
        if pol in ("fcfs", "sjf", "ljf"):
            if pol == "fcfs":
                head = min(waiting, key=lambda j: j.idx)
            elif pol == "sjf":
                head = min(waiting, key=lambda j: (j.estimate, j.idx))
            else:
                head = min(waiting, key=lambda j: (-j.estimate, j.idx))
            return head if head.nodes <= cap else None
        if pol == "bestfit":
            feas = [j for j in waiting if j.nodes <= cap]
            if not feas:
                return None
            return min(feas, key=lambda j: (free - j.nodes, j.idx))
        if pol == "backfill":
            head = min(waiting, key=lambda j: j.idx)
            if head.nodes <= cap:
                if bf is not None:
                    bf.clear()  # a starting head invalidates any window
                return head
            # shadow via estimates of running jobs (free-count based, pinned;
            # keyed on the LATEST dispatch — the engine's rsv_finish — which
            # equals the first start unless a failure requeued the job)
            rel = sorted(
                (max(j.last_start + j.estimate, clock + 1), j.idx, j.nodes)
                for j in running
            )
            cum, shadow, extra, k_idx = free, None, free, -1
            for t, _idx, n in rel:
                cum += n
                if cum >= head.nodes:
                    shadow, extra, k_idx = t, cum - head.nodes, _idx
                    break
            if shadow is None:
                shadow, extra = None, free  # unreachable if nodes<=total
            if bf is not None and shadow is not None:
                # Decision-for-decision mirror of the engine's batched
                # backfill pass (DESIGN.md §18): within one scheduling pass
                # the pass carries (shadow, extra) as loop-invariant
                # structure, updating only the budget on each admission.
                # The oracle keeps recomputing from scratch and ASSERTS the
                # carried values match — the shadow-invariance theorem,
                # checked on every admission of every backfill run.  The
                # caller enables the carry only under a count-based cap:
                # the theorem's premise is free < head_need when the head
                # blocks, and the contiguous cap can geometry-block a
                # count-feasible head (an admission's own release may then
                # cover the head, legitimately moving the shadow earlier).
                # The pass loop clears the carry on a budget overdraw (a
                # release tie at the shadow can move the reach entry
                # within its tie group), so a present carry must match.
                if bf.get("head") == head.idx:
                    assert (bf["shadow"], bf["extra"], bf["k_idx"]) \
                        == (shadow, extra, k_idx), (
                        "backfill shadow invariance violated: carried "
                        f"(shadow={bf['shadow']}, extra={bf['extra']}, "
                        f"k_idx={bf['k_idx']}) != recomputed "
                        f"({shadow}, {extra}, {k_idx}) at clock {clock}")
                else:
                    bf["head"] = head.idx
                    bf["shadow"], bf["extra"] = shadow, extra
                    bf["k_idx"] = k_idx
            cands = [
                j for j in waiting
                if j is not head and j.nodes <= cap
                and ((shadow is not None and clock + j.estimate <= shadow)
                     or j.nodes <= min(free, extra))
            ]
            return min(cands, key=lambda j: j.idx) if cands else None
        if pol == "preempt":
            # queue order (priority, submit-rank); head may reclaim nodes
            # from strictly-lower-priority running jobs (engine mirror);
            # reclaim feasibility is free-count based by design
            head = min(waiting, key=lambda j: (j.priority, j.idx))
            reclaimable = sum(j.nodes for j in running
                              if j.priority > head.priority)
            return head if head.nodes <= free + reclaimable else None
        raise ValueError(f"unknown policy {pol!r}")

    # ---- event loop ---------------------------------------------------------

    def run(self) -> Dict[str, np.ndarray]:
        assert self.policy in _POL, self.policy
        jobs = self.jobs
        n = len(jobs)
        unmet = [0] * n             # unmet-dependency counts
        dependents: List[List[int]] = [[] for _ in range(n)]
        for t, d in self.dep_pairs:
            unmet[t] += 1
            dependents[d].append(t)
        # released-but-unarrived jobs as a min-heap of row indices; rows are
        # sorted by (submit, id), so index order IS arrival order and the
        # heap top always carries the next arrival time.  Jobs enter when
        # their last dependency completes (immediately for dep-free jobs),
        # keeping the no-deps path at the seed's O(E log E).
        rel_heap = [i for i in range(n) if unmet[i] == 0]
        heapq.heapify(rel_heap)
        n_unarrived = n
        last_dep_fin = [0] * n
        ready = [0] * n
        waiting: List[_Job] = []
        heap: List[tuple] = []  # (finish, idx)
        running: Dict[int, _Job] = {}
        free = self.total_nodes
        clock = 0
        n_events = 0

        mach = self._mach_host()
        alpha_num, alpha_den = self._alpha()
        owner = (np.full(self.total_nodes, -1, dtype=np.int64)
                 if mach is not None else None)
        ev_time: List[int] = []
        ev_free: List[int] = []
        ev_lfb: List[int] = []

        # reliability: the merged failure/repair stream (one shared stable
        # sort with the engine), outage bookkeeping, and the kill log
        fail = self.failures
        if fail is not None:
            st_time, st_node, st_kind = merge_stream(fail)
            n_stream = int((st_time < int(INF_TIME)).sum())
            requeue = int(fail.requeue) == REQUEUE
            ckpt = int(fail.checkpoint_interval)
            overhead = int(fail.restart_overhead)
        ptr = 0
        down = (np.zeros(self.total_nodes, dtype=bool)
                if (fail is not None and owner is not None) else None)
        kill_log: List[dict] = []
        live = n  # jobs not yet completed or aborted

        # serving: SLO deadlines plus the autoscaler tick stream (the same
        # hysteresis rule as engine._process_capacity_ticks, applied once
        # per consumed tick, after reliability and before arrivals)
        svc = self.service
        if svc is not None:
            from repro.core.jobs import INF_TIME as _SVC_INF
            tick = np.asarray(svc.tick_time, dtype=np.int64)
            svc_T = len(tick)
            svc_up, svc_down = int(svc.up_threshold), int(svc.down_threshold)
            svc_step, svc_min = int(svc.step), int(svc.min_nodes)
            svc_max = min(
                self.total_nodes if svc.max_nodes is None
                else int(svc.max_nodes), self.total_nodes)
            if owner is not None and down is not None and svc_T > 0:
                raise ValueError(
                    "machine-mode failures cannot be combined with an "
                    "active autoscaler (engine parity)")
        else:
            tick, svc_T = None, 0
        ptr_s = 0
        n_online = self.total_nodes
        svc_offline = (np.zeros(self.total_nodes, dtype=bool)
                       if (svc is not None and owner is not None) else None)
        cap_log: List[tuple] = []  # (tick time, online count after rule)

        # malleable: the plan's per-job width/duration table (rows are the
        # same (submit, id)-sorted order as self.jobs), the resize tick
        # stream, and the elastic thresholds.  While a plan is active
        # ``j.nodes`` holds the job's CURRENT effective width — min_width
        # while waiting, the chosen/resized width while running — so the
        # selectors, the free counter, the failure slot rule and the
        # autoscaler demand all read widths with no further changes.
        mal = self.malleable
        ptr_m = 0
        req_nodes: List[int] = []
        if mal is not None:
            if alpha_num != 0:
                raise ValueError(
                    "malleable jobs cannot be combined with contention "
                    "dilation (engine parity)")
            if self.policy == "preempt":
                raise ValueError(
                    "malleable jobs cannot be combined with the preempt "
                    "policy (engine parity)")
            m_dur = np.asarray(mal.dur, dtype=np.int64)
            m_tick = np.asarray(mal.tick_time, dtype=np.int64)
            m_T = len(m_tick)          # 0 = moldable (no resize ticks)
            m_wlo, m_whi = int(mal.min_width), int(mal.max_width)
            m_W = m_whi - m_wlo + 1
            m_step = int(mal.step)
            m_shrT = int(mal.shrink_threshold)
            m_groT = int(mal.grow_threshold)
            req_nodes = [j.nodes for j in jobs]
            for j in jobs:
                j.nodes = m_wlo        # effective width while waiting
        else:
            m_T = 0

        def resize(j: _Job, new_w: int) -> None:
            """Apply a width change to a RUNNING job: close the node-second
            segment, rescale the remaining work (pinned float32 rule),
            move the node map, and refresh the allocation fingerprints."""
            nonlocal free
            w = j.nodes
            d = new_w - w
            k_old, k_new = w - m_wlo, new_w - m_wlo
            j.node_s += w * (clock - j.seg_start)
            j.seg_start = clock
            j.finish = clock + _ratio_ceil_host(
                j.finish - clock, int(m_dur[j.idx][k_new]),
                int(m_dur[j.idx][k_old]))
            heapq.heappush(heap, (j.finish, j.idx))
            if owner is not None:
                if d < 0:
                    owned = np.nonzero(owner == j.idx)[0]
                    owner[owned[len(owned) + d:]] = -1  # shed highest-index
                else:
                    ids = _host.place_host(self.alloc, mach, owner_view(), d)
                    owner[ids] = j.idx
                owned = np.nonzero(owner == j.idx)[0]
                j.alloc_span = _host.group_span_host(mach, owned)
                j.alloc_first, j.alloc_sum = _host.fingerprint_host(owned)
            j.nodes = new_w
            j.prev_w = new_w
            j.n_resizes += 1
            free -= d

        def shrink_one(j: _Job, node: int) -> None:
            """Failure hit on a job above min width (elastic only): shed
            exactly the failed node instead of killing the job.  The freed
            slot nets to zero against the node going down."""
            nonlocal free
            w = j.nodes
            j.node_s += w * (clock - j.seg_start)
            j.seg_start = clock
            j.finish = clock + _ratio_ceil_host(
                j.finish - clock, int(m_dur[j.idx][w - 1 - m_wlo]),
                int(m_dur[j.idx][w - m_wlo]))
            heapq.heappush(heap, (j.finish, j.idx))
            j.nodes = w - 1
            j.prev_w = w - 1
            j.n_resizes += 1
            free += 1
            if owner is not None:
                owner[node] = -1
                owned = np.nonzero(owner == j.idx)[0]
                j.alloc_span = _host.group_span_host(mach, owned)
                j.alloc_first, j.alloc_sum = _host.fingerprint_host(owned)

        def owner_view() -> np.ndarray:
            """Occupancy map as the placement strategies see it: down and
            drained nodes painted with the out-of-range owner id ``n``
            (engine mirror)."""
            ov = owner
            if svc_offline is not None:
                ov = np.where(svc_offline, n, ov)
            if down is not None:
                ov = np.where(down, n, ov)
            return ov

        def cap_now() -> int:
            if owner is None:
                return free
            return _host.placeable_cap_host(self.alloc, owner_view())

        def kill(j: _Job, node: int) -> None:
            """Apply the requeue/abort rule to a job hit by a node failure."""
            nonlocal free, live
            el = clock - j.last_start
            saved = (el // ckpt) * ckpt if ckpt > 0 else 0
            lost = el - saved
            del running[j.idx]
            free += j.nodes
            if mal is not None:
                j.node_s += j.nodes * (clock - j.seg_start)
            if owner is not None:
                owner[owner == j.idx] = -1
            if requeue:
                j.remaining = max(j.finish - clock + lost + overhead, 1)
                j.finish = -1
                j.n_restarts += 1
                j.lost_work += lost + overhead
                if mal is not None:
                    j.nodes = m_wlo   # back to min width; prev_w keeps the
                                      # pre-kill width backing ``remaining``
                waiting.append(j)
            else:
                j.aborted = True
                j.finish = clock
                j.lost_work += el
                live -= 1
                for t in dependents[j.idx]:   # after-any release
                    unmet[t] -= 1
                    last_dep_fin[t] = max(last_dep_fin[t], clock)
                    if unmet[t] == 0:
                        heapq.heappush(rel_heap, t)
            kill_log.append({"time": clock, "node": node, "job": j.idx,
                             "requeued": requeue, "lost": lost})

        def more_events() -> bool:
            # a resize can leave a job's old (later) heap entry stale after
            # the rescheduled finish pops, so with malleable jobs a
            # non-empty heap no longer implies pending work — count live
            # jobs instead (same rule the failure path already needs)
            if fail is None and mal is None:
                return bool(n_unarrived or heap)
            return live > 0

        while more_events():
            while heap and (heap[0][1] not in running
                            or running[heap[0][1]].finish != heap[0][0]):
                heapq.heappop(heap)   # stale entry from a preemption/kill
            # released PENDING jobs only: a job with unmet dependencies
            # generates no arrival event (mirrors the engine's release rule)
            t_arr = jobs[rel_heap[0]].submit if rel_heap else None
            t_fin = heap[0][0] if heap else None
            t_rel = (st_time[ptr] if fail is not None and ptr < n_stream
                     else None)
            t_svc = None
            if ptr_s < svc_T and int(tick[ptr_s]) < int(_SVC_INF):
                t_svc = int(tick[ptr_s])   # INF padding is never a source
            t_mal = None
            if ptr_m < m_T and int(m_tick[ptr_m]) < int(INF_TIME):
                t_mal = int(m_tick[ptr_m])  # INF clamp is never a source
            assert (t_arr is not None or t_fin is not None
                    or t_rel is not None or t_svc is not None
                    or t_mal is not None), \
                "deadlock: blocked jobs with no running dependency"
            clock = min(x for x in (t_arr, t_fin, t_rel, t_svc, t_mal)
                        if x is not None)
            n_events += 1
            # completions first (skip heap entries stale after preemption);
            # completing a job releases its dependents *now*, before the
            # arrival step of this same event
            while heap and heap[0][0] <= clock:
                fin, idx = heapq.heappop(heap)
                j = running.get(idx)
                if j is None or j.finish != fin:
                    continue  # stale: the job was preempted and re-queued
                del running[idx]
                free += j.nodes
                live -= 1
                if mal is not None:   # close the final node-second segment
                    j.node_s += j.nodes * (fin - j.seg_start)
                for t in dependents[idx]:
                    unmet[t] -= 1
                    last_dep_fin[t] = max(last_dep_fin[t], fin)
                    if unmet[t] == 0:
                        heapq.heappush(rel_heap, t)
                if owner is not None:
                    owner[owner == idx] = -1
            # reliability events: after completions (a job finishing at the
            # failure instant has completed), before arrivals (a dependent
            # of an aborted job releases within this same event)
            while fail is not None and ptr < n_stream \
                    and st_time[ptr] <= clock:
                node, kind = int(st_node[ptr]), int(st_kind[ptr])
                ptr += 1
                if kind == FAIL:
                    # elastic malleable jobs above min width shed the failed
                    # node instead of dying (DESIGN.md §17)
                    def hit(j: _Job, node: int) -> None:
                        if mal is not None and m_T > 0 and j.nodes > m_wlo:
                            shrink_one(j, node)
                        else:
                            kill(j, node)
                    if owner is not None:
                        if down[node]:
                            continue  # total-semantics guard (never renewal)
                        victim = int(owner[node])
                        down[node] = True
                        free -= 1
                        if victim >= 0:
                            hit(running[victim], node)
                    else:
                        # anonymous nodes: slot rule over the row-order
                        # running cumsum (engine mirror, DESIGN.md §15)
                        busy = sum(j.nodes for j in running.values())
                        n_up = free + busy
                        slot = node % max(n_up, 1)
                        free -= 1
                        if slot < busy:
                            cum = 0
                            for j in sorted(running.values(),
                                            key=lambda v: v.idx):
                                cum += j.nodes
                                if cum > slot:
                                    hit(j, node)
                                    break
                else:  # REPAIR
                    if owner is not None:
                        if not down[node]:
                            continue
                        down[node] = False
                    free += 1
            # autoscaler ticks: after reliability (capacity reacts to this
            # instant's failures), before arrivals (queued demand is read
            # BEFORE this event's arrivals join the queue — engine mirror)
            while ptr_s < svc_T and int(tick[ptr_s]) <= clock and live > 0:
                demand = sum(j.nodes for j in waiting)
                up = demand >= svc_up
                dn = (not up) and demand <= svc_down
                k_up = min(max(svc_max - n_online, 0), svc_step) if up else 0
                k_down = (min(max(n_online - svc_min, 0), svc_step,
                              max(free, 0)) if dn else 0)
                if svc_offline is not None:
                    if k_up:
                        # reactivate the lowest-index offline nodes
                        ids = np.nonzero(svc_offline)[0][:k_up]
                        svc_offline[ids] = False
                    if k_down:
                        # drain the highest-index FREE online nodes; the
                        # free counter bounds k_down, so a busy node is
                        # never taken (no running job is ever stranded)
                        cand = np.nonzero((owner < 0) & ~svc_offline)[0]
                        assert len(cand) >= k_down, "autoscale drain invariant"
                        svc_offline[cand[len(cand) - k_down:]] = True
                n_online += k_up - k_down
                free += k_up - k_down
                cap_log.append((int(tick[ptr_s]), n_online))
                ptr_s += 1
            # malleable resize ticks: after the autoscaler (resize reacts to
            # this instant's capacity), before arrivals (queue pressure is
            # read BEFORE this event's arrivals join — engine mirror).  At
            # most ONE job resizes per tick: under pressure the widest
            # running job above min width sheds up to ``step`` nodes (tie →
            # lowest row); when the queue drains the narrowest below max
            # width grows, bounded by step, headroom and placeable capacity.
            while ptr_m < m_T and int(m_tick[ptr_m]) <= clock and live > 0:
                demand = sum(j.nodes for j in waiting)
                if demand >= m_shrT:
                    cands = [j for j in running.values() if j.nodes > m_wlo]
                    if cands:
                        vic = min(cands, key=lambda j: (-j.nodes, j.idx))
                        d = min(m_step, vic.nodes - m_wlo)
                        resize(vic, vic.nodes - d)
                elif demand <= m_groT:
                    cands = [j for j in running.values() if j.nodes < m_whi]
                    if cands:
                        vic = min(cands, key=lambda j: (j.nodes, j.idx))
                        gcap = (max(free, 0) if owner is None else
                                _host.placeable_cap_host(self.alloc,
                                                         owner_view()))
                        d = min(m_step, m_whi - vic.nodes, gcap)
                        if d > 0:
                            resize(vic, vic.nodes + d)
                ptr_m += 1
            # arrivals: submit reached AND all dependencies DONE
            while rel_heap and jobs[rel_heap[0]].submit <= clock:
                i = heapq.heappop(rel_heap)
                ready[i] = max(jobs[i].submit, last_dep_fin[i])
                waiting.append(jobs[i])
                n_unarrived -= 1
            # scheduling pass — ``bf`` carries the backfill window's
            # (shadow, extra) across this pass's starts, engine-style;
            # ``_select`` asserts it against a fresh recompute (§18).
            # Enabled exactly where the engine batches: count-capped caps
            # (the invariance premise fails under the contiguous cap — see
            # the note in ``_select``) and rigid widths (a moldable
            # dispatch may start wider than the admitted minimum width,
            # overdrawing the carried ``extra`` budget).
            bf = ({} if (mal is None
                         and (self.machine is None
                              or _host.alloc_id(self.alloc)
                              != _host.CONTIGUOUS))
                  else None)
            while True:
                j = self._select(waiting, list(running.values()), free,
                                 cap_now(), clock, bf)
                if j is None:
                    break
                if j.nodes > free:  # preempt policy: suspend victims
                    victims = sorted(
                        (v for v in running.values()
                         if v.priority > j.priority),
                        key=lambda v: (-v.priority, -v.idx))
                    need = j.nodes - free
                    for v in victims:
                        if need <= 0:
                            break
                        need -= v.nodes
                        free += v.nodes
                        v.remaining = max(v.finish - clock, 1)
                        v.finish = -1
                        del running[v.idx]
                        if owner is not None:
                            owner[owner == v.idx] = -1
                        waiting.append(v)
                waiting.remove(j)
                if j.start < 0:
                    j.start = clock   # first dispatch only
                j.last_start = clock  # checkpoint base / rsv shadow key
                if mal is not None:
                    # moldable width choice: among widths that fit the
                    # current capacity, minimize the dilated duration;
                    # first-minimum tie-break → the narrowest such width
                    cap = cap_now()
                    row = m_dur[j.idx]
                    best_k, best_d = 0, None
                    for k in range(m_W):
                        if m_wlo + k <= cap and (best_d is None
                                                 or int(row[k]) < best_d):
                            best_k, best_d = k, int(row[k])
                    if j.prev_w == 0:      # fresh: dur table is exact
                        dilated = int(row[best_k])
                    else:                  # requeued: rescale remaining work
                        dilated = _ratio_ceil_host(
                            j.remaining, int(row[best_k]),
                            int(row[j.prev_w - m_wlo]))
                    j.nodes = m_wlo + best_k
                    j.prev_w = j.nodes
                    j.seg_start = clock
                    j.disp_dur = int(row[best_k])
                else:
                    dilated = j.remaining
                if owner is not None:
                    ids = _host.place_host(self.alloc, mach, owner_view(),
                                           j.nodes)
                    assert down is None or not down[ids].any(), \
                        "placement invariant violated: job on a down node"
                    assert svc_offline is None or not svc_offline[ids].any(), \
                        "placement invariant violated: job on a drained node"
                    owner[ids] = j.idx
                    j.alloc_span = _host.group_span_host(mach, ids)
                    j.alloc_first, j.alloc_sum = _host.fingerprint_host(ids)
                    if mal is None:
                        dilated = _con.dilate_host(alpha_num, alpha_den,
                                                   j.remaining, j.alloc_span)
                j.finish = clock + dilated
                free -= j.nodes
                running[j.idx] = j
                heapq.heappush(heap, (j.finish, j.idx))
                if bf is not None and bf.get("head") is not None:
                    # §18 budget carry: the admission consumed reserve
                    # nodes iff its release entry (clamped time, row)
                    # sorts after the reach entry — a release tie at the
                    # shadow breaks by row, exactly like the rel sort.  An
                    # overdraw (tie corner) moves the reach entry within
                    # its tie group: drop the carry and re-derive.
                    t_c = max(clock + j.estimate, clock + 1)
                    if (t_c, j.idx) > (bf["shadow"], bf["k_idx"]):
                        bf["extra"] -= j.nodes
                        if bf["extra"] < 0:
                            bf.clear()
            if owner is not None:
                ev_time.append(clock)
                ev_free.append(free)
                ev_lfb.append(_host.largest_free_run_host(owner_view()))

        out = {
            "submit": np.array([j.submit for j in jobs], dtype=np.int64),
            "runtime": np.array([j.runtime for j in jobs], dtype=np.int64),
            "nodes": np.array([j.nodes for j in jobs], dtype=np.int64),
            "start": np.array([j.start for j in jobs], dtype=np.int64),
            "finish": np.array([j.finish for j in jobs], dtype=np.int64),
            "ready": np.array(ready, dtype=np.int64),
        }
        out["wait"] = out["start"] - out["ready"]
        out["done"] = out["start"] >= 0
        out["valid"] = np.ones(n, dtype=bool)
        if fail is not None:
            aborted = np.array([j.aborted for j in jobs], dtype=bool)
            out["done"] = out["done"] & ~aborted
            out["aborted"] = aborted
            out["n_restarts"] = np.array(
                [j.n_restarts for j in jobs], dtype=np.int64)
            out["lost_work"] = np.array(
                [j.lost_work for j in jobs], dtype=np.int64)
            out["kill_log"] = kill_log
            out["makespan"] = int(out["finish"][out["done"]].max(initial=0))
        else:
            out["makespan"] = int(out["finish"].max(initial=0))
        out["n_events"] = n_events
        if svc is not None:
            # SLO verdict fixed at start time: met iff the job started by
            # its deadline (deadline rows are input-order; map through the
            # (submit, id) sort like every other job column)
            dl = np.asarray(svc.deadline, dtype=np.int64)[self._order]
            out["deadline"] = dl
            out["slo_met"] = out["done"] & (out["start"] <= dl)
            out["class_id"] = np.asarray(
                svc.class_id, dtype=np.int64)[self._order]
            out["cap_time"] = np.array([t for t, _ in cap_log],
                                       dtype=np.int64)
            out["cap_online"] = np.array([v for _, v in cap_log],
                                         dtype=np.int64)
        if mal is not None:
            # "nodes" reports the ORIGINAL request (engine parity: the
            # engine emits jobs.nodes untouched); the chosen/final width
            # lives in the mal_* columns
            out["nodes"] = np.array(req_nodes, dtype=np.int64)
            out["mal_width"] = np.array([j.nodes for j in jobs],
                                        dtype=np.int64)
            out["mal_nref"] = np.asarray(mal.nref, dtype=np.int64)[:n]
            out["mal_nresize"] = np.array([j.n_resizes for j in jobs],
                                          dtype=np.int64)
            out["mal_node_s"] = np.array([j.node_s for j in jobs],
                                         dtype=np.int64)
            out["mal_dur"] = np.array([j.disp_dur for j in jobs],
                                      dtype=np.int64)
        if mach is not None:
            out["alloc_first"] = np.array(
                [j.alloc_first for j in jobs], dtype=np.int64)
            out["alloc_span"] = np.array(
                [j.alloc_span for j in jobs], dtype=np.int64)
            out["alloc_sum"] = np.array(
                [j.alloc_sum for j in jobs], dtype=np.int64)
            out["ev_time"] = np.array(ev_time, dtype=np.int64)
            out["ev_free"] = np.array(ev_free, dtype=np.int64)
            out["ev_lfb"] = np.array(ev_lfb, dtype=np.int64)
        return out


def simulate_reference(trace, policy: str, *, total_nodes: int, machine=None,
                       alloc: str = "simple", contention=None, failures=None,
                       service=None, malleable=None):
    """One-call host oracle.  ``failures`` is a materialized
    ``repro.reliability.FailureTrace`` (NOT a ``FailureModel``),
    ``service`` a materialized ``repro.serving.ServicePlan`` and
    ``malleable`` a materialized ``repro.malleable.MalleablePlan`` — both
    engines must consume the identical arrays, so materialize once."""
    sim = ReferenceSimulator(total_nodes=total_nodes, policy=policy,
                             machine=machine, alloc=alloc,
                             contention=contention, failures=failures,
                             service=service, malleable=malleable)
    sim.load(trace["submit"], trace["runtime"], trace["nodes"],
             trace.get("estimate"), trace.get("priority"),
             deps=trace.get("deps"))
    return sim.run()


def replay_reference(trace, policy: str = "fcfs", *, total_nodes: int,
                     machine=None, alloc: str = "simple", contention=None,
                     failures=None):
    """Host oracle for ``repro.replay``'s windowed streaming runs.

    Windowed replay is decision-for-decision identical to the one-shot
    schedule (window boundaries never reorder or split an event, DESIGN.md
    §19), so the reference for a streamed trace is simply the reference
    schedule of the *whole* trace.  The trace goes through the replay
    runner's own int64 normalization — identical input columns on both
    sides — and the int64 host arithmetic here imposes no int32 horizon
    cap, which makes this the oracle for beyond-int32 archives that
    one-shot ``simulate`` refuses outright.
    """
    from repro.replay.runner import _normalize
    t = _normalize(dict(trace), total_nodes)
    return simulate_reference(t, policy, total_nodes=total_nodes,
                              machine=machine, alloc=alloc,
                              contention=contention, failures=failures)
