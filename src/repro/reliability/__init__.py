"""Reliability-aware simulation (DESIGN.md §15).

Node failures, job requeue/abort, and checkpoint-restart rework as a
first-class scenario axis: a frozen :class:`FailureModel` materializes
deterministic seeded failure/repair event streams that both engines
consume bit-identically.  ``failures=None`` statically elides the whole
subsystem — the no-failure engine compiles to the exact pre-reliability
event graph (property-tested via HLO fingerprints).
"""

from repro.reliability.model import (
    ABORT, FAIL, REPAIR, REQUEUE, REQUEUE_IDS, REQUEUE_NAMES,
    FailureModel, FailureTrace, make_fail_ctx, merge_stream,
)

__all__ = [
    "ABORT", "FAIL", "REPAIR", "REQUEUE", "REQUEUE_IDS", "REQUEUE_NAMES",
    "FailureModel", "FailureTrace", "make_fail_ctx", "merge_stream",
]
