"""Deterministic node-failure models (DESIGN.md §15).

A :class:`FailureModel` is a frozen host-side spec of a cluster's
reliability behaviour: every node runs an independent renewal process —
up for a seeded exponential (or Weibull) draw, down for a seeded
exponential repair draw, repeat — so a node can never fail while it is
already down.  ``materialize(n_nodes)`` lowers the spec to a
:class:`FailureTrace`: three *padded, fixed-shape* host arrays
(``fail_time``/``fail_node``/``repair_time``, ``INF_TIME`` in the padding
slots) plus the integer kill-policy knobs.  The traced engine consumes
them through :func:`make_fail_ctx` and never branches on shape, so MTBF /
checkpoint-interval / requeue-policy grids batch through ``vmap`` exactly
like policy sweeps (``max_failures`` is the one static axis).

The reference simulator consumes the *same* arrays through
:func:`merge_stream`, which pins the failure/repair interleaving both
engines walk: one stable sort by timestamp over the concatenated
``[failures..., repairs...]`` lists (ties therefore break fail-first,
then by event index).
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Optional, Tuple

import numpy as np

# The int32 "infinite time" sentinel, == repro.core.jobs.INF_TIME (that
# module cannot be imported here at module scope: repro.core.__init__
# pulls in the engine, which imports this module — asserted equal at the
# first materialization instead).
INF_TIME = np.int32(2**30 - 1)

# stream event kinds (pinned in both engines)
FAIL = 0
REPAIR = 1

# kill-policy ids (``FailureTrace.requeue``)
ABORT = 0
REQUEUE = 1
REQUEUE_IDS = {"abort": ABORT, "requeue": REQUEUE}
REQUEUE_NAMES = {v: k for k, v in REQUEUE_IDS.items()}

_DISTRIBUTIONS = ("exponential", "weibull")


@dataclasses.dataclass(frozen=True, eq=False)
class FailureTrace:
    """Materialized failure/repair streams (host arrays, padded).

    ``fail_time[f]`` is when node ``fail_node[f]`` goes down and
    ``repair_time[f]`` when that same node comes back up; entries are
    sorted by (fail_time, node) with ``INF_TIME`` padding at the tail, so
    the padded capacity ``fail_time.shape[-1]`` is the only static shape.
    A failure and its repair are always kept or dropped together — a
    materialized trace never strands a node down forever.
    """

    fail_time: np.ndarray    # i32[F], INF_TIME = padding
    fail_node: np.ndarray    # i32[F]
    repair_time: np.ndarray  # i32[F]
    requeue: int             # REQUEUE or ABORT
    checkpoint_interval: int  # 0 = no checkpoints (full rework on kill)
    restart_overhead: int
    n_failures: int          # real (unpadded) failure count
    truncated: bool = False  # renewal generated > max_failures pairs

    @property
    def capacity(self) -> int:
        return int(self.fail_time.shape[-1])


@dataclasses.dataclass(frozen=True)
class FailureModel:
    """Frozen reliability spec for a :class:`repro.api.Scenario`.

    ``mtbf`` is the per-node scale of the up-time distribution (the mean
    for ``exponential``; for ``weibull`` the scale parameter, with shape
    ``k``).  ``mean_repair`` is the mean of the exponential down-time
    draw.  ``requeue`` picks what happens to a job killed by a node
    failure: ``"requeue"`` re-enters the queue at its original submit
    rank with its lost work re-charged (bounded by
    ``checkpoint_interval``: work since the last checkpoint is lost, plus
    ``restart_overhead``), ``"abort"`` terminates it (dependents release
    with after-any semantics).  ``max_failures`` is the padded event
    capacity — the one field that changes compiled shapes; everything
    else is trace *data*, so ``sweep()`` batches MTBF / checkpoint /
    requeue grids into one executable.
    """

    mtbf: float
    seed: int = 0
    distribution: str = "exponential"
    k: float = 1.5                 # weibull shape (ignored for exponential)
    mean_repair: int = 60
    horizon: int = 1 << 20         # failures generated in [0, horizon)
    max_failures: int = 64         # padded capacity (static shape)
    requeue: str = "requeue"
    checkpoint_interval: int = 0   # 0 = no checkpoints (full rework)
    restart_overhead: int = 0

    def __post_init__(self):
        if not self.mtbf > 0:
            raise ValueError(f"mtbf must be positive, got {self.mtbf}")
        if self.distribution not in _DISTRIBUTIONS:
            raise ValueError(
                f"unknown distribution {self.distribution!r}; "
                f"known: {_DISTRIBUTIONS}")
        if not self.k > 0:
            raise ValueError(f"weibull shape k must be positive, got {self.k}")
        if self.mean_repair < 1:
            raise ValueError("mean_repair must be >= 1")
        if self.requeue not in REQUEUE_IDS:
            raise ValueError(
                f"requeue must be one of {sorted(REQUEUE_IDS)}, "
                f"got {self.requeue!r}")
        if self.max_failures < 0:
            raise ValueError("max_failures must be >= 0")
        if self.checkpoint_interval < 0 or self.restart_overhead < 0:
            raise ValueError(
                "checkpoint_interval/restart_overhead must be >= 0")
        if not 0 < self.horizon < int(INF_TIME) // 2:
            raise ValueError(
                f"horizon must be in (0, {int(INF_TIME) // 2}) so failure "
                "and repair timestamps stay clear of the int32 sentinel")

    def static_key(self) -> tuple:
        """The compile-bucket contribution: only the padded capacity
        changes compiled shapes (``repro.api.sweep`` keys on this)."""
        return ("failures", self.max_failures)

    def materialize(self, n_nodes: int) -> FailureTrace:
        """Deterministic (seed, n_nodes)-keyed failure/repair streams."""
        return _materialize(self, int(n_nodes))


@functools.lru_cache(maxsize=256)
def _materialize(model: FailureModel, n_nodes: int) -> FailureTrace:
    from repro.core.jobs import INF_TIME as _engine_inf

    assert INF_TIME == _engine_inf, "sentinel drifted from repro.core.jobs"
    rng = np.random.default_rng(model.seed)
    events: list[tuple[int, int, int]] = []   # (t_fail, node, t_repair)
    for node in range(n_nodes):
        t = 0
        for _ in range(model.max_failures):
            u = rng.random()
            if model.distribution == "exponential":
                dt = -model.mtbf * math.log1p(-u)
            else:
                dt = model.mtbf * (-math.log1p(-u)) ** (1.0 / model.k)
            t_fail = t + max(1, int(math.ceil(dt)))
            if t_fail >= model.horizon:
                break
            r = -model.mean_repair * math.log1p(-rng.random())
            t_repair = min(t_fail + max(1, int(math.ceil(r))),
                           int(INF_TIME) - 1)
            events.append((t_fail, node, t_repair))
            t = t_repair
    events.sort()                              # (fail_time, node) order
    truncated = len(events) > model.max_failures
    if truncated:
        # keeping only the earliest pairs concentrates every failure at the
        # start of the horizon — an MTBF sweep whose points all saturate
        # measures the truncation, not reliability.  Loud, once per
        # (model, n_nodes) thanks to the lru cache.
        import warnings

        warnings.warn(
            f"FailureModel(mtbf={model.mtbf}, horizon={model.horizon}) "
            f"generated {len(events)} failures for {n_nodes} nodes but "
            f"max_failures={model.max_failures}; keeping only the earliest "
            f"{model.max_failures} — raise max_failures (or mtbf/horizon) "
            "unless early-window truncation is intended",
            stacklevel=3)
    events = events[:model.max_failures]       # keep the earliest pairs
    F = model.max_failures
    fail_time = np.full((F,), INF_TIME, dtype=np.int32)
    fail_node = np.zeros((F,), dtype=np.int32)
    repair_time = np.full((F,), INF_TIME, dtype=np.int32)
    for i, (tf, node, tr) in enumerate(events):
        fail_time[i], fail_node[i], repair_time[i] = tf, node, tr
    return FailureTrace(
        fail_time=fail_time, fail_node=fail_node, repair_time=repair_time,
        requeue=REQUEUE_IDS[model.requeue],
        checkpoint_interval=int(model.checkpoint_interval),
        restart_overhead=int(model.restart_overhead),
        n_failures=len(events),
        truncated=truncated,
    )


def merge_stream(trace: FailureTrace) -> Tuple[np.ndarray, np.ndarray,
                                               np.ndarray]:
    """Host-side (time, node, kind) stream, sorted exactly like the engine.

    One stable argsort by timestamp over ``[failures..., repairs...]`` —
    the identical permutation ``jnp.argsort(..., stable=True)`` produces
    in the traced engine, so both engines walk the same interleaving.
    Padding entries (time ``INF_TIME``) sort to the tail and are never
    consumed.
    """
    times = np.concatenate([trace.fail_time, trace.repair_time])
    nodes = np.concatenate([trace.fail_node, trace.fail_node])
    kind = np.concatenate([
        np.full_like(trace.fail_node, FAIL),
        np.full_like(trace.fail_node, REPAIR),
    ])
    order = np.argsort(times, kind="stable")
    return times[order], nodes[order], kind[order]


def make_fail_ctx(failures, *, n_nodes: Optional[int] = None):
    """Canonicalize a ``failures`` argument into the engine's FailCtx.

    Accepts ``None`` (statically elided — the engine compiles the exact
    pre-reliability graph), a :class:`FailureModel` (materialized against
    ``n_nodes``, which must be concrete), a :class:`FailureTrace`, or an
    already-built ctx tuple (the ``vmap`` sweep path — leaves may be
    tracers).  The ctx is the 6-tuple
    ``(fail_time, fail_node, repair_time, requeue, checkpoint_interval,
    restart_overhead)`` of i32 device arrays.
    """
    import jax.numpy as jnp

    if failures is None:
        return None
    if isinstance(failures, FailureModel):
        if n_nodes is None:
            raise ValueError(
                "a FailureModel needs a concrete total_nodes to "
                "materialize; pass a FailureTrace (or prebuilt ctx) when "
                "total_nodes is traced")
        failures = failures.materialize(n_nodes)
    if isinstance(failures, FailureTrace):
        failures = (failures.fail_time, failures.fail_node,
                    failures.repair_time, failures.requeue,
                    failures.checkpoint_interval, failures.restart_overhead)
    if not (isinstance(failures, tuple) and len(failures) == 6):
        raise TypeError(
            "failures must be None, a FailureModel, a FailureTrace, or a "
            f"6-tuple fail ctx; got {type(failures).__name__}")
    return tuple(jnp.asarray(x, dtype=jnp.int32) for x in failures)
