"""Straggler detection policy (paper-adjacent: the DES evaluates it too).

On a real pod each host reports step wall time; the controller flags ranks
whose EMA-normalized time is a robust outlier for ``patience`` consecutive
steps, then triggers mitigation (evict + elastic re-mesh, or re-shard).
Here the policy itself is the artifact: unit-tested on synthetic timings and
evaluated against the DES in examples/schedule_fleet.py (stragglers =
runtime inflation).
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional


@dataclasses.dataclass
class StragglerDecision:
    step: int
    rank: int
    ratio: float
    action: str  # "warn" | "evict"


class StragglerMonitor:
    def __init__(self, n_ranks: int = 1, *, window: int = 32,
                 warn_ratio: float = 1.3, evict_ratio: float = 2.0,
                 patience: int = 3):
        self.n_ranks = n_ranks
        self.window = window
        self.warn_ratio = warn_ratio
        self.evict_ratio = evict_ratio
        self.patience = patience
        self.hist: List[Deque[float]] = [deque(maxlen=window) for _ in range(n_ranks)]
        self.strikes: List[int] = [0] * n_ranks
        self.step = 0

    def update(self, per_rank_seconds) -> List[StragglerDecision]:
        """Feed one step's wall time per rank; returns decisions (may be [])."""
        self.step += 1
        if isinstance(per_rank_seconds, (int, float)):
            per_rank_seconds = [float(per_rank_seconds)]
        decisions: List[StragglerDecision] = []
        med = sorted(per_rank_seconds)[len(per_rank_seconds) // 2]
        for r, dt in enumerate(per_rank_seconds):
            self.hist[r].append(dt)
            base = sorted(self.hist[r])[len(self.hist[r]) // 2]
            ref = max(min(base, med), 1e-9)
            ratio = dt / ref
            if ratio >= self.warn_ratio and len(self.hist[r]) >= 4:
                self.strikes[r] += 1
            else:
                self.strikes[r] = 0
            if self.strikes[r] >= self.patience:
                action = "evict" if ratio >= self.evict_ratio else "warn"
                decisions.append(StragglerDecision(self.step, r, ratio, action))
                if action == "evict":
                    self.strikes[r] = 0
        return decisions

    def summary(self) -> Dict[str, float]:
        flat = [dt for h in self.hist for dt in h]
        if not flat:
            return {"mean_s": 0.0, "p95_s": 0.0}
        flat = sorted(flat)
        return {
            "mean_s": sum(flat) / len(flat),
            "p95_s": flat[int(0.95 * (len(flat) - 1))],
        }
