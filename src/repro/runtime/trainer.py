"""Fault-tolerant training loop.

Checkpoint/restart semantics: the data stream is a pure function of the step
counter (repro.data), so (params, opt_state, data step) restored from the
last checkpoint resumes the *exact* gradient sequence.  Failures (real or
injected) roll back to the last checkpoint and replay; straggler decisions
are logged via StragglerMonitor.  Gradient int8 compression (error feedback)
is applied at the reduction point when enabled.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt.store import CheckpointManager, latest_step
from repro.configs.base import ModelConfig
from repro.models.api import get_model
from repro.optim.adamw import AdamWConfig, OptState, adamw_init, adamw_update
from repro.optim.compression import (
    CompressionState, compress_gradients, compression_init,
)
from repro.runtime.straggler import StragglerMonitor
from repro.sharding.rules import TRAIN_RULES


@dataclasses.dataclass
class TrainerConfig:
    steps: int = 100
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    keep: int = 2
    async_ckpt: bool = False
    log_every: int = 10
    compress_grads: bool = False
    accum: int = 1                      # gradient accumulation microbatches
    inject_failure_at: Optional[int] = None
    max_restarts: int = 3
    seed: int = 0


class _InjectedFailure(RuntimeError):
    pass


class Trainer:
    def __init__(self, model_cfg: ModelConfig, opt_cfg: AdamWConfig,
                 tcfg: TrainerConfig, dataset, *, mesh=None,
                 rules=TRAIN_RULES, log: Callable[[str], None] = print):
        self.model = get_model(model_cfg)
        self.cfg = model_cfg
        self.opt_cfg = opt_cfg
        self.tcfg = tcfg
        self.ds = dataset
        self.mesh = mesh
        self.rules = rules
        self.log = log
        self.monitor = StragglerMonitor(1)
        self.history: list[Dict[str, float]] = []
        self.restarts = 0
        self._build()

    # ------------------------------------------------------------------
    def _build(self):
        model, cfg = self.model, self.cfg
        rules, mesh = self.rules, self.mesh
        opt_cfg = self.opt_cfg
        accum = self.tcfg.accum
        compress = self.tcfg.compress_grads

        def loss_fn(params, batch):
            return model.loss_fn(params, batch, rules=rules, mesh=mesh)

        def train_step(params, opt_state, comp_state, batch):
            if accum > 1:
                def micro(carry, mb):
                    acc, = carry
                    (_, m), g = jax.value_and_grad(loss_fn, has_aux=True)(
                        params, mb)
                    return (jax.tree.map(jnp.add, acc, g),), m["ce"]

                zeros = jax.tree.map(jnp.zeros_like, params)
                mbs = jax.tree.map(
                    lambda x: x.reshape((accum, -1) + x.shape[1:]), batch)
                (gsum,), ces = jax.lax.scan(micro, (zeros,), mbs)
                grads = jax.tree.map(lambda g: g / accum, gsum)
                metrics = {"ce": jnp.mean(ces), "aux": jnp.float32(0)}
                loss = metrics["ce"]
            else:
                (loss, metrics), grads = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, batch)
            if compress:
                grads, comp_state, cm = compress_gradients(grads, comp_state)
                metrics = {**metrics, **cm}
            params, opt_state, om = adamw_update(grads, opt_state, params,
                                                 opt_cfg)
            return params, opt_state, comp_state, {
                "loss": loss, **metrics, **om}

        self._step_fn = jax.jit(train_step, donate_argnums=(0, 1, 2))

    # ------------------------------------------------------------------
    def _init_state(self):
        params = self.model.init(jax.random.PRNGKey(self.tcfg.seed))
        opt = adamw_init(params)
        comp = compression_init(params) if self.tcfg.compress_grads else \
            CompressionState(residual=jnp.zeros(()))
        return params, opt, comp

    def _restore_or_init(self, mgr: Optional[CheckpointManager]):
        params, opt, comp = self._init_state()
        start = 0
        if mgr and latest_step(mgr.ckpt_dir) is not None:
            (params, opt, comp), step, extra = mgr.restore((params, opt, comp))
            start = int(extra.get("data_step", step))
            self.log(f"[trainer] restored checkpoint step={step}")
        return params, opt, comp, start

    # ------------------------------------------------------------------
    def run(self) -> Dict[str, Any]:
        tcfg = self.tcfg
        mgr = (CheckpointManager(tcfg.ckpt_dir, keep=tcfg.keep,
                                 async_save=tcfg.async_ckpt)
               if tcfg.ckpt_dir else None)
        params, opt, comp, start = self._restore_or_init(mgr)
        self.ds.state.step = start
        step = start
        injected = False

        while step < tcfg.steps:
            try:
                batch = next(self.ds)
                batch = {k: jnp.asarray(v) for k, v in batch.items()}
                if (tcfg.inject_failure_at is not None
                        and step == tcfg.inject_failure_at and not injected):
                    injected = True
                    raise _InjectedFailure(f"injected fault at step {step}")
                t0 = time.time()
                params, opt, comp, metrics = self._step_fn(
                    params, opt, comp, batch)
                loss = float(metrics["loss"])
                dt = time.time() - t0
                for d in self.monitor.update(dt):
                    self.log(f"[straggler] step={d.step} rank={d.rank} "
                             f"ratio={d.ratio:.2f} action={d.action}")
                self.history.append({"step": step, "loss": loss, "dt": dt})
                if step % tcfg.log_every == 0:
                    self.log(f"[trainer] step={step} loss={loss:.4f} "
                             f"({dt*1000:.0f} ms)")
                step += 1
                self.ds.state.step = step
                if mgr and step % tcfg.ckpt_every == 0:
                    mgr.save(step, (params, opt, comp),
                             extra={"data_step": step})
            except _InjectedFailure as e:
                self.log(f"[trainer] FAILURE: {e}; restarting from checkpoint")
                self.restarts += 1
                if self.restarts > tcfg.max_restarts:
                    raise
                if mgr:
                    mgr.wait()
                params, opt, comp, step = self._restore_or_init(mgr)
                self.ds.state.step = step

        if mgr:
            mgr.save(step, (params, opt, comp), extra={"data_step": step})
            mgr.wait()
        return {
            "final_loss": self.history[-1]["loss"] if self.history else None,
            "history": self.history,
            "restarts": self.restarts,
            "straggler": self.monitor.summary(),
        }
