"""jit'd wrapper for the chunked linear-attention kernel."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.linattn_scan.kernel import linattn_grouped


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def linattn(r, k, v, logw, u, *, chunk: int = 128, interpret: bool = True):
    """[B, H, S, K] inputs; pads S to a chunk multiple (decay 0 on padding)."""
    B, H, S, K = r.shape
    chunk = min(chunk, S) if S else chunk
    pad = (-S) % chunk
    if pad:
        zp = lambda a: jnp.pad(a, ((0, 0), (0, 0), (0, pad), (0, 0)))
        r, k, v, logw = zp(r), zp(k), zp(v), zp(logw)
    y = linattn_grouped(r, k, v, logw, u, chunk=chunk, interpret=interpret)
    return y[:, :, :S]
