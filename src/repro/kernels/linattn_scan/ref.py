"""Token-by-token recurrence oracle for the chunked linear-attention kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def linattn_reference(r, k, v, logw, u):
    """r/k/v/logw: [B, H, S, K]; u: [H, K] -> y [B, H, S, K] (f32 math)."""
    B, H, S, K = r.shape
    rf, kf, vf = (a.astype(jnp.float32) for a in (r, k, v))
    w = jnp.exp(logw.astype(jnp.float32))
    uf = u.astype(jnp.float32)

    def step(state, inp):
        rt, kt, vt, wt = inp            # [B, H, K] each
        kv = jnp.einsum("bhk,bhv->bhkv", kt, vt)
        y = jnp.einsum("bhk,bhkv->bhv", rt, state + uf[None, :, :, None] * kv)
        state = wt[..., None] * state + kv
        return state, y

    xs = tuple(a.transpose(2, 0, 1, 3) for a in (rf, kf, vf, w))
    _, ys = jax.lax.scan(step, jnp.zeros((B, H, K, K), jnp.float32), xs)
    return ys.transpose(1, 2, 0, 3).astype(r.dtype)
