"""Chunked linear-attention Pallas kernel with per-channel data-dependent
decay (RWKV6 WKV / SSD-style recurrence).

    S_t = diag(w_t) S_{t-1} + k_t v_t^T          w_t = exp(logw_t) in (0,1)
    y_t = r_t . (S_{t-1} + diag(u) k_t v_t^T)

Grid: (B, H, num_chunks) with the chunk axis innermost; the [K, K] state
lives in VMEM scratch across grid steps (sequential on TPU).  Within a
chunk the recurrence is closed-form: two matmuls with decay-factored
r'/k' (flash-linear-attention chunk trick) — MXU work, K in {64, 128}.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _linattn_kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, y_ref, state_ref,
                    *, chunk: int, K: int):
    c = pl.program_id(2)

    @pl.when(c == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    r = r_ref[0, 0].astype(jnp.float32)      # [Q, K]
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    lw = lw_ref[0, 0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)         # [K]

    E = jnp.cumsum(lw, axis=0)               # inclusive log-decay products
    Eex = E - lw                             # exclusive (through t-1)

    # Intra-chunk pairwise weights in log space: exponent
    # Eex[t,k] - E[s,k] = sum_{j=s+1..t-1} logw_j <= 0 for t > s, so this is
    # unconditionally overflow-free (the factored exp(+E)/exp(-E) trick is
    # not — it blows up for steep decays x long chunks).
    ti = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    si = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    seg = Eex[:, None, :] - E[None, :, :]            # [Q, Q, K]
    seg = jnp.where((ti > si)[:, :, None], seg, -jnp.inf)
    att = jnp.sum(r[:, None, :] * k[None, :, :] * jnp.exp(seg), axis=-1)

    diag = jnp.sum(r * u[None, :] * k, axis=1)            # [Q]
    y = jax.lax.dot_general(att, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    y = y + diag[:, None] * v
    r_dec = r * jnp.exp(Eex)                 # Eex <= 0: stable
    y = y + jax.lax.dot_general(r_dec, state_ref[...],
                                (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
    y_ref[0, 0] = y.astype(y_ref.dtype)

    Eq = E[-1]                                             # [K]
    kw = k * jnp.exp(Eq[None, :] - E)
    state_ref[...] = (
        jnp.exp(Eq)[:, None] * state_ref[...]
        + jax.lax.dot_general(kw, v, (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)
    )


def linattn_grouped(
    r: jax.Array,      # [B, H, S, K]
    k: jax.Array,
    v: jax.Array,
    logw: jax.Array,   # [B, H, S, K] log decay (< 0)
    u: jax.Array,      # [H, K] bonus for the current token
    *,
    chunk: int = 128,
    interpret: bool = False,
) -> jax.Array:
    B, H, S, K = r.shape
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk
    kern = functools.partial(_linattn_kernel, chunk=chunk, K=K)
    return pl.pallas_call(
        kern,
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, K), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, chunk, K), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, chunk, K), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, chunk, K), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, K), lambda b, h, c: (h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, chunk, K), lambda b, h, c: (b, h, c, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, K), r.dtype),
        scratch_shapes=[pltpu.VMEM((K, K), jnp.float32)],
        interpret=interpret,
    )(r, k, v, logw, u)
