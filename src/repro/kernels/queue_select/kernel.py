"""Tiled masked lexicographic argmin — the scheduler's hot loop on TPU.

The paper's policy selectors reduce to: among feasible waiting jobs, find
the one minimizing (priority, index).  For million-job tables this is a
bandwidth-bound 1-D reduction; the kernel streams (score, feasible) tiles
through VMEM keeping the running (best_score, best_index) pair in scratch.

Grid: (num_tiles,) sequential; scratch: two (1,1) i32 cells.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BIG = 2**30 - 1  # python literal: inlined into the kernel, not captured


def _select_kernel(score_ref, mask_ref, out_ref, best_s, best_i, *, tile: int,
                   n_valid: int, num_tiles: int):
    t = pl.program_id(0)

    @pl.when(t == 0)
    def _init():
        best_s[0, 0] = jnp.int32(BIG)
        best_i[0, 0] = jnp.int32(-1)

    idx = t * tile + jax.lax.broadcasted_iota(jnp.int32, (1, tile), 1)
    feasible = (mask_ref[...] != 0) & (idx < n_valid)
    s = jnp.where(feasible, score_ref[...], BIG)
    tile_best = jnp.min(s)
    # first index achieving the tile minimum
    cand = jnp.where(feasible & (s == tile_best), idx, BIG)
    tile_idx = jnp.min(cand)

    better = (tile_best < best_s[0, 0]) & (tile_idx < BIG)
    best_i[0, 0] = jnp.where(better, tile_idx, best_i[0, 0])
    best_s[0, 0] = jnp.where(better, tile_best, best_s[0, 0])

    @pl.when(t == num_tiles - 1)
    def _fin():
        out_ref[0, 0] = best_i[0, 0]
        out_ref[0, 1] = best_s[0, 0]


def queue_select_blocked(scores: jax.Array, feasible: jax.Array, *,
                         tile: int = 1024) -> jax.Array:
    """Compiled lowering for backends without the Pallas TPU path.

    Same two-stage blocked reduction the kernel performs — per-tile
    (min, first-index) then a cross-tile min — expressed as reshaped
    ``jnp`` reductions so XLA:CPU/GPU emit vectorized loops over
    contiguous ``tile``-wide rows.  Bit-identical to
    ``queue_select_reference`` for every input, including the corner
    where a *feasible* entry carries score ``BIG`` (the reference
    returns its index; scores are pinned < ``BIG`` by the callers).
    """
    N = scores.shape[0]
    feas = feasible.astype(bool)
    s = jnp.where(feas, scores, BIG)
    pad = (-N) % tile
    if pad:
        s = jnp.pad(s, (0, pad), constant_values=BIG)
        feas = jnp.pad(feas, (0, pad))
    nt = s.shape[0] // tile
    st = s.reshape(nt, tile)
    best = jnp.min(jnp.min(st, axis=1))
    idx = jnp.arange(s.shape[0], dtype=jnp.int32).reshape(nt, tile)
    cand = jnp.where(feas.reshape(nt, tile) & (st == best), idx, BIG)
    bi = jnp.min(jnp.min(cand, axis=1))
    found = bi < BIG
    return jnp.stack([jnp.where(found, bi, -1).astype(jnp.int32),
                      jnp.where(found, best, BIG).astype(jnp.int32)])


def queue_select_tiled(scores: jax.Array, feasible: jax.Array, *,
                       tile: int = 1024, interpret: bool = False) -> jax.Array:
    """scores i32[N], feasible i32[N] -> i32[2] = (argmin index or -1, min)."""
    N = scores.shape[0]
    pad = (-N) % tile
    if pad:
        scores = jnp.pad(scores, (0, pad), constant_values=BIG)
        feasible = jnp.pad(feasible, (0, pad))
    nt = (N + pad) // tile
    kern = functools.partial(_select_kernel, tile=tile, n_valid=N,
                             num_tiles=nt)
    out = pl.pallas_call(
        kern,
        grid=(nt,),
        in_specs=[
            pl.BlockSpec((1, tile), lambda t: (0, t)),
            pl.BlockSpec((1, tile), lambda t: (0, t)),
        ],
        out_specs=pl.BlockSpec((1, 2), lambda t: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 2), jnp.int32),
        scratch_shapes=[pltpu.SMEM((1, 1), jnp.int32),
                        pltpu.SMEM((1, 1), jnp.int32)],
        interpret=interpret,
    )(scores.reshape(1, -1), feasible.astype(jnp.int32).reshape(1, -1))
    return out[0]
