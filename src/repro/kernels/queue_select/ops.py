"""jit'd wrapper for queue_select."""

from __future__ import annotations

import functools

import jax

from repro.kernels.queue_select.kernel import (
    queue_select_blocked, queue_select_tiled,
)


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def queue_select(scores, feasible, *, tile: int = 1024,
                 interpret: bool | None = None):
    """Masked lex-argmin: returns i32[2] (index or -1, best score).

    ``interpret=None`` (the default) selects a *compiled* lowering for the
    active backend: the Pallas kernel on TPU, the blocked ``jnp`` reduction
    everywhere else (the kernel's SMEM scratch has no CPU/GPU lowering).
    Pass ``interpret=True`` to force the Pallas interpreter (debugging
    escape hatch — orders of magnitude slower) or ``interpret=False`` to
    force the compiled Pallas kernel regardless of backend.
    """
    if interpret is None:
        if jax.default_backend() == "tpu":
            return queue_select_tiled(scores, feasible, tile=tile,
                                      interpret=False)
        return queue_select_blocked(scores, feasible, tile=tile)
    return queue_select_tiled(scores, feasible, tile=tile, interpret=interpret)
