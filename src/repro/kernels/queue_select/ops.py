"""jit'd wrapper for queue_select."""

from __future__ import annotations

import functools

import jax

from repro.kernels.queue_select.kernel import queue_select_tiled


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def queue_select(scores, feasible, *, tile: int = 1024, interpret: bool = True):
    """Masked lex-argmin: returns i32[2] (index or -1, best score)."""
    return queue_select_tiled(scores, feasible, tile=tile, interpret=interpret)
