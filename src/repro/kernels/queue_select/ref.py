"""Oracle for queue_select: masked lexicographic argmin in pure jnp."""

from __future__ import annotations

import jax.numpy as jnp

BIG = 2**30 - 1


def queue_select_reference(scores, feasible):
    s = jnp.where(feasible.astype(bool), scores, BIG)
    best = jnp.min(s)
    idx = jnp.where(feasible.astype(bool) & (s == best),
                    jnp.arange(s.shape[0], dtype=jnp.int32), BIG)
    bi = jnp.min(idx)
    found = bi < BIG
    return jnp.stack([jnp.where(found, bi, -1).astype(jnp.int32),
                      jnp.where(found, best, BIG).astype(jnp.int32)])
