"""Pure-jnp oracle for flash attention (naive, materializes scores)."""

from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

NEG_INF = -1e30


def attention_reference(
    q, k, v, *, causal: bool = True, window: Optional[int] = None,
    q_offset: int = 0,
):
    """q: [B, Sq, H, hd]; k/v: [B, Sk, KV, hd] -> [B, Sq, H, hd], f32 math."""
    B, Sq, H, hd = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    G = H // KV
    qf = q.astype(jnp.float32).reshape(B, Sq, KV, G, hd)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qf, kf) * (hd ** -0.5)
    q_pos = q_offset + jnp.arange(Sq)
    k_pos = jnp.arange(Sk)
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= q_pos[:, None] >= k_pos[None, :]
    if window is not None:
        mask &= q_pos[:, None] - k_pos[None, :] < window
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
    o = jnp.einsum("bkgqs,bskd->bkgqd", p, vf)
    return o.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, hd).astype(q.dtype)
