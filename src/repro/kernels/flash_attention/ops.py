"""Public jit'd wrapper: layout plumbing + padding around the Pallas kernel."""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_grouped


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "q_offset", "block_q", "block_k",
                     "interpret"),
)
def flash_attention(
    q: jax.Array,   # [B, Sq, H, hd]
    k: jax.Array,   # [B, Sk, KV, hd]
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    q_offset: int = 0,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,   # CPU container default; False on real TPU
) -> jax.Array:
    B, Sq, H, hd = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    G = H // KV
    block_q = min(block_q, max(Sq, 1))
    block_k = min(block_k, max(Sk, 1))
    pq = (-Sq) % block_q
    pk = (-Sk) % block_k

    qg = q.reshape(B, Sq, KV, G, hd).transpose(0, 2, 3, 1, 4)  # [B,KV,G,Sq,hd]
    kg = k.transpose(0, 2, 1, 3)                               # [B,KV,Sk,hd]
    vg = v.transpose(0, 2, 1, 3)
    if pq:
        qg = jnp.pad(qg, ((0, 0), (0, 0), (0, 0), (0, pq), (0, 0)))
    if pk:
        kg = jnp.pad(kg, ((0, 0), (0, 0), (0, pk), (0, 0)))
        vg = jnp.pad(vg, ((0, 0), (0, 0), (0, pk), (0, 0)))

    o = flash_attention_grouped(
        qg, kg, vg, causal=causal, window=window, q_offset=q_offset,
        block_q=block_q, block_k=block_k, seq_k_valid=Sk,
        interpret=interpret,
    )
    o = o[..., :Sq, :].transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, hd)
    return o
