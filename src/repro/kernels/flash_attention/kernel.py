"""Flash attention Pallas TPU kernel (online softmax over KV tiles).

Grid: (B, KV_heads, G, num_q_blocks, num_kv_blocks) — the kv-block axis is
innermost, so on TPU the kernel streams K/V tiles through VMEM while the
(m, l, acc) accumulators live in VMEM scratch across grid steps.  Causal
blocks above the diagonal are skipped with ``pl.when`` (no MXU work issued).

Block shapes are MXU-aligned: block_q x head_dim and block_k x head_dim with
head_dim in {64, 128} and blocks multiples of 128 (pad upstream).  GQA is
expressed in the grid (KV x G) so KV tiles are fetched once per G=heads/kv
group — the HBM->VMEM K/V traffic is the GQA-optimal schedule.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref,          # VMEM tiles
    o_ref,                        # output tile (revisited across kv blocks)
    m_ref, l_ref, acc_ref,        # VMEM scratch accumulators
    *, block_q: int, block_k: int, num_kv_blocks: int,
    causal: bool, window: Optional[int], q_offset: int, seq_k: int,
    scale: float,
):
    qi = pl.program_id(3)
    kj = pl.program_id(4)

    @pl.when(kj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_pos = q_offset + qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)
    k_pos = kj * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)

    # whole-block skip: the earliest q in this tile vs latest k
    first_q = q_offset + qi * block_q
    last_q = first_q + block_q - 1
    first_k = kj * block_k
    run = True
    if causal:
        run = first_k <= last_q
    if window is not None:
        run = jnp.logical_and(run, first_k + block_k - 1 > first_q - window)

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0, 0].astype(jnp.float32)          # [block_q, hd]
        k = k_ref[0, 0].astype(jnp.float32)             # [block_k, hd]
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale                                        # [block_q, block_k]
        mask = k_pos < seq_k
        if causal:
            mask = jnp.logical_and(mask, q_pos >= k_pos)
        if window is not None:
            mask = jnp.logical_and(mask, q_pos - k_pos < window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_ref[...] = m_new

    @pl.when(kj == num_kv_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_grouped(
    q: jax.Array,   # [B, KV, G, Sq, hd]
    k: jax.Array,   # [B, KV, Sk, hd]
    v: jax.Array,   # [B, KV, Sk, hd]
    *,
    causal: bool = True,
    window: Optional[int] = None,
    q_offset: int = 0,
    block_q: int = 128,
    block_k: int = 128,
    seq_k_valid: Optional[int] = None,
    interpret: bool = False,
) -> jax.Array:
    B, KV, G, Sq, hd = q.shape
    Sk = k.shape[2]
    assert Sq % block_q == 0 and Sk % block_k == 0, (Sq, Sk, block_q, block_k)
    nq, nk = Sq // block_q, Sk // block_k
    seq_k = seq_k_valid if seq_k_valid is not None else Sk
    kern = functools.partial(
        _flash_kernel,
        block_q=block_q, block_k=block_k, num_kv_blocks=nk,
        causal=causal, window=window, q_offset=q_offset, seq_k=seq_k,
        scale=hd ** -0.5,
    )
    return pl.pallas_call(
        kern,
        grid=(B, KV, G, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, 1, block_q, hd),
                         lambda b, h, g, i, j: (b, h, g, i, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b, h, g, i, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b, h, g, i, j: (b, h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, block_q, hd),
                               lambda b, h, g, i, j: (b, h, g, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KV, G, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),       # running max m
            pltpu.VMEM((block_q,), jnp.float32),       # running denom l
            pltpu.VMEM((block_q, hd), jnp.float32),    # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
