"""Malleable jobs: moldable width selection + elastic grow/shrink
(DESIGN.md §17)."""

from repro.malleable.model import (
    MalleableModel,
    MalleablePlan,
    make_mal_ctx,
    materialize_plan,
)

__all__ = [
    "MalleableModel",
    "MalleablePlan",
    "make_mal_ctx",
    "materialize_plan",
]
