"""Malleable jobs: moldable width selection and elastic grow/shrink
(DESIGN.md §17, two-level resource management).

A :class:`MalleableModel` is a frozen host-side spec of a speedup curve —
Amdahl (``param`` = serial fraction), power-law (``param`` = alpha,
``S(w) = w**alpha``) or a tabulated per-width efficiency — over a global
``[min_width, max_width]`` range, plus the malleability mode:

- ``"moldable"``: the scheduler picks each job's width once, at dispatch,
  as the placement-feasible width with the minimum dilated runtime
  (ties to the narrowest width);
- ``"elastic"``: moldable dispatch *plus* grow/shrink of running jobs at
  §16-style capacity ticks under queue pressure, and shrink-instead-of-
  requeue when a §15 node failure hits a job that still has width to give.

``materialize_plan`` lowers the spec against a concrete job trace to a
padded per-job width/dilation table ``dur[j, k] = ceil(runtime_j *
S(nref_j) / S(min_width + k))`` — row-aligned with the sorted job table by
replicating ``make_jobset``'s normalization — which both engines consume
through :func:`make_mal_ctx`.  Curve kind and parameters, tick interval and
every pressure threshold are trace *data* (the dur table and ctx scalars):
a curve sweep batches through ``vmap`` into ONE executable; the only static
axes are the width-range shape ``W = max_width - min_width + 1`` and the
elastic tick capacity ``max_ticks``.  ``malleable=None`` statically elides
the whole subsystem to the byte-identical pre-change HLO.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional, Tuple

import numpy as np

# The int32 "infinite time" sentinel, == repro.core.jobs.INF_TIME (imported
# late to keep this module import-light; asserted equal at materialization).
INF_TIME = np.int32(2**30 - 1)

_CURVES = ("amdahl", "power", "table")
_MODES = ("moldable", "elastic")


@dataclasses.dataclass(frozen=True)
class MalleableModel:
    """Frozen malleability spec for a :class:`repro.api.Scenario`.

    ``curve``/``param``/``table`` pick the speedup curve ``S(w)``:

    - ``"amdahl"``: ``S(w) = 1 / (param + (1 - param) / w)`` with
      ``param`` the serial fraction in ``[0, 1]``;
    - ``"power"``: ``S(w) = w ** param`` with ``param`` in ``(0, 1]``;
    - ``"table"``: ``S(w) = w * table[w - min_width]`` with ``table`` the
      per-width parallel efficiency in ``(0, 1]``, one entry per width.

    Every job's *reference* width is its (clamped) node request; running at
    width ``w`` dilates its runtime by ``S(nref) / S(w)`` (exact at
    ``w == nref``).  In ``"elastic"`` mode, capacity ticks at
    ``k * interval`` (``k = 1..max_ticks``) compare the queued node demand
    against the hysteresis band: demand ``>= shrink_threshold`` shrinks the
    widest running job by up to ``step`` nodes (freeing room for the
    queue); demand ``<= grow_threshold`` grows the narrowest running job
    into idle nodes.  Everything except ``min_width``/``max_width``/
    ``mode``/``max_ticks`` is vmap data: curve and threshold sweeps
    compile once (``repro.api.sweep``).
    """

    curve: str = "amdahl"
    param: float = 0.1
    table: Optional[Tuple[float, ...]] = None
    min_width: int = 1
    max_width: int = 8
    mode: str = "moldable"
    interval: int = 60
    max_ticks: int = 256
    shrink_threshold: int = 1
    grow_threshold: int = 0
    step: int = 1

    def __post_init__(self):
        if self.curve not in _CURVES:
            raise ValueError(
                f"unknown curve {self.curve!r}; known: {_CURVES}")
        if self.curve == "amdahl" and not 0.0 <= self.param <= 1.0:
            raise ValueError(
                f"amdahl serial fraction must be in [0, 1], got {self.param}")
        if self.curve == "power" and not 0.0 < self.param <= 1.0:
            raise ValueError(
                f"power-law alpha must be in (0, 1], got {self.param}")
        if not 1 <= self.min_width <= self.max_width:
            raise ValueError(
                f"need 1 <= min_width <= max_width, got "
                f"[{self.min_width}, {self.max_width}]")
        if self.curve == "table":
            n_w = self.max_width - self.min_width + 1
            if self.table is None or len(self.table) != n_w:
                raise ValueError(
                    f"table curve needs one efficiency per width "
                    f"({n_w} entries for [{self.min_width}, "
                    f"{self.max_width}]), got "
                    f"{None if self.table is None else len(self.table)}")
            if any(not 0.0 < e <= 1.0 for e in self.table):
                raise ValueError("table efficiencies must lie in (0, 1]")
        elif self.table is not None:
            raise ValueError("table is only meaningful with curve='table'")
        if self.mode not in _MODES:
            raise ValueError(f"unknown mode {self.mode!r}; known: {_MODES}")
        if self.mode == "elastic":
            if self.interval < 1:
                raise ValueError("interval must be >= 1")
            if self.max_ticks < 1:
                raise ValueError("elastic mode needs max_ticks >= 1")
            if self.step < 1:
                raise ValueError("step must be >= 1")
            if (self.grow_threshold < 0
                    or self.shrink_threshold <= self.grow_threshold):
                raise ValueError(
                    "hysteresis requires 0 <= grow_threshold < "
                    f"shrink_threshold, got grow={self.grow_threshold} "
                    f"shrink={self.shrink_threshold}")

    def static_key(self) -> tuple:
        """Compile-bucket contribution: the width-range shape and the
        padded elastic tick capacity are the only static axes — curve
        kind/parameters, interval and thresholds are vmap data."""
        return ("malleable", self.min_width, self.max_width, self.mode,
                self.max_ticks if self.mode == "elastic" else 0)

    def speedup(self, widths: np.ndarray) -> np.ndarray:
        """``S(w)`` over a float array of widths (host-side, float64)."""
        w = np.asarray(widths, dtype=np.float64)
        if self.curve == "amdahl":
            f = float(self.param)
            return 1.0 / (f + (1.0 - f) / w)
        if self.curve == "power":
            return w ** float(self.param)
        eff = np.asarray(self.table, dtype=np.float64)
        return w * eff[np.asarray(widths, dtype=np.int64) - self.min_width]


@dataclasses.dataclass(frozen=True, eq=False)
class MalleablePlan:
    """Materialized malleability plan (host arrays; both engines consume
    this).  ``dur[j, k]`` is job *j*'s dilated runtime at width
    ``min_width + k``, row-aligned with the (submit, id)-sorted padded job
    table; ``nref[j]`` its reference width (padding rows: dur = 1,
    nref = min_width).  ``tick_time`` is the padded elastic tick stream
    (shape ``[0]`` in moldable mode)."""

    dur: np.ndarray        # i32[J_cap, W] dilated runtime per width
    nref: np.ndarray       # i32[J_cap] reference (requested) width
    tick_time: np.ndarray  # i32[T] elastic tick clock; [0] = moldable
    min_width: int
    max_width: int
    step: int
    shrink_threshold: int
    grow_threshold: int
    n_jobs: int            # real (unpadded) job count

    @property
    def capacity(self) -> int:
        return int(self.dur.shape[0])

    @property
    def n_widths(self) -> int:
        return int(self.dur.shape[1])


def materialize_plan(model: MalleableModel, trace: Dict[str, np.ndarray], *,
                     total_nodes: int,
                     capacity: Optional[int] = None) -> MalleablePlan:
    """Lower a :class:`MalleableModel` against a concrete job trace.

    Replicates ``make_jobset``'s normalization (0-based submit, >= 1
    clamps, node requests capped at the machine, (submit, id) lexsort,
    padding) so the plan rows align with the padded job table in BOTH
    engines.  Raises on int32 clock overflow of the *dilated* horizon and
    on node-second accumulator overflow (the §15/§16 overflow-guard
    pattern, at the wider malleable bound).
    """
    from repro.core.jobs import INF_TIME as _engine_inf

    assert INF_TIME == _engine_inf, "sentinel drifted from repro.core.jobs"
    if model.min_width > int(total_nodes):
        raise ValueError(
            f"min_width={model.min_width} exceeds the machine "
            f"({total_nodes} nodes); no malleable job could ever start")

    submit = np.asarray(trace["submit"], dtype=np.int64)
    runtime = np.asarray(trace["runtime"], dtype=np.int64)
    nodes = np.asarray(trace["nodes"], dtype=np.int64)
    est = trace.get("estimate")
    estimate = (np.asarray(est, dtype=np.int64) if est is not None
                else runtime.copy())
    n = submit.shape[0]
    submit = submit - (submit.min() if n else 0)
    runtime = np.maximum(runtime, 1)
    estimate = np.maximum(estimate, 1)
    nodes = np.minimum(np.maximum(nodes, 1), int(total_nodes))
    order = np.lexsort((np.arange(n), submit))
    submit, runtime, estimate, nodes = (
        submit[order], runtime[order], estimate[order], nodes[order])

    wlo, whi = model.min_width, model.max_width
    widths = np.arange(wlo, whi + 1, dtype=np.int64)
    s_w = model.speedup(widths)                       # float64[W]
    nref = np.clip(nodes, wlo, whi)
    s_ref = s_w[nref - wlo]
    # dur[j, k] = ceil(runtime_j * S(nref_j) / S(w_k)); exact runtime at
    # w == nref (the ratio is exactly 1.0 in float64)
    ratio = s_ref[:, None] / s_w[None, :]
    dur = np.maximum(np.ceil(runtime[:, None] * ratio), 1.0)

    dur_max = int(dur.max(initial=1.0))
    top = int(submit.max(initial=0)) + 2 * max(dur_max,
                                               int(estimate.max(initial=1)))
    if top >= int(INF_TIME):
        raise ValueError(
            f"dilated trace horizon overflows the int32 clock: max arrival "
            f"{int(submit.max(initial=0))} + dilated runtimes reaches {top} "
            f">= {int(INF_TIME)}; rescale the trace or widen min_width")
    if whi * top >= 2**31:
        raise ValueError(
            f"node-second accumulator overflows int32: max_width={whi} * "
            f"horizon {top} reaches {whi * top} >= {2**31}; rescale the "
            "trace or narrow max_width")

    cap = int(capacity) if capacity is not None else n
    if cap < n:
        raise ValueError(f"capacity {cap} < number of jobs {n}")
    W = whi - wlo + 1
    dur_pad = np.ones((cap, W), dtype=np.int32)
    dur_pad[:n] = dur.astype(np.int32)
    nref_pad = np.full((cap,), wlo, dtype=np.int32)
    nref_pad[:n] = nref.astype(np.int32)

    if model.mode == "elastic":
        T = model.max_ticks
        ticks = np.arange(1, T + 1, dtype=np.int64) * model.interval
        tick_time = np.minimum(ticks, int(INF_TIME)).astype(np.int32)
    else:
        tick_time = np.zeros((0,), dtype=np.int32)

    return MalleablePlan(
        dur=dur_pad, nref=nref_pad, tick_time=tick_time,
        min_width=int(wlo), max_width=int(whi), step=int(model.step),
        shrink_threshold=int(model.shrink_threshold),
        grow_threshold=int(model.grow_threshold), n_jobs=n,
    )


def make_mal_ctx(malleable):
    """Canonicalize a ``malleable`` argument into the engine's MalCtx.

    Accepts ``None`` (statically elided — the engine compiles the exact
    pre-malleable graph), a :class:`MalleablePlan`, or an already-built
    ctx tuple (the ``vmap`` sweep path — leaves may be tracers).  The ctx
    is the 8-tuple ``(dur, nref, tick_time, min_width, max_width, step,
    shrink_threshold, grow_threshold)`` of i32 device arrays.
    """
    import jax.numpy as jnp

    if malleable is None:
        return None
    if isinstance(malleable, MalleablePlan):
        malleable = (malleable.dur, malleable.nref, malleable.tick_time,
                     malleable.min_width, malleable.max_width,
                     malleable.step, malleable.shrink_threshold,
                     malleable.grow_threshold)
    if not (isinstance(malleable, tuple) and len(malleable) == 8):
        raise TypeError(
            "malleable must be None, a MalleablePlan, or an 8-tuple mal "
            f"ctx; got {type(malleable).__name__}")
    return tuple(jnp.asarray(x, dtype=jnp.int32) for x in malleable)
