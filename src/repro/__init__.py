"""Scalable HPC job scheduling and resource management, reproduced in JAX.

Public surface (DESIGN.md §12):

    from repro import api, Scenario, run, run_ref, sweep

``repro.api`` is the declarative front door — experiment specs, one
``run()`` entry point, generic multi-axis ``sweep()``.  The substrate
subpackages (``core``, ``alloc``, ``traces``, ``refsim``, ``models``, …)
stay importable directly.

Everything here resolves lazily (PEP 562): ``import repro`` performs no
jax import, so entry points that must set ``XLA_FLAGS`` before jax
initializes (``repro.launch.dryrun``, the elastic-restore subprocesses)
keep working with the package on top of them.
"""

from __future__ import annotations

import importlib

_SUBMODULES = frozenset({
    "alloc", "api", "ckpt", "configs", "core", "data", "kernels", "launch",
    "malleable", "models", "optim", "refsim", "reliability", "replay",
    "runtime", "service", "serving", "sharding", "traces",
})

# names re-exported from repro.api on first access
_API_NAMES = frozenset({
    "ArrayTrace", "AutoscalePolicy", "FailureModel", "InjectedTrace",
    "MalleableModel", "Multicluster", "Result", "Scenario", "ServiceClass",
    "ServiceTrace", "SweepResult", "SwfTrace", "SyntheticTrace", "Topology",
    "WorkflowTrace", "run", "run_ref", "sweep",
})

__all__ = sorted(_SUBMODULES | _API_NAMES)


def __getattr__(name: str):
    if name in _SUBMODULES:
        return importlib.import_module(f"repro.{name}")
    if name in _API_NAMES:
        return getattr(importlib.import_module("repro.api"), name)
    raise AttributeError(f"module 'repro' has no attribute {name!r}")


def __dir__():
    return __all__
