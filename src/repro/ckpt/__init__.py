from repro.ckpt.store import (  # noqa: F401
    CheckpointManager, latest_step, load_checkpoint, load_checkpoint_raw,
    save_checkpoint,
)
