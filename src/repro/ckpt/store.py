"""Checkpointing: one .npy per leaf + JSON manifest, atomic, elastic restore.

- **atomic**: writes land in ``<dir>/tmp.<step>`` then a single rename
  publishes ``step_<n>``; a crash mid-write never corrupts the latest.
- **integrity**: every leaf records crc32 in the manifest, verified on load.
- **elastic**: leaves are stored unsharded (gathered); ``load_checkpoint``
  re-device_puts onto whatever sharding tree the *current* mesh provides, so
  restarts may change device count / mesh shape freely (tested 8 -> 4 devs).
- **async**: ``CheckpointManager(async_save=True)`` snapshots to host then
  writes in a daemon thread, keeping the train loop running.
"""

from __future__ import annotations

import dataclasses
import json
import os
import shutil
import threading
import zlib
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    def key(path):
        return "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
    return [(key(p), l) for p, l in leaves], treedef


def save_checkpoint(ckpt_dir: str, step: int, tree: Any, *,
                    extra: Optional[dict] = None, keep: int = 3) -> str:
    """Write tree -> <ckpt_dir>/step_<step>/ atomically. Returns final path."""
    os.makedirs(ckpt_dir, exist_ok=True)
    tmp = os.path.join(ckpt_dir, f"tmp.{step}")
    final = os.path.join(ckpt_dir, f"step_{step}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    leaves, _ = _flatten(tree)
    manifest = {"step": step, "leaves": [], "extra": extra or {}}
    for i, (key, leaf) in enumerate(leaves):
        arr = np.asarray(leaf)
        fname = f"leaf_{i:05d}.npy"
        np.save(os.path.join(tmp, fname), arr)
        manifest["leaves"].append({
            "key": key, "file": fname, "shape": list(arr.shape),
            "dtype": str(arr.dtype), "crc32": zlib.crc32(arr.tobytes()),
        })
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _gc(ckpt_dir, keep)
    return final


def _gc(ckpt_dir: str, keep: int):
    steps = sorted(
        (int(d.split("_")[1]), d) for d in os.listdir(ckpt_dir)
        if d.startswith("step_")
    )
    for _, d in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_")]
    return max(steps) if steps else None


def load_checkpoint(ckpt_dir: str, template: Any, *, step: Optional[int] = None,
                    shardings: Any = None, verify: bool = True):
    """Restore into ``template``'s structure; reshard onto ``shardings``.

    Returns (tree, step, extra).  Elastic: the stored leaves are global
    arrays; device placement comes entirely from the current ``shardings``.
    """
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    by_key = {l["key"]: l for l in manifest["leaves"]}

    leaves, treedef = _flatten(template)
    shard_leaves = (
        [s for _, s in _flatten(shardings)[0]] if shardings is not None
        else [None] * len(leaves)
    )
    out = []
    for (key, tmpl), shard in zip(leaves, shard_leaves):
        rec = by_key.get(key)
        if rec is None:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = np.load(os.path.join(path, rec["file"]))
        if verify and zlib.crc32(arr.tobytes()) != rec["crc32"]:
            raise IOError(f"crc mismatch for leaf {key!r} in {path}")
        if tuple(arr.shape) != tuple(np.shape(tmpl)):
            raise ValueError(
                f"checkpoint leaf {key!r} has shape {arr.shape}, template "
                f"expects {np.shape(tmpl)} — wrong model/config for this "
                f"checkpoint directory?")
        if shard is not None:
            out.append(jax.device_put(arr, shard))
        else:
            out.append(jax.numpy.asarray(arr))
    tree = jax.tree_util.tree_unflatten(treedef, out)
    return tree, manifest["step"], manifest.get("extra", {})


def load_checkpoint_raw(ckpt_dir: str, *, step: Optional[int] = None,
                        verify: bool = True):
    """Template-free restore: returns ``(leaves, step, extra)`` where
    ``leaves`` maps each flattened key to its host numpy array.

    For consumers whose array shapes are themselves checkpoint state — the
    streaming replay runner's window can double mid-run, so ``resume()``
    cannot build a shape-matching template before reading the manifest.
    crc32 verification is identical to :func:`load_checkpoint`.
    """
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    leaves = {}
    for rec in manifest["leaves"]:
        arr = np.load(os.path.join(path, rec["file"]))
        if verify and zlib.crc32(arr.tobytes()) != rec["crc32"]:
            raise IOError(f"crc mismatch for leaf {rec['key']!r} in {path}")
        leaves[rec["key"]] = arr
    return leaves, manifest["step"], manifest.get("extra", {})


class CheckpointManager:
    """keep-last-k manager with optional async writes."""

    def __init__(self, ckpt_dir: str, *, keep: int = 3, async_save: bool = False):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        self.last_saved: Optional[int] = None

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def save(self, step: int, tree: Any, *, extra: Optional[dict] = None):
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)  # snapshot
        self.wait()
        if self.async_save:
            self._thread = threading.Thread(
                target=save_checkpoint,
                args=(self.ckpt_dir, step, host_tree),
                kwargs={"extra": extra, "keep": self.keep},
                daemon=True,
            )
            self._thread.start()
        else:
            save_checkpoint(self.ckpt_dir, step, host_tree, extra=extra,
                            keep=self.keep)
        self.last_saved = step

    def restore(self, template: Any, *, shardings: Any = None,
                step: Optional[int] = None):
        return load_checkpoint(self.ckpt_dir, template, step=step,
                               shardings=shardings)
