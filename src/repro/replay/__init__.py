"""Crash-safe streaming replay of full-archive traces (DESIGN.md §19).

``replay_trace`` streams an SWF log (or trace dict) through bounded-size
windows — the device never holds more than the active window — with
durable per-round checkpoints; ``resume`` restarts an interrupted run
bit-exact from the last durable round.  CLI::

    python -m repro.replay TRACE.swf.gz --nodes 512 --policy backfill \\
        --ckpt-dir /tmp/ckpt [--resume]
"""

from repro.replay.runner import (  # noqa: F401
    ReplayError, ReplayFlags, ReplayInterrupted, ReplayResult,
    StreamingReplay, replay_trace, resume,
)
