"""CLI for streaming trace replay with checkpoint/resume.

    python -m repro.replay TRACE.swf[.gz] --nodes 512 [--policy backfill]
        [--window 4096] [--ckpt-dir DIR] [--resume] [--out summary.json]

``--resume`` restarts from the last durable round in ``--ckpt-dir``
(the trace and configuration must match the interrupted run); the
result is bit-exact with an uninterrupted one.
"""

import argparse
import json
import sys

from repro.replay.runner import StreamingReplay
from repro.traces.swf import load_swf


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.replay",
        description="Stream an SWF archive trace through the windowed "
                    "scheduler with durable checkpoints.")
    ap.add_argument("trace", help="path to .swf or .swf.gz log")
    ap.add_argument("--nodes", type=int, required=True,
                    help="cluster size (scalar-counter mode)")
    ap.add_argument("--policy", default="fcfs",
                    help="fcfs | sjf | backfill | preempt (default fcfs)")
    ap.add_argument("--window", type=int, default=4096,
                    help="active-window job slots (doubles on overflow)")
    ap.add_argument("--max-jobs", type=int, default=None,
                    help="replay only the first N loaded jobs")
    ap.add_argument("--strict", action="store_true",
                    help="reject malformed SWF lines instead of quarantining")
    ap.add_argument("--ckpt-dir", default=None,
                    help="directory for durable round checkpoints")
    ap.add_argument("--ckpt-every", type=int, default=16,
                    help="checkpoint every K rounds (default 16)")
    ap.add_argument("--resume", action="store_true",
                    help="continue from the last durable round in --ckpt-dir")
    ap.add_argument("--out", default=None,
                    help="write the summary JSON here (default stdout)")
    args = ap.parse_args(argv)

    if args.resume and args.ckpt_dir is None:
        ap.error("--resume requires --ckpt-dir")
    trace, report = load_swf(args.trace, max_jobs=args.max_jobs,
                             strict=args.strict)
    print(report.summary(), file=sys.stderr)
    runner = StreamingReplay(
        trace, args.policy, total_nodes=args.nodes, window=args.window,
        ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every)
    result = runner.run(resume=args.resume)
    summary = {"trace": report.summary(), **result.summary()}
    text = json.dumps(summary, indent=2)
    if args.out:
        with open(args.out, "w") as f:
            f.write(text + "\n")
    else:
        print(text)
    return 0


if __name__ == "__main__":
    sys.exit(main())
