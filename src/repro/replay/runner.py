"""Streaming, crash-safe replay of full-archive traces (DESIGN.md §19).

A full Parallel Workloads Archive log (10^5-10^6 jobs, month-long
horizons) cannot go through one-shot ``simulate``: padded J-sized device
state scales with the whole trace and the int32 clock caps the horizon.
:class:`StreamingReplay` instead drives the trace through bounded-size
**windows**: the device only ever holds the next W not-yet-finished jobs,
each round runs ``simulate_window`` up to the next unadmitted arrival,
finished rows are harvested to int64 host columns, and freed slots are
refilled from the trace cursor.  Clocks are rebased every round — the
host tracks absolute int64 time, the device sees window-relative int32
offsets from the round base ``t0`` — so horizons far beyond int32 never
overflow.

Windowing is *exact*, not approximate: rows are kept compacted in global
(submit, id) order, so every relative-order tie-break the engine performs
(FCFS/SJF selection, backfill's shadow walk, the blocking order, failure
victim cumsums) matches the one-shot run, and a round never processes an
event at or past the first unadmitted submit time, so the engine never
schedules against a partial arrival set.  The composition is therefore
bit-exact against both one-shot ``simulate`` and the host reference
simulator (tests/test_replay.py drives the differential grid).

Crash safety (the degradation ladder, loud-then-soft):

- every ``ckpt_every``-th round the carried state — live rows, harvested
  results, cursor, clocks, flags — lands in ``repro.ckpt.store``
  (atomic rename + crc32); ``resume()`` restarts from the last durable
  round and is bit-exact with an uninterrupted run;
- event-cap **saturation** is detected via ``simulate_window``'s
  ``saturated`` bit; the truncated round is a valid prefix, so the runner
  counts the flag, doubles the cap, and continues;
- **window overflow** (more than W jobs alive at once) is detected as a
  zero-progress round with no free slot; the window doubles (bounded by
  ``max_window_doublings``) before the runner aborts;
- **clock-rebase overflow** (a window-relative time that does not fit
  int32) is flagged, retried once with a doubled window, then aborts.

All three land as typed counters on ``ReplayResult.flags``.
"""

from __future__ import annotations

import dataclasses
import zlib
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt.store import load_checkpoint_raw, save_checkpoint
from repro.core.engine import make_alloc_ctx, simulate_window
from repro.core.jobs import (
    DONE, INF_TIME, PENDING, POLICY_IDS, JobSet, RelState, SimState,
)

# host-side "infinite"/unset sentinel for absolute int64 times; maps to the
# engine's int32 INF_TIME at upload and back at download
INF64 = np.int64(1) << 62

_I32_MIN = -(2 ** 31) + 1


class ReplayError(RuntimeError):
    """The degradation ladder ran out of retries (fail loud)."""


class ReplayInterrupted(RuntimeError):
    """Raised by the crash-injection test hook after a durable round."""


@dataclasses.dataclass
class ReplayFlags:
    """Typed degraded-condition counters (DESIGN.md §19 ladder)."""

    saturated_rounds: int = 0    # rounds that hit the event cap (cap doubled)
    cap_doublings: int = 0
    window_doublings: int = 0    # >W live jobs forced a bigger window
    rebase_overflows: int = 0    # a window-relative time did not fit int32

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "ReplayFlags":
        return cls(**{f.name: int(d.get(f.name, 0))
                      for f in dataclasses.fields(cls)})


@dataclasses.dataclass
class ReplayResult:
    """Per-job outcome columns in global (submit, id) order, absolute int64
    times on the trace's rebased epoch (min submit == 0).  Mirrors the
    one-shot ``SimResult``/refsim schema, so the three compare directly."""

    submit: np.ndarray       # i64[N]
    runtime: np.ndarray      # i64[N]
    estimate: np.ndarray     # i64[N]
    nodes: np.ndarray        # i64[N]
    priority: np.ndarray     # i64[N]
    start: np.ndarray        # i64[N] (-1 if never started, as in refsim)
    finish: np.ndarray       # i64[N] (-1 if never finished)
    wait: np.ndarray         # i64[N] start - submit (traces are dep-free)
    done: np.ndarray         # bool[N] completed (excludes aborted)
    alloc_first: np.ndarray  # i64[N] machine mode (-1 otherwise)
    alloc_span: np.ndarray   # i64[N]
    alloc_sum: np.ndarray    # i64[N]
    n_restarts: np.ndarray   # i64[N] failure mode (0 otherwise)
    lost_work: np.ndarray    # i64[N]
    aborted: np.ndarray      # bool[N]
    makespan: int
    n_events: int
    n_rounds: int
    peak_live: int           # peak window occupancy (<= final window)
    window: int              # final window size after any doublings
    flags: ReplayFlags

    @property
    def n_jobs(self) -> int:
        return int(self.submit.shape[0])

    def summary(self) -> dict:
        """Wait-time / node-usage summaries (the paper's accuracy metrics)."""
        w = self.wait[self.done]
        node_s = (self.nodes * (self.finish - self.start))[self.done]
        used = int(node_s.sum())
        return {
            "n_jobs": self.n_jobs,
            "n_done": int(self.done.sum()),
            "n_aborted": int(self.aborted.sum()),
            "makespan": int(self.makespan),
            "n_events": int(self.n_events),
            "n_rounds": int(self.n_rounds),
            "peak_live": int(self.peak_live),
            "window": int(self.window),
            "mean_wait": float(w.mean()) if w.size else 0.0,
            "p50_wait": float(np.percentile(w, 50)) if w.size else 0.0,
            "p95_wait": float(np.percentile(w, 95)) if w.size else 0.0,
            "max_wait": int(w.max()) if w.size else 0,
            "node_seconds": used,
            "flags": self.flags.as_dict(),
        }


def _normalize(trace: Dict[str, np.ndarray], total_nodes: int) -> dict:
    """make_jobset's normalization, kept int64 and unguarded by the int32
    horizon check (windows own overflow): rebase submit to 0, clamp
    runtime/estimate/nodes, sort by (submit, original index)."""
    submit = np.asarray(trace["submit"], dtype=np.int64)
    n = submit.shape[0]
    submit = submit - (submit.min() if n else 0)
    runtime = np.maximum(np.asarray(trace["runtime"], dtype=np.int64), 1)
    estimate = (np.maximum(np.asarray(trace["estimate"], dtype=np.int64), 1)
                if trace.get("estimate") is not None else runtime.copy())
    nodes = np.clip(np.asarray(trace["nodes"], dtype=np.int64), 1, total_nodes)
    priority = (np.asarray(trace["priority"], dtype=np.int64)
                if trace.get("priority") is not None
                else np.zeros(n, dtype=np.int64))
    if trace.get("deps") is not None:
        raise ValueError(
            "streaming replay drives dependency-free archive traces; "
            "workflow DAGs go through simulate/simulate_window directly")
    order = np.lexsort((np.arange(n), submit))
    return {
        "submit": submit[order], "runtime": runtime[order],
        "estimate": estimate[order], "nodes": nodes[order],
        "priority": priority[order],
    }


def _trace_crc(t: dict) -> int:
    crc = 0
    for key in ("submit", "runtime", "estimate", "nodes", "priority"):
        crc = zlib.crc32(np.ascontiguousarray(t[key]).tobytes(), crc)
    return crc


# live-row columns carried between rounds (absolute int64 host values)
_LIVE_TIME = ("start", "finish", "rsv")            # INF64-sentinel times
_LIVE_PLAIN = ("g", "submit", "runtime", "estimate", "nodes", "priority",
               "jstate", "remaining", "alloc_first", "alloc_span",
               "alloc_sum")
_LIVE_REL = ("last_start", "n_restarts", "lost_work", "aborted")


class StreamingReplay:
    """Windowed trace replay with durable per-round checkpoints.

    Most callers want :func:`replay_trace` / :func:`resume`; the class is
    the stateful core those wrap.  ``failures`` must be a *materialized*
    ``repro.reliability.FailureTrace`` (both engines must consume the
    identical arrays).  ``machine`` is a ``repro.alloc.Machine``;
    scalar-counter mode when ``None``.
    """

    def __init__(self, trace, policy="fcfs", *, total_nodes: int,
                 window: int = 4096, machine=None, alloc=None,
                 contention=None, failures=None,
                 max_events: Optional[int] = None,
                 ckpt_dir: Optional[str] = None, ckpt_every: int = 1,
                 keep: int = 3, max_window_doublings: int = 6,
                 _crash_after_round: Optional[int] = None):
        if isinstance(trace, str):
            from repro.traces.swf import load_swf
            trace, _ = load_swf(trace)
        self.total_nodes = int(total_nodes)
        self.policy_id = (POLICY_IDS[policy] if isinstance(policy, str)
                          else int(policy))
        self.machine = machine
        self.alloc = alloc
        self.contention = contention
        if machine is not None and machine.n_nodes != self.total_nodes:
            raise ValueError(
                f"machine has {machine.n_nodes} nodes but "
                f"total_nodes={self.total_nodes}")
        self.t = _normalize(trace, self.total_nodes)
        self.n_jobs = int(self.t["submit"].shape[0])
        self.trace_crc = _trace_crc(self.t)
        self.ckpt_dir = ckpt_dir
        self.ckpt_every = max(1, int(ckpt_every))
        self.keep = int(keep)
        self.max_window_doublings = int(max_window_doublings)
        self._crash_after_round = _crash_after_round

        # reliability stream: merged host-side exactly like both engines
        if failures is not None:
            from repro.reliability.model import merge_stream
            tt, nn, kk = merge_stream(failures)
            self.stream_time = tt.astype(np.int64)
            self._rel_const = (nn, kk, failures.requeue,
                               failures.checkpoint_interval,
                               failures.restart_overhead)
        else:
            self.stream_time = None
            self._rel_const = None
        self.has_rel = failures is not None

        # clock-rebase safety margin: the farthest any in-window event can
        # land past the round base is one (possibly contention-dilated)
        # dispatch plus the restart overhead; admission and t_hi stay below
        # ``limit`` so every int32 addition in the engine is overflow-free
        maxdur = int(max(self.t["runtime"].max(initial=1),
                         self.t["estimate"].max(initial=1)))
        dil = maxdur
        if contention is not None:
            from repro.alloc import Contention
            con = Contention.canonical(contention)
            num, den = int(con.alpha_num), int(con.alpha_den)
            dil = maxdur + maxdur * num * max(self.total_nodes - 1, 1) // den
        overhead = (int(failures.restart_overhead) if failures is not None
                    else 0)
        margin = 2 * (dil + overhead + 1)
        if margin >= int(INF_TIME) // 2:
            raise ReplayError(
                f"job durations too large for int32 windows (margin "
                f"{margin} >= {int(INF_TIME) // 2}); rescale the trace")
        self.limit = int(INF_TIME) - margin

        # loop state (overwritten by _restore on resume)
        self.window = int(window)
        self.cap = self._default_cap(self.window) if max_events is None \
            else int(max_events)
        self._cap_fixed = max_events is not None
        self.cursor = 0
        self.clock = 0                      # absolute int64 host clock
        self.free = self.total_nodes
        self.rel_ptr = 0
        self.n_events = 0
        self.round = 0
        self.n_rounds = 0
        self.peak_live = 0
        self.flags = ReplayFlags()
        self.live = self._empty_live()
        N = machine.n_nodes if machine is not None else 0
        self.owner_g = np.full(N, -1, dtype=np.int64)
        self.down = np.zeros(N if machine is not None else 0, dtype=bool)
        self.results = {
            "start": np.full(self.n_jobs, INF64, dtype=np.int64),
            "finish": np.full(self.n_jobs, INF64, dtype=np.int64),
            "done": np.zeros(self.n_jobs, dtype=bool),
            "alloc_first": np.full(self.n_jobs, -1, dtype=np.int64),
            "alloc_span": np.zeros(self.n_jobs, dtype=np.int64),
            "alloc_sum": np.zeros(self.n_jobs, dtype=np.int64),
            "n_restarts": np.zeros(self.n_jobs, dtype=np.int64),
            "lost_work": np.zeros(self.n_jobs, dtype=np.int64),
            "aborted": np.zeros(self.n_jobs, dtype=bool),
        }
        self._step = self._build_step()

    # ------------------------------------------------------------------
    # construction helpers
    # ------------------------------------------------------------------

    def _default_cap(self, window: int) -> int:
        K = 0 if self.stream_time is None else int(self.stream_time.shape[0])
        return 6 * (window + 1) + 2 * K + 16

    def _empty_live(self) -> dict:
        live = {k: np.zeros(0, dtype=np.int64) for k in _LIVE_PLAIN}
        live.update({k: np.zeros(0, dtype=np.int64) for k in _LIVE_TIME})
        if self.has_rel:
            live.update({k: np.zeros(0, dtype=np.int64) for k in _LIVE_REL})
            live["aborted"] = np.zeros(0, dtype=bool)
        return live

    def _build_step(self):
        pol = jnp.int32(self.policy_id)
        ctx = (make_alloc_ctx(self.machine, self.alloc, self.contention, None)
               if self.machine is not None else None)
        if self.has_rel:
            nodes_c = jnp.asarray(self._rel_const[0], jnp.int32)
            kind_c = jnp.asarray(self._rel_const[1], jnp.int32)
            knobs = tuple(jnp.int32(x) for x in self._rel_const[2:])

            def step(jobs, state, t_hi, cap, times):
                rel = (times, nodes_c, kind_c) + knobs
                return simulate_window(pol, jobs, state, t_hi, cap, ctx,
                                       rel=rel)
        else:
            def step(jobs, state, t_hi, cap):
                return simulate_window(pol, jobs, state, t_hi, cap, ctx)
        return jax.jit(step)

    # ------------------------------------------------------------------
    # int64 <-> window-relative int32 rebasing
    # ------------------------------------------------------------------

    def _rel32(self, abs64: np.ndarray, t0: int) -> np.ndarray:
        out = abs64 - t0
        sent = abs64 >= INF64
        if ((~sent) & ((out <= _I32_MIN) | (out >= int(INF_TIME)))).any():
            raise _RebaseOverflow()
        return np.where(sent, np.int64(INF_TIME), out).astype(np.int32)

    @staticmethod
    def _abs64(rel32: np.ndarray, t0: int) -> np.ndarray:
        r = rel32.astype(np.int64)
        return np.where(r >= np.int64(INF_TIME), INF64, r + t0)

    # ------------------------------------------------------------------
    # the round
    # ------------------------------------------------------------------

    def _harvest(self):
        live = self.live
        done = np.asarray(live["jstate"]) == DONE
        if done.any():
            g = live["g"][done]
            r = self.results
            r["start"][g] = live["start"][done]
            r["finish"][g] = live["finish"][done]
            r["alloc_first"][g] = live["alloc_first"][done]
            r["alloc_span"][g] = live["alloc_span"][done]
            r["alloc_sum"][g] = live["alloc_sum"][done]
            if self.has_rel:
                r["n_restarts"][g] = live["n_restarts"][done]
                r["lost_work"][g] = live["lost_work"][done]
                r["aborted"][g] = live["aborted"][done]
                r["done"][g] = ~live["aborted"][done].astype(bool)
            else:
                r["done"][g] = True
            self.live = {k: v[~done] for k, v in live.items()}

    def _admit(self, t0: int) -> int:
        n_live = len(self.live["g"])
        n_free = self.window - n_live
        k = min(n_free, self.n_jobs - self.cursor)
        if k <= 0:
            return 0
        # only jobs whose window-relative submit stays under the safety
        # limit; submits are sorted, so this is a prefix
        hi = np.searchsorted(
            self.t["submit"][self.cursor:self.cursor + k],
            np.int64(t0 + self.limit), side="right")
        k = int(min(k, hi))
        if k <= 0:
            return 0
        sl = slice(self.cursor, self.cursor + k)
        add = {
            "g": np.arange(self.cursor, self.cursor + k, dtype=np.int64),
            "submit": self.t["submit"][sl].copy(),
            "runtime": self.t["runtime"][sl].copy(),
            "estimate": self.t["estimate"][sl].copy(),
            "nodes": self.t["nodes"][sl].copy(),
            "priority": self.t["priority"][sl].copy(),
            "jstate": np.full(k, PENDING, dtype=np.int64),
            "remaining": self.t["runtime"][sl].copy(),
            "start": np.full(k, INF64, dtype=np.int64),
            "finish": np.full(k, INF64, dtype=np.int64),
            "rsv": np.full(k, INF64, dtype=np.int64),
            "alloc_first": np.full(k, -1, dtype=np.int64),
            "alloc_span": np.zeros(k, dtype=np.int64),
            "alloc_sum": np.zeros(k, dtype=np.int64),
        }
        if self.has_rel:
            add["last_start"] = np.full(k, t0, dtype=np.int64)
            add["n_restarts"] = np.zeros(k, dtype=np.int64)
            add["lost_work"] = np.zeros(k, dtype=np.int64)
            add["aborted"] = np.zeros(k, dtype=bool)
        self.live = {key: np.concatenate([self.live[key], add[key]])
                     for key in self.live}
        self.cursor += k
        return k

    def _window_args(self, t0: int):
        """Build the device JobSet/SimState for one round.  Live rows land
        compacted in rows [0, n) in ascending global order — the invariant
        every relative-order tie-break in the engine relies on — followed by
        invalid padding and one PENDING sentinel row (submit = INF) that
        keeps the engine's "simulation still live" guard exact while the
        trace has more jobs than the window."""
        live = self.live
        n = len(live["g"])
        W1 = self.window + 1
        i32 = np.int32

        def pad(a, fill, dtype=i32):
            out = np.full(W1, fill, dtype=dtype)
            out[:n] = a
            return out

        submit = pad(self._rel32(live["submit"], t0), int(INF_TIME))
        valid = np.zeros(W1, dtype=bool)
        valid[:n] = True
        jobs = JobSet(
            submit=submit,
            runtime=pad(live["runtime"], 1),
            estimate=pad(live["estimate"], 1),
            nodes=pad(live["nodes"], 1),
            priority=pad(live["priority"], 0),
            valid=valid,
        )
        jstate = pad(live["jstate"], DONE)
        if self.cursor < self.n_jobs:
            # the sentinel (never arrives): keeps the engine's
            # any-job-unfinished guard open while the trace still has
            # unadmitted jobs; in the drain the window IS the full
            # remaining table, so the guard must close exactly as in a
            # one-shot run
            jstate[W1 - 1] = PENDING
        N = self.machine.n_nodes if self.machine is not None else 0
        owner = np.full(N, -1, dtype=i32)
        if N and (self.owner_g >= 0).any():
            held = self.owner_g >= 0
            owner[held] = np.searchsorted(
                live["g"], self.owner_g[held]).astype(i32)
        rel = None
        if self.has_rel:
            rel = RelState(
                ptr=jnp.int32(self.rel_ptr),
                last_start=jnp.asarray(
                    pad(self._rel32(live["last_start"], t0), 0)),
                n_restarts=jnp.asarray(pad(live["n_restarts"], 0)),
                lost_work=jnp.asarray(pad(live["lost_work"], 0)),
                aborted=jnp.asarray(pad(live["aborted"], False, bool)),
                down=jnp.asarray(self.down),
            )
        state = SimState(
            clock=jnp.int32(self.clock - t0),
            jstate=jnp.asarray(jstate),
            n_unmet=jnp.zeros(0, dtype=jnp.int32),
            start=jnp.asarray(pad(self._rel32(live["start"], t0), int(INF_TIME))),
            finish=jnp.asarray(pad(self._rel32(live["finish"], t0), int(INF_TIME))),
            rsv_finish=jnp.asarray(pad(self._rel32(live["rsv"], t0), int(INF_TIME))),
            remaining=jnp.asarray(pad(live["remaining"], 1)),
            free=jnp.int32(self.free),
            n_events=jnp.int32(0),
            node_owner=jnp.asarray(owner),
            alloc_first=jnp.asarray(pad(live["alloc_first"], -1)),
            alloc_span=jnp.asarray(pad(live["alloc_span"], 0)),
            alloc_sum=jnp.asarray(pad(live["alloc_sum"], 0)),
            # machine mode always writes the fragmentation log; one slot
            # (never downloaded, writes past it drop) keeps the scatter
            # legal without materializing a per-event log per round
            ev_time=jnp.zeros(1 if N else 0, dtype=jnp.int32),
            ev_free=jnp.zeros(1 if N else 0, dtype=jnp.int32),
            ev_lfb=jnp.zeros(1 if N else 0, dtype=jnp.int32),
            rel=rel,
        )
        return jobs, state

    def _run_round(self, t0: int, t_hi_rel: int) -> tuple[int, bool]:
        """One simulate_window call; returns (events processed, saturated)."""
        jobs, state = self._window_args(t0)
        args = (jobs, state, jnp.int32(t_hi_rel),
                jnp.int32(min(self.cap, int(INF_TIME))))
        if self.has_rel:
            times = np.clip(self.stream_time - t0, np.int64(_I32_MIN),
                            np.int64(INF_TIME)).astype(np.int32)
            state, sat = self._step(*args, jnp.asarray(times))
        else:
            state, sat = self._step(*args)
        n = len(self.live["g"])
        live = self.live
        live["jstate"] = np.asarray(state.jstate[:n], dtype=np.int64)
        live["start"] = self._abs64(np.asarray(state.start[:n]), t0)
        live["finish"] = self._abs64(np.asarray(state.finish[:n]), t0)
        live["rsv"] = self._abs64(np.asarray(state.rsv_finish[:n]), t0)
        live["remaining"] = np.asarray(state.remaining[:n], dtype=np.int64)
        live["alloc_first"] = np.asarray(state.alloc_first[:n], dtype=np.int64)
        live["alloc_span"] = np.asarray(state.alloc_span[:n], dtype=np.int64)
        live["alloc_sum"] = np.asarray(state.alloc_sum[:n], dtype=np.int64)
        if self.has_rel:
            live["last_start"] = (
                np.asarray(state.rel.last_start[:n]).astype(np.int64) + t0)
            live["n_restarts"] = np.asarray(state.rel.n_restarts[:n],
                                            dtype=np.int64)
            live["lost_work"] = np.asarray(state.rel.lost_work[:n],
                                           dtype=np.int64)
            live["aborted"] = np.asarray(state.rel.aborted[:n])
            self.rel_ptr = int(state.rel.ptr)
            self.down = np.asarray(state.rel.down)
        if self.machine is not None:
            rows = np.asarray(state.node_owner)
            self.owner_g = np.full(rows.shape[0], -1, dtype=np.int64)
            held = rows >= 0
            if held.any():
                self.owner_g[held] = live["g"][rows[held]]
        self.free = int(state.free)
        self.clock = t0 + int(state.clock)
        ev = int(state.n_events)
        self.n_events += ev
        self.n_rounds += 1
        return ev, bool(sat)

    # ------------------------------------------------------------------
    # checkpointing
    # ------------------------------------------------------------------

    def _config(self) -> dict:
        return {
            "policy": self.policy_id,
            "total_nodes": self.total_nodes,
            "n_jobs": self.n_jobs,
            "trace_crc": self.trace_crc,
            "machine": self.machine is not None,
            "failures": self.has_rel,
        }

    def _save(self):
        tree = {f"live/{k}": v for k, v in self.live.items()}
        tree.update({f"res/{k}": v for k, v in self.results.items()})
        tree["owner_g"] = self.owner_g
        tree["down"] = self.down
        extra = {
            "round": self.round, "cursor": self.cursor,
            "clock": int(self.clock), "free": self.free,
            "rel_ptr": self.rel_ptr, "n_events": self.n_events,
            "window": self.window, "cap": self.cap,
            "n_rounds": self.n_rounds, "peak_live": self.peak_live,
            "flags": self.flags.as_dict(), "config": self._config(),
        }
        save_checkpoint(self.ckpt_dir, self.round, tree, extra=extra,
                        keep=self.keep)

    def _restore(self):
        leaves, _step, extra = load_checkpoint_raw(self.ckpt_dir)
        cfg = extra.get("config", {})
        if cfg != self._config():
            raise ReplayError(
                f"checkpoint in {self.ckpt_dir} was written by a different "
                f"replay configuration ({cfg} != {self._config()}); refusing "
                "to resume")
        self.live = {k[len("live/"):]: v for k, v in leaves.items()
                     if k.startswith("live/")}
        self.results = {k[len("res/"):]: v for k, v in leaves.items()
                        if k.startswith("res/")}
        self.owner_g = leaves["owner_g"]
        self.down = leaves["down"]
        for name in ("round", "cursor", "free", "rel_ptr", "n_events",
                     "window", "cap", "n_rounds", "peak_live"):
            setattr(self, name, int(extra[name]))
        self.clock = int(extra["clock"])
        self.flags = ReplayFlags.from_dict(extra["flags"])

    # ------------------------------------------------------------------
    # the loop
    # ------------------------------------------------------------------

    def run(self, *, resume: bool = False) -> ReplayResult:
        if resume:
            self._restore()
        while True:
            if self.ckpt_dir is not None and self.round % self.ckpt_every == 0:
                self._save()
            if self._crash_after_round is not None \
                    and self.round >= self._crash_after_round:
                raise ReplayInterrupted(
                    f"crash hook fired at round {self.round}")
            self.round += 1
            self._harvest()
            if self.cursor >= self.n_jobs and len(self.live["g"]) == 0:
                break
            t0 = int(self.clock)
            if len(self.live["g"]) == 0 \
                    and self.t["submit"][self.cursor] - t0 > self.limit:
                # idle gap wider than the int32 window: nothing is live, so
                # jump the host clock straight to the next arrival
                t0 = self.clock = int(self.t["submit"][self.cursor])
            admitted = self._admit(t0)
            n_live = len(self.live["g"])
            self.peak_live = max(self.peak_live, n_live)
            if self.cursor < self.n_jobs:
                t_next = int(self.t["submit"][self.cursor]) - t0
                t_hi = min(t_next - 1, self.limit)
            else:
                t_hi = self.limit
            try:
                events, sat = self._run_round(t0, t_hi)
            except _RebaseOverflow:
                self.flags.rebase_overflows += 1
                if self.flags.rebase_overflows > 1:
                    raise ReplayError(
                        "window-relative time does not fit int32 even after "
                        "a window doubling; rescale the trace") from None
                self._double_window()
                continue
            if sat:
                # the truncated round is a valid prefix: count it, raise the
                # cap, and let the next round continue from the same state
                self.flags.saturated_rounds += 1
                if not self._cap_fixed:
                    self.cap *= 2
                    self.flags.cap_doublings += 1
                elif events == 0:
                    raise ReplayError(
                        f"event cap {self.cap} saturated with no progress; "
                        "raise max_events")
            if events == 0 and admitted == 0 and not sat:
                if self.cursor < self.n_jobs and n_live >= self.window:
                    # window overflow: more than W jobs alive at once
                    self._double_window()
                elif self.cursor >= self.n_jobs:
                    # drain round fired nothing: the next would be
                    # identical (deterministic), so fail loud
                    raise ReplayError(
                        f"replay stalled draining {n_live} live jobs at "
                        f"clock {self.clock} (round {self.round}); no "
                        "event below the window limit can fire")
                else:
                    raise ReplayError(
                        f"replay stalled at clock {self.clock} (round "
                        f"{self.round}): no events below the window limit "
                        "and nothing to admit")
        return self._result()

    def _double_window(self):
        if self.flags.window_doublings >= self.max_window_doublings:
            raise ReplayError(
                f"active jobs exceed the window even after "
                f"{self.flags.window_doublings} doublings "
                f"(window={self.window}); raise window=")
        self.window *= 2
        self.flags.window_doublings += 1
        if not self._cap_fixed:
            self.cap = max(self.cap, self._default_cap(self.window))

    def _result(self) -> ReplayResult:
        r = self.results
        done = r["done"]
        fin = np.where(done, r["finish"], 0)
        # never-started/-finished rows take the refsim's int64 sentinel (-1):
        # INF_TIME is a real instant on a beyond-int32 horizon, so the int32
        # engine's sentinel cannot double as one here
        started = r["start"] < INF64
        start = np.where(started, r["start"], np.int64(-1))
        finish = np.where(r["finish"] < INF64, r["finish"], np.int64(-1))
        return ReplayResult(
            submit=self.t["submit"], runtime=self.t["runtime"],
            estimate=self.t["estimate"], nodes=self.t["nodes"],
            priority=self.t["priority"],
            start=start, finish=finish,
            wait=np.where(started, start - self.t["submit"], 0),
            done=done,
            alloc_first=r["alloc_first"], alloc_span=r["alloc_span"],
            alloc_sum=r["alloc_sum"],
            n_restarts=r["n_restarts"], lost_work=r["lost_work"],
            aborted=r["aborted"],
            makespan=int(fin.max(initial=0)),
            n_events=self.n_events, n_rounds=self.n_rounds,
            peak_live=self.peak_live, window=self.window, flags=self.flags,
        )


class _RebaseOverflow(Exception):
    pass


def replay_trace(trace, policy="fcfs", *, total_nodes: int, **kwargs
                 ) -> ReplayResult:
    """One-call streaming replay: ``trace`` is a dict of host arrays or a
    path to an ``.swf``/``.swf.gz`` log.  See :class:`StreamingReplay` for
    the windowing/checkpoint knobs."""
    return StreamingReplay(trace, policy, total_nodes=total_nodes,
                           **kwargs).run()


def resume(ckpt_dir: str, trace, policy="fcfs", *, total_nodes: int,
           **kwargs) -> ReplayResult:
    """Restart a replay from its last durable round.

    Call with the *same* trace and configuration as the interrupted run
    (verified against the checkpoint manifest; a mismatch refuses to
    resume).  The continuation is bit-exact with an uninterrupted run.
    """
    runner = StreamingReplay(trace, policy, total_nodes=total_nodes,
                             ckpt_dir=ckpt_dir, **{k: v for k, v in
                                                   kwargs.items()
                                                   if k != "ckpt_dir"})
    return runner.run(resume=True)
