"""Family-dispatching facade over the model zoo."""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict

import jax

from repro.configs.base import ModelConfig
from repro.models import encdec, lm
from repro.sharding.rules import (
    ShardingRules, TRAIN_RULES, count_params, init_from_defs,
    shapes_from_defs, specs_from_defs,
)


@dataclasses.dataclass(frozen=True)
class ModelAPI:
    cfg: ModelConfig
    param_defs: Any
    loss_fn: Callable
    prefill: Callable
    decode_step: Callable
    cache_defs_fn: Callable

    def init(self, key: jax.Array):
        return init_from_defs(self.param_defs, key)

    def param_shapes(self):
        return shapes_from_defs(self.param_defs)

    def param_specs(self, rules: ShardingRules, mesh):
        return specs_from_defs(self.param_defs, rules, mesh)

    def n_params(self) -> int:
        return count_params(self.param_defs)

    def n_active_params(self) -> int:
        """Active parameters per token (MoE discounts unused experts)."""
        cfg = self.cfg
        if not cfg.n_experts:
            return self.n_params()
        total = self.n_params()
        E, K = cfg.n_experts, cfg.top_k
        expert_leaf = 3 * cfg.d_model * cfg.expert_ff  # wi+wg+wo per expert
        per_layer_unused = (E - K) * expert_leaf
        return total - cfg.n_layers * per_layer_unused


def get_model(cfg: ModelConfig) -> ModelAPI:
    if cfg.family == "encdec":
        return ModelAPI(
            cfg=cfg,
            param_defs=encdec.param_defs(cfg),
            loss_fn=lambda p, b, **kw: encdec.loss_fn(p, b, cfg, **kw),
            prefill=lambda p, b, **kw: (encdec.forward(p, b, cfg, **kw), None),
            decode_step=lambda p, t, pos, c, **kw: encdec.decode_step(
                p, t, pos, c, cfg, **kw),
            cache_defs_fn=lambda batch, seq: encdec.cache_defs(
                cfg, batch, seq, max(seq // 2, 1)),
        )
    return ModelAPI(
        cfg=cfg,
        param_defs=lm.param_defs(cfg),
        loss_fn=lambda p, b, **kw: lm.loss_fn(p, b, cfg, **kw),
        prefill=lambda p, b, **kw: lm.prefill(p, b, cfg, **kw),
        decode_step=lambda p, t, pos, c, **kw: lm.decode_step(
            p, t, pos, c, cfg, **kw),
        cache_defs_fn=lambda batch, seq: lm.cache_defs(cfg, batch, seq),
    )
