"""Encoder-decoder transformer (seamless-m4t backbone).

The audio frontend is a stub per the assignment: ``input_specs`` provides
precomputed frame embeddings [B, S_src, d_model]; the encoder is a
bidirectional transformer over them, the decoder a causal transformer with
cross-attention.  Same ParamDef/scan machinery as ``lm.py``.
"""

from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models.attention import blockwise_attention
from repro.models.layers import (
    apply_mlp, apply_norm, apply_rope, cross_entropy, embed_defs,
    embed_tokens, logits_from_hidden, mlp_defs, norm_defs,
)
from repro.sharding.rules import ParamDef, ShardingRules, TRAIN_RULES, constrain


def param_defs(cfg: ModelConfig) -> Dict[str, Any]:
    enc_l, dec_l = cfg.enc_layers, cfg.n_layers
    return {
        "embed": embed_defs(cfg),
        "frontend_proj": ParamDef((cfg.d_model, cfg.d_model), ("embed_fsdp", None)),
        "encoder": {
            "ln1": norm_defs(cfg, (enc_l,)),
            "attn": attn.attn_defs(cfg, (enc_l,)),
            "ln2": norm_defs(cfg, (enc_l,)),
            "mlp": mlp_defs(cfg, (enc_l,)),
        },
        "enc_norm": norm_defs(cfg),
        "decoder": {
            "ln1": norm_defs(cfg, (dec_l,)),
            "self_attn": attn.attn_defs(cfg, (dec_l,)),
            "ln_x": norm_defs(cfg, (dec_l,)),
            "cross_attn": attn.attn_defs(cfg, (dec_l,)),
            "ln2": norm_defs(cfg, (dec_l,)),
            "mlp": mlp_defs(cfg, (dec_l,)),
        },
        "final_norm": norm_defs(cfg),
    }


def cache_defs(cfg: ModelConfig, batch: int, tgt_len: int, src_len: int):
    dt = jnp.dtype(cfg.dtype)
    KV, hd, L = cfg.kv_heads_c, cfg.head_dim, cfg.n_layers

    def kv(length):
        return {
            "k": ParamDef((L, batch, length, KV, hd),
                          ("layers", "cache_batch", "cache_seq", "kv", None),
                          init="zeros", dtype=dt),
            "v": ParamDef((L, batch, length, KV, hd),
                          ("layers", "cache_batch", "cache_seq", "kv", None),
                          init="zeros", dtype=dt),
        }

    return {"self": kv(tgt_len), "cross": kv(src_len)}


def _proj_qkv(cfg, p, x, positions=None):
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(dt))
    if positions is not None:
        q = apply_rope(q, positions, cfg)
        k = apply_rope(k, positions, cfg)
    return q, k, v


def _out(p, o):
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(o.dtype))


def encode(params, src_embeds, cfg: ModelConfig, *, rules=TRAIN_RULES, mesh=None):
    dt = jnp.dtype(cfg.dtype)
    h = jnp.einsum(
        "bsd,de->bse", src_embeds.astype(dt), params["frontend_proj"].astype(dt)
    )
    h = constrain(h, ("act_batch", "act_seq", "act_embed"), rules, mesh)
    S = h.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)[None]

    def body(carry, lp):
        hh = carry
        a = apply_norm(lp["ln1"], hh, cfg)
        q, k, v = _proj_qkv(cfg, lp["attn"], a, positions)
        o = blockwise_attention(
            q, k, v, causal=False, block_q=cfg.block_q, block_k=cfg.block_k
        )
        hh = hh + _out(lp["attn"], o)
        m = apply_norm(lp["ln2"], hh, cfg)
        hh = hh + apply_mlp(lp["mlp"], m, cfg)
        hh = constrain(hh, ("act_batch", "act_seq", "act_embed"), rules, mesh)
        return hh, 0

    if cfg.remat:
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    h, _ = jax.lax.scan(body, h, params["encoder"])
    return apply_norm(params["enc_norm"], h, cfg)


def _decoder_block(cfg, lp, h, enc_out, positions, rules, mesh):
    a = apply_norm(lp["ln1"], h, cfg)
    q, k, v = _proj_qkv(cfg, lp["self_attn"], a, positions)
    o = blockwise_attention(
        q, k, v, causal=True, block_q=cfg.block_q, block_k=cfg.block_k
    )
    h = h + _out(lp["self_attn"], o)
    x = apply_norm(lp["ln_x"], h, cfg)
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, lp["cross_attn"]["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", enc_out, lp["cross_attn"]["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", enc_out, lp["cross_attn"]["wv"].astype(dt))
    o = blockwise_attention(
        q, k, v, causal=False, block_q=cfg.block_q, block_k=cfg.block_k
    )
    h = h + _out(lp["cross_attn"], o)
    m = apply_norm(lp["ln2"], h, cfg)
    h = h + apply_mlp(lp["mlp"], m, cfg)
    return constrain(h, ("act_batch", "act_seq", "act_embed"), rules, mesh)


def forward(params, batch, cfg: ModelConfig, *, rules=TRAIN_RULES, mesh=None):
    """batch: {"src_embeds": [B,S_src,D], "tokens": [B,S_tgt]}."""
    enc_out = encode(params, batch["src_embeds"], cfg, rules=rules, mesh=mesh)
    h = embed_tokens(params["embed"], batch["tokens"], cfg)
    h = constrain(h, ("act_batch", "act_seq", "act_embed"), rules, mesh)
    positions = jnp.arange(h.shape[1], dtype=jnp.int32)[None]

    def body(carry, lp):
        return _decoder_block(cfg, lp, carry, enc_out, positions, rules, mesh), 0

    if cfg.remat:
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    h, _ = jax.lax.scan(body, h, params["decoder"])
    h = apply_norm(params["final_norm"], h, cfg)
    logits = logits_from_hidden(params["embed"], h, cfg)
    logits = constrain(logits, ("batch", None, "vocab"), rules, mesh)
    return logits


def loss_fn(params, batch, cfg: ModelConfig, *, rules=TRAIN_RULES, mesh=None):
    from repro.models.layers import chunked_lm_loss
    enc_out = encode(params, batch["src_embeds"], cfg, rules=rules, mesh=mesh)
    h = embed_tokens(params["embed"], batch["tokens"], cfg)
    h = constrain(h, ("act_batch", "act_seq", "act_embed"), rules, mesh)
    positions = jnp.arange(h.shape[1], dtype=jnp.int32)[None]

    def body(carry, lp):
        return _decoder_block(cfg, lp, carry, enc_out, positions, rules, mesh), 0

    if cfg.remat:
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    h, _ = jax.lax.scan(body, h, params["decoder"])
    h = apply_norm(params["final_norm"], h, cfg)
    loss = chunked_lm_loss(params["embed"], h, batch["labels"], cfg,
                           rules, mesh)
    return loss, {"ce": loss, "aux": jnp.float32(0)}


def decode_step(params, tokens, pos, cache, cfg: ModelConfig,
                *, rules=TRAIN_RULES, mesh=None):
    """One decoder token; cross K/V precomputed in ``cache['cross']``."""
    h = embed_tokens(params["embed"], tokens[:, None], cfg)
    positions = jnp.full((1, 1), pos, dtype=jnp.int32)

    def body(carry, xs):
        lp, sk, sv, xk, xv = xs
        hh = carry
        a = apply_norm(lp["ln1"], hh, cfg)
        q, k, v = _proj_qkv(cfg, lp["self_attn"], a, positions)
        sk = jax.lax.dynamic_update_slice_in_dim(sk, k, pos, axis=1)
        sv = jax.lax.dynamic_update_slice_in_dim(sv, v, pos, axis=1)
        o = attn.decode_attention(q, sk, sv, pos=pos)
        hh = hh + _out(lp["self_attn"], o)
        x = apply_norm(lp["ln_x"], hh, cfg)
        q = jnp.einsum("bsd,dhk->bshk", x, lp["cross_attn"]["wq"].astype(x.dtype))
        o = attn.decode_attention(q, xk, xv, pos=xk.shape[1] - 1)
        hh = hh + _out(lp["cross_attn"], o)
        m = apply_norm(lp["ln2"], hh, cfg)
        hh = hh + apply_mlp(lp["mlp"], m, cfg)
        return hh, (sk, sv)

    h, (nsk, nsv) = jax.lax.scan(
        body, h,
        (params["decoder"], cache["self"]["k"], cache["self"]["v"],
         cache["cross"]["k"], cache["cross"]["v"]),
    )
    h = apply_norm(params["final_norm"], h, cfg)
    logits = logits_from_hidden(params["embed"], h, cfg)[:, 0]
    return logits, {"self": {"k": nsk, "v": nsv}, "cross": cache["cross"]}
