"""RWKV6 "Finch" block: data-dependent per-channel decay linear attention.

Time-mix recurrence per head (key dim K == value dim V == head_dim):
    S_t = diag(w_t) S_{t-1} + k_t v_t^T
    y_t = r_t . (S_{t-1} + diag(u) k_t v_t^T)
with w_t in (0,1) produced by a low-rank data-dependent projection (the
Finch contribution).  Training uses the chunked closed form (factorized
decay products, f32); decode uses the O(1) recurrence.  Channel-mix is the
standard RWKV squared-relu FFN.  Chunk math mirrors repro.kernels.linattn_scan.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import rms_norm_simple
from repro.sharding.rules import ParamDef

LORA_R = 64


def rwkv_defs(cfg: ModelConfig, layers: tuple[int, ...] = ()):
    D = cfg.d_model
    F = cfg.d_ff
    lx = ("layers",) * len(layers)
    tm = {
        # token-shift mixing coefficients for r/k/v/g/w
        "mu": ParamDef(layers + (5, D), lx + (None, None), init="zeros"),
        "wr": ParamDef(layers + (D, D), lx + ("embed_fsdp", "heads")),
        "wk": ParamDef(layers + (D, D), lx + ("embed_fsdp", "heads")),
        "wv": ParamDef(layers + (D, D), lx + ("embed_fsdp", "heads")),
        "wg": ParamDef(layers + (D, D), lx + ("embed_fsdp", "heads")),
        "wo": ParamDef(layers + (D, D), lx + ("heads", "embed_fsdp")),
        # data-dependent decay (low-rank) + base
        "w0": ParamDef(layers + (D,), lx + (None,), init="zeros"),
        "wa": ParamDef(layers + (D, LORA_R), lx + ("embed_fsdp", None)),
        "wb": ParamDef(layers + (LORA_R, D), lx + (None, "heads")),
        "u": ParamDef(layers + (D,), lx + (None,), init="zeros"),
        "ln_scale": ParamDef(layers + (D,), lx + (None,), init="ones"),
    }
    cm = {
        "mu": ParamDef(layers + (2, D), lx + (None, None), init="zeros"),
        "wk": ParamDef(layers + (D, F), lx + ("embed_fsdp", "mlp")),
        "wv": ParamDef(layers + (F, D), lx + ("mlp", "embed_fsdp")),
        "wr": ParamDef(layers + (D, D), lx + ("embed_fsdp", None)),
    }
    return {"time_mix": tm, "channel_mix": cm}


def _token_shift(x: jax.Array, x_prev: Optional[jax.Array]):
    """Shifted sequence: z_t = x_{t-1} (x_prev seeds t=0). Returns (z, last)."""
    if x.shape[1] == 1 and x_prev is not None:
        return x_prev[:, None, :], x[:, 0]
    z = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    if x_prev is not None:
        z = z.at[:, 0].set(x_prev)
    return z, x[:, -1]


def _mix(x, z, mu):
    return x + (z - x) * mu[None, None, :]


def wkv_chunked(r, k, v, logw, u, chunk: int):
    """Chunked WKV. r/k/v/logw: [B, S, H, K]; u: [H, K].

    Returns y [B, S, H, K], final state [B, H, K, K] (key dim first).
    """
    B, S, H, K = r.shape
    Q = min(chunk, S)
    pad = (-S) % Q
    if pad:
        zp = lambda a: jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)))
        r, k, v = zp(r), zp(k), zp(v)
        logw = jnp.pad(logw, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nc = (S + pad) // Q
    resh = lambda a: a.reshape(B, nc, Q, H, K).swapaxes(0, 1)
    rs, ks, vs, lws = resh(r), resh(k), resh(v), resh(logw)

    @jax.checkpoint   # recompute per-chunk [Q,Q,H,K] decay tensors in backward
    def chunk_step(state, inp):
        rq, kq, vq, lwq = (a.astype(jnp.float32) for a in inp)
        E = jnp.cumsum(lwq, axis=1)                      # inclusive log-decay
        Eex = E - lwq                                    # exclusive (through t-1)
        # intra-chunk pairwise decays in log space (exponent <= 0 for t > s:
        # unconditionally stable; the factored exp(+E)*exp(-E) trick is not)
        mask = jnp.tril(jnp.ones((Q, Q), bool), k=-1)    # strictly past
        seg = Eex[:, :, None] - E[:, None]               # [B, Q, Q, H, K]
        seg = jnp.where(mask[None, :, :, None, None], seg, -jnp.inf)
        att = jnp.einsum("bqhk,bshk,bqshk->bhqs", rq, kq, jnp.exp(seg))
        r_dec = rq * jnp.exp(Eex)                        # Eex <= 0: stable
        diag = jnp.einsum("bqhk,hk,bqhk->bqh", rq, u.astype(jnp.float32), kq)
        y = jnp.einsum("bhqs,bshk->bqhk", att, vq)
        y = y + diag[..., None] * vq
        y = y + jnp.einsum("bqhk,bhkv->bqhv", r_dec, state)
        # state' = diag(prod w) state + sum_s (prod_{>s} w) k_s v_s^T
        Eq = E[:, -1]                                    # [B, H, K]
        kw = kq * jnp.exp(Eq[:, None] - E)
        state = jnp.exp(Eq)[..., None] * state + jnp.einsum(
            "bshk,bshv->bhkv", kw, vq
        )
        return state, y

    state0 = jnp.zeros((B, H, K, K), jnp.float32)
    state, ys = jax.lax.scan(chunk_step, state0, (rs, ks, vs, lws))
    y = ys.swapaxes(0, 1).reshape(B, S + pad, H, K)[:, :S]
    return y.astype(r.dtype), state


def wkv_step(r, k, v, logw, u, state):
    """One-token recurrence. r/k/v/logw: [B, H, K]; state [B, H, K, K]."""
    rf, kf, vf = (a.astype(jnp.float32) for a in (r, k, v))
    w = jnp.exp(logw.astype(jnp.float32))
    kv = jnp.einsum("bhk,bhv->bhkv", kf, vf)
    y = jnp.einsum("bhk,bhkv->bhv", rf, state + u.astype(jnp.float32)[None, :, :, None] * kv)
    state = w[..., None] * state + kv
    return y.astype(r.dtype), state


def apply_time_mix(
    p, x: jax.Array, cfg: ModelConfig,
    *, cache: Optional[dict] = None,
) -> Tuple[jax.Array, Optional[dict]]:
    B, S, D = x.shape
    hd = 64
    H = D // hd
    dt = x.dtype
    z, last = _token_shift(x, None if cache is None else cache["shift_att"])
    mu = p["mu"].astype(dt)
    xr, xk, xv, xg, xw = (_mix(x, z, mu[i]) for i in range(5))
    r = jnp.einsum("bsd,de->bse", xr, p["wr"].astype(dt)).reshape(B, S, H, hd)
    k = jnp.einsum("bsd,de->bse", xk, p["wk"].astype(dt)).reshape(B, S, H, hd)
    v = jnp.einsum("bsd,de->bse", xv, p["wv"].astype(dt)).reshape(B, S, H, hd)
    g = jnp.einsum("bsd,de->bse", xg, p["wg"].astype(dt))
    lora = jnp.einsum(
        "bsd,dr,re->bse", jnp.tanh(xw.astype(jnp.float32)),
        p["wa"].astype(jnp.float32), p["wb"].astype(jnp.float32),
    )
    logw = -jnp.exp(p["w0"].astype(jnp.float32)[None, None] + lora)  # < 0
    logw = logw.reshape(B, S, H, hd)
    u = p["u"].astype(jnp.float32).reshape(H, hd)

    if cache is None:
        y, _ = wkv_chunked(r, k, v, logw.astype(jnp.float32), u, cfg.rwkv_chunk)
        new_cache = None
    else:
        y, st = wkv_step(r[:, 0], k[:, 0], v[:, 0], logw[:, 0], u, cache["wkv"])
        y = y[:, None]
        new_cache = {"wkv": st, "shift_att": last}

    y = rms_norm_simple(y.reshape(B, S, D)) * p["ln_scale"].astype(dt)
    y = y * jax.nn.silu(g)
    out = jnp.einsum("bse,ed->bsd", y, p["wo"].astype(dt))
    return out, new_cache


def apply_channel_mix(
    p, x: jax.Array, cfg: ModelConfig,
    *, cache: Optional[dict] = None,
) -> Tuple[jax.Array, Optional[dict]]:
    dt = x.dtype
    z, last = _token_shift(x, None if cache is None else cache["shift_ffn"])
    mu = p["mu"].astype(dt)
    xk, xr = _mix(x, z, mu[0]), _mix(x, z, mu[1])
    k = jnp.einsum("bsd,df->bsf", xk, p["wk"].astype(dt))
    k = jnp.square(jax.nn.relu(k))
    kv = jnp.einsum("bsf,fd->bsd", k, p["wv"].astype(dt))
    r = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr, p["wr"].astype(dt)))
    out = r * kv
    return out, (None if cache is None else {"shift_ffn": last})


def init_rwkv_cache(cfg: ModelConfig, batch: int, dtype) -> dict:
    D = cfg.d_model
    hd = 64
    H = D // hd
    return {
        "wkv": jnp.zeros((batch, H, hd, hd), jnp.float32),
        "shift_att": jnp.zeros((batch, D), dtype),
        "shift_ffn": jnp.zeros((batch, D), dtype),
    }
