"""Shared neural building blocks (functional, pytree params)."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.sharding.rules import ParamDef


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def norm_defs(cfg: ModelConfig, layers: tuple[int, ...] = ()):
    d = {"scale": ParamDef(layers + (cfg.d_model,),
                           ("layers",) * len(layers) + (None,), init="ones")}
    if cfg.norm == "layernorm":
        d["bias"] = ParamDef(layers + (cfg.d_model,),
                             ("layers",) * len(layers) + (None,), init="zeros")
    return d


def apply_norm(p, x: jax.Array, cfg: ModelConfig, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    if cfg.norm == "rmsnorm":
        xf = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
        out = xf * p["scale"].astype(jnp.float32)
    else:
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
        out = (xf - mu) * jax.lax.rsqrt(var + eps)
        out = out * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return out.astype(x.dtype)


def rms_norm_simple(x: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    return (xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
            ).astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def mlp_defs(cfg: ModelConfig, layers: tuple[int, ...] = (), d_ff: int | None = None):
    D, F = cfg.d_model, d_ff or cfg.d_ff
    lx = ("layers",) * len(layers)
    if cfg.act == "swiglu":
        return {
            "wi": ParamDef(layers + (D, F), lx + ("embed_fsdp", "mlp")),
            "wg": ParamDef(layers + (D, F), lx + ("embed_fsdp", "mlp")),
            "wo": ParamDef(layers + (F, D), lx + ("mlp", "embed_fsdp")),
        }
    return {
        "wi": ParamDef(layers + (D, F), lx + ("embed_fsdp", "mlp")),
        "wo": ParamDef(layers + (F, D), lx + ("mlp", "embed_fsdp")),
    }


def apply_mlp(p, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    dt = x.dtype
    if cfg.act == "swiglu":
        h = jnp.einsum("bsd,df->bsf", x, p["wi"].astype(dt))
        g = jnp.einsum("bsd,df->bsf", x, p["wg"].astype(dt))
        h = jax.nn.silu(g) * h
    else:
        h = jnp.einsum("bsd,df->bsf", x, p["wi"].astype(dt))
        h = jax.nn.gelu(h)
    return jnp.einsum("bsf,fd->bsd", h, p["wo"].astype(dt))


# ---------------------------------------------------------------------------
# rotary position embeddings (RoPE / partial rotary / M-RoPE stub)
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, rotary_pct: float, theta: float):
    rot = int(head_dim * rotary_pct) // 2 * 2
    inv = 1.0 / (theta ** (jnp.arange(0, rot, 2, dtype=jnp.float32) / rot))
    return inv, rot


def apply_rope(x: jax.Array, positions: jax.Array, cfg: ModelConfig) -> jax.Array:
    """x: [..., S, H, hd]; positions: broadcastable to [..., S]."""
    inv, rot = rope_freqs(x.shape[-1], cfg.rotary_pct, cfg.rope_theta)
    if rot == 0:
        return x
    ang = positions[..., :, None].astype(jnp.float32) * inv  # [..., S, rot/2]
    sin = jnp.sin(ang)[..., None, :]
    cos = jnp.cos(ang)[..., None, :]
    xr, xp = x[..., :rot], x[..., rot:]
    x1, x2 = xr[..., : rot // 2], xr[..., rot // 2:]
    out1 = x1 * cos - x2 * sin
    out2 = x2 * cos + x1 * sin
    return jnp.concatenate([out1.astype(x.dtype), out2.astype(x.dtype), xp], axis=-1)


# ---------------------------------------------------------------------------
# embeddings / logits
# ---------------------------------------------------------------------------

def embed_defs(cfg: ModelConfig):
    d = {"tok": ParamDef((cfg.vocab_c, cfg.d_model), ("vocab", "embed_fsdp"),
                         init="embed", scale=0.02)}
    if not cfg.tie_embeddings:
        d["unembed"] = ParamDef((cfg.d_model, cfg.vocab_c), ("embed_fsdp", "vocab"))
    return d


def embed_tokens(p, tokens: jax.Array, cfg: ModelConfig) -> jax.Array:
    dt = jnp.dtype(cfg.dtype)
    return p["tok"].astype(dt)[tokens]


def logits_from_hidden(p, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    dt = x.dtype
    if cfg.tie_embeddings:
        return jnp.einsum("bsd,vd->bsv", x, p["tok"].astype(dt))
    return jnp.einsum("bsd,dv->bsv", x, p["unembed"].astype(dt))


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  mask: Optional[jax.Array] = None) -> jax.Array:
    """Mean next-token CE in f32; labels [B, S] int32."""
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


def chunked_lm_loss(
    embed_p, h: jax.Array, labels: jax.Array, cfg: ModelConfig,
    rules=None, mesh=None, *, chunk: int = 1024,
) -> jax.Array:
    """Fused unembed + CE, scanned over sequence chunks.

    Never materializes the [B, S, V] logits (MaxText-style): per chunk the
    [B, chunk, V] logits are computed, reduced to (lse, gold) and discarded;
    the checkpoint makes backward recompute them chunk-by-chunk.  Cuts the
    dominant train-memory term for large-vocab archs.
    """
    from repro.sharding.rules import constrain as _constrain

    B, S, D = h.shape
    chunk = min(chunk, S)
    pad = (-S) % chunk
    if pad:
        h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
    nc = (S + pad) // chunk
    hs = h.reshape(B, nc, chunk, D).swapaxes(0, 1)
    ls = labels.reshape(B, nc, chunk).swapaxes(0, 1)

    @jax.checkpoint
    def body(carry, inp):
        hc, lc = inp                                  # [B, chunk, D], [B, chunk]
        logits = logits_from_hidden(embed_p, hc, cfg)
        logits = _constrain(logits, ("batch", None, "vocab"), rules, mesh)
        lf = logits.astype(jnp.float32)
        lse = jax.nn.logsumexp(lf, axis=-1)           # [B, chunk]
        iota = jax.lax.broadcasted_iota(jnp.int32, lf.shape, 2)
        gold = jnp.sum(jnp.where(iota == lc[..., None], lf, 0.0), axis=-1)
        valid = (lc >= 0).astype(jnp.float32)
        nll = (lse - gold) * valid
        return (carry[0] + jnp.sum(nll), carry[1] + jnp.sum(valid)), None

    (total, count), _ = jax.lax.scan(
        body, (jnp.float32(0), jnp.float32(0)), (hs, ls))
    return total / jnp.maximum(count, 1.0)
