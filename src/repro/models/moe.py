"""Mixture-of-Experts layer: top-k routing + capacity-bounded dispatch.

Dispatch is *data-local*: tokens are routed within their own batch row
(vmapped), so the scatter/gather never crosses the data axis — the baseline
layout keeps experts TP-sharded on their ffn dim ("mlp" -> model) and pays
zero all-to-all.  Expert-parallel (experts -> data axis, all-to-all
dispatch) is a hillclimb variant (EXPERIMENTS.md §Perf).

Capacity C = ceil(S*k*cf/E) per (row, expert); overflow tokens are dropped
(standard Switch behaviour) and the aux load-balance loss pushes the router
toward uniformity.
"""

from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.layers import mlp_defs, apply_mlp
from repro.sharding.rules import ParamDef


def moe_defs(cfg: ModelConfig, layers: tuple[int, ...] = ()):
    D, F, E = cfg.d_model, cfg.expert_ff, cfg.n_experts
    lx = ("layers",) * len(layers)
    d = {
        "router": ParamDef(layers + (D, E), lx + ("embed_fsdp", None)),
        "wi": ParamDef(layers + (E, D, F), lx + ("experts", "embed_fsdp", "mlp")),
        "wg": ParamDef(layers + (E, D, F), lx + ("experts", "embed_fsdp", "mlp")),
        "wo": ParamDef(layers + (E, F, D), lx + ("experts", "mlp", "embed_fsdp")),
    }
    if cfg.n_shared_experts:
        d["shared"] = mlp_defs(cfg, layers, d_ff=cfg.expert_ff * cfg.n_shared_experts)
    return d


def _capacity(S: int, cfg: ModelConfig) -> int:
    return max(int(math.ceil(S * cfg.top_k * cfg.capacity_factor / cfg.n_experts)), 1)


def apply_moe(p, x: jax.Array, cfg: ModelConfig, rules=None,
              mesh=None) -> Tuple[jax.Array, jax.Array]:
    """x: [B, S, D] -> (y [B, S, D], aux_loss scalar).

    Explicitly batched dispatch (no vmap/scatter over hidden): the token
    gather is a ``repeat``, the combine is a reshape-sum, and the only
    scatter carries an iota batch index — all of which GSPMD shards on the
    batch dim given the constraints below (a vmapped scatter made it
    replicate the global batch: 84 GiB/device on mixtral train).
    """
    from repro.sharding.rules import constrain

    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    C = _capacity(S, cfg)
    dt = x.dtype

    logits = jnp.einsum("bsd,de->bse", x, p["router"].astype(dt)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_val, gate_idx = jax.lax.top_k(probs, K)           # [B, S, K]
    gate_val = gate_val / jnp.maximum(gate_val.sum(-1, keepdims=True), 1e-9)

    # Switch-style aux loss: E * sum_e f_e * p_e
    me = jnp.mean(probs, axis=(0, 1))                       # [E]
    ce = jnp.mean(
        jax.nn.one_hot(gate_idx[..., 0], E, dtype=jnp.float32), axis=(0, 1)
    )
    aux = E * jnp.sum(me * ce)

    # --- group-local dispatch (GShard semantics) --------------------------
    # Capacity is per (batch row x sequence shard): dispatch never crosses
    # the act_seq sharding (no all-gather of the sequence) and the
    # spmd_axis_name'd vmaps tell GSPMD the scatter/gather are parallel on
    # the mapped dims (a plain batched scatter made it gather the *global*
    # [B, S*K, D] tensor every layer).
    seq_ax = rules.physical("act_seq", mesh) if (rules and mesh) else None
    bat_ax = rules.physical("act_batch", mesh) if (rules and mesh) else None
    shards = 1
    if seq_ax is not None:
        sz = (mesh.shape[seq_ax] if isinstance(seq_ax, str)
              else int(np.prod([mesh.shape[a] for a in seq_ax])))
        if S % sz == 0 and S >= sz:
            shards = sz
    # One-hot dispatch matmul cost is O(S_g^2) per group: cap the group at
    # ~1024 tokens even when act_seq is unsharded (prefill), keeping the
    # group count a multiple of the seq-shard count so dispatch never
    # crosses shards.
    nG = shards
    while S % (nG * 2) == 0 and S // nG > 1024:
        nG *= 2
    S_g = S // nG
    C = _capacity(S_g, cfg)
    T = S_g * K
    # expert weights: gathered once per layer under FSDP training rules
    # (moe_wD=None), or kept D-sharded stationary when serving (moe_wD=data)
    wi = constrain(p["wi"].astype(dt), ("experts", "moe_wD", "mlp"), rules, mesh)
    wg = constrain(p["wg"].astype(dt), ("experts", "moe_wD", "mlp"), rules, mesh)
    wo = constrain(p["wo"].astype(dt), ("experts", "mlp", "moe_wD"), rules, mesh)

    def row(xr, er, gr):
        """One (row x group): xr [S_g, D]; er/gr [S_g, K]."""
        ef = er.reshape(T)
        gf = gr.reshape(T).astype(dt)
        pos = jnp.zeros((T,), jnp.int32)
        for ee in range(E):   # unrolled: avoids a [T, E] cumsum tensor
            m_e = ef == ee
            pos = jnp.where(m_e, jnp.cumsum(m_e.astype(jnp.int32)) - 1, pos)
        keep = pos < C
        slot = jnp.where(keep, ef * C + pos, E * C)         # E*C => dropped
        xt = jnp.repeat(xr, K, axis=0)                      # [T, D]
        # one-hot matmul dispatch/combine (GShard): ~+2k/E FLOPs overhead,
        # but pure dots — shards perfectly where a scatter made GSPMD
        # re-gather globally.  Out-of-range slots produce all-zero rows,
        # which IS the capacity-drop semantics.
        disp = jax.nn.one_hot(slot, E * C, dtype=dt)        # [T, E*C]
        buf = jnp.einsum("te,td->ed", disp, xt).reshape(E, C, D)
        hh = jnp.einsum("ecd,edf->ecf", buf, wi)
        gg = jnp.einsum("ecd,edf->ecf", buf, wg)
        yy = jnp.einsum("ecf,efd->ecd", jax.nn.silu(gg) * hh, wo)
        picked = jnp.einsum("te,ed->td", disp, yy.reshape(E * C, D))
        picked = picked * gf[:, None]
        return picked.reshape(S_g, K, D).sum(axis=1)        # [S_g, D]

    xg = x.reshape(B, nG, S_g, D)
    eg = gate_idx.reshape(B, nG, S_g, K)
    gg_ = gate_val.reshape(B, nG, S_g, K)
    inner = jax.vmap(row, spmd_axis_name=seq_ax) if nG > 1 else jax.vmap(row)
    outer = (jax.vmap(inner, spmd_axis_name=bat_ax) if bat_ax is not None
             else jax.vmap(inner))
    y = outer(xg, eg, gg_).reshape(B, S, D)
    y = constrain(y, ("act_batch", "act_seq", "act_embed"), rules, mesh)

    if cfg.n_shared_experts:
        y = y + apply_mlp(p["shared"], x, cfg)
    return y, aux
