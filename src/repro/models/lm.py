"""Decoder-only LM families: dense / moe / hybrid(zamba2) / rwkv / vlm.

One declarative ``param_defs`` tree per family (stacked [L, ...] leaves for
``lax.scan`` over layers — keeps HLO size and 512-way SPMD compile time
O(1) in depth), plus three entry points used by the launcher:

    loss_fn(params, batch)                 -> scalar loss   (train cells)
    prefill(params, batch)                 -> (last_logits, cache)
    decode_step(params, tokens, pos, cache)-> (logits, cache)

All activations carry logical-axis sharding constraints resolved through a
``ShardingRules`` table, so one model definition serves every mesh/layout.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import rwkv as rwkv_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import (
    apply_mlp, apply_norm, apply_rope, cross_entropy, embed_defs,
    embed_tokens, logits_from_hidden, mlp_defs, norm_defs,
)
from repro.sharding.rules import ParamDef, ShardingRules, TRAIN_RULES, constrain


# ---------------------------------------------------------------------------
# parameter trees
# ---------------------------------------------------------------------------

def _block_defs(cfg: ModelConfig, layers: tuple[int, ...]):
    d = {
        "ln1": norm_defs(cfg, layers),
        "attn": attn.attn_defs(cfg, layers),
        "ln2": norm_defs(cfg, layers),
    }
    if cfg.n_experts:
        d["moe"] = moe_mod.moe_defs(cfg, layers)
    else:
        d["mlp"] = mlp_defs(cfg, layers)
    return d


def param_defs(cfg: ModelConfig) -> Dict[str, Any]:
    fam = cfg.family
    defs: Dict[str, Any] = {"embed": embed_defs(cfg), "final_norm": norm_defs(cfg)}
    if fam in ("dense", "moe", "vlm"):
        defs["blocks"] = _block_defs(cfg, (cfg.n_layers,))
        if fam == "vlm":
            defs["frontend_proj"] = ParamDef(
                (cfg.d_model, cfg.d_model), ("embed_fsdp", None)
            )
    elif fam == "hybrid":
        G = cfg.n_layers // cfg.attn_every
        defs["mamba"] = ssm_mod.ssm_defs(cfg, (G, cfg.attn_every))
        defs["shared_attn"] = {
            "ln1": norm_defs(cfg),
            "attn": attn.attn_defs(cfg),
            "ln2": norm_defs(cfg),
            "mlp": mlp_defs(cfg),
        }
    elif fam == "rwkv":
        defs["blocks"] = {
            "ln1": norm_defs(cfg, (cfg.n_layers,)),
            "ln2": norm_defs(cfg, (cfg.n_layers,)),
            **rwkv_mod.rwkv_defs(cfg, (cfg.n_layers,)),
        }
    else:
        raise ValueError(f"lm.py does not handle family {fam!r}")
    return defs


def cache_defs(cfg: ModelConfig, batch: int, seq: int) -> Dict[str, Any]:
    """Decode-cache ParamDef tree (axes drive dry-run cache sharding)."""
    dt = jnp.dtype(cfg.dtype)
    fam = cfg.family
    KV, hd = cfg.kv_heads_c, cfg.head_dim
    cache_len = min(seq, cfg.window) if cfg.window else seq

    def kv(l_shape, l_axes):
        return {
            "k": ParamDef(l_shape + (batch, cache_len, KV, hd),
                          l_axes + ("cache_batch", "cache_seq", "kv", None),
                          init="zeros", dtype=dt),
            "v": ParamDef(l_shape + (batch, cache_len, KV, hd),
                          l_axes + ("cache_batch", "cache_seq", "kv", None),
                          init="zeros", dtype=dt),
        }

    if fam in ("dense", "moe", "vlm"):
        return kv((cfg.n_layers,), ("layers",))
    if fam == "hybrid":
        G, K = cfg.n_layers // cfg.attn_every, cfg.attn_every
        H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
        return {
            "attn": kv((G,), ("layers",)),
            "ssm_state": ParamDef((G, K, batch, H, P, N),
                                  ("layers", "layers", "cache_batch", "state", None, None),
                                  init="zeros", dtype=dt),
            "conv": ParamDef((G, K, batch, ssm_mod.CONV_K - 1,
                              cfg.d_inner + 2 * N),
                             ("layers", "layers", "cache_batch", None, "mlp"),
                             init="zeros", dtype=dt),
        }
    if fam == "rwkv":
        H = cfg.d_model // 64
        return {
            "wkv": ParamDef((cfg.n_layers, batch, H, 64, 64),
                            ("layers", "cache_batch", "state", None, None),
                            init="zeros", dtype=jnp.float32),
            "shift_att": ParamDef((cfg.n_layers, batch, cfg.d_model),
                                  ("layers", "cache_batch", None), init="zeros", dtype=dt),
            "shift_ffn": ParamDef((cfg.n_layers, batch, cfg.d_model),
                                  ("layers", "cache_batch", None), init="zeros", dtype=dt),
        }
    raise ValueError(fam)


# ---------------------------------------------------------------------------
# transformer block (dense / moe / vlm share it)
# ---------------------------------------------------------------------------

def _attention_sublayer(cfg, p, h, positions, rules, mesh, *, cache=None,
                        pos=None, window):
    dt = h.dtype
    B, S, D = h.shape
    a = apply_norm(p["ln1"], h, cfg)
    q = jnp.einsum("bsd,dhk->bshk", a, p["attn"]["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", a, p["attn"]["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", a, p["attn"]["wv"].astype(dt))
    if cfg.qk_norm:
        from repro.models.layers import rms_norm_simple
        q = rms_norm_simple(q) * p["attn"]["q_norm"].astype(dt)
        k = rms_norm_simple(k) * p["attn"]["k_norm"].astype(dt)
    q = apply_rope(q, positions, cfg)
    k = apply_rope(k, positions, cfg)
    q = constrain(q, ("batch", None, "heads", None), rules, mesh)
    k = constrain(k, ("batch", None, "kv", None), rules, mesh)

    if cache is None:
        o = attn.attend(cfg, q, k, v, causal=True, window=window)
        new_cache = {"k": k, "v": v}
    else:
        ck, cv, cpos = cache["k"], cache["v"], pos
        if cfg.window:
            slot = cpos % ck.shape[1]            # ring buffer for SWA caches
        else:
            slot = cpos
        ck = jax.lax.dynamic_update_slice_in_dim(ck, k, slot, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cv, v, slot, axis=1)
        if cfg.window:
            o = attn.decode_attention(q, ck, cv, pos=jnp.minimum(cpos, ck.shape[1] - 1))
        else:
            o = attn.decode_attention(q, ck, cv, pos=cpos, window=window)
        new_cache = {"k": ck, "v": cv}
    o = jnp.einsum("bshk,hkd->bsd", o, p["attn"]["wo"].astype(dt))
    o = constrain(o, ("act_batch", "act_seq", "act_embed"), rules, mesh)
    return h + o, new_cache


def _block(cfg, p, h, positions, rules, mesh, *, cache=None, pos=None):
    h, new_cache = _attention_sublayer(
        cfg, p, h, positions, rules, mesh, cache=cache, pos=pos, window=cfg.window
    )
    m = apply_norm(p["ln2"], h, cfg)
    if cfg.n_experts:
        y, aux = moe_mod.apply_moe(p["moe"], m, cfg, rules, mesh)
    else:
        y, aux = apply_mlp(p["mlp"], m, cfg), jnp.float32(0)
    y = constrain(y, ("act_batch", "act_seq", "act_embed"), rules, mesh)
    h = h + y
    h = constrain(h, ("act_batch", "act_seq", "act_embed"), rules, mesh)
    return h, new_cache, aux


def _rwkv_block(cfg, p, h, rules, mesh, *, cache=None):
    a = apply_norm(p["ln1"], h, cfg)
    y, c_att = rwkv_mod.apply_time_mix(p["time_mix"], a, cfg, cache=cache)
    h = h + y
    m = apply_norm(p["ln2"], h, cfg)
    y, c_ffn = rwkv_mod.apply_channel_mix(p["channel_mix"], m, cfg, cache=cache)
    h = h + y
    h = constrain(h, ("act_batch", "act_seq", "act_embed"), rules, mesh)
    new_cache = None if cache is None else {**c_att, **c_ffn}
    return h, new_cache


# ---------------------------------------------------------------------------
# full forward passes
# ---------------------------------------------------------------------------

def _maybe_remat(cfg, fn):
    if cfg.remat:
        pol = (jax.checkpoint_policies.checkpoint_dots
               if cfg.remat_policy == "dots"
               else jax.checkpoint_policies.nothing_saveable)
        return jax.checkpoint(fn, policy=pol)
    return fn


def _stack_forward(cfg, params, h, positions, rules, mesh, collect_cache: bool):
    """Scan over layers for dense/moe/vlm; returns (h, cache_tree, aux)."""

    def body(carry, lp):
        h, aux = carry
        h, kv, a = _block(cfg, lp, h, positions, rules, mesh)
        return (h, aux + a), (kv if collect_cache else 0)

    body = _maybe_remat(cfg, body)
    (h, aux), caches = jax.lax.scan(body, (h, jnp.float32(0)), params["blocks"])
    return h, (caches if collect_cache else None), aux


def _hybrid_forward(cfg, params, h, positions, rules, mesh, collect_cache: bool):
    shared = params["shared_attn"]

    def group(carry, gp):
        h, aux = carry

        def mamba_layer(hh, mp):
            o, _ = ssm_mod.apply_ssm(mp, hh, cfg)
            hh = constrain(hh + o, ("act_batch", "act_seq", "act_embed"),
                           rules, mesh)
            return hh, 0

        h, _ = jax.lax.scan(mamba_layer, h, gp)
        h, kv, a = _block(cfg, shared, h, positions, rules, mesh)
        return (h, aux + a), (kv if collect_cache else 0)

    group = _maybe_remat(cfg, group)
    (h, aux), caches = jax.lax.scan(group, (h, jnp.float32(0)), params["mamba"])
    return h, (caches if collect_cache else None), aux


def _rwkv_forward(cfg, params, h, rules, mesh):
    def body(carry, lp):
        return _rwkv_block(cfg, lp, carry, rules, mesh)[0], 0

    body = _maybe_remat(cfg, body)
    h, _ = jax.lax.scan(body, h, params["blocks"])
    return h, None, jnp.float32(0)


def _embed_inputs(cfg, params, batch, rules, mesh):
    """Token (+frontend) embedding; returns (h, positions, n_frontend)."""
    tokens = batch["tokens"]
    h = embed_tokens(params["embed"], tokens, cfg)
    n_front = 0
    if cfg.family == "vlm" and "patches" in batch:
        dtp = h.dtype
        pat = jnp.einsum(
            "bsd,de->bse", batch["patches"].astype(dtp),
            params["frontend_proj"].astype(dtp),
        )
        h = jnp.concatenate([pat, h], axis=1)
        n_front = batch["patches"].shape[1]
    S = h.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)[None, :]
    h = constrain(h, ("act_batch", "act_seq", "act_embed"), rules, mesh)
    return h, positions, n_front


def _backbone(params, batch, cfg: ModelConfig, *, rules, mesh, collect_cache):
    """Embed + blocks + final norm; returns (h_text, cache, aux)."""
    h, positions, n_front = _embed_inputs(cfg, params, batch, rules, mesh)
    if cfg.family in ("dense", "moe", "vlm"):
        h, cache, aux = _stack_forward(
            cfg, params, h, positions, rules, mesh, collect_cache
        )
    elif cfg.family == "hybrid":
        h, cache, aux = _hybrid_forward(
            cfg, params, h, positions, rules, mesh, collect_cache
        )
    elif cfg.family == "rwkv":
        h, cache, aux = _rwkv_forward(cfg, params, h, rules, mesh)
    else:
        raise ValueError(cfg.family)
    h = apply_norm(params["final_norm"], h, cfg)
    if n_front:
        h = h[:, n_front:]
    return h, cache, aux


def forward(
    params, batch, cfg: ModelConfig,
    *, rules: ShardingRules = TRAIN_RULES, mesh=None, collect_cache=False,
    last_only: bool = False,
):
    """Full-sequence forward. Returns (logits, cache, aux).

    ``last_only`` computes logits for the final position only (prefill never
    pays the [B, S, V] unembed).
    """
    h, cache, aux = _backbone(params, batch, cfg, rules=rules, mesh=mesh,
                              collect_cache=collect_cache)
    if last_only:
        h = h[:, -1:]
    logits = logits_from_hidden(params["embed"], h, cfg)
    logits = constrain(logits, ("batch", None, "vocab"), rules, mesh)
    return logits, cache, aux


def loss_fn(params, batch, cfg: ModelConfig, *, rules=TRAIN_RULES, mesh=None):
    from repro.models.layers import chunked_lm_loss
    h, _, aux = _backbone(params, batch, cfg, rules=rules, mesh=mesh,
                          collect_cache=False)
    loss = chunked_lm_loss(params["embed"], h, batch["labels"], cfg,
                           rules, mesh)
    return loss + 0.01 * aux, {"ce": loss, "aux": aux}


# ---------------------------------------------------------------------------
# serving: prefill + single-token decode
# ---------------------------------------------------------------------------

def prefill(params, batch, cfg: ModelConfig, *, rules=TRAIN_RULES, mesh=None):
    """Process a full prompt; emit last-position logits + decode cache.

    For attention families the per-layer K/V tensors are the cache (SWA
    archs keep the trailing ``window``); recurrent families re-run a short
    recurrence to produce their state (cache collection for them comes from
    the decode path; prefill here returns final logits only).
    """
    logits, cache, _ = forward(
        params, batch, cfg, rules=rules, mesh=mesh,
        collect_cache=cfg.family in ("dense", "moe", "vlm", "hybrid"),
        last_only=True,
    )
    return logits[:, -1], cache


def decode_step(params, tokens, pos, cache, cfg: ModelConfig,
                *, rules=TRAIN_RULES, mesh=None):
    """One decode step. tokens: i32[B]; pos: i32 scalar; cache: pytree."""
    h = embed_tokens(params["embed"], tokens[:, None], cfg)
    h = constrain(h, ("act_batch", None, "act_embed"), rules, mesh)
    positions = jnp.full((1, 1), pos, dtype=jnp.int32)

    if cfg.family in ("dense", "moe", "vlm"):
        def body(carry, xs):
            lp, ck, cv = xs
            hh, new_cache, _ = _block(
                cfg, lp, carry, positions, rules, mesh,
                cache={"k": ck, "v": cv}, pos=pos,
            )
            return hh, (new_cache["k"], new_cache["v"])

        h, (nk, nv) = jax.lax.scan(
            body, h, (params["blocks"], cache["k"], cache["v"])
        )
        new_cache = {"k": nk, "v": nv}

    elif cfg.family == "hybrid":
        shared = params["shared_attn"]

        def group(carry, xs):
            gp, ck, cv, sst, scv = xs
            hh = carry

            def mamba_layer(c, xs2):
                mp, st_i, cv_i = xs2
                o, nc = ssm_mod.apply_ssm(
                    mp, c, cfg, cache={"ssm_state": st_i, "conv": cv_i}
                )
                return c + o, (nc["ssm_state"], nc["conv"])

            hh, (nst, ncv) = jax.lax.scan(mamba_layer, hh, (gp, sst, scv))
            hh, kv, _ = _block(
                cfg, shared, hh, positions, rules, mesh,
                cache={"k": ck, "v": cv}, pos=pos,
            )
            return hh, (kv["k"], kv["v"], nst, ncv)

        h, (nk, nv, nst, ncv) = jax.lax.scan(
            group, h,
            (params["mamba"], cache["attn"]["k"], cache["attn"]["v"],
             cache["ssm_state"], cache["conv"]),
        )
        new_cache = {"attn": {"k": nk, "v": nv}, "ssm_state": nst, "conv": ncv}

    elif cfg.family == "rwkv":
        def body(carry, xs):
            lp, wkv, sa, sf = xs
            hh = carry
            a = apply_norm(lp["ln1"], hh, cfg)
            y, ca = rwkv_mod.apply_time_mix(
                lp["time_mix"], a, cfg, cache={"wkv": wkv, "shift_att": sa}
            )
            hh = hh + y
            m = apply_norm(lp["ln2"], hh, cfg)
            y, cf = rwkv_mod.apply_channel_mix(
                lp["channel_mix"], m, cfg, cache={"shift_ffn": sf}
            )
            hh = hh + y
            return hh, (ca["wkv"], ca["shift_att"], cf["shift_ffn"])

        h, (nw, nsa, nsf) = jax.lax.scan(
            body, h,
            (params["blocks"], cache["wkv"], cache["shift_att"],
             cache["shift_ffn"]),
        )
        new_cache = {"wkv": nw, "shift_att": nsa, "shift_ffn": nsf}
    else:
        raise ValueError(cfg.family)

    h = apply_norm(params["final_norm"], h, cfg)
    logits = logits_from_hidden(params["embed"], h, cfg)[:, 0]
    logits = constrain(logits, ("act_batch", "vocab"), rules, mesh)
    return logits, new_cache
