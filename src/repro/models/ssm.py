"""Mamba2 / SSD block (zamba2 backbone), chunked-scan formulation.

State-space recurrence per head h (state N, head dim P):
    S_t = a_t * S_{t-1} + x_t (dt_t B_t)^T        a_t = exp(dt_t * A_h)
    y_t = C_t . S_t + D_h * x_t

Computed chunk-parallel (Dao & Gu 2024): intra-chunk attention-like matmul
with decay mask + inter-chunk state carried by ``lax.scan`` — the same
split the Pallas ``linattn_scan`` kernel tiles for VMEM on TPU.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import rms_norm_simple
from repro.sharding.rules import ParamDef

CONV_K = 4  # depthwise causal conv width (mamba2 default)


def ssm_defs(cfg: ModelConfig, layers: tuple[int, ...] = ()):
    D, DI, H, P, N = (cfg.d_model, cfg.d_inner, cfg.ssm_heads,
                      cfg.ssm_head_dim, cfg.ssm_state)
    conv_dim = DI + 2 * N
    lx = ("layers",) * len(layers)
    return {
        # in_proj emits [z (DI) | xBC (DI+2N) | dt (H)]
        "w_in": ParamDef(layers + (D, 2 * DI + 2 * N + H), lx + ("embed_fsdp", "mlp")),
        "conv_w": ParamDef(layers + (CONV_K, conv_dim), lx + (None, "mlp")),
        "conv_b": ParamDef(layers + (conv_dim,), lx + ("mlp",), init="zeros"),
        "A_log": ParamDef(layers + (H,), lx + (None,), init="zeros"),
        "D_skip": ParamDef(layers + (H,), lx + (None,), init="ones"),
        "dt_bias": ParamDef(layers + (H,), lx + (None,), init="zeros"),
        "norm_scale": ParamDef(layers + (DI,), lx + ("mlp",), init="ones"),
        "w_out": ParamDef(layers + (DI, D), lx + ("mlp", "embed_fsdp")),
    }


def _split_proj(cfg: ModelConfig, proj: jax.Array):
    DI, N, H = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    z = proj[..., :DI]
    xBC = proj[..., DI:2 * DI + 2 * N]
    dt = proj[..., 2 * DI + 2 * N:]
    return z, xBC, dt


def _causal_conv(xBC: jax.Array, w: jax.Array, b: jax.Array,
                 conv_state: Optional[jax.Array] = None):
    """Depthwise causal conv over seq. xBC: [B, S, Cd]; w: [K, Cd].

    Returns (out, new_conv_state[K-1 last inputs]).
    """
    B, S, Cd = xBC.shape
    if conv_state is None:
        conv_state = jnp.zeros((B, CONV_K - 1, Cd), xBC.dtype)
    xp = jnp.concatenate([conv_state, xBC], axis=1)        # [B, S+K-1, Cd]
    out = sum(
        xp[:, i:i + S] * w[i][None, None, :] for i in range(CONV_K)
    ) + b[None, None, :]
    out = jax.nn.silu(out)
    new_state = xp[:, -(CONV_K - 1):]
    return out, new_state


def _ssd_chunked(x, dt, A, Bm, Cm, chunk: int):
    """Chunk-parallel SSD scan.

    x: [B, S, H, P]; dt: [B, S, H] (>0); A: [H] (<0); Bm/Cm: [B, S, N].
    Returns y [B, S, H, P], final state [B, H, P, N].
    """
    Bb, S, H, P = x.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    pad = (-S) % Q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    nc = (S + pad) // Q

    def resh(a, tail):
        return a.reshape((Bb, nc, Q) + tail).swapaxes(0, 1)  # [nc, B, Q, ...]

    xs, dts = resh(x, (H, P)), resh(dt, (H,))
    Bs, Cs = resh(Bm, (N,)), resh(Cm, (N,))
    la = jnp.einsum("h,cbqh->cbqh", A, dts)                  # log decay per step

    @jax.checkpoint   # recompute per-chunk [Q,Q,H] decay mats in backward
    def chunk_step(state, inp):
        xq, dq, bq, cq, laq = inp                            # [B,Q,H,P] etc.
        L = jnp.cumsum(laq, axis=1)                          # [B, Q, H] inclusive
        # intra-chunk: M[t,i] = exp(L_t - L_i) * (C_t.B_i) * dt_i  (i <= t)
        seg = L[:, :, None, :] - L[:, None, :, :]            # [B, Q, Q, H]
        mask = jnp.tril(jnp.ones((Q, Q), bool))
        seg = jnp.where(mask[None, :, :, None], seg, -jnp.inf)
        cb = jnp.einsum("bqn,bin->bqi", cq, bq)              # [B, Q, Q]
        M = jnp.exp(seg) * cb[..., None] * dq[:, None, :, :]  # [B,Q,Q,H]
        y_intra = jnp.einsum("bqih,bihp->bqhp", M.astype(xq.dtype), xq)
        # inter-chunk: y += exp(L_t) * C_t . state
        y_inter = jnp.einsum(
            "bqh,bqn,bhpn->bqhp", jnp.exp(L).astype(xq.dtype), cq, state
        )
        # state update: S' = exp(L_Q) S + sum_i exp(L_Q - L_i) x_i (dt_i B_i)^T
        Lq = L[:, -1]                                        # [B, H]
        w_i = jnp.exp(Lq[:, None] - L) * dq                  # [B, Q, H]
        ds = jnp.einsum("bqh,bqhp,bqn->bhpn", w_i.astype(xq.dtype), xq, bq)
        state = jnp.exp(Lq)[:, :, None, None].astype(state.dtype) * state + ds
        return state, y_intra + y_inter

    state0 = jnp.zeros((Bb, H, P, N), x.dtype)
    state, ys = jax.lax.scan(chunk_step, state0, (xs, dts, Bs, Cs, la))
    y = ys.swapaxes(0, 1).reshape(Bb, S + pad, H, P)[:, :S]
    return y, state


def apply_ssm(
    p, x: jax.Array, cfg: ModelConfig,
    *, cache: Optional[dict] = None,
) -> Tuple[jax.Array, Optional[dict]]:
    """Mamba2 block. x: [B, S, D].  With ``cache`` (decode): S==1 recurrent."""
    B, S, D = x.shape
    H, P, N, DI = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state, cfg.d_inner
    dt_f = x.dtype
    proj = jnp.einsum("bsd,de->bse", x, p["w_in"].astype(dt_f))
    z, xBC, dt_raw = _split_proj(cfg, proj)

    if cache is None:
        xBC, _ = _causal_conv(xBC, p["conv_w"].astype(dt_f), p["conv_b"].astype(dt_f))
        new_cache = None
    else:
        xBC, conv_state = _causal_conv(
            xBC, p["conv_w"].astype(dt_f), p["conv_b"].astype(dt_f),
            conv_state=cache["conv"],
        )
        new_cache = {"conv": conv_state}

    xin = xBC[..., :DI].reshape(B, S, H, P)
    Bm = xBC[..., DI:DI + N]
    Cm = xBC[..., DI + N:]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"][None, None, :])
    A = -jnp.exp(p["A_log"].astype(jnp.float32))             # [H], negative

    if cache is None:
        y, _ = _ssd_chunked(xin, dt.astype(dt_f), A, Bm, Cm, cfg.ssm_chunk)
    else:
        # single-step recurrence
        st = cache["ssm_state"]                               # [B, H, P, N]
        a = jnp.exp(dt[:, 0] * A[None, :])                    # [B, H]
        dBx = jnp.einsum(
            "bh,bhp,bn->bhpn", dt[:, 0].astype(dt_f), xin[:, 0], Bm[:, 0]
        )
        st = a[:, :, None, None].astype(st.dtype) * st + dBx
        y = jnp.einsum("bn,bhpn->bhp", Cm[:, 0], st)[:, None]  # [B, 1, H, P]
        new_cache["ssm_state"] = st

    y = y + xin * p["D_skip"].astype(dt_f)[None, None, :, None]
    y = y.reshape(B, S, DI)
    y = rms_norm_simple(y * jax.nn.silu(z)) * p["norm_scale"].astype(dt_f)
    out = jnp.einsum("bse,ed->bsd", y, p["w_out"].astype(dt_f))
    return out, new_cache


def init_ssm_cache(cfg: ModelConfig, batch: int, dtype) -> dict:
    H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    return {
        "ssm_state": jnp.zeros((batch, H, P, N), dtype),
        "conv": jnp.zeros((batch, CONV_K - 1, cfg.d_inner + 2 * N), dtype),
    }
