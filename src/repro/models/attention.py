"""GQA attention: blockwise-jnp path (memory-safe everywhere) + Pallas path.

The blockwise path is online-softmax over KV tiles with the GQA grouped
einsum (KV heads never materialized at Q-head width), causal and
sliding-window masking, and works for self/cross attention, prefill and
decode.  The Pallas kernel (repro.kernels.flash_attention) is the TPU fast
path; `use_pallas=True` swaps it in (validated in interpret mode on CPU).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.sharding.rules import ParamDef

NEG_INF = -1e30


def attn_defs(cfg: ModelConfig, layers: tuple[int, ...] = (), d_model: int | None = None):
    D = d_model or cfg.d_model
    H, KV, hd = cfg.heads_c, cfg.kv_heads_c, cfg.head_dim
    lx = ("layers",) * len(layers)
    d = {
        "wq": ParamDef(layers + (D, H, hd), lx + ("embed_fsdp", "heads", None)),
        "wk": ParamDef(layers + (D, KV, hd), lx + ("embed_fsdp", "kv", None)),
        "wv": ParamDef(layers + (D, KV, hd), lx + ("embed_fsdp", "kv", None)),
        "wo": ParamDef(layers + (H, hd, D), lx + ("heads", None, "embed_fsdp")),
    }
    if cfg.qk_norm:
        d["q_norm"] = ParamDef(layers + (hd,), lx + (None,), init="ones")
        d["k_norm"] = ParamDef(layers + (hd,), lx + (None,), init="ones")
    return d


def _mask_block(
    q_pos: jax.Array,     # [Sq]
    k_pos: jax.Array,     # [Bk]
    causal: bool,
    window: Optional[int],
    k_valid: Optional[jax.Array] = None,  # [Bk] bool (cache fill mask)
) -> jax.Array:
    m = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if causal:
        m &= q_pos[:, None] >= k_pos[None, :]
    if window is not None:
        m &= q_pos[:, None] - k_pos[None, :] < window
    if k_valid is not None:
        m &= k_valid[None, :]
    return m


def _block_attn(q, k, v, q_pos, k_pos, *, causal, window, scale, k_valid=None):
    """One (q-tile x kv-tile) online-softmax update step.

    q: [B, Sq, KV, G, hd]   k/v: [B, Bk, KV, hd]
    returns partial (m, l, acc) update terms.
    """
    s = jnp.einsum("bqkgd,bskd->bkgqs", q, k).astype(jnp.float32) * scale
    mask = _mask_block(q_pos, k_pos, causal, window, k_valid)  # [Sq, Bk]
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    m_new = jnp.max(s, axis=-1)                                   # [B,KV,G,Sq]
    p = jnp.exp(s - m_new[..., None])
    l_new = jnp.sum(p, axis=-1)
    acc_new = jnp.einsum("bkgqs,bskd->bkgqd", p.astype(v.dtype), v)
    return m_new, l_new, acc_new


def _combine(m1, l1, a1, m2, l2, a2):
    m = jnp.maximum(m1, m2)
    e1 = jnp.exp(m1 - m)
    e2 = jnp.exp(m2 - m)
    l = l1 * e1 + l2 * e2
    a = a1 * e1[..., None].astype(a1.dtype) + a2 * e2[..., None].astype(a2.dtype)
    return m, l, a


def blockwise_attention(
    q: jax.Array,              # [B, Sq, H, hd]
    k: jax.Array,              # [B, Sk, KV, hd]
    v: jax.Array,              # [B, Sk, KV, hd]
    *,
    causal: bool = True,
    window: Optional[int] = None,
    q_offset: jax.Array | int = 0,
    block_q: int = 512,
    block_k: int = 512,
    k_valid: Optional[jax.Array] = None,   # [B? or broadcast, Sk] bool
) -> jax.Array:
    """Memory-safe attention; never materializes [Sq, Sk] scores."""
    B, Sq, H, hd = q.shape
    _, Sk, KV, _ = k.shape
    G = H // KV
    scale = hd ** -0.5
    qg = q.reshape(B, Sq, KV, G, hd)

    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    # pad to tile multiples
    pq = (-Sq) % block_q
    pk = (-Sk) % block_k
    if pq:
        qg = jnp.pad(qg, ((0, 0), (0, pq), (0, 0), (0, 0), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    kv_valid = jnp.arange(Sk + pk) < Sk
    if k_valid is not None:
        kv_valid = kv_valid & jnp.pad(k_valid.reshape(-1), (0, pk))
    nq, nk = (Sq + pq) // block_q, (Sk + pk) // block_k

    q_positions = q_offset + jnp.arange(Sq + pq)
    k_positions = jnp.arange(Sk + pk)

    qg = qg.reshape(B, nq, block_q, KV, G, hd)

    def q_tile(carry, qi):
        qt, qp = qi                                  # [B,block_q,KV,G,hd], [block_q]
        m0 = jnp.full((B, KV, G, block_q), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KV, G, block_q), jnp.float32)
        a0 = jnp.zeros((B, KV, G, block_q, hd), qt.dtype)

        # checkpoint the inner tile: backward recomputes per-tile scores
        # instead of stacking [nq, nk, ...] score tensors (scan-of-scan remat)
        @jax.checkpoint
        def kv_tile(carry2, ki):
            kt, vt, kp, kval = ki
            m, l, a = carry2
            m2, l2, a2 = _block_attn(
                qt, kt, vt, qp, kp, causal=causal, window=window,
                scale=scale, k_valid=kval,
            )
            return _combine(m, l, a, m2, l2, a2), None

        ks = k.reshape(B, nk, block_k, KV, hd).swapaxes(0, 1)
        vs = v.reshape(B, nk, block_k, KV, hd).swapaxes(0, 1)
        kps = k_positions.reshape(nk, block_k)
        kvs = kv_valid.reshape(nk, block_k)
        (m, l, a), _ = jax.lax.scan(kv_tile, (m0, l0, a0), (ks, vs, kps, kvs))
        out = a / jnp.maximum(l, 1e-30)[..., None].astype(a.dtype)
        return carry, out                             # [B,KV,G,block_q,hd]

    _, outs = jax.lax.scan(
        q_tile, None,
        (qg.swapaxes(0, 1), q_positions.reshape(nq, block_q)),
    )
    # outs: [nq, B, KV, G, block_q, hd] -> [B, Sq, H, hd]
    out = outs.transpose(1, 0, 4, 2, 3, 5).reshape(B, Sq + pq, H, hd)
    return out[:, :Sq]


def decode_attention(
    q: jax.Array,              # [B, 1, H, hd]
    k_cache: jax.Array,        # [B, S, KV, hd]
    v_cache: jax.Array,
    *,
    pos: jax.Array,            # i32[] current position (# valid cache entries - 1)
    window: Optional[int] = None,
) -> jax.Array:
    """Single-token decode: scores fit in memory; one fused softmax."""
    B, S, KV, hd = k_cache.shape
    H = q.shape[2]
    G = H // KV
    qg = q.reshape(B, KV, G, hd)
    s = jnp.einsum("bkgd,bskd->bkgs", qg, k_cache).astype(jnp.float32)
    s = s * (hd ** -0.5)
    kpos = jnp.arange(S)
    valid = kpos <= pos
    if window is not None:
        valid &= kpos > pos - window
    s = jnp.where(valid[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p.astype(v_cache.dtype), v_cache)
    return out.reshape(B, 1, H, hd)


def attend(
    cfg: ModelConfig,
    q: jax.Array, k: jax.Array, v: jax.Array,
    *, causal: bool, window=None, q_offset=0, k_valid=None,
) -> jax.Array:
    if cfg.use_pallas:
        from repro.kernels.flash_attention.ops import flash_attention
        return flash_attention(
            q, k, v, causal=causal, window=window, q_offset=q_offset,
            block_q=cfg.block_q, block_k=cfg.block_k, interpret=True,
        )
    return blockwise_attention(
        q, k, v, causal=causal, window=window, q_offset=q_offset,
        block_q=cfg.block_q, block_k=cfg.block_k, k_valid=k_valid,
    )
