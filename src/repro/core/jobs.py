"""Job-table data structures for the JAX discrete-event scheduler.

The paper encapsulates each job as a ``TaskEvent`` C++ object moved between
SST components.  On SPMD hardware we keep the whole job table as a
struct-of-arrays pytree (``JobSet``) plus a mutable simulation state
(``SimState``); "moving a job between queues" is a masked state transition.

All times are int32 *relative* seconds (trace loaders normalize so that
``min(submit) == 0`` and ``max(submit) + 2*max(runtime) < 2**30``, which
keeps every ``clock + estimate`` addition overflow-free).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

# Job lifecycle states (paper Fig. 1: submission -> waiting -> running -> done).
PENDING = 0   # submitted to the simulator but its submit time is in the future
WAITING = 1   # in the wait queue
RUNNING = 2   # allocated nodes, executing
DONE = 3      # completed; resources reclaimed

# Sentinel "infinite" time.  Kept well under int32 max so sentinel arithmetic
# (e.g. INF + estimate) cannot wrap.
INF_TIME = np.int32(2**30 - 1)

# Scheduling policies (paper §2.1) + priority preemption (paper §5 lists
# preemption as planned future work; implemented here in both engines).
FCFS = 0
SJF = 1
LJF = 2
BESTFIT = 3
BACKFILL = 4
PREEMPT = 5

POLICY_NAMES = {
    FCFS: "fcfs",
    SJF: "sjf",
    LJF: "ljf",
    BESTFIT: "bestfit",
    BACKFILL: "backfill",
    PREEMPT: "preempt",
}
POLICY_IDS = {v: k for k, v in POLICY_NAMES.items()}


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class JobSet:
    """Immutable struct-of-arrays job table, sorted by (submit, id).

    ``valid`` masks padding rows so fixed-capacity tables can be batched /
    sharded.  ``estimate`` is the user walltime request (drives SJF/LJF
    ordering and EASY reservations); ``runtime`` is the actual duration
    (drives completion events) — mirroring how CQsim treats walltime vs. run
    time.
    """

    submit: jax.Array    # i32[J]
    runtime: jax.Array   # i32[J]  actual duration, >= 1
    estimate: jax.Array  # i32[J]  requested walltime, >= 1
    nodes: jax.Array     # i32[J]  requested nodes, >= 1
    priority: jax.Array  # i32[J]  lower = more important (preempt policy)
    valid: jax.Array     # bool[J]

    @property
    def capacity(self) -> int:
        return self.submit.shape[-1]

    def num_valid(self) -> jax.Array:
        return jnp.sum(self.valid.astype(jnp.int32), axis=-1)


def make_jobset(
    submit,
    runtime,
    nodes,
    estimate=None,
    priority=None,
    *,
    capacity: int | None = None,
    total_nodes: int | None = None,
) -> JobSet:
    """Build a normalized ``JobSet`` from host arrays.

    - sorts by (submit, original index) so row order == FCFS order,
    - clamps node requests to ``total_nodes`` (paper traces contain requests
      larger than the simulated machine; CQsim clamps the same way),
    - pads to ``capacity`` with invalid rows.
    """
    submit = np.asarray(submit, dtype=np.int64)
    runtime = np.asarray(runtime, dtype=np.int64)
    nodes = np.asarray(nodes, dtype=np.int64)
    estimate = (
        np.asarray(estimate, dtype=np.int64) if estimate is not None else runtime.copy()
    )
    n = submit.shape[0]
    priority = (np.asarray(priority, dtype=np.int64) if priority is not None
                else np.zeros(n, dtype=np.int64))
    if not (runtime.shape[0] == nodes.shape[0] == estimate.shape[0] == n):
        raise ValueError("job attribute arrays must have equal length")

    submit = submit - (submit.min() if n else 0)
    runtime = np.maximum(runtime, 1)
    estimate = np.maximum(estimate, 1)
    nodes = np.maximum(nodes, 1)
    if total_nodes is not None:
        nodes = np.minimum(nodes, total_nodes)

    horizon = submit.max(initial=0) + 2 * max(int(runtime.max(initial=1)), int(estimate.max(initial=1)))
    if horizon >= int(INF_TIME):
        raise ValueError(
            f"trace horizon {horizon} overflows int32 sentinel; rescale the trace"
        )

    order = np.lexsort((np.arange(n), submit))
    submit, runtime, estimate, nodes, priority = (
        submit[order], runtime[order], estimate[order], nodes[order],
        priority[order],
    )

    cap = capacity or n
    if cap < n:
        raise ValueError(f"capacity {cap} < number of jobs {n}")

    def pad(a, fill):
        out = np.full((cap,), fill, dtype=np.int32)
        out[:n] = a.astype(np.int32)
        return out

    valid = np.zeros((cap,), dtype=bool)
    valid[:n] = True
    return JobSet(
        submit=jnp.asarray(pad(submit, INF_TIME)),
        runtime=jnp.asarray(pad(runtime, 1)),
        estimate=jnp.asarray(pad(estimate, 1)),
        nodes=jnp.asarray(pad(nodes, 1)),
        priority=jnp.asarray(pad(priority, 0)),
        valid=jnp.asarray(valid),
    )


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SimState:
    """Mutable (functionally) simulation state for one cluster.

    The allocation fields (DESIGN.md §11) are zero-size placeholders when the
    simulation runs in seed scalar-counter mode (no ``Machine``): the pytree
    structure is identical in both modes, only leaf shapes differ.
    ``node_owner`` is the per-node occupancy map (-1 = free, else owning job
    row); ``alloc_first``/``alloc_span``/``alloc_sum`` fingerprint each job's
    latest allocation (lowest node id, distinct topology groups spanned, sum
    of 1-based node ids) for cross-engine node-map validation; the ``ev_*``
    ring records (clock, free nodes, largest free contiguous run) per event
    for fragmentation metrics.
    """

    clock: jax.Array        # i32 scalar
    jstate: jax.Array       # i32[J] in {PENDING, WAITING, RUNNING, DONE}
    start: jax.Array        # i32[J] FIRST start time (INF until started)
    finish: jax.Array       # i32[J] actual completion time (INF until started)
    rsv_finish: jax.Array   # i32[J] start + estimate; EASY shadow math input
    remaining: jax.Array    # i32[J] runtime left (preemption suspends work)
    free: jax.Array         # i32 scalar, nodes currently free
    n_events: jax.Array     # i32 scalar, events processed
    node_owner: jax.Array   # i32[N] owning job row per node (-1 free); [0] w/o machine
    alloc_first: jax.Array  # i32[J] lowest node id of latest allocation (-1 = never)
    alloc_span: jax.Array   # i32[J] group span of latest allocation (locality score)
    alloc_sum: jax.Array    # i32[J] sum of 1-based node ids of latest allocation
    ev_time: jax.Array      # i32[L] event clock log (-1 = unused slot); [0] w/o machine
    ev_free: jax.Array      # i32[L] free nodes after each event
    ev_lfb: jax.Array       # i32[L] largest free contiguous block after each event

    @classmethod
    def init(cls, jobs: JobSet, total_nodes: int, machine=None,
             event_log: int = 0) -> "SimState":
        J = jobs.capacity
        N = machine.n_nodes if machine is not None else 0
        L = int(event_log) if machine is not None else 0
        inf = jnp.full((J,), INF_TIME, dtype=jnp.int32)
        jstate = jnp.where(jobs.valid, jnp.int32(PENDING), jnp.int32(DONE))
        return cls(
            clock=jnp.int32(0),
            jstate=jstate,
            start=inf,
            finish=inf,
            rsv_finish=inf,
            remaining=jobs.runtime,
            free=jnp.int32(total_nodes),
            n_events=jnp.int32(0),
            node_owner=jnp.full((N,), -1, dtype=jnp.int32),
            alloc_first=jnp.full((J,), -1, dtype=jnp.int32),
            alloc_span=jnp.zeros((J,), dtype=jnp.int32),
            alloc_sum=jnp.zeros((J,), dtype=jnp.int32),
            ev_time=jnp.full((L,), -1, dtype=jnp.int32),
            ev_free=jnp.zeros((L,), dtype=jnp.int32),
            ev_lfb=jnp.zeros((L,), dtype=jnp.int32),
        )


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SimResult:
    """Per-job outcome; every paper metric derives from these arrays.

    The ``alloc_*`` / ``ev_*`` fields are zero-size or inert (-1 / 0) unless
    the simulation ran with a ``Machine`` (DESIGN.md §11).
    """

    start: jax.Array        # i32[J]
    finish: jax.Array       # i32[J]
    wait: jax.Array         # i32[J] start - submit
    makespan: jax.Array     # i32 scalar
    n_events: jax.Array     # i32 scalar
    done: jax.Array         # bool[J] job reached DONE (False => engine hit event cap)
    alloc_first: jax.Array  # i32[J] lowest node id of final allocation (-1 = none)
    alloc_span: jax.Array   # i32[J] topology groups spanned by final allocation
    alloc_sum: jax.Array    # i32[J] sum of 1-based node ids (node-map witness)
    ev_time: jax.Array      # i32[L] per-event clock (-1 = unused slot)
    ev_free: jax.Array      # i32[L] per-event free-node count
    ev_lfb: jax.Array       # i32[L] per-event largest free contiguous block


def result_from_state(jobs: JobSet, state: SimState) -> SimResult:
    wait = jnp.where(jobs.valid, state.start - jobs.submit, 0).astype(jnp.int32)
    fin = jnp.where(jobs.valid & (state.jstate == DONE), state.finish, 0)
    return SimResult(
        start=state.start,
        finish=state.finish,
        wait=wait,
        makespan=jnp.max(fin).astype(jnp.int32),
        n_events=state.n_events,
        done=(state.jstate == DONE) & jobs.valid,
        alloc_first=state.alloc_first,
        alloc_span=state.alloc_span,
        alloc_sum=state.alloc_sum,
        ev_time=state.ev_time,
        ev_free=state.ev_free,
        ev_lfb=state.ev_lfb,
    )
