"""Job-table data structures for the JAX discrete-event scheduler.

The paper encapsulates each job as a ``TaskEvent`` C++ object moved between
SST components.  On SPMD hardware we keep the whole job table as a
struct-of-arrays pytree (``JobSet``) plus a mutable simulation state
(``SimState``); "moving a job between queues" is a masked state transition.

All times are int32 *relative* seconds (trace loaders normalize so that
``min(submit) == 0`` and ``max(submit) + 2*max(runtime) < 2**30``, which
keeps every ``clock + estimate`` addition overflow-free).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

# Job lifecycle states (paper Fig. 1: submission -> waiting -> running -> done).
PENDING = 0   # submitted to the simulator but its submit time is in the future
WAITING = 1   # in the wait queue
RUNNING = 2   # allocated nodes, executing
DONE = 3      # completed; resources reclaimed

# Sentinel "infinite" time.  Kept well under int32 max so sentinel arithmetic
# (e.g. INF + estimate) cannot wrap.
INF_TIME = np.int32(2**30 - 1)

# Scheduling policies (paper §2.1) + priority preemption (paper §5 lists
# preemption as planned future work; implemented here in both engines).
FCFS = 0
SJF = 1
LJF = 2
BESTFIT = 3
BACKFILL = 4
PREEMPT = 5

POLICY_NAMES = {
    FCFS: "fcfs",
    SJF: "sjf",
    LJF: "ljf",
    BESTFIT: "bestfit",
    BACKFILL: "backfill",
    PREEMPT: "preempt",
}
POLICY_IDS = {v: k for k, v in POLICY_NAMES.items()}


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class JobSet:
    """Immutable struct-of-arrays job table, sorted by (submit, id).

    ``valid`` masks padding rows so fixed-capacity tables can be batched /
    sharded.  ``estimate`` is the user walltime request (drives SJF/LJF
    ordering and EASY reservations); ``runtime`` is the actual duration
    (drives completion events) — mirroring how CQsim treats walltime vs. run
    time.

    ``dep_dst``/``dep_src`` make task dependencies a first-class axis of the
    cluster engine (paper §3, DESIGN.md §13/§14): edge *e* means job
    ``dep_dst[e]`` cannot enter the wait queue until job ``dep_src[e]`` is
    DONE.  The edge list is a *static-shape* padded representation — real
    edges first, padding slots hold the out-of-range index ``capacity`` so
    every scatter (``.at[...]`` with ``mode="drop"``) ignores them — which
    keeps dependency memory at O(E) instead of the dense matrix's O(J²) and
    lets the engine maintain an incremental unmet-dependency counter
    (``SimState.n_unmet``) instead of re-reducing a matrix per event.  Both
    are ``None`` (statically elided — the engine compiles to the exact seed
    path) for plain job traces; being pytree leaves they batch through
    ``vmap`` ensembles and ``sweep()`` like any other job attribute
    (``stack_jobsets`` pads ragged edge counts to one shape).
    """

    submit: jax.Array    # i32[J]
    runtime: jax.Array   # i32[J]  actual duration, >= 1
    estimate: jax.Array  # i32[J]  requested walltime, >= 1
    nodes: jax.Array     # i32[J]  requested nodes, >= 1
    priority: jax.Array  # i32[J]  lower = more important (preempt policy)
    valid: jax.Array     # bool[J]
    dep_dst: jax.Array | None = None  # i32[E] dependent row  (capacity = pad)
    dep_src: jax.Array | None = None  # i32[E] dependency row (capacity = pad)

    @property
    def capacity(self) -> int:
        return self.submit.shape[-1]

    @property
    def edge_capacity(self) -> int:
        """Padded edge-list length (0 when the table carries no edges)."""
        return 0 if self.dep_dst is None else self.dep_dst.shape[-1]

    @property
    def deps(self) -> jax.Array | None:
        """Dense ``bool[J, J]`` reconstruction of the edge list (or ``None``).

        Host-side convenience for tests/metrics on a single (unbatched) job
        table; the engine itself never materializes this matrix.
        """
        if self.dep_dst is None:
            return None
        if self.dep_dst.ndim != 1:
            raise ValueError(
                "JobSet.deps reconstructs the dense matrix for unbatched "
                "tables only; index into the batch dimension first")
        J = self.capacity
        return jnp.zeros((J, J), dtype=bool).at[
            self.dep_dst, self.dep_src].set(True, mode="drop")

    def num_valid(self) -> jax.Array:
        return jnp.sum(self.valid.astype(jnp.int32), axis=-1)


def assert_acyclic(deps: np.ndarray) -> None:
    """Kahn's algorithm over a dense bool dependency matrix; raises on
    cycles.  ``deps[i, j]`` = *i* depends on *j*.  Shared by
    ``make_jobset`` and ``repro.core.workflow.make_taskset``."""
    n = deps.shape[0]
    indeg = deps.sum(axis=1).astype(np.int64)
    stack = list(np.nonzero(indeg == 0)[0])
    seen = 0
    dependents = [np.nonzero(deps[:, j])[0] for j in range(n)]
    while stack:
        j = stack.pop()
        seen += 1
        for i in dependents[j]:
            indeg[i] -= 1
            if indeg[i] == 0:
                stack.append(i)
    if seen != n:
        raise ValueError("dependency graph contains a cycle")


def _dense_deps(deps, n: int) -> np.ndarray:
    """Normalize ``deps`` (pair list or dense matrix, pre-sort indices) to a
    validated dense bool[n, n]; cycle-checked like ``make_taskset``.

    A bool 2-D array is always a dense matrix (a wrong shape is an error,
    never re-parsed as pairs); other 2-D arrays are a matrix only at the
    exact (n, n) shape, else a (job, dep) pair list.  Shared by
    ``make_jobset`` and ``repro.refsim.ReferenceSimulator.load`` so both
    engines accept bit-identical inputs.
    """
    mat = np.asarray(deps) if not isinstance(deps, (list, tuple)) else None
    is_dense = (mat is not None and mat.ndim == 2 and mat.dtype != object
                and (mat.dtype == bool or mat.shape == (n, n)))
    if is_dense:
        if mat.shape != (n, n):
            raise ValueError(
                f"dense deps matrix has shape {mat.shape}, expected ({n}, {n})")
        dense = mat.astype(bool)
        if dense.diagonal().any():
            raise ValueError("self-dependency")
    else:
        dense = np.zeros((n, n), dtype=bool)
        for pair in deps:
            t, d = int(pair[0]), int(pair[1])
            if not (0 <= t < n and 0 <= d < n):
                raise ValueError(f"dependency pair ({t},{d}) out of range")
            if t == d:
                raise ValueError("self-dependency")
            dense[t, d] = True
    assert_acyclic(dense)
    return dense


# Edge-list pads round up to this multiple so DAGs with nearby edge counts
# share one compiled shape (the differential-test matrix reuses executables).
_EDGE_ALIGN = 64


def dep_edge_arrays(deps, n: int, order: np.ndarray) -> tuple:
    """Normalize ``deps`` to (dst, src) index arrays in *sorted-row*
    coordinates, in (dst, src) lexicographic order.

    One shared path (validation + cycle check + sort permutation) for
    ``make_jobset`` and ``repro.refsim.ReferenceSimulator.load``, so both
    engines derive bit-identical edge sets from the same input.
    """
    dense = _dense_deps(deps, n)[order][:, order]
    return np.nonzero(dense)


def make_jobset(
    submit,
    runtime,
    nodes,
    estimate=None,
    priority=None,
    *,
    deps=None,
    capacity: int | None = None,
    edge_capacity: int | None = None,
    total_nodes: int | None = None,
) -> JobSet:
    """Build a normalized ``JobSet`` from host arrays.

    - sorts by (submit, original index) so row order == FCFS order,
    - clamps node requests to ``total_nodes`` (paper traces contain requests
      larger than the simulated machine; CQsim clamps the same way),
    - pads to ``capacity`` with invalid rows.

    ``deps`` is either an iterable of ``(job, dependency)`` index pairs or a
    dense bool matrix, both in *input* order (indices into ``submit``); it is
    cycle-checked, permuted into the sorted row order, and lowered to the
    padded ``dep_dst``/``dep_src`` edge list (length rounded up to a multiple
    of 64, or exactly ``edge_capacity`` when given; padding slots hold the
    out-of-range index ``capacity``).  An empty or all-False ``deps`` is
    elided to ``None`` so the no-dependency case compiles to the exact seed
    path.
    """
    submit = np.asarray(submit, dtype=np.int64)
    runtime = np.asarray(runtime, dtype=np.int64)
    nodes = np.asarray(nodes, dtype=np.int64)
    estimate = (
        np.asarray(estimate, dtype=np.int64) if estimate is not None else runtime.copy()
    )
    n = submit.shape[0]
    priority = (np.asarray(priority, dtype=np.int64) if priority is not None
                else np.zeros(n, dtype=np.int64))
    if not (runtime.shape[0] == nodes.shape[0] == estimate.shape[0] == n):
        raise ValueError("job attribute arrays must have equal length")

    submit = submit - (submit.min() if n else 0)
    runtime = np.maximum(runtime, 1)
    estimate = np.maximum(estimate, 1)
    nodes = np.maximum(nodes, 1)
    if total_nodes is not None:
        nodes = np.minimum(nodes, total_nodes)

    horizon = submit.max(initial=0) + 2 * max(int(runtime.max(initial=1)), int(estimate.max(initial=1)))
    if horizon >= int(INF_TIME):
        raise ValueError(
            f"trace horizon {horizon} overflows int32 sentinel; rescale the trace"
        )

    order = np.lexsort((np.arange(n), submit))
    submit, runtime, estimate, nodes, priority = (
        submit[order], runtime[order], estimate[order], nodes[order],
        priority[order],
    )

    cap = capacity or n
    if cap < n:
        raise ValueError(f"capacity {cap} < number of jobs {n}")

    dep_dst = dep_src = None
    if deps is not None:
        dst, src = dep_edge_arrays(deps, n, order)
        n_edges = int(dst.size)
        if n_edges:
            if edge_capacity is None:
                ecap = -(-n_edges // _EDGE_ALIGN) * _EDGE_ALIGN
            else:
                ecap = int(edge_capacity)
                if ecap < n_edges:
                    raise ValueError(
                        f"edge_capacity {ecap} < number of edges {n_edges}")
            dep_dst = np.full((ecap,), cap, dtype=np.int32)
            dep_src = np.full((ecap,), cap, dtype=np.int32)
            dep_dst[:n_edges] = dst
            dep_src[:n_edges] = src

    def pad(a, fill):
        out = np.full((cap,), fill, dtype=np.int32)
        out[:n] = a.astype(np.int32)
        return out

    valid = np.zeros((cap,), dtype=bool)
    valid[:n] = True
    return JobSet(
        submit=jnp.asarray(pad(submit, INF_TIME)),
        runtime=jnp.asarray(pad(runtime, 1)),
        estimate=jnp.asarray(pad(estimate, 1)),
        nodes=jnp.asarray(pad(nodes, 1)),
        priority=jnp.asarray(pad(priority, 0)),
        valid=jnp.asarray(valid),
        dep_dst=None if dep_dst is None else jnp.asarray(dep_dst),
        dep_src=None if dep_src is None else jnp.asarray(dep_src),
    )


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class RelState:
    """Reliability bookkeeping (DESIGN.md §15), present only when the
    simulation carries a failure model.

    Like ``JobSet.dep_dst``, the whole subtree is ``None`` on
    ``SimState`` for failure-free runs — not zero-size placeholders —
    so the no-failure engine lowers to the *exact* pre-reliability HLO
    module (fingerprint-tested).  ``down`` is the per-node outage mask
    in machine mode ([0] in scalar-counter mode, where outages are pure
    capacity accounting on the ``free`` counter); ``last_start`` is the
    clock of each job's latest dispatch, the base of the checkpoint
    rework math.
    """

    ptr: jax.Array         # i32 scalar: next unconsumed failure-stream entry
    last_start: jax.Array  # i32[J] clock of the latest dispatch (0 = never)
    n_restarts: jax.Array  # i32[J] requeue kills survived so far
    lost_work: jax.Array   # i32[J] rework + overhead (+ aborted work) charged
    aborted: jax.Array     # bool[J] terminated by a failure under "abort"
    down: jax.Array        # bool[N] node outage mask; [0] w/o machine


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class FailureInfo:
    """Per-job reliability outcome columns (``SimResult.rel``)."""

    n_restarts: jax.Array  # i32[J]
    lost_work: jax.Array   # i32[J]
    aborted: jax.Array     # bool[J]


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SvcState:
    """Serving/autoscaler bookkeeping (DESIGN.md §16), present only when
    the simulation carries a service plan.

    Like ``SimState.rel``, the whole subtree is ``None`` for serving-free
    runs — not zero-size placeholders — so the serving-free engine lowers
    to the *exact* pre-serving HLO module (fingerprint-tested).
    ``offline`` is the autoscaler's per-node out-of-service mask in
    machine mode ([0] in scalar-counter mode, where capacity is pure
    accounting on the ``free`` counter); ``cap_online`` logs the online
    node count after each consumed tick (-1 = tick never consumed), the
    capacity series goodput-under-autoscaling integrates.
    """

    ptr: jax.Array         # i32 scalar: next unconsumed autoscale tick
    n_online: jax.Array    # i32 scalar: nodes currently in service
    offline: jax.Array     # bool[N] scaled-out mask; [0] w/o machine
    cap_online: jax.Array  # i32[T] online count after each consumed tick


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SvcInfo:
    """Per-request serving outcome columns (``SimResult.svc``)."""

    slo_met: jax.Array     # bool[J] started within the class SLO deadline
    deadline: jax.Array    # i32[J] submit + slo_wait (INF_TIME = padding)
    cap_online: jax.Array  # i32[T] online nodes after each consumed tick


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class MalState:
    """Malleability bookkeeping (DESIGN.md §17), present only when the
    simulation carries a malleable plan.

    Like ``SimState.rel``/``SimState.svc``, the whole subtree is ``None``
    for rigid runs — not zero-size placeholders — so the rigid engine
    lowers to the *exact* pre-malleable HLO module (fingerprint-tested).
    ``width`` is each job's *current* effective width (``min_width``
    until first dispatch); ``prev_w`` the width at the latest dispatch
    (0 = never dispatched, the fresh-job sentinel of the re-dilation
    math); ``seg_start``/``node_s`` the open node-second segment and the
    accumulated node-second integral (``width * wall-time``, closed at
    every resize/kill/completion); ``disp_dur`` the dilated duration
    chosen at the latest dispatch (-1 = never)."""

    ptr: jax.Array        # i32 scalar: next unconsumed elastic tick
    width: jax.Array      # i32[J] current effective width
    prev_w: jax.Array     # i32[J] width at latest dispatch (0 = never)
    seg_start: jax.Array  # i32[J] clock opening the current node_s segment
    node_s: jax.Array     # i32[J] accumulated node-seconds
    n_resizes: jax.Array  # i32[J] grow/shrink actions applied so far
    disp_dur: jax.Array   # i32[J] dilated duration at latest dispatch (-1)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class MalInfo:
    """Per-job malleability outcome columns (``SimResult.mal``)."""

    width: jax.Array      # i32[J] final width
    nref: jax.Array       # i32[J] reference (requested) width
    n_resizes: jax.Array  # i32[J] grow/shrink actions applied
    node_s: jax.Array     # i32[J] node-seconds actually consumed
    disp_dur: jax.Array   # i32[J] dilated duration at latest dispatch


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SimState:
    """Mutable (functionally) simulation state for one cluster.

    The allocation fields (DESIGN.md §11) are zero-size placeholders when the
    simulation runs in seed scalar-counter mode (no ``Machine``): the pytree
    structure is identical in both modes, only leaf shapes differ.
    ``node_owner`` is the per-node occupancy map (-1 = free, else owning job
    row); ``alloc_first``/``alloc_span``/``alloc_sum`` fingerprint each job's
    latest allocation (lowest node id, distinct topology groups spanned, sum
    of 1-based node ids) for cross-engine node-map validation; the ``ev_*``
    ring records (clock, free nodes, largest free contiguous run) per event
    for fragmentation metrics.

    ``n_unmet`` is the incremental unmet-dependency counter (DESIGN.md §14):
    ``n_unmet[i]`` counts dependencies of job *i* not yet DONE, decremented
    by an O(E) scatter-add at each completion event, so the release test is
    an O(J) compare instead of an O(J²) matrix reduction.  Zero-size
    placeholder when the job table carries no edges (same elision pattern as
    the allocation fields).
    """

    clock: jax.Array        # i32 scalar
    jstate: jax.Array       # i32[J] in {PENDING, WAITING, RUNNING, DONE}
    n_unmet: jax.Array      # i32[J] unmet-dependency count; [0] w/o deps
    start: jax.Array        # i32[J] FIRST start time (INF until started)
    finish: jax.Array       # i32[J] actual completion time (INF until started)
    rsv_finish: jax.Array   # i32[J] start + estimate; EASY shadow math input
    remaining: jax.Array    # i32[J] runtime left (preemption suspends work)
    free: jax.Array         # i32 scalar, nodes currently free
    n_events: jax.Array     # i32 scalar, events processed
    node_owner: jax.Array   # i32[N] owning job row per node (-1 free); [0] w/o machine
    alloc_first: jax.Array  # i32[J] lowest node id of latest allocation (-1 = never)
    alloc_span: jax.Array   # i32[J] group span of latest allocation (locality score)
    alloc_sum: jax.Array    # i32[J] sum of 1-based node ids of latest allocation
    ev_time: jax.Array      # i32[L] event clock log (-1 = unused slot); [0] w/o machine
    ev_free: jax.Array      # i32[L] free nodes after each event
    ev_lfb: jax.Array       # i32[L] largest free contiguous block after each event
    rel: RelState | None = None  # reliability state; None = statically elided
    svc: SvcState | None = None  # serving state; None = statically elided
    mal: MalState | None = None  # malleability state; None = statically elided

    @classmethod
    def init(cls, jobs: JobSet, total_nodes: int, machine=None,
             event_log: int = 0, failures: bool = False,
             service: int | None = None,
             malleable: tuple | None = None) -> "SimState":
        J = jobs.capacity
        N = machine.n_nodes if machine is not None else 0
        L = int(event_log) if machine is not None else 0
        inf = jnp.full((J,), INF_TIME, dtype=jnp.int32)
        jstate = jnp.where(jobs.valid, jnp.int32(PENDING), jnp.int32(DONE))
        if jobs.dep_dst is None:
            n_unmet = jnp.zeros((0,), dtype=jnp.int32)
        else:
            n_unmet = jnp.zeros((J,), dtype=jnp.int32).at[jobs.dep_dst].add(
                1, mode="drop")
        return cls(
            clock=jnp.int32(0),
            jstate=jstate,
            n_unmet=n_unmet,
            start=inf,
            finish=inf,
            rsv_finish=inf,
            remaining=jobs.runtime,
            free=jnp.int32(total_nodes),
            n_events=jnp.int32(0),
            node_owner=jnp.full((N,), -1, dtype=jnp.int32),
            alloc_first=jnp.full((J,), -1, dtype=jnp.int32),
            alloc_span=jnp.zeros((J,), dtype=jnp.int32),
            alloc_sum=jnp.zeros((J,), dtype=jnp.int32),
            ev_time=jnp.full((L,), -1, dtype=jnp.int32),
            ev_free=jnp.zeros((L,), dtype=jnp.int32),
            ev_lfb=jnp.zeros((L,), dtype=jnp.int32),
            rel=None if not failures else RelState(
                ptr=jnp.int32(0),
                last_start=jnp.zeros((J,), dtype=jnp.int32),
                n_restarts=jnp.zeros((J,), dtype=jnp.int32),
                lost_work=jnp.zeros((J,), dtype=jnp.int32),
                aborted=jnp.zeros((J,), dtype=bool),
                down=jnp.zeros((N,), dtype=bool),
            ),
            # ``service`` is the padded autoscale tick capacity T (an int);
            # every node starts online, so n_online == total_nodes
            svc=None if service is None else SvcState(
                ptr=jnp.int32(0),
                n_online=jnp.int32(total_nodes),
                offline=jnp.zeros((N,), dtype=bool),
                cap_online=jnp.full((int(service),), -1, dtype=jnp.int32),
            ),
            # ``malleable`` is ``(min_width, tick_capacity)``; min_width may
            # be a tracer (vmap data), the tick capacity is static
            mal=None if malleable is None else MalState(
                ptr=jnp.int32(0),
                width=jnp.full((J,), 1, dtype=jnp.int32)
                * jnp.asarray(malleable[0], dtype=jnp.int32),
                prev_w=jnp.zeros((J,), dtype=jnp.int32),
                seg_start=jnp.zeros((J,), dtype=jnp.int32),
                node_s=jnp.zeros((J,), dtype=jnp.int32),
                n_resizes=jnp.zeros((J,), dtype=jnp.int32),
                disp_dur=jnp.full((J,), -1, dtype=jnp.int32),
            ),
        )


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SimResult:
    """Per-job outcome; every paper metric derives from these arrays.

    The ``alloc_*`` / ``ev_*`` fields are zero-size or inert (-1 / 0) unless
    the simulation ran with a ``Machine`` (DESIGN.md §11).
    """

    start: jax.Array        # i32[J]
    finish: jax.Array       # i32[J]
    ready: jax.Array        # i32[J] max(submit, last dep finish); == submit w/o deps
    wait: jax.Array         # i32[J] start - ready (paper Fig. 7 metric)
    makespan: jax.Array     # i32 scalar
    n_events: jax.Array     # i32 scalar
    done: jax.Array         # bool[J] job reached DONE (False => engine hit event cap)
    alloc_first: jax.Array  # i32[J] lowest node id of final allocation (-1 = none)
    alloc_span: jax.Array   # i32[J] topology groups spanned by final allocation
    alloc_sum: jax.Array    # i32[J] sum of 1-based node ids (node-map witness)
    ev_time: jax.Array      # i32[L] per-event clock (-1 = unused slot)
    ev_free: jax.Array      # i32[L] per-event free-node count
    ev_lfb: jax.Array       # i32[L] per-event largest free contiguous block
    rel: FailureInfo | None = None  # reliability columns; None w/o failures
    svc: SvcInfo | None = None      # serving columns; None w/o service
    mal: MalInfo | None = None      # malleability columns; None w/o malleable


def result_from_state(jobs: JobSet, state: SimState,
                      deadline: jax.Array | None = None,
                      nref: jax.Array | None = None) -> SimResult:
    if jobs.dep_dst is None:
        ready = jobs.submit
    else:
        # a job becomes *ready* when its last dependency finishes (submit for
        # roots); dep finishes are final whenever the job released, so the
        # post-hoc segment-max over the edge list is exact for every DONE
        # job (O(E), padding edges scatter out of range and drop).
        J = jobs.capacity
        src_fin = state.finish[jnp.clip(jobs.dep_src, 0, J - 1)]
        dep_fin = jnp.zeros((J,), dtype=jnp.int32).at[jobs.dep_dst].max(
            src_fin, mode="drop")
        ready = jnp.maximum(jobs.submit, dep_fin)
    wait = jnp.where(jobs.valid, state.start - ready, 0).astype(jnp.int32)
    if state.rel is None:
        # pinned expression (and trace) order: the failure-free path must
        # lower to the exact pre-reliability HLO module (fingerprint-tested);
        # serving columns are appended AFTER construction (below) so this
        # expression order never changes with the svc subtree elided
        fin = jnp.where(jobs.valid & (state.jstate == DONE), state.finish, 0)
        res = SimResult(
            start=state.start,
            finish=state.finish,
            ready=ready,
            wait=wait,
            makespan=jnp.max(fin).astype(jnp.int32),
            n_events=state.n_events,
            done=(state.jstate == DONE) & jobs.valid,
            alloc_first=state.alloc_first,
            alloc_span=state.alloc_span,
            alloc_sum=state.alloc_sum,
            ev_time=state.ev_time,
            ev_free=state.ev_free,
            ev_lfb=state.ev_lfb,
        )
        return _with_mal(_with_svc(res, state, deadline), state, nref)
    # an aborted job reached DONE only to terminate the event loop; it is
    # not a completion — excluded from `done` and the makespan
    done = jobs.valid & (state.jstate == DONE) & ~state.rel.aborted
    fin = jnp.where(done, state.finish, 0)
    res = SimResult(
        start=state.start,
        finish=state.finish,
        ready=ready,
        wait=wait,
        makespan=jnp.max(fin).astype(jnp.int32),
        n_events=state.n_events,
        done=done,
        alloc_first=state.alloc_first,
        alloc_span=state.alloc_span,
        alloc_sum=state.alloc_sum,
        ev_time=state.ev_time,
        ev_free=state.ev_free,
        ev_lfb=state.ev_lfb,
        rel=FailureInfo(n_restarts=state.rel.n_restarts,
                        lost_work=state.rel.lost_work,
                        aborted=state.rel.aborted),
    )
    return _with_mal(_with_svc(res, state, deadline), state, nref)


def _with_svc(res: SimResult, state: SimState,
              deadline: jax.Array | None) -> SimResult:
    """Append serving outcome columns when the run carried a service plan.

    The SLO verdict is fixed at start time: a request meets its SLO iff it
    dispatched no later than ``submit + slo_wait`` (and actually
    completed).  A no-op (the same ``res`` object) when ``state.svc`` is
    ``None``, so the pinned serving-free expression order is untouched.
    """
    if state.svc is None:
        return res
    return dataclasses.replace(
        res,
        svc=SvcInfo(
            slo_met=res.done & (state.start <= deadline),
            deadline=deadline,
            cap_online=state.svc.cap_online,
        ),
    )


def _with_mal(res: SimResult, state: SimState,
              nref: jax.Array | None) -> SimResult:
    """Append malleability outcome columns when the run carried a plan.

    A no-op (the same ``res`` object) when ``state.mal`` is ``None``, so
    the pinned rigid expression order is untouched."""
    if state.mal is None:
        return res
    return dataclasses.replace(
        res,
        mal=MalInfo(
            width=state.mal.width,
            nref=nref,
            n_resizes=state.mal.n_resizes,
            node_s=state.mal.node_s,
            disp_dur=state.mal.disp_dur,
        ),
    )
