"""Offline metric derivation (paper Figs. 3-4, 7).

The engine returns only per-job (start, finish); node occupancy, active-job
counts, queue lengths, utilization, waits, and slowdowns are all pure
functions of (submit, start, finish, nodes) — computed here in numpy so the
device loop stays lean (DESIGN.md §2).

Allocation results (simulations run with a ``repro.alloc.Machine``)
additionally carry per-job group spans and a per-event
(clock, free, largest-free-block) log, from which the locality and
fragmentation series derive (DESIGN.md §11.5).
"""

from __future__ import annotations

from typing import Dict

import numpy as np


def percentiles(values, qs, mask=None):
    """Exact linear-interpolation percentiles over (optionally masked)
    job columns — the one implementation ``summary`` and ``slo_summary``
    share, numerically identical to ``numpy.percentile`` (the same
    ``(q/100)·(n-1)`` position with the lerp evaluated from the nearer
    endpoint).  ``qs`` may be a scalar (returns ``float``) or a sequence
    (returns ``float64[len(qs)]``); an empty selection returns NaN."""
    scalar = np.ndim(qs) == 0
    values = np.asarray(values, dtype=np.float64).ravel()
    if mask is not None:
        values = values[np.asarray(mask, dtype=bool).ravel()]
    qs_arr = np.atleast_1d(np.asarray(qs, dtype=np.float64))
    if np.any((qs_arr < 0) | (qs_arr > 100)):
        raise ValueError(f"percentiles must lie in [0, 100]; got {qs!r}")
    if values.size == 0:
        out = np.full(qs_arr.shape, np.nan)
    else:
        s = np.sort(values)
        pos = qs_arr / 100.0 * (s.size - 1)
        lo = np.floor(pos).astype(np.int64)
        hi = np.minimum(lo + 1, s.size - 1)
        t = pos - lo
        d = s[hi] - s[lo]
        out = np.where(t >= 0.5, s[hi] - d * (1.0 - t), s[lo] + d * t)
    return float(out[0]) if scalar else out


def _select_valid(res: Dict[str, np.ndarray]):
    v = np.asarray(res["valid"], dtype=bool) & np.asarray(res["done"], dtype=bool)
    return (
        np.asarray(res["submit"])[v],
        np.asarray(res["start"])[v],
        np.asarray(res["finish"])[v],
        np.asarray(res["nodes"])[v],
        np.asarray(res["runtime"])[v],
    )


def step_series(times: np.ndarray, deltas: np.ndarray):
    """Event-sorted cumulative step function: returns (t, value_after_t)."""
    order = np.argsort(times, kind="stable")
    t = times[order]
    v = np.cumsum(deltas[order])
    # collapse duplicate timestamps to the final value at that time
    keep = np.r_[t[1:] != t[:-1], True]
    return t[keep], v[keep]


def occupancy_series(res) -> tuple[np.ndarray, np.ndarray]:
    """Nodes in use over time (paper Fig. 3a)."""
    _, start, finish, nodes, _ = _select_valid(res)
    times = np.r_[start, finish]
    deltas = np.r_[nodes, -nodes].astype(np.int64)
    return step_series(times, deltas)


def active_jobs_series(res) -> tuple[np.ndarray, np.ndarray]:
    """Number of running jobs over time (paper Fig. 3b)."""
    _, start, finish, _, _ = _select_valid(res)
    times = np.r_[start, finish]
    deltas = np.r_[np.ones_like(start), -np.ones_like(finish)].astype(np.int64)
    return step_series(times, deltas)


def queue_length_series(res) -> tuple[np.ndarray, np.ndarray]:
    """Waiting-queue length over time."""
    submit, start, _, _, _ = _select_valid(res)
    times = np.r_[submit, start]
    deltas = np.r_[np.ones_like(submit), -np.ones_like(start)].astype(np.int64)
    return step_series(times, deltas)


def sample_series(t: np.ndarray, v: np.ndarray, grid: np.ndarray) -> np.ndarray:
    """Sample a step series onto a regular grid (for plotting/comparison)."""
    idx = np.searchsorted(t, grid, side="right") - 1
    out = np.where(idx >= 0, v[np.clip(idx, 0, len(v) - 1)], 0)
    return out.astype(np.float64)


def fragmentation_series(res) -> tuple[np.ndarray, np.ndarray]:
    """Fragmentation over time from the engine's per-event log
    (DESIGN.md §11.5): ``1 - largest_free_block / free_nodes`` — 0 when all
    free capacity is one contiguous block, approaching 1 when free nodes are
    scattered.  Requires a result produced with a ``Machine``."""
    t, lfb, freen = _event_log(res)
    with np.errstate(divide="ignore", invalid="ignore"):
        frag = np.where(freen > 0, 1.0 - lfb / np.maximum(freen, 1), 0.0)
    return t, frag


def largest_free_block_series(res) -> tuple[np.ndarray, np.ndarray]:
    """Largest free contiguous block over time (allocation results only)."""
    t, lfb, _ = _event_log(res)
    return t, lfb.astype(np.float64)


def _event_log(res):
    if "ev_time" not in res:
        raise ValueError(
            "result has no event log; run simulate with a Machine "
            "(see repro.alloc)")
    t = np.asarray(res["ev_time"], dtype=np.int64)
    lfb = np.asarray(res["ev_lfb"], dtype=np.int64)
    freen = np.asarray(res["ev_free"], dtype=np.int64)
    used = t >= 0
    t, lfb, freen = t[used], lfb[used], freen[used]
    # collapse duplicate timestamps to the final row at that time
    keep = np.r_[t[1:] != t[:-1], True] if len(t) else np.zeros(0, bool)
    return t[keep], lfb[keep], freen[keep]


def job_span_series(res) -> tuple[np.ndarray, np.ndarray]:
    """Mean topology-group span of *running* jobs over time (locality;
    allocation results only).  NaN while nothing runs."""
    v = np.asarray(res["valid"], bool) & np.asarray(res["done"], bool)
    start = np.asarray(res["start"])[v]
    finish = np.asarray(res["finish"])[v]
    span = np.asarray(res["alloc_span"])[v].astype(np.int64)
    if len(start) == 0:
        return np.zeros(0, np.int64), np.zeros(0, np.float64)
    times = np.r_[start, finish]
    t, tot = step_series(times, np.r_[span, -span])
    _, cnt = step_series(times, np.r_[np.ones_like(start),
                                      -np.ones_like(finish)].astype(np.int64))
    with np.errstate(divide="ignore", invalid="ignore"):
        mean = np.where(cnt > 0, tot / np.maximum(cnt, 1), np.nan)
    return t, mean


def alloc_summary(res) -> Dict[str, float]:
    """Scalar locality/fragmentation metrics (allocation results only)."""
    v = np.asarray(res["valid"], bool) & np.asarray(res["done"], bool)
    span = np.asarray(res["alloc_span"])[v].astype(np.float64)
    t, frag = fragmentation_series(res)
    _, lfb, freen = _event_log(res)
    busy = freen < freen.max(initial=0) if len(freen) else np.zeros(0, bool)
    return {
        "mean_job_span": float(span.mean()) if len(span) else 0.0,
        "max_job_span": float(span.max()) if len(span) else 0.0,
        "mean_frag": float(frag[busy].mean()) if busy.any() else 0.0,
        "min_largest_free_block": float(lfb.min()) if len(lfb) else 0.0,
    }


def reliability_summary(res) -> Dict[str, float]:
    """Scalar reliability metrics (results carrying failure columns,
    DESIGN.md §15).

    ``goodput`` is the fraction of consumed node-seconds that produced
    completed work: useful / (useful + lost), where *useful* counts
    completed (non-aborted) jobs' runtimes and *lost* counts every
    node-second of checkpoint rework, restart overhead, and aborted
    partial work the failure model charged.

    Unit caveat: with a contention model active, *lost* accrues in
    dilated wall-clock units (elapsed time of a dilated run) while
    *useful* counts nominal runtimes, biasing goodput low by up to the
    dilation factor — compare goodput across contention settings with
    care, or run reliability studies with contention off (as
    ``benchmarks/fig_reliability.py`` does).
    """
    valid = np.asarray(res["valid"], dtype=bool)
    done = valid & np.asarray(res["done"], dtype=bool)
    nodes = np.asarray(res["nodes"], dtype=np.float64)
    runtime = np.asarray(res["runtime"], dtype=np.float64)
    lost = np.asarray(res["lost_work"], dtype=np.float64)
    useful_ns = float((nodes * runtime)[done].sum())
    lost_ns = float((nodes * lost)[valid].sum())
    denom = useful_ns + lost_ns
    return {
        "total_restarts": float(np.asarray(res["n_restarts"])[valid].sum()),
        "n_aborted": float(np.asarray(res["aborted"])[valid].sum()),
        "lost_node_s": lost_ns,
        "goodput": useful_ns / denom if denom > 0 else 1.0,
    }


def slo_summary(res, class_names=None, total_nodes=None) -> Dict[str, float]:
    """Scalar serving metrics (results carrying SLO columns, DESIGN.md §16).

    - ``slo_attainment`` / ``deadline_miss_rate``: fraction of completed
      requests that started by / after their deadline (the verdict both
      engines fix at start time);
    - ``p50_wait`` / ``p99_wait``: exact wait percentiles over completed
      requests (and ``{class}_p50_wait`` / ``{class}_p99_wait`` /
      ``{class}_miss_rate`` per class when ``class_names`` is given);
    - ``slo_goodput``: SLO-met node-seconds over the *provisioned capacity
      integral* — under autoscaling the capacity level steps through the
      consumed tick stream (``cap_time``/``cap_online``), so scaling down
      idle capacity raises goodput even at equal attainment.  Requires
      ``total_nodes`` (the level before the first tick); omitted when
      unavailable or when the makespan is empty.
    """
    valid = np.asarray(res["valid"], dtype=bool)
    done = valid & np.asarray(res["done"], dtype=bool)
    met = np.asarray(res["slo_met"], dtype=bool)
    wait = np.asarray(res["wait"], dtype=np.float64)
    n_done = int(done.sum())
    attain = float(met[done].sum()) / n_done if n_done else 1.0
    out = {
        "n_requests": float(valid.sum()),
        "slo_attainment": attain,
        "deadline_miss_rate": 1.0 - attain,
        "p50_wait": percentiles(wait, 50, mask=done),
        "p99_wait": percentiles(wait, 99, mask=done),
    }
    if class_names is not None and "class_id" in res:
        cid = np.asarray(res["class_id"], dtype=np.int64)
        for c, name in enumerate(class_names):
            sel = done & (cid == c)
            k = int(sel.sum())
            out[f"{name}_p50_wait"] = percentiles(wait, 50, mask=sel)
            out[f"{name}_p99_wait"] = percentiles(wait, 99, mask=sel)
            out[f"{name}_miss_rate"] = (
                float((~met[sel]).sum()) / k if k else 0.0)
    if total_nodes is not None and n_done:
        nodes = np.asarray(res["nodes"], dtype=np.float64)
        start = np.asarray(res["start"], dtype=np.float64)
        finish = np.asarray(res["finish"], dtype=np.float64)
        useful = float((nodes * (finish - start))[done & met].sum())
        makespan = float(finish[done].max())
        # capacity integral: total_nodes until the first consumed tick,
        # then the logged online level between ticks, clipped to makespan
        t = np.asarray(res.get("cap_time", ()), dtype=np.float64)
        lvl = np.asarray(res.get("cap_online", ()), dtype=np.float64)
        edges = np.clip(np.r_[0.0, t, makespan], 0.0, makespan)
        levels = np.r_[float(total_nodes), lvl]
        cap_int = float((np.maximum(np.diff(edges), 0.0) * levels).sum())
        if cap_int > 0:
            out["slo_goodput"] = useful / cap_int
    return out


def malleable_summary(res) -> Dict[str, float]:
    """Scalar malleability metrics (results carrying ``mal_*`` columns,
    DESIGN.md §17).

    - ``mean_width`` / ``max_width``: the chosen (final) widths of
      completed jobs;
    - ``total_resizes``: elastic grow/shrink actions plus failure-shrinks
      across all jobs (0 for moldable runs without failures);
    - ``mean_dilation``: mean of the dispatch-time dilated duration over
      the nominal runtime for completed jobs — 1.0 means every job ran at
      its reference width;
    - ``parallel_efficiency``: the rigid baseline's node-seconds over the
      consumed node-seconds, ``sum(runtime * nref) / sum(node_s)`` across
      completed jobs.  The ledger closes a segment at every width change,
      so this is exact under grow/shrink.  Values above 1.0 mean the
      malleable run consumed FEWER node-seconds than running every job at
      its requested width — sublinear speedup curves make narrow widths
      cheaper in node-seconds, so moldable packing routinely beats 1.0.
    """
    valid = np.asarray(res["valid"], dtype=bool)
    done = valid & np.asarray(res["done"], dtype=bool)
    width = np.asarray(res["mal_width"], dtype=np.float64)
    nref = np.asarray(res["mal_nref"], dtype=np.float64)
    runtime = np.asarray(res["runtime"], dtype=np.float64)
    dil = np.asarray(res["mal_dur"], dtype=np.float64)
    node_s = np.asarray(res["mal_node_s"], dtype=np.float64)
    n_done = int(done.sum())
    ideal = float((runtime * nref)[done].sum())
    consumed = float(node_s[done].sum())
    return {
        "mean_width": float(width[done].mean()) if n_done else 0.0,
        "max_width": float(width[done].max()) if n_done else 0.0,
        "total_resizes": float(
            np.asarray(res["mal_nresize"])[valid].sum()),
        "mean_dilation": (float((dil / runtime)[done].mean())
                          if n_done else 1.0),
        "parallel_efficiency": ideal / consumed if consumed > 0 else 1.0,
    }


def compare_summaries(baseline: Dict[str, float],
                      candidate: Dict[str, float],
                      keys=None) -> Dict[str, float]:
    """Per-metric deltas between two scalar-summary dicts (DESIGN.md §20).

    Returns ``{key: candidate[key] - baseline[key]}`` over the shared
    numeric keys (or the explicit ``keys``).  NaNs propagate — an empty
    percentile on either side yields a NaN delta, which ``rank_candidates``
    sorts last.  This is the "metric deltas that justify it" half of a
    what-if recommendation row.
    """
    if keys is None:
        keys = [k for k in candidate
                if k in baseline
                and isinstance(candidate[k], (int, float))
                and isinstance(baseline[k], (int, float))]
    return {k: float(candidate[k]) - float(baseline[k]) for k in keys}


def rank_candidates(rows, metric: str, *, goal: str = "min",
                    baseline: Dict[str, float] = None,
                    target: float = None):
    """Rank ``(label, summary)`` candidates into recommendation dicts.

    ``goal`` is ``"min"`` (smaller is better, e.g. p99 wait) or ``"max"``
    (e.g. goodput).  Each output row carries ``rank`` (1 = best),
    ``label``, ``metric``, ``value``, and — when a ``baseline`` summary is
    given — ``baseline`` and ``delta`` (value - baseline).  With a
    ``target``, ``meets_target`` marks rows at-or-better than it; ranking
    is unchanged (the caller picks "cheapest meeting target" by its own
    cost order).  NaN values rank last at their input order.
    """
    if goal not in ("min", "max"):
        raise ValueError(f"goal must be 'min' or 'max', got {goal!r}")
    rows = list(rows)
    for label, summ in rows:
        if metric not in summ:
            raise KeyError(
                f"candidate {label!r} summary has no metric {metric!r}; "
                f"available: {sorted(summ)}")
    sign = 1.0 if goal == "min" else -1.0

    def sort_key(item):
        i, (_, summ) = item
        v = float(summ[metric])
        return (1, 0.0, i) if np.isnan(v) else (0, sign * v, i)

    ranked = sorted(enumerate(rows), key=sort_key)
    out = []
    for rank, (_, (label, summ)) in enumerate(ranked, start=1):
        v = float(summ[metric])
        row = {"rank": rank, "label": label, "metric": metric, "value": v}
        if baseline is not None and metric in baseline:
            base_v = float(baseline[metric])
            row["baseline"] = base_v
            row["delta"] = v - base_v
        if target is not None:
            row["meets_target"] = bool(
                not np.isnan(v)
                and (v <= target if goal == "min" else v >= target))
        out.append(row)
    return out


def summary(res, total_nodes: int) -> Dict[str, float]:
    """Scalar metrics used by the five-policy comparison (paper Fig. 4b).

    Wait statistics are ready-time based when the result carries a ``ready``
    column (dependency-aware runs, DESIGN.md §13): wait = start - ready
    charges a workflow task only for time spent *eligible* in the queue,
    not for time blocked on upstream tasks (paper Fig. 7).  Without
    ``ready`` this degenerates to the classic start - submit.
    """
    submit, start, finish, nodes, runtime = _select_valid(res)
    if len(submit) == 0:
        return {k: 0.0 for k in (
            "n_jobs", "avg_wait", "p50_wait", "p95_wait", "max_wait",
            "avg_bounded_slowdown", "makespan", "utilization", "throughput")}
    if "ready" in res:
        v = (np.asarray(res["valid"], dtype=bool)
             & np.asarray(res["done"], dtype=bool))
        ready = np.asarray(res["ready"])[v]
    else:
        ready = submit
    wait = (start - ready).astype(np.float64)
    run = runtime.astype(np.float64)
    bsld = np.maximum((wait + run) / np.maximum(run, 10.0), 1.0)
    makespan = float(finish.max() - submit.min())
    node_seconds = float((nodes.astype(np.float64) * run).sum())
    if "mal_node_s" in res:
        # malleable runs occupy width * wall-seconds per segment (the
        # engine's ledger), not the requested rigid footprint — the rigid
        # formula can report > 1.0 when moldable packing beats it
        mask = (np.asarray(res["valid"], dtype=bool)
                & np.asarray(res["done"], dtype=bool))
        node_seconds = float(
            np.asarray(res["mal_node_s"], np.float64)[mask].sum())
    util = node_seconds / (total_nodes * makespan) if makespan > 0 else 0.0
    return {
        "n_jobs": float(len(submit)),
        "avg_wait": float(wait.mean()),
        "p50_wait": percentiles(wait, 50),
        "p95_wait": percentiles(wait, 95),
        "max_wait": float(wait.max()),
        "avg_bounded_slowdown": float(bsld.mean()),
        "makespan": makespan,
        "utilization": util,
        "throughput": float(len(submit)) / makespan if makespan > 0 else 0.0,
    }
