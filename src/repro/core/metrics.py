"""Offline metric derivation (paper Figs. 3-4, 7).

The engine returns only per-job (start, finish); node occupancy, active-job
counts, queue lengths, utilization, waits, and slowdowns are all pure
functions of (submit, start, finish, nodes) — computed here in numpy so the
device loop stays lean (DESIGN.md §2).
"""

from __future__ import annotations

from typing import Dict

import numpy as np


def _select_valid(res: Dict[str, np.ndarray]):
    v = np.asarray(res["valid"], dtype=bool) & np.asarray(res["done"], dtype=bool)
    return (
        np.asarray(res["submit"])[v],
        np.asarray(res["start"])[v],
        np.asarray(res["finish"])[v],
        np.asarray(res["nodes"])[v],
        np.asarray(res["runtime"])[v],
    )


def step_series(times: np.ndarray, deltas: np.ndarray):
    """Event-sorted cumulative step function: returns (t, value_after_t)."""
    order = np.argsort(times, kind="stable")
    t = times[order]
    v = np.cumsum(deltas[order])
    # collapse duplicate timestamps to the final value at that time
    keep = np.r_[t[1:] != t[:-1], True]
    return t[keep], v[keep]


def occupancy_series(res) -> tuple[np.ndarray, np.ndarray]:
    """Nodes in use over time (paper Fig. 3a)."""
    _, start, finish, nodes, _ = _select_valid(res)
    times = np.r_[start, finish]
    deltas = np.r_[nodes, -nodes].astype(np.int64)
    return step_series(times, deltas)


def active_jobs_series(res) -> tuple[np.ndarray, np.ndarray]:
    """Number of running jobs over time (paper Fig. 3b)."""
    _, start, finish, _, _ = _select_valid(res)
    times = np.r_[start, finish]
    deltas = np.r_[np.ones_like(start), -np.ones_like(finish)].astype(np.int64)
    return step_series(times, deltas)


def queue_length_series(res) -> tuple[np.ndarray, np.ndarray]:
    """Waiting-queue length over time."""
    submit, start, _, _, _ = _select_valid(res)
    times = np.r_[submit, start]
    deltas = np.r_[np.ones_like(submit), -np.ones_like(start)].astype(np.int64)
    return step_series(times, deltas)


def sample_series(t: np.ndarray, v: np.ndarray, grid: np.ndarray) -> np.ndarray:
    """Sample a step series onto a regular grid (for plotting/comparison)."""
    idx = np.searchsorted(t, grid, side="right") - 1
    out = np.where(idx >= 0, v[np.clip(idx, 0, len(v) - 1)], 0)
    return out.astype(np.float64)


def summary(res, total_nodes: int) -> Dict[str, float]:
    """Scalar metrics used by the five-policy comparison (paper Fig. 4b)."""
    submit, start, finish, nodes, runtime = _select_valid(res)
    if len(submit) == 0:
        return {k: 0.0 for k in (
            "n_jobs", "avg_wait", "p50_wait", "p95_wait", "max_wait",
            "avg_bounded_slowdown", "makespan", "utilization", "throughput")}
    wait = (start - submit).astype(np.float64)
    run = runtime.astype(np.float64)
    bsld = np.maximum((wait + run) / np.maximum(run, 10.0), 1.0)
    makespan = float(finish.max() - submit.min())
    node_seconds = float((nodes.astype(np.float64) * run).sum())
    util = node_seconds / (total_nodes * makespan) if makespan > 0 else 0.0
    return {
        "n_jobs": float(len(submit)),
        "avg_wait": float(wait.mean()),
        "p50_wait": float(np.percentile(wait, 50)),
        "p95_wait": float(np.percentile(wait, 95)),
        "max_wait": float(wait.max()),
        "avg_bounded_slowdown": float(bsld.mean()),
        "makespan": makespan,
        "utilization": util,
        "throughput": float(len(submit)) / makespan if makespan > 0 else 0.0,
    }
