"""Vectorized scheduling-policy selectors (paper §2.1).

Each selector answers: *given the current state, which waiting job starts
next?* and returns an ``int32`` job index or ``-1``.  The engine calls the
selector in a loop until it returns ``-1`` (one event may start many jobs —
paper Algorithm 1 lines 16-21).

Semantics (pinned identically in ``repro.refsim`` for validation):

- FCFS / SJF / LJF: *blocking* head-of-(re)ordered-queue. If the highest
  priority waiting job does not fit, nothing starts.
- BestFit: among waiting jobs that fit, pick the one leaving the fewest
  nodes free (tie: FCFS order). Work-conserving.
- Backfill: EASY — if the FCFS head fits, start it; otherwise compute the
  head's shadow (earliest time enough nodes free, using *estimates* of
  running jobs) and start the first FCFS-ordered waiting job that fits now
  and either completes by the shadow or uses only the shadow's extra nodes.

Dependency awareness (DESIGN.md §13): selectors key exclusively on the
WAITING set, and the engine admits a job to WAITING only after its last
dependency completes — so every policy here is dependency-aware for free.
The one semantic pin worth stating: backfill's shadow reservation is
computed for the WAITING head only, and unreleased dependents (still
PENDING) are treated exactly like not-yet-arrived jobs — they neither hold
a reservation nor block backfilling, mirroring how EASY treats future
arrivals it cannot see.  FCFS order keys on ``submit`` (not release time),
so a workflow task released late still queues at its submit-time rank;
both engines pin this identically.

Allocation awareness (DESIGN.md §11.2): every "fits now" test compares
against ``cap``, the engine-supplied placement-feasibility cap — the free
*count* for scattered strategies (identical to the seed scalar counter),
the largest free *contiguous run* under the ``contiguous`` strategy.
Backfill's shadow math and the preempt reclaim test deliberately stay
free-count based (user estimates and reclaim totals don't know node
geometry); both engines pin this identically.

A heap is the natural CPU data structure here; on SPMD hardware we instead
use masked O(J) reductions, which vmap/shard cleanly (see DESIGN.md §2).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.jobs import (
    BACKFILL, BESTFIT, FCFS, INF_TIME, LJF, RUNNING, SJF, WAITING,
    JobSet, SimState,
)

_BIG = jnp.int32(INF_TIME)


def _lex_argmin(primary: jax.Array, mask: jax.Array) -> jax.Array:
    """Index minimizing (primary, index) over ``mask``; -1 if mask empty."""
    p = jnp.where(mask, primary, _BIG)
    best = jnp.min(p)
    idx = jnp.argmin(jnp.where(mask & (p == best), jnp.arange(p.shape[0]), _BIG))
    return jnp.where(jnp.any(mask), idx.astype(jnp.int32), jnp.int32(-1))


# shared with the engine's batched scheduling passes (DESIGN.md §14/§18)
lex_argmin = _lex_argmin


def backfill_shadow(
    jobs: JobSet, state: SimState, head_need: jax.Array,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """EASY shadow reservation for a blocked head needing ``head_need`` nodes.

    Returns ``(shadow, extra, k_row)``: the earliest time the cumulative
    releases of running jobs (walltime *estimates*, clamped past the clock)
    cover the head, the spare nodes at that instant, and the row index of
    the *reach entry* — the release whose cumulative first covers the head
    (``-1`` when even the full running set cannot cover it).

    Within one scheduling pass the shadow TIME is invariant under backfill
    starts, and ``extra`` updates by a one-line rule keyed on ``k_row``: an
    admission whose (release, row) sorts lexicographically after the reach
    entry consumed ``nodes`` of the reserve, one sorting before leaves the
    window untouched (DESIGN.md §18 states and proves this) — so the
    engine's batched pass computes this ONCE per event instead of once per
    selector call.
    """
    running = state.jstate == RUNNING
    # clamp to > clock so an over-running job (actual > estimate) still
    # releases "in the future" for shadow math
    rsv = jnp.where(running, jnp.maximum(state.rsv_finish, state.clock + 1),
                    _BIG)
    rows = jnp.arange(jobs.capacity, dtype=jnp.int32)

    # Walk releases in (time, row) lex order, accumulating freed nodes
    # until the head is covered.  The walk is a data-dependent while_loop
    # of masked O(J) argmins: a blocked head typically needs only 1-3
    # releases, so this beats every sort-shaped alternative on XLA:CPU —
    # measured at J=2048: full argsort ~485us, lax.top_k ~550us (TopK
    # lowers WORSE than the sort), vs ~15us per walk step.  Ties break by
    # row index exactly like a stable sort, so refsim stays bit-identical.
    # Under vmap the batched while_loop runs max-iterations-across-members
    # with finished members' carries preserved — still sort-free.
    #
    # Semantics pin (matches refsim's walk): at least one release entry is
    # always counted — coverage is tested only AFTER adding an entry, so
    # even a head that free nodes alone could cover (possible under a
    # geometry cap, where "blocked" does not imply ``free < head_need``)
    # shadows at the EARLIEST release, not at the clock.
    def _cond(carry):
        cum, _sh, k_row, left = carry
        return ((k_row < 0) | (cum < head_need)) & jnp.any(left)

    def _body(carry):
        cum, _sh, _k_row, left = carry
        p = jnp.where(left, rsv, _BIG)
        best = jnp.min(p)
        i = jnp.argmin(jnp.where(left & (p == best), rows, _BIG))
        i = i.astype(jnp.int32)
        return cum + jobs.nodes[i], rsv[i], i, left.at[i].set(False)

    cum, sh, kr, _ = jax.lax.while_loop(
        _cond, _body, (state.free, _BIG, jnp.int32(-1), running))
    covered = (kr >= 0) & (cum >= head_need)
    shadow = jnp.where(covered, sh, _BIG)
    extra = jnp.where(covered, cum - head_need, state.free)
    k_row = jnp.where(covered, kr, jnp.int32(-1))
    return shadow, extra, k_row


def _blocking_head(jobs: JobSet, state: SimState, key: jax.Array,
                   cap: jax.Array) -> jax.Array:
    waiting = state.jstate == WAITING
    head = _lex_argmin(key, waiting)
    fits = jobs.nodes[jnp.maximum(head, 0)] <= cap
    return jnp.where((head >= 0) & fits, head, jnp.int32(-1))


def select_fcfs(jobs: JobSet, state: SimState, cap: jax.Array) -> jax.Array:
    # FCFS key = (submit, row); row order of an initial JobSet is already
    # (submit, id), and keying on submit keeps FCFS correct after the
    # multi-cluster engine migrates jobs into arbitrary free rows.
    return _blocking_head(jobs, state, jobs.submit, cap)


def select_sjf(jobs: JobSet, state: SimState, cap: jax.Array) -> jax.Array:
    return _blocking_head(jobs, state, jobs.estimate, cap)


def select_ljf(jobs: JobSet, state: SimState, cap: jax.Array) -> jax.Array:
    return _blocking_head(jobs, state, -jobs.estimate, cap)


def select_bestfit(jobs: JobSet, state: SimState, cap: jax.Array) -> jax.Array:
    waiting = state.jstate == WAITING
    feasible = waiting & (jobs.nodes <= cap)
    leftover = state.free - jobs.nodes
    return _lex_argmin(leftover, feasible)


def select_backfill(jobs: JobSet, state: SimState, cap: jax.Array) -> jax.Array:
    J = jobs.capacity
    waiting = state.jstate == WAITING
    head = _lex_argmin(jobs.submit, waiting)
    head_safe = jnp.maximum(head, 0)
    head_need = jobs.nodes[head_safe]
    head_fits = head_need <= cap

    idxs = jnp.arange(J, dtype=jnp.int32)
    fits_now = jobs.nodes <= cap
    # necessary condition for any backfill admission: some non-head
    # waiting job fits the cap — testing it BEFORE the shadow walk skips
    # the expensive branch on backlogged "nothing can start" selections
    any_fit = jnp.any(waiting & fits_now & (idxs != head_safe))

    def blocked(_):
        # ---- shadow computation over running jobs (walltime estimates) ---
        shadow, extra, _k_row = backfill_shadow(jobs, state, head_need)

        # ---- backfill candidates -----------------------------------------
        ends_by_shadow = (state.clock + jobs.estimate) <= shadow
        within_extra = jobs.nodes <= jnp.minimum(state.free, extra)
        cand = (waiting & fits_now & (idxs != head_safe)
                & (ends_by_shadow | within_extra))
        return _lex_argmin(jobs.submit, cand)

    # Lazy shadow: most selections either start the head, have nothing
    # waiting, or have no candidate that could fit; the release walk only
    # runs when the head is blocked AND something fits (measured 20x
    # single-stream throughput on SDSC-SP2-like traces).
    return jax.lax.cond(
        head_fits & (head >= 0),
        lambda _: head,
        lambda _: jax.lax.cond((head >= 0) & any_fit, blocked,
                               lambda __: jnp.int32(-1), _),
        None,
    )


def select_preempt(jobs: JobSet, state: SimState, cap: jax.Array) -> jax.Array:
    """Priority scheduling with preemption (paper §5 future work).

    Queue order: (priority, submit, row).  The head starts if it fits in
    free nodes OR if enough nodes can be reclaimed from strictly-lower-
    priority running jobs; the engine's ``_preempt_for`` suspends the
    minimal victim set before the start.  The reclaim test is free-count
    based by design (``cap`` is unused): placement after preemption falls
    back to scattered first-fit if the strategy cannot honor its shape
    (DESIGN.md §11.2), so count feasibility is exact.
    """
    waiting = state.jstate == WAITING
    # lexicographic (priority, submit): both bounded by INF_TIME < 2**30;
    # combine via f64-free two-stage argmin
    p = jnp.where(waiting, jobs.priority, _BIG)
    best_p = jnp.min(p)
    tier = waiting & (jobs.priority == best_p)
    head = _lex_argmin(jobs.submit, tier)
    head_safe = jnp.maximum(head, 0)
    running = state.jstate == RUNNING
    reclaimable = jnp.sum(jnp.where(
        running & (jobs.priority > jobs.priority[head_safe]), jobs.nodes, 0))
    fits = jobs.nodes[head_safe] <= state.free + reclaimable
    return jnp.where((head >= 0) & fits, head, jnp.int32(-1))


_SELECTORS = (select_fcfs, select_sjf, select_ljf, select_bestfit,
              select_backfill, select_preempt)
assert tuple(sorted((FCFS, SJF, LJF, BESTFIT, BACKFILL))) == tuple(range(5))

# public view of the dispatch table: the engine's static-policy hint clamps
# against its length, so growing the table updates every clip site at once
SELECTOR_TABLE = _SELECTORS


def select(policy: jax.Array, jobs: JobSet, state: SimState,
           cap: jax.Array | None = None, *,
           static_policy: int | None = None) -> jax.Array:
    """Dispatch on (possibly traced) policy id — vmap-able over policies.

    ``cap`` is the placement-feasibility cap (defaults to the scalar free
    counter, i.e. seed semantics); the engine passes ``placeable_cap`` when
    an allocation context is active.  When the engine resolved the policy id
    at trace time it passes ``static_policy`` and the selector is called
    directly — only that policy's reduction graph is traced, instead of a
    six-way ``lax.switch`` per scheduling step (DESIGN.md §14).
    """
    cap = state.free if cap is None else cap
    hi = len(_SELECTORS) - 1
    if static_policy is not None:
        return _SELECTORS[min(max(static_policy, 0), hi)](jobs, state, cap)
    return jax.lax.switch(jnp.clip(policy, 0, hi), _SELECTORS, jobs, state, cap)
