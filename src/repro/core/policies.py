"""Vectorized scheduling-policy selectors (paper §2.1).

Each selector answers: *given the current state, which waiting job starts
next?* and returns an ``int32`` job index or ``-1``.  The engine calls the
selector in a loop until it returns ``-1`` (one event may start many jobs —
paper Algorithm 1 lines 16-21).

Semantics (pinned identically in ``repro.refsim`` for validation):

- FCFS / SJF / LJF: *blocking* head-of-(re)ordered-queue. If the highest
  priority waiting job does not fit, nothing starts.
- BestFit: among waiting jobs that fit, pick the one leaving the fewest
  nodes free (tie: FCFS order). Work-conserving.
- Backfill: EASY — if the FCFS head fits, start it; otherwise compute the
  head's shadow (earliest time enough nodes free, using *estimates* of
  running jobs) and start the first FCFS-ordered waiting job that fits now
  and either completes by the shadow or uses only the shadow's extra nodes.

Dependency awareness (DESIGN.md §13): selectors key exclusively on the
WAITING set, and the engine admits a job to WAITING only after its last
dependency completes — so every policy here is dependency-aware for free.
The one semantic pin worth stating: backfill's shadow reservation is
computed for the WAITING head only, and unreleased dependents (still
PENDING) are treated exactly like not-yet-arrived jobs — they neither hold
a reservation nor block backfilling, mirroring how EASY treats future
arrivals it cannot see.  FCFS order keys on ``submit`` (not release time),
so a workflow task released late still queues at its submit-time rank;
both engines pin this identically.

Allocation awareness (DESIGN.md §11.2): every "fits now" test compares
against ``cap``, the engine-supplied placement-feasibility cap — the free
*count* for scattered strategies (identical to the seed scalar counter),
the largest free *contiguous run* under the ``contiguous`` strategy.
Backfill's shadow math and the preempt reclaim test deliberately stay
free-count based (user estimates and reclaim totals don't know node
geometry); both engines pin this identically.

A heap is the natural CPU data structure here; on SPMD hardware we instead
use masked O(J) reductions, which vmap/shard cleanly (see DESIGN.md §2).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.jobs import (
    BACKFILL, BESTFIT, FCFS, INF_TIME, LJF, RUNNING, SJF, WAITING,
    JobSet, SimState,
)

_BIG = jnp.int32(INF_TIME)


def _lex_argmin(primary: jax.Array, mask: jax.Array) -> jax.Array:
    """Index minimizing (primary, index) over ``mask``; -1 if mask empty."""
    p = jnp.where(mask, primary, _BIG)
    best = jnp.min(p)
    idx = jnp.argmin(jnp.where(mask & (p == best), jnp.arange(p.shape[0]), _BIG))
    return jnp.where(jnp.any(mask), idx.astype(jnp.int32), jnp.int32(-1))


def _first_index(mask: jax.Array) -> jax.Array:
    idx = jnp.argmax(mask)  # first True (argmax of bool picks lowest index)
    return jnp.where(jnp.any(mask), idx.astype(jnp.int32), jnp.int32(-1))


def _blocking_head(jobs: JobSet, state: SimState, key: jax.Array,
                   cap: jax.Array) -> jax.Array:
    waiting = state.jstate == WAITING
    head = _lex_argmin(key, waiting)
    fits = jobs.nodes[jnp.maximum(head, 0)] <= cap
    return jnp.where((head >= 0) & fits, head, jnp.int32(-1))


def select_fcfs(jobs: JobSet, state: SimState, cap: jax.Array) -> jax.Array:
    # FCFS key = (submit, row); row order of an initial JobSet is already
    # (submit, id), and keying on submit keeps FCFS correct after the
    # multi-cluster engine migrates jobs into arbitrary free rows.
    return _blocking_head(jobs, state, jobs.submit, cap)


def select_sjf(jobs: JobSet, state: SimState, cap: jax.Array) -> jax.Array:
    return _blocking_head(jobs, state, jobs.estimate, cap)


def select_ljf(jobs: JobSet, state: SimState, cap: jax.Array) -> jax.Array:
    return _blocking_head(jobs, state, -jobs.estimate, cap)


def select_bestfit(jobs: JobSet, state: SimState, cap: jax.Array) -> jax.Array:
    waiting = state.jstate == WAITING
    feasible = waiting & (jobs.nodes <= cap)
    leftover = state.free - jobs.nodes
    return _lex_argmin(leftover, feasible)


def select_backfill(jobs: JobSet, state: SimState, cap: jax.Array) -> jax.Array:
    J = jobs.capacity
    waiting = state.jstate == WAITING
    head = _lex_argmin(jobs.submit, waiting)
    head_safe = jnp.maximum(head, 0)
    head_need = jobs.nodes[head_safe]
    head_fits = head_need <= cap

    def blocked(_):
        # ---- shadow computation over running jobs (walltime estimates) ---
        running = state.jstate == RUNNING
        # clamp to > clock so an over-running job (actual > estimate) still
        # releases "in the future" for shadow math
        rsv = jnp.where(running, jnp.maximum(state.rsv_finish, state.clock + 1),
                        _BIG)
        # The shadow needs only the earliest releases until cumulative free
        # nodes cover the head: top-k of the M smallest release times is
        # O(J log M) vs O(J log J) for the full sort; fall back to the full
        # sort in the rare case M releases don't cover the head.  Ties are
        # broken by row index in both paths (and in refsim), so the two
        # engines stay bit-identical.
        rel_nodes = jnp.where(running, jobs.nodes, 0)
        n_running = jnp.sum(running.astype(jnp.int32))

        def shadow_from(rsv_sorted, nodes_sorted):
            cum_free = state.free + jnp.cumsum(nodes_sorted)
            enough = cum_free >= head_need
            k = _first_index(enough)
            k_safe = jnp.maximum(k, 0)
            sh = jnp.where(k >= 0, rsv_sorted[k_safe], _BIG)
            ex = jnp.where(k >= 0, cum_free[k_safe] - head_need, state.free)
            return sh, ex, k

        M = min(64, J)
        neg_top, order_m = jax.lax.top_k(-rsv, M)
        sh_m, ex_m, k_m = shadow_from(-neg_top, rel_nodes[order_m])

        def full_path(_):
            order = jnp.argsort(rsv)  # stable: ties by row index
            sh, ex, _ = shadow_from(rsv[order], rel_nodes[order])
            return sh, ex

        shadow, extra = jax.lax.cond(
            (k_m >= 0) | (n_running <= M),
            lambda _: (sh_m, ex_m), full_path, None,
        )

        # ---- backfill candidates -----------------------------------------
        idxs = jnp.arange(J, dtype=jnp.int32)
        fits_now = jobs.nodes <= cap
        ends_by_shadow = (state.clock + jobs.estimate) <= shadow
        within_extra = jobs.nodes <= jnp.minimum(state.free, extra)
        cand = (waiting & fits_now & (idxs != head_safe)
                & (ends_by_shadow | within_extra))
        return _lex_argmin(jobs.submit, cand)

    # Lazy shadow: most selections either start the head or have nothing
    # waiting; the O(J log J) sort only runs when the head is blocked
    # (measured 20x single-stream throughput on SDSC-SP2-like traces).
    return jax.lax.cond(
        head_fits & (head >= 0),
        lambda _: head,
        lambda _: jax.lax.cond(head >= 0, blocked, lambda __: jnp.int32(-1), _),
        None,
    )


def select_preempt(jobs: JobSet, state: SimState, cap: jax.Array) -> jax.Array:
    """Priority scheduling with preemption (paper §5 future work).

    Queue order: (priority, submit, row).  The head starts if it fits in
    free nodes OR if enough nodes can be reclaimed from strictly-lower-
    priority running jobs; the engine's ``_preempt_for`` suspends the
    minimal victim set before the start.  The reclaim test is free-count
    based by design (``cap`` is unused): placement after preemption falls
    back to scattered first-fit if the strategy cannot honor its shape
    (DESIGN.md §11.2), so count feasibility is exact.
    """
    waiting = state.jstate == WAITING
    # lexicographic (priority, submit): both bounded by INF_TIME < 2**30;
    # combine via f64-free two-stage argmin
    p = jnp.where(waiting, jobs.priority, _BIG)
    best_p = jnp.min(p)
    tier = waiting & (jobs.priority == best_p)
    head = _lex_argmin(jobs.submit, tier)
    head_safe = jnp.maximum(head, 0)
    running = state.jstate == RUNNING
    reclaimable = jnp.sum(jnp.where(
        running & (jobs.priority > jobs.priority[head_safe]), jobs.nodes, 0))
    fits = jobs.nodes[head_safe] <= state.free + reclaimable
    return jnp.where((head >= 0) & fits, head, jnp.int32(-1))


_SELECTORS = (select_fcfs, select_sjf, select_ljf, select_bestfit,
              select_backfill, select_preempt)
assert tuple(sorted((FCFS, SJF, LJF, BESTFIT, BACKFILL))) == tuple(range(5))

# public view of the dispatch table: the engine's static-policy hint clamps
# against its length, so growing the table updates every clip site at once
SELECTOR_TABLE = _SELECTORS


def select(policy: jax.Array, jobs: JobSet, state: SimState,
           cap: jax.Array | None = None, *,
           static_policy: int | None = None) -> jax.Array:
    """Dispatch on (possibly traced) policy id — vmap-able over policies.

    ``cap`` is the placement-feasibility cap (defaults to the scalar free
    counter, i.e. seed semantics); the engine passes ``placeable_cap`` when
    an allocation context is active.  When the engine resolved the policy id
    at trace time it passes ``static_policy`` and the selector is called
    directly — only that policy's reduction graph is traced, instead of a
    six-way ``lax.switch`` per scheduling step (DESIGN.md §14).
    """
    cap = state.free if cap is None else cap
    hi = len(_SELECTORS) - 1
    if static_policy is not None:
        return _SELECTORS[min(max(static_policy, 0), hi)](jobs, state, cap)
    return jax.lax.switch(jnp.clip(policy, 0, hi), _SELECTORS, jobs, state, cap)
