"""The discrete-event engine (paper §2.2, Algorithm 1) as a jit-able loop.

Event semantics, pinned identically in ``repro.refsim`` (DESIGN.md §8):

  1. advance clock to min(next arrival, next completion),
  2. process *all* completions with finish <= clock (reclaim nodes),
  3. process *all* arrivals with submit <= clock (enqueue),
  4. run the scheduling pass: repeatedly ask the policy selector for a job
     and start it, until the selector returns -1.

Dependencies (paper §3, DESIGN.md §13): when the job table carries a
``deps`` matrix, a PENDING job arrives only when ``submit <= clock`` AND
every dependency is DONE.  Dependents of a completing job are re-evaluated
at the completion event itself (completions run before arrivals), so a
released dependent joins the wait queue — and competes in the scheduling
pass — at its last dependency's finish time.  ``deps is None`` statically
elides every release check, compiling to the exact seed event graph.

Each event consumes at least one arrival or completion, so the loop runs at
most ``2*J + 1`` iterations; ``max_events`` is a safety cap on top.

Node allocation (DESIGN.md §11): with a ``Machine`` the engine additionally
maintains the per-node occupancy map.  Completions free the completing
jobs' nodes, starts place concrete nodes via the chosen strategy, and the
policy fit checks use ``placeable_cap`` — for the count-based strategies
that cap *is* the scalar free counter, so ``alloc="simple"`` with
contention off reproduces the seed scalar-counter schedule bit-for-bit.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro import alloc as _alloc
from repro.core import policies
from repro.core.jobs import (
    DONE, INF_TIME, PENDING, RUNNING, WAITING,
    JobSet, SimResult, SimState, result_from_state,
)

# An allocation context is either None (seed scalar-counter mode) or the
# pytree tuple (machine, strategy_i32, contention); its None-ness is static
# at trace time, so the scalar path compiles with zero allocation overhead.
AllocCtx = tuple


def _release_nodes(state_owner: jax.Array, released: jax.Array,
                   capacity: int) -> jax.Array:
    """Free every node whose owning job row is in the ``released`` mask."""
    own = state_owner
    hit = (own >= 0) & released[jnp.clip(own, 0, capacity - 1)]
    return jnp.where(hit, jnp.int32(-1), own)


def _start_job(jobs: JobSet, state: SimState, idx: jax.Array,
               ctx: Optional[AllocCtx]) -> SimState:
    """Allocate nodes to job ``idx`` and schedule its completion event.

    Uses ``state.remaining`` (== runtime unless previously preempted) and
    records only the FIRST start time (dispatch-latency metric).  With an
    allocation context, concrete nodes are placed by the strategy, the
    occupancy map and allocation fingerprints update, and contention dilates
    the remaining runtime by the allocation's group span.
    """
    start = state.clock
    if ctx is None:
        dil_rem = state.remaining[idx]
    else:
        machine, strategy, con = ctx
        mask = _alloc.place(strategy, machine, state.node_owner, jobs.nodes[idx])
        span = _alloc.group_span(machine, mask)
        first, asum = _alloc.alloc_fingerprint(mask)
        dil_rem = _alloc.dilate(con, state.remaining[idx], span)
        state = dataclasses.replace(
            state,
            node_owner=jnp.where(mask, idx, state.node_owner),
            alloc_first=state.alloc_first.at[idx].set(first),
            alloc_span=state.alloc_span.at[idx].set(span),
            alloc_sum=state.alloc_sum.at[idx].set(asum),
        )
    fin = start + dil_rem
    rsv = start + jobs.estimate[idx]
    first_start = jnp.minimum(state.start[idx], start)
    return dataclasses.replace(
        state,
        jstate=state.jstate.at[idx].set(RUNNING),
        start=state.start.at[idx].set(first_start),
        finish=state.finish.at[idx].set(fin),
        rsv_finish=state.rsv_finish.at[idx].set(rsv),
        free=state.free - jobs.nodes[idx],
    )


def _preempt_for(jobs: JobSet, state: SimState, idx: jax.Array,
                 ctx: Optional[AllocCtx]) -> SimState:
    """Suspend the minimal set of strictly-lower-priority running jobs so
    that job ``idx`` fits (paper §5 future work: preemption capability).

    Victims are chosen most-preemptible-first: (priority desc, row desc).
    Suspended jobs keep their elapsed work (remaining shrinks) and return to
    WAITING with their original submit time/FCFS rank.  Victims release
    their concrete nodes; the reclaim test is free-count based, so under the
    ``contiguous`` strategy the subsequent placement may fall back to
    scattered first-fit (DESIGN.md §11.2).
    """
    J = jobs.capacity
    need = jobs.nodes[idx] - state.free
    running = state.jstate == RUNNING
    lower = running & (jobs.priority > jobs.priority[idx])
    # order victims by (priority desc, row desc): key = -(priority*J + row)
    key = jnp.where(lower, -(jobs.priority * J + jnp.arange(J, dtype=jnp.int32)),
                    jnp.int32(INF_TIME))
    order = jnp.argsort(key)
    nodes_o = jnp.where(lower, jobs.nodes, 0)[order]
    cum = jnp.cumsum(nodes_o)
    # preempt the minimal prefix whose cumulative nodes cover the deficit
    take_rank = jnp.where(cum - nodes_o < jnp.maximum(need, 0), True, False)
    take_rank = take_rank & (nodes_o > 0)
    victim = jnp.zeros((J,), bool).at[order].set(take_rank)
    freed = jnp.sum(jnp.where(victim, jobs.nodes, 0)).astype(jnp.int32)
    new_remaining = jnp.where(
        victim, jnp.maximum(state.finish - state.clock, 1), state.remaining
    )
    node_owner = (state.node_owner if ctx is None
                  else _release_nodes(state.node_owner, victim, J))
    return dataclasses.replace(
        state,
        jstate=jnp.where(victim, WAITING, state.jstate),
        finish=jnp.where(victim, INF_TIME, state.finish),
        rsv_finish=jnp.where(victim, INF_TIME, state.rsv_finish),
        remaining=new_remaining,
        free=state.free + freed,
        node_owner=node_owner,
    )


def _select(policy: jax.Array, jobs: JobSet, state: SimState,
            ctx: Optional[AllocCtx]) -> jax.Array:
    """Policy selection under the active allocation feasibility cap."""
    cap = (state.free if ctx is None
           else _alloc.placeable_cap(ctx[1], state.node_owner))
    return policies.select(policy, jobs, state, cap)


def _schedule_pass(policy: jax.Array, jobs: JobSet, state: SimState,
                   ctx: Optional[AllocCtx]) -> SimState:
    """Start jobs until the policy blocks (Algorithm 1 lines 16-21)."""

    def cond(carry):
        _, idx = carry
        return idx >= 0

    def body(carry):
        st, idx = carry
        st = jax.lax.cond(
            jobs.nodes[idx] <= st.free,
            lambda s: s,
            lambda s: _preempt_for(jobs, s, idx, ctx),  # preempt policy only
            st,
        )
        st = _start_job(jobs, st, idx, ctx)
        return st, _select(policy, jobs, st, ctx)

    state, _ = jax.lax.while_loop(
        cond, body, (state, _select(policy, jobs, state, ctx))
    )
    return state


def _released(jobs: JobSet, jstate: jax.Array) -> jax.Array | None:
    """Dependency release mask: True where every dependency is DONE.

    ``None`` when the job table carries no dependency matrix — the static
    elision that keeps the no-deps path compiling to the exact seed graph.
    """
    if jobs.deps is None:
        return None
    unmet = jobs.deps & (jstate != DONE)[None, :]
    return ~jnp.any(unmet, axis=1)


def _event_step(policy: jax.Array, jobs: JobSet, state: SimState,
                ctx: Optional[AllocCtx] = None) -> SimState:
    pending = state.jstate == PENDING
    running = state.jstate == RUNNING

    # A PENDING job generates an arrival event only once its dependencies
    # are DONE; unreleased dependents are invisible to the clock (and to
    # backfill's shadow math, which never sees them as WAITING).
    rel = _released(jobs, state.jstate)
    arrivable = pending if rel is None else pending & rel
    t_arr = jnp.min(jnp.where(arrivable, jobs.submit, INF_TIME))
    t_fin = jnp.min(jnp.where(running, state.finish, INF_TIME))
    clock = jnp.minimum(t_arr, t_fin)

    # completions first (frees nodes for arrivals at the same timestamp)
    completed = running & (state.finish <= clock)
    freed = jnp.sum(jnp.where(completed, jobs.nodes, 0)).astype(jnp.int32)
    jstate = jnp.where(completed, DONE, state.jstate)
    node_owner = (state.node_owner if ctx is None
                  else _release_nodes(state.node_owner, completed, jobs.capacity))

    # arrivals — dependents of this event's completions release *now*
    # (paper §3 release rule): re-evaluate readiness after completions so a
    # job whose last dependency just finished joins the wait queue in the
    # same event, with ready_time = max(submit, last dep finish).
    arrived = (jstate == PENDING) & (jobs.submit <= clock)
    rel = _released(jobs, jstate)
    if rel is not None:
        arrived = arrived & rel
    jstate = jnp.where(arrived, WAITING, jstate)

    state = dataclasses.replace(
        state,
        clock=clock,
        jstate=jstate,
        free=state.free + freed,
        n_events=state.n_events + 1,
        node_owner=node_owner,
    )
    state = _schedule_pass(policy, jobs, state, ctx)
    if ctx is None:
        return state
    # fragmentation log: one (clock, free, largest-free-block) row per event
    slot = state.n_events - 1
    return dataclasses.replace(
        state,
        ev_time=state.ev_time.at[slot].set(state.clock, mode="drop"),
        ev_free=state.ev_free.at[slot].set(state.free, mode="drop"),
        ev_lfb=state.ev_lfb.at[slot].set(
            _alloc.largest_free_run(state.node_owner), mode="drop"),
    )


def make_alloc_ctx(machine, strategy, contention,
                   total_nodes=None) -> Optional[AllocCtx]:
    """Canonicalize user-facing allocation arguments into an ``AllocCtx``.

    Raises when allocation arguments are inconsistent: ``alloc``/
    ``contention`` without a ``machine`` would be silently ignored, and a
    ``machine`` whose size disagrees with a *concrete* ``total_nodes`` would
    corrupt the occupancy map (a traced ``total_nodes`` skips that check —
    the caller owns it in sweep code).
    """
    if machine is None:
        if strategy is not None or contention is not None:
            raise ValueError(
                "alloc/contention require machine=; without a Machine the "
                "simulation runs in scalar-counter mode and would silently "
                "ignore them")
        return None
    if total_nodes is not None:
        try:
            concrete = int(total_nodes)
        except (TypeError, jax.errors.TracerArrayConversionError,
                jax.errors.ConcretizationTypeError):
            concrete = None
        if concrete is not None and concrete != machine.n_nodes:
            raise ValueError(
                f"machine has {machine.n_nodes} nodes but "
                f"total_nodes={concrete}")
    strategy = jnp.asarray(_alloc.canonical_id(strategy), dtype=jnp.int32)
    return (machine, strategy, _alloc.Contention.canonical(contention))


def simulate(
    jobs: JobSet,
    policy: jax.Array | int,
    total_nodes: jax.Array | int,
    *,
    machine=None,
    alloc: jax.Array | int | str | None = None,
    contention=None,
    max_events: Optional[int] = None,
) -> SimResult:
    """Run the full job-scheduling simulation for one cluster.

    This is the low-level engine call; the declarative front door is
    ``repro.api.run(Scenario(...))``, which builds the job table, machine
    and contention from one spec and returns a unified ``Result``
    (DESIGN.md §12).  Kept stable for callers that already hold a
    ``JobSet``.

    Pure function of its inputs (``policy``, ``total_nodes``, the allocation
    ``alloc`` strategy id and ``contention`` parameters are traced, so the
    same executable serves every policy/machine-size/allocator combination);
    ``vmap``-able over ``jobs`` leaves, ``policy``, ``total_nodes``,
    ``alloc`` and/or ``contention`` for ensemble simulation (see
    ``repro.core.parallel``).

    Without ``machine`` the engine runs in the seed scalar-counter mode.
    With ``machine`` (a ``repro.alloc.Machine`` whose ``n_nodes`` must equal
    ``total_nodes``) each start places concrete nodes under the ``alloc``
    strategy and the result carries allocation fingerprints plus the
    per-event fragmentation log.
    """
    ctx = make_alloc_ctx(machine, alloc, contention, total_nodes)
    return _simulate_jit(
        jobs, jnp.asarray(policy, dtype=jnp.int32),
        jnp.asarray(total_nodes, dtype=jnp.int32), ctx, max_events=max_events,
    )


@functools.partial(jax.jit, static_argnames=("max_events",))
def _simulate_jit(
    jobs: JobSet,
    policy: jax.Array,
    total_nodes: jax.Array,
    ctx: Optional[AllocCtx],
    *,
    max_events: Optional[int] = None,
) -> SimResult:
    cap = max_events if max_events is not None else 6 * jobs.capacity + 8
    machine = ctx[0] if ctx is not None else None
    state = SimState.init(jobs, total_nodes, machine=machine, event_log=cap)

    def cond(st: SimState):
        unfinished = jnp.any((st.jstate != DONE))
        return unfinished & (st.n_events < cap)

    state = jax.lax.while_loop(
        cond, lambda st: _event_step(policy, jobs, st, ctx), state
    )
    return result_from_state(jobs, state)


def next_event_time(jobs: JobSet, state: SimState) -> jax.Array:
    pending = state.jstate == PENDING
    running = state.jstate == RUNNING
    rel = _released(jobs, state.jstate)
    arrivable = pending if rel is None else pending & rel
    t_arr = jnp.min(jnp.where(arrivable, jobs.submit, INF_TIME))
    t_fin = jnp.min(jnp.where(running, state.finish, INF_TIME))
    return jnp.minimum(t_arr, t_fin)


def simulate_window(
    policy: jax.Array,
    jobs: JobSet,
    state: SimState,
    t_hi: jax.Array,
    max_events: jax.Array | int,
    ctx: Optional[AllocCtx] = None,
) -> SimState:
    """Process every event with timestamp <= ``t_hi`` (conservative window).

    The multi-cluster engine (``repro.core.parallel``) calls this once per
    synchronization round — the JAX analogue of SST's conservative
    per-lookahead-window execution (DESIGN.md §2).
    """

    def cond(st: SimState):
        return (next_event_time(jobs, st) <= t_hi) & (st.n_events < max_events)

    return jax.lax.while_loop(
        cond, lambda st: _event_step(policy, jobs, st, ctx), state
    )


def simulate_np(trace, policy, *, total_nodes: int, capacity: int | None = None,
                machine=None, alloc: int | str | None = None, contention=None):
    """Host convenience shim: dict-of-numpy trace -> numpy result dict.

    Equivalent to ``repro.api.run(Scenario(trace=trace, ...)).to_np()``;
    kept as the minimal-dependency one-call path (and as the schema
    reference for ``repro.api.Result.to_np``).
    """
    import numpy as np
    from repro.core.jobs import make_jobset

    jobs = make_jobset(
        trace["submit"], trace["runtime"], trace["nodes"],
        trace.get("estimate"), trace.get("priority"),
        deps=trace.get("deps"),
        capacity=capacity, total_nodes=total_nodes,
    )
    pol = policies_id(policy)
    res = simulate(jobs, pol, total_nodes, machine=machine, alloc=alloc,
                   contention=contention)
    ok = np.asarray(res.done)
    out = {
        "submit": np.asarray(jobs.submit),
        "nodes": np.asarray(jobs.nodes),
        "runtime": np.asarray(jobs.runtime),
        "start": np.asarray(res.start),
        "finish": np.asarray(res.finish),
        "ready": np.asarray(res.ready),
        "wait": np.asarray(res.wait),
        "makespan": int(res.makespan),
        "n_events": int(res.n_events),
        "done": ok,
        "valid": np.asarray(jobs.valid),
    }
    if machine is not None:
        n_ev = out["n_events"]
        out["alloc_first"] = np.asarray(res.alloc_first)
        out["alloc_span"] = np.asarray(res.alloc_span)
        out["alloc_sum"] = np.asarray(res.alloc_sum)
        out["ev_time"] = np.asarray(res.ev_time)[:n_ev]
        out["ev_free"] = np.asarray(res.ev_free)[:n_ev]
        out["ev_lfb"] = np.asarray(res.ev_lfb)[:n_ev]
    return out


def policies_id(policy) -> int:
    from repro.core.jobs import POLICY_IDS
    if isinstance(policy, str):
        return POLICY_IDS[policy.lower()]
    return int(policy)
