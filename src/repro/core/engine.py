"""The discrete-event engine (paper §2.2, Algorithm 1) as a jit-able loop.

Event semantics, pinned identically in ``repro.refsim`` (DESIGN.md §8):

  1. advance clock to min(next arrival, next completion),
  2. process *all* completions with finish <= clock (reclaim nodes),
  3. process *all* arrivals with submit <= clock (enqueue),
  4. run the scheduling pass: repeatedly ask the policy selector for a job
     and start it, until the selector returns -1.

Dependencies (paper §3, DESIGN.md §13): when the job table carries a
``dep_dst``/``dep_src`` edge list, a PENDING job arrives only when
``submit <= clock`` AND every dependency is DONE.  Dependents of a
completing job are re-evaluated at the completion event itself (completions
run before arrivals), so a released dependent joins the wait queue — and
competes in the scheduling pass — at its last dependency's finish time.
The release test is the incremental counter ``SimState.n_unmet == 0``
(DESIGN.md §14): completions decrement the counters in O(E) — CSR-gather
in ``simulate``, scatter-add fallback in windows — replacing the two
O(J²) dense-matrix reductions the engine used to pay per event.
``dep_dst is None`` statically elides every release check, compiling to
the exact seed event graph.

Each event consumes at least one arrival or completion, so the loop runs at
most ``2*J + 1`` iterations; ``max_events`` is a safety cap on top.

Fast scheduling pass (DESIGN.md §14): when the job table carries
dependencies, the policy is *statically* known to be a blocking
head-of-queue discipline (FCFS/SJF/LJF), and the placement feasibility cap
is the free counter (scalar-counter mode, or the count-capped
``simple``/``spread`` strategies), the per-event scheduling pass reads the
entire feasible prefix off a loop-invariant queue permutation (one sort
per *call*, one O(J) cumsum per event) instead of re-running the policy
selector after every start — DAG stage fronts start whole release waves
in a single event.  Dependency-free tables, backfill, bestfit, preempt
and the geometry-capped strategies keep the per-start loop (with the
selector dispatched statically where known); the choice is made at trace
time (a traced policy — e.g. a ``vmap``-ped sweep axis — always takes the
seed loop), so no path pays for another's.

Malleable jobs (DESIGN.md §17): with a ``malleable`` plan the engine gains
moldable width choice at dispatch — among placement-feasible widths the
scheduler picks the one with the minimum dilated runtime (ties to the
narrowest), the job's node footprint becomes its *current width*, and every
fit check / completion / demand reduction reads the width through an
effective-jobs view — plus, in elastic mode, a fourth event source: a
deterministic resize-tick stream under which queue pressure shrinks the
widest running job (freeing nodes for the queue) and idle capacity grows
the narrowest one, and a §15 node failure shrinks its victim by one node
instead of requeueing it while the victim still has width to give.
``malleable=None`` statically elides all of it: ``SimState.mal`` is
``None`` and the rigid executable is HLO-identical to the pre-malleable
engine (fingerprint-tested).

Node allocation (DESIGN.md §11): with a ``Machine`` the engine additionally
maintains the per-node occupancy map.  Completions free the completing
jobs' nodes, starts place concrete nodes via the chosen strategy, and the
policy fit checks use ``placeable_cap`` — for the count-based strategies
that cap *is* the scalar free counter, so ``alloc="simple"`` with
contention off reproduces the seed scalar-counter schedule bit-for-bit.

Reliability (DESIGN.md §15): with a ``failures`` model the event loop gains
a third event source — a pre-materialized, padded failure/repair stream
(``repro.reliability``) consumed through a per-event pointer.  A failure
takes one node out of service until its repair; if the node was busy, the
running job is killed and either *requeues* (re-enters the wait queue at
its submit rank, re-charged for the work since its last checkpoint plus a
restart overhead) or *aborts* (terminates; dependents release with
after-any semantics).  Down nodes are masked out of every placement and
fit check by painting them with an out-of-range owner id at the strategy
call sites — the strategies themselves are untouched.  ``failures=None``
statically elides all of it: ``SimState.rel`` is ``None`` (no leaves, not
zero-size placeholders) and the no-failure executable is HLO-identical to
the pre-reliability engine (fingerprint-tested).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro import alloc as _alloc
from repro.core import policies
from repro.core.jobs import (
    BACKFILL, DONE, FCFS, INF_TIME, LJF, PENDING, PREEMPT, RUNNING, SJF,
    WAITING, JobSet, SimResult, SimState, result_from_state,
)
from repro.malleable.model import make_mal_ctx
from repro.reliability.model import FAIL, REQUEUE, make_fail_ctx
from repro.serving.model import make_svc_ctx

# An allocation context is either None (seed scalar-counter mode) or the
# pytree tuple (machine, strategy_i32, contention); its None-ness is static
# at trace time, so the scalar path compiles with zero allocation overhead.
AllocCtx = tuple

# Policies whose scheduling pass is a blocking head-of-(re)ordered-queue —
# eligible for the batched sort+cumsum pass when known at trace time.
_BLOCKING_POLICIES = (FCFS, SJF, LJF)
# Strategies whose placement-feasibility cap IS the free counter; contiguous
# (largest-free-run cap) and topo keep the per-start loop (DESIGN.md §14).
_COUNT_CAPPED = (_alloc.SIMPLE, _alloc.SPREAD)


def _concrete_int(x) -> Optional[int]:
    """``int(x)`` when ``x`` is concrete at trace time, else ``None``.

    Traced values (vmap sweep axes, jit arguments) return ``None`` — the
    caller falls back to the fully dynamic seed path.
    """
    if x is None:
        return None
    try:
        return int(x)
    except (TypeError, ValueError, jax.errors.ConcretizationTypeError):
        return None


def _static_policy_hint(policy) -> Optional[int]:
    """Concrete policy id clamped to the selector table, or ``None``.

    THE one place the static hint is derived (``simulate`` and
    ``simulate_window`` both call it), mirroring the dynamic path's
    ``jnp.clip(policy, 0, 5)`` so a stray id picks the same selector
    either way.
    """
    p = _concrete_int(policy)
    if p is None:
        return None
    return min(max(p, 0), len(policies.SELECTOR_TABLE) - 1)


def _release_nodes(state_owner: jax.Array, released: jax.Array,
                   capacity: int) -> jax.Array:
    """Free every node whose owning job row is in the ``released`` mask."""
    own = state_owner
    hit = (own >= 0) & released[jnp.clip(own, 0, capacity - 1)]
    return jnp.where(hit, jnp.int32(-1), own)


def _owner_eff(jobs: JobSet, state: SimState) -> jax.Array:
    """The occupancy map as the placement strategies should see it.

    With reliability active, down nodes are painted with the out-of-range
    owner id ``capacity`` — "busy, owned by nobody" — so every existing
    ``owner < 0`` free test and ``owner >= 0`` busy test excludes them
    without touching the strategies (DESIGN.md §15).  The *true*
    ``node_owner`` map (which release scatters read) never holds the
    sentinel, so a down node can never be freed by a job completion.

    The serving autoscaler (DESIGN.md §16) masks scaled-out nodes the
    same way: an offline node is "busy, owned by nobody" to every
    strategy, and since scale-down only ever takes *free* nodes, the true
    ``node_owner`` map never references an offline node either.
    """
    if state.rel is None and state.svc is None:
        return state.node_owner
    own = state.node_owner
    if state.svc is not None:
        own = jnp.where(state.svc.offline, jnp.int32(jobs.capacity), own)
    if state.rel is not None:
        own = jnp.where(state.rel.down, jnp.int32(jobs.capacity), own)
    return own


def _jobs_eff(jobs: JobSet, state: SimState) -> JobSet:
    """The job table as fit checks and node accounting should see it.

    With malleability active, each job's node footprint is its *current
    width* (``min_width`` while waiting — the width a dispatch is
    guaranteed to be able to choose — and the running width thereafter),
    not its rigid request.  The same ``jobs`` object comes back when
    ``state.mal`` is ``None``, so the rigid paths trace unchanged
    (DESIGN.md §17).
    """
    if state.mal is None:
        return jobs
    return dataclasses.replace(jobs, nodes=state.mal.width)


def _ratio_ceil(r: jax.Array, dur_new: jax.Array,
                dur_old: jax.Array) -> jax.Array:
    """``max(1, ceil(r * dur_new / dur_old))`` — the width re-dilation of a
    remaining wall time, in float32 with a pinned operation order
    ``(r * dur_new) / dur_old`` mirrored bit-exactly (np.float32 scalar
    ops, same order) in ``repro.refsim`` (DESIGN.md §17)."""
    v = (r.astype(jnp.float32) * dur_new.astype(jnp.float32)) \
        / dur_old.astype(jnp.float32)
    return jnp.maximum(jnp.ceil(v).astype(jnp.int32), 1)


def _start_job(jobs: JobSet, state: SimState, idx: jax.Array,
               ctx: Optional[AllocCtx],
               mctx: Optional[tuple] = None) -> SimState:
    """Allocate nodes to job ``idx`` and schedule its completion event.

    Uses ``state.remaining`` (== runtime unless previously preempted) and
    records only the FIRST start time (dispatch-latency metric).  With an
    allocation context, concrete nodes are placed by the strategy, the
    occupancy map and allocation fingerprints update, and contention dilates
    the remaining runtime by the allocation's group span.
    """
    start = state.clock
    if state.rel is not None:
        state = dataclasses.replace(
            state, rel=dataclasses.replace(
                state.rel,
                last_start=state.rel.last_start.at[idx].set(start)))
    if mctx is not None:
        # moldable width choice (DESIGN.md §17): among placement-feasible
        # widths pick the minimum dilated duration, ties to the narrowest
        # (argmin returns the first minimum).  The policy admitted this job
        # at its effective (minimum) width, so at least one width fits.
        dur_t, _, _, wlo = mctx[0], mctx[1], mctx[2], mctx[3]
        W = dur_t.shape[1]
        dur_row = dur_t[idx]
        widths = wlo + jnp.arange(W, dtype=jnp.int32)
        if ctx is None:
            cap = state.free
        else:
            cap = _alloc.placeable_cap(ctx[1], _owner_eff(jobs, state))
        k = jnp.argmin(jnp.where(widths <= cap, dur_row,
                                 jnp.int32(INF_TIME))).astype(jnp.int32)
        w = wlo + k
        # fresh dispatch (prev_w == 0 sentinel) reads the dur table exactly;
        # a redispatch after a requeue converts the re-charged remaining
        # (wall units at the pre-kill width) to the new width
        prev = state.mal.prev_w[idx]
        prev_k = jnp.clip(prev - wlo, 0, W - 1)
        dil_rem = jnp.where(
            prev == 0, dur_row[k],
            _ratio_ceil(state.remaining[idx], dur_row[k], dur_row[prev_k]))
        if ctx is not None:
            machine, strategy, _ = ctx
            mask = _alloc.place(strategy, machine, _owner_eff(jobs, state),
                                w)
            span = _alloc.group_span(machine, mask)
            first, asum = _alloc.alloc_fingerprint(mask)
            state = dataclasses.replace(
                state,
                node_owner=jnp.where(mask, idx, state.node_owner),
                alloc_first=state.alloc_first.at[idx].set(first),
                alloc_span=state.alloc_span.at[idx].set(span),
                alloc_sum=state.alloc_sum.at[idx].set(asum),
            )
        m = state.mal
        state = dataclasses.replace(state, mal=dataclasses.replace(
            m,
            width=m.width.at[idx].set(w),
            prev_w=m.prev_w.at[idx].set(w),
            seg_start=m.seg_start.at[idx].set(start),
            disp_dur=m.disp_dur.at[idx].set(dur_row[k]),
        ))
        fin = start + dil_rem
        rsv = start + jobs.estimate[idx]
        first_start = jnp.minimum(state.start[idx], start)
        return dataclasses.replace(
            state,
            jstate=state.jstate.at[idx].set(RUNNING),
            start=state.start.at[idx].set(first_start),
            finish=state.finish.at[idx].set(fin),
            rsv_finish=state.rsv_finish.at[idx].set(rsv),
            free=state.free - w,
        )
    if ctx is None:
        dil_rem = state.remaining[idx]
    else:
        machine, strategy, con = ctx
        mask = _alloc.place(strategy, machine, _owner_eff(jobs, state),
                            jobs.nodes[idx])
        span = _alloc.group_span(machine, mask)
        first, asum = _alloc.alloc_fingerprint(mask)
        dil_rem = _alloc.dilate(con, state.remaining[idx], span)
        state = dataclasses.replace(
            state,
            node_owner=jnp.where(mask, idx, state.node_owner),
            alloc_first=state.alloc_first.at[idx].set(first),
            alloc_span=state.alloc_span.at[idx].set(span),
            alloc_sum=state.alloc_sum.at[idx].set(asum),
        )
    fin = start + dil_rem
    rsv = start + jobs.estimate[idx]
    first_start = jnp.minimum(state.start[idx], start)
    return dataclasses.replace(
        state,
        jstate=state.jstate.at[idx].set(RUNNING),
        start=state.start.at[idx].set(first_start),
        finish=state.finish.at[idx].set(fin),
        rsv_finish=state.rsv_finish.at[idx].set(rsv),
        free=state.free - jobs.nodes[idx],
    )


def _preempt_for(jobs: JobSet, state: SimState, idx: jax.Array,
                 ctx: Optional[AllocCtx]) -> SimState:
    """Suspend the minimal set of strictly-lower-priority running jobs so
    that job ``idx`` fits (paper §5 future work: preemption capability).

    Victims are chosen most-preemptible-first: (priority desc, row desc).
    Suspended jobs keep their elapsed work (remaining shrinks) and return to
    WAITING with their original submit time/FCFS rank.  Victims release
    their concrete nodes; the reclaim test is free-count based, so under the
    ``contiguous`` strategy the subsequent placement may fall back to
    scattered first-fit (DESIGN.md §11.2).
    """
    J = jobs.capacity
    need = jobs.nodes[idx] - state.free
    running = state.jstate == RUNNING
    lower = running & (jobs.priority > jobs.priority[idx])
    # order victims by (priority desc, row desc) via a two-stage
    # lexicographic sort — the packed key ``-(priority*J + row)`` the seed
    # engine used overflows int32 for priorities near INF_TIME (mirrors
    # select_preempt's two-stage argmin; non-victims sort last)
    rows = jnp.arange(J, dtype=jnp.int32)
    big = jnp.int32(INF_TIME)
    order = jnp.lexsort((jnp.where(lower, -rows, big),
                         jnp.where(lower, -jobs.priority, big)))
    nodes_o = jnp.where(lower, jobs.nodes, 0)[order]
    cum = jnp.cumsum(nodes_o)
    # preempt the minimal prefix whose cumulative nodes cover the deficit
    take_rank = jnp.where(cum - nodes_o < jnp.maximum(need, 0), True, False)
    take_rank = take_rank & (nodes_o > 0)
    victim = jnp.zeros((J,), bool).at[order].set(take_rank)
    freed = jnp.sum(jnp.where(victim, jobs.nodes, 0)).astype(jnp.int32)
    new_remaining = jnp.where(
        victim, jnp.maximum(state.finish - state.clock, 1), state.remaining
    )
    node_owner = (state.node_owner if ctx is None
                  else _release_nodes(state.node_owner, victim, J))
    return dataclasses.replace(
        state,
        jstate=jnp.where(victim, WAITING, state.jstate),
        finish=jnp.where(victim, INF_TIME, state.finish),
        rsv_finish=jnp.where(victim, INF_TIME, state.rsv_finish),
        remaining=new_remaining,
        free=state.free + freed,
        node_owner=node_owner,
    )


def _select(policy: jax.Array, jobs: JobSet, state: SimState,
            ctx: Optional[AllocCtx],
            static_policy: Optional[int] = None) -> jax.Array:
    """Policy selection under the active allocation feasibility cap.

    Policies read node requests through the effective-jobs view: with
    malleability active a waiting job asks for its *minimum* width (any
    admitted job is guaranteed a feasible dispatch width) and a running
    job occupies its current width (backfill's shadow math)."""
    cap = (state.free if ctx is None
           else _alloc.placeable_cap(ctx[1], _owner_eff(jobs, state)))
    return policies.select(policy, _jobs_eff(jobs, state), state, cap,
                           static_policy=static_policy)


def blocking_order(jobs: JobSet, static_policy: int) -> jax.Array:
    """Loop-invariant queue permutation for a blocking policy.

    The blocking policies key on ``submit``/``estimate``/``-estimate``,
    all invariant for the lifetime of a ``simulate`` (or window) call — so
    the (key, row) sort the batched pass needs is computed ONCE per call,
    outside the event loop, not once per event (stable sort ⇒ ties break
    by row, matching ``_lex_argmin``).  Backfill's blocking phase is FCFS
    (the EASY head keys on ``submit``), so it shares the FCFS permutation.
    """
    key = {FCFS: jobs.submit, SJF: jobs.estimate,
           LJF: -jobs.estimate, BACKFILL: jobs.submit}[static_policy]
    return jnp.argsort(key, stable=True)


def _batched_pass(jobs: JobSet, state: SimState, ctx: Optional[AllocCtx],
                  order: jax.Array) -> SimState:
    """Start the whole feasible prefix of the waiting queue in one shot.

    For a blocking head-of-queue policy with a free-counter feasibility cap,
    the sequential pass is: walk waiting jobs in policy-key order, start
    each while it still fits, stop at the first that does not.  Node counts
    are >= 1, so the started set is exactly the longest key-ordered waiting
    prefix whose node cumsum stays <= free — one O(J) cumsum over the
    precomputed ``blocking_order`` permutation replaces the whole
    select-one-start-one loop (DESIGN.md §14), bit-identical to it by
    construction.  (Non-waiting rows contribute zero to the cumsum, and the
    cumsum strictly increases across waiting rows, so masking with the
    waiting flag yields exactly the sequential prefix.)  The starts are
    then applied selector-free, in key order; with a count-capped strategy
    the same loop additionally runs each job's node placement.
    """
    waiting = state.jstate == WAITING
    w_sorted = waiting[order]
    cum = jnp.cumsum(jnp.where(w_sorted, jobs.nodes[order], 0))
    take = (cum <= state.free) & w_sorted     # longest feasible prefix
    n_take = jnp.cumsum(take.astype(jnp.int32))
    n_started = n_take[-1]

    # Apply the starts one row at a time: the i-th started row is found by
    # binary search on the running take-count (scatter-free compaction),
    # and each start is a handful of single-element in-place updates — far
    # cheaper on XLA:CPU than rewriting four J-sized arrays with masked
    # `where`s on every event.
    if ctx is not None:
        # allocation mode: placements mutate the node map, so reuse the
        # full `_start_job` (the fori carries the whole state)
        def place(i, st):
            pos = jnp.searchsorted(n_take, i + 1)
            return _start_job(jobs, st, order[pos], ctx)

        return jax.lax.fori_loop(0, n_started, place, state)

    # scalar-counter mode: carry ONLY the five leaves a start touches —
    # XLA copies every carried buffer at the loop boundary per event, so a
    # full-state carry would tax the (common) zero-start event with ~10
    # J-sized copies and halve trickle-workload throughput
    if state.rel is not None:
        # reliability adds exactly one more leaf: the checkpoint base
        # ``last_start`` every dispatch must stamp (DESIGN.md §15)
        def place_slim_rel(i, carry):
            jstate, start, finish, rsv, free, last = carry
            pos = jnp.searchsorted(n_take, i + 1)
            idx = order[pos]
            t0 = state.clock
            return (
                jstate.at[idx].set(RUNNING),
                start.at[idx].set(jnp.minimum(start[idx], t0)),
                finish.at[idx].set(t0 + state.remaining[idx]),
                rsv.at[idx].set(t0 + jobs.estimate[idx]),
                free - jobs.nodes[idx],
                last.at[idx].set(t0),
            )

        jstate, start, finish, rsv, free, last = jax.lax.fori_loop(
            0, n_started, place_slim_rel,
            (state.jstate, state.start, state.finish, state.rsv_finish,
             state.free, state.rel.last_start),
        )
        return dataclasses.replace(
            state, jstate=jstate, start=start, finish=finish,
            rsv_finish=rsv, free=free,
            rel=dataclasses.replace(state.rel, last_start=last))

    def place_slim(i, carry):
        jstate, start, finish, rsv, free = carry
        pos = jnp.searchsorted(n_take, i + 1)
        idx = order[pos]
        t0 = state.clock
        return (
            jstate.at[idx].set(RUNNING),
            start.at[idx].set(jnp.minimum(start[idx], t0)),
            finish.at[idx].set(t0 + state.remaining[idx]),
            rsv.at[idx].set(t0 + jobs.estimate[idx]),
            free - jobs.nodes[idx],
        )

    jstate, start, finish, rsv, free = jax.lax.fori_loop(
        0, n_started, place_slim,
        (state.jstate, state.start, state.finish, state.rsv_finish,
         state.free),
    )
    return dataclasses.replace(
        state, jstate=jstate, start=start, finish=finish, rsv_finish=rsv,
        free=free)


def _batched_backfill_pass(jobs: JobSet, state: SimState,
                           ctx: Optional[AllocCtx],
                           order: jax.Array) -> SimState:
    """One whole EASY-backfill scheduling pass per event (DESIGN.md §18).

    Phase A — the blocking prefix: EASY starts the FCFS head while it
    fits, which is exactly the blocking batched pass over the submit-order
    permutation.  Phase B — the backfill window: once the head blocks, its
    shadow reservation is computed ONCE.  The shadow TIME is loop-invariant
    under admissions (DESIGN.md §18 proves it from the count-capped premise
    ``free < head_need``), and the ``extra`` budget follows a one-line
    lexicographic rule against the reach entry, so candidates are admitted
    under the shrinking (free, extra) budget without re-sorting anything.
    The admitted set is NOT a prefix of the queue (first-fit skips
    infeasible candidates), so phase B keeps a short while_loop — but each
    iteration is one masked O(J) argmin, and the per-select top-k/sort of
    the seed loop is gone.  Bit-identical to the seed selector loop by the
    invariance argument; the differential grids in
    ``tests/test_engine_fastpath.py`` pin it against refsim.
    """
    # Phase A — the FCFS prefix — runs only when the head can actually
    # start: on trickle workloads most events arrive with the head still
    # blocked, and the prefix machinery (sorted gather + two cumsums)
    # would tax every one of them for zero starts.  The head-fits test is
    # free-count based, which IS the placement cap on every phase-B
    # eligible path (count-capped premise, DESIGN.md §18).
    waiting0 = state.jstate == WAITING
    head0 = policies.lex_argmin(jobs.submit, waiting0)
    head0_fits = ((head0 >= 0)
                  & (jobs.nodes[jnp.maximum(head0, 0)] <= state.free))

    def _phase_a(st: SimState) -> tuple[SimState, jax.Array]:
        st = _batched_pass(jobs, st, ctx, order)
        return st, policies.lex_argmin(jobs.submit, st.jstate == WAITING)

    state, head = jax.lax.cond(head0_fits, _phase_a,
                               lambda st: (st, head0), state)
    head_safe = jnp.maximum(head, 0)
    head_need = jobs.nodes[head_safe]
    idxs = jnp.arange(jobs.capacity, dtype=jnp.int32)
    waiting = state.jstate == WAITING
    # necessary condition for ANY admission: some non-head waiting job fits
    # the free count.  Checking it first (~2 O(J) passes) skips the shadow
    # walk and the guaranteed-failing pick on the frequent backlogged
    # events where nothing fits — the single biggest per-event saving on
    # congested traces.
    any_fit = jnp.any(waiting & (idxs != head_safe)
                      & (jobs.nodes <= state.free))

    def window(st: SimState) -> SimState:
        shadow, extra0, k_row0 = policies.backfill_shadow(jobs, st,
                                                          head_need)
        # release times and estimates are fixed within the event, so each
        # candidate's ends-by-shadow verdict is loop-invariant too
        ends_by = (st.clock + jobs.estimate) <= shadow

        def pick(jstate, free, extra):
            # fits-now compares against the free *count*: phase B is only
            # reached with a count-capped (or scalar) feasibility cap,
            # where ``placeable_cap == state.free`` (same invariant the
            # blocking batched pass rests on)
            cand = ((jstate == WAITING) & (idxs != head_safe)
                    & (jobs.nodes <= free)
                    & (ends_by | (jobs.nodes <= jnp.minimum(free, extra))))
            return policies.lex_argmin(jobs.submit, cand)

        def cond(carry):
            return carry[3] >= 0

        def body(carry):
            st, extra, k_row, idx = carry
            st = _start_job(jobs, st, idx, ctx)
            # the admission consumed reserve nodes iff its release entry
            # (clamped time, row) sorts after the reach entry — a release
            # tie at the shadow breaks by row, exactly like the rel sort
            t_c = jnp.maximum(st.clock + jobs.estimate[idx], st.clock + 1)
            after = (t_c > shadow) | ((t_c == shadow) & (idx > k_row))
            extra = extra - jnp.where(after, jobs.nodes[idx], 0)
            # overdraw (reachable only on a release tie at the shadow, via
            # an ends-by admission wider than the reserve): the reach entry
            # moved within the tie group — recompute it.  While-guarded so
            # the rare case costs nothing under vmap; the shadow time is
            # unchanged by §18, only (extra, k_row) refresh.
            def _redo(carry):
                _sh, ex2, kr2 = policies.backfill_shadow(jobs, st,
                                                         head_need)
                return jnp.bool_(True), ex2, kr2

            _, extra, k_row = jax.lax.while_loop(
                lambda c: ~c[0], _redo, (extra >= 0, extra, k_row))
            return st, extra, k_row, pick(st.jstate, st.free, extra)

        st, _, _, _ = jax.lax.while_loop(
            cond, body,
            (st, extra0, k_row0, pick(st.jstate, st.free, extra0)))
        return st

    # with no waiting head there is nothing to backfill against (a head
    # that still fits cannot exist after phase A), and with no fitting
    # candidate there is nothing the window could admit
    return jax.lax.cond((head >= 0) & any_fit, window, lambda s: s, state)


def _schedule_pass(policy: jax.Array, jobs: JobSet, state: SimState,
                   ctx: Optional[AllocCtx],
                   static_policy: Optional[int] = None,
                   fast_order: Optional[jax.Array] = None,
                   mctx: Optional[tuple] = None) -> SimState:
    """Start jobs until the policy blocks (Algorithm 1 lines 16-21).

    Dispatches *at trace time* between the batched prefix pass (when the
    caller precomputed a ``blocking_order`` permutation) and the per-start
    selector loop — a traced policy (``static_policy is None``) always
    compiles the seed loop, so vmapped sweeps pay nothing extra.
    """
    if fast_order is not None:
        if static_policy == BACKFILL:
            return _batched_backfill_pass(jobs, state, ctx, fast_order)
        return _batched_pass(jobs, state, ctx, fast_order)

    def cond(carry):
        _, idx = carry
        return idx >= 0

    def body(carry):
        st, idx = carry
        if static_policy is None or static_policy == PREEMPT:
            # the preempt guard reads the effective node request — with
            # malleability a selected job always fits at its minimum width,
            # so the preempt branch never fires (and the preempt policy
            # itself is rejected with malleable= at the API layer)
            need = (jobs.nodes[idx] if mctx is None
                    else st.mal.width[idx])
            st = jax.lax.cond(
                need <= st.free,
                lambda s: s,
                lambda s: _preempt_for(jobs, s, idx, ctx),  # preempt only
                st,
            )
        st = _start_job(jobs, st, idx, ctx, mctx)
        return st, _select(policy, jobs, st, ctx, static_policy)

    state, _ = jax.lax.while_loop(
        cond, body, (state, _select(policy, jobs, state, ctx, static_policy))
    )
    return state


def dep_csr(jobs: JobSet) -> Optional[tuple]:
    """Loop-invariant CSR row bounds over the (dst-sorted) edge list.

    ``dep_dst`` is emitted dst-ascending by ``make_jobset`` with padding
    (index ``capacity``) at the tail, so per-row edge ranges are two
    ``searchsorted`` arrays computed once per ``simulate`` call.  The event
    loop then updates ``n_unmet`` with gathers + one cumsum instead of an
    E-sized scatter-add (~100x cheaper on XLA:CPU; padding edges sit past
    every row's range and drop out for free).  Returns ``None`` for
    edge-free tables.  Callers whose edge lists may have lost dst order
    (multicluster windows after defensive edge neutralization) must keep
    the scatter-add fallback.
    """
    if jobs.dep_dst is None:
        return None
    J = jobs.capacity
    rows = jnp.arange(J + 1, dtype=jobs.dep_dst.dtype)
    bounds = jnp.searchsorted(jobs.dep_dst, rows)
    return bounds[:-1], bounds[1:]


def _process_rel_events(jobs: JobSet, state: SimState,
                        ctx: Optional[AllocCtx], rel: tuple,
                        mctx: Optional[tuple] = None) -> SimState:
    """Consume every failure/repair stream entry with time <= clock.

    Entries are processed one at a time in stream order (an inner
    ``while_loop`` over the pointer) because each kill changes the running
    set the next kill's victim rule reads.  Semantics, pinned identically
    in ``repro.refsim`` (DESIGN.md §15):

    - *fail* in machine mode: node ``ev_node`` goes down; if it was owned
      by a job, that job is the victim.  In scalar-counter mode nodes are
      anonymous: with ``busy`` running node-seconds and ``n_up`` nodes in
      service, slot ``ev_node % n_up`` hits a running job iff it lands in
      ``[0, busy)`` (utilization-proportional), and the victim is the job
      covering the slot in row-order node cumsum.
    - victim *requeue*: back to WAITING at its submit rank, remaining
      re-charged by the work since its last checkpoint (all of it when
      ``checkpoint_interval == 0``) plus the restart overhead.
    - victim *abort*: DONE + ``aborted``; ``finish`` records the kill
      time, and dependents release (after-any), so DAGs never deadlock.
    - *repair*: the node returns to service.

    The per-node renewal construction guarantees a node never fails while
    down; the machine-mode guards (``down[node]``) only make the
    semantics total under hand-built streams.

    With an *elastic* malleable plan (DESIGN.md §17), a failure whose
    victim still has width to give (``width > min_width``) sheds exactly
    the failed node instead of dying: the job keeps its other nodes and
    its elapsed work, its remaining wall time re-dilates to the narrower
    width, and ``n_resizes`` ticks up.  At ``width == min_width`` the
    normal requeue/abort semantics apply (a requeue resets the width to
    ``min_width`` but remembers the pre-kill width, the basis of the
    redispatch re-dilation).
    """
    ev_time, ev_node, ev_kind, requeue, ckpt, overhead = rel
    K = ev_time.shape[0]
    J = jobs.capacity
    # static: elastic malleability (tick stream present) enables the
    # shrink-instead-of-requeue path; moldable plans keep rigid kills
    mal_shrink = mctx is not None and mctx[2].shape[0] > 0
    # A finished simulation never needs its remaining stream entries — and
    # under vmap this guard is load-bearing: a batched while_loop keeps
    # executing (and discarding) finished members' bodies, and without it a
    # done member whose clock snaps to its leftover stream tail re-drains
    # that whole tail on EVERY lockstep iteration (measured 50-100x on
    # heterogeneous-MTBF sweeps; live members always pass the guard, so
    # semantics are untouched).
    live = jnp.any(state.jstate != DONE)

    def cond(st: SimState):
        p = st.rel.ptr
        return (p < K) & (ev_time[jnp.minimum(p, K - 1)] <= st.clock) & live

    def body(st: SimState) -> SimState:
        r = st.rel
        e = jnp.minimum(r.ptr, K - 1)
        node = ev_node[e]
        is_fail = ev_kind[e] == FAIL
        eff_nodes = jobs.nodes if mctx is None else st.mal.width

        if ctx is None:
            runn = st.jstate == RUNNING
            rn = jnp.where(runn, eff_nodes, 0)
            busy = jnp.sum(rn)
            n_up = st.free + busy
            slot = node % jnp.maximum(n_up, 1)
            cum = jnp.cumsum(rn)
            victim = jnp.argmax(cum > slot).astype(jnp.int32)
            has_victim = is_fail & (slot < busy)
            goes_down = is_fail
            comes_up = ~is_fail
            new_down = r.down                     # [0] placeholder
        else:
            own = st.node_owner[node]
            was_down = r.down[node]
            has_victim = is_fail & (own >= 0) & ~was_down
            victim = jnp.maximum(own, 0)
            goes_down = is_fail & ~was_down
            comes_up = ~is_fail & was_down
            new_down = r.down.at[node].set(is_fail)

        # failure-shrink (elastic malleability only): a victim with width
        # to give sheds the failed node instead of dying
        w_v = eff_nodes[victim]
        if mal_shrink:
            wlo = mctx[3]
            shrink = has_victim & (w_v > wlo)
            kill = has_victim & ~shrink
        else:
            shrink = jnp.asarray(False)
            kill = has_victim

        # checkpoint rework: work since the last checkpoint (the whole run
        # when ckpt == 0) is lost and re-charged on requeue; remaining is
        # in the same post-dilation units preemption pins (DESIGN.md §11)
        el = st.clock - r.last_start[victim]
        saved = jnp.where(ckpt > 0, (el // jnp.maximum(ckpt, 1)) * ckpt, 0)
        lost = el - saved
        req = requeue == REQUEUE
        kill_req = kill & req
        kill_abort = kill & ~req
        new_rem = jnp.maximum(st.finish[victim] - st.clock + lost + overhead,
                              1)

        jstate = st.jstate.at[victim].set(jnp.where(
            kill,
            jnp.where(req, jnp.int32(WAITING), jnp.int32(DONE)),
            st.jstate[victim]))
        finish = st.finish.at[victim].set(jnp.where(
            kill, jnp.where(req, jnp.int32(INF_TIME), st.clock),
            st.finish[victim]))
        rsv = st.rsv_finish.at[victim].set(jnp.where(
            kill, jnp.int32(INF_TIME), st.rsv_finish[victim]))
        remaining = st.remaining.at[victim].set(jnp.where(
            kill_req, new_rem, st.remaining[victim]))
        n_restarts = r.n_restarts.at[victim].add(kill_req.astype(jnp.int32))
        lost_work = r.lost_work.at[victim].add(jnp.where(
            kill_req, lost + overhead, jnp.where(kill_abort, el, 0)))
        aborted = r.aborted.at[victim].set(kill_abort | r.aborted[victim])

        n_unmet = st.n_unmet
        if jobs.dep_dst is not None:
            dec = ((jobs.dep_src == victim) & kill_abort).astype(jnp.int32)
            n_unmet = n_unmet.at[jobs.dep_dst].add(-dec, mode="drop")

        # a kill frees the victim's whole (effective) footprint; a shrink
        # frees exactly the failed node — which then immediately goes down,
        # so the free counter nets zero on a shrink
        freed = jnp.where(kill, w_v, jnp.where(shrink, 1, 0))
        free = (st.free + freed - goes_down.astype(jnp.int32)
                + comes_up.astype(jnp.int32))

        node_owner = st.node_owner
        if ctx is not None:
            vmask = jnp.zeros((J,), bool).at[victim].set(kill)
            node_owner = _release_nodes(st.node_owner, vmask, J)
            if mal_shrink:
                # the shrink releases the failed node specifically
                node_owner = node_owner.at[node].set(jnp.where(
                    shrink, jnp.int32(-1), node_owner[node]))

        if mal_shrink:
            W = mctx[0].shape[1]
            k_old = jnp.clip(w_v - wlo, 0, W - 1)
            k_new = jnp.clip(w_v - 1 - wlo, 0, W - 1)
            sh_rem = _ratio_ceil(st.finish[victim] - st.clock,
                                 mctx[0][victim, k_new],
                                 mctx[0][victim, k_old])
            finish = finish.at[victim].set(jnp.where(
                shrink, st.clock + sh_rem, finish[victim]))
            if ctx is not None:
                own_mask = node_owner == victim
                s_first, s_asum = _alloc.alloc_fingerprint(own_mask)
                s_span = _alloc.group_span(ctx[0], own_mask)
                st = dataclasses.replace(
                    st,
                    alloc_first=st.alloc_first.at[victim].set(jnp.where(
                        shrink, s_first, st.alloc_first[victim])),
                    alloc_span=st.alloc_span.at[victim].set(jnp.where(
                        shrink, s_span, st.alloc_span[victim])),
                    alloc_sum=st.alloc_sum.at[victim].set(jnp.where(
                        shrink, s_asum, st.alloc_sum[victim])),
                )

        mal = st.mal
        if mctx is not None:
            m = st.mal
            touched = kill | shrink
            closed = jnp.where(touched,
                               w_v * (st.clock - m.seg_start[victim]), 0)
            new_w = jnp.where(shrink, w_v - 1,
                              jnp.where(kill_req, mctx[3], w_v))
            mal = dataclasses.replace(
                m,
                width=m.width.at[victim].set(jnp.where(
                    touched, new_w, m.width[victim])),
                prev_w=m.prev_w.at[victim].set(jnp.where(
                    shrink, new_w, m.prev_w[victim])),
                seg_start=m.seg_start.at[victim].set(jnp.where(
                    shrink, st.clock, m.seg_start[victim])),
                node_s=m.node_s.at[victim].add(closed),
                n_resizes=m.n_resizes.at[victim].add(
                    shrink.astype(jnp.int32)),
            )

        new_rel = dataclasses.replace(
            r, ptr=r.ptr + 1,
            n_restarts=n_restarts, lost_work=lost_work, aborted=aborted,
            down=new_down)
        return dataclasses.replace(
            st, jstate=jstate, finish=finish, rsv_finish=rsv,
            remaining=remaining, n_unmet=n_unmet, free=free,
            node_owner=node_owner, rel=new_rel, mal=mal)

    return jax.lax.while_loop(cond, body, state)


def _process_capacity_ticks(jobs: JobSet, state: SimState,
                            ctx: Optional[AllocCtx], svc: tuple) -> SimState:
    """Consume every autoscaler tick with time <= clock (DESIGN.md §16).

    Ticks are processed one at a time in stream order (an inner
    ``while_loop`` over the pointer) because each tick's capacity change
    feeds the next tick's bounds.  Semantics, pinned identically in
    ``repro.refsim``:

    - queued demand is the node-request sum over WAITING jobs (this
      event's arrivals have NOT happened yet — capacity ticks run after
      completions and reliability entries, before arrivals);
    - demand >= up_threshold: up to ``step`` nodes come back online,
      never beyond ``max_nodes`` (pre-clamped to the machine size).  In
      machine mode the *lowest-index* offline nodes return;
    - else if demand <= down_threshold: up to ``step`` nodes go offline,
      never below ``min_nodes`` and never more than the free count — a
      busy node is never taken, so a running job is never stranded (drain
      semantics: capacity leaves only as it frees up).  In machine mode
      the *highest-index* free online nodes leave;
    - the online count after the tick is logged to ``cap_online[ptr]``
      (the capacity series goodput-under-autoscaling integrates).
    """
    deadline, tick_time, up_t, down_t, step, min_n, max_n = svc
    T = tick_time.shape[0]
    # same vmap liveness guard as the reliability stream: a finished batch
    # member must not re-drain its leftover tick tail every lockstep
    # iteration (and a finished simulation needs no capacity changes)
    live = jnp.any(state.jstate != DONE)

    def cond(st: SimState):
        p = st.svc.ptr
        return (p < T) & (tick_time[jnp.minimum(p, T - 1)] <= st.clock) & live

    def body(st: SimState) -> SimState:
        s = st.svc
        demand = jnp.sum(jnp.where(st.jstate == WAITING,
                                   _jobs_eff(jobs, st).nodes, 0))
        up = demand >= up_t
        down = ~up & (demand <= down_t)
        k_up = jnp.where(up, jnp.clip(max_n - s.n_online, 0, step), 0)
        k_down = jnp.where(
            down,
            jnp.minimum(jnp.clip(s.n_online - min_n, 0, step),
                        jnp.maximum(st.free, 0)),
            0)
        delta = (k_up - k_down).astype(jnp.int32)
        if ctx is None:
            offline = s.offline               # [0] placeholder
        else:
            # scale-up reactivates the lowest-index offline nodes;
            # scale-down deactivates the highest-index FREE online nodes
            # (cumsum rank masks; k_down <= free so enough candidates)
            on_rank = jnp.cumsum(s.offline.astype(jnp.int32))
            react = s.offline & (on_rank <= k_up)
            free_node = (st.node_owner < 0) & ~s.offline
            down_rank = jnp.cumsum(free_node[::-1].astype(jnp.int32))[::-1]
            deact = free_node & (down_rank <= k_down)
            offline = (s.offline & ~react) | deact
        n_online = s.n_online + delta
        new_svc = dataclasses.replace(
            s, ptr=s.ptr + 1, n_online=n_online, offline=offline,
            cap_online=s.cap_online.at[s.ptr].set(n_online, mode="drop"))
        return dataclasses.replace(st, free=st.free + delta, svc=new_svc)

    return jax.lax.while_loop(cond, body, state)


def _process_mal_ticks(jobs: JobSet, state: SimState,
                       ctx: Optional[AllocCtx], mctx: tuple) -> SimState:
    """Consume every elastic resize tick with time <= clock (DESIGN.md §17).

    Ticks are processed one at a time in stream order (an inner
    ``while_loop`` over the pointer) because each resize changes the
    widths the next tick's demand and candidate rules read.  Semantics,
    pinned identically in ``repro.refsim`` — at most ONE resize action per
    tick:

    - queued demand is the effective-width sum over WAITING jobs (this
      event's arrivals have NOT happened yet — resize ticks run after
      completions, reliability entries and capacity ticks, before
      arrivals);
    - demand >= shrink_threshold: the *widest* running job above
      ``min_width`` (ties to the lowest row) sheds
      ``min(step, width - min_width)`` nodes, freeing room for the queue.
      In machine mode its *highest-index* owned nodes release;
    - else if demand <= grow_threshold: the *narrowest* running job below
      ``max_width`` (ties to the lowest row) grows by ``min(step,
      max_width - width, cap)`` where ``cap`` is the placement-feasibility
      cap (the free counter, or the strategy's placeable cap in machine
      mode; no action when the cap is 0).  In machine mode the new nodes
      place via the active strategy over ``owner_eff``;
    - either action closes the job's node-second segment, re-dilates its
      remaining wall time to the new width (``_ratio_ceil``), restamps its
      finish event, and recomputes its allocation fingerprints.
    """
    dur_t, _, tick_time, wlo, whi, step = mctx[0], mctx[1], mctx[2], \
        mctx[3], mctx[4], mctx[5]
    shrink_t, grow_t = mctx[6], mctx[7]
    T = tick_time.shape[0]
    W = dur_t.shape[1]
    # same vmap liveness guard as the reliability/capacity streams
    live = jnp.any(state.jstate != DONE)

    def cond(st: SimState):
        p = st.mal.ptr
        return (p < T) & (tick_time[jnp.minimum(p, T - 1)] <= st.clock) & live

    def body(st: SimState) -> SimState:
        m = st.mal
        running = st.jstate == RUNNING
        demand = jnp.sum(jnp.where(st.jstate == WAITING, m.width, 0))
        shrink_tick = demand >= shrink_t
        grow_tick = ~shrink_tick & (demand <= grow_t)
        # shrink: widest running above min_width; grow: narrowest running
        # below max_width — both tie to the lowest row (first argext)
        s_cand = running & (m.width > wlo)
        g_cand = running & (m.width < whi)
        s_vic = jnp.argmax(jnp.where(s_cand, m.width, -1)).astype(jnp.int32)
        g_vic = jnp.argmin(jnp.where(g_cand, m.width,
                                     jnp.int32(INF_TIME))).astype(jnp.int32)
        do_shrink = shrink_tick & jnp.any(s_cand)
        vic = jnp.where(do_shrink, s_vic, g_vic)
        w_v = m.width[vic]
        if ctx is None:
            gcap = jnp.maximum(st.free, 0)
        else:
            gcap = _alloc.placeable_cap(ctx[1], _owner_eff(jobs, st))
        d_grow = jnp.minimum(jnp.minimum(step, whi - w_v), gcap)
        do_grow = grow_tick & jnp.any(g_cand) & (d_grow > 0)
        resize = do_shrink | do_grow
        d = jnp.where(do_shrink, jnp.minimum(step, w_v - wlo),
                      jnp.where(do_grow, d_grow, 0))
        new_w = jnp.where(do_shrink, w_v - d, w_v + d)

        # remaining wall time re-dilates to the new width
        k_old = jnp.clip(w_v - wlo, 0, W - 1)
        k_new = jnp.clip(new_w - wlo, 0, W - 1)
        new_r = _ratio_ceil(st.finish[vic] - st.clock,
                            dur_t[vic, k_new], dur_t[vic, k_old])
        finish = st.finish.at[vic].set(jnp.where(
            resize, st.clock + new_r, st.finish[vic]))
        free = st.free + jnp.where(do_shrink, d,
                                   jnp.where(do_grow, -d, 0))

        node_owner = st.node_owner
        alloc_first, alloc_span, alloc_sum = (
            st.alloc_first, st.alloc_span, st.alloc_sum)
        if ctx is not None:
            machine, strategy, _ = ctx
            own_mask = st.node_owner == vic
            # shrink releases the d highest-index owned nodes
            shed_rank = jnp.cumsum(
                own_mask[::-1].astype(jnp.int32))[::-1]
            shed = own_mask & (shed_rank <= jnp.where(do_shrink, d, 0))
            # grow places d new nodes via the strategy over owner_eff
            add = _alloc.place(strategy, machine, _owner_eff(jobs, st),
                               jnp.where(do_grow, d, 0))
            node_owner = jnp.where(shed, jnp.int32(-1), st.node_owner)
            node_owner = jnp.where(add, vic, node_owner)
            mask_new = node_owner == vic
            n_first, n_asum = _alloc.alloc_fingerprint(mask_new)
            n_span = _alloc.group_span(machine, mask_new)
            alloc_first = st.alloc_first.at[vic].set(jnp.where(
                resize, n_first, st.alloc_first[vic]))
            alloc_span = st.alloc_span.at[vic].set(jnp.where(
                resize, n_span, st.alloc_span[vic]))
            alloc_sum = st.alloc_sum.at[vic].set(jnp.where(
                resize, n_asum, st.alloc_sum[vic]))

        closed = jnp.where(resize, w_v * (st.clock - m.seg_start[vic]), 0)
        new_mal = dataclasses.replace(
            m, ptr=m.ptr + 1,
            width=m.width.at[vic].set(jnp.where(resize, new_w, w_v)),
            prev_w=m.prev_w.at[vic].set(jnp.where(
                resize, new_w, m.prev_w[vic])),
            seg_start=m.seg_start.at[vic].set(jnp.where(
                resize, st.clock, m.seg_start[vic])),
            node_s=m.node_s.at[vic].add(closed),
            n_resizes=m.n_resizes.at[vic].add(resize.astype(jnp.int32)))
        return dataclasses.replace(
            st, finish=finish, free=free, node_owner=node_owner,
            alloc_first=alloc_first, alloc_span=alloc_span,
            alloc_sum=alloc_sum, mal=new_mal)

    return jax.lax.while_loop(cond, body, state)


def _event_step(policy: jax.Array, jobs: JobSet, state: SimState,
                ctx: Optional[AllocCtx] = None,
                static_policy: Optional[int] = None,
                fast_order: Optional[jax.Array] = None,
                csr: Optional[tuple] = None,
                rel: Optional[tuple] = None,
                svc: Optional[tuple] = None,
                mctx: Optional[tuple] = None) -> SimState:
    pending = state.jstate == PENDING
    running = state.jstate == RUNNING
    has_deps = jobs.dep_dst is not None
    mal_ticks = mctx is not None and mctx[2].shape[0] > 0

    # A PENDING job generates an arrival event only once its dependencies
    # are DONE; unreleased dependents are invisible to the clock (and to
    # backfill's shadow math, which never sees them as WAITING).  The
    # pre-completion release mask is the standing counter — no recompute.
    arrivable = pending & (state.n_unmet == 0) if has_deps else pending
    t_arr = jnp.min(jnp.where(arrivable, jobs.submit, INF_TIME))
    t_fin = jnp.min(jnp.where(running, state.finish, INF_TIME))
    clock = jnp.minimum(t_arr, t_fin)
    if rel is not None:
        K = rel[0].shape[0]
        p = state.rel.ptr
        t_rel = jnp.where(p < K, rel[0][jnp.minimum(p, K - 1)],
                          jnp.int32(INF_TIME))
        clock = jnp.minimum(clock, t_rel)
    if svc is not None and svc[1].shape[0] > 0:
        # T == 0 (no autoscaler) statically elides the tick clock source
        T = svc[1].shape[0]
        p = state.svc.ptr
        t_svc = jnp.where(p < T, svc[1][jnp.minimum(p, T - 1)],
                          jnp.int32(INF_TIME))
        clock = jnp.minimum(clock, t_svc)
    if mal_ticks:
        # T == 0 (moldable mode) statically elides the resize clock source
        Tm = mctx[2].shape[0]
        p = state.mal.ptr
        t_mal = jnp.where(p < Tm, mctx[2][jnp.minimum(p, Tm - 1)],
                          jnp.int32(INF_TIME))
        clock = jnp.minimum(clock, t_mal)

    # completions first (frees nodes for arrivals at the same timestamp);
    # with malleability a completing job frees its current width and closes
    # its node-second segment
    completed = running & (state.finish <= clock)
    eff_nodes = jobs.nodes if mctx is None else state.mal.width
    freed = jnp.sum(jnp.where(completed, eff_nodes, 0)).astype(jnp.int32)
    jstate = jnp.where(completed, DONE, state.jstate)
    node_owner = (state.node_owner if ctx is None
                  else _release_nodes(state.node_owner, completed, jobs.capacity))
    mal_after = state.mal
    if mctx is not None:
        closed = jnp.where(completed,
                           state.mal.width * (clock - state.mal.seg_start), 0)
        mal_after = dataclasses.replace(
            state.mal, node_s=state.mal.node_s + closed)

    # arrivals — dependents of this event's completions release *now*
    # (paper §3 release rule): each RUNNING->DONE transition happens exactly
    # once, so decrementing n_unmet along the completing jobs' out-edges
    # keeps the counters exact; a job whose last dependency just finished
    # joins the wait queue in the same event, with ready_time = max(submit,
    # last dep finish).  Padding edges scatter out of range and drop.
    n_unmet = state.n_unmet
    if has_deps:
        J = jobs.capacity
        dec = completed[jnp.clip(jobs.dep_src, 0, J - 1)].astype(jnp.int32)
        if csr is not None:
            row_start, row_end = csr
            c = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                                 jnp.cumsum(dec)])
            n_unmet = n_unmet - (c[row_end] - c[row_start])
        else:
            n_unmet = n_unmet.at[jobs.dep_dst].add(-dec, mode="drop")
    if rel is not None or svc is not None or mal_ticks:
        # stream events run after completions (a job finishing at the
        # failure/tick instant has completed) and before arrivals (a job
        # whose last dependency aborts still releases within this same
        # event; autoscale and resize ticks read queued demand *before*
        # this event's arrivals join the queue) — order: completions,
        # reliability, capacity ticks, resize ticks, arrivals
        state = dataclasses.replace(
            state, clock=clock, jstate=jstate, n_unmet=n_unmet,
            free=state.free + freed, node_owner=node_owner, mal=mal_after)
        if rel is not None:
            state = _process_rel_events(jobs, state, ctx, rel, mctx)
        if svc is not None and svc[1].shape[0] > 0:
            state = _process_capacity_ticks(jobs, state, ctx, svc)
        if mal_ticks:
            state = _process_mal_ticks(jobs, state, ctx, mctx)
        jstate, n_unmet = state.jstate, state.n_unmet
        arrived = (jstate == PENDING) & (jobs.submit <= clock)
        if has_deps:
            arrived = arrived & (n_unmet == 0)
        jstate = jnp.where(arrived, WAITING, jstate)
        state = dataclasses.replace(
            state, jstate=jstate, n_events=state.n_events + 1)
    else:
        arrived = (jstate == PENDING) & (jobs.submit <= clock)
        if has_deps:
            arrived = arrived & (n_unmet == 0)
        jstate = jnp.where(arrived, WAITING, jstate)

        state = dataclasses.replace(
            state,
            clock=clock,
            jstate=jstate,
            n_unmet=n_unmet,
            free=state.free + freed,
            n_events=state.n_events + 1,
            node_owner=node_owner,
            mal=mal_after,
        )
    state = _schedule_pass(policy, jobs, state, ctx, static_policy,
                           fast_order, mctx)
    if ctx is None:
        return state
    # fragmentation log: one (clock, free, largest-free-block) row per event
    slot = state.n_events - 1
    return dataclasses.replace(
        state,
        ev_time=state.ev_time.at[slot].set(state.clock, mode="drop"),
        ev_free=state.ev_free.at[slot].set(state.free, mode="drop"),
        ev_lfb=state.ev_lfb.at[slot].set(
            _alloc.largest_free_run(_owner_eff(jobs, state)), mode="drop"),
    )


def make_alloc_ctx(machine, strategy, contention,
                   total_nodes=None) -> Optional[AllocCtx]:
    """Canonicalize user-facing allocation arguments into an ``AllocCtx``.

    Raises when allocation arguments are inconsistent: ``alloc``/
    ``contention`` without a ``machine`` would be silently ignored, and a
    ``machine`` whose size disagrees with a *concrete* ``total_nodes`` would
    corrupt the occupancy map (a traced ``total_nodes`` skips that check —
    the caller owns it in sweep code).
    """
    if machine is None:
        if strategy is not None or contention is not None:
            raise ValueError(
                "alloc/contention require machine=; without a Machine the "
                "simulation runs in scalar-counter mode and would silently "
                "ignore them")
        return None
    if total_nodes is not None:
        try:
            concrete = int(total_nodes)
        except (TypeError, jax.errors.TracerArrayConversionError,
                jax.errors.ConcretizationTypeError):
            concrete = None
        if concrete is not None and concrete != machine.n_nodes:
            raise ValueError(
                f"machine has {machine.n_nodes} nodes but "
                f"total_nodes={concrete}")
    strategy = jnp.asarray(_alloc.canonical_id(strategy), dtype=jnp.int32)
    return (machine, strategy, _alloc.Contention.canonical(contention))


def simulate(
    jobs: JobSet,
    policy: jax.Array | int,
    total_nodes: jax.Array | int,
    *,
    machine=None,
    alloc: jax.Array | int | str | None = None,
    contention=None,
    failures=None,
    service=None,
    malleable=None,
    max_events: Optional[int] = None,
) -> SimResult:
    """Run the full job-scheduling simulation for one cluster.

    This is the low-level engine call; the declarative front door is
    ``repro.api.run(Scenario(...))``, which builds the job table, machine
    and contention from one spec and returns a unified ``Result``
    (DESIGN.md §12).  Kept stable for callers that already hold a
    ``JobSet``.

    Pure function of its inputs (``policy``, ``total_nodes``, the allocation
    ``alloc`` strategy id and ``contention`` parameters are traced, so the
    same executable serves every policy/machine-size/allocator combination);
    ``vmap``-able over ``jobs`` leaves, ``policy``, ``total_nodes``,
    ``alloc`` and/or ``contention`` for ensemble simulation (see
    ``repro.core.parallel``).

    Without ``machine`` the engine runs in the seed scalar-counter mode.
    With ``machine`` (a ``repro.alloc.Machine`` whose ``n_nodes`` must equal
    ``total_nodes``) each start places concrete nodes under the ``alloc``
    strategy and the result carries allocation fingerprints plus the
    per-event fragmentation log.

    When ``policy`` (and, with a machine, ``alloc``) is concrete at call
    time, the executable specializes on it: the policy selector dispatches
    directly instead of through ``lax.switch``, and the blocking policies
    take the batched scheduling pass (DESIGN.md §14).  Each concrete policy
    then compiles its own executable; traced values (vmap axes) keep the
    shared fully-dynamic executable with seed semantics.

    ``failures`` (None, a ``repro.reliability.FailureModel``, a
    ``FailureTrace``, or a prebuilt fail-ctx tuple) switches on the
    reliability subsystem (DESIGN.md §15); ``None`` statically elides it.

    ``service`` (None, a ``repro.serving.ServiceTrace``, a ``ServicePlan``,
    or a prebuilt svc-ctx tuple) switches on the online-serving subsystem
    (DESIGN.md §16): per-job SLO deadlines in the result and a hysteresis
    autoscaler consuming a deterministic capacity-tick stream.  ``None``
    statically elides it to the pre-serving event graph.

    ``malleable`` (None, a ``repro.malleable.MalleablePlan``, or a prebuilt
    mal-ctx tuple) switches on the malleability subsystem (DESIGN.md §17):
    moldable width choice at dispatch, and — in elastic mode — grow/shrink
    resize ticks plus shrink-instead-of-requeue on node failures.
    ``None`` statically elides it to the rigid event graph.
    """
    ctx = make_alloc_ctx(machine, alloc, contention, total_nodes)
    fctx = make_fail_ctx(failures, n_nodes=_concrete_int(total_nodes))
    sctx = make_svc_ctx(service, n_nodes=_concrete_int(total_nodes))
    mctx = make_mal_ctx(malleable)
    if (ctx is not None and fctx is not None and sctx is not None
            and sctx[1].shape[-1] > 0):
        # the autoscaler's offline mask and the reliability down mask would
        # double-count the shared free counter (a node can be failed and
        # drained at once); scalar-counter mode composes fine
        raise ValueError(
            "machine-mode failures cannot be combined with an active "
            "autoscaler; drop machine=, failures=, or autoscale")
    static_policy = _static_policy_hint(policy)
    if mctx is not None:
        if contention is not None:
            # the speedup curve already maps width to runtime; span-based
            # contention would dilate the dilated value a second time
            raise ValueError(
                "malleable jobs cannot be combined with contention "
                "dilation; the speedup curve owns the width->runtime map")
        if static_policy == PREEMPT:
            raise ValueError(
                "malleable jobs cannot be combined with the preempt "
                "policy; a suspended job's width bookkeeping is undefined")
        if mctx[0].ndim == 2 and mctx[0].shape[0] != jobs.capacity:
            raise ValueError(
                f"malleable plan rows ({mctx[0].shape[0]}) do not match "
                f"the job-table capacity ({jobs.capacity}); materialize "
                "the plan with capacity == the padded job capacity")
    static_strategy = _concrete_int(ctx[1]) if ctx is not None else None
    return _simulate_jit(
        jobs, jnp.asarray(policy, dtype=jnp.int32),
        jnp.asarray(total_nodes, dtype=jnp.int32), ctx, fctx=fctx,
        sctx=sctx, mctx=mctx, max_events=max_events,
        static_policy=static_policy, static_strategy=static_strategy,
    )


@functools.partial(
    jax.jit,
    static_argnames=("max_events", "static_policy", "static_strategy"))
def _simulate_jit(
    jobs: JobSet,
    policy: jax.Array,
    total_nodes: jax.Array,
    ctx: Optional[AllocCtx],
    fctx: Optional[tuple] = None,
    sctx: Optional[tuple] = None,
    mctx: Optional[tuple] = None,
    *,
    max_events: Optional[int] = None,
    static_policy: Optional[int] = None,
    static_strategy: Optional[int] = None,
) -> SimResult:
    if fctx is None:
        base_cap = 6 * jobs.capacity + 8
        rel = None
    else:
        # every failure adds at most one kill (an extra start + completion
        # cycle) and two stream entries, so the event bound grows with the
        # padded failure capacity F — a static shape, known at trace time
        F = fctx[0].shape[-1]
        base_cap = 6 * jobs.capacity + 6 * F + 8
        # one loop-invariant stable merge of the failure + repair streams,
        # pinned identically (host-side) in repro.reliability.merge_stream
        times = jnp.concatenate([fctx[0], fctx[2]])
        nodes = jnp.concatenate([fctx[1], fctx[1]])
        kind = jnp.concatenate([jnp.zeros_like(fctx[1]),
                                jnp.ones_like(fctx[1])])
        order = jnp.argsort(times, stable=True)
        rel = (times[order], nodes[order], kind[order],
               fctx[3], fctx[4], fctx[5])
    if sctx is None:
        svc = None
        svc_T = None
    else:
        # each capacity tick consumes exactly one event; T is static
        svc_T = sctx[1].shape[-1]
        base_cap = base_cap + svc_T
        # clamp max_nodes to the actual cluster size here (total_nodes may
        # be traced, so the spec layer cannot always do it)
        svc = sctx[:6] + (
            jnp.minimum(sctx[6], jnp.asarray(total_nodes, jnp.int32)),)
    if mctx is not None:
        # each elastic resize tick consumes exactly one event; the tick
        # capacity is a static shape (0 in moldable mode)
        base_cap = base_cap + mctx[2].shape[-1]
    cap = max_events if max_events is not None else base_cap
    machine = ctx[0] if ctx is not None else None
    state = SimState.init(jobs, total_nodes, machine=machine, event_log=cap,
                          failures=fctx is not None, service=svc_T,
                          malleable=None if mctx is None
                          else (mctx[3], mctx[2].shape[-1]))
    # the batched prefix pass assumes rigid node requests; malleable runs
    # keep the per-start selector loop (widths change under its feet)
    fast_order = (None if mctx is not None
                  else _fast_order(jobs, ctx, static_policy, static_strategy))
    csr = dep_csr(jobs)   # jobs are immutable here, dst order guaranteed

    def cond(st: SimState):
        unfinished = jnp.any((st.jstate != DONE))
        return unfinished & (st.n_events < cap)

    state = jax.lax.while_loop(
        cond,
        lambda st: _event_step(policy, jobs, st, ctx, static_policy,
                               fast_order, csr, rel, svc, mctx),
        state,
    )
    return result_from_state(
        jobs, state, deadline=None if sctx is None else sctx[0],
        nref=None if mctx is None else mctx[1])


def _fast_order(jobs: JobSet, ctx: Optional[AllocCtx],
                static_policy: Optional[int],
                static_strategy: Optional[int]) -> Optional[jax.Array]:
    """The loop-invariant batched-pass permutation, or ``None`` when the
    combination keeps the per-start selector loop (DESIGN.md §14
    eligibility table).

    The batched pass needs a blocking policy and a free-counter feasibility
    cap, and it only *pays* on workloads whose events start many jobs at
    once — which is the dependency-carrying tables (DAG stage fronts
    release whole waves into one event; measured 7-90x there).  Dependency-
    free traces trickle arrivals in, so their typical event starts 0-1
    jobs and the per-event selection prefix would tax every event; they
    keep the selector loop (measured at or above seed throughput with the
    static selector dispatch).  All three paths are bit-identical — this
    is purely a trace-time cost model.

    Backfill is the exception to the deps-only rule: its seed loop paid a
    shadow recomputation over the running set on EVERY blocked select, so
    the batched pass — one shadow per event instead of one per select
    (DESIGN.md §18) — wins on dependency-free traces too (measured
    2.0k -> 28.8k ev/s on the congested no-deps SDSC-like case, where
    nearly every event has a blocked head).
    """
    if static_policy == BACKFILL \
            and (ctx is None or static_strategy in _COUNT_CAPPED):
        return blocking_order(jobs, static_policy)
    if jobs.dep_dst is not None and static_policy in _BLOCKING_POLICIES \
            and (ctx is None or static_strategy in _COUNT_CAPPED):
        return blocking_order(jobs, static_policy)
    return None


def next_event_time(jobs: JobSet, state: SimState) -> jax.Array:
    pending = state.jstate == PENDING
    running = state.jstate == RUNNING
    arrivable = (pending & (state.n_unmet == 0)
                 if jobs.dep_dst is not None else pending)
    t_arr = jnp.min(jnp.where(arrivable, jobs.submit, INF_TIME))
    t_fin = jnp.min(jnp.where(running, state.finish, INF_TIME))
    return jnp.minimum(t_arr, t_fin)


def simulate_window(
    policy: jax.Array,
    jobs: JobSet,
    state: SimState,
    t_hi: jax.Array,
    max_events: jax.Array | int,
    ctx: Optional[AllocCtx] = None,
    rel: Optional[tuple] = None,
) -> tuple[SimState, jax.Array]:
    """Process every event with timestamp <= ``t_hi`` (conservative window).

    The multi-cluster engine (``repro.core.parallel``) calls this once per
    synchronization round — the JAX analogue of SST's conservative
    per-lookahead-window execution (DESIGN.md §2) — and the streaming
    trace-replay runner (``repro.replay``) once per refill round.
    ``policy`` is usually a closed-over concrete array here, so the
    fast-path specialization resolves at trace time exactly as in
    ``simulate``.

    Returns ``(state, saturated)``.  ``saturated`` is a bool scalar set
    when the loop stopped at ``max_events`` with events still due at or
    below ``t_hi`` — the window's answer is then a *truncated prefix* of
    the round, which used to be silent.  Callers must either re-enter with
    a higher cap (the state is a valid prefix; replay doubles the cap and
    continues) or surface the flag (``MulticlusterResult.saturated``).

    ``rel`` is the merged failure/repair stream 6-tuple of ``simulate``'s
    reliability path (``state.rel`` must then be initialized); ``None``
    statically elides it, keeping the existing callers' lowering
    byte-identical.
    """
    static_policy = _static_policy_hint(policy)
    static_strategy = _concrete_int(ctx[1]) if ctx is not None else None
    fast_order = _fast_order(jobs, ctx, static_policy, static_strategy)

    def next_due(st: SimState):
        # the failure/repair stream is a clock source in _event_step, so it
        # must also be one here: a round whose only upcoming event is a
        # repair (jobs queued behind down nodes) would otherwise never fire.
        # Gated on the same any-job-unfinished guard as simulate's cond —
        # a finished table never needs its remaining stream entries.
        nxt = next_event_time(jobs, st)
        if rel is not None:
            K = rel[0].shape[0]
            p = st.rel.ptr
            t_rel = jnp.where(p < K, rel[0][jnp.minimum(p, K - 1)],
                              jnp.int32(INF_TIME))
            live = jnp.any(st.jstate != DONE)
            nxt = jnp.minimum(nxt, jnp.where(live, t_rel,
                                             jnp.int32(INF_TIME)))
        return nxt

    def cond(st: SimState):
        # INF_TIME is the nothing-is-due sentinel (padding rows, drained
        # streams), never a real instant: without the strict bound a drain
        # round at t_hi = INF_TIME would spin no-op events into the cap —
        # and then read as saturated
        due = next_due(st)
        return (due <= t_hi) & (due < INF_TIME) & (st.n_events < max_events)

    state = jax.lax.while_loop(
        cond,
        lambda st: _event_step(policy, jobs, st, ctx, static_policy,
                               fast_order, None, rel),
        state,
    )
    due = next_due(state)
    saturated = (due <= t_hi) & (due < INF_TIME) \
        & (state.n_events >= max_events)
    return state, saturated


def simulate_np(trace, policy, *, total_nodes: int, capacity: int | None = None,
                machine=None, alloc: int | str | None = None, contention=None):
    """Host convenience shim: dict-of-numpy trace -> numpy result dict.

    Equivalent to ``repro.api.run(Scenario(trace=trace, ...)).to_np()``;
    kept as the minimal-dependency one-call path (and as the schema
    reference for ``repro.api.Result.to_np``).
    """
    import numpy as np
    from repro.core.jobs import make_jobset

    jobs = make_jobset(
        trace["submit"], trace["runtime"], trace["nodes"],
        trace.get("estimate"), trace.get("priority"),
        deps=trace.get("deps"),
        capacity=capacity, total_nodes=total_nodes,
    )
    pol = policies_id(policy)
    res = simulate(jobs, pol, total_nodes, machine=machine, alloc=alloc,
                   contention=contention)
    ok = np.asarray(res.done)
    out = {
        "submit": np.asarray(jobs.submit),
        "nodes": np.asarray(jobs.nodes),
        "runtime": np.asarray(jobs.runtime),
        "start": np.asarray(res.start),
        "finish": np.asarray(res.finish),
        "ready": np.asarray(res.ready),
        "wait": np.asarray(res.wait),
        "makespan": int(res.makespan),
        "n_events": int(res.n_events),
        "done": ok,
        "valid": np.asarray(jobs.valid),
    }
    if machine is not None:
        n_ev = out["n_events"]
        out["alloc_first"] = np.asarray(res.alloc_first)
        out["alloc_span"] = np.asarray(res.alloc_span)
        out["alloc_sum"] = np.asarray(res.alloc_sum)
        out["ev_time"] = np.asarray(res.ev_time)[:n_ev]
        out["ev_free"] = np.asarray(res.ev_free)[:n_ev]
        out["ev_lfb"] = np.asarray(res.ev_lfb)[:n_ev]
    return out


def policies_id(policy) -> int:
    from repro.core.jobs import POLICY_IDS
    if isinstance(policy, str):
        return POLICY_IDS[policy.lower()]
    return int(policy)
