"""The discrete-event engine (paper §2.2, Algorithm 1) as a jit-able loop.

Event semantics, pinned identically in ``repro.refsim``:

  1. advance clock to min(next arrival, next completion),
  2. process *all* completions with finish <= clock (reclaim nodes),
  3. process *all* arrivals with submit <= clock (enqueue),
  4. run the scheduling pass: repeatedly ask the policy selector for a job
     and start it, until the selector returns -1.

Each event consumes at least one arrival or completion, so the loop runs at
most ``2*J + 1`` iterations; ``max_events`` is a safety cap on top.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import policies
from repro.core.jobs import (
    DONE, INF_TIME, PENDING, RUNNING, WAITING,
    JobSet, SimResult, SimState, result_from_state,
)
import jax.numpy as jnp  # noqa: F811  (used by preemption helpers)


def _start_job(jobs: JobSet, state: SimState, idx: jax.Array) -> SimState:
    """Allocate nodes to job ``idx`` and schedule its completion event.

    Uses ``state.remaining`` (== runtime unless previously preempted) and
    records only the FIRST start time (dispatch-latency metric).
    """
    start = state.clock
    fin = start + state.remaining[idx]
    rsv = start + jobs.estimate[idx]
    first = jnp.minimum(state.start[idx], start)
    return SimState(
        clock=state.clock,
        jstate=state.jstate.at[idx].set(RUNNING),
        start=state.start.at[idx].set(first),
        finish=state.finish.at[idx].set(fin),
        rsv_finish=state.rsv_finish.at[idx].set(rsv),
        remaining=state.remaining,
        free=state.free - jobs.nodes[idx],
        n_events=state.n_events,
    )


def _preempt_for(jobs: JobSet, state: SimState, idx: jax.Array) -> SimState:
    """Suspend the minimal set of strictly-lower-priority running jobs so
    that job ``idx`` fits (paper §5 future work: preemption capability).

    Victims are chosen most-preemptible-first: (priority desc, row desc).
    Suspended jobs keep their elapsed work (remaining shrinks) and return to
    WAITING with their original submit time/FCFS rank.
    """
    J = jobs.capacity
    need = jobs.nodes[idx] - state.free
    running = state.jstate == RUNNING
    lower = running & (jobs.priority > jobs.priority[idx])
    # order victims by (priority desc, row desc): key = -(priority*J + row)
    key = jnp.where(lower, -(jobs.priority * J + jnp.arange(J, dtype=jnp.int32)),
                    jnp.int32(INF_TIME))
    order = jnp.argsort(key)
    nodes_o = jnp.where(lower, jobs.nodes, 0)[order]
    cum = jnp.cumsum(nodes_o)
    # preempt the minimal prefix whose cumulative nodes cover the deficit
    take_rank = jnp.where(cum - nodes_o < jnp.maximum(need, 0), True, False)
    take_rank = take_rank & (nodes_o > 0)
    victim = jnp.zeros((J,), bool).at[order].set(take_rank)
    freed = jnp.sum(jnp.where(victim, jobs.nodes, 0)).astype(jnp.int32)
    new_remaining = jnp.where(
        victim, jnp.maximum(state.finish - state.clock, 1), state.remaining
    )
    return SimState(
        clock=state.clock,
        jstate=jnp.where(victim, WAITING, state.jstate),
        start=state.start,
        finish=jnp.where(victim, INF_TIME, state.finish),
        rsv_finish=jnp.where(victim, INF_TIME, state.rsv_finish),
        remaining=new_remaining,
        free=state.free + freed,
        n_events=state.n_events,
    )


def _schedule_pass(policy: jax.Array, jobs: JobSet, state: SimState) -> SimState:
    """Start jobs until the policy blocks (Algorithm 1 lines 16-21)."""

    def cond(carry):
        _, idx = carry
        return idx >= 0

    def body(carry):
        st, idx = carry
        st = jax.lax.cond(
            jobs.nodes[idx] <= st.free,
            lambda s: s,
            lambda s: _preempt_for(jobs, s, idx),  # preempt policy only
            st,
        )
        st = _start_job(jobs, st, idx)
        return st, policies.select(policy, jobs, st)

    state, _ = jax.lax.while_loop(
        cond, body, (state, policies.select(policy, jobs, state))
    )
    return state


def _event_step(policy: jax.Array, jobs: JobSet, state: SimState) -> SimState:
    pending = state.jstate == PENDING
    running = state.jstate == RUNNING

    t_arr = jnp.min(jnp.where(pending, jobs.submit, INF_TIME))
    t_fin = jnp.min(jnp.where(running, state.finish, INF_TIME))
    clock = jnp.minimum(t_arr, t_fin)

    # completions first (frees nodes for arrivals at the same timestamp)
    completed = running & (state.finish <= clock)
    freed = jnp.sum(jnp.where(completed, jobs.nodes, 0)).astype(jnp.int32)
    jstate = jnp.where(completed, DONE, state.jstate)

    # arrivals
    arrived = (jstate == PENDING) & (jobs.submit <= clock)
    jstate = jnp.where(arrived, WAITING, jstate)

    state = SimState(
        clock=clock,
        jstate=jstate,
        start=state.start,
        finish=state.finish,
        rsv_finish=state.rsv_finish,
        remaining=state.remaining,
        free=state.free + freed,
        n_events=state.n_events + 1,
    )
    return _schedule_pass(policy, jobs, state)


@functools.partial(jax.jit, static_argnames=("max_events",))
def simulate(
    jobs: JobSet,
    policy: jax.Array | int,
    total_nodes: jax.Array | int,
    *,
    max_events: Optional[int] = None,
) -> SimResult:
    """Run the full job-scheduling simulation for one cluster.

    Pure function of its inputs (``policy`` and ``total_nodes`` are traced,
    so the same executable serves every policy/machine size); ``vmap``-able
    over ``jobs`` leaves, ``policy`` and/or ``total_nodes`` for ensemble
    simulation (see ``repro.core.parallel``).
    """
    policy = jnp.asarray(policy, dtype=jnp.int32)
    cap = max_events if max_events is not None else 6 * jobs.capacity + 8
    state = SimState.init(jobs, total_nodes)

    def cond(st: SimState):
        unfinished = jnp.any((st.jstate != DONE))
        return unfinished & (st.n_events < cap)

    state = jax.lax.while_loop(
        cond, lambda st: _event_step(policy, jobs, st), state
    )
    return result_from_state(jobs, state)


def next_event_time(jobs: JobSet, state: SimState) -> jax.Array:
    pending = state.jstate == PENDING
    running = state.jstate == RUNNING
    t_arr = jnp.min(jnp.where(pending, jobs.submit, INF_TIME))
    t_fin = jnp.min(jnp.where(running, state.finish, INF_TIME))
    return jnp.minimum(t_arr, t_fin)


def simulate_window(
    policy: jax.Array,
    jobs: JobSet,
    state: SimState,
    t_hi: jax.Array,
    max_events: jax.Array | int,
) -> SimState:
    """Process every event with timestamp <= ``t_hi`` (conservative window).

    The multi-cluster engine (``repro.core.parallel``) calls this once per
    synchronization round — the JAX analogue of SST's conservative
    per-lookahead-window execution (DESIGN.md §2).
    """

    def cond(st: SimState):
        return (next_event_time(jobs, st) <= t_hi) & (st.n_events < max_events)

    return jax.lax.while_loop(cond, lambda st: _event_step(policy, jobs, st), state)


def simulate_np(trace, policy, *, total_nodes: int, capacity: int | None = None):
    """Host convenience wrapper: dict-of-numpy trace -> numpy result dict."""
    import numpy as np
    from repro.core.jobs import make_jobset

    jobs = make_jobset(
        trace["submit"], trace["runtime"], trace["nodes"],
        trace.get("estimate"), trace.get("priority"),
        capacity=capacity, total_nodes=total_nodes,
    )
    pol = policies_id(policy)
    res = simulate(jobs, pol, total_nodes)
    ok = np.asarray(res.done)
    return {
        "submit": np.asarray(jobs.submit),
        "nodes": np.asarray(jobs.nodes),
        "runtime": np.asarray(jobs.runtime),
        "start": np.asarray(res.start),
        "finish": np.asarray(res.finish),
        "wait": np.asarray(res.wait),
        "makespan": int(res.makespan),
        "n_events": int(res.n_events),
        "done": ok,
        "valid": np.asarray(jobs.valid),
    }


def policies_id(policy) -> int:
    from repro.core.jobs import POLICY_IDS
    if isinstance(policy, str):
        return POLICY_IDS[policy.lower()]
    return int(policy)
