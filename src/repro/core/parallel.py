"""Parallel discrete-event simulation (paper Figs. 5-6, DESIGN.md §2).

Two parallelization modes, both SPMD-native:

1. **Ensemble** — many independent simulations (trace shards, policy sweeps,
   parameter studies) batched with ``vmap`` and sharded across devices with
   ``shard_map``.  This is the weak-scaling mode the paper exercises by
   growing job counts per rank.

2. **Multi-cluster conservative windows** — one simulation partitioned into
   K clusters, each advanced independently over a time window ``W`` and then
   synchronized.  Job *migration* messages emitted in window ``k`` carry a
   latency >= W, so they cannot affect window ``k`` — the window is a valid
   conservative lookahead bound, exactly SST's synchronization contract,
   expressed with ``shard_map`` + ``all_gather`` instead of MPI.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import alloc as _alloc
from repro.core.engine import simulate, simulate_window
from repro.core.jobs import (
    DONE, INF_TIME, PENDING, WAITING,
    JobSet, SimResult, SimState,
)

# ---------------------------------------------------------------------------
# ensemble mode
# ---------------------------------------------------------------------------


def stack_jobsets(jobsets: list[JobSet]) -> JobSet:
    """Stack equally-sized JobSets into a leading batch dimension.

    Members may mix edge-free tables (``dep_dst is None``) and edge lists of
    *different* padded lengths (e.g. a sweep over DAG seeds where each seed
    generates a different edge count, or one seed generates zero edges):
    every member is padded to the longest edge list with inert out-of-range
    edges (index = capacity, the same padding ``make_jobset`` emits), so the
    stacked pytree is uniform.  Padding edges scatter out of bounds and
    drop, so schedules are unchanged.
    """
    if any(j.dep_dst is not None for j in jobsets):
        ecap = max(j.edge_capacity for j in jobsets)

        def pad_edges(j: JobSet) -> JobSet:
            extra = ecap - j.edge_capacity
            if extra == 0:
                return j
            fill = jnp.full((extra,), j.capacity, dtype=jnp.int32)
            if j.dep_dst is None:
                return dataclasses.replace(j, dep_dst=fill, dep_src=fill)
            return dataclasses.replace(
                j,
                dep_dst=jnp.concatenate([j.dep_dst, fill]),
                dep_src=jnp.concatenate([j.dep_src, fill]),
            )

        jobsets = [pad_edges(j) for j in jobsets]
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *jobsets)


def simulate_ensemble(
    jobs_b: JobSet,
    policies_b,
    total_nodes_b,
    *,
    machine=None,
    alloc_b=None,
    contention=None,
    failures_b=None,
    mesh: Optional[Mesh] = None,
    max_events: Optional[int] = None,
) -> SimResult:
    """vmap-batched simulation, optionally sharded over a 1-D device mesh.

    ``jobs_b`` leaves have leading batch dim B; ``policies_b``/``total_nodes_b``
    are i32[B].  With a mesh, B must divide evenly across the ``sim`` axis;
    each device advances its ensemble members fully independently (zero
    cross-device communication — the embarrassingly-parallel mode).

    Allocation sweep axis (DESIGN.md §11): with ``machine`` (one static
    topology broadcast to all members) ``alloc_b`` is an i32[B] of placement
    strategy ids — strategy is ensemble data, exactly like policy.

    Reliability sweep axis (DESIGN.md §15): ``failures_b`` is a stacked fail
    ctx — ``jax.tree.map(jnp.stack, *[make_fail_ctx(t) for t in traces])``
    — whose leaves carry a leading B dim; per-member failure streams are
    ensemble data too (uniform ``max_failures`` padding required).
    """
    policies_b = jnp.asarray(policies_b, dtype=jnp.int32)
    total_nodes_b = jnp.asarray(total_nodes_b, dtype=jnp.int32)
    if machine is None:
        if alloc_b is not None or contention is not None:
            raise ValueError(
                "alloc_b/contention require machine=; without a Machine the "
                "ensemble runs in scalar-counter mode and would silently "
                "ignore them")
        if failures_b is None:
            fn = jax.vmap(functools.partial(simulate, max_events=max_events))
            args = (jobs_b, policies_b, total_nodes_b)
        else:
            fn = jax.vmap(
                lambda j, p, t, f: simulate(j, p, t, failures=f,
                                            max_events=max_events))
            args = (jobs_b, policies_b, total_nodes_b, failures_b)
    else:
        bad = np.asarray(total_nodes_b) != machine.n_nodes
        if bad.any():
            raise ValueError(
                f"machine has {machine.n_nodes} nodes but total_nodes_b "
                f"contains {sorted(set(np.asarray(total_nodes_b)[bad].tolist()))}")
        if alloc_b is None:
            alloc_b = jnp.zeros_like(policies_b)
        # one shared canonicalizer (repro.alloc.canonical_id) handles str/int
        # ids, numpy arrays, and mixed str/int sequences identically here, in
        # make_alloc_ctx, and in the Scenario sweep layer
        alloc_b = jnp.asarray(_alloc.canonical_id(alloc_b), dtype=jnp.int32)
        if failures_b is None:
            fn = jax.vmap(
                lambda j, p, t, a: simulate(
                    j, p, t, machine=machine, alloc=a, contention=contention,
                    max_events=max_events)
            )
            args = (jobs_b, policies_b, total_nodes_b, alloc_b)
        else:
            fn = jax.vmap(
                lambda j, p, t, a, f: simulate(
                    j, p, t, machine=machine, alloc=a, contention=contention,
                    failures=f, max_events=max_events)
            )
            args = (jobs_b, policies_b, total_nodes_b, alloc_b, failures_b)
    if mesh is None:
        return jax.jit(fn)(*args)

    axis = mesh.axis_names[0]
    shard = NamedSharding(mesh, P(axis))
    args = tuple(jax.device_put(a, shard) for a in args)
    out_shard = jax.tree.map(lambda _: shard, jax.eval_shape(fn, *args))
    return jax.jit(fn, out_shardings=out_shard)(*args)


def simulate_alloc_sweep(
    jobs: JobSet,
    policy,
    total_nodes,
    machine,
    strategies=("simple", "contiguous", "spread", "topo"),
    *,
    contention=None,
    mesh: Optional[Mesh] = None,
    max_events: Optional[int] = None,
) -> SimResult:
    """Run ONE trace under every allocation strategy as a batched ensemble.

    Legacy shim: ``repro.api.sweep(scenario, axes={"alloc": strategies})``
    is the general form (any axis grid, static-bucket compilation, unified
    results) and reproduces this function bit-for-bit (regression-tested in
    ``tests/test_api.py``).  Kept for callers that already hold a
    ``JobSet``.

    Returns a ``SimResult`` whose leaves have leading dim ``len(strategies)``
    in the order given — the "same trace, different allocators, different
    makespans" scenario family from DESIGN.md §11.
    """
    B = len(strategies)
    jobs_b = stack_jobsets([jobs] * B)
    policies_b = jnp.full((B,), int(policy), dtype=jnp.int32)
    total_nodes_b = jnp.full((B,), int(total_nodes), dtype=jnp.int32)
    alloc_b = jnp.asarray(_alloc.canonical_id(list(strategies)),
                          dtype=jnp.int32)
    return simulate_ensemble(
        jobs_b, policies_b, total_nodes_b, machine=machine, alloc_b=alloc_b,
        contention=contention, mesh=mesh, max_events=max_events,
    )


# ---------------------------------------------------------------------------
# multi-cluster conservative-window mode
# ---------------------------------------------------------------------------


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class MulticlusterResult:
    """Final per-cluster tables: leaves shaped [C, J]."""

    jobs: JobSet          # post-migration job tables (valid marks ownership)
    state: SimState
    migrated: jax.Array   # i32[C] jobs exported by each cluster
    dropped: jax.Array    # i32[C] imports dropped for lack of free rows (should be 0)
    saturated: jax.Array  # bool[C] any round hit the event cap with events still due


def _queue_load(jobs: JobSet, state: SimState) -> jax.Array:
    """Pending work metric: node-seconds waiting in queue (estimates)."""
    waiting = (state.jstate == WAITING) | (state.jstate == PENDING)
    return jnp.sum(
        jnp.where(waiting, jobs.nodes * jnp.minimum(jobs.estimate, 1 << 16), 0)
    ).astype(jnp.int32)


def _export_jobs(jobs: JobSet, state: SimState, t_hi, latency, max_export: int,
                 enable: jax.Array):
    """Pick up to ``max_export`` *tail* waiting/pending jobs to offload.

    Tail = largest submit time first (least FCFS-urgent), so migration never
    reorders the local head-of-queue.  Jobs with dependency edges (either
    direction) are pinned to their cluster: the dependency matrix is local,
    so exporting either endpoint of an edge would sever it (DESIGN.md §13).
    Returns (jobs', state', packet).
    """
    J = jobs.capacity
    movable = ((state.jstate == WAITING) | (state.jstate == PENDING)) & jobs.valid
    if jobs.dep_dst is not None:
        # rows touched by any live edge (either endpoint) are pinned;
        # padding / neutralized edges hold index J and drop out
        has_edges = (
            jnp.zeros((J,), bool)
            .at[jobs.dep_dst].set(True, mode="drop")
            .at[jobs.dep_src].set(True, mode="drop")
        )
        movable = movable & ~has_edges
    # rank movable jobs by descending submit (non-movable sort last)
    key = jnp.where(movable, -jobs.submit, jnp.int32(INF_TIME))
    order = jnp.argsort(key)  # ascending => movable with largest submit first
    take = jnp.arange(J) < jnp.where(enable, max_export, 0)
    n_movable = jnp.sum(movable.astype(jnp.int32))
    take = take & (jnp.arange(J) < n_movable)
    sel_rows = order[:max_export]
    sel_ok = take[:max_export]

    new_submit = jnp.maximum(jobs.submit[sel_rows], t_hi + latency)
    packet = {
        "submit": jnp.where(sel_ok, new_submit, INF_TIME).astype(jnp.int32),
        "runtime": jobs.runtime[sel_rows].astype(jnp.int32),
        "estimate": jobs.estimate[sel_rows].astype(jnp.int32),
        "nodes": jobs.nodes[sel_rows].astype(jnp.int32),
        "priority": jobs.priority[sel_rows].astype(jnp.int32),
        "ok": sel_ok,
    }
    # remove exported jobs locally
    remove = jnp.zeros((J,), bool).at[sel_rows].set(sel_ok)
    jobs = dataclasses.replace(jobs, valid=jobs.valid & ~remove)
    state = dataclasses.replace(
        state, jstate=jnp.where(remove, DONE, state.jstate)
    )
    return jobs, state, packet


def _import_jobs(jobs: JobSet, state: SimState, flat):
    """Insert gathered packets destined to this cluster into free rows."""
    J = jobs.capacity
    ok = flat["ok"]
    n_imp = jnp.sum(ok.astype(jnp.int32))
    free_rows_order = jnp.argsort(jnp.where(jobs.valid, 1, 0), stable=True)
    n_free = jnp.sum((~jobs.valid).astype(jnp.int32))
    slot = jnp.cumsum(ok.astype(jnp.int32)) - 1           # slot per packet
    can = ok & (slot < n_free)
    rows = free_rows_order[jnp.clip(slot, 0, J - 1)]
    rows = jnp.where(can, rows, J)  # J = out-of-bounds => dropped by mode="drop"

    # imported jobs are dependency-free by construction (_export_jobs pins
    # edge endpoints), but neutralize edges touching the landing rows
    # defensively — both endpoints move to the out-of-range pad index J —
    # so a reused row can never inherit stale edges
    new_dst, new_src = jobs.dep_dst, jobs.dep_src
    if new_dst is not None:
        hit = jnp.isin(new_dst, rows) | jnp.isin(new_src, rows)
        new_dst = jnp.where(hit, jnp.int32(J), new_dst)
        new_src = jnp.where(hit, jnp.int32(J), new_src)
    jobs = JobSet(
        submit=jobs.submit.at[rows].set(flat["submit"], mode="drop"),
        runtime=jobs.runtime.at[rows].set(flat["runtime"], mode="drop"),
        estimate=jobs.estimate.at[rows].set(flat["estimate"], mode="drop"),
        nodes=jobs.nodes.at[rows].set(flat["nodes"], mode="drop"),
        priority=jobs.priority.at[rows].set(flat["priority"], mode="drop"),
        valid=jobs.valid.at[rows].set(True, mode="drop"),
        dep_dst=new_dst,
        dep_src=new_src,
    )
    n_unmet = state.n_unmet
    if new_dst is not None:
        n_unmet = n_unmet.at[rows].set(0, mode="drop")  # landing rows dep-free
    state = dataclasses.replace(
        state,
        jstate=state.jstate.at[rows].set(PENDING, mode="drop"),
        n_unmet=n_unmet,
        start=state.start.at[rows].set(INF_TIME, mode="drop"),
        finish=state.finish.at[rows].set(INF_TIME, mode="drop"),
        rsv_finish=state.rsv_finish.at[rows].set(INF_TIME, mode="drop"),
        remaining=state.remaining.at[rows].set(flat["runtime"], mode="drop"),
    )
    dropped = n_imp - jnp.minimum(n_imp, n_free)
    return jobs, state, dropped


def simulate_multicluster(
    jobs_c: JobSet,
    policy,
    nodes_c,
    *,
    window: int,
    horizon: int,
    mesh: Optional[Mesh] = None,
    migrate: bool = True,
    max_export: int = 8,
    latency: Optional[int] = None,
    load_imbalance_threshold: float = 1.5,
    max_events: Optional[int] = None,
) -> MulticlusterResult:
    """Conservative-window multi-cluster simulation.

    ``jobs_c`` leaves are [C, J]; ``nodes_c`` is i32[C].  Each round: every
    cluster simulates events in ``(r*W, (r+1)*W]`` independently; clusters
    whose queue load exceeds ``threshold * mean`` export up to ``max_export``
    tail jobs to the least-loaded cluster, with arrival latency >= ``W``
    (the conservative lookahead).  With ``mesh`` the cluster dimension is
    sharded via ``shard_map``; without, it runs vmapped on one device with
    identical semantics (the collective degenerates to an identity gather).
    """
    C = jobs_c.submit.shape[0]
    J = jobs_c.submit.shape[1]
    policy = jnp.asarray(policy, dtype=jnp.int32)
    nodes_c = jnp.asarray(nodes_c, dtype=jnp.int32)
    latency = int(latency if latency is not None else window)
    if latency < window:
        raise ValueError("migration latency must be >= window for conservative sync")
    n_rounds = int(np.ceil(horizon / window)) + 1
    ev_cap = max_events if max_events is not None else 2 * J + 8

    def local_sim(jobs, nodes, axis_name):
        # jobs leaves [Cl, J]; runs on one shard (or the whole batch w/o mesh)
        state = jax.vmap(SimState.init, in_axes=(0, 0))(jobs, nodes)

        def round_body(r, carry):
            jobs, state, mig, drop, sat = carry
            t_hi = (r + 1) * jnp.int32(window)
            state, sat_r = jax.vmap(
                lambda j, s: simulate_window(policy, j, s, t_hi, ev_cap)
            )(jobs, state)
            sat = sat | sat_r
            if not migrate:
                return jobs, state, mig, drop, sat

            load_l = jax.vmap(_queue_load)(jobs, state)          # [Cl]
            if axis_name is not None:
                loads = jax.lax.all_gather(load_l, axis_name).reshape(-1)  # [C]
                my0 = jax.lax.axis_index(axis_name) * load_l.shape[0]
            else:
                loads = load_l
                my0 = 0
            mean_load = jnp.mean(loads.astype(jnp.float32))
            dest = jnp.argmin(loads).astype(jnp.int32)           # global id
            gids = my0 + jnp.arange(load_l.shape[0], dtype=jnp.int32)
            over = (
                (load_l.astype(jnp.float32) > load_imbalance_threshold * mean_load)
                & (gids != dest)
                & (loads[dest] < load_l)
            )
            jobs, state, pkt = jax.vmap(
                lambda j, s, en: _export_jobs(j, s, t_hi, jnp.int32(latency),
                                              max_export, en)
            )(jobs, state, over)
            pkt["dest"] = jnp.broadcast_to(dest, pkt["ok"].shape).astype(jnp.int32)
            mig = mig + jax.vmap(lambda o: jnp.sum(o.astype(jnp.int32)))(pkt["ok"])

            if axis_name is not None:
                gpkt = {k: jax.lax.all_gather(v, axis_name) for k, v in pkt.items()}
                gpkt = {k: v.reshape((-1,) + v.shape[3:]) for k, v in gpkt.items()}
            else:
                gpkt = {k: v.reshape((-1,) + v.shape[2:]) for k, v in pkt.items()}

            def imp(j, s, gid):
                flat = dict(gpkt)
                flat["ok"] = gpkt["ok"] & (gpkt["dest"] == gid)
                j, s, d = _import_jobs(j, s, flat)
                return j, s, d

            jobs, state, d = jax.vmap(imp)(jobs, state, gids)
            return jobs, state, mig, drop + d, sat

        mig0 = jnp.zeros((jobs.submit.shape[0],), jnp.int32)
        sat0 = jnp.zeros((jobs.submit.shape[0],), bool)
        carry = (jobs, state, mig0, jnp.zeros_like(mig0), sat0)
        jobs, state, mig, drop, sat = jax.lax.fori_loop(
            0, n_rounds, round_body, carry)
        # drain any events beyond the horizon (no migration afterwards)
        state, sat_d = jax.vmap(
            lambda j, s: simulate_window(policy, j, s, jnp.int32(INF_TIME), ev_cap)
        )(jobs, state)
        return jobs, state, mig, drop, sat | sat_d

    if mesh is None:
        jobs, state, mig, drop, sat = jax.jit(
            lambda j, n: local_sim(j, n, None)
        )(jobs_c, nodes_c)
    else:
        axis = mesh.axis_names[0]
        from jax.experimental.shard_map import shard_map
        fn = shard_map(
            lambda j, n: local_sim(j, n, axis),
            mesh=mesh,
            in_specs=(P(axis), P(axis)),
            out_specs=P(axis),
            check_rep=False,
        )
        jobs, state, mig, drop, sat = jax.jit(fn)(jobs_c, nodes_c)

    return MulticlusterResult(jobs=jobs, state=state, migrated=mig,
                              dropped=drop, saturated=sat)


def multicluster_result_np(res: MulticlusterResult) -> dict:
    """Flatten per-cluster tables to one host-side result dict."""
    jobs, state = res.jobs, res.state
    flat = lambda a: np.asarray(a).reshape(-1)
    valid = flat(jobs.valid)
    done = flat(state.jstate) == DONE
    out = {
        "submit": flat(jobs.submit),
        "runtime": flat(jobs.runtime),
        "nodes": flat(jobs.nodes),
        "start": flat(state.start),
        "finish": flat(state.finish),
        "valid": valid,
        "done": done & valid,
        "migrated": int(np.asarray(res.migrated).sum()),
        "dropped": int(np.asarray(res.dropped).sum()),
        "saturated": bool(np.asarray(res.saturated).any()),
    }
    if jobs.dep_dst is not None:
        dst = np.asarray(jobs.dep_dst)                     # [C, E]
        src = np.asarray(jobs.dep_src)
        fin = np.asarray(state.finish)                     # [C, J]
        C, J = fin.shape
        dep_fin = np.zeros((C, J), dtype=fin.dtype)
        for c in range(C):                                 # host side, C small
            live = dst[c] < J
            np.maximum.at(dep_fin[c], dst[c][live], fin[c][src[c][live]])
        out["ready"] = np.maximum(np.asarray(jobs.submit), dep_fin).reshape(-1)
    else:
        out["ready"] = out["submit"]
    out["wait"] = out["start"] - out["ready"]
    fin = out["finish"][out["done"]]
    out["makespan"] = int(fin.max(initial=0))
    return out
