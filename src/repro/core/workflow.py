"""Workflow (DAG) management component (paper §3) as a JAX event loop.

This is the *standalone* multi-resource workflow engine: tasks draw from
abstract (cpu, memory, ...) pools, matching the paper's §3 validation
setup.  To schedule a DAG onto the *cluster* — concrete nodes, all six
policies, allocation strategies, contention — lower it with
``repro.traces.workflows.workflow_to_trace`` (or a
``repro.api.WorkflowTrace`` scenario) and run it through the main engine's
dependency axis instead (DESIGN.md §13).

Tasks carry multi-resource requirements (cpu, memory, ... — paper Listing 2)
and a dependency set; a task is *ready* when every dependency is DONE.  The
paper implements the DAG with adjacency lists; on SPMD hardware we use a
dense boolean dependency matrix so the ready-set is one masked reduction —
O(T^2) bits but fully parallel, fine for the few-thousand-task workflows the
paper targets (Montage/Galactic, SIPHT).

Scheduling policies:
  - ``fcfs``       blocking head-of-ready-queue (paper's baseline)
  - ``fcfs_fit``   work-conserving: first ready task that fits (paper's
                   description of filling resource gaps)
  - ``cpath``      critical-path-first priority (beyond-paper extension;
                   pass ``priority=critical_path_length(...)``)
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.jobs import DONE, INF_TIME, RUNNING, WAITING, assert_acyclic

WF_FCFS = 0
WF_FCFS_FIT = 1
WF_CPATH = 2
WF_POLICY_IDS = {"fcfs": WF_FCFS, "fcfs_fit": WF_FCFS_FIT, "cpath": WF_CPATH}


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class TaskSet:
    """Struct-of-arrays task table for one workflow (paper §3.1)."""

    exec_time: jax.Array   # i32[T]
    resources: jax.Array   # i32[T, R] requirement per resource type
    deps: jax.Array        # bool[T, T]; deps[i, j] => task i needs task j
    valid: jax.Array       # bool[T]
    priority: jax.Array    # i32[T]; lower = scheduled earlier (default: id)

    @property
    def capacity(self) -> int:
        return self.exec_time.shape[-1]

    @property
    def n_resources(self) -> int:
        return self.resources.shape[-1]


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class WorkflowState:
    clock: jax.Array      # i32
    tstate: jax.Array     # i32[T]
    start: jax.Array      # i32[T]
    finish: jax.Array     # i32[T]
    free: jax.Array       # i32[R]
    n_events: jax.Array   # i32


def make_taskset(
    exec_time, resources, dep_pairs, *, capacity: int | None = None,
    priority=None,
) -> TaskSet:
    """Host-side constructor.

    ``dep_pairs`` is an iterable of (task, dependency) index pairs; indices
    refer to positions in ``exec_time``.  Cycles are rejected host-side.
    """
    exec_time = np.maximum(np.asarray(exec_time, dtype=np.int64), 1)
    resources = np.asarray(resources, dtype=np.int64)
    if resources.ndim == 1:
        resources = resources[:, None]
    n = exec_time.shape[0]
    cap = capacity or n
    if cap < n:
        raise ValueError("capacity < number of tasks")

    deps = np.zeros((cap, cap), dtype=bool)
    for t, d in dep_pairs:
        if not (0 <= t < n and 0 <= d < n):
            raise ValueError(f"dependency pair ({t},{d}) out of range")
        if t == d:
            raise ValueError("self-dependency")
        deps[t, d] = True
    assert_acyclic(deps[:n, :n])

    res = np.zeros((cap, resources.shape[1]), dtype=np.int32)
    res[:n] = resources.astype(np.int32)
    et = np.full((cap,), 1, dtype=np.int32)
    et[:n] = exec_time.astype(np.int32)
    valid = np.zeros((cap,), dtype=bool)
    valid[:n] = True
    prio = np.arange(cap, dtype=np.int32)
    if priority is not None:
        prio[:n] = np.asarray(priority, dtype=np.int32)
    return TaskSet(
        exec_time=jnp.asarray(et),
        resources=jnp.asarray(res),
        deps=jnp.asarray(deps),
        valid=jnp.asarray(valid),
        priority=jnp.asarray(prio),
    )


# cycle check lives in repro.core.jobs.assert_acyclic (shared with
# make_jobset, which builds the cluster engine's dependency matrix)


def critical_path_length(tasks_exec: np.ndarray, dep_pairs) -> np.ndarray:
    """Longest exec-time path from each task to any sink (host-side).

    Returned as a *negated* priority so that higher critical path => lower
    priority value => scheduled earlier under ``cpath``.
    """
    n = len(tasks_exec)
    succ = [[] for _ in range(n)]
    indeg_rev = np.zeros(n, dtype=np.int64)
    for t, d in dep_pairs:
        succ[d].append(t)           # edge d -> t in execution order
        indeg_rev[d] += 1           # reverse graph in-degree (== #successors consumed)
    cp = np.asarray(tasks_exec, dtype=np.int64).copy()
    # process in reverse-topological order: repeatedly relax from sinks
    out_count = np.array([len(s) for s in succ], dtype=np.int64)
    stack = list(np.nonzero(out_count == 0)[0])
    pred = [[] for _ in range(n)]
    for t, d in dep_pairs:
        pred[t].append(d)
    remaining = out_count.copy()
    while stack:
        t = stack.pop()
        for d in pred[t]:
            cp[d] = max(cp[d], tasks_exec[d] + cp[t])
            remaining[d] -= 1
            if remaining[d] == 0:
                stack.append(d)
    return (-cp).astype(np.int32)


# ---------------------------------------------------------------------------
# event engine
# ---------------------------------------------------------------------------

def _ready_mask(tasks: TaskSet, tstate: jax.Array) -> jax.Array:
    unmet = tasks.deps & (tstate != DONE)[None, :]
    return (tstate == WAITING) & ~jnp.any(unmet, axis=1)


def _fits(tasks: TaskSet, free: jax.Array) -> jax.Array:
    return jnp.all(tasks.resources <= free[None, :], axis=1)


def _select_task(policy: jax.Array, tasks: TaskSet, state: WorkflowState) -> jax.Array:
    ready = _ready_mask(tasks, state.tstate)
    fits = _fits(tasks, state.free)
    prio = jnp.where(ready, tasks.priority, INF_TIME)
    T = tasks.capacity

    def blocking(prio_key):
        best = jnp.min(prio_key)
        head = jnp.argmin(
            jnp.where(ready & (prio_key == best), jnp.arange(T), INF_TIME)
        ).astype(jnp.int32)
        ok = jnp.any(ready) & fits[jnp.maximum(head, 0)]
        return jnp.where(ok, head, jnp.int32(-1))

    def work_conserving(prio_key):
        cand = ready & fits
        key = jnp.where(cand, prio_key, INF_TIME)
        best = jnp.min(key)
        pick = jnp.argmin(
            jnp.where(cand & (key == best), jnp.arange(T), INF_TIME)
        ).astype(jnp.int32)
        return jnp.where(jnp.any(cand), pick, jnp.int32(-1))

    return jax.lax.switch(
        jnp.clip(policy, 0, 2),
        (
            lambda: blocking(prio),
            lambda: work_conserving(prio),
            lambda: work_conserving(prio),  # cpath: priority carries -cp
        ),
    )


def _start_task(tasks: TaskSet, state: WorkflowState, idx: jax.Array) -> WorkflowState:
    return WorkflowState(
        clock=state.clock,
        tstate=state.tstate.at[idx].set(RUNNING),
        start=state.start.at[idx].set(state.clock),
        finish=state.finish.at[idx].set(state.clock + tasks.exec_time[idx]),
        free=state.free - tasks.resources[idx],
        n_events=state.n_events,
    )


def _wf_event(policy: jax.Array, tasks: TaskSet, state: WorkflowState) -> WorkflowState:
    running = state.tstate == RUNNING
    clock = jnp.min(jnp.where(running, state.finish, INF_TIME))

    completed = running & (state.finish <= clock)
    freed = jnp.sum(
        jnp.where(completed[:, None], tasks.resources, 0), axis=0
    ).astype(jnp.int32)
    state = WorkflowState(
        clock=clock,
        tstate=jnp.where(completed, DONE, state.tstate),
        start=state.start,
        finish=state.finish,
        free=state.free + freed,
        n_events=state.n_events + 1,
    )

    def cond(c):
        return c[1] >= 0

    def body(c):
        st, idx = c
        st = _start_task(tasks, st, idx)
        return st, _select_task(policy, tasks, st)

    state, _ = jax.lax.while_loop(cond, body, (state, _select_task(policy, tasks, state)))
    return state


@functools.partial(jax.jit, static_argnames=("max_events",))
def simulate_workflow(
    tasks: TaskSet,
    pools: jax.Array,
    policy: jax.Array | int = WF_FCFS,
    *,
    max_events: Optional[int] = None,
) -> WorkflowState:
    """Simulate one workflow on resource pools ``pools`` (i32[R])."""
    policy = jnp.asarray(policy, dtype=jnp.int32)
    T = tasks.capacity
    cap = max_events if max_events is not None else T + 8
    inf = jnp.full((T,), INF_TIME, dtype=jnp.int32)
    state = WorkflowState(
        clock=jnp.int32(0),
        tstate=jnp.where(tasks.valid, jnp.int32(WAITING), jnp.int32(DONE)),
        start=inf,
        finish=inf,
        free=jnp.asarray(pools, dtype=jnp.int32),
        n_events=jnp.int32(0),
    )
    # initial scheduling pass at t=0 (all roots are ready immediately)
    def cond0(c):
        return c[1] >= 0

    def body0(c):
        st, idx = c
        st = _start_task(tasks, st, idx)
        return st, _select_task(policy, tasks, st)

    state, _ = jax.lax.while_loop(
        cond0, body0, (state, _select_task(policy, tasks, state))
    )

    def cond(st: WorkflowState):
        return jnp.any(st.tstate == RUNNING) & (st.n_events < cap)

    return jax.lax.while_loop(cond, lambda st: _wf_event(policy, tasks, st), state)


def workflow_result_np(tasks: TaskSet, state: WorkflowState) -> dict:
    valid = np.asarray(tasks.valid)
    start = np.asarray(state.start)
    finish = np.asarray(state.finish)
    done = np.asarray(state.tstate) == DONE
    deps = np.asarray(tasks.deps)
    # a task becomes *ready* when its last dependency finishes (0 for roots);
    # wait = start - ready is the paper Fig. 7 per-task wait metric.
    dep_fin = np.where(deps, finish[None, :], 0)
    ready = dep_fin.max(axis=1, initial=0)
    return {
        "exec_time": np.asarray(tasks.exec_time),
        "start": start,
        "finish": finish,
        "ready": ready,
        "wait": np.where(valid, start - ready, 0),
        "done": done & valid,
        "valid": valid,
        "makespan": int(finish[valid & done].max(initial=0)),
        "n_events": int(state.n_events),
    }
