"""The discrete-event scheduler core: job tables, policies, the jit-able
engine, offline metrics, and the parallel (ensemble / multicluster) modes.

The declarative front door is ``repro.api``; these are the underlying
building blocks, re-exported here as the stable low-level surface.
"""

from repro.core import metrics
from repro.core.engine import (
    make_alloc_ctx, policies_id, simulate, simulate_np, simulate_window,
)
from repro.core.jobs import (
    BACKFILL, BESTFIT, DONE, FCFS, INF_TIME, LJF, PENDING, POLICY_IDS,
    POLICY_NAMES, PREEMPT, RUNNING, SJF, WAITING, JobSet, SimResult,
    SimState, make_jobset, result_from_state,
)
from repro.core.parallel import (
    MulticlusterResult, multicluster_result_np, simulate_alloc_sweep,
    simulate_ensemble, simulate_multicluster, stack_jobsets,
)

__all__ = [
    "BACKFILL", "BESTFIT", "DONE", "FCFS", "INF_TIME", "LJF", "PENDING",
    "POLICY_IDS", "POLICY_NAMES", "PREEMPT", "RUNNING", "SJF", "WAITING",
    "JobSet", "MulticlusterResult", "SimResult", "SimState",
    "make_alloc_ctx", "make_jobset", "metrics", "multicluster_result_np",
    "policies_id", "result_from_state", "simulate", "simulate_alloc_sweep",
    "simulate_ensemble", "simulate_multicluster", "simulate_np",
    "simulate_window", "stack_jobsets",
]
