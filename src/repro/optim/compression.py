"""int8 gradient compression with error feedback (1-bit-Adam-style residual).

On a real multi-pod deployment this wraps the cross-pod (DCN) gradient
all-reduce: quantize -> reduce int8 payload (4x fewer bytes) -> dequantize,
with the quantization residual carried into the next step so the compressed
SGD direction is unbiased in the long run (error-feedback guarantee).

In the single-controller SPMD program the reduction itself is implicit in
the backward pass, so we expose the compression as a gradient transform
applied at the reduction point; tests verify (a) the error-feedback
telescoping property and (b) convergence parity on a convex problem.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class CompressionState:
    residual: Any  # error-feedback buffer, same tree as grads


def compression_init(params) -> CompressionState:
    return CompressionState(
        residual=jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
    )


def _quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    absmax = jnp.max(jnp.abs(x))
    scale = jnp.maximum(absmax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compress_gradients(
    grads, state: CompressionState,
) -> Tuple[Any, CompressionState, dict]:
    """Returns (dequantized grads, new residual state, metrics)."""

    def one(g, r):
        gf = g.astype(jnp.float32) + r
        q, scale = _quantize_int8(gf)
        deq = q.astype(jnp.float32) * scale
        return deq.astype(g.dtype), gf - deq

    out = jax.tree.map(one, grads, state.residual)
    newg = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    newr = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    err = sum(jnp.sum(jnp.abs(r)) for r in jax.tree.leaves(newr))
    return newg, CompressionState(residual=newr), {"ef_l1": err}


def payload_bytes(grads, compressed: bool) -> int:
    """Collective payload accounting for EXPERIMENTS.md (f32 vs int8+scale)."""
    n = sum(x.size for x in jax.tree.leaves(grads))
    return n + 4 * len(jax.tree.leaves(grads)) if compressed else 4 * n
