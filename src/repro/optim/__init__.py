from repro.optim.adamw import (  # noqa: F401
    AdamWConfig, OptState, adamw_init, adamw_update, global_norm,
    cosine_schedule,
)
from repro.optim.compression import (  # noqa: F401
    CompressionState, compress_gradients, compression_init,
)
