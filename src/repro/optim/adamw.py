"""AdamW (decoupled weight decay) + schedules, pytree-native.

Optimizer state mirrors the parameter tree leaf-for-leaf, so the same
PartitionSpecs shard it (ZeRO: FSDP'd params => FSDP'd moments for free).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class OptState:
    m: Any
    v: Any
    step: jax.Array


def adamw_init(params) -> OptState:
    zeros = lambda p: jnp.zeros_like(p)
    return OptState(
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
        step=jnp.zeros((), jnp.int32),
    )


def cosine_schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip(
        (step - cfg.warmup_steps) / max(cfg.total_steps - cfg.warmup_steps, 1),
        0.0, 1.0,
    )
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * cos


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
            for x in jax.tree.leaves(tree))
    )


def adamw_update(
    grads, state: OptState, params, cfg: AdamWConfig,
) -> Tuple[Any, OptState, dict]:
    step = state.step + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    lr = cosine_schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p
        return (p - lr * delta).astype(p.dtype), m, v

    out = jax.tree.map(upd, params, grads, state.m, state.v)
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_params, OptState(m=new_m, v=new_v, step=step), {
        "grad_norm": gnorm, "lr": lr,
    }
