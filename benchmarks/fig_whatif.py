"""What-if service benchmark (DESIGN.md §20): cold vs warm query latency.

The service's performance claim is that a long-running planner amortizes
XLA compiles across queries: the first query against a scenario bucket
pays the compile (cold), every subsequent query — different candidate
values, different deltas, same shapes — reuses the persistent executable
(warm).  This benchmark measures both paths for each query family against
the built-in demo fleet and pins the cache counters next to the timings,
so a regression that silently re-compiles per query (e.g. a static-key
change that buckets by candidate *values*) shows up as warm_compiles > 0
and a warm/cold ratio near 1.

Emits ``fig_whatif/<family>/<path>`` CSV rows and a machine-readable
``results/fig_whatif.json`` (schema 1, uploaded by the CI service-smoke
job next to the other benchmark artifacts).
"""

from __future__ import annotations

import json
import os
import time

from benchmarks import common
from repro.api import cache_stats, reset_cache_stats
from repro.service import (
    CapacityPlanner, JobRequest, Objective, ScenarioDelta, WhatIfQuery,
    demo_fleet,
)


def _queries(smoke: bool):
    # lowest point sized so the demo fleet's padded failure capacity is not
    # saturated (a truncated stream measures the cutoff, not reliability)
    mtbf_grid = (500e3, 2000e3) if smoke else (500e3, 1000e3, 2000e3, 4000e3)
    deltas = (0, 64) if smoke else (0, 32, 64, 128)
    return {
        "placement": [
            WhatIfQuery(kind="placement",
                        job=JobRequest(submit=0, runtime=400, nodes=w))
            for w in (4, 16, 48)],
        "capacity": [
            WhatIfQuery(kind="capacity", queue="batch",
                        deltas=tuple(ScenarioDelta(add_nodes=d)
                                     for d in deltas))],
        "reliability": [
            WhatIfQuery(kind="reliability", queue="flaky",
                        mtbf_grid=mtbf_grid,
                        objective=Objective(metric="goodput", goal="max"))],
    }


def _run(smoke: bool, outdir: str = "results") -> None:
    os.makedirs(outdir, exist_ok=True)
    report = {"schema": 1, "smoke": smoke,
              "generated_unix": time.time(), "cases": {}}
    families = _queries(smoke)

    for family, queries in families.items():
        # cold: drop the cached runners so the first answer recompiles
        planner = CapacityPlanner(demo_fleet())
        reset_cache_stats(clear=True)
        t0 = time.time()
        for q in queries:
            planner.answer(q)
        cold_s = time.time() - t0
        cold = cache_stats()

        reset_cache_stats()
        t0 = time.time()
        for q in queries:
            planner.answer(q)
        warm_s = time.time() - t0
        warm = cache_stats()
        assert warm.compiles == 0, (
            f"{family}: warm pass recompiled {warm.compiles}x — the "
            "persistent-executable contract regressed")

        for path, secs, stats in (("cold", cold_s, cold),
                                  ("warm", warm_s, warm)):
            report["cases"][f"{family}_{path}"] = {
                "run_s": secs, "n_queries": len(queries),
                "compiles": stats.compiles, "hits": stats.hits,
            }
            common.emit(f"fig_whatif/{family}/{path}",
                        secs / len(queries),
                        f"compiles={stats.compiles}:hits={stats.hits}")

    report["finished_unix"] = time.time()
    out = os.path.join(outdir, "fig_whatif.json")
    with open(out, "w") as f:
        json.dump(report, f, indent=1, sort_keys=True)
        f.write("\n")
    print(f"# wrote {out}", flush=True)


def main() -> None:
    _run(smoke=False)


def smoke() -> None:
    _run(smoke=True)


if __name__ == "__main__":
    import sys

    smoke() if "--smoke" in sys.argv else main()
