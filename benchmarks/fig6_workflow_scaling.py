"""Paper Fig. 6: scalability of workflow simulation (Galactic Plane).

Scales the Galactic-like workflow (union of Montage tile sub-workflows) in
size and in ensemble width, reporting tasks/second.
"""

from __future__ import annotations

import os

import jax
import numpy as np

from benchmarks.common import emit, series_to_csv, time_call
from repro.core.workflow import WF_POLICY_IDS, make_taskset, simulate_workflow
from repro.traces import workflows as W

POOLS = np.array([64, 1 << 20])


def main(outdir: str = "results") -> None:
    os.makedirs(outdir, exist_ok=True)
    rows = []
    for tiles in (2, 4, 8, 16):
        wf = W.galactic_like(tiles, 12, seed=tiles)
        n = len(wf["exec_time"])
        ts = make_taskset(wf["exec_time"], wf["resources"], wf["dep_pairs"])
        t = time_call(
            lambda: simulate_workflow(ts, POOLS, WF_POLICY_IDS["fcfs_fit"]).n_events)
        rows.append((tiles, n, t, n / t))
        emit(f"fig6_galactic_tiles{tiles}", t,
             f"tasks={n};tasks_per_s={n / t:.0f}")

    # ensemble width (the parallel axis): vmap W copies vs python loop
    wf = W.galactic_like(4, 12, seed=9)
    ts = make_taskset(wf["exec_time"], wf["resources"], wf["dep_pairs"])
    for width in (1, 8, 32):
        batched = jax.tree.map(
            lambda x: jax.numpy.broadcast_to(x, (width,) + x.shape), ts)
        pools_b = np.broadcast_to(POOLS, (width, 2))
        fn = jax.jit(jax.vmap(
            lambda t_, p_: simulate_workflow(t_, p_, WF_POLICY_IDS["fcfs_fit"])))
        t = time_call(lambda: fn(batched, pools_b).n_events)
        n = len(wf["exec_time"]) * width
        emit(f"fig6_ensemble_w{width}", t, f"tasks_per_s={n / t:.0f}")
        rows.append((f"ens{width}", n, t, n / t))
    series_to_csv(os.path.join(outdir, "fig6_workflow_scaling.csv"),
                  ["scale", "tasks", "seconds", "tasks_per_s"], rows)


if __name__ == "__main__":
    main()
