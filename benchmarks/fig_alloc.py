"""Allocation-strategy comparison (DESIGN.md §11): the same trace under
different allocators yields different makespans, waits, locality and
fragmentation — the scenario family the seed scalar counter could not
express.

Runs the 4 strategies x {contention off, on} on a dragonfly machine over a
synthetic SDSC-SP2-like trace (and a real SWF trace if ``REPRO_SWF`` points
at one), as one vmapped ensemble per contention setting.  Emits
``fig_alloc/<trace>/<strategy>[+con]`` rows with
``makespan:avg_wait:mean_span:mean_frag`` in the derived column; the full
table lands in ``results/fig_alloc.csv``.
"""

from __future__ import annotations

import os

import numpy as np

from benchmarks import common
from repro import alloc
from repro.core import metrics
from repro.core.jobs import POLICY_IDS, make_jobset
from repro.core.parallel import simulate_alloc_sweep
from repro.traces import sdsc_sp2_like
from repro.traces.swf import load_swf

STRATEGIES = ("simple", "contiguous", "spread", "topo")


def _sweep_rows(tag, trace, machine, total_nodes, contention, rows):
    jobs = make_jobset(trace["submit"], trace["runtime"], trace["nodes"],
                       trace.get("estimate"), capacity=None,
                       total_nodes=total_nodes)
    policy = POLICY_IDS["backfill"]

    def run():
        return simulate_alloc_sweep(jobs, policy, total_nodes, machine,
                                    STRATEGIES, contention=contention)

    # one warmup (compile), one timed run whose result feeds the metrics
    secs = common.time_call(run, warmup=1, iters=1)
    res = run()
    suffix = "+con" if contention is not None else ""
    valid = np.asarray(jobs.valid)
    for i, strat in enumerate(STRATEGIES):
        n_ev = int(res.n_events[i])
        out = {
            "valid": valid, "done": np.asarray(res.done[i]),
            "submit": np.asarray(jobs.submit), "nodes": np.asarray(jobs.nodes),
            "runtime": np.asarray(jobs.runtime),
            "start": np.asarray(res.start[i]), "finish": np.asarray(res.finish[i]),
            "alloc_span": np.asarray(res.alloc_span[i]),
            "ev_time": np.asarray(res.ev_time[i])[:n_ev],
            "ev_free": np.asarray(res.ev_free[i])[:n_ev],
            "ev_lfb": np.asarray(res.ev_lfb[i])[:n_ev],
        }
        s = metrics.summary(out, total_nodes)
        a = metrics.alloc_summary(out)
        derived = (f"{s['makespan']:.0f}:{s['avg_wait']:.1f}"
                   f":{a['mean_job_span']:.2f}:{a['mean_frag']:.3f}")
        common.emit(f"fig_alloc/{tag}/{strat}{suffix}", secs / len(STRATEGIES),
                    derived)
        rows.append((tag, strat, contention is not None, s["makespan"],
                     s["avg_wait"], s["utilization"], a["mean_job_span"],
                     a["mean_frag"], a["min_largest_free_block"]))


def _run(n_jobs: int, groups: int, per_group: int):
    total = groups * per_group
    machine = alloc.dragonfly(groups, per_group)
    con = alloc.Contention.make(1, 5)  # +20% runtime per extra group spanned
    rows: list = []

    trace = sdsc_sp2_like(n_jobs, seed=7)
    _sweep_rows("sdsc_sp2_like", trace, machine, total, None, rows)
    _sweep_rows("sdsc_sp2_like", trace, machine, total, con, rows)

    swf_path = os.environ.get("REPRO_SWF", "")
    if swf_path and os.path.exists(swf_path):
        swf = load_swf(swf_path, max_jobs=n_jobs)
        _sweep_rows(os.path.basename(swf_path), swf, machine, total, None, rows)
        _sweep_rows(os.path.basename(swf_path), swf, machine, total, con, rows)

    os.makedirs("results", exist_ok=True)
    common.series_to_csv(
        "results/fig_alloc.csv",
        ("trace", "strategy", "contention", "makespan", "avg_wait",
         "utilization", "mean_job_span", "mean_frag", "min_largest_free_block"),
        rows,
    )


def main():
    _run(n_jobs=1000, groups=16, per_group=8)


def smoke():
    """CI dry pass: tiny trace + machine, same code path."""
    _run(n_jobs=120, groups=4, per_group=4)
