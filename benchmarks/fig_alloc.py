"""Allocation-strategy comparison (DESIGN.md §11): the same trace under
different allocators yields different makespans, waits, locality and
fragmentation — the scenario family the seed scalar counter could not
express.

One ``sweep()`` per trace runs the full 4-strategy × 2-contention grid as
a single vmapped executable (DESIGN.md §12) over a dragonfly machine, on a
synthetic SDSC-SP2-like trace (and a real SWF trace if ``REPRO_SWF``
points at one).  Emits ``fig_alloc/<trace>/<strategy>[+con]`` rows with
``makespan:avg_wait:mean_span:mean_frag`` in the derived column; the full
table lands in ``results/fig_alloc.csv``.
"""

from __future__ import annotations

import os

from benchmarks import common
from repro.api import (
    Scenario, SwfTrace, SyntheticTrace, Topology, sweep,
)

STRATEGIES = ("simple", "contiguous", "spread", "topo")
CONTENTIONS = (None, (1, 5))  # off / +20% runtime per extra group spanned


def _sweep_rows(tag, base: Scenario, rows: list):
    grid_holder = []

    def run_grid():
        grid_holder[:] = [sweep(base, axes={"contention": CONTENTIONS,
                                            "alloc": STRATEGIES})]
        return [r.raw.n_events for r in grid_holder[0].results]

    # one warmup (compile), one timed run whose result feeds the metrics
    secs = common.time_call(run_grid, warmup=1, iters=1)
    grid = grid_holder[0]
    n_points = len(grid)
    for point, res in grid:
        s = res.summary()
        suffix = "+con" if point["contention"] is not None else ""
        derived = (f"{s['makespan']:.0f}:{s['avg_wait']:.1f}"
                   f":{s['mean_job_span']:.2f}:{s['mean_frag']:.3f}")
        common.emit(f"fig_alloc/{tag}/{point['alloc']}{suffix}",
                    secs / n_points, derived)
        rows.append((tag, point["alloc"], point["contention"] is not None,
                     s["makespan"], s["avg_wait"], s["utilization"],
                     s["mean_job_span"], s["mean_frag"],
                     s["min_largest_free_block"]))


def _run(n_jobs: int, groups: int, per_group: int):
    topo = Topology.dragonfly(groups, per_group)
    rows: list = []

    base = Scenario(trace=SyntheticTrace(n_jobs=n_jobs, seed=7, kind="sdsc_sp2"),
                    topology=topo, policy="backfill")
    _sweep_rows("sdsc_sp2_like", base, rows)

    swf_path = os.environ.get("REPRO_SWF", "")
    if swf_path and os.path.exists(swf_path):
        swf_base = base.with_(trace=SwfTrace(swf_path, max_jobs=n_jobs))
        _sweep_rows(os.path.basename(swf_path), swf_base, rows)

    os.makedirs("results", exist_ok=True)
    common.series_to_csv(
        "results/fig_alloc.csv",
        ("trace", "strategy", "contention", "makespan", "avg_wait",
         "utilization", "mean_job_span", "mean_frag", "min_largest_free_block"),
        rows,
    )


def main():
    _run(n_jobs=1000, groups=16, per_group=8)


def smoke():
    """CI dry pass: tiny trace + machine, same code path."""
    _run(n_jobs=120, groups=4, per_group=4)
