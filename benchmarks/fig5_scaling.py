"""Paper Fig. 5: parallel performance of the scheduler.

The paper scales MPI ranks; our SPMD analogue has two measurable axes on
this 1-physical-core container:

  (a) *vectorized ensemble*: B independent simulations batched with vmap vs.
      a serial python loop — the SIMD parallelism that maps 1:1 onto devices
      (each device runs its ensemble shard with zero communication);
  (b) *job-size scaling*: events/second as the per-simulation job count
      grows (the paper's "greater speedup for larger jobs" effect —
      vector lanes amortize fixed per-event cost);
  (c) *device-partitioned run*: subprocess with XLA host devices ∈ {1,2,4}
      running the sharded ensemble — demonstrates the partitioning is real;
      wall-clock speedup is bounded by the single physical core, so we
      report events/s and note the bound.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys

import jax
import numpy as np

from benchmarks.common import emit, series_to_csv, time_call
from repro.core.engine import simulate
from repro.core.jobs import POLICY_IDS, make_jobset
from repro.core.parallel import simulate_ensemble, stack_jobsets
from repro.traces import das2_like


def _jobsets(B, J, seed0=100):
    return [
        make_jobset(*(lambda t: (t["submit"], t["runtime"], t["nodes"],
                                 t["estimate"]))(das2_like(J, seed=seed0 + i)),
        total_nodes=400)
        for i in range(B)
    ]


def bench_ensemble(outdir: str):
    J = 300
    rows = []
    for B in (1, 4, 16, 64):
        jsets = _jobsets(B, J)
        jb = stack_jobsets(jsets)
        pols = np.full((B,), POLICY_IDS["backfill"], np.int32)
        nodes = np.full((B,), 400, np.int32)

        t_vmap = time_call(lambda: simulate_ensemble(jb, pols, nodes).n_events)
        t_loop = time_call(
            lambda: [simulate(js, POLICY_IDS["backfill"], 400).n_events
                     for js in jsets],
            warmup=1, iters=1)
        events = B * 2 * J
        rows.append((B, t_loop, t_vmap, t_loop / t_vmap, events / t_vmap))
        emit(f"fig5_ensemble_B{B}", t_vmap,
             f"speedup_vs_serial={t_loop / t_vmap:.2f};events_per_s={events / t_vmap:.0f}")
    series_to_csv(os.path.join(outdir, "fig5_ensemble.csv"),
                  ["batch", "t_serial_s", "t_vmap_s", "speedup", "events_per_s"],
                  rows)


def bench_job_size(outdir: str):
    rows = []
    for J in (200, 1000, 4000):
        js = _jobsets(1, J)[0]
        t = time_call(lambda: simulate(js, POLICY_IDS["fcfs"], 400).n_events)
        rows.append((J, t, 2 * J / t))
        emit(f"fig5_jobsize_J{J}", t, f"events_per_s={2 * J / t:.0f}")
    series_to_csv(os.path.join(outdir, "fig5_jobsize.csv"),
                  ["jobs", "seconds", "events_per_s"], rows)


_CHILD = r"""
import os, sys, json, time
os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={sys.argv[1]}"
sys.path.insert(0, "src")
import jax, numpy as np
from jax.sharding import Mesh
from repro.core.jobs import POLICY_IDS, make_jobset
from repro.core.parallel import simulate_ensemble, stack_jobsets
from repro.traces import das2_like
D = int(sys.argv[1]); B = 16; J = 200
jsets = [make_jobset(*(lambda t: (t["submit"], t["runtime"], t["nodes"],
         t["estimate"]))(das2_like(J, seed=i)), total_nodes=400) for i in range(B)]
jb = stack_jobsets(jsets)
mesh = Mesh(np.array(jax.devices()), ("sim",))
pols = np.full((B,), POLICY_IDS["backfill"], np.int32)
nodes = np.full((B,), 400, np.int32)
r = simulate_ensemble(jb, pols, nodes, mesh=mesh); jax.block_until_ready(r.n_events)
t0 = time.perf_counter()
r = simulate_ensemble(jb, pols, nodes, mesh=mesh); jax.block_until_ready(r.n_events)
print(json.dumps({"devices": D, "seconds": time.perf_counter() - t0,
                  "events": int(np.asarray(r.n_events).sum())}))
"""


def bench_devices(outdir: str):
    rows = []
    for d in (1, 2, 4):
        p = subprocess.run([sys.executable, "-c", _CHILD, str(d)],
                           capture_output=True, text=True, timeout=900,
                           env={**os.environ, "PYTHONPATH": "src"})
        line = p.stdout.strip().splitlines()[-1] if p.stdout.strip() else "{}"
        try:
            rec = json.loads(line)
        except json.JSONDecodeError:
            emit(f"fig5_devices_{d}", 0.0, f"FAILED:{p.stderr[-120:]}")
            continue
        rows.append((rec["devices"], rec["seconds"],
                     rec["events"] / rec["seconds"]))
        emit(f"fig5_devices_{d}", rec["seconds"],
             f"events_per_s={rec['events'] / rec['seconds']:.0f};"
             "note=1_physical_core_bounds_wallclock")
    if rows:
        series_to_csv(os.path.join(outdir, "fig5_devices.csv"),
                      ["devices", "seconds", "events_per_s"], rows)


def main(outdir: str = "results") -> None:
    os.makedirs(outdir, exist_ok=True)
    bench_ensemble(outdir)
    bench_job_size(outdir)
    bench_devices(outdir)


if __name__ == "__main__":
    main()
