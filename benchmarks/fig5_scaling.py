"""Paper Fig. 5: parallel performance of the scheduler.

The paper scales MPI ranks; our SPMD analogue has two measurable axes on
this 1-physical-core container:

  (a) *vectorized ensemble*: a B-seed ``sweep()`` (ONE vmapped executable)
      vs. a serial ``run()`` loop over the same scenarios — the SIMD
      parallelism that maps 1:1 onto devices;
  (b) *job-size scaling*: events/second as the per-simulation job count
      grows (the paper's "greater speedup for larger jobs" effect —
      vector lanes amortize fixed per-event cost);
  (c) *device-partitioned run*: subprocess with XLA host devices ∈ {1,2,4}
      running the mesh-sharded sweep — demonstrates the partitioning is
      real; wall-clock speedup is bounded by the single physical core, so
      we report events/s and note the bound.

Both sides of (a) go through the Scenario API end-to-end (trace
materialization + job-table build + device run), so the comparison is
apples-to-apples for what a user actually calls.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys

import numpy as np

from benchmarks.common import emit, series_to_csv, time_call
from repro.api import Scenario, SyntheticTrace, run, sweep

BASE = Scenario(trace=SyntheticTrace(n_jobs=300, seed=100, kind="das2"),
                total_nodes=400, policy="backfill")


def bench_ensemble(outdir: str):
    J = 300
    rows = []
    for B in (1, 4, 16, 64):
        seeds = [100 + i for i in range(B)]

        # return the n_events arrays so time_call's block_until_ready waits
        # for the async device work, not just the host-side dispatch
        t_sweep = time_call(
            lambda: [r.raw.n_events
                     for r in sweep(BASE, axes={"trace.seed": seeds}).results])
        t_loop = time_call(
            lambda: [run(BASE.with_(**{"trace.seed": s})).raw.n_events
                     for s in seeds],
            warmup=1, iters=1)
        events = B * 2 * J
        rows.append((B, t_loop, t_sweep, t_loop / t_sweep, events / t_sweep))
        emit(f"fig5_ensemble_B{B}", t_sweep,
             f"speedup_vs_serial={t_loop / t_sweep:.2f};"
             f"events_per_s={events / t_sweep:.0f}")
    series_to_csv(os.path.join(outdir, "fig5_ensemble.csv"),
                  ["batch", "t_serial_s", "t_sweep_s", "speedup",
                   "events_per_s"], rows)


def bench_job_size(outdir: str):
    rows = []
    for J in (200, 1000, 4000):
        scn = BASE.with_(policy="fcfs", trace=SyntheticTrace(
            n_jobs=J, seed=100, kind="das2"))
        t = time_call(lambda: run(scn).raw.n_events)
        rows.append((J, t, 2 * J / t))
        emit(f"fig5_jobsize_J{J}", t, f"events_per_s={2 * J / t:.0f}")
    series_to_csv(os.path.join(outdir, "fig5_jobsize.csv"),
                  ["jobs", "seconds", "events_per_s"], rows)


_CHILD = r"""
import os, sys, json, time
os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={sys.argv[1]}"
sys.path.insert(0, "src")
import jax, numpy as np
from jax.sharding import Mesh
from repro.api import Scenario, SyntheticTrace, sweep
D = int(sys.argv[1]); B = 16; J = 200
base = Scenario(trace=SyntheticTrace(n_jobs=J, seed=0, kind="das2"),
                total_nodes=400, policy="backfill")
mesh = Mesh(np.array(jax.devices()), ("sim",))
axes = {"trace.seed": list(range(B))}
g = sweep(base, axes=axes, mesh=mesh)
jax.block_until_ready(g[0].raw.n_events)
t0 = time.perf_counter()
g = sweep(base, axes=axes, mesh=mesh)
events = int(sum(np.asarray(r.raw.n_events) for r in g.results))
print(json.dumps({"devices": D, "seconds": time.perf_counter() - t0,
                  "events": events}))
"""


def bench_devices(outdir: str):
    rows = []
    for d in (1, 2, 4):
        p = subprocess.run([sys.executable, "-c", _CHILD, str(d)],
                           capture_output=True, text=True, timeout=900,
                           env={**os.environ, "PYTHONPATH": "src"})
        line = p.stdout.strip().splitlines()[-1] if p.stdout.strip() else "{}"
        try:
            rec = json.loads(line)
        except json.JSONDecodeError:
            emit(f"fig5_devices_{d}", 0.0, f"FAILED:{p.stderr[-120:]}")
            continue
        rows.append((rec["devices"], rec["seconds"],
                     rec["events"] / rec["seconds"]))
        emit(f"fig5_devices_{d}", rec["seconds"],
             f"events_per_s={rec['events'] / rec['seconds']:.0f};"
             "note=1_physical_core_bounds_wallclock")
    if rows:
        series_to_csv(os.path.join(outdir, "fig5_devices.csv"),
                      ["devices", "seconds", "events_per_s"], rows)


def main(outdir: str = "results") -> None:
    os.makedirs(outdir, exist_ok=True)
    bench_ensemble(outdir)
    bench_job_size(outdir)
    bench_devices(outdir)


if __name__ == "__main__":
    main()
