"""Measured §Perf track: DES engine throughput (events/s), JAX vs reference.

This is the paper-side performance benchmark that hillclimbs iterate on —
per-policy event throughput on a fixed trace, plus the Pallas queue_select
hot-spot microbenchmark at scheduler-relevant queue sizes.
"""

from __future__ import annotations

import os

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, series_to_csv, time_call
from repro.core.engine import simulate
from repro.core.jobs import POLICY_IDS, make_jobset
from repro.kernels.queue_select.ops import queue_select
from repro.refsim import simulate_reference
from repro.traces import sdsc_sp2_like


def main(outdir: str = "results") -> None:
    os.makedirs(outdir, exist_ok=True)
    J = 2000
    trace = sdsc_sp2_like(J, seed=13)
    jobs = make_jobset(trace["submit"], trace["runtime"], trace["nodes"],
                       trace["estimate"], total_nodes=128)
    rows = []
    for pol in ("fcfs", "sjf", "bestfit", "backfill"):
        t_jax = time_call(lambda: simulate(jobs, POLICY_IDS[pol], 128).n_events)
        t_ref = time_call(
            lambda: simulate_reference(trace, pol, total_nodes=128),
            warmup=0, iters=1)
        ev = 2 * J
        rows.append((pol, t_jax, ev / t_jax, t_ref, ev / t_ref))
        emit(f"des_throughput_{pol}", t_jax,
             f"jax_events_per_s={ev / t_jax:.0f};ref_events_per_s={ev / t_ref:.0f}")
    series_to_csv(os.path.join(outdir, "des_throughput.csv"),
                  ["policy", "t_jax_s", "jax_events_per_s", "t_ref_s",
                   "ref_events_per_s"], rows)

    # scheduler hot-spot kernel at production queue sizes
    rng = np.random.default_rng(0)
    for N in (65_536, 1_048_576):
        scores = jnp.asarray(rng.integers(0, 1 << 20, N).astype(np.int32))
        feas = jnp.asarray((rng.random(N) < 0.1).astype(np.int32))
        t = time_call(lambda: queue_select(scores, feas, tile=8192,
                                           interpret=True))
        emit(f"queue_select_N{N}", t,
             f"interpret_mode;GBps={(N * 8 / t) / 1e9:.2f}")


if __name__ == "__main__":
    main()
