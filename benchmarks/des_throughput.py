"""Measured §Perf track: DES engine throughput (events/s), JAX vs reference.

This is the paper-side performance benchmark that hillclimbs iterate on —
per-policy event throughput on a fixed trace, a deps-heavy workflow case
exercising the sparse dependency counters + batched scheduling pass
(DESIGN.md §14), and the Pallas queue_select hot-spot microbenchmark at
scheduler-relevant queue sizes.

Besides the human-readable CSV rows it emits a machine-readable
``results/BENCH_engine.json`` — one entry per case with events/s, run time
and the compile/run split — so future PRs have a perf trajectory to regress
against (acceptance floor for this PR: >= 3x events/s on the deps-heavy
workflow case vs the dense-matrix engine, >= 1.0x on no-deps FCFS).
"""

from __future__ import annotations

import json
import os
import time

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, series_to_csv, time_call
from repro.core.engine import simulate
from repro.core.jobs import POLICY_IDS, make_jobset
from repro.kernels.queue_select.ops import queue_select
from repro.refsim import simulate_reference
from repro.traces import sdsc_sp2_like
from repro.traces.workflows import galactic_like, workflow_to_trace

BENCH_JSON = "BENCH_engine.json"


def _measure(jobs, policy: str, total_nodes: int, iters: int = 3,
             service=None, malleable=None) -> dict:
    """events/s for one compiled engine call, with the compile/run split.

    The first call pays trace+compile; steady-state is the median of at
    least ``iters`` further calls, repeating (up to 15) until ~0.6 s of
    samples accumulate so millisecond-scale cases aren't at the mercy of
    scheduler noise.  ``n_events`` comes from the result itself, so the
    rate is exact for any schedule.
    """
    pol = POLICY_IDS[policy]
    t0 = time.perf_counter()
    res = simulate(jobs, pol, total_nodes, service=service,
                   malleable=malleable)
    res.n_events.block_until_ready()
    first = time.perf_counter() - t0
    times = []
    while len(times) < iters or (sum(times) < 0.6 and len(times) < 15):
        t0 = time.perf_counter()
        res = simulate(jobs, pol, total_nodes, service=service,
                       malleable=malleable)
        res.n_events.block_until_ready()
        times.append(time.perf_counter() - t0)
    run_s = float(np.median(times))
    n_events = int(res.n_events)
    return {
        "n_events": n_events,
        "run_s": run_s,
        "events_per_s": n_events / run_s,
        "compile_s": max(first - run_s, 0.0),
    }


def _galactic_jobs(tiles: int, width: int, total_nodes: int):
    """The deps-heavy workload: a chain-of-montage-tiles Galactic Plane DAG
    lowered onto the cluster (PR 3's workload at benchmark scale)."""
    trace = workflow_to_trace(galactic_like(tiles=tiles, width=width, seed=0))
    jobs = make_jobset(
        trace["submit"], trace["runtime"], trace["nodes"], trace["estimate"],
        deps=trace["deps"], total_nodes=total_nodes,
    )
    meta = {"n_jobs": len(trace["submit"]), "n_edges": len(trace["deps"]),
            "total_nodes": total_nodes}
    return jobs, meta


def run_bench(outdir: str = "results", *, smoke: bool = False) -> dict:
    os.makedirs(outdir, exist_ok=True)
    # schema 3: queue_select cases are timed compiled and carry
    # bytes/tile/mode so GB/s figures are comparable across cases
    # (schema 2 added generated_unix/finished_unix); pinned by
    # tests/test_bench_schema.py — bump the version when keys change
    report: dict = {"schema": 3, "smoke": smoke, "cases": {},
                    "generated_unix": time.time()}

    # ---- no-deps policy throughput on the SDSC-SP2-like trace --------------
    J = 200 if smoke else 2000
    total_nodes = 128
    trace = sdsc_sp2_like(J, seed=13)
    jobs = make_jobset(trace["submit"], trace["runtime"], trace["nodes"],
                       trace["estimate"], total_nodes=total_nodes)
    rows = []
    for pol in ("fcfs", "sjf", "bestfit", "backfill"):
        m = _measure(jobs, pol, total_nodes)
        t0 = time.perf_counter()
        ref = simulate_reference(trace, pol, total_nodes=total_nodes)
        t_ref = time.perf_counter() - t0
        ref_rate = ref["n_events"] / t_ref
        report["cases"][f"nodeps_{pol}"] = {
            **m, "trace": "sdsc_sp2_like", "n_jobs": J,
            "total_nodes": total_nodes, "ref_events_per_s": ref_rate,
        }
        rows.append((pol, m["run_s"], m["events_per_s"], t_ref, ref_rate))
        emit(f"des_throughput_{pol}", m["run_s"],
             f"jax_events_per_s={m['events_per_s']:.0f};"
             f"ref_events_per_s={ref_rate:.0f}")
    series_to_csv(os.path.join(outdir, "des_throughput.csv"),
                  ["policy", "t_jax_s", "jax_events_per_s", "t_ref_s",
                   "ref_events_per_s"], rows)

    # ---- deps-heavy workflow cases (sparse counters + batched pass) --------
    wf_cases = ([("galactic_smoke", 2, 5, 16)] if smoke else
                [("galactic521", 8, 20, 64), ("galactic8k", 200, 12, 256)])
    for name, tiles, width, nodes in wf_cases:
        gjobs, meta = _galactic_jobs(tiles, width, nodes)
        for pol in ("fcfs", "backfill") if not smoke else ("fcfs",):
            m = _measure(gjobs, pol, nodes, iters=1 if name == "galactic8k" else 3)
            report["cases"][f"{name}_{pol}"] = {**m, **meta}
            emit(f"des_throughput_{name}_{pol}", m["run_s"],
                 f"jax_events_per_s={m['events_per_s']:.0f};"
                 f"n_edges={meta['n_edges']}")

    # ---- open-arrival serving case (deadline state + autoscale ticks) ------
    from repro.api import (AutoscalePolicy, Scenario, ServiceClass,
                           ServiceTrace, build_jobset)

    svc_spec = ServiceTrace(
        horizon=4096 if smoke else 1 << 16, rate=0.04, seed=5,
        max_jobs=256 if smoke else 4096,
        classes=(ServiceClass("interactive", nodes=1, mean_runtime=30,
                              slo_wait=60),
                 ServiceClass("batch", nodes=8, mean_runtime=600,
                              dist="exponential", slo_wait=1800, weight=0.3)),
        autoscale=AutoscalePolicy(up_threshold=48, down_threshold=8,
                                  min_nodes=16, max_nodes=64, step=8,
                                  interval=256,
                                  max_ticks=16 if smoke else 256))
    svc_scn = Scenario(trace=svc_spec, total_nodes=64, policy="fcfs")
    svc_jobs = build_jobset(svc_scn)
    m = _measure(svc_jobs, "fcfs", 64, service=svc_spec.plan())
    report["cases"]["serving_open_fcfs"] = {
        **m, "trace": "service_poisson", "n_jobs": svc_spec.plan().n_requests,
        "total_nodes": 64,
    }
    emit("des_throughput_serving_open_fcfs", m["run_s"],
         f"jax_events_per_s={m['events_per_s']:.0f};"
         f"n_requests={svc_spec.plan().n_requests}")

    # ---- moldable width choice on the no-deps trace (DESIGN.md §17) --------
    from repro.malleable import MalleableModel, make_mal_ctx, materialize_plan

    mal_model = MalleableModel(curve="amdahl", param=0.1, min_width=1,
                               max_width=16, mode="moldable")
    mal_plan = materialize_plan(mal_model, trace, total_nodes=total_nodes)
    m = _measure(jobs, "backfill", total_nodes,
                 malleable=make_mal_ctx(mal_plan))
    report["cases"]["moldable_backfill"] = {
        **m, "trace": "sdsc_sp2_like", "n_jobs": J,
        "total_nodes": total_nodes, "n_widths": mal_plan.n_widths,
    }
    emit("des_throughput_moldable_backfill", m["run_s"],
         f"jax_events_per_s={m['events_per_s']:.0f};"
         f"n_widths={mal_plan.n_widths}")

    # ---- streaming trace replay (DESIGN.md §19) ----------------------------
    # archive-scale jobs/s through the bounded-window crash-safe runner; the
    # arrival rate puts utilization ~0.76, so the backlog stays inside the
    # window (no doubling ladder) — replay_smoke.py covers degraded paths
    from repro.replay import replay_trace
    from repro.traces import synthetic_trace

    RJ = 2_000 if smoke else 200_000
    rwin = 512 if smoke else 4096
    rtrace = synthetic_trace(RJ, seed=3, mean_interarrival=220.0)
    t0 = time.perf_counter()
    rres = replay_trace(rtrace, "backfill", total_nodes=128, window=rwin)
    t_rep = time.perf_counter() - t0
    rsum = rres.summary()
    report["cases"]["trace_replay"] = {
        # single-shot timing: the per-window-shape compiles are part of a
        # real replay, so they stay inside run_s (conservative rate)
        "run_s": t_rep,
        "n_events": rsum["n_events"],
        "events_per_s": rsum["n_events"] / t_rep,
        "compile_s": 0.0,
        "n_jobs": RJ,
        "jobs_per_s": RJ / t_rep,
        "window": rsum["window"],
        "peak_live": rsum["peak_live"],
        "n_rounds": rsum["n_rounds"],
        "trace": "synthetic", "total_nodes": 128,
    }
    emit("trace_replay", t_rep,
         f"jobs_per_s={RJ / t_rep:.0f};rounds={rsum['n_rounds']};"
         f"peak_live={rsum['peak_live']}")

    # ---- scheduler hot-spot kernel at production queue sizes ---------------
    # Timed on the *compiled* default lowering (Pallas on TPU, blocked jnp
    # reduction elsewhere — ISSUE 8: the old interpret=True default timed
    # the Pallas Python interpreter, reading 0.04 GB/s at N=1M).  GB/s is
    # derived from the actual argument nbytes, not a hardcoded element size.
    rng = np.random.default_rng(0)
    tile = 8192
    for N in ((65_536,) if smoke else (65_536, 1_048_576)):
        scores = jnp.asarray(rng.integers(0, 1 << 20, N).astype(np.int32))
        feas = jnp.asarray((rng.random(N) < 0.1).astype(np.int32))
        t = time_call(lambda: queue_select(scores, feas, tile=tile))
        nbytes = int(scores.nbytes) + int(feas.nbytes)
        gbps = (nbytes / t) / 1e9
        report["cases"][f"queue_select_N{N}"] = {
            "run_s": t, "GBps": gbps, "bytes": nbytes, "tile": tile,
            "mode": "compiled",
        }
        emit(f"queue_select_N{N}", t, f"compiled;tile={tile};GBps={gbps:.2f}")

    report["finished_unix"] = time.time()
    path = os.path.join(outdir, BENCH_JSON)
    with open(path, "w") as f:
        json.dump(report, f, indent=1, sort_keys=True)
    print(f"# wrote {path}", flush=True)
    return report


def main(outdir: str = "results") -> None:
    run_bench(outdir, smoke=False)


def smoke(outdir: str = "results") -> None:
    """CI dry pass: tiny sizes, same artifact schema (uploaded by CI)."""
    run_bench(outdir, smoke=True)


if __name__ == "__main__":
    import sys
    smoke() if "--smoke" in sys.argv else main()
