"""Run the full (arch x shape x mesh) dry-run sweep, one subprocess per cell.

Each cell runs in a fresh process (jax locks the host-device count at init
and compile state accumulates), writes results/dryrun/<arch>_<shape>_<mesh>.json
and is skipped on re-run if the JSON already exists (resumable).

    PYTHONPATH=src python -m benchmarks.dryrun_sweep [--mesh single|multi|both]
        [--only arch1,arch2] [--timeout 3600]
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

ARCHS = [
    "llama4-scout-17b-a16e", "mixtral-8x7b", "mistral-nemo-12b",
    "llama3.2-3b", "stablelm-3b", "h2o-danube-1.8b", "zamba2-2.7b",
    "rwkv6-7b", "qwen2-vl-72b", "seamless-m4t-medium",
]
SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]

# fail-fast ordering: one cell per (family x kind) first, then the rest
PRIORITY = [
    ("llama3.2-3b", "train_4k"), ("llama3.2-3b", "decode_32k"),
    ("mixtral-8x7b", "train_4k"), ("zamba2-2.7b", "train_4k"),
    ("rwkv6-7b", "train_4k"), ("qwen2-vl-72b", "prefill_32k"),
    ("seamless-m4t-medium", "train_4k"), ("rwkv6-7b", "long_500k"),
]


def cell_list(meshes, only=None):
    cells, seen = [], set()
    for mesh in meshes:
        for a, s in PRIORITY:
            if (a, s, mesh) not in seen:
                cells.append((a, s, mesh)); seen.add((a, s, mesh))
        for a in ARCHS:
            for s in SHAPES:
                if (a, s, mesh) not in seen:
                    cells.append((a, s, mesh)); seen.add((a, s, mesh))
    if only:
        cells = [c for c in cells if c[0] in only]
    return cells


def out_path(outdir, a, s, mesh):
    safe = a.replace("/", "_")
    return os.path.join(outdir, f"{safe}__{s}__{mesh}.json")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--only", default=None)
    ap.add_argument("--timeout", type=int, default=3600)
    ap.add_argument("--outdir", default="results/dryrun")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args(argv)
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    only = set(args.only.split(",")) if args.only else None
    os.makedirs(args.outdir, exist_ok=True)

    cells = cell_list(meshes, only)
    t00 = time.time()
    n_ok = n_skip = n_err = 0
    for i, (a, s, mesh) in enumerate(cells):
        path = out_path(args.outdir, a, s, mesh)
        if os.path.exists(path) and not args.force:
            try:
                st = json.load(open(path)).get("status")
                if st in ("ok", "skipped"):
                    n_skip += 1
                    continue
            except Exception:
                pass
        t0 = time.time()
        cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", a,
               "--shape", s, "--mesh", mesh, "--out", path]
        env = dict(os.environ)
        env["PYTHONPATH"] = "src"
        try:
            p = subprocess.run(cmd, capture_output=True, text=True,
                               timeout=args.timeout, env=env)
            status = "ok" if p.returncode == 0 else "err"
        except subprocess.TimeoutExpired:
            status = "timeout"
            with open(path, "w") as f:
                json.dump({"arch": a, "shape": s, "mesh": mesh,
                           "status": "error", "error": "timeout"}, f)
        dt = time.time() - t0
        if status == "ok":
            n_ok += 1
        else:
            n_err += 1
        tail = ""
        if status != "ok":
            tail = (p.stderr or "")[-400:].replace("\n", " | ") if status == "err" else "timeout"
        print(f"[{i+1}/{len(cells)}] {a} x {s} [{mesh}] -> {status} "
              f"({dt:.0f}s, total {(time.time()-t00)/60:.1f}m) {tail}",
              flush=True)
    print(f"done: ok={n_ok} cached={n_skip} err={n_err}")


if __name__ == "__main__":
    main()
