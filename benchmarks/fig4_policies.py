"""Paper Fig. 4(b): comparative analysis of the five scheduling policies —
one ``sweep()`` over the policy axis, one compiled executable."""

from __future__ import annotations

import os

from benchmarks.common import emit, sweep_to_csv
from repro.api import Scenario, SyntheticTrace, sweep

POLICIES = ("fcfs", "bestfit", "backfill", "sjf", "ljf")

# congest=2 halves inter-arrival gaps so the policies diverge
BASE = Scenario(
    trace=SyntheticTrace(n_jobs=3000, seed=4, kind="sdsc_sp2", congest=2),
    total_nodes=128,
)


def main(outdir: str = "results") -> None:
    os.makedirs(outdir, exist_ok=True)
    grid = sweep(BASE, axes={"policy": POLICIES})
    for point, res in grid:
        s = res.summary()
        emit(f"fig4b_policy_{point['policy']}", 0.0,
             f"avg_wait={s['avg_wait']:.0f};util={s['utilization']:.3f};"
             f"bsld={s['avg_bounded_slowdown']:.1f}")
    sweep_to_csv(os.path.join(outdir, "fig4_policies.csv"), grid,
                 ["avg_wait", "p95_wait", "avg_bounded_slowdown",
                  "utilization", "makespan"])


if __name__ == "__main__":
    main()
