"""Paper Fig. 4(b): comparative analysis of the five scheduling policies."""

from __future__ import annotations

import os

from benchmarks.common import emit, series_to_csv
from repro.core import metrics
from repro.core.engine import simulate_np
from repro.traces import sdsc_sp2_like

POLICIES = ("fcfs", "bestfit", "backfill", "sjf", "ljf")


def main(outdir: str = "results") -> None:
    os.makedirs(outdir, exist_ok=True)
    trace = sdsc_sp2_like(3000, seed=4)
    trace["submit"] = trace["submit"] // 2  # congest so policies differ
    rows = []
    for p in POLICIES:
        out = simulate_np(trace, p, total_nodes=128)
        s = metrics.summary(out, 128)
        rows.append((p, s["avg_wait"], s["p95_wait"],
                     s["avg_bounded_slowdown"], s["utilization"],
                     s["makespan"]))
        emit(f"fig4b_policy_{p}", 0.0,
             f"avg_wait={s['avg_wait']:.0f};util={s['utilization']:.3f};"
             f"bsld={s['avg_bounded_slowdown']:.1f}")
    series_to_csv(os.path.join(outdir, "fig4_policies.csv"),
                  ["policy", "avg_wait", "p95_wait", "bounded_slowdown",
                   "utilization", "makespan"], rows)


if __name__ == "__main__":
    main()
