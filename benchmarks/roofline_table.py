"""Collect results/dryrun/*.json into the EXPERIMENTS.md roofline tables."""

from __future__ import annotations

import glob
import json
import os

from benchmarks.common import emit

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(outdir="results/dryrun"):
    recs = {}
    for p in glob.glob(os.path.join(outdir, "*.json")):
        r = json.load(open(p))
        recs[(r["arch"], r["shape"], r.get("mesh", "single"))] = r
    return recs


def fmt_row(r):
    if r.get("status") == "skipped":
        return "| {arch} | {shape} | — | skipped: sub-quadratic-only cell | | | | | |".format(**r)
    if r.get("status") != "ok":
        return "| {arch} | {shape} | — | ERROR {err} | | | | | |".format(
            err=r.get("error", "?")[:40], **r)
    return ("| {arch} | {shape} | {rules} | {bot} | {tc:.4f} | {tm:.4f} | "
            "{tl:.4f} | {uf:.2f} | {hbm:.1f} |").format(
        arch=r["arch"], shape=r["shape"], rules=r["rules"],
        bot=r["bottleneck"], tc=r["t_compute_s"], tm=r["t_memory_s"],
        tl=r["t_collective_s"], uf=r["useful_flops_ratio"],
        hbm=r["memory"]["peak_bytes_per_device"] / 2**30)


def markdown(recs, mesh="single"):
    lines = [
        "| arch | shape | rules | bound | t_compute (s) | t_memory (s) | "
        "t_collective (s) | useful-FLOPs | HBM peak (GiB/dev) |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for (a, s, m), r in sorted(recs.items()):
        if m != mesh:
            continue
        lines.append(fmt_row(r))
    return "\n".join(lines)


def main(outdir: str = "results") -> None:
    recs = load()
    if not recs:
        emit("roofline_table", 0.0, "no dryrun results found")
        return
    n_ok = sum(1 for r in recs.values() if r.get("status") == "ok")
    n_skip = sum(1 for r in recs.values() if r.get("status") == "skipped")
    with open(os.path.join(outdir, "roofline_single.md"), "w") as f:
        f.write(markdown(recs, "single"))
    with open(os.path.join(outdir, "roofline_multi.md"), "w") as f:
        f.write(markdown(recs, "multi"))
    fits = sum(1 for r in recs.values() if r.get("status") == "ok"
               and r["memory"]["peak_bytes_per_device"] < 16 * 2**30)
    emit("roofline_table", 0.0,
         f"cells_ok={n_ok};skipped={n_skip};fit_under_16GiB={fits}")


if __name__ == "__main__":
    main()
