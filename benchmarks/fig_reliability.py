"""Reliability figure (DESIGN.md §15): goodput and wait vs MTBF.

The scenario family the failure subsystem opens: one congested SDSC-SP2-
like workload swept over a node-MTBF grid under both kill rules, and a
checkpoint-interval tuning curve at fixed MTBF.  Each sweep compiles to
ONE executable (failure streams are vmap leaves; ``max_failures`` is the
only static axis).  The smoke pass validates EVERY grid point bit-exactly
against the host reference simulator (schedules and reliability columns);
the full run oracle-checks a sampled harshest-MTBF point.

Emits ``fig_reliability/<rule>/mtbf=<m>`` rows with
``goodput:avg_wait:restarts:aborted`` in the derived column; the table
lands in ``results/fig_reliability.csv`` and a machine-readable
``results/fig_reliability.json`` (uploaded by CI next to
``BENCH_engine.json``).
"""

from __future__ import annotations

import json
import os
import time

from benchmarks import common
from repro.api import FailureModel, Scenario, SyntheticTrace, run_ref, sweep

# per-node MTBF (s) sized so the harshest point stays within the padded
# stream capacity — a saturated max_failures would concentrate every
# failure in the earliest window and the sweep would measure truncation,
# not reliability (materialize() warns; the truncation guard below hard-fails)
MTBFS = (50e3, 100e3, 200e3, 400e3, 800e3, 1600e3)
CKPTS = (0, 600, 3600, 14400)


def _grid_rows(tag, base, axes, rows, report, *, validate):
    import numpy as np

    grid_holder = []

    def run_grid():
        grid_holder[:] = [sweep(base, axes=axes)]
        return [r.raw.n_events for r in grid_holder[0].results]

    secs = common.time_call(run_grid, warmup=1, iters=1)
    grid = grid_holder[0]
    assert grid.n_compiles == 1, grid.n_compiles
    for point, res in grid:
        scn = res.scenario
        assert not scn.failures.materialize(int(scn.total_nodes)).truncated, \
            f"failure stream truncated at {point}; raise max_failures"
        if validate:
            ref = run_ref(scn)
            assert res.matches(ref), point
            for col in ("n_restarts", "lost_work", "aborted"):
                n = int(ref["valid"].sum())
                assert np.array_equal(res[col][:n], ref[col]), (point, col)
        s = res.summary()
        label = "/".join(f"{k.split('.')[-1]}={v}" for k, v in point.items())
        derived = (f"{s['goodput']:.4f}:{s['avg_wait']:.1f}"
                   f":{s['total_restarts']:.0f}:{s['n_aborted']:.0f}")
        common.emit(f"fig_reliability/{tag}/{label}", secs / len(grid),
                    derived)
        axis = list(point.values()) + [""] * (2 - len(point))
        rows.append((tag, axis[0], axis[1], s["goodput"], s["avg_wait"],
                     s["p95_wait"], s["total_restarts"], s["n_aborted"],
                     s["lost_node_s"], s["makespan"]))
        report["points"].append({"tag": tag, **point, **{
            k: s[k] for k in ("goodput", "avg_wait", "p95_wait",
                              "total_restarts", "n_aborted", "lost_node_s",
                              "makespan", "utilization")}})


def _run(n_jobs: int, max_failures: int, horizon: int, *, validate: bool,
         outdir: str = "results", smoke: bool = False):
    os.makedirs(outdir, exist_ok=True)
    report = {"schema": 1, "smoke": smoke, "generated_unix": time.time(),
              "points": []}
    rows: list = []
    base = Scenario(
        trace=SyntheticTrace(n_jobs=n_jobs, seed=11, kind="sdsc_sp2",
                             congest=4),
        total_nodes=128, policy="backfill",
        failures=FailureModel(mtbf=MTBFS[0], seed=3, mean_repair=600,
                              horizon=horizon, max_failures=max_failures,
                              checkpoint_interval=3600))

    # goodput & wait vs MTBF, requeue vs abort, one executable
    _grid_rows("mtbf", base,
               {"failures.mtbf": MTBFS,
                "failures.requeue": ("requeue", "abort")},
               rows, report, validate=validate)

    # checkpoint-interval tuning at the harshest MTBF (requeue only)
    _grid_rows("ckpt", base,
               {"failures.checkpoint_interval": CKPTS},
               rows, report, validate=validate)

    if not validate:
        # the full run still oracle-checks one sampled (harshest-MTBF)
        # point; the smoke pass validates every point
        import numpy as np

        from repro.api import run, run_ref

        probe = base.with_(**{"failures.mtbf": MTBFS[0]})
        res, ref = run(probe), run_ref(probe)
        assert res.matches(ref), "sampled oracle check failed"
        n = int(ref["valid"].sum())
        assert np.array_equal(res["n_restarts"][:n], ref["n_restarts"])
        print("# sampled oracle check ok", flush=True)

    common.series_to_csv(
        os.path.join(outdir, "fig_reliability.csv"),
        ["case", "axis1", "axis2", "goodput", "avg_wait", "p95_wait",
         "total_restarts", "n_aborted", "lost_node_s", "makespan"],
        rows)
    report["finished_unix"] = time.time()
    path = os.path.join(outdir, "fig_reliability.json")
    with open(path, "w") as f:
        json.dump(report, f, indent=1, sort_keys=True)
    print(f"# wrote {path}", flush=True)
    return report


def main():
    # horizon 2^19 s across 128 nodes at the harshest MTBF (50k s) expects
    # ~1.3k failures; capacity 2048 leaves headroom (truncation hard-fails)
    _run(2000, 2048, 1 << 19, validate=False)


def smoke():
    """CI dry pass: tiny trace + short horizon, every grid point validated
    vs refsim (schedules AND reliability columns)."""
    _run(120, 256, 1 << 15, validate=True, smoke=True)


if __name__ == "__main__":
    import sys

    smoke() if "--smoke" in sys.argv else main()
