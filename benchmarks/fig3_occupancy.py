"""Paper Fig. 3: node occupancy + active jobs over time, ours vs CQsim-analogue.

Emits results/fig3_occupancy.csv with both simulators' series sampled on a
common grid, plus an agreement metric (they must match exactly).
"""

from __future__ import annotations

import os

import numpy as np

from benchmarks.common import emit, series_to_csv, time_call
from repro.core import metrics
from repro.core.engine import simulate_np
from repro.refsim import simulate_reference
from repro.traces import das2_like

N_JOBS = 2000
NODES = 400


def main(outdir: str = "results") -> None:
    os.makedirs(outdir, exist_ok=True)
    trace = das2_like(N_JOBS, seed=42)

    t_ref = time_call(lambda: simulate_reference(trace, "fcfs", total_nodes=NODES),
                      warmup=0, iters=1)
    ref = simulate_reference(trace, "fcfs", total_nodes=NODES)
    t_jax = time_call(lambda: simulate_np(trace, "fcfs", total_nodes=NODES),
                      warmup=1, iters=1)
    ours = simulate_np(trace, "fcfs", total_nodes=NODES)

    grid = np.linspace(0, ours["makespan"], 400)
    rows = []
    agree = {}
    for name, fn in (("occupancy", metrics.occupancy_series),
                     ("active_jobs", metrics.active_jobs_series),
                     ("queue_len", metrics.queue_length_series)):
        t1, v1 = fn(ours)
        t2, v2 = fn(ref)
        s1 = metrics.sample_series(t1, v1, grid)
        s2 = metrics.sample_series(t2, v2, grid)
        agree[name] = float(np.max(np.abs(s1 - s2)))
        rows.append((name, s1, s2))

    series_to_csv(
        os.path.join(outdir, "fig3_occupancy.csv"),
        ["t"] + [f"{n}_{src}" for n, _, _ in rows for src in ("ours", "ref")],
        [(float(g),) + tuple(float(x) for n, s1, s2 in rows for x in (s1[i], s2[i]))
         for i, g in enumerate(grid)],
    )
    emit("fig3_occupancy_jax", t_jax,
         f"max_series_diff={max(agree.values()):.1f};jobs={N_JOBS}")
    emit("fig3_occupancy_ref", t_ref, "reference_simulator")
    assert max(agree.values()) == 0.0, agree


if __name__ == "__main__":
    main()
