"""Serving figure (DESIGN.md §16): throughput-vs-SLO frontier.

The scenario family the serving subsystem opens: an interactive+batch
request mix under Poisson open arrivals, swept over an arrival-rate grid
under both queue policies with the queue-pressure autoscaler on and off.
Every grid point shares one static bucket (rate, class mix, and autoscale
thresholds are trace *data*; only ``max_jobs`` / ``max_ticks`` are static),
so the whole rate × policy × autoscale grid compiles to ONE executable.

The smoke pass validates EVERY grid point bit-exactly against the host
reference simulator (schedules, SLO verdicts, and the capacity log); the
full run oracle-checks a sampled highest-rate point.

Emits ``fig_serving/<policy>/<autoscale>/rate=<r>`` rows with
``attainment:p99_wait:goodput`` in the derived column; the table lands in
``results/fig_serving.csv`` and a machine-readable
``results/fig_serving.json`` — including the frontier (max sustainable
rate at >= 95% SLO attainment per policy × autoscale cell) — uploaded by
CI next to ``BENCH_engine.json``.
"""

from __future__ import annotations

import dataclasses
import json
import os
import time

from benchmarks import common
from repro.api import (
    AutoscalePolicy, Scenario, ServiceClass, ServiceTrace, run_ref, sweep,
)

# offered load ~= rate * E[nodes * runtime] ~= rate * 1461 node-s/request
# on 64 nodes -> saturation near rate 0.044: the grid spans under- to
# over-subscribed so the attainment frontier sits strictly inside it
RATES = (0.010, 0.020, 0.030, 0.040, 0.050)
ATTAINMENT_TARGET = 0.95

CLASSES = (
    ServiceClass("interactive", nodes=1, mean_runtime=30, slo_wait=60),
    ServiceClass("batch", nodes=8, mean_runtime=600, dist="exponential",
                 slo_wait=1800, weight=0.3),
)


def _base(horizon: int, max_jobs: int, max_ticks: int) -> Scenario:
    auto = AutoscalePolicy(up_threshold=48, down_threshold=8, min_nodes=16,
                           max_nodes=64, step=8, interval=256,
                           max_ticks=max_ticks)
    return Scenario(
        trace=ServiceTrace(horizon=horizon, rate=RATES[0], seed=5,
                           max_jobs=max_jobs, classes=CLASSES,
                           autoscale=auto),
        total_nodes=64, policy="fcfs")


def _run(horizon: int, max_jobs: int, max_ticks: int, *, validate: bool,
         outdir: str = "results", smoke: bool = False):
    import numpy as np

    os.makedirs(outdir, exist_ok=True)
    report = {"schema": 1, "smoke": smoke, "generated_unix": time.time(),
              "points": [], "frontier": {}}
    base = _base(horizon, max_jobs, max_ticks)
    auto_on = base.trace.autoscale
    axes = {
        "trace.rate": RATES,
        "policy": ("fcfs", "sjf"),
        "trace.autoscale": (auto_on,
                            dataclasses.replace(auto_on, enabled=False)),
    }

    grid_holder = []

    def run_grid():
        grid_holder[:] = [sweep(base, axes=axes)]
        return [r.raw.n_events for r in grid_holder[0].results]

    secs = common.time_call(run_grid, warmup=1, iters=1)
    grid = grid_holder[0]
    # rate / policy / thresholds are vmap data: the frontier is ONE compile
    assert grid.n_compiles == 1, grid.n_compiles

    rows = []
    for point, res in grid:
        if validate:
            ref = run_ref(res.scenario)
            assert res.matches(ref), point
            n = int(ref["valid"].sum())
            for col in ("slo_met", "deadline", "class_id"):
                assert np.array_equal(res[col][:n], ref[col]), (point, col)
            assert np.array_equal(res["cap_online"], ref["cap_online"]), point
        s = res.summary()
        scaled = point["trace.autoscale"].enabled
        label = (f"{point['policy']}/{'auto' if scaled else 'fixed'}"
                 f"/rate={point['trace.rate']}")
        derived = (f"{s['slo_attainment']:.4f}:{s['p99_wait']:.1f}"
                   f":{s['slo_goodput']:.4f}")
        common.emit(f"fig_serving/{label}", secs / len(grid), derived)
        rows.append((point["policy"], "auto" if scaled else "fixed",
                     point["trace.rate"], s["slo_attainment"],
                     s["deadline_miss_rate"], s["p50_wait"], s["p99_wait"],
                     s["slo_goodput"], s["n_requests"], s["makespan"]))
        report["points"].append({
            "policy": point["policy"], "autoscale": bool(scaled),
            "rate": point["trace.rate"],
            **{k: s[k] for k in ("slo_attainment", "deadline_miss_rate",
                                 "p50_wait", "p99_wait", "slo_goodput",
                                 "n_requests", "makespan")}})

    # frontier: max rate whose attainment clears the target, per cell
    for pol in axes["policy"]:
        for scaled in (True, False):
            ok = [p["rate"] for p in report["points"]
                  if p["policy"] == pol and p["autoscale"] is scaled
                  and p["slo_attainment"] >= ATTAINMENT_TARGET]
            report["frontier"][f"{pol}/{'auto' if scaled else 'fixed'}"] = (
                max(ok) if ok else None)

    if not validate:
        # the full run still oracle-checks one sampled (highest-rate) point
        probe = grid.get(**{"trace.rate": RATES[-1], "policy": "fcfs",
                            "trace.autoscale": auto_on})
        ref = run_ref(probe.scenario)
        assert probe.matches(ref), "sampled oracle check failed"
        print("# sampled oracle check ok", flush=True)

    common.series_to_csv(
        os.path.join(outdir, "fig_serving.csv"),
        ["policy", "autoscale", "rate", "slo_attainment",
         "deadline_miss_rate", "p50_wait", "p99_wait", "slo_goodput",
         "n_requests", "makespan"],
        rows)
    report["finished_unix"] = time.time()
    path = os.path.join(outdir, "fig_serving.json")
    with open(path, "w") as f:
        json.dump(report, f, indent=1, sort_keys=True)
    print(f"# wrote {path}", flush=True)
    return report


def main():
    # 2^16 s horizon at the top rate generates ~3.3k requests; max_jobs
    # 4096 leaves headroom (materialize warns loudly on truncation)
    _run(1 << 16, 4096, 256, validate=False)


def smoke():
    """CI dry pass: short horizon, every grid point validated vs refsim
    (schedules, SLO verdicts, and capacity logs)."""
    _run(4096, 256, 16, validate=True, smoke=True)


if __name__ == "__main__":
    import sys

    smoke() if "--smoke" in sys.argv else main()
