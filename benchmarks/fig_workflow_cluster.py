"""Workflow DAGs scheduled onto the cluster (DESIGN.md §13).

The paper's Fig. 6/7 workflows run here as first-class cluster jobs: one
Galactic Plane DAG swept over policy × allocation strategy in a single
compiled executable, reporting the ready-time wait (Fig. 7 metric),
makespan and locality per grid point — the two-level scheduling study
(workflow structure × batch scheduler × placement) that the standalone
pool engine cannot express.
"""

from __future__ import annotations

import os

from benchmarks.common import emit, series_to_csv, time_call
from repro.api import Scenario, Topology, WorkflowTrace, run_ref, sweep

POLICIES = ("fcfs", "sjf", "backfill", "bestfit")
ALLOCS = ("simple", "contiguous", "topo")


def _grid(scn: Scenario, policies, allocs):
    return sweep(scn, axes={"policy": policies, "alloc": allocs})


def main(outdir: str = "results") -> None:
    os.makedirs(outdir, exist_ok=True)
    scn = Scenario(
        trace=WorkflowTrace(kind="galactic",
                            params=(("tiles", 8), ("width", 12))),
        topology=Topology.dragonfly(8, 8), policy="fcfs",
        contention=(1, 5),
    )
    secs = time_call(lambda: _grid(scn, POLICIES, ALLOCS), warmup=1, iters=2)
    grid = _grid(scn, POLICIES, ALLOCS)
    assert grid.n_compiles == 1, grid.n_compiles
    rows = []
    for point, res in grid:
        s = res.summary()
        rows.append((point["policy"], point["alloc"], int(s["n_jobs"]),
                     f"{s['avg_wait']:.1f}", f"{s['p95_wait']:.1f}",
                     int(s["makespan"]), f"{s['utilization']:.3f}",
                     f"{s['mean_job_span']:.2f}"))
    emit("fig_workflow_cluster_grid", secs / len(grid),
         f"points={len(grid)};compiles={grid.n_compiles}")
    # spot-validate one corner of the grid against the reference simulator
    corner = grid.get(policy="backfill", alloc="topo")
    assert corner.matches(run_ref(corner.scenario), node_maps=True)
    series_to_csv(os.path.join(outdir, "fig_workflow_cluster.csv"),
                  ["policy", "alloc", "tasks", "avg_wait", "p95_wait",
                   "makespan", "utilization", "mean_job_span"], rows)


def smoke(outdir: str = "results") -> None:
    """CI dry pass: tiny DAG, 2x2 grid, one executable, ref-validated."""
    os.makedirs(outdir, exist_ok=True)
    scn = Scenario(
        trace=WorkflowTrace(kind="galactic",
                            params=(("tiles", 2), ("width", 6))),
        topology=Topology.mesh2d(4, 4), policy="fcfs",
    )
    grid = _grid(scn, ("fcfs", "backfill"), ("simple", "contiguous"))
    assert grid.n_compiles == 1, grid.n_compiles
    for point, res in grid:
        assert res.matches(run_ref(res.scenario), node_maps=True), point
    emit("fig_workflow_cluster_smoke", 0.0,
         f"points={len(grid)};makespan={grid[0].makespan}")


if __name__ == "__main__":
    main()
