"""Shared benchmark utilities: timing + CSV emission."""

from __future__ import annotations

import time
from typing import Callable, Iterable

import numpy as np


def time_call(fn: Callable, *, warmup: int = 1, iters: int = 3) -> float:
    """Median wall seconds per call (after warmup, block_until_ready-safe)."""
    for _ in range(warmup):
        _block(fn())
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        _block(fn())
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def _block(x):
    try:
        import jax
        jax.block_until_ready(x)
    except Exception:
        pass
    return x


def emit(name: str, seconds: float, derived: str = "") -> str:
    """`name,us_per_call,derived` CSV row (scaffold contract)."""
    row = f"{name},{seconds * 1e6:.1f},{derived}"
    print(row, flush=True)
    return row


def series_to_csv(path: str, header: Iterable[str], rows):
    import csv
    with open(path, "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(list(header))
        for r in rows:
            w.writerow(list(r))


def sweep_to_csv(path: str, grid, fields: Iterable[str]):
    """Write a ``repro.api.SweepResult`` to CSV: one row per grid point,
    axis values first, then the requested ``Result.summary()`` fields."""
    axis_names = list(grid.axes)
    fields = list(fields)
    rows = [
        [summary[a] for a in axis_names] + [summary[f] for f in fields]
        for summary in grid.summaries()
    ]
    series_to_csv(path, axis_names + fields, rows)
    return rows
