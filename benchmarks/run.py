"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows; artifacts land in results/.

    PYTHONPATH=src python -m benchmarks.run            # all
    PYTHONPATH=src python -m benchmarks.run fig5       # substring filter
"""

from __future__ import annotations

import sys
import time
import traceback

from benchmarks import (
    des_throughput, fig3_occupancy, fig4_policies, fig4_wait, fig5_scaling,
    fig6_workflow_scaling, fig7_workflow_wait, roofline_table,
)

BENCHES = [
    ("fig3_occupancy", fig3_occupancy.main),
    ("fig4_wait", fig4_wait.main),
    ("fig4_policies", fig4_policies.main),
    ("fig5_scaling", fig5_scaling.main),
    ("fig6_workflow_scaling", fig6_workflow_scaling.main),
    ("fig7_workflow_wait", fig7_workflow_wait.main),
    ("des_throughput", des_throughput.main),
    ("roofline_table", roofline_table.main),
]


def main() -> int:
    pattern = sys.argv[1] if len(sys.argv) > 1 else ""
    print("name,us_per_call,derived")
    failed = []
    for name, fn in BENCHES:
        if pattern and pattern not in name:
            continue
        t0 = time.time()
        try:
            fn()
            print(f"# {name} done in {time.time() - t0:.1f}s", flush=True)
        except Exception as e:
            failed.append(name)
            traceback.print_exc()
            print(f"# {name} FAILED: {e}", flush=True)
    if failed:
        print(f"# FAILED benches: {failed}")
        return 1
    print("# all benches passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
