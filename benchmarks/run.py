"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows; artifacts land in results/.

    PYTHONPATH=src python -m benchmarks.run            # all
    PYTHONPATH=src python -m benchmarks.run fig5       # substring filter
    PYTHONPATH=src python -m benchmarks.run --smoke    # CI dry pass: run the
                                                       # tiny smoke() variant
                                                       # of benches that have
                                                       # one, skip the rest
"""

from __future__ import annotations

import sys
import time
import traceback

from benchmarks import (
    des_throughput, fig3_occupancy, fig4_policies, fig4_wait, fig5_scaling,
    fig6_workflow_scaling, fig7_workflow_wait, fig_alloc, fig_malleable,
    fig_reliability, fig_serving, fig_whatif, fig_workflow_cluster,
    roofline_table,
)

BENCHES = [
    ("fig3_occupancy", fig3_occupancy),
    ("fig4_wait", fig4_wait),
    ("fig4_policies", fig4_policies),
    ("fig5_scaling", fig5_scaling),
    ("fig6_workflow_scaling", fig6_workflow_scaling),
    ("fig7_workflow_wait", fig7_workflow_wait),
    ("fig_workflow_cluster", fig_workflow_cluster),
    ("fig_alloc", fig_alloc),
    ("fig_reliability", fig_reliability),
    ("fig_serving", fig_serving),
    ("fig_malleable", fig_malleable),
    ("fig_whatif", fig_whatif),
    ("des_throughput", des_throughput),
    ("roofline_table", roofline_table),
]


def main() -> int:
    args = sys.argv[1:]
    smoke = "--smoke" in args
    args = [a for a in args if a != "--smoke"]
    pattern = args[0] if args else ""
    print("name,us_per_call,derived")
    failed = []
    for name, mod in BENCHES:
        if pattern and pattern not in name:
            continue
        fn = getattr(mod, "smoke", None) if smoke else mod.main
        if fn is None:
            print(f"# {name} skipped (no smoke variant)", flush=True)
            continue
        t0 = time.time()
        try:
            fn()
            print(f"# {name} done in {time.time() - t0:.1f}s", flush=True)
        except Exception as e:
            failed.append(name)
            traceback.print_exc()
            print(f"# {name} FAILED: {e}", flush=True)
    if failed:
        print(f"# FAILED benches: {failed}")
        return 1
    print("# all benches passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
