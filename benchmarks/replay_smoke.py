"""CI replay smoke (DESIGN.md §19): archive-scale crash-safe replay.

A ~200k-job synthetic archive goes through the full streaming path:

1. ``dump_swf`` -> ``load_swf`` round trip on a gzipped SWF (the archive
   itself lands in ``results/`` as a CI artifact);
2. a bounded-window streaming replay of the whole archive (memory stays
   O(window), not O(trace));
3. a forced kill at a mid checkpoint round followed by ``resume()`` on a
   prefix, byte-compared against the uninterrupted run;
4. an exact cross-check of the replayed prefix against the int64 host
   reference simulator (start/finish/wait column-for-column).

Everything is asserted, so a regression fails the CI step loudly; the
timings and summaries land in ``results/replay_smoke.json`` for the perf
trajectory.  ``--smoke`` shrinks the sizes for a quick local pass.
"""

from __future__ import annotations

import dataclasses
import json
import os
import tempfile
import time

import numpy as np

from benchmarks.common import emit
from repro.refsim import replay_reference
from repro.replay import (
    ReplayInterrupted, StreamingReplay, replay_trace, resume,
)
from repro.traces import dump_swf, load_swf, synthetic_trace

OUT_JSON = "replay_smoke.json"
ARCHIVE = "synthetic_200k.swf.gz"
TOTAL_NODES = 128
WINDOW = 4096


def _assert_identical(a, b) -> None:
    """Byte-identical ReplayResults (every array field + every scalar)."""
    for f in dataclasses.fields(a):
        x, y = getattr(a, f.name), getattr(b, f.name)
        if isinstance(x, np.ndarray):
            np.testing.assert_array_equal(x, y, err_msg=f.name)
        elif f.name == "flags":
            assert x.as_dict() == y.as_dict(), (x, y)
        else:
            assert x == y, f"{f.name}: {x} != {y}"


def run_smoke(outdir: str = "results", *, n_jobs: int = 200_000,
              prefix: int = 20_000, smoke: bool = False) -> dict:
    if smoke:
        n_jobs, prefix = 20_000, 4_000
    os.makedirs(outdir, exist_ok=True)
    report: dict = {"schema": 1, "smoke": smoke, "n_jobs": n_jobs,
                    "prefix_jobs": prefix, "total_nodes": TOTAL_NODES,
                    "window": WINDOW, "generated_unix": time.time()}

    # 1. materialize the archive and round-trip it through the SWF loader
    # ~0.76 offered utilization on 128 nodes: the backlog stays inside the
    # window, so the replay demonstrates bounded memory rather than the
    # doubling ladder (the ladder is pinned by tests/test_replay.py)
    trace = synthetic_trace(n_jobs, seed=3, mean_interarrival=220.0)
    path = os.path.join(outdir, ARCHIVE)
    t0 = time.perf_counter()
    n = dump_swf(path, trace, comment=f"synthetic replay smoke ({n_jobs} jobs)")
    loaded, rep = load_swf(path, rebase=False)
    t_io = time.perf_counter() - t0
    assert n == n_jobs and rep.n_jobs == n_jobs, rep.summary()
    assert rep.n_quarantined == 0, rep.summary()
    for key in ("submit", "runtime", "nodes", "estimate"):
        np.testing.assert_array_equal(
            np.asarray(trace[key], dtype=np.int64), loaded[key], err_msg=key)
    report["swf_round_trip_s"] = t_io
    report["swf_bytes"] = os.path.getsize(path)
    emit("replay_smoke_swf_round_trip", t_io, f"bytes={report['swf_bytes']}")

    # 2. full-archive streaming replay off the loaded SWF arrays
    t0 = time.perf_counter()
    full = replay_trace(loaded, "backfill", total_nodes=TOTAL_NODES,
                        window=WINDOW)
    t_full = time.perf_counter() - t0
    s = full.summary()
    assert s["n_done"] + s["n_aborted"] == n_jobs, s
    assert s["peak_live"] <= s["window"], s
    report["replay_s"] = t_full
    report["jobs_per_s"] = n_jobs / t_full
    report["summary"] = s
    emit("replay_smoke_full", t_full,
         f"jobs_per_s={n_jobs / t_full:.0f};rounds={s['n_rounds']};"
         f"peak_live={s['peak_live']}")

    # 3. forced kill at a checkpointed round, then a bit-exact resume; a
    # small window forces many rounds so the kill lands mid-trace
    pwin = 512
    pfx = {k: v[:prefix] for k, v in loaded.items()}
    straight = replay_trace(dict(pfx), "backfill", total_nodes=TOTAL_NODES,
                            window=pwin)
    t0 = time.perf_counter()
    with tempfile.TemporaryDirectory() as ckpt:
        try:
            StreamingReplay(dict(pfx), "backfill", total_nodes=TOTAL_NODES,
                            window=pwin, ckpt_dir=ckpt, ckpt_every=1,
                            _crash_after_round=2).run()
            raise AssertionError("crash hook never fired — raise prefix size")
        except ReplayInterrupted:
            pass
        resumed = resume(ckpt, dict(pfx), "backfill",
                         total_nodes=TOTAL_NODES, window=pwin)
    _assert_identical(resumed, straight)
    report["kill_resume_s"] = time.perf_counter() - t0
    report["resume_identical"] = True
    emit("replay_smoke_kill_resume", report["kill_resume_s"],
         "byte_identical=True")

    # 4. the replayed prefix against the int64 host reference simulator
    t0 = time.perf_counter()
    ref = replay_reference(dict(pfx), "backfill", total_nodes=TOTAL_NODES)
    np.testing.assert_array_equal(straight.start, ref["start"])
    np.testing.assert_array_equal(straight.finish[straight.done],
                                  ref["finish"][ref["done"]])
    np.testing.assert_array_equal(straight.wait[straight.done],
                                  ref["wait"][ref["done"]])
    np.testing.assert_array_equal(straight.done, ref["done"])
    assert straight.n_events == int(ref["n_events"])
    report["refsim_s"] = time.perf_counter() - t0
    report["refsim_match"] = True
    emit("replay_smoke_refsim", report["refsim_s"], "column_exact=True")

    report["finished_unix"] = time.time()
    out = os.path.join(outdir, OUT_JSON)
    with open(out, "w") as f:
        json.dump(report, f, indent=1, sort_keys=True)
    print(f"# wrote {out}", flush=True)
    return report


def main(outdir: str = "results") -> None:
    run_smoke(outdir, smoke=False)


def smoke(outdir: str = "results") -> None:
    run_smoke(outdir, smoke=True)


if __name__ == "__main__":
    import sys
    smoke() if "--smoke" in sys.argv else main()
