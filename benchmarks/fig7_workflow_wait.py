"""Paper Fig. 7: SIPHT workflow wait-time validation vs the reference."""

from __future__ import annotations

import os

import numpy as np

from benchmarks.common import emit, series_to_csv
from repro.core.workflow import (
    WF_POLICY_IDS, make_taskset, simulate_workflow, workflow_result_np,
)
from repro.refsim.workflow import simulate_workflow_reference
from repro.traces import workflows as W

POOLS = np.array([8, 8192])


def main(outdir: str = "results") -> None:
    os.makedirs(outdir, exist_ok=True)
    rows = []
    for width in (10, 30, 60):
        wf = W.sipht_like(width, seed=width)
        ts = make_taskset(wf["exec_time"], wf["resources"], wf["dep_pairs"])
        ours = workflow_result_np(
            ts, simulate_workflow(ts, POOLS, WF_POLICY_IDS["fcfs"]))
        ref = simulate_workflow_reference(
            wf["exec_time"], wf["resources"], wf["dep_pairs"], POOLS, "fcfs")
        n = len(ref["wait"])
        exact = int((ours["wait"][:n] == ref["wait"]).sum())
        rows.append((width, n, exact, float(ours["wait"][:n].mean()),
                     float(ref["wait"].mean()), int(ours["makespan"]),
                     int(ref["makespan"])))
        emit(f"fig7_sipht_w{width}", 0.0,
             f"exact_match={exact}/{n};makespan={ours['makespan']}")
        assert exact == n
    series_to_csv(os.path.join(outdir, "fig7_workflow_wait.csv"),
                  ["width", "tasks", "exact", "mean_wait_ours",
                   "mean_wait_ref", "makespan_ours", "makespan_ref"], rows)


if __name__ == "__main__":
    main()
