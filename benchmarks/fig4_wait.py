"""Paper Fig. 4(a): per-job wait-time validation vs the reference simulator,
on DAS-2-like and SDSC-SP2-like traces — both engines driven from the SAME
``Scenario`` spec."""

from __future__ import annotations

import os

import numpy as np

from benchmarks.common import emit, series_to_csv
from repro.api import Scenario, SyntheticTrace, run, run_ref


def main(outdir: str = "results") -> None:
    os.makedirs(outdir, exist_ok=True)
    rows = []
    for trace_name, kind, seed, nodes in (
        ("das2", "das2", 1, 400),
        ("sdsc_sp2", "sdsc_sp2", 2, 128),
    ):
        scn = Scenario(trace=SyntheticTrace(n_jobs=2000, seed=seed, kind=kind),
                       total_nodes=nodes, policy="backfill")
        ours = run(scn).to_np()
        ref = run_ref(scn).to_np()
        n = len(ref["wait"])
        exact = int((ours["wait"][:n] == ref["wait"]).sum())
        rows.append((trace_name, n, exact,
                     float(ours["wait"][:n].mean()), float(ref["wait"].mean()),
                     float(np.percentile(ours["wait"][:n], 95)),
                     float(np.percentile(ref["wait"], 95))))
        emit(f"fig4a_wait_{trace_name}", 0.0,
             f"exact_match={exact}/{n};mean_ours={rows[-1][3]:.1f};"
             f"mean_ref={rows[-1][4]:.1f}")
        assert exact == n
    series_to_csv(os.path.join(outdir, "fig4_wait.csv"),
                  ["trace", "jobs", "exact_match", "mean_ours", "mean_ref",
                   "p95_ours", "p95_ref"], rows)


if __name__ == "__main__":
    main()
