"""Paper Fig. 4(a): per-job wait-time validation vs the reference simulator,
on DAS-2-like and SDSC-SP2-like traces."""

from __future__ import annotations

import os

import numpy as np

from benchmarks.common import emit, series_to_csv
from repro.core.engine import simulate_np
from repro.refsim import simulate_reference
from repro.traces import das2_like, sdsc_sp2_like


def main(outdir: str = "results") -> None:
    os.makedirs(outdir, exist_ok=True)
    rows = []
    for trace_name, trace, nodes in (
        ("das2", das2_like(2000, seed=1), 400),
        ("sdsc_sp2", sdsc_sp2_like(2000, seed=2), 128),
    ):
        ours = simulate_np(trace, "backfill", total_nodes=nodes)
        ref = simulate_reference(trace, "backfill", total_nodes=nodes)
        n = len(ref["wait"])
        exact = int((ours["wait"][:n] == ref["wait"]).sum())
        rows.append((trace_name, n, exact,
                     float(ours["wait"][:n].mean()), float(ref["wait"].mean()),
                     float(np.percentile(ours["wait"][:n], 95)),
                     float(np.percentile(ref["wait"], 95))))
        emit(f"fig4a_wait_{trace_name}", 0.0,
             f"exact_match={exact}/{n};mean_ours={rows[-1][3]:.1f};"
             f"mean_ref={rows[-1][4]:.1f}")
        assert exact == n
    series_to_csv(os.path.join(outdir, "fig4_wait.csv"),
                  ["trace", "jobs", "exact_match", "mean_ours", "mean_ref",
                   "p95_ours", "p95_ref"], rows)


if __name__ == "__main__":
    main()
